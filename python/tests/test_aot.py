"""AOT path tests: artifacts build, HLO text parses, profiles are sane,
and the L2 workload graphs match direct kernel composition.
"""

import json
import pathlib

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


@pytest.fixture(scope="module")
def manifest(tmp_path_factory):
    """Build all artifacts once into a temp dir (keeps the real artifacts/
    directory owned by `make artifacts`)."""
    out = tmp_path_factory.mktemp("artifacts")
    return aot.build(out), out


class TestRegistry:
    def test_variant_names_unique(self):
        names = [v.name for v in model.variants()]
        assert len(names) == len(set(names))

    def test_all_apps_covered(self):
        apps = {v.app for v in model.variants()}
        assert apps == {"ep", "blackscholes", "electrostatics", "smith_waterman"}

    def test_variant_by_name(self):
        v = model.variant_by_name("ep_16k")
        assert v.app == "ep"
        with pytest.raises(KeyError):
            model.variant_by_name("nope")


class TestAotBuild:
    def test_every_variant_has_artifact(self, manifest):
        m, out = manifest
        for name, entry in m["variants"].items():
            hlo = out / entry["hlo"]
            assert hlo.exists(), name
            text = hlo.read_text()
            assert text.startswith("HloModule"), f"{name} not HLO text"
            assert "ENTRY" in text

    def test_profiles_json_written(self, manifest):
        m, out = manifest
        on_disk = json.loads((out / "profiles.json").read_text())
        assert on_disk == m

    def test_profile_quantities_positive(self, manifest):
        m, _ = manifest
        for name, entry in m["variants"].items():
            p = entry["profile"]
            assert p["instructions"] > 0, name
            assert p["bytes_accessed"] > 0, name
            assert p["ratio"] > 0, name

    def test_compute_vs_memory_bound_ordering(self, manifest):
        """BlackScholes must profile as more compute-bound than EP — the
        paper's central workload contrast (R_bs=11.1 > R_B > R_ep=3.11)."""
        m, _ = manifest
        r = {e["app"]: e["profile"]["ratio"] for e in m["variants"].values()}
        assert r["blackscholes"] > r["ep"]
        # ES (n^2 compute over n data) is the most compute-bound of all.
        assert r["electrostatics"] > r["blackscholes"]

    def test_input_specs_recorded(self, manifest):
        m, _ = manifest
        ep_entry = m["variants"]["ep_16k"]
        assert ep_entry["inputs"] == [{"shape": [16384], "dtype": "uint32"}]


class TestWorkloadGraphs:
    """The L2 graphs (what actually lowers to HLO) vs oracle math."""

    def test_ep_workload(self):
        seeds = jnp.arange(2048, dtype=jnp.uint32)
        np.testing.assert_allclose(
            model.ep_workload(seeds), ref.ep_ref(seeds), rtol=1e-5, atol=1e-3
        )

    def test_blackscholes_workload_finite(self):
        idx = jnp.arange(2048, dtype=jnp.uint32)
        call, put = model.blackscholes_workload(idx)
        assert np.isfinite(np.asarray(call)).all()
        assert np.isfinite(np.asarray(put)).all()
        assert (np.asarray(call) >= -1e-3).all()

    def test_electrostatics_workload_matches_ref(self):
        ps = jnp.arange(256, dtype=jnp.uint32)
        as_ = jnp.arange(128, dtype=jnp.uint32)
        got = model.electrostatics_workload(ps, as_)

        # Rebuild the same synthesized geometry and check against the oracle.
        def coords(seed, scale):
            f = np.asarray(seed, np.float32)
            return np.stack(
                [
                    (f * 0.6180339887) % 1.0 * scale,
                    (f * 0.7548776662) % 1.0 * scale,
                    (f * 0.5698402910) % 1.0 * scale,
                ],
                axis=1,
            )

        points = coords(ps, 16.0)
        axyz = coords(np.asarray(as_) * np.uint32(2654435761), 16.0)
        q = ((np.asarray(as_, np.float32) * 0.3819660113) % 1.0) * 2.0 - 1.0
        atoms = np.concatenate([axyz, q[:, None]], axis=1).astype(np.float32)
        want = ref.electrostatics_ref(jnp.asarray(points), jnp.asarray(atoms))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)

    def test_sw_workload_roundtrip(self):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.integers(0, 4, (32, 12)).astype(np.int32))
        d = jnp.asarray(rng.integers(0, 4, (32, 12)).astype(np.int32))
        got = model.smith_waterman_workload(q, d)
        np.testing.assert_allclose(got, ref.smith_waterman_ref(q, d))


class TestHloTextInterchange:
    def test_hlo_text_reparses_via_xla_client(self, manifest):
        """The text we ship must be accepted by an HLO parser (the same
        grammar the rust side's HloModuleProto::from_text_file uses)."""
        _, out = manifest
        from jax._src.lib import xla_client as xc

        for hlo in out.glob("*.hlo.txt"):
            # mlir->computation->text->computation roundtrip: re-parse text.
            comp = xc._xla.hlo_module_from_text(hlo.read_text())
            assert comp is not None
