"""L1 correctness: every Pallas kernel vs its pure ref.py oracle.

Hypothesis sweeps shapes and seeds; fixed-size smoke tests pin the exact
variant sizes that aot.py ships.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.blackscholes import blackscholes
from compile.kernels.electrostatics import electrostatics
from compile.kernels.ep import ep, OUT_LEN, N_BINS
from compile.kernels.smith_waterman import smith_waterman

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# EP
# ---------------------------------------------------------------------------


class TestEp:
    def test_matches_ref_fixed(self):
        seeds = jnp.arange(16384, dtype=jnp.uint32)
        got = ep(seeds)
        want = ref.ep_ref(seeds)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)

    @settings(max_examples=20, deadline=None)
    @given(
        ntiles=st.integers(1, 8),
        tile=st.sampled_from([128, 256, 512]),
        seed0=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, ntiles, tile, seed0):
        n = ntiles * tile
        seeds = jnp.uint32(seed0) + jnp.arange(n, dtype=jnp.uint32)
        got = ep(seeds, tile=tile)
        want = ref.ep_ref(seeds)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)

    def test_output_shape_and_invariants(self):
        seeds = jnp.arange(2048, dtype=jnp.uint32)
        out = ep(seeds)
        assert out.shape == (OUT_LEN,)
        counts, accepted = out[:N_BINS], out[N_BINS + 2]
        # Every accepted pair lands in exactly one annulus.
        assert float(jnp.sum(counts)) == pytest.approx(float(accepted))
        # Marsaglia acceptance rate is ~pi/4.
        assert 0.7 < float(accepted) / 2048 < 0.87

    def test_tile_decomposition_invariance(self):
        seeds = jnp.arange(4096, dtype=jnp.uint32)
        a = ep(seeds, tile=512)
        b = ep(seeds, tile=2048)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-2)

    def test_deterministic(self):
        seeds = jnp.arange(2048, dtype=jnp.uint32) + jnp.uint32(7)
        np.testing.assert_array_equal(ep(seeds), ep(seeds))


# ---------------------------------------------------------------------------
# BlackScholes
# ---------------------------------------------------------------------------


def _bs_inputs(n, seed=0):
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.uniform(5.0, 30.0, n).astype(np.float32))
    x = jnp.asarray(rng.uniform(1.0, 100.0, n).astype(np.float32))
    t = jnp.asarray(rng.uniform(0.25, 10.0, n).astype(np.float32))
    return s, x, t


class TestBlackScholes:
    def test_matches_ref_fixed(self):
        s, x, t = _bs_inputs(16384)
        call, put = blackscholes(s, x, t)
        call_w, put_w = ref.blackscholes_ref(s, x, t)
        np.testing.assert_allclose(call, call_w, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(put, put_w, rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        ntiles=st.integers(1, 6),
        tile=st.sampled_from([128, 512, 1024]),
        seed=st.integers(0, 1000),
    )
    def test_matches_ref_hypothesis(self, ntiles, tile, seed):
        s, x, t = _bs_inputs(ntiles * tile, seed)
        call, put = blackscholes(s, x, t, tile=tile)
        call_w, put_w = ref.blackscholes_ref(s, x, t)
        np.testing.assert_allclose(call, call_w, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(put, put_w, rtol=1e-5, atol=1e-5)

    def test_put_call_parity(self):
        from compile.kernels.blackscholes import RISKFREE

        s, x, t = _bs_inputs(4096, seed=3)
        call, put = blackscholes(s, x, t)
        parity = np.asarray(call) - np.asarray(put)
        want = np.asarray(s) - np.asarray(x) * np.exp(-RISKFREE * np.asarray(t))
        np.testing.assert_allclose(parity, want, rtol=2e-4, atol=2e-3)

    def test_call_price_bounds(self):
        s, x, t = _bs_inputs(4096, seed=5)
        call, _ = blackscholes(s, x, t)
        c = np.asarray(call)
        assert (c >= -1e-3).all()
        assert (c <= np.asarray(s) + 1e-3).all()


# ---------------------------------------------------------------------------
# Electrostatics
# ---------------------------------------------------------------------------


def _es_inputs(n_points, n_atoms, seed=0):
    rng = np.random.default_rng(seed)
    points = jnp.asarray(rng.uniform(0, 16, (n_points, 3)).astype(np.float32))
    atoms = jnp.asarray(
        np.concatenate(
            [
                rng.uniform(0, 16, (n_atoms, 3)),
                rng.uniform(-1, 1, (n_atoms, 1)),
            ],
            axis=1,
        ).astype(np.float32)
    )
    return points, atoms


class TestElectrostatics:
    def test_matches_ref_fixed(self):
        points, atoms = _es_inputs(1024, 512)
        got = electrostatics(points, atoms)
        want = ref.electrostatics_ref(points, atoms)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(
        pt=st.sampled_from([64, 128, 256]),
        np_tiles=st.integers(1, 4),
        at=st.sampled_from([32, 64, 128]),
        na_tiles=st.integers(1, 4),
        seed=st.integers(0, 1000),
    )
    def test_matches_ref_hypothesis(self, pt, np_tiles, at, na_tiles, seed):
        points, atoms = _es_inputs(pt * np_tiles, at * na_tiles, seed)
        got = electrostatics(points, atoms, tile_points=pt, tile_atoms=at)
        want = ref.electrostatics_ref(points, atoms)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)

    def test_superposition_linearity(self):
        """Potential of union == sum of potentials (atom-tile accumulation)."""
        points, atoms = _es_inputs(128, 128, seed=9)
        a1, a2 = atoms[:64], atoms[64:]
        whole = electrostatics(points, atoms, tile_points=128, tile_atoms=64)
        parts = ref.electrostatics_ref(points, a1) + ref.electrostatics_ref(
            points, a2
        )
        np.testing.assert_allclose(whole, parts, rtol=1e-4, atol=1e-3)

    def test_charge_sign(self):
        """A single positive charge yields positive potential everywhere."""
        points, _ = _es_inputs(64, 1, seed=1)
        atom = jnp.asarray([[8.0, 8.0, 8.0, 1.0]], dtype=jnp.float32)
        pot = ref.electrostatics_ref(points, atom)
        assert (np.asarray(pot) > 0).all()


# ---------------------------------------------------------------------------
# Smith-Waterman
# ---------------------------------------------------------------------------


def _sw_inputs(batch, lq, ld, seed=0, alphabet=4):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(0, alphabet, (batch, lq)).astype(np.int32))
    d = jnp.asarray(rng.integers(0, alphabet, (batch, ld)).astype(np.int32))
    return q, d


class TestSmithWaterman:
    def test_matches_ref_fixed(self):
        q, d = _sw_inputs(32, 24, 24)
        got = smith_waterman(q, d, tile=32)
        want = ref.smith_waterman_ref(q, d)
        np.testing.assert_allclose(got, want, rtol=0, atol=0)

    @settings(max_examples=10, deadline=None)
    @given(
        tiles=st.integers(1, 2),
        tile=st.sampled_from([8, 16]),
        lq=st.integers(1, 20),
        ld=st.integers(1, 20),
        seed=st.integers(0, 1000),
    )
    def test_matches_ref_hypothesis(self, tiles, tile, lq, ld, seed):
        q, d = _sw_inputs(tiles * tile, lq, ld, seed)
        got = smith_waterman(q, d, tile=tile)
        want = ref.smith_waterman_ref(q, d)
        np.testing.assert_allclose(got, want, rtol=0, atol=0)

    def test_identical_sequences_score(self):
        """Aligning a sequence against itself scores len * MATCH."""
        from compile.kernels.smith_waterman import MATCH

        q = jnp.asarray(np.tile(np.arange(16, dtype=np.int32), (8, 1)))
        got = smith_waterman(q, q, tile=8)
        np.testing.assert_allclose(got, np.full(8, 16 * MATCH, np.float32))

    def test_disjoint_alphabets_score_zero(self):
        q = jnp.zeros((8, 12), jnp.int32)
        d = jnp.ones((8, 12), jnp.int32)
        got = smith_waterman(q, d, tile=8)
        np.testing.assert_allclose(got, np.zeros(8, np.float32))

    def test_substring_found(self):
        """A planted exact substring is recovered with full score."""
        from compile.kernels.smith_waterman import MATCH

        rng = np.random.default_rng(4)
        q = rng.integers(10, 20, (8, 10)).astype(np.int32)  # alphabet 10..19
        d = rng.integers(20, 30, (8, 30)).astype(np.int32)  # alphabet 20..29
        d[:, 7:17] = q  # plant the query
        got = smith_waterman(jnp.asarray(q), jnp.asarray(d), tile=8)
        np.testing.assert_allclose(got, np.full(8, 10 * MATCH, np.float32))
