"""AOT compile path: lower every L2 variant to HLO *text* + profiles.json.

HLO text (NOT ``lowered.compile().serialize()`` / serialized HloModuleProto)
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/load_hlo and README.md gotchas.

profiles.json plays the role of the paper's CUDA-profiler pass: per kernel it
records flops, bytes accessed, and the instructions/bytes ratio R_i that
Algorithm 1 consumes, derived from XLA's HLO cost analysis of the lowered
module (our stand-in for `#inst / 4*(stores + L1 global-load misses)`).

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import variants


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def cost_profile(lowered) -> dict:
    """XLA cost analysis -> the paper's per-kernel profile quantities."""
    ca = lowered.compile().cost_analysis()
    flops = float(ca.get("flops", 0.0))
    transcendentals = float(ca.get("transcendentals", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    # Weight transcendentals like the SFU-heavy instructions they are on real
    # hardware (a GTX580 SFU op retires ~4x slower than an FMA).
    inst = flops + 4.0 * transcendentals
    # Paper: R_i = #inst / (4 * (#global stores + #L1 global-load misses)).
    # XLA reports bytes, i.e. 4 bytes per 32-bit transaction -> the paper's
    # denominator is exactly `bytes accessed` for f32 data.
    ratio = inst / byts if byts > 0 else 0.0
    return {
        "flops": flops,
        "transcendentals": transcendentals,
        "bytes_accessed": byts,
        "instructions": inst,
        "ratio": ratio,
    }


def build(out_dir: pathlib.Path) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest: dict = {"format": 1, "variants": {}}
    for v in variants():
        lowered = jax.jit(v.fn).lower(*v.in_specs)
        text = to_hlo_text(lowered)
        hlo_path = out_dir / f"{v.name}.hlo.txt"
        hlo_path.write_text(text)
        prof = cost_profile(lowered)
        manifest["variants"][v.name] = {
            "app": v.app,
            "description": v.description,
            "hlo": hlo_path.name,
            "inputs": [
                {"shape": list(s.shape), "dtype": s.dtype.name} for s in v.in_specs
            ],
            "profile": prof,
        }
        print(
            f"  {v.name}: {len(text)} chars, "
            f"inst={prof['instructions']:.3g} bytes={prof['bytes_accessed']:.3g} "
            f"R={prof['ratio']:.3f}"
        )
    (out_dir / "profiles.json").write_text(json.dumps(manifest, indent=2))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    print(f"AOT-compiling {len(variants())} variants -> {out_dir}")
    build(out_dir)
    print("done")


if __name__ == "__main__":
    main()
