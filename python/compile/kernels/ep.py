"""L1 Pallas kernel: NPB EP (Embarrassingly Parallel) core.

The paper uses NAS Parallel Benchmarks EP (M=24) as its memory-bound exemplar
(R_ep = 3.11 < R_B). EP generates pairs of uniform deviates, applies the
Marsaglia polar acceptance test, produces Gaussian pairs, and tallies them
into ten square annuli while accumulating the coordinate sums.

Hardware adaptation (CUDA -> Pallas/TPU): the CUDA version assigns one
thread per sample and reduces per-block partial tallies in shared memory.
Here the grid iterates over contiguous sample tiles (BlockSpec carries the
HBM->VMEM schedule that threadblock tiling provided), each tile is processed
as a vector on the lane dimension, and the partial tallies are accumulated
into a single output block across grid steps — the Pallas idiom for a
shared-memory tree reduction.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import lcg_uniform

N_BINS = 10
# Output layout: [0:N_BINS] annulus counts, [N_BINS] = sum X, [N_BINS+1] = sum Y,
# [N_BINS+2] = number of accepted pairs.
OUT_LEN = N_BINS + 3


def _ep_kernel(seed_ref, o_ref, *, tile: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros((OUT_LEN,), jnp.float32)

    seeds = seed_ref[...]
    x = lcg_uniform(seeds, tile)
    y = lcg_uniform(seeds + np.uint32(0x9E3779B9), tile)

    t = x * x + y * y
    accept = (t <= 1.0) & (t > 0.0)
    # Guard the log against t==0 / rejected lanes.
    t_safe = jnp.where(accept, t, 0.5)
    factor = jnp.sqrt(-2.0 * jnp.log(t_safe) / t_safe)
    gx = jnp.where(accept, x * factor, 0.0)
    gy = jnp.where(accept, y * factor, 0.0)

    mag = jnp.maximum(jnp.abs(gx), jnp.abs(gy))
    annulus = jnp.clip(mag.astype(jnp.int32), 0, N_BINS - 1)
    onehot = (annulus[:, None] == jnp.arange(N_BINS)[None, :]) & accept[:, None]
    counts = jnp.sum(onehot.astype(jnp.float32), axis=0)

    partial = jnp.concatenate(
        [
            counts,
            jnp.sum(gx, keepdims=True),
            jnp.sum(gy, keepdims=True),
            jnp.sum(accept.astype(jnp.float32), keepdims=True),
        ]
    )
    o_ref[...] = o_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("tile",))
def ep(seeds: jnp.ndarray, *, tile: int = 2048) -> jnp.ndarray:
    """Run the EP tally over ``seeds`` (uint32, shape (n,), n % tile == 0).

    Returns float32[OUT_LEN]: ten annulus counts, sum of Gaussian Xs, sum of
    Gaussian Ys, and the accepted-pair count.
    """
    n = seeds.shape[0]
    assert n % tile == 0, f"n={n} must be a multiple of tile={tile}"
    grid = n // tile
    return pl.pallas_call(
        functools.partial(_ep_kernel, tile=tile),
        grid=(grid,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((OUT_LEN,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((OUT_LEN,), jnp.float32),
        interpret=True,
    )(seeds)
