"""L1 Pallas kernel: batched Smith-Waterman local alignment scoring ("SW").

The paper's SW workload scores query/database sequence pairs with the classic
local-alignment dynamic program (linear gap penalty):

    H[i][j] = max(0,
                  H[i-1][j-1] + s(q_i, d_j),
                  H[i-1][j]   - GAP,
                  H[i][j-1]   - GAP)
    score   = max over all i, j of H[i][j]

Hardware adaptation: GPU SW implementations assign one alignment per thread
(inter-task parallelism) and stage the query in shared memory. Here each
grid step owns a tile of alignments; the DP rows advance with a fori_loop
and the j-recurrence is a lax.scan, both vectorized across the batch (lane)
dimension — the TPU-ish replacement for one-thread-per-cell wavefronts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MATCH = 3.0
MISMATCH = -3.0
GAP = 2.0


def _sw_kernel(q_ref, d_ref, o_ref):
    q = q_ref[...]  # (B, LQ) int32
    d = d_ref[...]  # (B, LD) int32
    batch, lq = q.shape
    ld = d.shape[1]

    def row_body(i, carry):
        h_prev, best = carry  # h_prev: (B, LD+1) = H[i-1][0..LD]
        qi = q[:, i]  # (B,)

        def col_step(h_left, j):
            sub = jnp.where(qi == d[:, j], MATCH, MISMATCH)
            h = jnp.maximum(
                0.0,
                jnp.maximum(
                    h_prev[:, j] + sub,
                    jnp.maximum(h_prev[:, j + 1] - GAP, h_left - GAP),
                ),
            )
            return h, h

        h_last, row = jax.lax.scan(
            col_step, jnp.zeros((batch,), jnp.float32), jnp.arange(ld)
        )
        row = jnp.transpose(row)  # (B, LD)
        new_prev = jnp.concatenate(
            [jnp.zeros((batch, 1), jnp.float32), row], axis=1
        )
        best = jnp.maximum(best, jnp.max(row, axis=1))
        return new_prev, best

    h0 = jnp.zeros((batch, ld + 1), jnp.float32)
    best0 = jnp.zeros((batch,), jnp.float32)
    _, best = jax.lax.fori_loop(0, lq, row_body, (h0, best0))
    o_ref[...] = best


@functools.partial(jax.jit, static_argnames=("tile",))
def smith_waterman(q: jnp.ndarray, d: jnp.ndarray, *, tile: int = 32):
    """Local-alignment scores for sequence pairs.

    q: int32[B, LQ], d: int32[B, LD] (token ids); returns float32[B].
    B % tile == 0.
    """
    batch, lq = q.shape
    ld = d.shape[1]
    assert batch % tile == 0, f"batch={batch} must be a multiple of tile={tile}"
    grid = batch // tile
    return pl.pallas_call(
        _sw_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile, lq), lambda i: (i, 0)),
            pl.BlockSpec((tile, ld), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.float32),
        interpret=True,
    )(q, d)
