"""L1 Pallas kernel: Black-Scholes European option pricing.

The paper's compute-bound exemplar (R_bs = 11.1 > R_B): price 4M European
options. Straight elementwise math — one CUDA thread per option in the
original; here the grid walks option tiles and each tile is evaluated as a
vector on the lane dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cnd

RISKFREE = 0.02
VOLATILITY = 0.30


def _bs_kernel(s_ref, x_ref, t_ref, call_ref, put_ref):
    s = s_ref[...]
    x = x_ref[...]
    t = t_ref[...]

    sqrt_t = jnp.sqrt(t)
    d1 = (jnp.log(s / x) + (RISKFREE + 0.5 * VOLATILITY * VOLATILITY) * t) / (
        VOLATILITY * sqrt_t
    )
    d2 = d1 - VOLATILITY * sqrt_t
    cnd_d1 = cnd(d1)
    cnd_d2 = cnd(d2)
    exp_rt = jnp.exp(-RISKFREE * t)

    call_ref[...] = s * cnd_d1 - x * exp_rt * cnd_d2
    put_ref[...] = x * exp_rt * (1.0 - cnd_d2) - s * (1.0 - cnd_d1)


@functools.partial(jax.jit, static_argnames=("tile",))
def blackscholes(
    s: jnp.ndarray, x: jnp.ndarray, t: jnp.ndarray, *, tile: int = 2048
):
    """Price European call/put options. All inputs float32[n], n % tile == 0."""
    n = s.shape[0]
    assert n % tile == 0, f"n={n} must be a multiple of tile={tile}"
    grid = n // tile
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    return pl.pallas_call(
        _bs_kernel,
        grid=(grid,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(s, x, t)
