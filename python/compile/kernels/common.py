"""Shared numerical helpers used by both the Pallas kernels and the pure-jnp
reference oracles.

Everything here is plain jnp so it can be called from inside a Pallas kernel
body (interpret=True executes kernel bodies with regular JAX ops) as well as
from ref implementations.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Cumulative normal distribution (Abramowitz & Stegun 26.2.17), the classic
# polynomial approximation used by the CUDA SDK BlackScholes sample the paper
# benchmarks. Max absolute error ~7.5e-8 — comfortably inside our test rtol.
# ---------------------------------------------------------------------------

_A1 = 0.31938153
_A2 = -0.356563782
_A3 = 1.781477937
_A4 = -1.821255978
_A5 = 1.330274429
_RSQRT2PI = 0.39894228040143267794  # 1/sqrt(2*pi)


def cnd(d: jnp.ndarray) -> jnp.ndarray:
    """Cumulative normal distribution Phi(d) for float32 arrays."""
    k = 1.0 / (1.0 + 0.2316419 * jnp.abs(d))
    poly = k * (_A1 + k * (_A2 + k * (_A3 + k * (_A4 + k * _A5))))
    w = _RSQRT2PI * jnp.exp(-0.5 * d * d) * poly
    return jnp.where(d > 0, 1.0 - w, w)


# ---------------------------------------------------------------------------
# NPB-EP style pseudo-random uniforms. The real NPB uses a 48-bit linear
# congruential generator; we reproduce the same structure with a 32-bit-safe
# split LCG that is deterministic and identical between kernel and oracle.
# ---------------------------------------------------------------------------

# numpy scalars (not jnp arrays): Pallas kernel bodies may not close over
# jnp constant arrays, but np scalar operands fold into the computation.


def lcg_uniform(seed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Deterministic uniforms in (-1, 1), shape (n,), from integer seeds.

    seed: uint32 array broadcastable to (n,) — callers pass
    ``seed0 + arange(n)`` so every element gets an independent stream.
    Uses the murmur3 finalizer so consecutive seeds are decorrelated (a raw
    LCG leaves x/y streams linearly dependent and skews the EP acceptance
    rate away from pi/4).
    """
    s = seed.astype(jnp.uint32)
    s = s ^ (s >> np.uint32(16))
    s = s * np.uint32(0x85EBCA6B)
    s = s ^ (s >> np.uint32(13))
    s = s * np.uint32(0xC2B2AE35)
    s = s ^ (s >> np.uint32(16))
    # Map the top 24 bits to (0,1) then to (-1,1).
    u = (s >> np.uint32(8)).astype(jnp.float32) * np.float32(1.0 / 16777216.0)
    return 2.0 * u - 1.0
