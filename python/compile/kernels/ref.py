"""Pure-jnp / numpy reference oracles for every L1 Pallas kernel.

These are written independently of the kernels (no pallas, no tiling, plain
dense math; the Smith-Waterman oracle is a literal python-loop DP) and serve
as the CORE correctness signal: pytest asserts allclose between each kernel
and its oracle across hypothesis-generated shapes and seeds.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .common import cnd, lcg_uniform
from .blackscholes import RISKFREE, VOLATILITY
from .electrostatics import SOFTENING
from .ep import N_BINS
from . import smith_waterman as sw_mod


def ep_ref(seeds: jnp.ndarray) -> jnp.ndarray:
    """Dense (untiled) EP tally — same math as kernels.ep, no pallas."""
    n = seeds.shape[0]
    x = lcg_uniform(seeds, n)
    y = lcg_uniform(seeds + jnp.uint32(0x9E3779B9), n)
    t = x * x + y * y
    accept = (t <= 1.0) & (t > 0.0)
    t_safe = jnp.where(accept, t, 0.5)
    factor = jnp.sqrt(-2.0 * jnp.log(t_safe) / t_safe)
    gx = jnp.where(accept, x * factor, 0.0)
    gy = jnp.where(accept, y * factor, 0.0)
    mag = jnp.maximum(jnp.abs(gx), jnp.abs(gy))
    annulus = np.clip(np.asarray(mag, dtype=np.int64), 0, N_BINS - 1)
    acc_np = np.asarray(accept)
    counts = np.bincount(annulus[acc_np], minlength=N_BINS).astype(np.float32)
    return jnp.concatenate(
        [
            jnp.asarray(counts),
            jnp.sum(gx, keepdims=True),
            jnp.sum(gy, keepdims=True),
            jnp.sum(accept.astype(jnp.float32), keepdims=True),
        ]
    )


def blackscholes_ref(s, x, t):
    """Dense Black-Scholes call/put prices."""
    sqrt_t = jnp.sqrt(t)
    d1 = (jnp.log(s / x) + (RISKFREE + 0.5 * VOLATILITY**2) * t) / (
        VOLATILITY * sqrt_t
    )
    d2 = d1 - VOLATILITY * sqrt_t
    exp_rt = jnp.exp(-RISKFREE * t)
    call = s * cnd(d1) - x * exp_rt * cnd(d2)
    put = x * exp_rt * (1.0 - cnd(d2)) - s * (1.0 - cnd(d1))
    return call, put


def electrostatics_ref(points, atoms):
    """O(n_points * n_atoms) dense Coulomb sum."""
    d = points[:, None, :] - atoms[None, :, :3]
    r2 = jnp.sum(d * d, axis=-1) + SOFTENING
    return jnp.sum(atoms[None, :, 3] / jnp.sqrt(r2), axis=1)


def smith_waterman_ref(q: np.ndarray, d: np.ndarray) -> np.ndarray:
    """Literal python-loop Smith-Waterman DP (the textbook recurrence)."""
    q = np.asarray(q)
    d = np.asarray(d)
    batch, lq = q.shape
    ld = d.shape[1]
    out = np.zeros(batch, dtype=np.float32)
    for b in range(batch):
        h = np.zeros((lq + 1, ld + 1), dtype=np.float32)
        best = 0.0
        for i in range(1, lq + 1):
            for j in range(1, ld + 1):
                s = sw_mod.MATCH if q[b, i - 1] == d[b, j - 1] else sw_mod.MISMATCH
                h[i, j] = max(
                    0.0,
                    h[i - 1, j - 1] + s,
                    h[i - 1, j] - sw_mod.GAP,
                    h[i, j - 1] - sw_mod.GAP,
                )
                best = max(best, h[i, j])
        out[b] = best
    return out
