"""L1 Pallas kernel: direct Coulomb summation (VMD Electrostatics, "ES").

The paper's ES workload (40K atoms) computes the electrostatic potential on a
lattice of grid points from a set of point charges:

    potential[i] = sum_j q_j / ||p_i - a_j||

Hardware adaptation: the CUDA kernel tiles atoms through constant/shared
memory while each thread owns a grid point. In Pallas the 2D grid iterates
(point-tile, atom-tile); the atom tile is the VMEM-resident operand
(BlockSpec re-fetches per step, playing the role of the shared-memory
staging loop) and the accumulation across atom tiles uses the
same-output-block reduction idiom.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SOFTENING = 1e-6  # avoids the singularity when a grid point touches an atom


def _es_kernel(points_ref, atoms_ref, pot_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        pot_ref[...] = jnp.zeros_like(pot_ref)

    pts = points_ref[...]  # (TP, 3)
    atoms = atoms_ref[...]  # (TA, 4) -> x, y, z, q

    d = pts[:, None, :] - atoms[None, :, :3]  # (TP, TA, 3)
    r2 = jnp.sum(d * d, axis=-1) + SOFTENING
    contrib = atoms[None, :, 3] / jnp.sqrt(r2)  # (TP, TA)
    pot_ref[...] = pot_ref[...] + jnp.sum(contrib, axis=1)


@functools.partial(jax.jit, static_argnames=("tile_points", "tile_atoms"))
def electrostatics(
    points: jnp.ndarray,
    atoms: jnp.ndarray,
    *,
    tile_points: int = 256,
    tile_atoms: int = 128,
) -> jnp.ndarray:
    """Potential at ``points`` (f32[np,3]) from ``atoms`` (f32[na,4] xyzq)."""
    n_points, n_atoms = points.shape[0], atoms.shape[0]
    assert n_points % tile_points == 0 and n_atoms % tile_atoms == 0
    grid = (n_points // tile_points, n_atoms // tile_atoms)
    return pl.pallas_call(
        _es_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_points, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_atoms, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_points,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_points,), jnp.float32),
        interpret=True,
    )(points, atoms)
