"""L2: JAX workload graphs over the L1 Pallas kernels, plus the variant
registry consumed by aot.py.

Each *variant* is one AOT artifact: a jitted function at a fixed problem
size, lowered once to HLO text and executed from the Rust coordinator via
PJRT. The four applications are the ones the paper benchmarks (NPB EP,
BlackScholes, VMD Electrostatics, Smith-Waterman); sizes are scaled to be
CPU-friendly (the GTX580-scale occupancy parameters live in the Rust
workload definitions, see DESIGN.md §2).

Input conventions (mirrored by rust/src/runtime/inputs.rs — keep in sync):
every variant takes deterministic inputs derived from a single uint32 seed
so the Rust side can generate bit-identical literals.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .kernels.blackscholes import blackscholes
from .kernels.electrostatics import electrostatics
from .kernels.ep import ep
from .kernels.smith_waterman import smith_waterman


@dataclasses.dataclass(frozen=True)
class Variant:
    """One AOT artifact: name, callable, and its example input specs."""

    name: str
    app: str  # ep | blackscholes | electrostatics | smith_waterman
    fn: Callable
    in_specs: Sequence[jax.ShapeDtypeStruct]
    # Human description recorded into profiles.json.
    description: str


# ---------------------------------------------------------------------------
# Workload graphs. Each takes raw integer seeds / index arrays so that both
# python tests and the rust runtime can construct inputs trivially.
# ---------------------------------------------------------------------------


def ep_workload(seeds: jnp.ndarray) -> jnp.ndarray:
    """NPB-EP tally over a seed vector."""
    return ep(seeds)


def blackscholes_workload(idx: jnp.ndarray):
    """Price n options with deterministically generated market parameters.

    idx: uint32[n] (element index + seed); parameters are synthesized
    in-graph so the artifact needs only one tiny input.
    """
    u = (idx.astype(jnp.float32) * 0.6180339887) % 1.0  # golden-ratio hash
    v = (idx.astype(jnp.float32) * 0.7548776662) % 1.0
    w = (idx.astype(jnp.float32) * 0.5698402910) % 1.0
    s = 5.0 + 25.0 * u  # spot in [5, 30)
    x = 1.0 + 99.0 * v  # strike in [1, 100)
    t = 0.25 + 9.75 * w  # expiry in [0.25, 10)
    call, put = blackscholes(s, x, t)
    return call, put


def electrostatics_workload(point_seed: jnp.ndarray, atom_seed: jnp.ndarray):
    """Potential lattice from synthesized atom cloud.

    point_seed: uint32[n_points], atom_seed: uint32[n_atoms]; coordinates
    are hashed from the seeds in-graph.
    """

    def coords(seed, scale):
        f = seed.astype(jnp.float32)
        return jnp.stack(
            [
                (f * 0.6180339887) % 1.0 * scale,
                (f * 0.7548776662) % 1.0 * scale,
                (f * 0.5698402910) % 1.0 * scale,
            ],
            axis=1,
        )

    points = coords(point_seed, 16.0)
    axyz = coords(atom_seed * jnp.uint32(2654435761), 16.0)
    q = ((atom_seed.astype(jnp.float32) * 0.3819660113) % 1.0) * 2.0 - 1.0
    atoms = jnp.concatenate([axyz, q[:, None]], axis=1)
    return electrostatics(points, atoms)


def smith_waterman_workload(q_tok: jnp.ndarray, d_tok: jnp.ndarray):
    """Batched local-alignment scores over token-id matrices."""
    return smith_waterman(q_tok, d_tok)


# ---------------------------------------------------------------------------
# Variant registry: the set of artifacts `make artifacts` builds. Sizes are
# chosen so a single execution is ~0.5-5 ms on CPU — large enough that the
# serving example measures real compute, small enough for fast test cycles.
# ---------------------------------------------------------------------------

U32 = jnp.uint32
I32 = jnp.int32
SW_LQ = 48
SW_LD = 48


def variants() -> list[Variant]:
    return [
        Variant(
            name="ep_16k",
            app="ep",
            fn=ep_workload,
            in_specs=[jax.ShapeDtypeStruct((16384,), U32)],
            description="NPB EP tally, 16384 Gaussian-pair candidates",
        ),
        Variant(
            name="ep_64k",
            app="ep",
            fn=ep_workload,
            in_specs=[jax.ShapeDtypeStruct((65536,), U32)],
            description="NPB EP tally, 65536 Gaussian-pair candidates",
        ),
        Variant(
            name="blackscholes_16k",
            app="blackscholes",
            fn=blackscholes_workload,
            in_specs=[jax.ShapeDtypeStruct((16384,), U32)],
            description="BlackScholes, 16384 European options",
        ),
        Variant(
            name="blackscholes_64k",
            app="blackscholes",
            fn=blackscholes_workload,
            in_specs=[jax.ShapeDtypeStruct((65536,), U32)],
            description="BlackScholes, 65536 European options",
        ),
        Variant(
            name="electrostatics_1kx512",
            app="electrostatics",
            fn=electrostatics_workload,
            in_specs=[
                jax.ShapeDtypeStruct((1024,), U32),
                jax.ShapeDtypeStruct((512,), U32),
            ],
            description="Direct Coulomb sum, 1024 grid points x 512 atoms",
        ),
        Variant(
            name="smith_waterman_64x48",
            app="smith_waterman",
            fn=smith_waterman_workload,
            in_specs=[
                jax.ShapeDtypeStruct((64, SW_LQ), I32),
                jax.ShapeDtypeStruct((64, SW_LD), I32),
            ],
            description="Smith-Waterman scoring, 64 pairs of length 48",
        ),
    ]


def variant_by_name(name: str) -> Variant:
    for v in variants():
        if v.name == name:
            return v
    raise KeyError(name)
