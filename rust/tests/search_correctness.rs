//! Search-subsystem correctness: branch-and-bound exactness against the
//! exhaustive sweep (bit-identical, both model backends), anytime
//! determinism (same seed + budget ⇒ identical incumbent trajectory),
//! and budget enforcement.
//!
//! These are the debug-build companions to the release-mode CI gates in
//! `benches/search_quality.rs` (which pushes the same exactness check to
//! n = 8 and the anytime quality gate to the n = 10 sweep distribution).

use kreorder::exec::{AnalyticBackend, ExecutionBackend, SimulatorBackend};
use kreorder::gpu::GpuSpec;
use kreorder::perm::sweep_with;
use kreorder::search::{
    parse_strategy, BranchAndBound, LocalSearch, SearchBudget, SearchOutcome, SearchStrategy,
    SimulatedAnnealing,
};
use kreorder::sched::{registry, reorder};
use kreorder::workloads::{all_scenarios, by_id, scenario_by_id};

type Factory = dyn Fn() -> Box<dyn ExecutionBackend> + Sync;

fn assert_permutation(order: &[usize], n: usize) {
    let mut sorted = order.to_vec();
    sorted.sort_unstable();
    assert_eq!(sorted, (0..n).collect::<Vec<_>>(), "not a permutation: {order:?}");
}

/// Branch-and-bound must agree with the exhaustive sweep bit-for-bit —
/// best makespan *and* lexicographically tie-broken best order — on
/// every scenario family, on both model backends.
#[test]
fn bnb_matches_sweep_bitwise_on_all_scenario_families() {
    let gpu = GpuSpec::gtx580();
    let sim: &Factory = &|| Box::new(SimulatorBackend::new());
    let analytic: &Factory = &|| Box::new(AnalyticBackend::new());
    for sc in all_scenarios() {
        for n in [2usize, 5] {
            for (bname, factory) in [("sim", sim), ("analytic", analytic)] {
                let ks = sc.workload(&gpu, n, 9);
                let sw = sweep_with(&gpu, &ks, factory);
                let out =
                    BranchAndBound::new().search(&gpu, &ks, factory, &SearchBudget::unlimited());
                assert!(out.complete, "{} n={n} {bname}: not proven optimal", sc.id);
                assert_eq!(
                    out.best_ms.to_bits(),
                    sw.best_ms.to_bits(),
                    "{} n={n} {bname}: bnb {} vs sweep {}",
                    sc.id,
                    out.best_ms,
                    sw.best_ms
                );
                assert_eq!(
                    out.best_order, sw.best_order,
                    "{} n={n} {bname}: tie-break drift",
                    sc.id
                );
            }
        }
    }
}

/// Same exactness on a paper workload (n = 6), where the permutation
/// space is the paper's own Table 3 setting.
#[test]
fn bnb_matches_sweep_on_paper_experiment() {
    let gpu = GpuSpec::gtx580();
    let factory: &Factory = &|| Box::new(SimulatorBackend::new());
    let ks = by_id("epbs-6").unwrap().kernels;
    let sw = sweep_with(&gpu, &ks, factory);
    let out = BranchAndBound::new().search(&gpu, &ks, factory, &SearchBudget::unlimited());
    assert!(out.complete);
    assert_eq!(out.best_ms.to_bits(), sw.best_ms.to_bits());
    assert_eq!(out.best_order, sw.best_order);
    // Accounting sanity: never more evaluations than the exhaustive
    // space (720 permutations) plus the warm start — pruning can only
    // reduce this (`pruned_subtrees` in the bench output tracks by how
    // much).
    assert!(
        out.evals <= 721,
        "evaluation accounting broken: {} evals for 720 permutations",
        out.evals
    );
}

/// Identical-kernel workloads tie everywhere: branch-and-bound must
/// still report the sweep's lexicographically smallest optimal order
/// (the identity), not an arbitrary tied one.
#[test]
fn bnb_tie_break_matches_sweep_on_identical_kernels() {
    let gpu = GpuSpec::gtx580();
    let factory: &Factory = &|| Box::new(SimulatorBackend::new());
    let ks = vec![by_id("epbs-6").unwrap().kernels[0].clone(); 5];
    let sw = sweep_with(&gpu, &ks, factory);
    let out = BranchAndBound::new().search(&gpu, &ks, factory, &SearchBudget::unlimited());
    assert_eq!(sw.best_order, vec![0, 1, 2, 3, 4]);
    assert_eq!(out.best_order, vec![0, 1, 2, 3, 4]);
    assert_eq!(out.best_ms.to_bits(), sw.best_ms.to_bits());
}

fn assert_outcomes_identical(a: &SearchOutcome, b: &SearchOutcome) {
    assert_eq!(a.strategy, b.strategy);
    assert_eq!(a.best_ms.to_bits(), b.best_ms.to_bits());
    assert_eq!(a.best_order, b.best_order);
    assert_eq!(a.evals, b.evals);
    assert_eq!(a.trajectory.len(), b.trajectory.len(), "trajectory lengths");
    for (x, y) in a.trajectory.iter().zip(&b.trajectory) {
        assert_eq!(x.eval, y.eval);
        assert_eq!(x.best_ms.to_bits(), y.best_ms.to_bits());
    }
}

/// Same seed + same evaluation budget ⇒ bit-identical incumbent
/// trajectory, for both anytime strategies.
#[test]
fn anytime_trajectories_deterministic_per_seed_and_budget() {
    let gpu = GpuSpec::gtx580();
    let factory: &Factory = &|| Box::new(SimulatorBackend::new());
    let ks = scenario_by_id("skewed").unwrap().workload(&gpu, 10, 4);
    let budget = SearchBudget::evals(300);
    for strategy in [
        Box::new(SimulatedAnnealing::new(42)) as Box<dyn SearchStrategy>,
        Box::new(LocalSearch::new(42)),
    ] {
        let a = strategy.search(&gpu, &ks, factory, &budget);
        let b = strategy.search(&gpu, &ks, factory, &budget);
        assert_outcomes_identical(&a, &b);
        assert_permutation(&a.best_order, ks.len());
        assert!(!a.complete, "anytime results must not claim optimality");
        assert!(a.evals <= 300, "budget exceeded: {}", a.evals);
        // Trajectory is sorted by evaluation index and improving.
        for w in a.trajectory.windows(2) {
            assert!(w[0].eval < w[1].eval);
            assert!(w[0].best_ms > w[1].best_ms);
        }
    }
}

/// Anytime strategies warm-start from Algorithm 1, so they can never
/// report anything worse than the greedy order.
#[test]
fn anytime_never_worse_than_algorithm1_warm_start() {
    let gpu = GpuSpec::gtx580();
    let factory: &Factory = &|| Box::new(SimulatorBackend::new());
    for sc in all_scenarios() {
        let ks = sc.workload(&gpu, 9, 5);
        let greedy = reorder(&gpu, &ks).order;
        let t_greedy = SimulatorBackend::new().execute(&gpu, &ks, &greedy).makespan_ms;
        for spelling in ["anneal:3", "local:3"] {
            let s = parse_strategy(spelling).unwrap();
            let out = s.search(&gpu, &ks, factory, &SearchBudget::evals(150));
            assert!(
                out.best_ms <= t_greedy * (1.0 + 1e-12),
                "{} on {}: {} worse than warm start {}",
                spelling,
                sc.id,
                out.best_ms,
                t_greedy
            );
            assert_permutation(&out.best_order, ks.len());
        }
    }
}

/// An exhausted evaluation budget degrades branch-and-bound to a valid
/// (non-proven) incumbent instead of overrunning.
#[test]
fn bnb_respects_eval_budget() {
    let gpu = GpuSpec::gtx580();
    let factory: &Factory = &|| Box::new(SimulatorBackend::new());
    let ks = scenario_by_id("uniform").unwrap().workload(&gpu, 8, 2);

    // A budget of 1 is consumed entirely by the warm start: the solver
    // must degrade to exactly the Algorithm 1 order, unproven.
    let out = BranchAndBound::new().search(&gpu, &ks, factory, &SearchBudget::evals(1));
    assert!(!out.complete);
    assert_eq!(out.evals, 1);
    assert_eq!(out.best_order, reorder(&gpu, &ks).order);
    assert!(out.best_ms.is_finite());

    // A small budget is never overrun, and the incumbent it returns is
    // at least as good as the warm start.
    let warm = out.best_ms;
    let out = BranchAndBound::new().search(&gpu, &ks, factory, &SearchBudget::evals(40));
    assert!(out.evals <= 40, "budget overrun: {}", out.evals);
    assert!(out.best_ms <= warm * (1.0 + 1e-12));
    assert_permutation(&out.best_order, ks.len());
}

/// The `search` launch-policy spelling works end to end through the
/// policy registry (the coordinator's parse path) and emits permutations
/// on both the exact and the anytime path.
#[test]
fn search_policy_via_registry_orders_both_window_sizes() {
    let gpu = GpuSpec::gtx580();
    let policy = registry::parse("search:local:1:200").unwrap();
    assert_eq!(policy.name(), "search:local:1:200");
    for n in [5usize, 10] {
        let ks = scenario_by_id("mixed").unwrap().workload(&gpu, n, 7);
        let order = policy.order(&gpu, &ks);
        assert_permutation(&order, n);
    }
    // Same spelling round-trips through the registry (the coordinator
    // logs policy names and must be able to reconstruct them).
    let reparsed = registry::parse(&policy.name()).unwrap();
    assert_eq!(reparsed.name(), policy.name());
}

/// Every registered strategy spelling produces a valid permutation under
/// a small budget on every scenario family.
#[test]
fn every_strategy_emits_permutations_on_every_family() {
    let gpu = GpuSpec::gtx580();
    let factory: &Factory = &|| Box::new(SimulatorBackend::new());
    for sc in all_scenarios() {
        let ks = sc.workload(&gpu, 7, 13);
        for spelling in ["bnb", "anneal:1", "local:1"] {
            let s = parse_strategy(spelling).unwrap();
            let out = s.search(&gpu, &ks, factory, &SearchBudget::evals(100));
            assert_permutation(&out.best_order, ks.len());
            assert!(out.evals <= 100, "{spelling} on {}: {} evals", sc.id, out.evals);
        }
    }
}
