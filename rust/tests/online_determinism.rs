//! Integration pins for the online streaming scheduler.
//!
//! The headline contract (ISSUE 5 acceptance): a run with a fixed
//! (arrival seed, strategy seed, window policy) produces **bit-identical
//! per-kernel sojourn times** across runs — the virtual clock makes the
//! whole subsystem a pure function of its configuration. The rest of
//! the file pins record/replay round-trips, the FIFO-vs-reordered tail
//! ordering the bench gates, and cross-policy sanity.

use kreorder::exec::{AnalyticBackend, ExecutionBackend, SimulatorBackend};
use kreorder::gpu::GpuSpec;
use kreorder::online::{
    fifo_window_capacity_per_s, offline_oracle, parse_window_policy, simulate_online,
    ClosedLoopSource, OnlineOpts, OnlineReorderer, OnlineReport, ReplaySource, Trace,
};
use kreorder::workloads::scenario_by_id;

fn sim_factory() -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
    Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)
}

fn analytic_factory() -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
    Box::new(|| Box::new(AnalyticBackend::new()) as Box<dyn ExecutionBackend>)
}

fn run_poisson(
    family: &str,
    n: usize,
    rate: f64,
    arrival_seed: u64,
    window: &str,
    reorderer: &OnlineReorderer,
) -> OnlineReport {
    let gpu = GpuSpec::gtx580();
    let trace = Trace::poisson(family, n, rate, arrival_seed);
    let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
    let w = parse_window_policy(window).unwrap();
    let factory = sim_factory();
    simulate_online(&gpu, source, w, reorderer, factory.as_ref(), &OnlineOpts::default())
}

fn sojourn_bits(r: &OnlineReport) -> Vec<u64> {
    r.sojourns_ms().iter().map(|t| t.to_bits()).collect()
}

/// The acceptance pin: bit-identical per-kernel sojourn times across
/// runs for a fixed (arrival seed, strategy seed, window policy), for
/// every window policy and both reorderer modes.
#[test]
fn fixed_seeds_replay_bit_identically() {
    let reorderers = [
        OnlineReorderer::fifo(),
        OnlineReorderer::search("local:3", 300).unwrap(),
        OnlineReorderer::search("anneal:7", 300).unwrap(),
    ];
    for window in ["fixed:6", "linger:6:25", "adaptive:6:25"] {
        for reorderer in &reorderers {
            let a = run_poisson("skewed", 40, 400.0, 11, window, reorderer);
            let b = run_poisson("skewed", 40, 400.0, 11, window, reorderer);
            assert_eq!(
                sojourn_bits(&a),
                sojourn_bits(&b),
                "sojourns drifted: window={window} reorderer={}",
                reorderer.name()
            );
            assert_eq!(a.span_ms.to_bits(), b.span_ms.to_bits());
            assert_eq!(a.decision_evals, b.decision_evals);
            let batches_a: Vec<(u64, usize, Vec<usize>)> = a
                .batches
                .iter()
                .map(|x| (x.id, x.n, x.order.clone()))
                .collect();
            let batches_b: Vec<(u64, usize, Vec<usize>)> = b
                .batches
                .iter()
                .map(|x| (x.id, x.n, x.order.clone()))
                .collect();
            assert_eq!(batches_a, batches_b);
        }
    }
}

#[test]
fn arrival_seed_changes_the_run() {
    let r = OnlineReorderer::search("local:0", 200).unwrap();
    let a = run_poisson("uniform", 30, 300.0, 1, "linger:8:30", &r);
    let b = run_poisson("uniform", 30, 300.0, 2, "linger:8:30", &r);
    assert_ne!(sojourn_bits(&a), sojourn_bits(&b));
}

#[test]
fn strategy_seed_changes_only_ordering_not_arrivals() {
    let a = run_poisson(
        "mixed",
        30,
        600.0,
        5,
        "linger:8:30",
        &OnlineReorderer::search("anneal:1", 300).unwrap(),
    );
    let b = run_poisson(
        "mixed",
        30,
        600.0,
        5,
        "linger:8:30",
        &OnlineReorderer::search("anneal:2", 300).unwrap(),
    );
    // Same trace, same arrivals…
    let arrivals_a: Vec<u64> = a.kernels.iter().map(|k| k.arrival_ms.to_bits()).collect();
    let arrivals_b: Vec<u64> = b.kernels.iter().map(|k| k.arrival_ms.to_bits()).collect();
    assert_eq!(arrivals_a, arrivals_b);
    // …and identical window compositions under the arrival-driven
    // linger policy (close decisions never depend on the chosen order).
    let sizes_a: Vec<usize> = a.batches.iter().map(|x| x.n).collect();
    let sizes_b: Vec<usize> = b.batches.iter().map(|x| x.n).collect();
    assert_eq!(sizes_a, sizes_b);
}

#[test]
fn recorded_trace_replays_bit_identically_via_csv() {
    let gpu = GpuSpec::gtx580();
    let trace = Trace::bursty("small-large", 32, 250.0, 9);
    let reorderer = OnlineReorderer::search("local:1", 200).unwrap();
    let factory = sim_factory();

    let run = |t: &Trace| {
        let source = Box::new(ReplaySource::from_trace(t, &gpu).unwrap());
        let w = parse_window_policy("adaptive:8:40").unwrap();
        simulate_online(&gpu, source, w, &reorderer, factory.as_ref(), &OnlineOpts::default())
    };
    let direct = run(&trace);
    // Round-trip the trace through its CSV serialization (what
    // `kreorder serve --record` writes and `replay:<file>` reads).
    let parsed = Trace::parse(&trace.to_csv()).unwrap();
    let replayed = run(&parsed);
    assert_eq!(sojourn_bits(&direct), sojourn_bits(&replayed));
    assert_eq!(direct.span_ms.to_bits(), replayed.span_ms.to_bits());
}

#[test]
fn closed_loop_run_records_and_replays_bit_identically() {
    // A closed-loop run is reactive (arrivals depend on completions),
    // yet its realized schedule, recorded as a trace and replayed
    // open-loop, must reproduce the identical run — the record/replay
    // escape hatch for production incidents.
    let gpu = GpuSpec::gtx580();
    let fam = scenario_by_id("uniform").unwrap();
    let factory = sim_factory();
    let reorderer = OnlineReorderer::fifo();
    let run_closed = || {
        let source = Box::new(ClosedLoopSource::new(fam, &gpu, 20, 4, 2.0, 13));
        let w = parse_window_policy("adaptive:4:20").unwrap();
        simulate_online(&gpu, source, w, &reorderer, factory.as_ref(), &OnlineOpts::default())
    };
    let closed = run_closed();
    let again = run_closed();
    assert_eq!(sojourn_bits(&closed), sojourn_bits(&again), "closed loop not deterministic");

    let trace = Trace {
        family: "uniform".into(),
        n: 20,
        seed: 13, // the closed loop draws its pool from its own seed
        devices: 1,
        times_ms: closed.kernels.iter().map(|k| k.arrival_ms).collect(),
    };
    let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
    let w = parse_window_policy("adaptive:4:20").unwrap();
    let replayed =
        simulate_online(&gpu, source, w, &reorderer, factory.as_ref(), &OnlineOpts::default());
    assert_eq!(sojourn_bits(&closed), sojourn_bits(&replayed));
}

/// The bench's hard gate, pinned as a test so `cargo test` catches a
/// regression before CI's bench-smoke does: under mild overload on the
/// skewed and small-large regimes, the reordered windows must not lose
/// the p99 sojourn race to FIFO.
#[test]
fn reordered_p99_beats_fifo_on_the_gated_regimes() {
    let gpu = GpuSpec::gtx580();
    for family in ["skewed", "small-large"] {
        let sc = scenario_by_id(family).unwrap();
        let pool = sc.workload(&gpu, 64, 23);
        // Calibrate ~1.05x the FIFO capacity of 8-kernel windows — the
        // same normalization benches/online_latency.rs uses (shared
        // helper, so the gate and this pin measure the same regime).
        let factory = sim_factory();
        let rate = 1.05 * fifo_window_capacity_per_s(&gpu, &pool, 8, factory.as_ref());

        let fifo = run_poisson(family, 64, rate, 23, "linger:8:40", &OnlineReorderer::fifo());
        let reord = run_poisson(
            family,
            64,
            rate,
            23,
            "linger:8:40",
            &OnlineReorderer::search("local:0", 300).unwrap(),
        );
        // Same trace + arrival-driven windows: identical compositions,
        // so the only difference is launch order within each window.
        let sizes_f: Vec<usize> = fifo.batches.iter().map(|b| b.n).collect();
        let sizes_r: Vec<usize> = reord.batches.iter().map(|b| b.n).collect();
        assert_eq!(sizes_f, sizes_r, "{family}: window composition diverged");
        for (f, r) in fifo.batches.iter().zip(&reord.batches) {
            assert!(
                r.makespan_ms <= f.makespan_ms + 1e-9,
                "{family}: reordered window slower than FIFO (guard broken)"
            );
        }
        let (pf, pr) = (fifo.sojourn_stats().p99_ms, reord.sojourn_stats().p99_ms);
        assert!(pr <= pf + 1e-9, "{family}: reordered p99 {pr} > fifo p99 {pf}");
    }
}

#[test]
fn oracle_bounds_the_online_span_from_below() {
    let gpu = GpuSpec::gtx580();
    let pool = scenario_by_id("skewed").unwrap().workload(&gpu, 8, 3);
    let factory = sim_factory();
    let oracle = offline_oracle(&gpu, &pool, factory.as_ref(), 1000);
    assert_eq!(oracle.method, "bnb-exact");
    let r = run_poisson("skewed", 8, 200.0, 3, "linger:4:20", &OnlineReorderer::fifo());
    // The clairvoyant single-batch optimum can never exceed an online
    // span that also pays arrival gaps, windowing and queueing.
    assert!(
        oracle.makespan_ms <= r.span_ms + 1e-9,
        "oracle {} !<= online span {}",
        oracle.makespan_ms,
        r.span_ms
    );
}

#[test]
fn analytic_backend_runs_the_same_subsystem() {
    // The online engine is backend-generic: the analytic round model
    // slots in through the same factory seam, deterministically.
    let gpu = GpuSpec::gtx580();
    let trace = Trace::poisson("complementary", 16, 300.0, 4);
    let reorderer = OnlineReorderer::search("local:0", 128).unwrap();
    let factory = analytic_factory();
    let run = || {
        let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
        let w = parse_window_policy("linger:4:25").unwrap();
        simulate_online(&gpu, source, w, &reorderer, factory.as_ref(), &OnlineOpts::default())
    };
    let a = run();
    assert_eq!(a.backend, "analytic");
    assert_eq!(a.kernels.len(), 16);
    assert_eq!(sojourn_bits(&a), sojourn_bits(&run()));
}

#[test]
fn slo_linger_bounds_queue_wait_when_underloaded() {
    // At 10% utilization with a 15 ms linger, no kernel's window-wait
    // share of latency can exceed the linger bound (the device is idle
    // when windows close).
    let gpu = GpuSpec::gtx580();
    let pool = scenario_by_id("uniform").unwrap().workload(&gpu, 24, 6);
    let factory = sim_factory();
    let rate = 0.1 * fifo_window_capacity_per_s(&gpu, &pool, 8, factory.as_ref());
    let r = run_poisson("uniform", 24, rate, 6, "linger:8:15", &OnlineReorderer::fifo());
    for (k, q) in r.kernels.iter().zip(r.queue_waits_ms()) {
        // Window wait ≤ linger; the rest of the queue wait can only be
        // residual device busy time, which is bounded by one window's
        // service at this load.
        assert!(k.close_ms - k.arrival_ms <= 15.0 + 1e-9, "{k:?}");
        assert!(q >= 0.0);
    }
    // SLO attainment is 1.0 for an SLO beyond the max sojourn.
    let max_sojourn = r.sojourn_stats().max_ms;
    assert_eq!(r.slo_attainment(max_sojourn + 1.0), 1.0);
    assert!(r.slo_attainment(-1.0) == 0.0);
}
