//! Hostile-input pins for every string boundary the CLI exposes.
//!
//! Each registry parser (`FleetSpec`, `Trace`, window / route / search
//! strategy spellings, arrival processes, fault plans, trace sinks) must turn
//! malformed input into an actionable `Err` — echoing the offending
//! input or naming the violated rule, never panicking, never guessing.
//! These are table tests: add a row when a fuzzer or an incident finds
//! a new way to mistype a spec.

use kreorder::admission::parse_admission_policy;
use kreorder::fault::FaultPlan;
use kreorder::fleet::{parse_route_policy, FleetSpec};
use kreorder::obs::parse_trace_sink;
use kreorder::online::{parse_window_policy, ArrivalSpec, Trace};
use kreorder::search::parse_strategy;
use kreorder::workloads::{parse_deps, DepGraph};

/// Every parser error must be loud enough to act on: non-empty, and
/// carrying either the offending input or a description of valid forms.
fn assert_actionable(msg: &str, input: &str, parser: &str) {
    assert!(!msg.is_empty(), "{parser}: empty error for `{input}`");
    assert!(
        msg.len() > 20,
        "{parser}: error for `{input}` too terse to act on: {msg}"
    );
}

#[test]
fn fleet_specs_reject_hostile_input() {
    let hostile = [
        "",
        " ",
        "0",
        "-3",
        "abc",
        "1,",
        ",1",
        "1,,1",
        "1,-2",
        "1,0",
        "1,nan",
        "1,inf",
        "0x2",
        "2x0",
        "2x",
        "x2",
        "1;2",
        "1e309",
        "🚀",
    ];
    for s in hostile {
        let err = FleetSpec::parse(s).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(&format!("`{s}`")), "input not echoed: {msg}");
        assert_actionable(&msg, s, "FleetSpec");
    }
}

#[test]
fn traces_reject_hostile_input() {
    let hostile: [(&str, &str); 9] = [
        ("", "empty trace"),
        ("not a trace", "missing `# kreorder-trace v1` header"),
        ("# kreorder-trace v2 family=a n=0 seed=0\nat_ms\n", "header"),
        ("# kreorder-trace v1 family=a seed=0\nat_ms\n", "n="),
        ("# kreorder-trace v1 family=a n=x seed=0\nat_ms\n", "n="),
        ("# kreorder-trace v1 family=a n=0 seed=0 bogus=1\nat_ms\n", "bogus"),
        ("# kreorder-trace v1 family=a n=0 seed=0\n", "at_ms"),
        ("# kreorder-trace v1 family=a n=1 seed=0\nat_ms\nnope\n", "nope"),
        ("# kreorder-trace v1 family=a n=2 seed=0\nat_ms\n5.0\n1.0\n", "non-decreasing"),
    ];
    for (text, needle) in hostile {
        let err = Trace::parse(text).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(needle), "expected `{needle}` in: {msg}");
        assert_actionable(&msg, text, "Trace");
    }
    // Count mismatch between the header and the rows is caught too.
    let err = Trace::parse("# kreorder-trace v1 family=a n=3 seed=0\nat_ms\n1.0\n").unwrap_err();
    assert!(err.to_string().contains("n=3"), "{err}");
}

#[test]
fn window_policies_reject_hostile_input() {
    let hostile = [
        "", "zzz", "fixed", "fixed:x", "fixed:-1", "linger", "linger:8", "linger:8:x",
        "linger:8:-5", "linger:8:inf", "adaptive:4", "fixed:4:extra", "linger:8:50:9",
    ];
    for s in hostile {
        let err = parse_window_policy(s).unwrap_err();
        assert_actionable(&err.to_string(), s, "window");
    }
}

#[test]
fn route_policies_reject_hostile_input() {
    let hostile = [
        "", "zzz", "p2c", "p2c:x", "p2c:-1", "jsq:extra", "lrw:7", "affinity:0",
        "circuit:", "circuit:zzz", "circuit:p2c", "circuit:circuit:", "roundrobin:1",
    ];
    for s in hostile {
        let err = parse_route_policy(s).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(&format!("`{s}`")), "input not echoed: {msg}");
        assert_actionable(&msg, s, "route");
    }
    // The circuit wrapper nests — the valid nested spellings stay valid.
    assert!(parse_route_policy("circuit:p2c:7").is_ok());
    assert!(parse_route_policy("circuit:jsq").is_ok());
}

#[test]
fn search_strategies_reject_hostile_input() {
    let hostile = ["", "zzz", "bnb:7", "exact:1", "local:x", "anneal:-1", "local:1:2"];
    for s in hostile {
        let err = parse_strategy(s).unwrap_err();
        assert_actionable(&err.to_string(), s, "strategy");
    }
}

#[test]
fn arrival_specs_reject_hostile_input() {
    let hostile = [
        "",
        "zzz",
        "poisson",
        "poisson:80",
        "poisson:x:1",
        "poisson:-80:1",
        "poisson:inf:1",
        "poisson:80:x",
        "bursty:0:1",
        "closed:4",
        "closed:0:5:1",
        "closed:4:-1:1",
        "closed:4:5:1:9",
    ];
    for s in hostile {
        let err = ArrivalSpec::parse(s).unwrap_err();
        assert_actionable(&err.to_string(), s, "arrivals");
    }
}

#[test]
fn admission_policies_reject_hostile_input() {
    let hostile = [
        "",
        " ",
        "zzz",
        "none:1",
        "bound",
        "bound:",
        "bound:0",
        "bound:-1",
        "bound:x",
        "bound:1.5",
        "bound:4:9",
        "deadline",
        "deadline:",
        "deadline:0",
        "deadline:-5",
        "deadline:nan",
        "deadline:inf",
        "deadline:25:7",
        "codel",
        "codel:5",
        "codel:0:80",
        "codel:5:0",
        "codel:x:80",
        "codel:5:80:1",
        "🚀",
    ];
    for s in hostile {
        let err = parse_admission_policy(s).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(&format!("`{s}`")), "input not echoed: {msg}");
        assert_actionable(&msg, s, "admission");
    }
    // The valid spellings stay valid, and round-trip their names.
    for s in ["none", "bound:4", "deadline:25", "codel:10:80"] {
        assert_eq!(parse_admission_policy(s).unwrap().name(), s);
    }
}

#[test]
fn trace_sinks_reject_hostile_input() {
    let hostile = [
        "", " ", "zzz", "none:1", "ring", "ring:", "ring:0", "ring:x", "ring:-1", "ring:4:9",
        "jsonl", "jsonl:", "🚀",
    ];
    for s in hostile {
        let err = parse_trace_sink(s).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(&format!("`{s}`")), "input not echoed: {msg}");
        assert!(msg.contains("valid sinks"), "{msg}");
        assert_actionable(&msg, s, "trace sink");
    }
    // The valid spellings stay valid, and round-trip their names. The
    // jsonl path is everything after the first `:`, colons included.
    for s in ["none", "ring:64", "jsonl:/tmp/x.jsonl", "jsonl:a:b.jsonl"] {
        assert_eq!(parse_trace_sink(s).unwrap().name(), s);
    }
}

#[test]
fn fault_plans_reject_hostile_input() {
    let hostile: [(&str, &str); 14] = [
        ("crash", "missing `:`"),
        ("crash:0", "expected `<dev>@<t>`"),
        ("crash:x@5", "device must be"),
        ("crash:0@oops", "time must be"),
        ("crash:0@-5", ">= 0"),
        ("crash:0@10:recover@5", "after the crash"),
        ("crash:0@10:revive@20", "recover@"),
        ("slowdown:1@5", "factor"),
        ("slowdown:1@5:0", "> 0"),
        ("slowdown:1@5:x", "factor must be"),
        ("launchfail:0.5", "launchfail:<p>:<seed>"),
        ("launchfail:2:1", "[0, 1]"),
        ("launchfail:0.1:1;launchfail:0.2:2", "at most one"),
        ("meteor:1@2", "unknown clause"),
    ];
    for (s, needle) in hostile {
        let err = FaultPlan::parse(s).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(needle), "expected `{needle}` in: {msg}");
        // Every fault error ends with the valid-clause cheat sheet.
        assert!(msg.contains("valid clauses"), "{msg}");
        assert_actionable(&msg, s, "fault plan");
    }
    // Device bounds are a separate, also-actionable check.
    let plan = FaultPlan::parse("crash:7@5").unwrap();
    let err = plan.validate_for(4).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("device 7"), "{msg}");
    assert!(msg.contains("4-device"), "{msg}");
    // Comments and blank clauses are tolerated, not errors.
    assert!(FaultPlan::parse("# a comment\n\ncrash:0@5;").is_ok());
}

/// Out-of-range fault devices are reported against the exact offending
/// clause, with the device index, the fleet size, and the valid range
/// all in the same sentence.
#[test]
fn fault_device_bounds_echo_the_offending_clause() {
    let plan = FaultPlan::parse("crash:0@5;slowdown:6@10:2;launchfail:0.1:1").unwrap();
    let msg = plan.validate_for(4).unwrap_err().to_string();
    assert!(msg.contains("`slowdown:6@10:2`"), "clause not echoed: {msg}");
    assert!(!msg.contains("crash:0@5"), "innocent clause blamed: {msg}");
    assert!(msg.contains("device 6"), "{msg}");
    assert!(msg.contains("4-device"), "{msg}");
    assert!(msg.contains("0..4"), "{msg}");
    assert_actionable(&msg, "slowdown:6@10:2", "fault device bounds");
}

#[test]
fn dependency_specs_reject_hostile_input() {
    let hostile = [
        "nonsense",
        "->",
        "0->",
        "->1",
        "0->x",
        "x->1",
        "0->-1",
        "0->1->2",
        "0,1,2",
        "0 1",
        "0->1;zzz",
        "0.5->1",
    ];
    for s in hostile {
        let err = parse_deps(s).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("valid clauses"), "{msg}");
        assert_actionable(&msg, s, "deps");
    }
    // Comments, blank clauses, the CSV header, and mixed separators are
    // tolerated, not errors.
    assert_eq!(
        parse_deps("# kreorder-deps v1\npred,succ\n0,2\n1->2; \n").unwrap(),
        vec![(0, 2), (1, 2)]
    );
}

/// Structural DAG violations (range, self-loops, cycles, the bitmask
/// cap) are caught at graph build time with actionable errors.
#[test]
fn dep_graphs_reject_invalid_structure() {
    let cases: [(usize, &[(usize, usize)], &str); 4] = [
        (3, &[(0, 5)], "out of range"),
        (3, &[(1, 1)], "itself"),
        (3, &[(0, 1), (1, 2), (2, 0)], "cycle"),
        (65, &[(0, 1)], "64"),
    ];
    for (n, deps, needle) in cases {
        let msg = DepGraph::build(n, deps).unwrap_err().to_string();
        assert!(msg.contains(needle), "expected `{needle}` in: {msg}");
        assert_actionable(&msg, needle, "DepGraph");
    }
}

/// The unified registry front door wraps every subsystem parser with one
/// error shape: kind + echoed input + the kind's cheat sheet.
#[test]
fn unified_registry_errors_are_uniform() {
    use kreorder::registry;
    let errs = [
        registry::parse_policy("blorp").unwrap_err(),
        registry::parse_strategy("blorp").unwrap_err(),
        registry::parse_route("blorp").unwrap_err(),
        registry::parse_window("blorp").unwrap_err(),
        registry::parse_arrivals("blorp").unwrap_err(),
        registry::parse_fault_plan("blorp").unwrap_err(),
        registry::parse_admission("blorp").unwrap_err(),
        registry::parse_trace("blorp").unwrap_err(),
    ];
    for err in errs {
        let msg = err.to_string();
        assert!(msg.contains("`blorp`"), "input not echoed: {msg}");
        assert!(
            msg.contains(&format!("invalid {} spelling", err.kind)),
            "{msg}"
        );
        assert!(
            msg.contains(&format!("valid {} spellings", err.kind)),
            "{msg}"
        );
        assert_actionable(&msg, "blorp", err.kind);
    }
}
