//! Acceptance pin: the per-permutation sweep hot path performs **no heap
//! allocation after warm-up**, for both model backends, on both the flat
//! (`execute_order`) and prefix-checkpointed paths.
//!
//! A counting global allocator wraps the system allocator; this file
//! contains a single `#[test]` (its own test binary) so no concurrent
//! test pollutes the counter.

use kreorder::exec::{AnalyticBackend, ExecutionBackend, PreparedWorkload, SimulatorBackend};
use kreorder::gpu::GpuSpec;
use kreorder::workloads::synthetic_workload;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// One full lexicographic enumeration of the permutation space through
/// the checkpoint API plus a flat pass, using only preallocated buffers —
/// the exact shape of the sweep's per-worker hot loop.
fn full_pass(
    prepared: &mut dyn PreparedWorkload,
    used: &mut [bool],
    order: &mut Vec<usize>,
    n: usize,
    sink: &mut f64,
) {
    fn dfs(
        prepared: &mut dyn PreparedWorkload,
        used: &mut [bool],
        order: &mut Vec<usize>,
        n: usize,
        sink: &mut f64,
    ) {
        if n - order.len() == 1 {
            let k = used.iter().position(|u| !u).unwrap();
            order.push(k);
            *sink += prepared.execute_suffix(&order[n - 1..]);
            order.pop();
            return;
        }
        for k in 0..n {
            if used[k] {
                continue;
            }
            used[k] = true;
            order.push(k);
            prepared.checkpoint_push(k);
            dfs(prepared, used, order, n, sink);
            prepared.checkpoint_pop();
            order.pop();
            used[k] = false;
        }
    }
    dfs(prepared, used, order, n, sink);
}

#[test]
fn per_permutation_path_is_allocation_free_after_warmup() {
    let gpu = GpuSpec::gtx580();
    let n = 5;
    let ks = synthetic_workload(&gpu, n, 42);

    // All n! orders, materialized before measurement.
    let mut orders: Vec<Vec<usize>> = Vec::new();
    let mut idx: Vec<usize> = (0..n).collect();
    kreorder::perm::for_each_permutation(&mut idx, &mut |p| orders.push(p.to_vec()));

    let factories: Vec<(&str, Box<dyn ExecutionBackend>)> = vec![
        ("sim", Box::new(SimulatorBackend::new())),
        ("analytic", Box::new(AnalyticBackend::new())),
    ];

    for (name, mut backend) in factories {
        let mut prepared = backend.prepare(&gpu, &ks);
        assert!(prepared.supports_checkpoints(), "{name}");
        let mut used = vec![false; n];
        let mut order: Vec<usize> = Vec::with_capacity(n);
        let mut sink = 0.0f64;

        // Warm-up: one full checkpointed pass + one flat pass grows every
        // reusable buffer to its steady-state capacity.
        full_pass(prepared.as_mut(), &mut used, &mut order, n, &mut sink);
        for o in &orders {
            sink += prepared.execute_order(o);
        }

        // Measured: the identical work must not touch the allocator.
        let before = ALLOC_CALLS.load(Ordering::Relaxed);
        full_pass(prepared.as_mut(), &mut used, &mut order, n, &mut sink);
        for o in &orders {
            sink += prepared.execute_order(o);
        }
        let after = ALLOC_CALLS.load(Ordering::Relaxed);

        assert!(sink.is_finite());
        assert_eq!(
            after - before,
            0,
            "{name}: hot path allocated {} time(s) after warm-up",
            after - before
        );
    }
}
