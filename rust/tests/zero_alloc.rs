//! Acceptance pin: the per-permutation sweep hot path performs **no heap
//! allocation after warm-up**, for both model backends, on both the flat
//! (`execute_order`) and prefix-checkpointed paths — and the anytime
//! search loops (one simulated-annealing run, one local-search descent)
//! are equally allocation-free on their cursor-evaluated hot path.
//!
//! A counting global allocator wraps the system allocator; this file
//! contains a single `#[test]` (its own test binary) so no concurrent
//! test pollutes the counter.

use kreorder::exec::{
    AnalyticBackend, ExecutionBackend, PrefixCursor, PreparedWorkload, SimulatorBackend,
};
use kreorder::gpu::GpuSpec;
use kreorder::sched::reorder;
use kreorder::search::{LocalSearch, SimulatedAnnealing};
use kreorder::workloads::synthetic_workload;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// One full lexicographic enumeration of the permutation space through
/// the checkpoint API plus a flat pass, using only preallocated buffers —
/// the exact shape of the sweep's per-worker hot loop.
fn full_pass(
    prepared: &mut dyn PreparedWorkload,
    used: &mut [bool],
    order: &mut Vec<usize>,
    n: usize,
    sink: &mut f64,
) {
    fn dfs(
        prepared: &mut dyn PreparedWorkload,
        used: &mut [bool],
        order: &mut Vec<usize>,
        n: usize,
        sink: &mut f64,
    ) {
        if n - order.len() == 1 {
            let k = used.iter().position(|u| !u).unwrap();
            order.push(k);
            *sink += prepared.execute_suffix(&order[n - 1..]);
            order.pop();
            return;
        }
        for k in 0..n {
            if used[k] {
                continue;
            }
            used[k] = true;
            order.push(k);
            prepared.checkpoint_push(k);
            dfs(prepared, used, order, n, sink);
            prepared.checkpoint_pop();
            order.pop();
            used[k] = false;
        }
    }
    dfs(prepared, used, order, n, sink);
}

#[test]
fn per_permutation_path_is_allocation_free_after_warmup() {
    let gpu = GpuSpec::gtx580();
    let n = 5;
    let ks = synthetic_workload(&gpu, n, 42);

    // All n! orders, materialized before measurement.
    let mut orders: Vec<Vec<usize>> = Vec::new();
    let mut idx: Vec<usize> = (0..n).collect();
    kreorder::perm::for_each_permutation(&mut idx, &mut |p| orders.push(p.to_vec()));

    let factories: Vec<(&str, Box<dyn ExecutionBackend>)> = vec![
        ("sim", Box::new(SimulatorBackend::new())),
        ("analytic", Box::new(AnalyticBackend::new())),
    ];

    for (name, mut backend) in factories {
        let mut prepared = backend.prepare(&gpu, &ks);
        assert!(prepared.supports_checkpoints(), "{name}");
        let mut used = vec![false; n];
        let mut order: Vec<usize> = Vec::with_capacity(n);
        let mut sink = 0.0f64;

        // Warm-up: one full checkpointed pass + one flat pass grows every
        // reusable buffer to its steady-state capacity.
        full_pass(prepared.as_mut(), &mut used, &mut order, n, &mut sink);
        for o in &orders {
            sink += prepared.execute_order(o);
        }

        // Measured: the identical work must not touch the allocator.
        let before = ALLOC_CALLS.load(Ordering::Relaxed);
        full_pass(prepared.as_mut(), &mut used, &mut order, n, &mut sink);
        for o in &orders {
            sink += prepared.execute_order(o);
        }
        let after = ALLOC_CALLS.load(Ordering::Relaxed);

        assert!(sink.is_finite());
        assert_eq!(
            after - before,
            0,
            "{name}: hot path allocated {} time(s) after warm-up",
            after - before
        );
    }

    // ---- anytime search loops: SA + one local-search descent ----------
    //
    // The cursor-evaluated move loops must be equally allocation-free:
    // run each loop once to warm every checkpoint depth and scratch
    // buffer, then re-run the identical (seeded, deterministic) loop
    // under the counter. The incumbent is folded into preallocated
    // buffers via the `offer` callback, exactly as `search()` does with
    // its warmed `Incumbent`.
    let warm_order = reorder(&gpu, &ks).order;
    let factories: Vec<(&str, Box<dyn ExecutionBackend>)> = vec![
        ("sim", Box::new(SimulatorBackend::new())),
        ("analytic", Box::new(AnalyticBackend::new())),
    ];
    for (name, mut backend) in factories {
        let mut cursor = PrefixCursor::new(backend.prepare(&gpu, &ks));
        let mut cur = warm_order.clone();
        let mut cand = cur.clone();
        let mut best_ms = f64::INFINITY;
        let mut best_order = vec![0usize; n];
        // Anchoring at n-1 touches every checkpoint depth once — the
        // only allocation the snapshot stack ever makes is that first
        // touch (each level reserves its workload-wide max capacity).
        let t_warm = cursor.eval_anchored(&cur, n - 1);

        // Warm-up: grows every scratch buffer the seeded loops reach.
        run_anytime_loops(
            &mut cursor,
            &warm_order,
            t_warm,
            &mut cur,
            &mut cand,
            &mut best_ms,
            &mut best_order,
        );

        // Measured: the identical loops must not touch the allocator.
        let before = ALLOC_CALLS.load(Ordering::Relaxed);
        run_anytime_loops(
            &mut cursor,
            &warm_order,
            t_warm,
            &mut cur,
            &mut cand,
            &mut best_ms,
            &mut best_order,
        );
        let after = ALLOC_CALLS.load(Ordering::Relaxed);

        assert!(best_ms.is_finite() && best_order.len() == n);
        assert_eq!(
            after - before,
            0,
            "{name}: anytime search loop allocated {} time(s) after warm-up",
            after - before
        );
    }
}

/// One seeded SA run plus one local-search descent over preallocated
/// buffers — the anytime hot loops exactly as `search()` drives them,
/// with the incumbent folded into caller-owned storage.
fn run_anytime_loops(
    cursor: &mut PrefixCursor<'_>,
    warm_order: &[usize],
    t_warm: f64,
    cur: &mut Vec<usize>,
    cand: &mut Vec<usize>,
    best_ms: &mut f64,
    best_order: &mut Vec<usize>,
) {
    let sa = SimulatedAnnealing::new(9);
    let ls = LocalSearch::new(9);
    let mut offer = |_: u64, t: f64, o: &[usize]| {
        if t < *best_ms {
            *best_ms = t;
            best_order.copy_from_slice(o);
        }
    };

    cur.copy_from_slice(warm_order);
    let mut evals = 1u64;
    sa.anneal_on(cursor, cur, cand, t_warm, 400, None, &mut evals, &mut offer);

    cur.copy_from_slice(warm_order);
    let mut evals = 1u64;
    let (t_end, _stopped) =
        ls.descend_on(cursor, cur, cand, t_warm, 400, None, &mut evals, &mut offer);
    assert!(t_end.is_finite());
}
