//! PR pins for the prefix-reuse / symmetry-collapse optimizations: both
//! are **pure speedups**, so every observable result must stay
//! bit-identical to the reference paths.
//!
//! * Anytime strategies evaluated through the [`PrefixCursor`] produce
//!   the exact same [`SearchOutcome`] — best makespan bits, best order,
//!   evaluation count and full incumbent trajectory — as full
//!   per-candidate evaluation, on every scenario family and both model
//!   backends.
//! * Branch-and-bound with the identical-kernel symmetry collapse
//!   returns the same proven optimum (bits *and* tie-broken order) as
//!   the full-enumeration solver and the exhaustive sweep, on workloads
//!   with duplicated kernels.

use kreorder::exec::{AnalyticBackend, ExecutionBackend, SimulatorBackend};
use kreorder::gpu::{equivalence_classes, GpuSpec, KernelProfile};
use kreorder::perm::sweep_with;
use kreorder::search::{
    BranchAndBound, LocalSearch, SearchBudget, SearchOutcome, SearchStrategy, SimulatedAnnealing,
};
use kreorder::workloads::{all_scenarios, scenario_by_id};

type Factory = dyn Fn() -> Box<dyn ExecutionBackend> + Sync;

fn assert_outcomes_identical(a: &SearchOutcome, b: &SearchOutcome, ctx: &str) {
    assert_eq!(a.strategy, b.strategy, "{ctx}");
    assert_eq!(
        a.best_ms.to_bits(),
        b.best_ms.to_bits(),
        "{ctx}: best {} vs {}",
        a.best_ms,
        b.best_ms
    );
    assert_eq!(a.best_order, b.best_order, "{ctx}");
    assert_eq!(a.evals, b.evals, "{ctx}");
    assert_eq!(a.trajectory.len(), b.trajectory.len(), "{ctx}: trajectory lengths");
    for (x, y) in a.trajectory.iter().zip(&b.trajectory) {
        assert_eq!(x.eval, y.eval, "{ctx}");
        assert_eq!(x.best_ms.to_bits(), y.best_ms.to_bits(), "{ctx}");
    }
}

/// Cursor evaluation vs full evaluation: identical `SearchOutcome` for
/// both anytime strategies on every scenario family (simulator model).
#[test]
fn anytime_cursor_outcomes_bit_identical_on_all_families() {
    let gpu = GpuSpec::gtx580();
    let factory: &Factory = &|| Box::new(SimulatorBackend::new());
    let budget = SearchBudget::evals(250);
    for sc in all_scenarios() {
        let ks = sc.workload(&gpu, 8, 3);
        for seed in [0u64, 7] {
            let pairs: [(Box<dyn SearchStrategy>, Box<dyn SearchStrategy>); 2] = [
                (
                    Box::new(SimulatedAnnealing::new(seed)),
                    Box::new(SimulatedAnnealing::new(seed).full_evaluation()),
                ),
                (
                    Box::new(LocalSearch::new(seed)),
                    Box::new(LocalSearch::new(seed).full_evaluation()),
                ),
            ];
            for (fast, reference) in pairs {
                let a = fast.search(&gpu, &ks, factory, &budget);
                let b = reference.search(&gpu, &ks, factory, &budget);
                let ctx = format!("{} seed={seed} {}", sc.id, a.strategy);
                assert_outcomes_identical(&a, &b, &ctx);
            }
        }
    }
}

/// The same pin on the analytic round model — the cursor must be exact
/// on every checkpoint-capable backend, not just the simulator.
#[test]
fn anytime_cursor_outcomes_bit_identical_on_analytic_backend() {
    let gpu = GpuSpec::gtx580();
    let factory: &Factory = &|| Box::new(AnalyticBackend::new());
    let ks = scenario_by_id("complementary").unwrap().workload(&gpu, 10, 5);
    let budget = SearchBudget::evals(400);
    let a = SimulatedAnnealing::new(11).search(&gpu, &ks, factory, &budget);
    let b = SimulatedAnnealing::new(11)
        .full_evaluation()
        .search(&gpu, &ks, factory, &budget);
    assert_outcomes_identical(&a, &b, "analytic anneal");
    let a = LocalSearch::new(11).search(&gpu, &ks, factory, &budget);
    let b = LocalSearch::new(11)
        .full_evaluation()
        .search(&gpu, &ks, factory, &budget);
    assert_outcomes_identical(&a, &b, "analytic local");
}

/// A workload of `copies[i]` clones of each base kernel — the shape real
/// app streams (many instances of one profiled kernel) produce.
fn duplicated_workload(
    gpu: &GpuSpec,
    base_n: usize,
    copies: &[usize],
    seed: u64,
) -> Vec<KernelProfile> {
    let base = scenario_by_id("uniform").unwrap().workload(gpu, base_n, seed);
    assert_eq!(base.len(), copies.len());
    let mut ks = Vec::new();
    for (k, &m) in base.iter().zip(copies) {
        for _ in 0..m {
            ks.push(k.clone());
        }
    }
    ks
}

/// Symmetry-collapsed branch-and-bound == full-enumeration
/// branch-and-bound == exhaustive sweep, on duplicated-kernel workloads
/// (sequential solver path, both model backends).
#[test]
fn bnb_symmetry_matches_full_enumeration_and_sweep() {
    let gpu = GpuSpec::gtx580();
    let sim: &Factory = &|| Box::new(SimulatorBackend::new());
    let analytic: &Factory = &|| Box::new(AnalyticBackend::new());
    for copies in [&[2usize, 2, 1][..], &[3, 1, 2][..]] {
        let ks = duplicated_workload(&gpu, 3, copies, 17);
        let classes = equivalence_classes(&ks);
        assert!(
            classes.iter().enumerate().any(|(i, &c)| c != i),
            "workload must actually contain duplicates"
        );
        for (bname, factory) in [("sim", sim), ("analytic", analytic)] {
            let sw = sweep_with(&gpu, &ks, factory);
            let sym = BranchAndBound::new().search(&gpu, &ks, factory, &SearchBudget::unlimited());
            let full = BranchAndBound::without_symmetry().search(
                &gpu,
                &ks,
                factory,
                &SearchBudget::unlimited(),
            );
            let ctx = format!("{copies:?} {bname}");
            assert!(sym.complete && full.complete, "{ctx}");
            assert_eq!(sym.best_ms.to_bits(), full.best_ms.to_bits(), "{ctx}");
            assert_eq!(sym.best_order, full.best_order, "{ctx}");
            assert_eq!(sym.best_ms.to_bits(), sw.best_ms.to_bits(), "{ctx}");
            assert_eq!(sym.best_order, sw.best_order, "{ctx}: sweep tie-break drift");
            assert!(
                sym.evals <= full.evals,
                "{ctx}: collapse must never evaluate more ({} vs {})",
                sym.evals,
                full.evals
            );
        }
    }
}

/// The collapse on the parallel solver path (n > 6) and on an
/// all-identical workload, where the tree shrinks by the full n!.
#[test]
fn bnb_symmetry_exact_on_parallel_path_and_identical_workloads() {
    let gpu = GpuSpec::gtx580();
    let factory: &Factory = &|| Box::new(SimulatorBackend::new());

    // n = 7 (past SEQUENTIAL_MAX_N): prefix tasks are canonically
    // filtered and the per-node skip runs inside worker tasks.
    let ks = duplicated_workload(&gpu, 3, &[3, 2, 2], 29);
    let sym = BranchAndBound::new().search(&gpu, &ks, factory, &SearchBudget::unlimited());
    let full =
        BranchAndBound::without_symmetry().search(&gpu, &ks, factory, &SearchBudget::unlimited());
    assert!(sym.complete && full.complete);
    assert_eq!(sym.best_ms.to_bits(), full.best_ms.to_bits());
    assert_eq!(sym.best_order, full.best_order);
    assert!(sym.evals <= full.evals);

    // All-identical: every order ties, the canonical tree is one path,
    // and the reported optimum must still be the identity order.
    let ks = duplicated_workload(&gpu, 1, &[6], 31);
    let sym = BranchAndBound::new().search(&gpu, &ks, factory, &SearchBudget::unlimited());
    assert!(sym.complete);
    assert_eq!(sym.best_order, vec![0, 1, 2, 3, 4, 5]);
    // The collapsed tree holds exactly one completion beyond the warm
    // start's evaluation.
    assert!(sym.evals <= 2, "expected ≤ 2 evals on a fully collapsed tree, got {}", sym.evals);
}

/// On all-distinct workloads the collapse is a no-op: identical
/// outcomes, identical evaluation counts.
#[test]
fn bnb_symmetry_noop_without_duplicates() {
    let gpu = GpuSpec::gtx580();
    let factory: &Factory = &|| Box::new(SimulatorBackend::new());
    let ks = scenario_by_id("skewed").unwrap().workload(&gpu, 6, 2);
    assert_eq!(equivalence_classes(&ks), (0..6).collect::<Vec<_>>());
    let sym = BranchAndBound::new().search(&gpu, &ks, factory, &SearchBudget::unlimited());
    let full =
        BranchAndBound::without_symmetry().search(&gpu, &ks, factory, &SearchBudget::unlimited());
    assert_eq!(sym.best_ms.to_bits(), full.best_ms.to_bits());
    assert_eq!(sym.best_order, full.best_order);
    assert_eq!(sym.evals, full.evals);
    assert_eq!(sym.pruned_subtrees, full.pruned_subtrees);
}
