//! Integration across the scheduling stack: workloads → Algorithm 1 →
//! simulator → permutation sweeps → metrics, on reduced problem sizes.

use kreorder::exec::{AnalyticBackend, ExecutionBackend, SimulatorBackend};
use kreorder::gpu::GpuSpec;
use kreorder::metrics::{ExperimentRow, Table3};
use kreorder::perm::{sweep, sweep_with};
use kreorder::sched::{registry, reorder};
use kreorder::sim::{self, rounds::pack_rounds};
use kreorder::workloads::{all_experiments, by_id, epbsessw_8, synthetic_workload};

#[test]
fn every_paper_experiment_end_to_end() {
    // Full sweep for the 6-kernel experiments (720 perms each, fast);
    // spot-simulation only for the 8-kernel one (its full sweep is the
    // fig1 bench's job).
    let gpu = GpuSpec::gtx580();
    let mut table = Table3::default();
    for e in all_experiments() {
        let sched = reorder(&gpu, &e.kernels);
        let t_alg = sim::simulate_order(&gpu, &e.kernels, &sched.order).makespan_ms;
        assert!(t_alg > 0.0);
        if e.kernels.len() > 6 {
            continue;
        }
        let sw = sweep(&gpu, &e.kernels);
        assert_eq!(sw.n_perms, 720);
        // The paper's headline shape: the algorithm must beat the median
        // of the permutation space in every experiment.
        let pct = sw.percentile_rank(t_alg);
        assert!(pct > 50.0, "{}: percentile {pct}", e.name);
        // And must lie within the permutation range.
        assert!(t_alg >= sw.best_ms * (1.0 - 1e-9), "{}", e.name);
        assert!(t_alg <= sw.worst_ms * (1.0 + 1e-9), "{}", e.name);
        table.push(ExperimentRow {
            name: e.name.to_string(),
            optimal_ms: sw.best_ms,
            worst_ms: sw.worst_ms,
            algorithm_ms: t_alg,
            percentile: pct,
            n_perms: sw.n_perms,
        });
    }
    // Table renders with all experiments.
    let md = table.to_markdown();
    assert!(md.contains("EP-6-shm"));
    assert!(md.contains("EpBs-6-shm"));
}

#[test]
fn worst_case_speedup_exceeds_spread_floor() {
    // Shape check vs the paper: every experiment shows a real spread
    // between best and worst orders (the phenomenon under study).
    let gpu = GpuSpec::gtx580();
    for id in ["ep-6-shm", "bs-6-blk", "epbs-6"] {
        let e = by_id(id).unwrap();
        let sw = sweep(&gpu, &e.kernels);
        let spread = sw.worst_ms / sw.best_ms;
        assert!(spread > 1.15, "{id}: spread only {spread}");
    }
}

#[test]
fn algorithm_round_structure_respects_capacity() {
    let gpu = GpuSpec::gtx580();
    for e in all_experiments() {
        let sched = reorder(&gpu, &e.kernels);
        // Re-deriving rounds from the final order with the analytic
        // model must never violate SM capacity — except singleton
        // rounds, where a single kernel legitimately runs in multiple
        // waves (e.g. BS-6-blk's register-bound 768/1024-thread blocks).
        let rounds = pack_rounds(&gpu, &e.kernels, &sched.order);
        for r in &rounds {
            if r.kernels.len() < 2 {
                continue;
            }
            assert!(
                r.footprint.fits_within(&gpu.sm_capacity()),
                "{}: round {:?} overflows",
                e.name,
                r.kernels
            );
        }
    }
}

#[test]
fn policies_disagree_where_order_matters() {
    let gpu = GpuSpec::gtx580();
    let e = by_id("epbsessw-8").unwrap();
    let mut backend = SimulatorBackend::new();
    let fifo = registry::parse("fifo").unwrap().order(&gpu, &e.kernels);
    let rev = registry::parse("reverse").unwrap().order(&gpu, &e.kernels);
    let t_fifo = backend.execute(&gpu, &e.kernels, &fifo).makespan_ms;
    let t_rev = backend.execute(&gpu, &e.kernels, &rev).makespan_ms;
    assert!((t_fifo - t_rev).abs() > 1e-6);
}

/// Refactor pin: the trait-object pipeline (registry policy + simulator
/// backend) produces exactly the same Table-3 numbers as the direct
/// function calls, on the paper's 8-kernel experiment.
#[test]
fn trait_pipeline_matches_direct_calls_on_epbsessw_8() {
    let gpu = GpuSpec::gtx580();
    let ks = epbsessw_8();
    let direct_order = reorder(&gpu, &ks).order;
    let trait_order = registry::parse("algorithm1").unwrap().order(&gpu, &ks);
    assert_eq!(direct_order, trait_order);

    let direct_ms = sim::simulate_order(&gpu, &ks, &direct_order).makespan_ms;
    let trait_ms = SimulatorBackend::new()
        .execute(&gpu, &ks, &trait_order)
        .makespan_ms;
    assert_eq!(direct_ms, trait_ms);
}

/// The backend seam also carries the sweep: an analytic-backend sweep
/// evaluates the same permutation space (count, partition) as the
/// simulator sweep, just under a different timing model.
#[test]
fn sweep_runs_on_both_model_backends() {
    let gpu = GpuSpec::gtx580();
    let ks = synthetic_workload(&gpu, 5, 13);
    let sim_sweep = sweep(&gpu, &ks);
    let analytic_sweep = sweep_with(&gpu, &ks, &|| Box::new(AnalyticBackend::new()));
    assert_eq!(sim_sweep.n_perms, 120);
    assert_eq!(analytic_sweep.n_perms, 120);
    assert!(analytic_sweep.best_ms.is_finite());
    assert!(analytic_sweep.best_ms <= analytic_sweep.worst_ms);
}

#[test]
fn mixed_experiments_produce_mixed_rounds() {
    // EpBs-6: the algorithm must put memory-bound and compute-bound
    // kernels in the same opening round (the paper's central heuristic).
    let gpu = GpuSpec::gtx580();
    let e = by_id("epbs-6").unwrap();
    let sched = reorder(&gpu, &e.kernels);
    let first = &sched.rounds[0];
    let has_mem = first.iter().any(|&i| e.kernels[i].memory_bound(&gpu));
    let has_cmp = first.iter().any(|&i| !e.kernels[i].memory_bound(&gpu));
    assert!(has_mem && has_cmp, "round 0 = {first:?} not mixed");
}

#[test]
fn synthetic_workloads_schedule_and_simulate() {
    let gpu = GpuSpec::gtx580();
    for seed in 0..20 {
        let ks = synthetic_workload(&gpu, 10, seed);
        let sched = reorder(&gpu, &ks);
        let mut sorted = sched.order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..10).collect::<Vec<_>>(), "seed {seed}");
        let r = sim::simulate_order(&gpu, &ks, &sched.order);
        assert!(r.makespan_ms.is_finite() && r.makespan_ms > 0.0);
        // Work conservation: makespan >= aggregate lower bound.
        let work: f64 = ks.iter().map(|k| k.total_work()).sum();
        let mem: f64 = ks.iter().map(|k| k.total_mem()).sum();
        // Jitter can reduce total work by at most `block_jitter`.
        let lb = gpu.makespan_lower_bound(work, mem) * (1.0 - gpu.block_jitter);
        assert!(r.makespan_ms >= lb, "seed {seed}: {} < {lb}", r.makespan_ms);
    }
}

#[test]
fn cli_binary_smoke() {
    // The CLI is part of the public surface; run the cheap subcommands.
    let bin = env!("CARGO_BIN_EXE_kreorder");
    let out = std::process::Command::new(bin).arg("help").output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("table3"));

    let out = std::process::Command::new(bin)
        .args(["sweep", "--exp", "epbs-6"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("720 permutations"), "{text}");

    let out = std::process::Command::new(bin)
        .args(["sched", "--exp", "ep-6-shm"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Algorithm 1 order"));
    // The sched table now iterates the whole registry.
    assert!(text.contains("sjf"), "{text}");
    assert!(text.contains("coschedule"), "{text}");

    // The registry listing subcommand.
    let out = std::process::Command::new(bin).arg("policies").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for name in ["fifo", "reverse", "random:<seed>", "algorithm1", "sjf", "coschedule"] {
        assert!(text.contains(name), "missing {name} in: {text}");
    }

    // Unknown policies fail with the full list of valid names.
    let out = std::process::Command::new(bin)
        .args(["serve", "--policy", "bogus", "--sim-only", "--batches", "1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("valid policies"), "{err}");
    assert!(err.contains("coschedule"), "{err}");

    let out = std::process::Command::new(bin).arg("bogus").output().unwrap();
    assert!(!out.status.success());
}
