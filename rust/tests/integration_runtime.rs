//! Integration: AOT artifacts → PJRT runtime → correct numerics.
//!
//! Requires `make artifacts` (the Makefile's `test-rust` target
//! guarantees this). These tests exercise the same path the coordinator's
//! hot loop uses.
//!
//! Compiled only with `--features pjrt` (the runtime module needs the XLA
//! bindings) and `#[ignore]`d by default: they depend on AOT artifacts
//! produced outside cargo, which offline/CI environments don't have. Run
//! with `make artifacts && cargo test --features pjrt -- --ignored`.

#![cfg(feature = "pjrt")]

use kreorder::profile::ArtifactStore;
use kreorder::runtime::Runtime;
use std::cell::OnceCell;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    // Tests run from the crate root.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

thread_local! {
    // The PJRT handles are !Send, so each test thread owns a runtime
    // (mirroring the coordinator's worker-owns-runtime design).
    static RT: OnceCell<Runtime> = const { OnceCell::new() };
}

fn with_runtime<T>(f: impl FnOnce(&Runtime) -> T) -> T {
    RT.with(|cell| {
        let rt = cell.get_or_init(|| {
            let store = ArtifactStore::load(artifacts_dir()).expect("run `make artifacts` first");
            Runtime::new(store).expect("PJRT CPU client")
        });
        f(rt)
    })
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
fn manifest_lists_all_four_apps() {
    let store = ArtifactStore::load(artifacts_dir()).unwrap();
    let mut apps: Vec<String> = store
        .manifest
        .variants
        .values()
        .map(|v| v.app.clone())
        .collect();
    apps.sort();
    apps.dedup();
    assert_eq!(
        apps,
        vec!["blackscholes", "electrostatics", "ep", "smith_waterman"]
    );
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
fn ep_executes_with_sane_tally() {
    let out = with_runtime(|rt| rt.execute("ep_16k", 0).unwrap());
    // Output: one leaf of 13 floats (10 annulus counts, sumx, sumy, accepted).
    assert_eq!(out.outputs.len(), 1);
    let leaf = &out.outputs[0];
    assert_eq!(leaf.len(), 13);
    let counts_sum: f32 = leaf[..10].iter().sum();
    let accepted = leaf[12];
    assert!((counts_sum - accepted).abs() < 1.0, "{counts_sum} vs {accepted}");
    // Marsaglia acceptance ratio ~ pi/4 of 16384.
    let ratio = accepted / 16384.0;
    assert!((0.75..0.82).contains(&ratio), "acceptance {ratio}");
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
fn blackscholes_prices_are_positive_and_bounded() {
    let out = with_runtime(|rt| rt.execute("blackscholes_16k", 7).unwrap());
    assert_eq!(out.outputs.len(), 2); // call, put
    for leaf in &out.outputs {
        assert_eq!(leaf.len(), 16384);
        assert!(leaf.iter().all(|x| x.is_finite()));
    }
    // Calls are non-negative and below the max spot (30).
    assert!(out.outputs[0].iter().all(|&c| (-1e-3..30.5).contains(&c)));
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
fn electrostatics_potential_finite() {
    let out = with_runtime(|rt| rt.execute("electrostatics_1kx512", 3).unwrap());
    assert_eq!(out.outputs.len(), 1);
    assert_eq!(out.outputs[0].len(), 1024);
    assert!(out.outputs[0].iter().all(|x| x.is_finite()));
    // Potentials can't all be zero for random charges.
    assert!(out.outputs[0].iter().any(|&x| x.abs() > 1e-3));
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
fn smith_waterman_scores_in_range() {
    let out = with_runtime(|rt| rt.execute("smith_waterman_64x48", 11).unwrap());
    assert_eq!(out.outputs.len(), 1);
    let scores = &out.outputs[0];
    assert_eq!(scores.len(), 64);
    // Local alignment scores: 0 <= s <= len * MATCH = 48 * 3.
    assert!(scores.iter().all(|&s| (0.0..=144.0).contains(&s)));
    // Random 4-letter sequences of length 48 essentially always align
    // somewhere with positive score.
    assert!(scores.iter().all(|&s| s > 0.0));
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
fn execution_is_deterministic_per_seed() {
    let a = with_runtime(|rt| rt.execute("ep_16k", 42).unwrap());
    let b = with_runtime(|rt| rt.execute("ep_16k", 42).unwrap());
    assert_eq!(a.outputs, b.outputs);
    let c = with_runtime(|rt| rt.execute("ep_16k", 43).unwrap());
    assert_ne!(a.outputs, c.outputs);
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
fn unknown_variant_is_an_error() {
    assert!(with_runtime(|rt| rt.execute("not_a_variant", 0).is_err()));
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
fn preload_all_compiles_every_variant() {
    with_runtime(|rt| rt.preload_all().unwrap());
    // After preloading, executions should be fast (cache hits) — just
    // verify they still work.
    let names = with_runtime(|rt| rt.store().variant_names());
    for name in names {
        let out = with_runtime(|rt| rt.execute(&name, 1).unwrap());
        assert!(!out.outputs.is_empty(), "{name}");
    }
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
fn checksum_is_stable_fingerprint() {
    let a = with_runtime(|rt| rt.execute("blackscholes_16k", 5).unwrap());
    let b = with_runtime(|rt| rt.execute("blackscholes_16k", 5).unwrap());
    assert_eq!(a.checksum(), b.checksum());
    assert!(a.checksum().is_finite());
}
