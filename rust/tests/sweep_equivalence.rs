//! Golden equivalence suite for the sweep fast paths.
//!
//! The prefix-checkpointed sweep and the streaming-statistics sweep must
//! be *indistinguishable* from the naive per-permutation `execute` sweep:
//! bit-identical best/worst makespans and orders, bit-identical time
//! multisets, and percentile ranks matching within histogram resolution —
//! for n ≤ 6, on both model backends.

use kreorder::exec::{AnalyticBackend, ExecutionBackend, SimulatorBackend};
use kreorder::gpu::GpuSpec;
use kreorder::perm::{sweep_flat_with, sweep_stats_with, sweep_with};
use kreorder::workloads::{by_id, synthetic_workload};

type Factory<'a> = &'a (dyn Fn() -> Box<dyn ExecutionBackend> + Sync);

fn backends() -> Vec<(&'static str, Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync>)> {
    vec![
        ("sim", Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)),
        (
            "analytic",
            Box::new(|| Box::new(AnalyticBackend::new()) as Box<dyn ExecutionBackend>),
        ),
    ]
}

fn assert_sweeps_identical(
    gpu: &GpuSpec,
    kernels: &[kreorder::gpu::KernelProfile],
    factory: Factory,
    label: &str,
) {
    let naive = sweep_flat_with(gpu, kernels, factory);
    let fast = sweep_with(gpu, kernels, factory);

    assert_eq!(naive.n_perms, fast.n_perms, "{label}: n_perms");
    assert_eq!(
        naive.best_ms.to_bits(),
        fast.best_ms.to_bits(),
        "{label}: best_ms {} vs {}",
        naive.best_ms,
        fast.best_ms
    );
    assert_eq!(
        naive.worst_ms.to_bits(),
        fast.worst_ms.to_bits(),
        "{label}: worst_ms {} vs {}",
        naive.worst_ms,
        fast.worst_ms
    );
    assert_eq!(naive.best_order, fast.best_order, "{label}: best_order");
    assert_eq!(naive.worst_order, fast.worst_order, "{label}: worst_order");

    // Same multiset of makespans, bit for bit.
    let mut a = naive.times.clone();
    let mut b = fast.times.clone();
    a.sort_unstable_by(f64::total_cmp);
    b.sort_unstable_by(f64::total_cmp);
    assert_eq!(a.len(), b.len(), "{label}");
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: sorted times diverge at {i}");
    }
}

/// Tentpole acceptance: the checkpointed sweep is exactly the naive sweep
/// for every n ≤ 6 on both model backends, across varied workloads.
#[test]
fn checkpointed_sweep_matches_naive_bitwise() {
    let gpu = GpuSpec::gtx580();
    for (name, factory) in backends() {
        for n in 2..=6 {
            for seed in [1u64, 17, 123] {
                let ks = synthetic_workload(&gpu, n, seed);
                assert_sweeps_identical(
                    &gpu,
                    &ks,
                    factory.as_ref(),
                    &format!("{name} n={n} seed={seed}"),
                );
            }
        }
    }
}

/// The paper's 6-kernel experiments, checkpointed vs naive.
#[test]
fn paper_experiments_checkpointed_matches_naive() {
    let gpu = GpuSpec::gtx580();
    for (name, factory) in backends() {
        for id in ["ep-6-shm", "epbs-6"] {
            let ks = by_id(id).unwrap().kernels;
            assert_sweeps_identical(&gpu, &ks, factory.as_ref(), &format!("{name} {id}"));
        }
    }
}

/// Streaming `SweepStats` agrees with the naive sweep: exact extremes
/// (values and orders) and percentile ranks within histogram resolution.
#[test]
fn streaming_stats_match_naive() {
    let gpu = GpuSpec::gtx580();
    for (name, factory) in backends() {
        for n in 3..=6 {
            for seed in [5u64, 99] {
                let ks = synthetic_workload(&gpu, n, seed);
                let naive = sweep_flat_with(&gpu, &ks, factory.as_ref());
                let stats = sweep_stats_with(&gpu, &ks, factory.as_ref(), 4096);
                let label = format!("{name} n={n} seed={seed}");

                assert_eq!(stats.n_perms, naive.n_perms, "{label}");
                assert_eq!(stats.best_ms.to_bits(), naive.best_ms.to_bits(), "{label}");
                assert_eq!(stats.worst_ms.to_bits(), naive.worst_ms.to_bits(), "{label}");
                assert_eq!(stats.best_order, naive.best_order, "{label}");
                assert_eq!(stats.worst_order, naive.worst_order, "{label}");

                // Percentile ranks agree to within half the probe's bin
                // mass (the histogram's resolution bound).
                for &t in [naive.best_ms, naive.median_ms(), naive.worst_ms].iter() {
                    let exact = naive.percentile_rank(t);
                    let approx = stats.percentile_rank(t);
                    let tol = 50.0 * stats.bin_mass(t) as f64 / stats.n_perms as f64 + 1e-6;
                    assert!(
                        (exact - approx).abs() <= tol,
                        "{label}: rank({t}) exact {exact} vs approx {approx} (tol {tol})"
                    );
                }
            }
        }
    }
}
