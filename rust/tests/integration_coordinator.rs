//! Integration: the coordinator service through its public API.
//!
//! Two halves:
//!
//! * `drain` — always-on pins for shutdown/drain semantics and
//!   deterministic batching under the injectable [`ManualClock`]:
//!   every request submitted before `shutdown()` is either completed or
//!   reported (a disconnect error at the handle), never silently
//!   dropped or hung.
//! * `pjrt_payloads` — the full three-layer request path executing REAL
//!   AOT payloads via the PJRT execution backend. Compiled only with
//!   `--features pjrt` and `#[ignore]`d by default: the payloads are
//!   AOT artifacts produced outside cargo (`make artifacts`), which
//!   offline/CI environments don't have. Run with
//!   `make artifacts && cargo test --features pjrt -- --ignored`.

mod drain {
    use kreorder::coordinator::{CoordinatorBuilder, LaunchRequest, ManualClock};
    use kreorder::gpu::{AppKind, KernelProfile};
    use std::sync::Arc;
    use std::time::Duration;

    fn profile(i: u64) -> KernelProfile {
        KernelProfile {
            name: format!("k{i}"),
            app: AppKind::Synthetic,
            n_blocks: 16,
            regs_per_block: 512,
            shmem_per_block: 0,
            warps_per_block: 4 + (i % 3) as u32 * 8,
            ratio: 1.0 + i as f64,
            work_per_block: 500.0,
            artifact: String::new(),
        }
    }

    fn request(i: u64) -> LaunchRequest {
        LaunchRequest {
            id: i,
            profile: profile(i),
            seed: i,
        }
    }

    /// A coordinator whose linger can never expire (frozen manual
    /// clock): batching depends only on occupancy, flush and shutdown.
    fn frozen(window: usize) -> kreorder::coordinator::Coordinator {
        CoordinatorBuilder::new()
            .window(window)
            .linger(Duration::from_secs(3600))
            .clock(Arc::new(ManualClock::new()))
            .start()
    }

    #[test]
    fn shutdown_completes_undispatched_requests() {
        // Window 100 + frozen clock: nothing would ever dispatch these
        // five requests — except shutdown's drain, which must answer
        // every one of them.
        let c = frozen(100);
        let handles: Vec<_> = (0..5).map(|i| c.submit(request(i))).collect();
        let (reports, stats) = c.shutdown();
        assert_eq!(stats.n_responses, 5);
        assert_eq!(reports.iter().map(|r| r.n).sum::<usize>(), 5);
        let mut ids: Vec<u64> = handles
            .into_iter()
            .map(|h| h.wait().expect("drained request must be answered").id)
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..5).collect::<Vec<_>>());
    }

    #[test]
    fn shutdown_drain_respects_window_chunks() {
        // Drain splits the leftover queue into window-sized batches: 7
        // requests through a window of 3 arrive as 3+3+1.
        let c = frozen(3);
        let handles: Vec<_> = (0..7).map(|i| c.submit(request(i))).collect();
        let (reports, stats) = c.shutdown();
        assert_eq!(stats.n_responses, 7);
        let mut sizes: Vec<usize> = reports.iter().map(|r| r.n).collect();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![1, 3, 3]);
        for h in handles {
            h.wait().expect("answered");
        }
    }

    #[test]
    fn shutdown_with_nothing_pending_reports_no_batches() {
        let c = frozen(4);
        let (reports, stats) = c.shutdown();
        assert!(reports.is_empty());
        assert_eq!(stats.n_responses, 0);
        assert_eq!(stats.n_batches, 0);
    }

    #[test]
    fn drop_reports_rather_than_hangs_a_straggler() {
        // Drop (the no-result shutdown path) also drains: the handle
        // resolves rather than hanging, and even if a future change
        // dropped the batch instead, the reply channel closing must
        // surface as an error — "completed or reported", never stuck.
        let c = frozen(100);
        let h = c.submit(request(0));
        drop(c);
        match h.wait_timeout(Duration::from_secs(10)) {
            Ok(r) => assert_eq!(r.id, 0),
            Err(e) => panic!("straggler neither completed nor answered: {e}"),
        }
    }

    #[test]
    fn deterministic_batching_is_identical_across_runs() {
        // Frozen clock + fixed submission sequence: batch compositions
        // and ids must be bit-identical run to run.
        let run = || {
            let c = frozen(4);
            let handles: Vec<_> = (0..10).map(|i| c.submit(request(i))).collect();
            // Shutdown first: the final partial window (2 kernels) only
            // dispatches through the drain under a frozen clock.
            let (reports, _) = c.shutdown();
            let mut seen: Vec<(u64, u64, usize)> = handles
                .into_iter()
                .map(|h| {
                    let r = h.wait().unwrap();
                    (r.id, r.batch_id, r.position)
                })
                .collect();
            seen.sort_unstable();
            let sizes: Vec<usize> = reports.iter().map(|r| r.n).collect();
            (seen, sizes)
        };
        // 10 = 4 + 4 + drain 2; every placement identical across runs.
        let (a_seen, a_sizes) = run();
        let (b_seen, b_sizes) = run();
        assert_eq!(a_sizes, vec![4, 4, 2]);
        assert_eq!(a_seen, b_seen);
        assert_eq!(a_sizes, b_sizes);
    }

    #[test]
    fn multi_device_shutdown_answers_everything() {
        let c = CoordinatorBuilder::new()
            .devices(3)
            .window(2)
            .linger(Duration::from_secs(3600))
            .clock(Arc::new(ManualClock::new()))
            .start();
        let handles: Vec<_> = (0..12).map(|i| c.submit(request(i))).collect();
        let (reports, stats) = c.shutdown();
        assert_eq!(stats.n_responses, 12);
        assert_eq!(reports.iter().map(|r| r.n).sum::<usize>(), 12);
        for h in handles {
            h.wait().expect("answered");
        }
        // Batches really did round-robin across the workers.
        let mut devices: Vec<usize> = reports.iter().map(|r| r.device).collect();
        devices.sort_unstable();
        devices.dedup();
        assert_eq!(devices, vec![0, 1, 2]);
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_payloads {
    use kreorder::coordinator::{Coordinator, CoordinatorBuilder, LaunchRequest};
    use kreorder::gpu::GpuSpec;
    use kreorder::workloads::{by_id, synthetic_workload};
    use std::path::PathBuf;
    use std::time::Duration;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn coordinator(window: usize) -> Coordinator {
        CoordinatorBuilder::new()
            .policy_named("algorithm1")
            .unwrap()
            .pjrt_backend(artifacts_dir())
            .window(window)
            .linger(Duration::from_millis(10))
            .start()
    }

    #[test]
    #[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
    fn serves_real_payloads_for_every_app() {
        let e = by_id("epbsessw-8").unwrap(); // 2 kernels per app
        let coord = coordinator(8);
        let handles: Vec<_> = e
            .kernels
            .iter()
            .enumerate()
            .map(|(i, k)| {
                coord.submit(LaunchRequest {
                    id: i as u64,
                    profile: k.clone(),
                    seed: 1000 + i as u64,
                })
            })
            .collect();
        let mut positions = Vec::new();
        for h in handles {
            let r = h.wait().unwrap();
            assert!(r.checksum.is_finite(), "id {} failed", r.id);
            assert!(r.exec_wall_ms > 0.0);
            positions.push(r.position);
        }
        positions.sort_unstable();
        assert_eq!(positions, (0..8).collect::<Vec<_>>());

        let (reports, stats) = coord.shutdown();
        assert_eq!(stats.n_failures, 0);
        assert_eq!(stats.n_responses, 8);
        // The batch must have been reordered by Algorithm 1 (trait
        // dispatch), simulated, and executed by the PJRT backend.
        let batch = &reports[0];
        assert_eq!(batch.n, 8);
        assert_eq!(batch.policy, "algorithm1");
        assert_eq!(batch.backend, "pjrt");
        assert!(batch.sim_policy_ms <= batch.sim_fifo_ms + 1e-9);
    }

    #[test]
    #[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
    fn sustained_stream_multiple_batches() {
        let gpu = GpuSpec::gtx580();
        let coord = coordinator(4);
        let mut handles = Vec::new();
        for b in 0..4u64 {
            for (i, k) in synthetic_workload(&gpu, 4, b).into_iter().enumerate() {
                handles.push(coord.submit(LaunchRequest {
                    id: b * 4 + i as u64,
                    profile: k,
                    seed: b * 4 + i as u64,
                }));
            }
            coord.flush();
        }
        let mut ok = 0;
        for h in handles {
            let r = h.wait().unwrap();
            if r.checksum.is_finite() {
                ok += 1;
            }
        }
        assert_eq!(ok, 16);
        let (reports, stats) = coord.shutdown();
        assert_eq!(stats.n_responses, 16);
        assert!(reports.len() >= 4);
        assert!(stats.throughput_per_s() > 0.0);
    }

    #[test]
    #[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
    fn bad_artifact_name_is_failure_injected_not_fatal() {
        let gpu = GpuSpec::gtx580();
        let coord = coordinator(2);
        let mut good = synthetic_workload(&gpu, 2, 99);
        good[1].artifact = "no_such_artifact".into();
        let h0 = coord.submit(LaunchRequest {
            id: 0,
            profile: good[0].clone(),
            seed: 0,
        });
        let h1 = coord.submit(LaunchRequest {
            id: 1,
            profile: good[1].clone(),
            seed: 0,
        });
        coord.flush();
        let r0 = h0.wait().unwrap();
        let r1 = h1.wait().unwrap();
        // One succeeds, the broken one reports the failure sentinel; the
        // service keeps running either way.
        let (a, b) = if r0.id == 0 { (r0, r1) } else { (r1, r0) };
        assert!(a.checksum.is_finite());
        assert_eq!(b.checksum, f64::NEG_INFINITY);
        let (_, stats) = coord.shutdown();
        assert_eq!(stats.n_failures, 1);
    }

    #[test]
    #[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
    fn multi_device_pjrt_builds_one_runtime_per_worker() {
        // Two device workers, each constructing its own PJRT backend via
        // the factory (the handles are !Send): both must serve real
        // payloads.
        let gpu = GpuSpec::gtx580();
        let coord = CoordinatorBuilder::new()
            .policy_named("algorithm1")
            .unwrap()
            .pjrt_backend(artifacts_dir())
            .devices(2)
            .window(4)
            .linger(Duration::from_millis(10))
            .start();
        let mut handles = Vec::new();
        for b in 0..4u64 {
            for (i, k) in synthetic_workload(&gpu, 4, b).into_iter().enumerate() {
                handles.push(coord.submit(LaunchRequest {
                    id: b * 4 + i as u64,
                    profile: k,
                    seed: i as u64,
                }));
            }
            coord.flush();
        }
        for h in handles {
            assert!(h.wait().unwrap().checksum.is_finite());
        }
        let (reports, _) = coord.shutdown();
        let mut devices: Vec<usize> = reports.iter().map(|r| r.device).collect();
        devices.sort_unstable();
        devices.dedup();
        assert_eq!(devices, vec![0, 1]);
    }
}
