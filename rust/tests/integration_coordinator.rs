//! Integration: the coordinator service executing REAL AOT payloads via
//! PJRT while reordering batches with Algorithm 1 — the full three-layer
//! request path.

use kreorder::coordinator::{Coordinator, CoordinatorConfig, LaunchRequest};
use kreorder::gpu::GpuSpec;
use kreorder::sched::Policy;
use kreorder::workloads::{by_id, synthetic_workload};
use std::path::PathBuf;
use std::time::Duration;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn cfg(window: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        gpu: GpuSpec::gtx580(),
        policy: Policy::Algorithm1,
        window,
        linger: Duration::from_millis(10),
        artifacts_dir: Some(artifacts_dir()),
    }
}

#[test]
fn serves_real_payloads_for_every_app() {
    let gpu = GpuSpec::gtx580();
    let e = by_id("epbsessw-8").unwrap(); // 2 kernels per app
    let coord = Coordinator::start(cfg(8));
    let handles: Vec<_> = e
        .kernels
        .iter()
        .enumerate()
        .map(|(i, k)| {
            coord.submit(LaunchRequest {
                id: i as u64,
                profile: k.clone(),
                seed: 1000 + i as u64,
            })
        })
        .collect();
    let mut positions = Vec::new();
    for h in handles {
        let r = h.wait().unwrap();
        assert!(r.checksum.is_finite(), "id {} failed", r.id);
        assert!(r.exec_wall_ms > 0.0);
        positions.push(r.position);
    }
    positions.sort_unstable();
    assert_eq!(positions, (0..8).collect::<Vec<_>>());

    let (reports, stats) = coord.shutdown();
    assert_eq!(stats.n_failures, 0);
    assert_eq!(stats.n_responses, 8);
    // The batch must have been reordered by Algorithm 1 and simulated.
    let batch = &reports[0];
    assert_eq!(batch.n, 8);
    assert!(batch.sim_policy_ms <= batch.sim_fifo_ms + 1e-9);
    let _ = gpu;
}

#[test]
fn sustained_stream_multiple_batches() {
    let gpu = GpuSpec::gtx580();
    let coord = Coordinator::start(cfg(4));
    let mut handles = Vec::new();
    for b in 0..4u64 {
        for (i, k) in synthetic_workload(&gpu, 4, b).into_iter().enumerate() {
            handles.push(coord.submit(LaunchRequest {
                id: b * 4 + i as u64,
                profile: k,
                seed: b * 4 + i as u64,
            }));
        }
        coord.flush();
    }
    let mut ok = 0;
    for h in handles {
        let r = h.wait().unwrap();
        if r.checksum.is_finite() {
            ok += 1;
        }
    }
    assert_eq!(ok, 16);
    let (reports, stats) = coord.shutdown();
    assert_eq!(stats.n_responses, 16);
    assert!(reports.len() >= 4);
    assert!(stats.throughput_per_s() > 0.0);
}

#[test]
fn bad_artifact_name_is_failure_injected_not_fatal() {
    let gpu = GpuSpec::gtx580();
    let coord = Coordinator::start(cfg(2));
    let mut good = synthetic_workload(&gpu, 2, 99);
    good[1].artifact = "no_such_artifact".into();
    let h0 = coord.submit(LaunchRequest {
        id: 0,
        profile: good[0].clone(),
        seed: 0,
    });
    let h1 = coord.submit(LaunchRequest {
        id: 1,
        profile: good[1].clone(),
        seed: 0,
    });
    coord.flush();
    let r0 = h0.wait().unwrap();
    let r1 = h1.wait().unwrap();
    // One succeeds, the broken one reports the failure sentinel; the
    // service keeps running either way.
    let (a, b) = if r0.id == 0 { (r0, r1) } else { (r1, r0) };
    assert!(a.checksum.is_finite());
    assert_eq!(b.checksum, f64::NEG_INFINITY);
    let (_, stats) = coord.shutdown();
    assert_eq!(stats.n_failures, 1);
}
