//! Integration: the coordinator service executing REAL AOT payloads via
//! the PJRT execution backend while reordering batches with Algorithm 1 —
//! the full three-layer request path, through the trait seams.
//!
//! Compiled only with `--features pjrt` and `#[ignore]`d by default: the
//! payloads are AOT artifacts produced outside cargo (`make artifacts`),
//! which offline/CI environments don't have. Run with
//! `make artifacts && cargo test --features pjrt -- --ignored`.

#![cfg(feature = "pjrt")]

use kreorder::coordinator::{Coordinator, CoordinatorBuilder, LaunchRequest};
use kreorder::gpu::GpuSpec;
use kreorder::workloads::{by_id, synthetic_workload};
use std::path::PathBuf;
use std::time::Duration;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn coordinator(window: usize) -> Coordinator {
    CoordinatorBuilder::new()
        .policy_named("algorithm1")
        .unwrap()
        .pjrt_backend(artifacts_dir())
        .window(window)
        .linger(Duration::from_millis(10))
        .start()
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
fn serves_real_payloads_for_every_app() {
    let e = by_id("epbsessw-8").unwrap(); // 2 kernels per app
    let coord = coordinator(8);
    let handles: Vec<_> = e
        .kernels
        .iter()
        .enumerate()
        .map(|(i, k)| {
            coord.submit(LaunchRequest {
                id: i as u64,
                profile: k.clone(),
                seed: 1000 + i as u64,
            })
        })
        .collect();
    let mut positions = Vec::new();
    for h in handles {
        let r = h.wait().unwrap();
        assert!(r.checksum.is_finite(), "id {} failed", r.id);
        assert!(r.exec_wall_ms > 0.0);
        positions.push(r.position);
    }
    positions.sort_unstable();
    assert_eq!(positions, (0..8).collect::<Vec<_>>());

    let (reports, stats) = coord.shutdown();
    assert_eq!(stats.n_failures, 0);
    assert_eq!(stats.n_responses, 8);
    // The batch must have been reordered by Algorithm 1 (trait dispatch),
    // simulated, and executed by the PJRT backend.
    let batch = &reports[0];
    assert_eq!(batch.n, 8);
    assert_eq!(batch.policy, "algorithm1");
    assert_eq!(batch.backend, "pjrt");
    assert!(batch.sim_policy_ms <= batch.sim_fifo_ms + 1e-9);
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
fn sustained_stream_multiple_batches() {
    let gpu = GpuSpec::gtx580();
    let coord = coordinator(4);
    let mut handles = Vec::new();
    for b in 0..4u64 {
        for (i, k) in synthetic_workload(&gpu, 4, b).into_iter().enumerate() {
            handles.push(coord.submit(LaunchRequest {
                id: b * 4 + i as u64,
                profile: k,
                seed: b * 4 + i as u64,
            }));
        }
        coord.flush();
    }
    let mut ok = 0;
    for h in handles {
        let r = h.wait().unwrap();
        if r.checksum.is_finite() {
            ok += 1;
        }
    }
    assert_eq!(ok, 16);
    let (reports, stats) = coord.shutdown();
    assert_eq!(stats.n_responses, 16);
    assert!(reports.len() >= 4);
    assert!(stats.throughput_per_s() > 0.0);
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
fn bad_artifact_name_is_failure_injected_not_fatal() {
    let gpu = GpuSpec::gtx580();
    let coord = coordinator(2);
    let mut good = synthetic_workload(&gpu, 2, 99);
    good[1].artifact = "no_such_artifact".into();
    let h0 = coord.submit(LaunchRequest {
        id: 0,
        profile: good[0].clone(),
        seed: 0,
    });
    let h1 = coord.submit(LaunchRequest {
        id: 1,
        profile: good[1].clone(),
        seed: 0,
    });
    coord.flush();
    let r0 = h0.wait().unwrap();
    let r1 = h1.wait().unwrap();
    // One succeeds, the broken one reports the failure sentinel; the
    // service keeps running either way.
    let (a, b) = if r0.id == 0 { (r0, r1) } else { (r1, r0) };
    assert!(a.checksum.is_finite());
    assert_eq!(b.checksum, f64::NEG_INFINITY);
    let (_, stats) = coord.shutdown();
    assert_eq!(stats.n_failures, 1);
}

#[test]
#[ignore = "needs AOT artifacts (`make artifacts`) and a PJRT-enabled environment"]
fn multi_device_pjrt_builds_one_runtime_per_worker() {
    // Two device workers, each constructing its own PJRT backend via the
    // factory (the handles are !Send): both must serve real payloads.
    let gpu = GpuSpec::gtx580();
    let coord = CoordinatorBuilder::new()
        .policy_named("algorithm1")
        .unwrap()
        .pjrt_backend(artifacts_dir())
        .devices(2)
        .window(4)
        .linger(Duration::from_millis(10))
        .start();
    let mut handles = Vec::new();
    for b in 0..4u64 {
        for (i, k) in synthetic_workload(&gpu, 4, b).into_iter().enumerate() {
            handles.push(coord.submit(LaunchRequest {
                id: b * 4 + i as u64,
                profile: k,
                seed: i as u64,
            }));
        }
        coord.flush();
    }
    for h in handles {
        assert!(h.wait().unwrap().checksum.is_finite());
    }
    let (reports, _) = coord.shutdown();
    let mut devices: Vec<usize> = reports.iter().map(|r| r.device).collect();
    devices.sort_unstable();
    devices.dedup();
    assert_eq!(devices, vec![0, 1]);
}
