//! Acceptance pins for the observability layer (`kreorder::obs`):
//!
//! 1. **None-sink bit-identity + allocation parity** — each virtual-clock
//!    engine handed the strict no-op sink produces a bit-identical report
//!    and the exact same number of heap allocations as its untraced entry
//!    point, on both model backends: the sink observes, never perturbs.
//! 2. **Stream determinism** — `ring` and `jsonl` sinks capture
//!    bit-identical event streams across two runs of the same
//!    (seed, config), and the two sinks agree on the serialized stream.
//! 3. **Export round-trips** — the JSONL stream reparses to the identical
//!    event vector, and the Chrome trace-event JSON for a D=4 fleet run
//!    passes the structural validator with one batch-span lane per
//!    device.
//!
//! A counting global allocator wraps the system allocator; this file
//! holds a single `#[test]` (its own test binary) so no concurrent test
//! pollutes the counter.

use kreorder::admission::NoAdmission;
use kreorder::exec::{AnalyticBackend, ExecutionBackend, SimulatorBackend};
use kreorder::fault::FaultConfig;
use kreorder::fleet::{parse_route_policy, simulate_fleet_traced, FleetSpec};
use kreorder::gpu::GpuSpec;
use kreorder::obs::{export, JsonlSink, NoTrace, RingSink, TraceSink};
use kreorder::online::{
    parse_window_policy, simulate_online, simulate_online_traced, OnlineOpts, OnlineReorderer,
    ReplaySource, Trace,
};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn factory(backend: &str) -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
    match backend {
        "sim" => Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>),
        "analytic" => Box::new(|| Box::new(AnalyticBackend::new()) as Box<dyn ExecutionBackend>),
        other => panic!("unknown backend {other}"),
    }
}

/// One deterministic online run through the public untraced entry point.
/// Returns the full report, serialized — `Debug` covers every field, so
/// string equality pins bit-identity.
fn online_untraced(backend: &str) -> String {
    let gpu = GpuSpec::gtx580();
    let trace = Trace::poisson("mixed", 32, 600.0, 7);
    let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
    let window = parse_window_policy("linger:6:30").unwrap();
    let reorderer = OnlineReorderer::search("local:0", 200).unwrap();
    let f = factory(backend);
    let opts = OnlineOpts::default();
    let report = simulate_online(&gpu, source, window, &reorderer, f.as_ref(), &opts);
    format!("{report:?}")
}

/// The identical run through the traced entry point with a caller-chosen
/// sink.
fn online_traced(backend: &str, sink: &mut dyn TraceSink) -> String {
    let gpu = GpuSpec::gtx580();
    let trace = Trace::poisson("mixed", 32, 600.0, 7);
    let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
    let window = parse_window_policy("linger:6:30").unwrap();
    let reorderer = OnlineReorderer::search("local:0", 200).unwrap();
    let f = factory(backend);
    let mut admission = NoAdmission;
    let report = simulate_online_traced(
        &gpu,
        source,
        window,
        &reorderer,
        f.as_ref(),
        &OnlineOpts::default(),
        &mut admission,
        sink,
    );
    format!("{report:?}")
}

/// One deterministic D=4 fleet run with the given sink. Round-robin
/// routing guarantees every device executes batches, so the Chrome
/// export carries one batch-span lane per device.
fn fleet_traced(sink: &mut dyn TraceSink) -> String {
    let gpu = GpuSpec::gtx580();
    let fleet = FleetSpec::parse("4").unwrap();
    let trace = Trace::poisson("mixed", 48, 800.0, 13);
    let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
    let f = factory("sim");
    let mut admission = NoAdmission;
    let report = simulate_fleet_traced(
        &fleet,
        source,
        parse_route_policy("roundrobin").unwrap(),
        &|| parse_window_policy("linger:4:20").unwrap(),
        &OnlineReorderer::search("local:0", 200).unwrap(),
        f.as_ref(),
        &OnlineOpts::default(),
        &FaultConfig::default(),
        &mut admission,
        sink,
    );
    format!("{report:?}")
}

/// Allocation calls performed by `f`, plus its result.
fn count_allocs<R>(f: impl FnOnce() -> R) -> (usize, R) {
    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    let r = f();
    let after = ALLOC_CALLS.load(Ordering::Relaxed);
    (after - before, r)
}

#[test]
fn tracing_observes_never_perturbs() {
    // ---- 1. none-sink bit-identity + allocation parity ----------------
    // The untraced entry points delegate to the traced engines with the
    // no-op sink, so the two calls must match bit for bit AND allocation
    // for allocation — any event construction hoisted out of the
    // `if traced` guard shows up here as an allocation-count drift.
    for backend in ["sim", "analytic"] {
        // Warm-up absorbs one-time lazy initialization.
        let _ = online_untraced(backend);
        let (untraced_allocs, untraced_report) = count_allocs(|| online_untraced(backend));
        let mut none = NoTrace;
        let (none_allocs, none_report) = count_allocs(|| online_traced(backend, &mut none));
        assert_eq!(
            untraced_report, none_report,
            "{backend}: none-sink run drifted from the untraced engine"
        );
        assert_eq!(
            untraced_allocs, none_allocs,
            "{backend}: none-sink run allocated differently from the untraced engine"
        );
    }

    // ---- 2. ring/jsonl stream determinism per (seed, config) ----------
    let mut ring_a = RingSink::new(100_000);
    let report_a = fleet_traced(&mut ring_a);
    let mut ring_b = RingSink::new(100_000);
    let report_b = fleet_traced(&mut ring_b);
    assert_eq!(report_a, report_b, "traced fleet runs must be bit-identical");
    let events = ring_a.snapshot();
    assert!(!events.is_empty(), "a traced fleet run must record events");
    assert_eq!(events, ring_b.snapshot(), "ring streams drifted across runs");

    let mut jsonl_a = JsonlSink::new("never-flushed-a.jsonl");
    let _ = fleet_traced(&mut jsonl_a);
    let mut jsonl_b = JsonlSink::new("never-flushed-b.jsonl");
    let _ = fleet_traced(&mut jsonl_b);
    assert_eq!(jsonl_a.lines(), jsonl_b.lines(), "jsonl streams drifted across runs");
    // The two sink kinds agree on the serialized stream.
    let ring_serialized = export::jsonl(&events);
    let jsonl_serialized: String = jsonl_a.lines().iter().map(|l| format!("{l}\n")).collect();
    assert_eq!(ring_serialized, jsonl_serialized, "ring and jsonl disagree on the stream");

    // ---- 3. export round-trips ----------------------------------------
    let reparsed = export::events_from_jsonl(&ring_serialized).unwrap();
    assert_eq!(reparsed, events, "JSONL round-trip must be lossless");

    let chrome = export::chrome_trace_json(&events);
    let summary = export::validate_chrome_trace(&chrome).expect("exported trace must validate");
    assert!(summary.n_spans > 0, "a fleet run must export batch spans");
    assert_eq!(
        summary.n_lanes, 4,
        "round-robin over D=4 must put batch spans on every device lane"
    );
    assert!(summary.n_events >= 2 * summary.n_spans, "spans are B/E pairs");
    assert!(summary.max_ts_us >= 0.0);
}
