//! Overload-protection invariants, property-sweep style.
//!
//! Two contracts, pinned across random overload traces, every admission
//! policy kind, both model backends and both virtual-clock engines:
//!
//! 1. **Conservation** — every arrival is accounted exactly once:
//!    `completed + shed == arrivals`, the completed and shed id sets
//!    partition the arrival ids, and every shed record carries the
//!    [`ShedCause::Rejected`] cause with `attempts == 0` (no faults run
//!    here, so admission is the only shedder).
//! 2. **`admission=none` is a strict no-op** — bit-identical reports to
//!    the ungated engines, and a bound the trace can never reach
//!    (`bound:1000000`) is *also* bit-identical: the gate observes the
//!    queue, it never perturbs it.
//!
//! The "proptest" here is the crate's own seeded [`SplitMix64`] driving
//! case generation — deterministic, dependency-free, and every failure
//! message carries the case's full coordinates for replay.

use kreorder::admission::{parse_admission_policy, NoAdmission};
use kreorder::exec::{AnalyticBackend, ExecutionBackend, SimulatorBackend};
use kreorder::fleet::{FleetSimConfig, FleetSpec};
use kreorder::gpu::GpuSpec;
use kreorder::online::{
    parse_window_policy, simulate_online, simulate_online_with_admission, OnlineOpts,
    OnlineReorderer, ReplaySource, ShedCause, Trace,
};
use kreorder::util::SplitMix64;

const FAMILIES: [&str; 3] = ["uniform", "skewed", "mixed"];
const POLICIES: [&str; 6] = [
    "none",
    "bound:1",
    "bound:4",
    "deadline:25",
    "deadline:250",
    "codel:10:80",
];

fn factory(analytic: bool) -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
    if analytic {
        Box::new(|| Box::new(AnalyticBackend::new()) as Box<dyn ExecutionBackend>)
    } else {
        Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)
    }
}

fn source(trace: &Trace) -> Box<ReplaySource> {
    let gpu = GpuSpec::gtx580();
    Box::new(ReplaySource::from_trace(trace, &gpu).expect("registry family"))
}

/// Assert the (completed, shed) id sets partition `0..count` and every
/// shed record is a zero-attempt rejection.
fn assert_conservation(
    label: &str,
    count: usize,
    completed: impl Iterator<Item = u64>,
    shed: &[kreorder::online::ShedRecord],
) {
    let mut ids: Vec<u64> = completed.chain(shed.iter().map(|s| s.id)).collect();
    assert_eq!(ids.len(), count, "{label}: completed + shed != arrivals");
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), count, "{label}: duplicate ids across completed/shed");
    assert_eq!(ids.first().copied(), Some(0).filter(|_| count > 0), "{label}");
    for s in shed {
        assert_eq!(s.attempts, 0, "{label}: rejected arrivals never attempted");
        assert!(
            matches!(s.cause, ShedCause::Rejected { .. }),
            "{label}: unexpected shed cause {}",
            s.cause
        );
    }
}

#[test]
fn online_random_overload_conserves_across_policies_and_backends() {
    let gpu = GpuSpec::gtx580();
    let mut rng = SplitMix64::new(0xC0DE_2024);
    for case in 0..24 {
        let family = FAMILIES[(rng.next_u64() % FAMILIES.len() as u64) as usize];
        let count = 12 + (rng.next_u64() % 28) as usize;
        // Rates spanning mild to absurd overload for these tiny pools.
        let rate = 200.0 + rng.next_f64() * 3800.0;
        let seed = rng.next_u64();
        let policy = POLICIES[(rng.next_u64() % POLICIES.len() as u64) as usize];
        let analytic = rng.next_u64() % 2 == 0;
        let label = format!(
            "case {case}: {family} n={count} rate={rate:.1} seed={seed} {policy} analytic={analytic}"
        );

        let trace = Trace::poisson(family, count, rate, seed);
        let mut admission = parse_admission_policy(policy).expect("sweep spelling");
        let r = simulate_online_with_admission(
            &gpu,
            source(&trace),
            parse_window_policy("linger:6:30").unwrap(),
            &OnlineReorderer::fifo(),
            factory(analytic).as_ref(),
            &OnlineOpts::default(),
            admission.as_mut(),
        );
        assert_eq!(r.admission, admission.name(), "{label}");
        assert_conservation(&label, count, r.kernels.iter().map(|k| k.id), &r.shed);
        if policy == "none" {
            assert!(r.shed.is_empty(), "{label}: none must never shed");
        }
    }
}

#[test]
fn fleet_random_overload_conserves_across_policies_and_backends() {
    let mut rng = SplitMix64::new(0xF1EE_7001);
    for case in 0..12 {
        let devices = 1 + (rng.next_u64() % 3) as usize;
        let family = FAMILIES[(rng.next_u64() % FAMILIES.len() as u64) as usize];
        let count = 12 + (rng.next_u64() % 24) as usize;
        let rate = 300.0 + rng.next_f64() * 3000.0;
        let seed = rng.next_u64();
        let policy = POLICIES[(rng.next_u64() % POLICIES.len() as u64) as usize];
        let analytic = rng.next_u64() % 2 == 0;
        let label = format!(
            "case {case}: {devices}dev {family} n={count} rate={rate:.1} seed={seed} {policy} \
             analytic={analytic}"
        );

        let trace = Trace::poisson(family, count, rate, seed);
        let r = FleetSimConfig::new(FleetSpec::homogeneous(devices), source(&trace))
            .route_named("jsq")
            .unwrap()
            .window_named("linger:6:30")
            .unwrap()
            .backend(factory(analytic))
            .admission_named(policy)
            .unwrap()
            .run();
        assert_conservation(&label, count, r.kernels.iter().map(|k| k.id), &r.shed);
        if policy == "none" {
            assert!(r.shed.is_empty(), "{label}: none must never shed");
        }
    }
}

#[test]
fn admission_none_is_bit_identical_to_the_ungated_online_engine() {
    let gpu = GpuSpec::gtx580();
    for analytic in [false, true] {
        let trace = Trace::poisson("mixed", 24, 900.0, 11);
        let window = || parse_window_policy("linger:6:30").unwrap();
        let reorderer = OnlineReorderer::search("local:0", 150).unwrap();
        let base = simulate_online(
            &gpu,
            source(&trace),
            window(),
            &reorderer,
            factory(analytic).as_ref(),
            &OnlineOpts::default(),
        );
        let mut none = NoAdmission;
        let gated = simulate_online_with_admission(
            &gpu,
            source(&trace),
            window(),
            &reorderer,
            factory(analytic).as_ref(),
            &OnlineOpts::default(),
            &mut none,
        );
        // An unreachable bound runs the gate arithmetic on every
        // arrival yet must not perturb a single bit: the gate observes.
        let mut big = parse_admission_policy("bound:1000000").unwrap();
        let bounded = simulate_online_with_admission(
            &gpu,
            source(&trace),
            window(),
            &reorderer,
            factory(analytic).as_ref(),
            &OnlineOpts::default(),
            big.as_mut(),
        );
        for other in [&gated, &bounded] {
            assert!(other.shed.is_empty(), "analytic={analytic}");
            assert_eq!(base.kernels.len(), other.kernels.len());
            assert_eq!(base.span_ms.to_bits(), other.span_ms.to_bits(), "analytic={analytic}");
            for (a, b) in base.kernels.iter().zip(other.kernels.iter()) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.finish_ms.to_bits(), b.finish_ms.to_bits(), "analytic={analytic}");
                assert_eq!(a.start_ms.to_bits(), b.start_ms.to_bits());
                assert_eq!(a.batch, b.batch);
                assert_eq!(a.position, b.position);
            }
        }
        assert_eq!(gated.admission, "none");
        assert_eq!(bounded.admission, "bound:1000000");
    }
}

#[test]
fn admission_none_is_bit_identical_to_the_ungated_fleet_engine() {
    for analytic in [false, true] {
        let trace = Trace::poisson("skewed", 30, 1200.0, 17);
        let run = |admission: Option<&str>| {
            let cfg = FleetSimConfig::new(FleetSpec::parse("1,0.5").unwrap(), source(&trace))
                .route_named("jsq")
                .unwrap()
                .window_named("linger:6:30")
                .unwrap()
                .backend(factory(analytic));
            match admission {
                Some(a) => cfg.admission_named(a).unwrap().run(),
                None => cfg.run(),
            }
        };
        let base = run(None);
        let gated = run(Some("none"));
        let bounded = run(Some("bound:1000000"));
        for other in [&gated, &bounded] {
            assert!(other.shed.is_empty(), "analytic={analytic}");
            assert_eq!(base.kernels.len(), other.kernels.len());
            assert_eq!(base.span_ms.to_bits(), other.span_ms.to_bits(), "analytic={analytic}");
            for (a, b) in base.kernels.iter().zip(other.kernels.iter()) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.device, b.device, "analytic={analytic}");
                assert_eq!(a.finish_ms.to_bits(), b.finish_ms.to_bits(), "analytic={analytic}");
                assert_eq!(a.route_ms.to_bits(), b.route_ms.to_bits());
            }
        }
        assert_eq!(base.admission, "none");
        assert_eq!(bounded.admission, "bound:1000000");
    }
}

#[test]
fn a_hard_bound_actually_bounds_the_standing_queue() {
    // Deep overload with bound:1: at most one kernel is ever in the
    // system, so every completed sojourn is one batch's worth — orders
    // of magnitude below the ungated tail — and most arrivals bounce.
    let gpu = GpuSpec::gtx580();
    let trace = Trace::poisson("uniform", 40, 4000.0, 23);
    let mut one = parse_admission_policy("bound:1").unwrap();
    let r = simulate_online_with_admission(
        &gpu,
        source(&trace),
        parse_window_policy("fixed:1").unwrap(),
        &OnlineReorderer::fifo(),
        factory(false).as_ref(),
        &OnlineOpts::default(),
        one.as_mut(),
    );
    assert_eq!(r.kernels.len() + r.shed.len(), 40);
    assert!(!r.shed.is_empty(), "bound:1 under 40 near-simultaneous arrivals must shed");
    assert!(!r.kernels.is_empty(), "the first arrival is always admitted");
    // With occupancy capped at 1 and fixed:1 windows, no admitted
    // kernel ever waits behind another admitted kernel's batch.
    let max_sojourn = r
        .kernels
        .iter()
        .map(|k| k.finish_ms - k.arrival_ms)
        .fold(0.0f64, f64::max);
    let ungated = simulate_online(
        &gpu,
        source(&trace),
        parse_window_policy("fixed:1").unwrap(),
        &OnlineReorderer::fifo(),
        factory(false).as_ref(),
        &OnlineOpts::default(),
    );
    let ungated_max = ungated
        .kernels
        .iter()
        .map(|k| k.finish_ms - k.arrival_ms)
        .fold(0.0f64, f64::max);
    assert!(
        max_sojourn < ungated_max,
        "bounded max sojourn {max_sojourn} ms should sit far below ungated {ungated_max} ms"
    );
}
