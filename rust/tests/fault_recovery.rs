//! Integration pins for the fault-injection and recovery subsystem.
//!
//! The headline contract (ISSUE 7 acceptance): **no kernel is ever
//! silently lost**. Under every fault plan — crashes, recoveries,
//! slowdowns, seeded launch failures — every arrival ends in exactly one
//! of the completed ledger (`FleetReport::kernels`) or the shed ledger
//! (`FleetReport::shed`, with a recorded cause), and the whole run is
//! bit-identical per (fault plan, fault seed, configuration) on both
//! model backends. An empty plan is a strict no-op: the fault-aware
//! entry point bit-matches `simulate_fleet`, and on one device it
//! bit-matches the single-device online engine.

use kreorder::exec::{AnalyticBackend, ExecutionBackend, SimulatorBackend};
use kreorder::fault::{FaultConfig, FaultPlan, RetryPolicy};
use kreorder::fleet::{
    parse_route_policy, simulate_fleet, simulate_fleet_with_faults, FleetReport, FleetSpec,
    ShedCause,
};
use kreorder::gpu::GpuSpec;
use kreorder::online::{
    parse_window_policy, simulate_online, ClosedLoopSource, OnlineOpts, OnlineReorderer,
    ReplaySource, Trace,
};
use kreorder::workloads::scenario_by_id;

fn sim_factory() -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
    Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)
}

fn analytic_factory() -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
    Box::new(|| Box::new(AnalyticBackend::new()) as Box<dyn ExecutionBackend>)
}

fn run_faulty(
    fleet: &FleetSpec,
    trace: &Trace,
    route: &str,
    faults: &FaultConfig,
    factory: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
) -> FleetReport {
    let gpu = GpuSpec::gtx580();
    let source = Box::new(ReplaySource::from_trace(trace, &gpu).unwrap());
    let reorderer = OnlineReorderer::search("local:3", 200).unwrap();
    simulate_fleet_with_faults(
        fleet,
        source,
        parse_route_policy(route).unwrap(),
        &|| parse_window_policy("linger:6:25").unwrap(),
        &reorderer,
        factory,
        &OnlineOpts::default(),
        faults,
    )
}

fn sojourn_bits(r: &FleetReport) -> Vec<u64> {
    r.sojourns_ms().iter().map(|t| t.to_bits()).collect()
}

/// Every arrival id appears in exactly one ledger.
fn assert_conserved(r: &FleetReport, n_arrivals: usize) {
    let mut ids: Vec<u64> = r.kernels.iter().map(|k| k.id).collect();
    ids.extend(r.shed.iter().map(|s| s.id));
    ids.sort_unstable();
    let expected: Vec<u64> = (0..n_arrivals as u64).collect();
    assert_eq!(
        ids, expected,
        "conservation violated: completed {} + shed {} vs {} arrivals",
        r.kernels.len(),
        r.shed.len(),
        n_arrivals
    );
}

/// The acceptance pin: completed + shed == arrivals under every fault
/// plan, on both model backends, with the whole ledger (sojourn bits,
/// shed records, fault accounting) bit-identical across two runs.
#[test]
fn no_kernel_is_lost_under_any_plan_on_either_backend() {
    let fleet = FleetSpec::parse("1,1,0.5").unwrap();
    let trace = Trace::poisson("mixed", 32, 400.0, 11);
    let plans = [
        "crash:0@20",
        "crash:0@15:recover@60",
        "slowdown:1@10:3.0",
        "launchfail:0.3:7",
        "crash:2@25;slowdown:0@5:2.0;launchfail:0.15:9",
    ];
    let factories: [(&str, Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync>); 2] =
        [("sim", sim_factory()), ("analytic", analytic_factory())];
    for plan_spec in plans {
        let faults = FaultConfig {
            plan: FaultPlan::parse(plan_spec).unwrap(),
            retry: RetryPolicy::new(4, 13),
        };
        for (bname, factory) in &factories {
            let a = run_faulty(&fleet, &trace, "jsq", &faults, factory.as_ref());
            let b = run_faulty(&fleet, &trace, "jsq", &faults, factory.as_ref());
            assert_conserved(&a, 32);
            assert_eq!(
                sojourn_bits(&a),
                sojourn_bits(&b),
                "sojourns drifted: plan={plan_spec} backend={bname}"
            );
            assert_eq!(a.shed, b.shed, "shed ledger drifted: plan={plan_spec}");
            assert_eq!(a.span_ms.to_bits(), b.span_ms.to_bits());
            assert_eq!(a.n_rerouted, b.n_rerouted);
            assert_eq!(a.n_launch_failures, b.n_launch_failures);
            assert_eq!(a.n_degraded_decisions, b.n_degraded_decisions);
            for s in &a.shed {
                // The cause is a typed enum now, so "has a cause" is
                // structural; pin that its rendering stays actionable.
                assert!(
                    !s.cause.to_string().is_empty(),
                    "shed kernel {} has a blank cause",
                    s.id
                );
                // No admission gate runs here: faults are the only shedder.
                assert!(
                    !matches!(s.cause, ShedCause::Rejected { .. }),
                    "fault run shed kernel {} with an admission cause",
                    s.id
                );
            }
        }
    }
}

/// An empty fault plan is a strict no-op: the fault-aware entry point
/// produces the bit-identical run to `simulate_fleet` — no extra
/// events, no PRNG draws, no float drift.
#[test]
fn an_empty_plan_bit_matches_the_faultless_engine() {
    let gpu = GpuSpec::gtx580();
    let fleet = FleetSpec::parse("1,1,0.5").unwrap();
    let trace = Trace::bursty("skewed", 32, 300.0, 9);
    let reorderer = OnlineReorderer::search("local:1", 200).unwrap();
    let factory = sim_factory();
    let make_window = || parse_window_policy("linger:6:30").unwrap();

    let plain = simulate_fleet(
        &fleet,
        Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap()),
        parse_route_policy("lrw").unwrap(),
        &make_window,
        &reorderer,
        factory.as_ref(),
        &OnlineOpts::default(),
    );
    let faulty = simulate_fleet_with_faults(
        &fleet,
        Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap()),
        parse_route_policy("lrw").unwrap(),
        &make_window,
        &reorderer,
        factory.as_ref(),
        &OnlineOpts::default(),
        &FaultConfig::default(),
    );
    assert_eq!(sojourn_bits(&plain), sojourn_bits(&faulty));
    assert_eq!(plain.span_ms.to_bits(), faulty.span_ms.to_bits());
    assert_eq!(faulty.n_fault_events, 0);
    assert_eq!(faulty.n_rerouted, 0);
    assert_eq!(faulty.n_launch_failures, 0);
    assert!(faulty.shed.is_empty());
    assert_eq!(faulty.completion_rate(), 1.0);
}

/// On one device with no faults, the fleet engine's fault entry point
/// bit-matches the single-device online engine record for record.
#[test]
fn single_device_empty_plan_matches_the_online_engine() {
    let gpu = GpuSpec::gtx580();
    let trace = Trace::poisson("skewed", 24, 300.0, 11);
    let reorderer = OnlineReorderer::search("local:3", 200).unwrap();
    let factory = sim_factory();

    let online = simulate_online(
        &gpu,
        Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap()),
        parse_window_policy("linger:6:25").unwrap(),
        &reorderer,
        factory.as_ref(),
        &OnlineOpts::default(),
    );
    let fleet = simulate_fleet_with_faults(
        &FleetSpec::homogeneous(1),
        Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap()),
        parse_route_policy("jsq").unwrap(),
        &|| parse_window_policy("linger:6:25").unwrap(),
        &reorderer,
        factory.as_ref(),
        &OnlineOpts::default(),
        &FaultConfig::default(),
    );
    assert_eq!(online.kernels.len(), fleet.kernels.len());
    for (o, f) in online.kernels.iter().zip(&fleet.kernels) {
        assert_eq!(o.id, f.id);
        assert_eq!(o.arrival_ms.to_bits(), f.arrival_ms.to_bits());
        assert_eq!(o.close_ms.to_bits(), f.close_ms.to_bits());
        assert_eq!(o.start_ms.to_bits(), f.start_ms.to_bits());
        assert_eq!(o.finish_ms.to_bits(), f.finish_ms.to_bits());
    }
    assert_eq!(online.span_ms.to_bits(), fleet.span_ms.to_bits());
}

/// A permanent crash mid-run: health-aware routing steers around the
/// dead device, every orphaned kernel re-routes, and nothing is shed.
#[test]
fn a_crash_reroutes_orphans_and_health_aware_routing_finishes_everything() {
    let fleet = FleetSpec::homogeneous(3);
    let trace = Trace::poisson("uniform", 48, 600.0, 5);
    let faults = FaultConfig {
        plan: FaultPlan::parse("crash:0@15").unwrap(),
        retry: RetryPolicy::default(),
    };
    let factory = sim_factory();
    let r = run_faulty(&fleet, &trace, "jsq", &faults, factory.as_ref());
    assert_conserved(&r, 48);
    assert!(r.shed.is_empty(), "health-aware jsq shed {:?}", r.shed);
    assert_eq!(r.completion_rate(), 1.0);
    assert!(r.n_rerouted > 0, "a crash at 15 ms under load must orphan something");
    for k in &r.kernels {
        assert!(
            k.device != 0 || k.finish_ms <= 15.0,
            "kernel {} finished on the dead device at {:.2} ms",
            k.id,
            k.finish_ms
        );
    }
}

/// Crash with recovery: the device serves again after `recover@`, and
/// everything still completes.
#[test]
fn a_recovered_device_returns_to_service() {
    let fleet = FleetSpec::homogeneous(2);
    let trace = Trace::poisson("uniform", 48, 300.0, 5);
    let faults = FaultConfig {
        plan: FaultPlan::parse("crash:0@10:recover@40").unwrap(),
        retry: RetryPolicy::default(),
    };
    let factory = sim_factory();
    let r = run_faulty(&fleet, &trace, "jsq", &faults, factory.as_ref());
    assert_conserved(&r, 48);
    assert!(r.shed.is_empty());
    assert!(
        r.kernels.iter().any(|k| k.device == 0 && k.start_ms >= 40.0),
        "device 0 never served again after recovery at 40 ms"
    );
    // Nothing *starts* on device 0 while it is down.
    for k in &r.kernels {
        assert!(
            k.device != 0 || k.start_ms < 10.0 || k.start_ms >= 40.0,
            "kernel {} started on device 0 at {:.2} ms while it was down",
            k.id,
            k.start_ms
        );
    }
}

/// Launch failures at the retry cap shed with a recorded cause and the
/// exact attempt count; a partial failure rate still conserves kernels.
#[test]
fn launch_failures_retry_then_shed_at_the_attempt_cap() {
    let fleet = FleetSpec::homogeneous(2);
    let trace = Trace::poisson("mixed", 16, 400.0, 3);
    let factory = sim_factory();

    // p = 1.0: every attempt fails, so every kernel sheds after exactly
    // max_attempts tries.
    let always = FaultConfig {
        plan: FaultPlan::parse("launchfail:1.0:7").unwrap(),
        retry: RetryPolicy::new(2, 0),
    };
    let r = run_faulty(&fleet, &trace, "jsq", &always, factory.as_ref());
    assert_conserved(&r, 16);
    assert!(r.kernels.is_empty(), "p=1.0 launch failure completed a kernel");
    assert_eq!(r.shed.len(), 16);
    for s in &r.shed {
        assert_eq!(s.attempts, 2, "kernel {} shed after {} attempts", s.id, s.attempts);
        assert!(
            matches!(s.cause, ShedCause::RetryCap { attempts: 2 }),
            "cause: {}",
            s.cause
        );
        assert!(s.cause.to_string().contains("retry cap"), "cause: {}", s.cause);
    }
    assert_eq!(r.n_launch_failures, 32, "16 kernels x 2 attempts");

    // A moderate failure rate with the default retry budget: failures
    // happen, retries absorb most of them, nothing is lost either way.
    let partial = FaultConfig {
        plan: FaultPlan::parse("launchfail:0.3:7").unwrap(),
        retry: RetryPolicy::default(),
    };
    let r = run_faulty(&fleet, &trace, "jsq", &partial, factory.as_ref());
    assert_conserved(&r, 16);
    assert!(r.n_launch_failures > 0, "p=0.3 over 16 kernels drew no failures");
    assert!(!r.kernels.is_empty(), "p=0.3 completed nothing");
}

/// A slowed device degrades to FIFO ordering (counted, not hidden) and
/// still serves everything.
#[test]
fn slowdown_devices_degrade_to_fifo_and_still_serve() {
    let fleet = FleetSpec::homogeneous(2);
    let trace = Trace::poisson("mixed", 32, 400.0, 11);
    let faults = FaultConfig {
        plan: FaultPlan::parse("slowdown:1@0:3.0").unwrap(),
        retry: RetryPolicy::default(),
    };
    let factory = sim_factory();
    let r = run_faulty(&fleet, &trace, "roundrobin", &faults, factory.as_ref());
    assert_conserved(&r, 32);
    assert!(r.shed.is_empty());
    assert!(
        r.n_degraded_decisions > 0,
        "round-robin sends half the windows to the slowed device; those must degrade"
    );
}

/// Generated plans are deterministic per seed, valid for their fleet,
/// and round-trip through the CSV serialization.
#[test]
fn generated_plans_are_deterministic_valid_and_round_trip() {
    let a = FaultPlan::generate(42, 4, 500.0, 6);
    let b = FaultPlan::generate(42, 4, 500.0, 6);
    assert_eq!(a.name(), b.name());
    assert!(!a.is_empty());
    assert!(a.validate_for(4).is_ok());
    let reparsed = FaultPlan::parse(&a.to_csv()).unwrap();
    assert_eq!(reparsed.name(), a.name());
    // A different seed draws a different plan (at 6 faults the
    // collision odds are negligible).
    let c = FaultPlan::generate(43, 4, 500.0, 6);
    assert_ne!(a.name(), c.name());
}

/// Closed-loop sources must not deadlock when their outstanding kernel
/// is shed: the shed path feeds completions back, so think-time clients
/// keep issuing and the run terminates with everything accounted for.
#[test]
fn closed_loop_sources_survive_sheds_without_deadlock() {
    let gpu = GpuSpec::gtx580();
    let family = scenario_by_id("mixed").unwrap();
    let fleet = FleetSpec::homogeneous(2);
    let faults = FaultConfig {
        plan: FaultPlan::parse("launchfail:1.0:5").unwrap(),
        retry: RetryPolicy::new(2, 1),
    };
    let factory = sim_factory();
    let reorderer = OnlineReorderer::fifo();
    let r = simulate_fleet_with_faults(
        &fleet,
        Box::new(ClosedLoopSource::new(family, &gpu, 16, 4, 2.0, 3)),
        parse_route_policy("jsq").unwrap(),
        &|| parse_window_policy("linger:6:25").unwrap(),
        &reorderer,
        factory.as_ref(),
        &OnlineOpts::default(),
        &faults,
    );
    assert_eq!(
        r.kernels.len() + r.shed.len(),
        16,
        "closed loop stalled: {} completed + {} shed of 16",
        r.kernels.len(),
        r.shed.len()
    );
}
