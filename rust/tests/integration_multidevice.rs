//! Integration: multi-device dispatch through the builder — batches are
//! round-robined across per-device worker threads, each dispatching
//! through its own `ExecutionBackend` trait object. Runs on the model
//! backends, so no external artifacts are needed.

use kreorder::coordinator::{CoordinatorBuilder, LaunchRequest};
use kreorder::gpu::GpuSpec;
use kreorder::workloads::synthetic_workload;
use std::collections::BTreeMap;
use std::time::Duration;

/// The acceptance check for the redesign: `devices(2)` demonstrably
/// dispatches batches on two worker threads.
#[test]
fn two_devices_share_the_batch_stream() {
    let gpu = GpuSpec::gtx580();
    let coord = CoordinatorBuilder::new()
        .policy_named("algorithm1")
        .unwrap()
        .devices(2)
        .window(4)
        .linger(Duration::from_millis(10))
        .start();

    let n_batches = 8u64;
    let mut handles = Vec::new();
    for b in 0..n_batches {
        for (i, k) in synthetic_workload(&gpu, 4, b).into_iter().enumerate() {
            handles.push(coord.submit(LaunchRequest {
                id: b * 4 + i as u64,
                profile: k,
                seed: i as u64,
            }));
        }
        coord.flush();
    }

    // Every request is answered exactly once, and each response names the
    // device that served it.
    let mut ids = Vec::new();
    let mut response_devices: BTreeMap<u64, usize> = BTreeMap::new();
    for h in handles {
        let r = h.wait().unwrap();
        ids.push(r.id);
        response_devices.insert(r.batch_id, r.device);
    }
    ids.sort_unstable();
    assert_eq!(ids, (0..n_batches * 4).collect::<Vec<_>>());

    let (reports, stats) = coord.shutdown();
    assert_eq!(stats.n_responses, (n_batches * 4) as usize);

    // Both device workers actually executed batches…
    let mut devices: Vec<usize> = reports.iter().map(|r| r.device).collect();
    devices.sort_unstable();
    devices.dedup();
    assert_eq!(devices, vec![0, 1], "expected both workers to serve");

    // …under strict round-robin by batch id, consistently between the
    // per-batch reports and the per-request responses.
    for r in &reports {
        assert_eq!(r.device, (r.batch_id as usize) % 2, "{r:?}");
        assert_eq!(response_devices.get(&r.batch_id), Some(&r.device));
    }
    // Shutdown returns reports ordered by batch id despite concurrent
    // workers.
    let ids: Vec<u64> = reports.iter().map(|r| r.batch_id).collect();
    let mut sorted = ids.clone();
    sorted.sort_unstable();
    assert_eq!(ids, sorted);
}

#[test]
fn many_devices_with_fewer_batches_still_answer_everything() {
    let gpu = GpuSpec::gtx580();
    let coord = CoordinatorBuilder::new()
        .devices(8)
        .window(2)
        .linger(Duration::from_millis(5))
        .start();
    let handles: Vec<_> = synthetic_workload(&gpu, 6, 3)
        .into_iter()
        .enumerate()
        .map(|(i, k)| {
            let h = coord.submit(LaunchRequest {
                id: i as u64,
                profile: k,
                seed: i as u64,
            });
            coord.flush(); // one-kernel batches: ids spread over devices
            h
        })
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
    let (reports, stats) = coord.shutdown();
    assert_eq!(stats.n_responses, 6);
    assert_eq!(reports.len(), 6);
}

#[test]
fn per_device_backends_are_independent_instances() {
    // The analytic backend on 3 devices: every batch report must name the
    // backend, and results must be identical across devices for identical
    // workloads (stateless model backends).
    let gpu = GpuSpec::gtx580();
    let coord = CoordinatorBuilder::new()
        .analytic_backend()
        .devices(3)
        .window(4)
        .linger(Duration::from_millis(5))
        .start();
    let mut handles = Vec::new();
    for b in 0..6u64 {
        // Same workload every batch.
        for (i, k) in synthetic_workload(&gpu, 4, 7).into_iter().enumerate() {
            handles.push(coord.submit(LaunchRequest {
                id: b * 4 + i as u64,
                profile: k,
                seed: 0,
            }));
        }
        coord.flush();
    }
    for h in handles {
        h.wait().unwrap();
    }
    let (reports, _) = coord.shutdown();
    let full: Vec<_> = reports.iter().filter(|r| r.n == 4).collect();
    assert!(full.len() >= 3, "expected several full batches");
    for r in &full {
        assert_eq!(r.backend, "analytic");
        assert_eq!(r.order, full[0].order, "policy must be deterministic");
        assert!((r.sim_policy_ms - full[0].sim_policy_ms).abs() < 1e-9);
    }
}

#[test]
fn search_policy_serves_batches_end_to_end() {
    // Coordinator integration for the search subsystem: a window-sized
    // batch is ordered by budgeted branch-and-bound (window ≤ the
    // policy's exact threshold) and the reordered batch must never be
    // slower than FIFO on the simulated device — search starts from the
    // Algorithm 1 warm start and only improves it.
    let gpu = GpuSpec::gtx580();
    let coord = CoordinatorBuilder::new()
        .policy_named("search:local:0:256")
        .unwrap()
        .devices(2)
        .window(5)
        .linger(Duration::from_millis(10))
        .start();

    let mut handles = Vec::new();
    for b in 0..4u64 {
        for (i, k) in synthetic_workload(&gpu, 5, 100 + b).into_iter().enumerate() {
            handles.push(coord.submit(LaunchRequest {
                id: b * 5 + i as u64,
                profile: k,
                seed: i as u64,
            }));
        }
        coord.flush();
    }
    for h in handles {
        h.wait().unwrap();
    }
    let (reports, stats) = coord.shutdown();
    assert_eq!(stats.n_responses, 20);
    for r in reports.iter().filter(|r| r.n == 5) {
        assert!(
            r.sim_policy_ms <= r.sim_fifo_ms * (1.0 + 1e-9),
            "search order slower than FIFO: {} vs {} (batch {})",
            r.sim_policy_ms,
            r.sim_fifo_ms,
            r.batch_id
        );
    }
}
