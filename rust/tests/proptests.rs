//! Property-based tests over randomized workloads.
//!
//! The offline environment ships no proptest crate, so these use the
//! in-tree deterministic generator (`synthetic_workload` + `SplitMix64`):
//! every case reports its seed on failure, making reproduction a
//! one-liner. Each property runs across hundreds of seeded cases.

use kreorder::exec::{AnalyticBackend, ExecutionBackend, PreparedWorkload, SimulatorBackend};
use kreorder::gpu::{GpuSpec, KernelProfile, ResourceVec};
use kreorder::perm::for_each_permutation;
use kreorder::sched::{registry, reorder, reorder_with, CombinedProfile, ScoreConfig};
use kreorder::sim::{
    self, rounds::pack_rounds, simulate_order, simulate_order_traced, BlockEvent,
};
use kreorder::util::{parallel_map, SplitMix64};
use kreorder::workloads::synthetic_workload;
use std::sync::atomic::{AtomicUsize, Ordering};

const CASES: u64 = 150;

fn gpu() -> GpuSpec {
    GpuSpec::gtx580()
}

fn workload(seed: u64) -> Vec<KernelProfile> {
    let n = 2 + (seed % 7) as usize; // 2..=8 kernels
    synthetic_workload(&gpu(), n, seed)
}

/// Any permutation of the workload must simulate to a finite, positive
/// makespan that is at least the roofline lower bound (work conservation)
/// and every kernel must finish by the makespan.
#[test]
fn prop_simulation_work_conservation() {
    for seed in 0..CASES {
        let g = gpu();
        let ks = workload(seed);
        let mut order: Vec<usize> = (0..ks.len()).collect();
        SplitMix64::new(seed).shuffle(&mut order);
        let r = simulate_order(&g, &ks, &order);
        assert!(r.makespan_ms.is_finite() && r.makespan_ms > 0.0, "seed {seed}");
        let work: f64 = ks.iter().map(|k| k.total_work()).sum();
        let mem: f64 = ks.iter().map(|k| k.total_mem()).sum();
        let lb = g.makespan_lower_bound(work, mem) * (1.0 - g.block_jitter);
        assert!(
            r.makespan_ms >= lb * (1.0 - 1e-9),
            "seed {seed}: makespan {} < lower bound {lb}",
            r.makespan_ms
        );
        for (i, &f) in r.kernel_finish_ms.iter().enumerate() {
            assert!(f > 0.0 && f <= r.makespan_ms * (1.0 + 1e-12), "seed {seed} kernel {i}");
        }
    }
}

/// The traced simulation places and finishes every block exactly once,
/// with monotone timestamps, and never exceeds SM resources at any
/// instant (replayed from the trace).
#[test]
fn prop_trace_resource_safety() {
    for seed in 0..CASES / 3 {
        let g = gpu();
        let ks = workload(seed);
        let order: Vec<usize> = (0..ks.len()).collect();
        let r = simulate_order_traced(&g, &ks, &order);
        let total_blocks: u32 = ks.iter().map(|k| k.n_blocks).sum();

        let mut placed = 0u32;
        let mut finished = 0u32;
        let mut last_t = 0.0f64;
        let cap = g.sm_capacity();
        let mut used: Vec<ResourceVec> = vec![ResourceVec::ZERO; g.n_sm as usize];
        for ev in &r.trace {
            assert!(ev.t_ms >= last_t - 1e-12, "seed {seed}: time went backwards");
            last_t = ev.t_ms;
            let res = ks[ev.kernel].block_resources();
            match ev.kind {
                sim::BlockEventKind::Placed => {
                    placed += 1;
                    used[ev.sm as usize] += res;
                    assert!(
                        used[ev.sm as usize].fits_within(&cap),
                        "seed {seed}: SM {} over capacity at t={}",
                        ev.sm,
                        ev.t_ms
                    );
                }
                sim::BlockEventKind::Finished => {
                    finished += 1;
                    used[ev.sm as usize] -= res;
                    assert!(used[ev.sm as usize].non_negative(), "seed {seed}");
                }
            }
        }
        assert_eq!(placed, total_blocks, "seed {seed}");
        assert_eq!(finished, total_blocks, "seed {seed}");
    }
}

/// Algorithm 1 always emits a permutation, for every score configuration.
#[test]
fn prop_scheduler_emits_permutation() {
    let configs = [
        ScoreConfig::default(),
        ScoreConfig::paper_strict(),
        ScoreConfig {
            resource_balance: false,
            ..ScoreConfig::default()
        },
        ScoreConfig {
            ratio_balance: false,
            ..ScoreConfig::default()
        },
        ScoreConfig {
            shm_sort: false,
            ..ScoreConfig::default()
        },
    ];
    for seed in 0..CASES {
        let g = gpu();
        let ks = workload(seed);
        for (ci, cfg) in configs.iter().enumerate() {
            let s = reorder_with(&g, &ks, cfg);
            let mut sorted = s.order.clone();
            sorted.sort_unstable();
            assert_eq!(
                sorted,
                (0..ks.len()).collect::<Vec<_>>(),
                "seed {seed} config {ci}"
            );
            // Rounds partition the order.
            let flat: Vec<usize> = s.rounds.iter().flatten().copied().collect();
            assert_eq!(flat, s.order, "seed {seed} config {ci}");
        }
    }
}

/// Every registered policy — including seeded `random:<s>` instances —
/// emits a valid permutation of `0..n` for arbitrary workloads. This is
/// the contract the coordinator and every backend rely on.
#[test]
fn prop_every_registered_policy_emits_permutation() {
    for seed in 0..CASES {
        let g = gpu();
        let ks = workload(seed);
        let mut policies = registry::all_policies();
        policies.push(registry::parse(&format!("random:{seed}")).unwrap());
        for p in &policies {
            let order = p.order(&g, &ks);
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(
                sorted,
                (0..ks.len()).collect::<Vec<_>>(),
                "seed {seed} policy {} order {order:?}",
                p.name()
            );
        }
    }
}

/// Both model backends report a finite positive makespan for every
/// registered policy's order, and the simulator backend agrees exactly
/// with the direct simulation call (the refactor-equivalence property,
/// generalized across random workloads).
#[test]
fn prop_model_backends_time_every_policy() {
    for seed in 0..CASES / 3 {
        let g = gpu();
        let ks = workload(seed);
        let mut sim_backend = SimulatorBackend::new();
        let mut analytic = AnalyticBackend::new();
        for p in registry::all_policies() {
            let order = p.order(&g, &ks);
            let t_sim = sim_backend.execute(&g, &ks, &order).makespan_ms;
            let t_direct = simulate_order(&g, &ks, &order).makespan_ms;
            assert_eq!(t_sim, t_direct, "seed {seed} policy {}", p.name());
            let t_analytic = analytic.execute(&g, &ks, &order).makespan_ms;
            assert!(
                t_analytic.is_finite() && t_analytic > 0.0,
                "seed {seed} policy {} analytic {t_analytic}",
                p.name()
            );
        }
    }
}

/// The algorithm's analytic rounds never violate per-SM capacity.
#[test]
fn prop_rounds_respect_capacity() {
    for seed in 0..CASES {
        let g = gpu();
        let ks = workload(seed);
        let s = reorder(&g, &ks);
        for round in &s.rounds {
            // Singleton rounds may exceed capacity (multi-wave kernels).
            if round.len() < 2 {
                continue;
            }
            let mut used = ResourceVec::ZERO;
            for &k in round {
                used += ks[k].per_sm_footprint(&g);
            }
            assert!(
                used.fits_within(&g.sm_capacity()),
                "seed {seed}: round {round:?}"
            );
        }
    }
}

/// ProfileCombine is commutative and associative (in resources, work and
/// memory), matching the paper's virtual-kernel construction.
#[test]
fn prop_profile_combine_algebra() {
    for seed in 0..CASES {
        let g = gpu();
        let ks = synthetic_workload(&g, 3, seed);
        let (a, b, c) = (
            CombinedProfile::of(&g, &ks[0]),
            CombinedProfile::of(&g, &ks[1]),
            CombinedProfile::of(&g, &ks[2]),
        );
        let ab = a.combine(&b);
        let ba = b.combine(&a);
        assert_eq!(ab, ba, "seed {seed}");
        let abc1 = ab.combine(&c);
        let abc2 = a.combine(&b.combine(&c));
        assert!(
            (abc1.work - abc2.work).abs() < 1e-9
                && (abc1.mem - abc2.mem).abs() < 1e-9
                && (abc1.footprint.warps - abc2.footprint.warps).abs() < 1e-9,
            "seed {seed}"
        );
    }
}

/// Identical kernels (same profile, any multiplicity) are order-invariant
/// — the paper's scope claim, exactly, even with jitter enabled.
#[test]
fn prop_identical_kernels_order_invariant() {
    for seed in 0..40 {
        let g = gpu();
        let mut rng = SplitMix64::new(seed);
        let base = &synthetic_workload(&g, 1, seed)[0];
        let n = 3 + rng.below(2); // 3..=4 kernels (n! sims each)
        let ks: Vec<KernelProfile> = (0..n).map(|_| base.clone()).collect();
        let mut idx: Vec<usize> = (0..n).collect();
        let reference = simulate_order(&g, &ks, &idx);
        let mut worst_dev = 0.0f64;
        for_each_permutation(&mut idx, &mut |p| {
            let t = simulate_order(&g, &ks, p).makespan_ms;
            worst_dev = worst_dev.max((t - reference.makespan_ms).abs() / reference.makespan_ms);
        });
        assert!(worst_dev < 1e-9, "seed {seed}: deviation {worst_dev}");
    }
}

/// The exhaustive best order is at least as good as the algorithm's, and
/// the algorithm's at least as good as the exhaustive worst (sanity of
/// the Table-3 columns) — on small workloads where the sweep is cheap.
#[test]
fn prop_algorithm_within_sweep_bounds() {
    for seed in 0..40 {
        let g = gpu();
        let ks = synthetic_workload(&g, 5, seed);
        let sw = kreorder::perm::sweep(&g, &ks);
        let t_alg = simulate_order(&g, &ks, &reorder(&g, &ks).order).makespan_ms;
        assert!(t_alg >= sw.best_ms * (1.0 - 1e-9), "seed {seed}");
        assert!(t_alg <= sw.worst_ms * (1.0 + 1e-9), "seed {seed}");
    }
}

/// Percentile rank is antitone: a faster time never ranks lower.
#[test]
fn prop_percentile_antitone() {
    for seed in 0..30 {
        let g = gpu();
        let ks = synthetic_workload(&g, 4, seed);
        let sw = kreorder::perm::sweep(&g, &ks);
        let probes = [sw.best_ms, sw.median_ms(), sw.worst_ms, sw.best_ms * 0.9];
        for a in &probes {
            for b in &probes {
                if a < b {
                    assert!(
                        sw.percentile_rank(*a) >= sw.percentile_rank(*b) - 1e-9,
                        "seed {seed}: rank({a}) < rank({b})"
                    );
                }
            }
        }
    }
}

/// The work-stealing `parallel_map` runs every task exactly once and
/// returns results in task order, under adversarially uneven task costs
/// (randomized sizes, thread counts, and per-task spin durations).
#[test]
fn prop_parallel_map_work_stealing_runs_each_task_once() {
    for seed in 0..25 {
        let mut rng = SplitMix64::new(seed);
        let n = 1 + rng.below(150);
        let threads = 1 + rng.below(16);
        let costs: Vec<u64> = (0..n).map(|_| rng.below(2000) as u64).collect();
        let counters: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let out = parallel_map(n, threads, |i| {
            counters[i].fetch_add(1, Ordering::Relaxed);
            // Uneven spin so workers finish their claims at very
            // different times.
            let mut acc = 0u64;
            for x in 0..costs[i] * 50 {
                acc = acc.wrapping_add(x ^ seed);
            }
            std::hint::black_box(acc);
            i * 3 + 1
        });
        assert_eq!(
            out,
            (0..n).map(|i| i * 3 + 1).collect::<Vec<_>>(),
            "seed {seed} n={n} threads={threads}"
        );
        for (i, c) in counters.iter().enumerate() {
            assert_eq!(
                c.load(Ordering::Relaxed),
                1,
                "seed {seed}: task {i} ran {} times",
                c.load(Ordering::Relaxed)
            );
        }
    }
}

/// Prepared workload handles agree exactly with their backend's
/// `execute` for arbitrary workloads and orders — the contract the
/// sweep hot path rests on (the checkpointed variant is pinned in
/// `tests/sweep_equivalence.rs`).
#[test]
fn prop_prepared_handles_match_execute() {
    for seed in 0..CASES / 5 {
        let g = gpu();
        let ks = workload(seed);
        let mut orders: Vec<Vec<usize>> = Vec::new();
        for i in 0..4u64 {
            let mut o: Vec<usize> = (0..ks.len()).collect();
            SplitMix64::new(seed.wrapping_mul(31).wrapping_add(i)).shuffle(&mut o);
            orders.push(o);
        }
        let mut backends: Vec<Box<dyn ExecutionBackend>> = vec![
            Box::new(SimulatorBackend::new()),
            Box::new(AnalyticBackend::new()),
        ];
        for backend in &mut backends {
            let direct: Vec<f64> = orders
                .iter()
                .map(|o| backend.execute(&g, &ks, o).makespan_ms)
                .collect();
            let mut prepared = backend.prepare(&g, &ks);
            for (o, d) in orders.iter().zip(&direct) {
                assert_eq!(
                    prepared.execute_order(o).to_bits(),
                    d.to_bits(),
                    "seed {seed} order {o:?}"
                );
            }
        }
    }
}

/// Round packing (analytic model) partitions the kernels for any order.
#[test]
fn prop_pack_rounds_partitions() {
    for seed in 0..CASES {
        let g = gpu();
        let ks = workload(seed);
        let mut order: Vec<usize> = (0..ks.len()).collect();
        SplitMix64::new(seed ^ 0xABCD).shuffle(&mut order);
        let rounds = pack_rounds(&g, &ks, &order);
        let flat: Vec<usize> = rounds.iter().flat_map(|r| r.kernels.clone()).collect();
        assert_eq!(flat, order, "seed {seed}");
    }
}

/// Every registered DAG scenario family generates a valid DAG at every
/// size: edges in range, acyclic (the builder's Kahn check passes), and
/// the arrival (identity) order is topological — the invariant the
/// online layer's FIFO guard rests on.
#[test]
fn prop_dag_scenarios_are_acyclic_with_topological_arrival_order() {
    use kreorder::workloads::all_dag_scenarios;
    for seed in 0..CASES / 3 {
        let g = gpu();
        for sc in all_dag_scenarios() {
            for n in 1..=9usize {
                let w = sc.workload(&g, n, seed);
                assert_eq!(w.n(), n, "seed {seed} family {} n={n}", sc.id);
                let graph = w
                    .dep_graph()
                    .unwrap_or_else(|e| panic!("seed {seed} family {} n={n}: {e}", sc.id));
                for &(p, s) in &w.deps {
                    assert!(
                        p < s,
                        "seed {seed} family {} n={n}: edge {p}->{s} points backward",
                        sc.id
                    );
                }
                let identity: Vec<usize> = (0..n).collect();
                assert!(
                    graph.is_topological(&identity),
                    "seed {seed} family {} n={n}: arrival order not topological",
                    sc.id
                );
            }
        }
    }
}

/// The constrained sweep enumerates exactly the linear extensions of the
/// dependency graph: its order count equals the subset-DP count for
/// random forward-edge DAGs, collapses to 1 on a chain, and recovers n!
/// on the antichain (no edges).
#[test]
fn prop_constrained_sweep_counts_linear_extensions() {
    use kreorder::perm::sweep_dag;
    use kreorder::workloads::{DepGraph, Workload};
    for seed in 0..CASES / 5 {
        let g = gpu();
        let mut rng = SplitMix64::new(seed ^ 0xDA6);
        let n = 2 + (seed % 6) as usize; // 2..=7 kernels
        let ks = synthetic_workload(&g, n, seed);

        // Random forward-edge DAG: each (i, j), i < j, independently.
        let mut deps = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.below(3) == 0 {
                    deps.push((i, j));
                }
            }
        }
        let graph = DepGraph::build(n, &deps).expect("forward edges are acyclic");
        let ext = graph.linear_extension_count().expect("n <= 7");
        let sw = sweep_dag(&g, &ks, &graph);
        assert_eq!(
            sw.n_perms as u128, ext,
            "seed {seed} n={n} deps {deps:?}: sweep count != extension count"
        );
        assert!(
            graph.is_topological(&sw.best_order),
            "seed {seed}: best order infeasible"
        );

        // Chain: exactly one topological order, the chain itself.
        let chain = Workload::independent(ks.clone()).with_chain(&(0..n).collect::<Vec<_>>());
        let chain_graph = chain.dep_graph().unwrap();
        assert_eq!(chain_graph.linear_extension_count(), Some(1), "seed {seed}");
        let sw_chain = sweep_dag(&g, &ks, &chain_graph);
        assert_eq!(sw_chain.n_perms, 1, "seed {seed}");
        assert_eq!(sw_chain.best_order, (0..n).collect::<Vec<_>>(), "seed {seed}");

        // Antichain: every permutation, n! of them.
        let free = DepGraph::empty(n);
        let factorial: u128 = (1..=n as u128).product();
        assert_eq!(free.linear_extension_count(), Some(factorial), "seed {seed}");
        let sw_free = sweep_dag(&g, &ks, &free);
        assert_eq!(sw_free.n_perms as u128, factorial, "seed {seed}");
    }
}

/// Dispatch is head-of-line in kernel-launch order: a kernel's first
/// block is never placed before an earlier kernel's first block.
#[test]
fn prop_dispatch_respects_launch_order() {
    for seed in 0..CASES / 3 {
        let g = gpu();
        let ks = workload(seed);
        let mut order: Vec<usize> = (0..ks.len()).collect();
        SplitMix64::new(seed ^ 0x1234).shuffle(&mut order);
        let r = simulate_order_traced(&g, &ks, &order);
        let placements: Vec<&BlockEvent> = r
            .trace
            .iter()
            .filter(|e| e.kind == sim::BlockEventKind::Placed)
            .collect();
        // Record the position of each kernel's first placement; it must
        // follow the launch order.
        let mut first_seen: Vec<Option<usize>> = vec![None; ks.len()];
        for (pos, ev) in placements.iter().enumerate() {
            if first_seen[ev.kernel].is_none() {
                first_seen[ev.kernel] = Some(pos);
            }
        }
        let firsts: Vec<usize> = order.iter().map(|&k| first_seen[k].unwrap()).collect();
        for w in firsts.windows(2) {
            assert!(w[0] < w[1], "seed {seed}: launch order violated");
        }
    }
}
