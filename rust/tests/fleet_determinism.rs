//! Integration pins for the fleet dispatch subsystem.
//!
//! The headline contract (ISSUE 6 acceptance): `simulate_fleet` produces
//! **bit-identical per-kernel sojourns** across runs for every
//! (route policy × window policy × reorderer) combination on both model
//! backends — the fleet, like the single-device online engine, is a pure
//! function of its configuration. The rest of the file pins the trace
//! record/replay round-trip through the fleet engine (including the
//! device-count header), the rejection of traces replayed onto a smaller
//! fleet, and the routed-vs-roundrobin p99 ordering the bench gates.

use kreorder::exec::{AnalyticBackend, ExecutionBackend, SimulatorBackend};
use kreorder::fleet::{parse_route_policy, simulate_fleet, FleetReport, FleetSpec};
use kreorder::gpu::GpuSpec;
use kreorder::online::{
    fifo_window_capacity_per_s, parse_window_policy, OnlineOpts, OnlineReorderer, ReplaySource,
    Trace,
};
use kreorder::workloads::scenario_by_id;

fn sim_factory() -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
    Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)
}

fn analytic_factory() -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
    Box::new(|| Box::new(AnalyticBackend::new()) as Box<dyn ExecutionBackend>)
}

fn run_fleet(
    fleet: &FleetSpec,
    trace: &Trace,
    route: &str,
    window: &str,
    reorderer: &OnlineReorderer,
    factory: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
) -> FleetReport {
    let gpu = GpuSpec::gtx580();
    let source = Box::new(ReplaySource::from_trace(trace, &gpu).unwrap());
    simulate_fleet(
        fleet,
        source,
        parse_route_policy(route).unwrap(),
        &|| parse_window_policy(window).unwrap(),
        reorderer,
        factory,
        &OnlineOpts::default(),
    )
}

fn sojourn_bits(r: &FleetReport) -> Vec<u64> {
    r.sojourns_ms().iter().map(|t| t.to_bits()).collect()
}

/// The acceptance pin: bit-identical sojourns, spans, eval counts and
/// device placements across runs for every route × window × reorderer
/// combination, on both model backends, on a heterogeneous fleet.
#[test]
fn fleet_runs_are_bit_identical_for_every_route_window_reorderer() {
    let fleet = FleetSpec::parse("1,0.5").unwrap();
    let trace = Trace::poisson("skewed", 32, 400.0, 11);
    let reorderers = [
        OnlineReorderer::fifo(),
        OnlineReorderer::search("local:3", 200).unwrap(),
    ];
    let factories: [(&str, Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync>); 2] =
        [("sim", sim_factory()), ("analytic", analytic_factory())];
    for route in ["roundrobin", "jsq", "lrw", "p2c:5", "affinity"] {
        for window in ["fixed:6", "linger:6:25", "adaptive:6:25"] {
            for reorderer in &reorderers {
                for (bname, factory) in &factories {
                    let a = run_fleet(&fleet, &trace, route, window, reorderer, factory.as_ref());
                    let b = run_fleet(&fleet, &trace, route, window, reorderer, factory.as_ref());
                    assert_eq!(
                        sojourn_bits(&a),
                        sojourn_bits(&b),
                        "sojourns drifted: route={route} window={window} reorderer={} \
                         backend={bname}",
                        reorderer.name()
                    );
                    assert_eq!(a.span_ms.to_bits(), b.span_ms.to_bits());
                    assert_eq!(a.decision_evals, b.decision_evals);
                    // Placement is part of the contract, not just timing.
                    let devs_a: Vec<usize> = a.kernels.iter().map(|k| k.device).collect();
                    let devs_b: Vec<usize> = b.kernels.iter().map(|k| k.device).collect();
                    assert_eq!(devs_a, devs_b);
                }
            }
        }
    }
}

#[test]
fn fleet_trace_records_and_replays_bit_identically_via_csv() {
    // The fleet record/replay escape hatch: a trace stamped with the
    // fleet size round-trips through its CSV serialization (what
    // `kreorder fleet --record` writes and `--replay` reads) and drives
    // an identical run.
    let fleet = FleetSpec::parse("1,1,0.5").unwrap();
    let trace = Trace::bursty("small-large", 32, 300.0, 9).with_devices(fleet.len());
    let reorderer = OnlineReorderer::search("local:1", 200).unwrap();
    let factory = sim_factory();

    let direct = run_fleet(&fleet, &trace, "jsq", "linger:6:30", &reorderer, factory.as_ref());
    let parsed = Trace::parse(&trace.to_csv()).unwrap();
    assert_eq!(parsed.devices, 3);
    let replayed = run_fleet(&fleet, &parsed, "jsq", "linger:6:30", &reorderer, factory.as_ref());
    assert_eq!(sojourn_bits(&direct), sojourn_bits(&replayed));
    assert_eq!(direct.span_ms.to_bits(), replayed.span_ms.to_bits());
}

#[test]
fn traces_reject_smaller_fleets_with_an_actionable_error() {
    let trace = Trace::poisson("uniform", 8, 200.0, 3).with_devices(3);
    let parsed = Trace::parse(&trace.to_csv()).unwrap();
    assert_eq!(parsed.devices, 3);
    let err = FleetSpec::homogeneous(2).validate_trace(&parsed).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("3-device"), "{msg}");
    assert!(msg.contains("only 2"), "{msg}");
    assert!(msg.contains("--devices 3"), "{msg}");
    // Equal or larger fleets replay fine.
    assert!(FleetSpec::homogeneous(3).validate_trace(&parsed).is_ok());
    assert!(FleetSpec::parse("1,1,0.5,0.25").unwrap().validate_trace(&parsed).is_ok());
}

/// The bench's hard gate, pinned as a test so `cargo test` catches a
/// regression before CI's bench-smoke does: on a lopsided fleet under
/// mild overload, load-aware routing must not lose the fleet p99
/// sojourn race to blind round-robin on the identical replayed trace.
#[test]
fn load_aware_routing_beats_roundrobin_on_a_skewed_heterogeneous_fleet() {
    let fleet = FleetSpec::parse("1,1,0.25").unwrap();
    let gpu = GpuSpec::gtx580();
    let pool = scenario_by_id("skewed").unwrap().workload(&gpu, 64, 23);
    let factory = sim_factory();
    // Calibrate ~1.05x the fleet's summed FIFO capacity of 8-kernel
    // windows — the same normalization benches/fleet_routing.rs uses.
    let capacity: f64 = fleet
        .devices
        .iter()
        .map(|g| fifo_window_capacity_per_s(g, &pool, 8, factory.as_ref()))
        .sum();
    let rate = 1.05 * capacity;
    let trace = Trace::poisson("skewed", 64, rate, 23);
    // FIFO reorderer isolates the routing effect from the ordering one.
    let reorderer = OnlineReorderer::fifo();

    let rr = run_fleet(&fleet, &trace, "roundrobin", "linger:8:40", &reorderer, factory.as_ref());
    let rr_p99 = rr.sojourn_stats().p99_ms;
    for route in ["jsq", "lrw"] {
        let routed = run_fleet(&fleet, &trace, route, "linger:8:40", &reorderer, factory.as_ref());
        let p99 = routed.sojourn_stats().p99_ms;
        assert!(
            p99 <= rr_p99 + 1e-9,
            "{route} fleet p99 {p99} ms lost to roundrobin {rr_p99} ms"
        );
    }
}
