//! Reporting: percentiles, histograms, and the Table-3 / Fig-1 style
//! outputs (markdown tables, CSV series).

mod histogram;
mod table;

pub use histogram::Histogram;
pub use table::{ExperimentRow, Table3};

/// p-th percentile (0–100) of a sample, linear interpolation, like
/// `numpy.percentile(..., method="linear")`. Returns 0 for empty input.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut xs = samples.to_vec();
    xs.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (xs.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        xs[lo]
    } else {
        let w = rank - lo as f64;
        xs[lo] * (1.0 - w) + xs[hi] * w
    }
}

/// Arithmetic mean (0 for empty input).
pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Sample standard deviation (0 for n < 2).
pub fn stddev(samples: &[f64]) -> f64 {
    if samples.len() < 2 {
        return 0.0;
    }
    let m = mean(samples);
    let var = samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (samples.len() - 1) as f64;
    var.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_endpoints() {
        let xs = vec![4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = vec![0.0, 10.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.5);
    }

    #[test]
    fn percentile_empty() {
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn mean_and_stddev() {
        let xs = vec![2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-12);
        assert_eq!(stddev(&[1.0]), 0.0);
    }
}
