//! Table-3 style experiment reporting: per-experiment rows with the
//! paper's comparison columns (optimal, worst, algorithm, percentile rank,
//! speedup over worst, deviation from optimal).

use crate::util::{deviation_pct, ratio_or_zero};

/// One row of the reproduction of the paper's Table 3.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentRow {
    pub name: String,
    pub optimal_ms: f64,
    pub worst_ms: f64,
    pub algorithm_ms: f64,
    /// Percentile rank of the algorithm's order in the permutation space.
    pub percentile: f64,
    pub n_perms: usize,
}

impl ExperimentRow {
    /// Speedup of the algorithm's order over the worst order.
    pub fn speedup_over_worst(&self) -> f64 {
        ratio_or_zero(self.worst_ms, self.algorithm_ms)
    }

    /// Deviation of the algorithm's order from the optimal, in percent.
    pub fn deviation_from_optimal_pct(&self) -> f64 {
        deviation_pct(self.algorithm_ms, self.optimal_ms)
    }
}

/// A full Table 3: rows plus render helpers.
#[derive(Debug, Clone, Default)]
pub struct Table3 {
    pub rows: Vec<ExperimentRow>,
}

impl Table3 {
    pub fn push(&mut self, row: ExperimentRow) {
        self.rows.push(row);
    }

    /// Render as a GitHub-flavored markdown table mirroring the paper's
    /// column layout.
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str(
            "| Experiment | Optimal (ms) | Worst (ms) | Algorithm (ms) | Percentile rank | Speedup over worst | Deviation from optimal |\n",
        );
        s.push_str("|---|---|---|---|---|---|---|\n");
        for r in &self.rows {
            s.push_str(&format!(
                "| {} | {:.2} | {:.2} | {:.2} | {:.1}% | {:.3} | {:.2}% |\n",
                r.name,
                r.optimal_ms,
                r.worst_ms,
                r.algorithm_ms,
                r.percentile,
                r.speedup_over_worst(),
                r.deviation_from_optimal_pct(),
            ));
        }
        s
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "experiment,optimal_ms,worst_ms,algorithm_ms,percentile_rank,speedup_over_worst,deviation_from_optimal_pct,n_perms\n",
        );
        for r in &self.rows {
            s.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{:.3},{:.4},{:.4},{}\n",
                r.name,
                r.optimal_ms,
                r.worst_ms,
                r.algorithm_ms,
                r.percentile,
                r.speedup_over_worst(),
                r.deviation_from_optimal_pct(),
                r.n_perms,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> ExperimentRow {
        ExperimentRow {
            name: "EpBs-6".into(),
            optimal_ms: 100.0,
            worst_ms: 167.0,
            algorithm_ms: 100.2,
            percentile: 96.1,
            n_perms: 720,
        }
    }

    #[test]
    fn derived_columns() {
        let r = row();
        assert!((r.speedup_over_worst() - 167.0 / 100.2).abs() < 1e-12);
        assert!((r.deviation_from_optimal_pct() - 0.2).abs() < 1e-9);
    }

    #[test]
    fn markdown_contains_rows() {
        let mut t = Table3::default();
        t.push(row());
        let md = t.to_markdown();
        assert!(md.contains("| EpBs-6 |"));
        assert!(md.contains("96.1%"));
        assert_eq!(md.lines().count(), 3);
    }

    #[test]
    fn csv_roundtrips_fields() {
        let mut t = Table3::default();
        t.push(row());
        let csv = t.to_csv();
        assert!(csv.lines().count() == 2);
        let fields: Vec<&str> = csv.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(fields.len(), 8);
        assert_eq!(fields[0], "EpBs-6");
        assert_eq!(fields[7], "720");
    }
}
