//! Fixed-bin histogram for the Fig-1 "time distribution of all
//! permutations" panel.

/// Equal-width histogram over `[min, max]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    pub min: f64,
    pub max: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    /// Build a histogram with `n_bins` equal-width bins spanning the data.
    /// Degenerate data (all equal) lands in the first bin.
    pub fn build(samples: &[f64], n_bins: usize) -> Histogram {
        assert!(n_bins > 0);
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in samples {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if samples.is_empty() {
            return Histogram {
                min: 0.0,
                max: 0.0,
                counts: vec![0; n_bins],
            };
        }
        let width = hi - lo;
        let mut counts = vec![0u64; n_bins];
        for &x in samples {
            let idx = if width <= 0.0 {
                0
            } else {
                (((x - lo) / width) * n_bins as f64).min(n_bins as f64 - 1.0) as usize
            };
            counts[idx] += 1;
        }
        Histogram {
            min: lo,
            max: hi,
            counts,
        }
    }

    /// Bin center of bin `i`.
    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.max - self.min) / self.counts.len() as f64;
        self.min + (i as f64 + 0.5) * w
    }

    /// Total count.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// p-th percentile (0–100) read off the binned distribution:
    /// linear interpolation within the bin where the cumulative count
    /// crosses the rank, so the answer is exact to bin resolution
    /// (±half a bin width). Two documented edge cases: an **empty**
    /// histogram returns `0.0` (there is no distribution to read), and
    /// a **zero-width** one — a single sample, or all samples equal —
    /// returns `min` exactly for every `p`. Used by the online latency
    /// reports for distribution summaries where the raw samples have
    /// been discarded.
    pub fn percentile(&self, p: f64) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        if self.max == self.min {
            // Every percentile of a zero-width span is that value;
            // skip the interpolation so the answer is exact rather
            // than `min + frac · 0`.
            return self.min;
        }
        let p = p.clamp(0.0, 100.0);
        let rank = p / 100.0 * total as f64;
        let width = (self.max - self.min) / self.counts.len() as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = cum + c;
            if next as f64 >= rank {
                // Interpolate inside this bin by the fraction of its
                // mass below the rank.
                let frac = ((rank - cum as f64) / c as f64).clamp(0.0, 1.0);
                return self.min + (i as f64 + frac) * width;
            }
            cum = next;
        }
        self.max
    }

    /// CSV rows: `bin_center,count`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("bin_center_ms,count\n");
        for (i, c) in self.counts.iter().enumerate() {
            s.push_str(&format!("{:.6},{}\n", self.bin_center(i), c));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_all_samples() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let h = Histogram::build(&xs, 10);
        assert_eq!(h.total(), 100);
        assert_eq!(h.counts, vec![10; 10]);
    }

    #[test]
    fn max_value_lands_in_last_bin() {
        let xs = vec![0.0, 1.0];
        let h = Histogram::build(&xs, 4);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[3], 1);
    }

    #[test]
    fn degenerate_all_equal() {
        let xs = vec![5.0; 7];
        let h = Histogram::build(&xs, 3);
        assert_eq!(h.counts[0], 7);
        assert_eq!(h.total(), 7);
    }

    #[test]
    fn empty_input() {
        let h = Histogram::build(&[], 3);
        assert_eq!(h.total(), 0);
    }

    #[test]
    fn percentile_tracks_exact_within_bin_resolution() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let h = Histogram::build(&xs, 100);
        let bin_width = (h.max - h.min) / 100.0;
        for p in [1.0, 25.0, 50.0, 90.0, 99.0] {
            let exact = crate::metrics::percentile(&xs, p);
            let approx = h.percentile(p);
            assert!(
                (approx - exact).abs() <= bin_width,
                "p{p}: approx {approx} vs exact {exact}"
            );
        }
        assert_eq!(h.percentile(0.0), h.min);
        assert!((h.percentile(100.0) - h.max).abs() <= bin_width);
    }

    #[test]
    fn percentile_empty_and_degenerate() {
        assert_eq!(Histogram::build(&[], 4).percentile(50.0), 0.0);
        let h = Histogram::build(&[5.0; 9], 4);
        // All mass in one zero-width bin.
        assert_eq!(h.percentile(50.0), 5.0);
    }

    #[test]
    fn percentile_single_sample_is_that_sample_at_every_p() {
        let h = Histogram::build(&[3.0], 4);
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p), 3.0, "p{p}");
        }
    }

    #[test]
    fn percentile_empty_is_zero_at_every_p() {
        let h = Histogram::build(&[], 4);
        for p in [0.0, 50.0, 100.0] {
            assert_eq!(h.percentile(p), 0.0, "p{p}");
        }
    }

    #[test]
    fn csv_has_header_and_rows() {
        let h = Histogram::build(&[1.0, 2.0, 3.0], 3);
        let csv = h.to_csv();
        assert!(csv.starts_with("bin_center_ms,count\n"));
        assert_eq!(csv.lines().count(), 4);
    }
}
