//! Fixed-bin histogram for the Fig-1 "time distribution of all
//! permutations" panel.

/// Equal-width histogram over `[min, max]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    pub min: f64,
    pub max: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    /// Build a histogram with `n_bins` equal-width bins spanning the data.
    /// Degenerate data (all equal) lands in the first bin.
    pub fn build(samples: &[f64], n_bins: usize) -> Histogram {
        assert!(n_bins > 0);
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in samples {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if samples.is_empty() {
            return Histogram {
                min: 0.0,
                max: 0.0,
                counts: vec![0; n_bins],
            };
        }
        let width = hi - lo;
        let mut counts = vec![0u64; n_bins];
        for &x in samples {
            let idx = if width <= 0.0 {
                0
            } else {
                (((x - lo) / width) * n_bins as f64).min(n_bins as f64 - 1.0) as usize
            };
            counts[idx] += 1;
        }
        Histogram {
            min: lo,
            max: hi,
            counts,
        }
    }

    /// Bin center of bin `i`.
    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.max - self.min) / self.counts.len() as f64;
        self.min + (i as f64 + 0.5) * w
    }

    /// Total count.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// CSV rows: `bin_center,count`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("bin_center_ms,count\n");
        for (i, c) in self.counts.iter().enumerate() {
            s.push_str(&format!("{:.6},{}\n", self.bin_center(i), c));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_all_samples() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let h = Histogram::build(&xs, 10);
        assert_eq!(h.total(), 100);
        assert_eq!(h.counts, vec![10; 10]);
    }

    #[test]
    fn max_value_lands_in_last_bin() {
        let xs = vec![0.0, 1.0];
        let h = Histogram::build(&xs, 4);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[3], 1);
    }

    #[test]
    fn degenerate_all_equal() {
        let xs = vec![5.0; 7];
        let h = Histogram::build(&xs, 3);
        assert_eq!(h.counts[0], 7);
        assert_eq!(h.total(), 7);
    }

    #[test]
    fn empty_input() {
        let h = Histogram::build(&[], 3);
        assert_eq!(h.total(), 0);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let h = Histogram::build(&[1.0, 2.0, 3.0], 3);
        let csv = h.to_csv();
        assert!(csv.starts_with("bin_center_ms,count\n"));
        assert_eq!(csv.lines().count(), 4);
    }
}
