//! [`FleetSpec`] — how many devices, and how fast each one is.
//!
//! A fleet is a list of per-device [`GpuSpec`]s. Heterogeneity is
//! modeled as a per-device *speed factor* scaling the compute roofline
//! (`compute_rate_per_sm`; memory bandwidth scales with it through
//! `balanced_ratio`), so a `0.5` device is uniformly half as fast and
//! every kernel that fits the baseline device fits every device. The
//! CLI spelling (`--devices`) is either a bare device count
//! (homogeneous) or a comma list of speed terms:
//!
//! | spelling | fleet |
//! |---|---|
//! | `4` | four baseline (GTX 580) devices |
//! | `1,1,0.5` | two baseline devices and one half-speed device |
//! | `2x1,2x0.25` | two baseline and two quarter-speed devices |

use crate::gpu::GpuSpec;
use crate::online::Trace;
use std::fmt;

/// A fleet of (possibly heterogeneous) devices, one [`GpuSpec`] each.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    /// Per-device models, indexed by device id.
    pub devices: Vec<GpuSpec>,
}

impl FleetSpec {
    /// `n` identical baseline (GTX 580) devices; `n` clamps to at least 1.
    pub fn homogeneous(n: usize) -> FleetSpec {
        FleetSpec {
            devices: vec![GpuSpec::gtx580(); n.max(1)],
        }
    }

    /// One device per speed factor, each a baseline device with its
    /// compute roofline scaled by the factor. An empty slice yields a
    /// single baseline device.
    pub fn heterogeneous(speeds: &[f64]) -> FleetSpec {
        if speeds.is_empty() {
            return FleetSpec::homogeneous(1);
        }
        let base = GpuSpec::gtx580();
        FleetSpec {
            devices: speeds
                .iter()
                .map(|&s| GpuSpec {
                    compute_rate_per_sm: base.compute_rate_per_sm * s,
                    ..base.clone()
                })
                .collect(),
        }
    }

    /// Parse a `--devices` spelling; see the module docs for the forms.
    pub fn parse(s: &str) -> Result<FleetSpec, FleetParseError> {
        let err = || FleetParseError { input: s.into() };
        let trimmed = s.trim();
        if trimmed.is_empty() {
            return Err(err());
        }
        if !trimmed.contains(',') && !trimmed.contains('x') {
            if let Ok(n) = trimmed.parse::<usize>() {
                if n == 0 {
                    return Err(err());
                }
                return Ok(FleetSpec::homogeneous(n));
            }
            // Not an integer: fall through and read it as one speed term.
        }
        let speed = |v: &str| -> Result<f64, FleetParseError> {
            let f: f64 = v.trim().parse().map_err(|_| err())?;
            if f.is_finite() && f > 0.0 {
                Ok(f)
            } else {
                Err(err())
            }
        };
        let mut speeds = Vec::new();
        for term in trimmed.split(',') {
            match term.split_once('x') {
                Some((count, v)) => {
                    let count: usize = count.trim().parse().map_err(|_| err())?;
                    if count == 0 {
                        return Err(err());
                    }
                    let f = speed(v)?;
                    speeds.extend(std::iter::repeat(f).take(count));
                }
                None => speeds.push(speed(term)?),
            }
        }
        Ok(FleetSpec::heterogeneous(&speeds))
    }

    /// Number of devices.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// Whether the fleet has no devices (only constructible by hand —
    /// the parser and constructors guarantee at least one).
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Canonical spelling: the device count when every device is the
    /// baseline, otherwise the comma list of speed factors. Exact for
    /// fleets built by [`FleetSpec::parse`] / [`FleetSpec::homogeneous`]
    /// / [`FleetSpec::heterogeneous`]; fleets of hand-built [`GpuSpec`]s
    /// are named by their compute-roofline ratio to the baseline.
    pub fn name(&self) -> String {
        let base = GpuSpec::gtx580();
        if self.devices.iter().all(|d| *d == base) {
            return self.devices.len().to_string();
        }
        self.devices
            .iter()
            .map(|d| format!("{}", d.peak_compute() / base.peak_compute()))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Check that a recorded trace fits this fleet: a trace recorded on
    /// `D` devices routes into at least `D` (a smaller fleet would see a
    /// different overload regime than the one recorded, silently).
    pub fn validate_trace(&self, trace: &Trace) -> Result<(), FleetMismatchError> {
        if trace.devices > self.devices.len() {
            Err(FleetMismatchError {
                trace_devices: trace.devices,
                fleet_devices: self.devices.len(),
            })
        } else {
            Ok(())
        }
    }

    /// Check a fault plan's device indices against this fleet (the CLI
    /// boundary for [`crate::fleet::simulate_fleet_with_faults`], which
    /// panics on out-of-range devices rather than guessing).
    pub fn validate_fault_plan(
        &self,
        plan: &crate::fault::FaultPlan,
    ) -> Result<(), crate::fault::FaultParseError> {
        plan.validate_for(self.devices.len())
    }
}

/// Error for unknown fleet spellings; `Display` lists the valid forms.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FleetParseError {
    pub input: String,
}

impl fmt::Display for FleetParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "invalid fleet spec `{}` — valid forms: a device count (e.g. `4`), or a comma \
             list of speed factors `<speed>` / `<count>x<speed>` (e.g. `1,1,0.5` or \
             `2x1,2x0.25`); speeds must be finite and > 0",
            self.input
        )
    }
}

impl std::error::Error for FleetParseError {}

/// A recorded trace was replayed onto a smaller fleet than it was
/// recorded for.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FleetMismatchError {
    pub trace_devices: usize,
    pub fleet_devices: usize,
}

impl fmt::Display for FleetMismatchError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "trace was recorded for a {}-device fleet but this fleet has only {} — replay on \
             at least {} devices (`--devices {}`) or re-record the trace for this fleet",
            self.trace_devices, self.fleet_devices, self.trace_devices, self.trace_devices
        )
    }
}

impl std::error::Error for FleetMismatchError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_count_is_homogeneous() {
        let f = FleetSpec::parse("4").unwrap();
        assert_eq!(f.len(), 4);
        assert!(f.devices.iter().all(|d| *d == GpuSpec::gtx580()));
        assert_eq!(f.name(), "4");
        // Canonical names re-parse to the same fleet.
        assert_eq!(FleetSpec::parse(&f.name()).unwrap(), f);
    }

    #[test]
    fn speed_lists_scale_the_compute_roofline() {
        let f = FleetSpec::parse("1,1,0.5").unwrap();
        assert_eq!(f.len(), 3);
        let base = GpuSpec::gtx580();
        assert_eq!(f.devices[0], base);
        assert_eq!(f.devices[2].peak_compute(), base.peak_compute() * 0.5);
        // Memory bandwidth scales with compute through balanced_ratio.
        assert_eq!(f.devices[2].memory_bandwidth(), base.memory_bandwidth() * 0.5);
        assert_eq!(f.name(), "1,1,0.5");
        assert_eq!(FleetSpec::parse(&f.name()).unwrap(), f);
    }

    #[test]
    fn count_x_speed_terms_expand() {
        let f = FleetSpec::parse("2x1,2x0.25").unwrap();
        assert_eq!(f.len(), 4);
        assert_eq!(f.devices[0], f.devices[1]);
        assert_eq!(f.devices[2], f.devices[3]);
        let base = GpuSpec::gtx580();
        assert_eq!(f.devices[3].peak_compute(), base.peak_compute() * 0.25);
    }

    #[test]
    fn bad_spellings_error_and_echo_input() {
        for s in ["", "0", "x", "1,", "1,-2", "1,nan", "0x2", "2x0", "1,inf", "a"] {
            let err = FleetSpec::parse(s).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(&format!("`{s}`")), "{msg}");
            assert!(msg.contains("speed factors"), "{msg}");
        }
    }

    #[test]
    fn trace_device_count_is_validated() {
        let mut trace = Trace::poisson("uniform", 4, 100.0, 1);
        trace.devices = 4;
        let small = FleetSpec::homogeneous(2);
        let err = small.validate_trace(&trace).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("4-device"), "{msg}");
        assert!(msg.contains("only 2"), "{msg}");
        assert!(msg.contains("--devices 4"), "{msg}");
        // An equal or larger fleet replays fine.
        assert!(FleetSpec::homogeneous(4).validate_trace(&trace).is_ok());
        assert!(FleetSpec::homogeneous(8).validate_trace(&trace).is_ok());
    }

    #[test]
    fn homogeneous_clamps_to_one_device() {
        assert_eq!(FleetSpec::homogeneous(0).len(), 1);
        assert_eq!(FleetSpec::heterogeneous(&[]).len(), 1);
    }
}
