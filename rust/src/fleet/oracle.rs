//! Clairvoyant fleet lower bound: how fast could *any* router and *any*
//! launch order have finished the whole pool, ignoring arrival times?
//!
//! Three admissible bounds under the fluid model, combined by max:
//!
//! * **bottleneck kernel** — some kernel must run somewhere, so the pool
//!   cannot finish before the largest per-kernel bound on its *best*
//!   device;
//! * **aggregate compute** — total work over the fleet's summed compute
//!   roofline;
//! * **aggregate bandwidth** — total memory traffic over the fleet's
//!   summed bandwidth.
//!
//! No schedule — clairvoyant, preemptive, perfectly balanced — beats
//! this, so `fleet span / bound` reads as the price of the arrival
//! process, the routing policy and the windowing combined. The bound is
//! intentionally machine-independent (no search, no backend): it prices
//! devices exactly the way [`crate::gpu::GpuSpec::makespan_lower_bound`]
//! prices one device. One caveat: it prices the *nominal* profiles, so
//! a backend with per-block jitter `j` (the simulator's default is 0.1)
//! can undercut it by at most a factor `1 - j` — compare with that
//! slack, or run against `GpuSpec::deterministic()` devices.

use super::spec::FleetSpec;
use crate::gpu::KernelProfile;

/// Lower bound (virtual ms) on serving `kernels` on `fleet` with every
/// kernel available at t = 0. Returns 0 for an empty pool or fleet.
pub fn fleet_lower_bound(fleet: &FleetSpec, kernels: &[KernelProfile]) -> f64 {
    if kernels.is_empty() || fleet.devices.is_empty() {
        return 0.0;
    }
    let bottleneck = kernels
        .iter()
        .map(|k| {
            fleet
                .devices
                .iter()
                .map(|g| g.makespan_lower_bound(k.total_work(), k.total_mem()))
                .fold(f64::INFINITY, f64::min)
        })
        .fold(0.0, f64::max);
    let total_work: f64 = kernels.iter().map(|k| k.total_work()).sum();
    let total_mem: f64 = kernels.iter().map(|k| k.total_mem()).sum();
    let peak: f64 = fleet.devices.iter().map(|g| g.peak_compute()).sum();
    let bandwidth: f64 = fleet.devices.iter().map(|g| g.memory_bandwidth()).sum();
    let compute_bound = if peak > 0.0 { total_work / peak } else { 0.0 };
    let memory_bound = if bandwidth > 0.0 { total_mem / bandwidth } else { 0.0 };
    bottleneck.max(compute_bound).max(memory_bound)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuSpec;
    use crate::workloads::scenario_by_id;

    #[test]
    fn bound_is_positive_and_tightens_with_more_devices() {
        let gpu = GpuSpec::gtx580();
        let pool = scenario_by_id("mixed").unwrap().workload(&gpu, 24, 5);
        let one = fleet_lower_bound(&FleetSpec::homogeneous(1), &pool);
        let four = fleet_lower_bound(&FleetSpec::homogeneous(4), &pool);
        assert!(one > 0.0);
        assert!(four > 0.0);
        // More devices can only lower (or bottleneck-pin) the bound.
        assert!(four <= one + 1e-12, "four {four} !<= one {one}");
    }

    #[test]
    fn single_device_bound_matches_gpu_spec_bound() {
        let gpu = GpuSpec::gtx580();
        let pool = scenario_by_id("uniform").unwrap().workload(&gpu, 8, 3);
        let total_work: f64 = pool.iter().map(|k| k.total_work()).sum();
        let total_mem: f64 = pool.iter().map(|k| k.total_mem()).sum();
        let direct = gpu.makespan_lower_bound(total_work, total_mem);
        let viafleet = fleet_lower_bound(&FleetSpec::homogeneous(1), &pool);
        // On one device the aggregate bounds coincide with the GpuSpec
        // bound; the bottleneck-kernel term can only raise it.
        assert!(viafleet >= direct - 1e-12, "{viafleet} < {direct}");
    }

    #[test]
    fn slow_devices_weaken_the_bound_less_than_removing_them() {
        let gpu = GpuSpec::gtx580();
        let pool = scenario_by_id("skewed").unwrap().workload(&gpu, 16, 7);
        let fast_pair = fleet_lower_bound(&FleetSpec::parse("1,1").unwrap(), &pool);
        let lopsided = fleet_lower_bound(&FleetSpec::parse("1,0.25").unwrap(), &pool);
        let solo = fleet_lower_bound(&FleetSpec::homogeneous(1), &pool);
        assert!(fast_pair <= lopsided + 1e-12);
        assert!(lopsided <= solo + 1e-12);
    }

    #[test]
    fn empty_inputs_bound_to_zero() {
        assert_eq!(fleet_lower_bound(&FleetSpec::homogeneous(2), &[]), 0.0);
    }
}
