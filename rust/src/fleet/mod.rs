//! Fleet dispatch: routing arrivals across many reordering devices.
//!
//! The online layer ([`crate::online`]) answers *when* to close one
//! device's reorder window and *what order* to launch it in. This layer
//! sits in front of it and answers *which device* — the shared-cloud
//! setting where a stream of kernels fans out over a fleet of
//! (possibly heterogeneous) GPUs, each running its own window + reorder
//! loop:
//!
//! ```text
//!            ┌────────────┐     ┌─ window ─ reorder ─ device 0
//!  arrivals ─┤ RoutePolicy ├────┼─ window ─ reorder ─ device 1
//!            └────────────┘     └─ window ─ reorder ─ device 2 …
//! ```
//!
//! * [`RoutePolicy`] + [`parse_route_policy`] — the routing registry
//!   (`roundrobin`, `jsq`, `lrw`, `p2c:<seed>`, `affinity`, and the
//!   [`Circuit`] breaker wrapper `circuit:<inner>`), shared by the
//!   virtual-clock engine here and the live thread coordinator
//!   ([`crate::coordinator::CoordinatorBuilder::route_policy`]).
//! * [`FleetSpec`] — the devices, with heterogeneity as per-device
//!   speed factors (`--devices 1,1,0.5`).
//! * [`FleetSimConfig`] — the preferred builder form of the simulation
//!   entry point: owns every piece, defaults the common ones, and runs
//!   the same engine bit-identically. The positional
//!   [`simulate_fleet_with_admission`] stays as the thin underlying
//!   call.
//! * [`simulate_fleet`] — the deterministic discrete-event loop over D
//!   devices (fault < routing decision < completion < batch start <
//!   arrival < retry < recheck at equal times); bit-identical replay
//!   per configuration. [`simulate_fleet_with_faults`] is the same loop
//!   with a [`crate::fault::FaultConfig`] threaded through it: crashes
//!   orphan a device's backlog back to the router, [`Health`] lets the
//!   load-aware policies route around dead devices, failed launches
//!   retry with seeded backoff and are shed past the cap — never lost.
//!   [`simulate_fleet_with_admission`] puts an
//!   [`crate::admission::AdmissionPolicy`] gate in front of the router:
//!   under overload, arrivals the policy rejects become first-class
//!   [`ShedRecord`]s with a [`ShedCause::Rejected`] cause — the last
//!   rung of the degradation ladder (reorder → FIFO → shed) — and
//!   `admission=none` is a strict bit-identical no-op.
//!   [`simulate_fleet_traced`] is the full engine with a
//!   [`crate::obs::TraceSink`] observing every decision as a typed
//!   [`crate::obs::TraceEvent`] stream; every other entry point
//!   delegates to it with the no-op sink.
//! * [`FleetReport`] — per-kernel timestamps with device provenance,
//!   per-device utilization/imbalance, fleet percentile rollups, and
//!   the fault ledger ([`ShedRecord`], reroute/degradation counters).
//! * [`fleet_lower_bound`] — the clairvoyant fleet oracle the span is
//!   priced against.
//!
//! `benches/fleet_routing.rs` replays identical traces through every
//! route policy on homogeneous and heterogeneous fleets and gates
//! routed p99 sojourn against the `roundrobin` baseline in CI;
//! `benches/fault_tolerance.rs` gates the recovery story (health-aware
//! rerouting beats health-blind routing under a 1-of-4 crash plan).

pub mod config;
pub mod engine;
pub mod oracle;
pub mod report;
pub mod route;
pub mod spec;

pub use config::FleetSimConfig;
pub use engine::{
    simulate_fleet, simulate_fleet_traced, simulate_fleet_with_admission,
    simulate_fleet_with_faults,
};
pub use oracle::fleet_lower_bound;
pub use report::{
    p99_speedup, FleetBatchRecord, FleetKernelRecord, FleetReport, ShedCause, ShedRecord,
};
pub use route::{
    parse_route_policy, route_policy_help_table, Affinity, Circuit, DeviceLoad, FleetView, Health,
    Jsq, Lrw, P2c, RoundRobin, RouteParseError, RoutePolicy,
};
pub use spec::{FleetMismatchError, FleetParseError, FleetSpec};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{ExecutionBackend, SimulatorBackend};
    use crate::gpu::GpuSpec;
    use crate::online::{parse_window_policy, OnlineOpts, OnlineReorderer, ReplaySource, Trace};

    /// The module-level happy path: a skewed stream over a lopsided
    /// fleet, routed by jsq, reordered per device.
    #[test]
    fn end_to_end_fleet_run() {
        let fleet = FleetSpec::parse("1,0.5").unwrap();
        let gpu = GpuSpec::gtx580();
        let trace = Trace::poisson("skewed", 24, 500.0, 13);
        let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
        let make_backend: Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> =
            Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>);
        let r = simulate_fleet(
            &fleet,
            source,
            parse_route_policy("jsq").unwrap(),
            &|| parse_window_policy("linger:6:30").unwrap(),
            &OnlineReorderer::search("local:0", 200).unwrap(),
            make_backend.as_ref(),
            &OnlineOpts::default(),
        );
        assert_eq!(r.kernels.len(), 24);
        assert_eq!(r.route, "jsq");
        assert_eq!(r.window, "linger:6:30");
        assert_eq!(r.n_devices(), 2);
        let pool = trace.pool(&gpu).unwrap();
        let lb = fleet_lower_bound(&fleet, &pool);
        assert!(lb > 0.0);
        // The oracle prices nominal profiles; the simulator's ±10%
        // per-block jitter can undercut it by at most that factor.
        assert!(r.span_ms >= lb * 0.9 - 1e-9, "span {} beat the oracle {}", r.span_ms, lb);
    }
}
