//! What a fleet run produced: per-kernel timestamps with device
//! provenance, per-device rollups (utilization, imbalance) and
//! fleet-wide latency distributions, reusing the single-device
//! [`LatencyStats`] machinery.

use crate::metrics::mean;
use crate::online::report::LatencyStats;
// Shed reporting is unified with the online engine: one `ShedCause`
// enum (Display + stable CSV spelling) serves both paths, so
// `--record` traces round-trip shed/rejected rows identically.
pub use crate::online::report::{ShedCause, ShedRecord};

/// One kernel's complete fleet timeline: arrive → route → window close
/// → batch start → finish, all in virtual ms, plus where it ran.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetKernelRecord {
    pub id: u64,
    /// Device the router placed this kernel on.
    pub device: usize,
    pub arrival_ms: f64,
    /// When the routing decision placed it (>= arrival; equal unless the
    /// router was backlogged at the same instant).
    pub route_ms: f64,
    pub close_ms: f64,
    pub start_ms: f64,
    pub finish_ms: f64,
    /// Fleet-wide batch id (close order across all devices).
    pub batch: u64,
    /// Launch position within its batch after reordering.
    pub position: usize,
}

/// One closed window's service record on its device.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetBatchRecord {
    pub id: u64,
    pub device: usize,
    pub n: usize,
    pub close_ms: f64,
    pub ready_ms: f64,
    pub start_ms: f64,
    pub makespan_ms: f64,
    pub evals: u64,
    pub order: Vec<usize>,
}

/// Everything [`crate::fleet::simulate_fleet`] measured, kernels sorted
/// by id.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub source: String,
    pub route: String,
    pub window: String,
    pub reorderer: String,
    pub backend: String,
    /// Admission-policy spelling that gated arrivals (`"none"` when the
    /// run was ungated).
    pub admission: String,
    pub kernels: Vec<FleetKernelRecord>,
    pub batches: Vec<FleetBatchRecord>,
    /// Latest finish time across the fleet (0 for an empty run).
    pub span_ms: f64,
    /// Total busy (executing) time per device, indexed by device id.
    pub device_busy_ms: Vec<f64>,
    pub decision_evals: u64,
    pub n_unsimulable: usize,
    /// Window decisions served in FIFO arrival order because the device
    /// was degraded or the search's FIFO guard rejected its order.
    pub n_degraded_decisions: u64,
    /// Kernels handed back to the router by a device crash.
    pub n_rerouted: u64,
    /// Launch attempts that failed under a `launchfail` process.
    pub n_launch_failures: u64,
    /// Fault events the plan injected (crash/recover/slowdown).
    pub n_fault_events: usize,
    /// Kernels shed with a cause (sorted by id) — faults *or* admission
    /// rejections. Empty without faults under `admission=none`.
    pub shed: Vec<ShedRecord>,
}

impl FleetReport {
    /// Number of devices in the fleet.
    pub fn n_devices(&self) -> usize {
        self.device_busy_ms.len()
    }

    /// Per-kernel sojourn (arrival → finish), in kernel-id order.
    pub fn sojourns_ms(&self) -> Vec<f64> {
        self.kernels.iter().map(|k| k.finish_ms - k.arrival_ms).collect()
    }

    /// Per-kernel queueing delay (arrival → batch start).
    pub fn queue_waits_ms(&self) -> Vec<f64> {
        self.kernels.iter().map(|k| k.start_ms - k.arrival_ms).collect()
    }

    /// Per-kernel service time (batch start → finish).
    pub fn services_ms(&self) -> Vec<f64> {
        self.kernels.iter().map(|k| k.finish_ms - k.start_ms).collect()
    }

    /// Fleet-wide sojourn distribution.
    pub fn sojourn_stats(&self) -> LatencyStats {
        LatencyStats::from_samples(&self.sojourns_ms())
    }

    /// Fleet-wide queueing-delay distribution.
    pub fn queue_stats(&self) -> LatencyStats {
        LatencyStats::from_samples(&self.queue_waits_ms())
    }

    /// Sojourn distribution of the kernels served by one device.
    pub fn device_sojourn_stats(&self, device: usize) -> LatencyStats {
        let samples: Vec<f64> = self
            .kernels
            .iter()
            .filter(|k| k.device == device)
            .map(|k| k.finish_ms - k.arrival_ms)
            .collect();
        LatencyStats::from_samples(&samples)
    }

    /// Kernels served per device, indexed by device id.
    pub fn device_kernel_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_devices()];
        for k in &self.kernels {
            if k.device < counts.len() {
                counts[k.device] += 1;
            }
        }
        counts
    }

    /// Busy fraction per device over the fleet span.
    pub fn utilizations(&self) -> Vec<f64> {
        self.device_busy_ms
            .iter()
            .map(|&busy| {
                if self.span_ms > 0.0 {
                    (busy / self.span_ms).min(1.0)
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// Load imbalance: the busiest device's busy time over the fleet
    /// mean (1.0 = perfectly balanced; an idle fleet reports 1.0).
    pub fn imbalance(&self) -> f64 {
        if self.device_busy_ms.is_empty() {
            return 1.0;
        }
        let max = self.device_busy_ms.iter().copied().fold(0.0, f64::max);
        let mean_busy = mean(&self.device_busy_ms);
        if mean_busy > 0.0 {
            max / mean_busy
        } else {
            1.0
        }
    }

    /// Served kernels per (virtual) second of fleet span.
    pub fn throughput_per_s(&self) -> f64 {
        if self.span_ms > 0.0 {
            self.kernels.len() as f64 / (self.span_ms / 1e3)
        } else {
            0.0
        }
    }

    /// Mean kernels per closed window across the fleet.
    pub fn mean_window(&self) -> f64 {
        if self.batches.is_empty() {
            return 0.0;
        }
        self.kernels.len() as f64 / self.batches.len() as f64
    }

    /// Kernels shed (unserved, with a cause).
    pub fn n_shed(&self) -> usize {
        self.shed.len()
    }

    /// Fraction of arrivals that completed (1.0 without faults).
    pub fn completion_rate(&self) -> f64 {
        let total = self.kernels.len() + self.shed.len();
        if total > 0 {
            self.kernels.len() as f64 / total as f64
        } else {
            1.0
        }
    }

    /// Multi-line human-readable rollup. Fault accounting appears as an
    /// extra line only when the run actually injected or shed anything,
    /// so fault-free summaries are unchanged.
    pub fn summary(&self) -> String {
        let utils = self
            .utilizations()
            .iter()
            .map(|u| format!("{u:.2}"))
            .collect::<Vec<_>>()
            .join(" ");
        let mut s = format!(
            "fleet    : {} devices, route {}, window {}, reorder {}, backend {}\n\
             source   : {}\n\
             sojourn  : {}\n\
             queue    : {}\n\
             span     : {:.3} ms, throughput {:.1} kernels/s, mean window {:.2}\n\
             devices  : util [{}], imbalance {:.3}, kernels {:?}\n\
             decisions: {} evals, {} unsimulable",
            self.n_devices(),
            self.route,
            self.window,
            self.reorderer,
            self.backend,
            self.source,
            self.sojourn_stats().line(),
            self.queue_stats().line(),
            self.span_ms,
            self.throughput_per_s(),
            self.mean_window(),
            utils,
            self.imbalance(),
            self.device_kernel_counts(),
            self.decision_evals,
            self.n_unsimulable,
        );
        if self.n_fault_events > 0
            || !self.shed.is_empty()
            || self.n_launch_failures > 0
            || self.n_degraded_decisions > 0
        {
            s.push_str(&format!(
                "\nfaults   : {} events, {} rerouted, {} launch failures, {} shed, \
                 {} degraded decisions, completion rate {:.4}",
                self.n_fault_events,
                self.n_rerouted,
                self.n_launch_failures,
                self.shed.len(),
                self.n_degraded_decisions,
                self.completion_rate(),
            ));
        }
        s
    }
}

/// Fleet p99-sojourn speedup of `candidate` over `baseline` (the
/// routed-vs-roundrobin headline number; > 1 means `candidate` is
/// better, 0 when either report is degenerate).
pub fn p99_speedup(baseline: &FleetReport, candidate: &FleetReport) -> f64 {
    let b = baseline.sojourn_stats().p99_ms;
    let c = candidate.sojourn_stats().p99_ms;
    if b > 0.0 && c > 0.0 {
        b / c
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kernel(id: u64, device: usize, arrival: f64, finish: f64) -> FleetKernelRecord {
        FleetKernelRecord {
            id,
            device,
            arrival_ms: arrival,
            route_ms: arrival,
            close_ms: arrival,
            start_ms: arrival,
            finish_ms: finish,
            batch: id,
            position: 0,
        }
    }

    fn report(kernels: Vec<FleetKernelRecord>, busy: Vec<f64>, span: f64) -> FleetReport {
        FleetReport {
            source: "test".into(),
            route: "jsq".into(),
            window: "fixed:1".into(),
            reorderer: "fifo".into(),
            backend: "sim".into(),
            admission: "none".into(),
            kernels,
            batches: Vec::new(),
            span_ms: span,
            device_busy_ms: busy,
            decision_evals: 0,
            n_unsimulable: 0,
            n_degraded_decisions: 0,
            n_rerouted: 0,
            n_launch_failures: 0,
            n_fault_events: 0,
            shed: Vec::new(),
        }
    }

    #[test]
    fn rollups_split_by_device() {
        let r = report(
            vec![
                kernel(0, 0, 0.0, 10.0),
                kernel(1, 1, 0.0, 20.0),
                kernel(2, 0, 5.0, 15.0),
            ],
            vec![20.0, 20.0],
            20.0,
        );
        assert_eq!(r.device_kernel_counts(), vec![2, 1]);
        assert_eq!(r.device_sojourn_stats(0).n, 2);
        assert_eq!(r.device_sojourn_stats(1).n, 1);
        assert_eq!(r.sojourn_stats().n, 3);
        assert_eq!(r.utilizations(), vec![1.0, 1.0]);
        assert!((r.imbalance() - 1.0).abs() < 1e-12);
        let s = r.summary();
        assert!(s.contains("2 devices"), "{s}");
        assert!(s.contains("route jsq"), "{s}");
    }

    #[test]
    fn imbalance_reads_skew() {
        let r = report(vec![kernel(0, 0, 0.0, 30.0)], vec![30.0, 0.0, 0.0], 30.0);
        // One device does all the work of three: max/mean = 3.
        assert!((r.imbalance() - 3.0).abs() < 1e-12);
        let idle = report(Vec::new(), vec![0.0, 0.0], 0.0);
        assert_eq!(idle.imbalance(), 1.0);
        assert_eq!(idle.throughput_per_s(), 0.0);
        assert_eq!(idle.utilizations(), vec![0.0, 0.0]);
    }

    #[test]
    fn fault_accounting_is_silent_without_faults_and_loud_with_them() {
        let clean = report(vec![kernel(0, 0, 0.0, 10.0)], vec![10.0], 10.0);
        assert!(!clean.summary().contains("faults"), "{}", clean.summary());
        assert_eq!(clean.completion_rate(), 1.0);
        assert_eq!(clean.n_shed(), 0);

        let mut faulty = report(vec![kernel(0, 0, 0.0, 10.0)], vec![10.0], 10.0);
        faulty.n_fault_events = 1;
        faulty.n_rerouted = 2;
        faulty.shed.push(ShedRecord {
            id: 9,
            arrival_ms: 3.0,
            attempts: 4,
            cause: ShedCause::RetryCap { attempts: 4 },
        });
        let s = faulty.summary();
        assert!(s.contains("faults"), "{s}");
        assert!(s.contains("1 shed"), "{s}");
        assert!(s.contains("2 rerouted"), "{s}");
        assert!((faulty.completion_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn p99_speedup_compares_reports() {
        let slow = report(vec![kernel(0, 0, 0.0, 40.0), kernel(1, 0, 0.0, 40.0)], vec![40.0], 40.0);
        let fast = report(vec![kernel(0, 0, 0.0, 10.0), kernel(1, 0, 0.0, 10.0)], vec![10.0], 10.0);
        assert!((p99_speedup(&slow, &fast) - 4.0).abs() < 1e-12);
        assert!((p99_speedup(&fast, &slow) - 0.25).abs() < 1e-12);
        let empty = report(Vec::new(), vec![0.0], 0.0);
        assert_eq!(p99_speedup(&empty, &fast), 0.0);
    }
}
