//! [`RoutePolicy`] — *which device* an arriving kernel goes to.
//!
//! A fleet run has two online decisions per kernel: the route (here) and
//! the order within its device's reorder window
//! ([`crate::online::OnlineReorderer`]). The policies in this registry
//! cover the classic load-balancing spectrum plus two that exploit what
//! this crate already knows about kernels:
//!
//! | spelling | behavior |
//! |---|---|
//! | `roundrobin` | blind rotation (the baseline every bench gate compares against) |
//! | `jsq` | join-shortest-queue by outstanding kernel count |
//! | `lrw` | least residual work: queue *time*, priced via the backend's admissible [`crate::exec::PreparedWorkload::suffix_lower_bound`] over each device's backlog |
//! | `p2c:<seed>` | power-of-two-choices: sample two devices, join the shorter queue |
//! | `affinity` | class affinity: kernels that are model-identical (the predicate behind [`crate::gpu::equivalence_classes`]) co-locate so symmetry collapse keeps paying in the per-device search |
//!
//! `jsq` counts kernels; on a heterogeneous fleet (or heavy-tailed kernel
//! work) queue *length* mispredicts queue *work*, which is where `lrw`'s
//! pricing earns its extra cost. Like the window policies, every route
//! policy must be a **deterministic** function of the state it is shown
//! (plus, for `p2c`, its own seeded PRNG stream) — the fleet engine's
//! bit-identical-replay guarantee (`tests/fleet_determinism.rs`) rests
//! on it.

use crate::gpu::KernelProfile;
use crate::util::SplitMix64;
use std::fmt;

/// Snapshot of one device at a routing instant.
#[derive(Debug, Clone, Copy)]
pub struct DeviceLoad {
    /// Device index in the fleet.
    pub device: usize,
    /// Kernels routed to this device and not yet completed (open window
    /// + queued batches + executing batch).
    pub outstanding: usize,
    /// Kernels in the device's open reorder window.
    pub n_pending: usize,
    /// Windows closed but not yet started on the device.
    pub queued_batches: usize,
    /// Earliest time the device frees (`<= now_ms` means idle). The
    /// thread coordinator cannot predict this and passes `now_ms` for an
    /// idle device, `+inf` for a busy one.
    pub free_at_ms: f64,
    /// Device compute roofline (work units per ms) — how heterogeneous
    /// fleets expose their speed differences to the policies.
    pub peak_compute: f64,
    /// Admissible lower bound (ms) on the device's residual work:
    /// executing-batch remainder plus a
    /// [`crate::exec::PreparedWorkload::suffix_lower_bound`] over the
    /// backlog. `NaN` when the caller did not price it (only policies
    /// with [`RoutePolicy::needs_pricing`] get finite values; `lrw`
    /// falls back to `outstanding` on `NaN`).
    pub backlog_lb_ms: f64,
}

/// Everything a [`RoutePolicy`] sees when it places one kernel.
#[derive(Debug, Clone, Copy)]
pub struct FleetView<'a> {
    /// Current virtual time (or clock-derived time in the coordinator).
    pub now_ms: f64,
    /// One entry per device, indexed by device id.
    pub devices: &'a [DeviceLoad],
}

/// Decides which device an arriving kernel joins.
///
/// Contract: `route` returns a device index (the engine clamps it into
/// range defensively); equal-score ties must break toward the lowest
/// index so runs replay bit-identically.
pub trait RoutePolicy: Send {
    /// Registry spelling of this policy instance (e.g. `"p2c:7"`).
    fn name(&self) -> String;

    /// Whether [`DeviceLoad::backlog_lb_ms`] must be priced before
    /// `route` is called. Pricing costs a backend `prepare` per device
    /// per decision, so only `lrw` asks for it.
    fn needs_pricing(&self) -> bool {
        false
    }

    /// Pick the device for `kernel` given the fleet snapshot.
    fn route(&mut self, kernel: &KernelProfile, fleet: &FleetView<'_>) -> usize;
}

/// First device minimizing `score` (strict `<`, so ties break toward
/// the lowest index — the determinism contract).
fn argmin_by(devices: &[DeviceLoad], score: impl Fn(&DeviceLoad) -> f64) -> usize {
    let mut best = 0usize;
    let mut best_score = f64::INFINITY;
    for d in devices {
        let s = score(d);
        if s < best_score {
            best_score = s;
            best = d.device;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// Implementations
// ---------------------------------------------------------------------------

/// `roundrobin` — blind rotation, load- and kernel-oblivious. The
/// baseline the fleet bench gates every other policy against, and the
/// coordinator's historical dispatch rule.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> Self {
        RoundRobin::default()
    }
}

impl RoutePolicy for RoundRobin {
    fn name(&self) -> String {
        "roundrobin".to_string()
    }

    fn route(&mut self, _kernel: &KernelProfile, fleet: &FleetView<'_>) -> usize {
        let d = self.next % fleet.devices.len().max(1);
        self.next = self.next.wrapping_add(1);
        d
    }
}

/// `jsq` — join the device with the fewest outstanding kernels. Optimal
/// among length-based rules on homogeneous fleets; blind to device speed
/// and kernel size.
#[derive(Debug, Clone, Copy, Default)]
pub struct Jsq;

impl Jsq {
    pub fn new() -> Self {
        Jsq
    }
}

impl RoutePolicy for Jsq {
    fn name(&self) -> String {
        "jsq".to_string()
    }

    fn route(&mut self, _kernel: &KernelProfile, fleet: &FleetView<'_>) -> usize {
        argmin_by(fleet.devices, |d| d.outstanding as f64)
    }
}

/// `lrw` — least residual work. Scores each device by its priced
/// backlog lower bound plus the arriving kernel's own compute-roofline
/// time on that device, so a slow or work-laden device loses to a fast
/// or empty one even at equal queue length. Falls back to `jsq` scoring
/// where the caller cannot price backlogs (`backlog_lb_ms` NaN — the
/// live coordinator path).
#[derive(Debug, Clone, Copy, Default)]
pub struct Lrw;

impl Lrw {
    pub fn new() -> Self {
        Lrw
    }
}

impl RoutePolicy for Lrw {
    fn name(&self) -> String {
        "lrw".to_string()
    }

    fn needs_pricing(&self) -> bool {
        true
    }

    fn route(&mut self, kernel: &KernelProfile, fleet: &FleetView<'_>) -> usize {
        argmin_by(fleet.devices, |d| {
            if d.backlog_lb_ms.is_finite() {
                let own = if d.peak_compute > 0.0 {
                    kernel.total_work() / d.peak_compute
                } else {
                    0.0
                };
                d.backlog_lb_ms + own
            } else {
                d.outstanding as f64
            }
        })
    }
}

/// `p2c:<seed>` — power-of-two-choices: sample two distinct devices from
/// a seeded PRNG stream, join the one with fewer outstanding kernels.
/// Near-jsq balance at O(1) state inspection; deterministic per seed.
#[derive(Debug, Clone)]
pub struct P2c {
    seed: u64,
    rng: SplitMix64,
}

/// Domain-separation constant for the `p2c` PRNG stream (the arrival
/// constants live in `online::arrivals`).
const P2C_SEED_XOR: u64 = 0xF1EE_7007;

impl P2c {
    pub fn new(seed: u64) -> Self {
        P2c {
            seed,
            rng: SplitMix64::new(seed ^ P2C_SEED_XOR),
        }
    }
}

impl RoutePolicy for P2c {
    fn name(&self) -> String {
        format!("p2c:{}", self.seed)
    }

    fn route(&mut self, _kernel: &KernelProfile, fleet: &FleetView<'_>) -> usize {
        let n = fleet.devices.len();
        if n <= 1 {
            return 0;
        }
        let a = (self.rng.next_u64() % n as u64) as usize;
        let mut b = (self.rng.next_u64() % (n as u64 - 1)) as usize;
        if b >= a {
            b += 1; // distinct second sample
        }
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        // `<=` keeps the lower index on ties (determinism contract).
        if fleet.devices[lo].outstanding <= fleet.devices[hi].outstanding {
            lo
        } else {
            hi
        }
    }
}

/// Outstanding-kernel slack beyond the fleet minimum that makes
/// [`Affinity`] re-home a class instead of keeping it co-located: small
/// enough that a hot class cannot wedge one device, large enough that a
/// class is not ping-ponged by ordinary queue noise.
const REBALANCE_SLACK: usize = 8;

/// `affinity` — class affinity. Model-identical kernels (the same
/// predicate [`crate::gpu::equivalence_classes`] collapses on) are
/// routed to the same home device, so per-device reorder windows fill
/// with repeated kernels and the search layer's identical-kernel
/// symmetry collapse keeps paying. New classes are homed on the
/// least-loaded device; a home that falls more than [`REBALANCE_SLACK`]
/// outstanding kernels behind the fleet minimum is re-homed so affinity
/// never beats load balance by more than a bounded margin.
#[derive(Debug, Clone, Default)]
pub struct Affinity {
    /// One `(representative, home device)` entry per class seen.
    classes: Vec<(KernelProfile, usize)>,
}

impl Affinity {
    pub fn new() -> Self {
        Affinity::default()
    }
}

impl RoutePolicy for Affinity {
    fn name(&self) -> String {
        "affinity".to_string()
    }

    fn route(&mut self, kernel: &KernelProfile, fleet: &FleetView<'_>) -> usize {
        let n = fleet.devices.len().max(1);
        let min_out = fleet.devices.iter().map(|d| d.outstanding).min().unwrap_or(0);
        if let Some(slot) = self
            .classes
            .iter_mut()
            .find(|(rep, _)| rep.model_identical(kernel))
        {
            let home = slot.1.min(n - 1);
            if fleet.devices[home].outstanding > min_out + REBALANCE_SLACK {
                slot.1 = argmin_by(fleet.devices, |d| d.outstanding as f64);
                return slot.1;
            }
            slot.1 = home;
            return home;
        }
        let home = argmin_by(fleet.devices, |d| d.outstanding as f64);
        self.classes.push((kernel.clone(), home));
        home
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Error for unknown route-policy spellings; `Display` lists the valid
/// forms.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RouteParseError {
    pub input: String,
}

impl fmt::Display for RouteParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown route policy `{}` — valid policies: roundrobin, jsq, lrw, p2c:<seed>, \
             affinity",
            self.input
        )
    }
}

impl std::error::Error for RouteParseError {}

/// Parse a route-policy spelling (`"roundrobin"`, `"jsq"`, `"lrw"`,
/// `"p2c:7"`, `"affinity"`; `"rr"` is accepted as an alias) into a
/// trait object.
///
/// ```
/// let p = kreorder::fleet::parse_route_policy("p2c:7").unwrap();
/// assert_eq!(p.name(), "p2c:7");
/// assert!(kreorder::fleet::parse_route_policy("nope").is_err());
/// ```
pub fn parse_route_policy(s: &str) -> Result<Box<dyn RoutePolicy>, RouteParseError> {
    let lower = s.to_ascii_lowercase();
    let err = || RouteParseError { input: s.into() };
    let mut parts = lower.split(':');
    let head = parts.next().unwrap_or("");
    let policy: Box<dyn RoutePolicy> = match head {
        "roundrobin" | "rr" => Box::new(RoundRobin::new()),
        "jsq" => Box::new(Jsq::new()),
        "lrw" => Box::new(Lrw::new()),
        "p2c" => {
            let seed = parts
                .next()
                .ok_or_else(err)?
                .parse::<u64>()
                .map_err(|_| err())?;
            Box::new(P2c::new(seed))
        }
        "affinity" => Box::new(Affinity::new()),
        _ => return Err(err()),
    };
    if parts.next().is_some() {
        return Err(err());
    }
    Ok(policy)
}

/// Human-readable table of the route-policy spellings (one per line).
pub fn route_policy_help_table() -> String {
    let rows = [
        ("roundrobin", "blind rotation across devices (the gate baseline)"),
        ("jsq", "join-shortest-queue by outstanding kernel count"),
        (
            "lrw",
            "least residual work, priced by the backend's admissible suffix lower bound",
        ),
        ("p2c:<seed>", "power-of-two-choices: sample two devices, join the shorter"),
        (
            "affinity",
            "co-locate model-identical kernels so symmetry collapse keeps paying",
        ),
    ];
    let mut out = String::new();
    for (name, desc) in rows {
        out.push_str(&format!("  {name:<20} {desc}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuSpec;
    use crate::workloads::synthetic_workload;

    fn load(device: usize, outstanding: usize, backlog: f64) -> DeviceLoad {
        DeviceLoad {
            device,
            outstanding,
            n_pending: 0,
            queued_batches: 0,
            free_at_ms: 0.0,
            peak_compute: GpuSpec::gtx580().peak_compute(),
            backlog_lb_ms: backlog,
        }
    }

    fn kernel() -> KernelProfile {
        synthetic_workload(&GpuSpec::gtx580(), 1, 5)[0].clone()
    }

    #[test]
    fn roundrobin_rotates_regardless_of_load() {
        let loads = [load(0, 9, f64::NAN), load(1, 0, f64::NAN), load(2, 5, f64::NAN)];
        let view = FleetView { now_ms: 0.0, devices: &loads };
        let mut p = RoundRobin::new();
        let k = kernel();
        let picks: Vec<usize> = (0..6).map(|_| p.route(&k, &view)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn jsq_joins_shortest_with_lowest_index_ties() {
        let loads = [load(0, 3, f64::NAN), load(1, 1, f64::NAN), load(2, 1, f64::NAN)];
        let view = FleetView { now_ms: 0.0, devices: &loads };
        assert_eq!(Jsq::new().route(&kernel(), &view), 1);
    }

    #[test]
    fn lrw_prefers_less_residual_work_over_shorter_queue() {
        // Device 1 has fewer kernels but a much larger priced backlog
        // (heavy kernels): lrw must disagree with jsq here.
        let loads = [load(0, 4, 10.0), load(1, 1, 500.0)];
        let view = FleetView { now_ms: 0.0, devices: &loads };
        assert_eq!(Jsq::new().route(&kernel(), &view), 1);
        assert_eq!(Lrw::new().route(&kernel(), &view), 0);
        assert!(Lrw::new().needs_pricing());
    }

    #[test]
    fn lrw_falls_back_to_queue_length_without_pricing() {
        let loads = [load(0, 4, f64::NAN), load(1, 1, f64::NAN)];
        let view = FleetView { now_ms: 0.0, devices: &loads };
        assert_eq!(Lrw::new().route(&kernel(), &view), 1);
    }

    #[test]
    fn p2c_is_deterministic_per_seed_and_avoids_the_longer_queue() {
        let loads = [load(0, 0, f64::NAN), load(1, 100, f64::NAN), load(2, 0, f64::NAN)];
        let view = FleetView { now_ms: 0.0, devices: &loads };
        let k = kernel();
        let picks = |seed| {
            let mut p = P2c::new(seed);
            (0..32).map(|_| p.route(&k, &view)).collect::<Vec<_>>()
        };
        assert_eq!(picks(7), picks(7), "same seed must replay identically");
        assert_ne!(picks(7), picks(8), "different seeds should diverge");
        // Device 1 is only ever chosen when both samples land on it —
        // impossible since the two samples are distinct.
        assert!(picks(7).iter().all(|&d| d != 1));
    }

    #[test]
    fn affinity_colocates_identical_kernels_until_rebalance() {
        let gpu = GpuSpec::gtx580();
        let pool = synthetic_workload(&gpu, 2, 5);
        let mut p = Affinity::new();
        let balanced = [load(0, 2, f64::NAN), load(1, 0, f64::NAN)];
        let view = FleetView { now_ms: 0.0, devices: &balanced };
        // First sighting homes the class on the least-loaded device and
        // repeats stick to it.
        let home = p.route(&pool[0], &view);
        assert_eq!(home, 1);
        assert_eq!(p.route(&pool[0].clone(), &view), home);
        // A different class gets its own (possibly equal) home decision.
        assert!(!pool[0].model_identical(&pool[1]));
        let _ = p.route(&pool[1], &view);
        // Overloading the home past the slack re-homes the class.
        let skewed = [load(0, 0, f64::NAN), load(1, 100, f64::NAN)];
        let view = FleetView { now_ms: 0.0, devices: &skewed };
        assert_eq!(p.route(&pool[0], &view), 0);
    }

    #[test]
    fn spellings_parse_and_round_trip() {
        for s in ["roundrobin", "jsq", "lrw", "p2c:7", "affinity", "JSQ"] {
            let p = parse_route_policy(s).unwrap_or_else(|e| panic!("{e}"));
            assert_eq!(p.name(), s.to_ascii_lowercase());
            assert!(parse_route_policy(&p.name()).is_ok());
        }
        // The alias parses to the canonical spelling.
        assert_eq!(parse_route_policy("rr").unwrap().name(), "roundrobin");
    }

    #[test]
    fn bad_spellings_error_and_list_names() {
        for s in ["nope", "p2c", "p2c:x", "p2c:1:2", "jsq:1", "lrw:0", "affinity:a"] {
            let err = parse_route_policy(s).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(s), "{msg}");
            for name in ["roundrobin", "jsq", "lrw", "p2c:<seed>", "affinity"] {
                assert!(msg.contains(name), "missing {name} in: {msg}");
            }
        }
    }

    #[test]
    fn help_table_covers_registry() {
        let t = route_policy_help_table();
        for name in ["roundrobin", "jsq", "lrw", "p2c:<seed>", "affinity"] {
            assert!(t.contains(name));
        }
    }
}
