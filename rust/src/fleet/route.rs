//! [`RoutePolicy`] — *which device* an arriving kernel goes to.
//!
//! A fleet run has two online decisions per kernel: the route (here) and
//! the order within its device's reorder window
//! ([`crate::online::OnlineReorderer`]). The policies in this registry
//! cover the classic load-balancing spectrum plus two that exploit what
//! this crate already knows about kernels:
//!
//! | spelling | behavior |
//! |---|---|
//! | `roundrobin` | blind rotation (the baseline every bench gate compares against) |
//! | `jsq` | join-shortest-queue by outstanding kernel count |
//! | `lrw` | least residual work: queue *time*, priced via the backend's admissible [`crate::exec::PreparedWorkload::suffix_lower_bound`] over each device's backlog |
//! | `p2c:<seed>` | power-of-two-choices: sample two devices, join the shorter queue |
//! | `affinity` | class affinity: kernels that are model-identical (the predicate behind [`crate::gpu::equivalence_classes`]) co-locate so symmetry collapse keeps paying in the per-device search |
//! | `circuit:<inner>` | per-device circuit breaker around any inner policy: consecutive launch failures trip the breaker, timed half-open probes close it again |
//!
//! `jsq` counts kernels; on a heterogeneous fleet (or heavy-tailed kernel
//! work) queue *length* mispredicts queue *work*, which is where `lrw`'s
//! pricing earns its extra cost. Like the window policies, every route
//! policy must be a **deterministic** function of the state it is shown
//! (plus, for `p2c`, its own seeded PRNG stream) — the fleet engine's
//! bit-identical-replay guarantee (`tests/fleet_determinism.rs`) rests
//! on it.
//!
//! Every load-aware policy (`jsq`, `lrw`, `p2c`, `affinity`) routes
//! around devices whose [`DeviceLoad::health`] is [`Health::Down`]
//! (falling back to the full fleet only when *no* device is up);
//! `roundrobin` stays deliberately blind — it is the no-health baseline
//! the fault bench gates rerouting against.

use crate::gpu::KernelProfile;
use crate::util::SplitMix64;
use std::fmt;

/// Device health as the router sees it. `Down` devices are excluded by
/// every load-aware policy (unless the whole fleet is down); `Degraded`
/// marks stragglers — still routable, but the fleet engine serves their
/// windows in FIFO order rather than spending search budget on a device
/// that is already late.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Health {
    /// Serving normally.
    #[default]
    Healthy,
    /// Serving, but slowed (a straggler) — reorder effort is wasted here.
    Degraded,
    /// Not serving: crashed, or masked by a tripped circuit breaker.
    Down,
}

/// Snapshot of one device at a routing instant.
#[derive(Debug, Clone, Copy)]
pub struct DeviceLoad {
    /// Device index in the fleet.
    pub device: usize,
    /// Kernels routed to this device and not yet completed (open window
    /// + queued batches + executing batch).
    pub outstanding: usize,
    /// Kernels in the device's open reorder window.
    pub n_pending: usize,
    /// Windows closed but not yet started on the device.
    pub queued_batches: usize,
    /// Earliest time the device frees (`<= now_ms` means idle). The
    /// thread coordinator cannot predict this and passes `now_ms` for an
    /// idle device, `+inf` for a busy one.
    pub free_at_ms: f64,
    /// Device compute roofline (work units per ms) — how heterogeneous
    /// fleets expose their speed differences to the policies.
    pub peak_compute: f64,
    /// Admissible lower bound (ms) on the device's residual work:
    /// executing-batch remainder plus a
    /// [`crate::exec::PreparedWorkload::suffix_lower_bound`] over the
    /// backlog. `NaN` when the caller did not price it (only policies
    /// with [`RoutePolicy::needs_pricing`] get finite values; `lrw`
    /// falls back to `outstanding` on `NaN`).
    pub backlog_lb_ms: f64,
    /// Whether the device is serving, slowed, or down (see [`Health`]).
    pub health: Health,
}

/// Everything a [`RoutePolicy`] sees when it places one kernel.
#[derive(Debug, Clone, Copy)]
pub struct FleetView<'a> {
    /// Current virtual time (or clock-derived time in the coordinator).
    pub now_ms: f64,
    /// One entry per device, indexed by device id.
    pub devices: &'a [DeviceLoad],
}

/// Decides which device an arriving kernel joins.
///
/// Contract: `route` returns a device index (the engine clamps it into
/// range defensively); equal-score ties must break toward the lowest
/// index so runs replay bit-identically.
pub trait RoutePolicy: Send {
    /// Registry spelling of this policy instance (e.g. `"p2c:7"`).
    fn name(&self) -> String;

    /// Whether [`DeviceLoad::backlog_lb_ms`] must be priced before
    /// `route` is called. Pricing costs a backend `prepare` per device
    /// per decision, so only `lrw` asks for it.
    fn needs_pricing(&self) -> bool {
        false
    }

    /// Pick the device for `kernel` given the fleet snapshot.
    fn route(&mut self, kernel: &KernelProfile, fleet: &FleetView<'_>) -> usize;

    /// Feedback after a launch attempt on `device` (`ok` false on a
    /// launch failure). Default no-op; [`Circuit`] uses it to drive its
    /// per-device breakers. Callers only emit it when a fault model is
    /// active, so policies ignoring it cost nothing.
    fn on_outcome(&mut self, _device: usize, _ok: bool, _now_ms: f64) {}
}

/// First *routable* device minimizing `score` (strict `<`, so ties break
/// toward the lowest index — the determinism contract). `Down` devices
/// are skipped unless every device is down, in which case the whole
/// fleet is scored (the kernel has to land somewhere; it will wait out
/// the outage there).
fn argmin_by(devices: &[DeviceLoad], score: impl Fn(&DeviceLoad) -> f64) -> usize {
    let any_up = devices.iter().any(|d| d.health != Health::Down);
    let mut best = 0usize;
    let mut best_score = f64::INFINITY;
    for d in devices {
        if any_up && d.health == Health::Down {
            continue;
        }
        let s = score(d);
        if s < best_score {
            best_score = s;
            best = d.device;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// Implementations
// ---------------------------------------------------------------------------

/// `roundrobin` — blind rotation, load- and kernel-oblivious. The
/// baseline the fleet bench gates every other policy against, and the
/// coordinator's historical dispatch rule.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> Self {
        RoundRobin::default()
    }
}

impl RoutePolicy for RoundRobin {
    fn name(&self) -> String {
        "roundrobin".to_string()
    }

    fn route(&mut self, _kernel: &KernelProfile, fleet: &FleetView<'_>) -> usize {
        let d = self.next % fleet.devices.len().max(1);
        self.next = self.next.wrapping_add(1);
        d
    }
}

/// `jsq` — join the device with the fewest outstanding kernels. Optimal
/// among length-based rules on homogeneous fleets; blind to device speed
/// and kernel size.
#[derive(Debug, Clone, Copy, Default)]
pub struct Jsq;

impl Jsq {
    pub fn new() -> Self {
        Jsq
    }
}

impl RoutePolicy for Jsq {
    fn name(&self) -> String {
        "jsq".to_string()
    }

    fn route(&mut self, _kernel: &KernelProfile, fleet: &FleetView<'_>) -> usize {
        argmin_by(fleet.devices, |d| d.outstanding as f64)
    }
}

/// `lrw` — least residual work. Scores each device by its priced
/// backlog lower bound plus the arriving kernel's own compute-roofline
/// time on that device, so a slow or work-laden device loses to a fast
/// or empty one even at equal queue length. Falls back to `jsq` scoring
/// where the caller cannot price backlogs (`backlog_lb_ms` NaN — the
/// live coordinator path).
#[derive(Debug, Clone, Copy, Default)]
pub struct Lrw;

impl Lrw {
    pub fn new() -> Self {
        Lrw
    }
}

impl RoutePolicy for Lrw {
    fn name(&self) -> String {
        "lrw".to_string()
    }

    fn needs_pricing(&self) -> bool {
        true
    }

    fn route(&mut self, kernel: &KernelProfile, fleet: &FleetView<'_>) -> usize {
        argmin_by(fleet.devices, |d| {
            if d.backlog_lb_ms.is_finite() {
                let own = if d.peak_compute > 0.0 {
                    kernel.total_work() / d.peak_compute
                } else {
                    0.0
                };
                d.backlog_lb_ms + own
            } else {
                d.outstanding as f64
            }
        })
    }
}

/// `p2c:<seed>` — power-of-two-choices: sample two distinct devices from
/// a seeded PRNG stream, join the one with fewer outstanding kernels.
/// Near-jsq balance at O(1) state inspection; deterministic per seed.
#[derive(Debug, Clone)]
pub struct P2c {
    seed: u64,
    rng: SplitMix64,
}

/// Domain-separation constant for the `p2c` PRNG stream (the arrival
/// constants live in `online::arrivals`).
const P2C_SEED_XOR: u64 = 0xF1EE_7007;

impl P2c {
    pub fn new(seed: u64) -> Self {
        P2c {
            seed,
            rng: SplitMix64::new(seed ^ P2C_SEED_XOR),
        }
    }
}

impl RoutePolicy for P2c {
    fn name(&self) -> String {
        format!("p2c:{}", self.seed)
    }

    fn route(&mut self, _kernel: &KernelProfile, fleet: &FleetView<'_>) -> usize {
        if fleet.devices.len() <= 1 {
            return 0;
        }
        // Sample among the devices that are up; with everything healthy
        // this is the identity pool, so the PRNG stream (and therefore
        // every pick) is bit-identical to the health-blind behavior.
        let mut pool: Vec<usize> = fleet
            .devices
            .iter()
            .filter(|d| d.health != Health::Down)
            .map(|d| d.device)
            .collect();
        if pool.is_empty() {
            pool = (0..fleet.devices.len()).collect();
        }
        let n = pool.len();
        if n == 1 {
            return pool[0];
        }
        let a = (self.rng.next_u64() % n as u64) as usize;
        let mut b = (self.rng.next_u64() % (n as u64 - 1)) as usize;
        if b >= a {
            b += 1; // distinct second sample
        }
        let (lo, hi) = if a <= b { (pool[a], pool[b]) } else { (pool[b], pool[a]) };
        // `<=` keeps the lower index on ties (determinism contract).
        if fleet.devices[lo].outstanding <= fleet.devices[hi].outstanding {
            lo
        } else {
            hi
        }
    }
}

/// Outstanding-kernel slack beyond the fleet minimum that makes
/// [`Affinity`] re-home a class instead of keeping it co-located: small
/// enough that a hot class cannot wedge one device, large enough that a
/// class is not ping-ponged by ordinary queue noise.
const REBALANCE_SLACK: usize = 8;

/// `affinity` — class affinity. Model-identical kernels (the same
/// predicate [`crate::gpu::equivalence_classes`] collapses on) are
/// routed to the same home device, so per-device reorder windows fill
/// with repeated kernels and the search layer's identical-kernel
/// symmetry collapse keeps paying. New classes are homed on the
/// least-loaded device; a home that falls more than [`REBALANCE_SLACK`]
/// outstanding kernels behind the fleet minimum is re-homed so affinity
/// never beats load balance by more than a bounded margin.
#[derive(Debug, Clone, Default)]
pub struct Affinity {
    /// One `(representative, home device)` entry per class seen.
    classes: Vec<(KernelProfile, usize)>,
}

impl Affinity {
    pub fn new() -> Self {
        Affinity::default()
    }
}

impl RoutePolicy for Affinity {
    fn name(&self) -> String {
        "affinity".to_string()
    }

    fn route(&mut self, kernel: &KernelProfile, fleet: &FleetView<'_>) -> usize {
        let n = fleet.devices.len().max(1);
        // The rebalance reference is the minimum over devices that are
        // up (identical to the plain minimum when nothing is down) — a
        // crashed device's empty queue must not make every class look
        // overloaded.
        let min_out = fleet
            .devices
            .iter()
            .filter(|d| d.health != Health::Down)
            .map(|d| d.outstanding)
            .min()
            .unwrap_or(0);
        if let Some(slot) = self
            .classes
            .iter_mut()
            .find(|(rep, _)| rep.model_identical(kernel))
        {
            let home = slot.1.min(n - 1);
            let home_down = fleet.devices[home].health == Health::Down;
            if home_down || fleet.devices[home].outstanding > min_out + REBALANCE_SLACK {
                // Overloaded or dead home: re-home on the least-loaded
                // live device (sticky, so the class stays co-located).
                slot.1 = argmin_by(fleet.devices, |d| d.outstanding as f64);
                return slot.1;
            }
            slot.1 = home;
            return home;
        }
        let home = argmin_by(fleet.devices, |d| d.outstanding as f64);
        self.classes.push((kernel.clone(), home));
        home
    }
}

/// Consecutive launch failures on one device that trip its breaker.
pub const CIRCUIT_TRIP_AFTER: u32 = 3;

/// How long (virtual ms) a tripped breaker stays open before the next
/// routing instant may probe the device again (half-open state).
pub const CIRCUIT_COOLDOWN_MS: f64 = 50.0;

/// Per-device breaker state for [`Circuit`].
#[derive(Debug, Clone, Copy, PartialEq)]
enum Breaker {
    /// Normal: counting consecutive failures toward the trip threshold.
    Closed { consecutive_failures: u32 },
    /// Tripped: the device is masked from the inner policy until the
    /// cooldown deadline.
    Open { until_ms: f64 },
    /// Cooldown elapsed: the device is offered to the inner policy
    /// again; the next outcome closes the breaker or re-trips it.
    HalfOpen,
}

/// `circuit:<inner>` — a per-device circuit breaker around any inner
/// route policy. [`CIRCUIT_TRIP_AFTER`] consecutive launch failures
/// (reported through [`RoutePolicy::on_outcome`]) trip a device's
/// breaker: the device is shown to the inner policy as [`Health::Down`]
/// for [`CIRCUIT_COOLDOWN_MS`] of virtual time, after which it goes
/// *half-open* — offered again, and the first outcome either closes the
/// breaker (success) or re-trips it for another cooldown (failure).
/// All transitions are pure functions of `(outcomes, now_ms)`, so the
/// wrapper preserves the bit-identical-replay contract.
pub struct Circuit {
    inner: Box<dyn RoutePolicy>,
    breakers: Vec<Breaker>,
    scratch: Vec<DeviceLoad>,
}

impl Circuit {
    pub fn new(inner: Box<dyn RoutePolicy>) -> Self {
        Circuit {
            inner,
            breakers: Vec::new(),
            scratch: Vec::new(),
        }
    }
}

impl RoutePolicy for Circuit {
    fn name(&self) -> String {
        format!("circuit:{}", self.inner.name())
    }

    fn needs_pricing(&self) -> bool {
        self.inner.needs_pricing()
    }

    fn route(&mut self, kernel: &KernelProfile, fleet: &FleetView<'_>) -> usize {
        let n = fleet.devices.len();
        if self.breakers.len() < n {
            self.breakers
                .resize(n, Breaker::Closed { consecutive_failures: 0 });
        }
        // Timed half-open: an expired cooldown lets the next routing
        // instant probe the device again.
        for b in &mut self.breakers[..n] {
            if let Breaker::Open { until_ms } = *b {
                if fleet.now_ms >= until_ms {
                    *b = Breaker::HalfOpen;
                }
            }
        }
        // Show the inner policy a view where tripped devices are down.
        self.scratch.clear();
        self.scratch.extend_from_slice(fleet.devices);
        for d in &mut self.scratch {
            if matches!(self.breakers[d.device.min(n - 1)], Breaker::Open { .. }) {
                d.health = Health::Down;
            }
        }
        // Never mask the whole fleet: if breakers would leave nothing
        // routable, fall back to the unmasked view.
        let view = if self.scratch.iter().all(|d| d.health == Health::Down)
            && fleet.devices.iter().any(|d| d.health != Health::Down)
        {
            FleetView { now_ms: fleet.now_ms, devices: fleet.devices }
        } else {
            FleetView { now_ms: fleet.now_ms, devices: &self.scratch }
        };
        self.inner.route(kernel, &view)
    }

    fn on_outcome(&mut self, device: usize, ok: bool, now_ms: f64) {
        if self.breakers.len() <= device {
            self.breakers
                .resize(device + 1, Breaker::Closed { consecutive_failures: 0 });
        }
        let b = &mut self.breakers[device];
        if ok {
            *b = Breaker::Closed { consecutive_failures: 0 };
        } else {
            *b = match *b {
                Breaker::Closed { consecutive_failures } => {
                    let f = consecutive_failures + 1;
                    if f >= CIRCUIT_TRIP_AFTER {
                        Breaker::Open { until_ms: now_ms + CIRCUIT_COOLDOWN_MS }
                    } else {
                        Breaker::Closed { consecutive_failures: f }
                    }
                }
                // A failed half-open probe re-trips for another cooldown.
                Breaker::HalfOpen => Breaker::Open { until_ms: now_ms + CIRCUIT_COOLDOWN_MS },
                open @ Breaker::Open { .. } => open,
            };
        }
        self.inner.on_outcome(device, ok, now_ms);
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Error for unknown route-policy spellings; `Display` lists the valid
/// forms.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RouteParseError {
    pub input: String,
}

impl fmt::Display for RouteParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown route policy `{}` — valid policies: roundrobin, jsq, lrw, p2c:<seed>, \
             affinity, circuit:<inner>",
            self.input
        )
    }
}

impl std::error::Error for RouteParseError {}

/// Parse a route-policy spelling (`"roundrobin"`, `"jsq"`, `"lrw"`,
/// `"p2c:7"`, `"affinity"`, `"circuit:<inner>"`; `"rr"` is accepted as
/// an alias) into a trait object.
///
/// ```
/// let p = kreorder::fleet::parse_route_policy("p2c:7").unwrap();
/// assert_eq!(p.name(), "p2c:7");
/// assert_eq!(kreorder::fleet::parse_route_policy("circuit:jsq").unwrap().name(), "circuit:jsq");
/// assert!(kreorder::fleet::parse_route_policy("nope").is_err());
/// ```
pub fn parse_route_policy(s: &str) -> Result<Box<dyn RoutePolicy>, RouteParseError> {
    let lower = s.to_ascii_lowercase();
    let err = || RouteParseError { input: s.into() };
    if let Some(inner) = lower.strip_prefix("circuit:") {
        // The wrapper nests (e.g. `circuit:p2c:7`); errors echo the full
        // input, not just the inner spelling.
        let inner = parse_route_policy(inner).map_err(|_| err())?;
        return Ok(Box::new(Circuit::new(inner)));
    }
    let mut parts = lower.split(':');
    let head = parts.next().unwrap_or("");
    let policy: Box<dyn RoutePolicy> = match head {
        "roundrobin" | "rr" => Box::new(RoundRobin::new()),
        "jsq" => Box::new(Jsq::new()),
        "lrw" => Box::new(Lrw::new()),
        "p2c" => {
            let seed = parts
                .next()
                .ok_or_else(err)?
                .parse::<u64>()
                .map_err(|_| err())?;
            Box::new(P2c::new(seed))
        }
        "affinity" => Box::new(Affinity::new()),
        _ => return Err(err()),
    };
    if parts.next().is_some() {
        return Err(err());
    }
    Ok(policy)
}

/// Human-readable table of the route-policy spellings (one per line).
pub fn route_policy_help_table() -> String {
    let rows = [
        ("roundrobin", "blind rotation across devices (the gate baseline)"),
        ("jsq", "join-shortest-queue by outstanding kernel count"),
        (
            "lrw",
            "least residual work, priced by the backend's admissible suffix lower bound",
        ),
        ("p2c:<seed>", "power-of-two-choices: sample two devices, join the shorter"),
        (
            "affinity",
            "co-locate model-identical kernels so symmetry collapse keeps paying",
        ),
        (
            "circuit:<inner>",
            "per-device breaker around any policy: trips on consecutive failures, half-open probes",
        ),
    ];
    let mut out = String::new();
    for (name, desc) in rows {
        out.push_str(&format!("  {name:<20} {desc}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuSpec;
    use crate::workloads::synthetic_workload;

    fn load(device: usize, outstanding: usize, backlog: f64) -> DeviceLoad {
        DeviceLoad {
            device,
            outstanding,
            n_pending: 0,
            queued_batches: 0,
            free_at_ms: 0.0,
            peak_compute: GpuSpec::gtx580().peak_compute(),
            backlog_lb_ms: backlog,
            health: Health::Healthy,
        }
    }

    fn down(device: usize, outstanding: usize) -> DeviceLoad {
        DeviceLoad {
            health: Health::Down,
            ..load(device, outstanding, f64::NAN)
        }
    }

    fn kernel() -> KernelProfile {
        synthetic_workload(&GpuSpec::gtx580(), 1, 5)[0].clone()
    }

    #[test]
    fn roundrobin_rotates_regardless_of_load() {
        let loads = [load(0, 9, f64::NAN), load(1, 0, f64::NAN), load(2, 5, f64::NAN)];
        let view = FleetView { now_ms: 0.0, devices: &loads };
        let mut p = RoundRobin::new();
        let k = kernel();
        let picks: Vec<usize> = (0..6).map(|_| p.route(&k, &view)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn jsq_joins_shortest_with_lowest_index_ties() {
        let loads = [load(0, 3, f64::NAN), load(1, 1, f64::NAN), load(2, 1, f64::NAN)];
        let view = FleetView { now_ms: 0.0, devices: &loads };
        assert_eq!(Jsq::new().route(&kernel(), &view), 1);
    }

    #[test]
    fn lrw_prefers_less_residual_work_over_shorter_queue() {
        // Device 1 has fewer kernels but a much larger priced backlog
        // (heavy kernels): lrw must disagree with jsq here.
        let loads = [load(0, 4, 10.0), load(1, 1, 500.0)];
        let view = FleetView { now_ms: 0.0, devices: &loads };
        assert_eq!(Jsq::new().route(&kernel(), &view), 1);
        assert_eq!(Lrw::new().route(&kernel(), &view), 0);
        assert!(Lrw::new().needs_pricing());
    }

    #[test]
    fn lrw_falls_back_to_queue_length_without_pricing() {
        let loads = [load(0, 4, f64::NAN), load(1, 1, f64::NAN)];
        let view = FleetView { now_ms: 0.0, devices: &loads };
        assert_eq!(Lrw::new().route(&kernel(), &view), 1);
    }

    #[test]
    fn p2c_is_deterministic_per_seed_and_avoids_the_longer_queue() {
        let loads = [load(0, 0, f64::NAN), load(1, 100, f64::NAN), load(2, 0, f64::NAN)];
        let view = FleetView { now_ms: 0.0, devices: &loads };
        let k = kernel();
        let picks = |seed| {
            let mut p = P2c::new(seed);
            (0..32).map(|_| p.route(&k, &view)).collect::<Vec<_>>()
        };
        assert_eq!(picks(7), picks(7), "same seed must replay identically");
        assert_ne!(picks(7), picks(8), "different seeds should diverge");
        // Device 1 is only ever chosen when both samples land on it —
        // impossible since the two samples are distinct.
        assert!(picks(7).iter().all(|&d| d != 1));
    }

    #[test]
    fn affinity_colocates_identical_kernels_until_rebalance() {
        let gpu = GpuSpec::gtx580();
        let pool = synthetic_workload(&gpu, 2, 5);
        let mut p = Affinity::new();
        let balanced = [load(0, 2, f64::NAN), load(1, 0, f64::NAN)];
        let view = FleetView { now_ms: 0.0, devices: &balanced };
        // First sighting homes the class on the least-loaded device and
        // repeats stick to it.
        let home = p.route(&pool[0], &view);
        assert_eq!(home, 1);
        assert_eq!(p.route(&pool[0].clone(), &view), home);
        // A different class gets its own (possibly equal) home decision.
        assert!(!pool[0].model_identical(&pool[1]));
        let _ = p.route(&pool[1], &view);
        // Overloading the home past the slack re-homes the class.
        let skewed = [load(0, 0, f64::NAN), load(1, 100, f64::NAN)];
        let view = FleetView { now_ms: 0.0, devices: &skewed };
        assert_eq!(p.route(&pool[0], &view), 0);
    }

    #[test]
    fn spellings_parse_and_round_trip() {
        for s in [
            "roundrobin",
            "jsq",
            "lrw",
            "p2c:7",
            "affinity",
            "JSQ",
            "circuit:jsq",
            "circuit:p2c:7",
        ] {
            let p = parse_route_policy(s).unwrap_or_else(|e| panic!("{e}"));
            assert_eq!(p.name(), s.to_ascii_lowercase());
            assert!(parse_route_policy(&p.name()).is_ok());
        }
        // The alias parses to the canonical spelling.
        assert_eq!(parse_route_policy("rr").unwrap().name(), "roundrobin");
        assert_eq!(parse_route_policy("circuit:rr").unwrap().name(), "circuit:roundrobin");
        // The wrapper delegates needs_pricing to its inner policy.
        assert!(parse_route_policy("circuit:lrw").unwrap().needs_pricing());
        assert!(!parse_route_policy("circuit:jsq").unwrap().needs_pricing());
    }

    #[test]
    fn bad_spellings_error_and_list_names() {
        for s in [
            "nope", "p2c", "p2c:x", "p2c:1:2", "jsq:1", "lrw:0", "affinity:a", "circuit:",
            "circuit:nope", "circuit",
        ] {
            let err = parse_route_policy(s).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(s), "{msg}");
            for name in ["roundrobin", "jsq", "lrw", "p2c:<seed>", "affinity", "circuit:<inner>"] {
                assert!(msg.contains(name), "missing {name} in: {msg}");
            }
        }
    }

    #[test]
    fn help_table_covers_registry() {
        let t = route_policy_help_table();
        for name in ["roundrobin", "jsq", "lrw", "p2c:<seed>", "affinity", "circuit:<inner>"] {
            assert!(t.contains(name));
        }
    }

    #[test]
    fn load_aware_policies_route_around_down_devices() {
        // Device 1 is the shortest queue but down: jsq, lrw and p2c must
        // all avoid it; roundrobin stays blind by design.
        let loads = [load(0, 3, f64::NAN), down(1, 0), load(2, 5, f64::NAN)];
        let view = FleetView { now_ms: 0.0, devices: &loads };
        let k = kernel();
        assert_eq!(Jsq::new().route(&k, &view), 0);
        assert_eq!(Lrw::new().route(&k, &view), 0);
        let mut p2c = P2c::new(7);
        assert!((0..64).all(|_| p2c.route(&k, &view) != 1));
        let mut rr = RoundRobin::new();
        let picks: Vec<usize> = (0..3).map(|_| rr.route(&k, &view)).collect();
        assert_eq!(picks, vec![0, 1, 2], "roundrobin is the no-health baseline");
    }

    #[test]
    fn all_down_fleet_still_routes_somewhere() {
        let loads = [down(0, 2), down(1, 1)];
        let view = FleetView { now_ms: 0.0, devices: &loads };
        let k = kernel();
        assert_eq!(Jsq::new().route(&k, &view), 1);
        let d = P2c::new(3).route(&k, &view);
        assert!(d < 2);
    }

    #[test]
    fn affinity_rehomes_off_a_dead_device() {
        let gpu = GpuSpec::gtx580();
        let pool = synthetic_workload(&gpu, 1, 5);
        let mut p = Affinity::new();
        let healthy = [load(0, 5, f64::NAN), load(1, 0, f64::NAN)];
        let view = FleetView { now_ms: 0.0, devices: &healthy };
        assert_eq!(p.route(&pool[0], &view), 1, "homes on the least-loaded device");
        // Home dies: the class re-homes onto the live device and sticks.
        let crashed = [load(0, 5, f64::NAN), down(1, 0)];
        let view = FleetView { now_ms: 0.0, devices: &crashed };
        assert_eq!(p.route(&pool[0], &view), 0);
        assert_eq!(p.route(&pool[0], &view), 0);
    }

    #[test]
    fn circuit_trips_after_consecutive_failures_and_probes_half_open() {
        let k = kernel();
        let loads = [load(0, 0, f64::NAN), load(1, 9, f64::NAN)];
        let mut c = Circuit::new(Box::new(Jsq::new()));
        let view_at = |t: f64| FleetView { now_ms: t, devices: &loads };
        // Healthy: jsq picks the shorter queue (device 0).
        assert_eq!(c.route(&k, &view_at(0.0)), 0);
        // Trip device 0 with consecutive launch failures.
        for _ in 0..CIRCUIT_TRIP_AFTER {
            c.on_outcome(0, false, 0.0);
        }
        assert_eq!(c.route(&k, &view_at(1.0)), 1, "tripped breaker masks device 0");
        // Cooldown not elapsed: still masked.
        assert_eq!(c.route(&k, &view_at(CIRCUIT_COOLDOWN_MS - 1.0)), 1);
        // Cooldown elapsed: half-open — the device is offered again.
        assert_eq!(c.route(&k, &view_at(CIRCUIT_COOLDOWN_MS + 1.0)), 0);
        // A failed probe re-trips immediately…
        c.on_outcome(0, false, CIRCUIT_COOLDOWN_MS + 1.0);
        assert_eq!(c.route(&k, &view_at(CIRCUIT_COOLDOWN_MS + 2.0)), 1);
        // …and a successful probe after the next cooldown closes it.
        let later = 2.0 * CIRCUIT_COOLDOWN_MS + 2.0;
        assert_eq!(c.route(&k, &view_at(later)), 0);
        c.on_outcome(0, true, later);
        assert_eq!(c.route(&k, &view_at(later + 1.0)), 0);
        // One more single failure does not re-trip a closed breaker.
        c.on_outcome(0, false, later + 1.0);
        assert_eq!(c.route(&k, &view_at(later + 2.0)), 0);
    }

    #[test]
    fn circuit_never_masks_the_whole_fleet() {
        let k = kernel();
        let loads = [load(0, 0, f64::NAN), load(1, 1, f64::NAN)];
        let mut c = Circuit::new(Box::new(Jsq::new()));
        for d in 0..2 {
            for _ in 0..CIRCUIT_TRIP_AFTER {
                c.on_outcome(d, false, 0.0);
            }
        }
        // Both breakers open: the unmasked view is used instead.
        assert_eq!(c.route(&k, &FleetView { now_ms: 1.0, devices: &loads }), 0);
    }
}
