//! The fleet event loop: arrivals → route → per-device reorder windows.
//!
//! [`simulate_fleet`] extends the single-device virtual-clock simulation
//! ([`crate::online::simulate_online`]) to `D` devices, each with its
//! own [`WindowPolicy`] instance, its own batch queue and its own
//! backend, with a [`RoutePolicy`] deciding which device every arriving
//! kernel joins. Time is still a plain `f64` of virtual milliseconds,
//! the loop is still O(events), and a run is still a pure function of
//! its configuration: equal (arrival seed, route policy, window policy,
//! strategy seed, backend) produce **bit-identical** per-kernel
//! timestamps on every machine (`tests/fleet_determinism.rs` pins it).
//!
//! [`simulate_fleet_with_faults`] is the same loop with a
//! [`FaultConfig`] threaded through it; `simulate_fleet` is the
//! empty-plan special case, and an empty plan is a **strict no-op** —
//! no extra events, no PRNG draws, no float arithmetic — so the
//! fault-free timestamps are bit-identical through either entry point
//! (`tests/fault_recovery.rs` pins that too).
//!
//! Seven event kinds drive the loop, processed in this fixed priority at
//! equal times:
//!
//! 1. **fault** — a [`FaultPlan`] event fires (device down / recover /
//!    slowdown). A device going **down** orphans everything it holds —
//!    open window, queued batches, and the in-flight remainder of its
//!    executing batch — back to the router, which re-routes each kernel
//!    under the live health state;
//! 2. **routing decision** — a popped arrival is placed on a device
//!    (under a `launchfail` process this is also where a launch attempt
//!    can fail: the kernel backs off per the [`RetryPolicy`] and, past
//!    the attempt cap, is **shed** with a cause — never silently lost);
//! 3. **completion** — a kernel's model finish time passed;
//! 4. **batch start** — a device is free and a closed window's decision
//!    overhead has elapsed (device ties break toward the lowest index);
//! 5. **arrival** — the source's next kernel enters the router (under
//!    [`simulate_fleet_with_admission`] this is where the admission
//!    gate admits or sheds it, before any routing state is touched);
//! 6. **retry** — a failed launch's backoff elapsed; the kernel
//!    re-enters the router;
//! 7. **recheck** — some device's [`WindowPolicy`] `Wait` deadline
//!    landed.
//!
//! Every *up* device's window policy is consulted after every event; the
//! first device (by index) whose policy says `Close` runs the shared
//! [`OnlineReorderer`] over its own pending kernels and queues the
//! batch behind its own device. A [`Health::Degraded`] device (a
//! straggler) skips the search and serves its windows in FIFO arrival
//! order — reorder effort is wasted on a device that is already late —
//! and the report counts every such degraded decision.

use super::report::{FleetBatchRecord, FleetKernelRecord, FleetReport, ShedCause, ShedRecord};
use super::route::{DeviceLoad, FleetView, Health, RoutePolicy};
use super::spec::FleetSpec;
use crate::admission::{AdmissionPolicy, AdmissionState, NoAdmission};
use crate::exec::ExecutionBackend;
use crate::fault::{FaultAction, FaultConfig, FaultPlan};
use crate::gpu::{GpuSpec, KernelProfile};
use crate::obs::{NoTrace, TraceEvent, TraceSink};
use crate::online::arrivals::{Arrival, ArrivalSource};
use crate::online::window::{WindowDecision, WindowPolicy, WindowState};
use crate::online::{OnlineOpts, OnlineReorderer, ReorderDecision};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

/// Totally ordered f64 for the completion heap (event times are always
/// finite).
#[derive(Debug, Clone, Copy, PartialEq)]
struct EventTime(f64);

impl Eq for EventTime {}

impl PartialOrd for EventTime {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for EventTime {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// A kernel waiting in a device's open reorder window.
struct Open {
    id: u64,
    arrival_ms: f64,
    route_ms: f64,
    profile: KernelProfile,
}

/// A closed window queued behind its device.
struct Closed {
    batch: u64,
    close_ms: f64,
    /// Close time plus decision overhead; service cannot start earlier.
    ready_ms: f64,
    members: Vec<Open>,
    order: Vec<usize>,
    evals: u64,
}

/// One device's complete scheduling state.
struct Dev {
    gpu: GpuSpec,
    window: Box<dyn WindowPolicy>,
    backend: Box<dyn ExecutionBackend>,
    pending: Vec<Open>,
    queue: VecDeque<Closed>,
    free_at: f64,
    /// Kernels routed here and not yet completed.
    outstanding: usize,
    busy_ms: f64,
    recheck: Option<f64>,
    /// Injected state: up / straggling / down.
    health: Health,
    /// Injected service-time multiplier (1.0 = nominal).
    slow: f64,
    /// The executing batch's members with their finish times, kept so a
    /// crash can orphan the in-flight remainder. Replaced wholesale at
    /// each batch start (the device is serial, so by then every previous
    /// member has completed).
    running: Vec<(f64, Open)>,
}

/// Event priorities at equal times (lower wins). The relative order of
/// the five fault-free kinds is unchanged from the pre-fault engine, so
/// an empty plan replays bit-identically.
const EV_FAULT: u8 = 0;
const EV_ROUTE: u8 = 1;
const EV_COMPLETION: u8 = 2;
const EV_BATCH_START: u8 = 3;
const EV_ARRIVAL: u8 = 4;
const EV_RETRY: u8 = 5;
const EV_RECHECK: u8 = 6;

/// Close device `dev`'s open window at `now`: reorder within the
/// per-decision budget and queue the batch behind the device. Returns
/// `(evaluations spent, decision was a degraded FIFO fallback)`.
///
/// When `traced`, emits a [`TraceEvent::ReorderDecision`] pricing the
/// chosen order against FIFO on a *fresh* backend — pure observation,
/// the device's own backend state is never touched.
#[allow(clippy::too_many_arguments)]
fn close_window(
    dev: &mut Dev,
    device: usize,
    now: f64,
    batch_id: u64,
    decision_ms_per_eval: f64,
    reorderer: &OnlineReorderer,
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
    traced: bool,
    sink: &mut dyn TraceSink,
) -> (u64, bool) {
    let members = std::mem::take(&mut dev.pending);
    let (decision, degraded) = if dev.health == Health::Degraded {
        // Straggler: don't spend search budget on a device that is
        // already late — serve the FIFO-guarded arrival order.
        let d = ReorderDecision {
            order: (0..members.len()).collect(),
            evals: 0,
            degraded: true,
        };
        (d, true)
    } else {
        let profiles: Vec<KernelProfile> = members.iter().map(|m| m.profile.clone()).collect();
        let d = reorderer.decide(&dev.gpu, &profiles, make_backend);
        let degraded = d.degraded;
        (d, degraded)
    };
    if traced && !members.is_empty() {
        let profiles: Vec<KernelProfile> = members.iter().map(|m| m.profile.clone()).collect();
        let mut fresh = make_backend();
        let mut prepared = fresh.prepare(&dev.gpu, &profiles);
        let chosen_ms = prepared.execute_order(&decision.order);
        let identity: Vec<usize> = (0..profiles.len()).collect();
        let fifo_ms = prepared.execute_order(&identity);
        sink.record(TraceEvent::ReorderDecision {
            t_ms: now,
            device,
            batch: batch_id,
            n: profiles.len(),
            strategy: reorderer.name(),
            evals: decision.evals,
            degraded: decision.degraded,
            chosen_ms,
            fifo_ms,
        });
    }
    let evals = decision.evals;
    dev.queue.push_back(Closed {
        batch: batch_id,
        close_ms: now,
        ready_ms: now + decision_ms_per_eval * evals as f64,
        members,
        order: decision.order,
        evals,
    });
    (evals, degraded)
}

/// Admissible lower bound (ms) on everything device `dev` still owes:
/// the executing batch's remainder plus the backend's suffix bound over
/// the backlog (open window + queued batches).
fn price_backlog(dev: &mut Dev, now: f64) -> f64 {
    let residual = (dev.free_at - now).max(0.0);
    let mut profiles: Vec<KernelProfile> =
        dev.pending.iter().map(|o| o.profile.clone()).collect();
    for b in &dev.queue {
        profiles.extend(b.members.iter().map(|o| o.profile.clone()));
    }
    if profiles.is_empty() {
        return residual;
    }
    let all: Vec<usize> = (0..profiles.len()).collect();
    let mut prepared = dev.backend.prepare(&dev.gpu, &profiles);
    let lb = prepared.suffix_lower_bound(&all);
    // Backends without a bound seam report -inf; price the backlog as
    // free rather than poisoning the score.
    residual + if lb.is_finite() { lb.max(0.0) } else { 0.0 }
}

/// Fill `loads` with the per-device snapshot a [`RoutePolicy`] decides
/// over. The caller owns the buffer and reuses it across routing
/// decisions (one allocation per run, not per decision — the first step
/// of the ROADMAP O(log D) device-view item). Backlog pricing costs a
/// backend `prepare` per device, so it only happens when the policy
/// asked for it ([`RoutePolicy::needs_pricing`]).
fn device_loads(devs: &mut [Dev], now: f64, price: bool, loads: &mut Vec<DeviceLoad>) {
    loads.clear();
    for (d, dev) in devs.iter_mut().enumerate() {
        let backlog_lb_ms = if price { price_backlog(dev, now) } else { f64::NAN };
        loads.push(DeviceLoad {
            device: d,
            outstanding: dev.outstanding,
            n_pending: dev.pending.len(),
            queued_batches: dev.queue.len(),
            free_at_ms: dev.free_at,
            peak_compute: dev.gpu.peak_compute(),
            backlog_lb_ms,
            health: dev.health,
        });
    }
}

/// Run the fleet scheduler over one arrival stream with no injected
/// faults. See the module docs for the event model; the returned
/// [`FleetReport`] carries every per-kernel timestamp with its device.
pub fn simulate_fleet(
    fleet: &FleetSpec,
    source: Box<dyn ArrivalSource>,
    route: Box<dyn RoutePolicy>,
    make_window: &dyn Fn() -> Box<dyn WindowPolicy>,
    reorderer: &OnlineReorderer,
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
    opts: &OnlineOpts,
) -> FleetReport {
    simulate_fleet_with_faults(
        fleet,
        source,
        route,
        make_window,
        reorderer,
        make_backend,
        opts,
        &FaultConfig::default(),
    )
}

/// [`simulate_fleet`] with a [`FaultConfig`] threaded through the loop.
///
/// **Prefer [`crate::fleet::FleetSimConfig`]** for new call sites: the
/// builder names each positional argument, defaults the common ones,
/// and runs this exact engine — bit-identical reports. The positional
/// form stays for existing callers and for the builder itself; it is
/// not going away, but it is no longer the front door.
///
/// The no-kernel-lost invariant (`tests/fault_recovery.rs`): every
/// arrival ends as exactly one of a completed kernel record, or a
/// [`ShedRecord`] with a cause (retry cap exhausted, or stranded on a
/// crashed device that never recovers). Equal `(fault plan, retry,
/// config)` replay **bit-identically**; an empty plan reproduces
/// [`simulate_fleet`] exactly.
///
/// # Panics
///
/// Panics if the fleet is empty or the plan names a device the fleet
/// does not have (validate with [`FaultPlan::validate_for`] first at
/// the CLI boundary).
#[allow(clippy::too_many_arguments)]
pub fn simulate_fleet_with_faults(
    fleet: &FleetSpec,
    source: Box<dyn ArrivalSource>,
    route: Box<dyn RoutePolicy>,
    make_window: &dyn Fn() -> Box<dyn WindowPolicy>,
    reorderer: &OnlineReorderer,
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
    opts: &OnlineOpts,
    faults: &FaultConfig,
) -> FleetReport {
    let mut none = NoAdmission;
    simulate_fleet_with_admission(
        fleet,
        source,
        route,
        make_window,
        reorderer,
        make_backend,
        opts,
        faults,
        &mut none,
    )
}

/// [`simulate_fleet_with_faults`] with an [`AdmissionPolicy`] gating
/// arrivals at the virtual clock. A rejected arrival never reaches the
/// router: it becomes a first-class [`ShedRecord`] with a
/// [`ShedCause::Rejected`] cause and its source is notified
/// (`on_completion`) so closed-loop clients never starve. Retries and
/// crash orphans were already admitted and are **not** re-gated. The
/// extended conservation invariant (`tests/overload_protection.rs`) is
/// `kernels.len() + shed.len() == arrivals`.
///
/// When the policy [`is_noop`](AdmissionPolicy::is_noop) (the `none`
/// spelling) the entire gate is skipped — no occupancy snapshot, no
/// backlog pricing, no float arithmetic — so `none` runs are
/// **bit-identical** to [`simulate_fleet_with_faults`]. `deadline`
/// pricing reuses the same admissible `price_backlog` seam as `lrw`
/// routing, taken over the best currently-up device.
#[allow(clippy::too_many_arguments)]
pub fn simulate_fleet_with_admission(
    fleet: &FleetSpec,
    source: Box<dyn ArrivalSource>,
    route: Box<dyn RoutePolicy>,
    make_window: &dyn Fn() -> Box<dyn WindowPolicy>,
    reorderer: &OnlineReorderer,
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
    opts: &OnlineOpts,
    faults: &FaultConfig,
    admission: &mut dyn AdmissionPolicy,
) -> FleetReport {
    let mut sink = NoTrace;
    simulate_fleet_traced(
        fleet,
        source,
        route,
        make_window,
        reorderer,
        make_backend,
        opts,
        faults,
        admission,
        &mut sink,
    )
}

/// [`simulate_fleet_with_admission`] with a [`TraceSink`] observing
/// every decision the loop makes: arrivals, admission verdicts, window
/// decides, reorder decisions (chosen vs FIFO makespan, priced on a
/// fresh backend), route decisions with their load snapshots, batch
/// spans, fault-plan firings, retry/backoff and every shed with its
/// cause.
///
/// The sink **observes, never perturbs** — the same discipline as
/// `admission=none`. With [`NoTrace`] (`is_noop`) no event is even
/// constructed, so untraced entry points are bit-identical and
/// allocation-free versus the pre-trace engine: this *is* the only
/// engine, and the untraced entry points delegate here
/// (`tests/trace_observability.rs` pins both properties).
///
/// [`TraceEvent::BatchFinish`] is emitted at batch *start* time stamped
/// with the future finish time (the virtual-clock engine already knows
/// the makespan then), so the stream is not globally monotone in
/// `t_ms`; [`crate::obs::export::chrome_trace_json`] reconstructs
/// per-device spans post hoc and clips them at device crashes.
#[allow(clippy::too_many_arguments)]
pub fn simulate_fleet_traced(
    fleet: &FleetSpec,
    mut source: Box<dyn ArrivalSource>,
    mut route: Box<dyn RoutePolicy>,
    make_window: &dyn Fn() -> Box<dyn WindowPolicy>,
    reorderer: &OnlineReorderer,
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
    opts: &OnlineOpts,
    faults: &FaultConfig,
    admission: &mut dyn AdmissionPolicy,
    sink: &mut dyn TraceSink,
) -> FleetReport {
    let traced = !sink.is_noop();
    assert!(!fleet.devices.is_empty(), "simulate_fleet needs at least one device");
    faults
        .plan
        .validate_for(fleet.devices.len())
        .unwrap_or_else(|e| panic!("{e}"));
    let mut devs: Vec<Dev> = fleet
        .devices
        .iter()
        .map(|gpu| Dev {
            gpu: gpu.clone(),
            window: make_window(),
            backend: make_backend(),
            pending: Vec::new(),
            queue: VecDeque::new(),
            free_at: 0.0,
            outstanding: 0,
            busy_ms: 0.0,
            recheck: None,
            health: Health::Healthy,
            slow: 1.0,
            running: Vec::new(),
        })
        .collect();
    let source_name = source.name();
    let route_name = route.name();
    let window_name = devs[0].window.name();
    let backend_name = devs[0].backend.name().to_string();
    let needs_pricing = route.needs_pricing();
    let admission_name = admission.name();
    let gate_active = !admission.is_noop();
    let admission_pricing = gate_active && admission.needs_pricing();
    let decision_ms_per_eval = if opts.decision_ms_per_eval.is_finite() {
        opts.decision_ms_per_eval.max(0.0)
    } else {
        0.0
    };

    // Fault machinery. With an empty plan every piece below is inert:
    // the timeline is empty (no EV_FAULT candidates), `launchfail` is
    // `None` (no draws at route time), and the retry queue never fills.
    let timeline = faults.plan.timeline();
    let mut fault_idx = 0usize;
    let launchfail = faults.plan.launch_failures;
    let retry = &faults.retry;
    // Launch attempts per kernel id (only touched under `launchfail`).
    let mut attempts: HashMap<u64, u32> = HashMap::new();
    // Kernels backing off after a failed launch: (due time, id) heap
    // plus the parked arrival payloads.
    let mut retry_q: BinaryHeap<Reverse<(EventTime, u64)>> = BinaryHeap::new();
    let mut parked: HashMap<u64, Arrival> = HashMap::new();

    let mut now = 0.0f64;
    // Arrivals popped from the source but not yet placed on a device,
    // with the time each one entered the router.
    let mut to_route: VecDeque<(f64, Arrival)> = VecDeque::new();
    // Min-heap of (finish time, kernel id, device) completion events.
    let mut completions: BinaryHeap<Reverse<(EventTime, u64, usize)>> = BinaryHeap::new();
    let mut next_batch = 0u64;
    // Scratch device view, reused across routing decisions.
    let mut loads: Vec<DeviceLoad> = Vec::with_capacity(devs.len());

    let mut kernels: Vec<FleetKernelRecord> = Vec::new();
    let mut batches: Vec<FleetBatchRecord> = Vec::new();
    let mut decision_evals = 0u64;
    let mut n_unsimulable = 0usize;
    let mut n_degraded_decisions = 0u64;
    let mut n_rerouted = 0u64;
    let mut n_launch_failures = 0u64;
    let mut shed: Vec<ShedRecord> = Vec::new();

    loop {
        // Ask every up device's policy about its open window. Closing
        // never advances time, so each policy always sees the post-close
        // state before the clock moves again. Down devices are skipped:
        // their windows are frozen until recovery (or shed at drain).
        let mut close_dev: Option<usize> = None;
        for (d, dev) in devs.iter_mut().enumerate() {
            dev.recheck = None;
            if dev.health == Health::Down || dev.pending.is_empty() {
                continue;
            }
            let state = WindowState {
                now_ms: now,
                n_pending: dev.pending.len(),
                oldest_arrival_ms: dev.pending[0].arrival_ms,
                device_free_at_ms: dev.free_at,
                queued_batches: dev.queue.len(),
            };
            let decision = dev.window.decide(&state);
            if traced {
                sink.record(TraceEvent::WindowDecide {
                    t_ms: now,
                    device: d,
                    n_pending: state.n_pending,
                    queued_batches: state.queued_batches,
                    close: matches!(decision, WindowDecision::Close),
                });
            }
            match decision {
                WindowDecision::Close => {
                    close_dev = Some(d);
                    break;
                }
                WindowDecision::Wait { recheck_at_ms } => {
                    debug_assert!(
                        recheck_at_ms.map_or(true, |t| t > now),
                        "window policy returned a non-future recheck deadline"
                    );
                    dev.recheck = recheck_at_ms;
                }
            }
        }
        if let Some(d) = close_dev {
            let (evals, degraded) = close_window(
                &mut devs[d],
                d,
                now,
                next_batch,
                decision_ms_per_eval,
                reorderer,
                make_backend,
                traced,
                sink,
            );
            decision_evals += evals;
            if degraded {
                n_degraded_decisions += 1;
            }
            next_batch += 1;
            continue;
        }

        // Earliest event, ties broken by the fixed priority order
        // (batch-start device ties break toward the lowest index by the
        // strict `<` scan).
        let t_fault = timeline.get(fault_idx).map(|e| e.at_ms);
        let t_route = to_route.front().map(|(t, _)| *t);
        let t_completion = completions.peek().map(|Reverse((t, _, _))| t.0);
        let mut start: Option<(f64, usize)> = None;
        for (d, dev) in devs.iter().enumerate() {
            if dev.health == Health::Down {
                continue; // a down device cannot start work
            }
            if let Some(b) = dev.queue.front() {
                let t = b.ready_ms.max(dev.free_at);
                if start.map_or(true, |(bt, _)| t < bt) {
                    start = Some((t, d));
                }
            }
        }
        let t_arrival = source.next_at();
        let t_retry = retry_q.peek().map(|Reverse((t, _))| t.0);
        let t_recheck = devs.iter().filter_map(|d| d.recheck).reduce(f64::min);
        let candidates = [
            (t_fault, EV_FAULT),
            (t_route, EV_ROUTE),
            (t_completion, EV_COMPLETION),
            (start.map(|(t, _)| t), EV_BATCH_START),
            (t_arrival, EV_ARRIVAL),
            (t_retry, EV_RETRY),
            (t_recheck, EV_RECHECK),
        ];
        let mut next: Option<(f64, u8)> = None;
        for (t, kind) in candidates {
            let Some(t) = t else { continue };
            let better = match next {
                None => true,
                Some((bt, bk)) => t < bt || (t == bt && kind < bk),
            };
            if better {
                next = Some((t, kind));
            }
        }

        match next {
            None => {
                // End-of-stream drain: nothing else can ever happen, so
                // open windows on up devices close regardless of policy,
                // lowest device first (a fixed:<k> window would
                // otherwise strand its remainder forever).
                match devs
                    .iter()
                    .position(|d| d.health != Health::Down && !d.pending.is_empty())
                {
                    Some(d) => {
                        let (evals, degraded) = close_window(
                            &mut devs[d],
                            d,
                            now,
                            next_batch,
                            decision_ms_per_eval,
                            reorderer,
                            make_backend,
                            traced,
                            sink,
                        );
                        decision_evals += evals;
                        if degraded {
                            n_degraded_decisions += 1;
                        }
                        next_batch += 1;
                    }
                    None => {
                        // Anything still held by a device that is down
                        // with no recovery coming (the fault timeline is
                        // exhausted — it was a candidate above) can
                        // never be served: shed it with a cause rather
                        // than losing it.
                        let mut stranded = false;
                        for (d, dev) in devs.iter_mut().enumerate() {
                            if dev.health != Health::Down {
                                continue;
                            }
                            let mut orphans: Vec<Open> = Vec::new();
                            for b in dev.queue.drain(..) {
                                orphans.extend(b.members);
                            }
                            orphans.append(&mut dev.pending);
                            for o in orphans {
                                stranded = true;
                                dev.outstanding -= 1;
                                let cause = ShedCause::Stranded { device: d };
                                if traced {
                                    sink.record(TraceEvent::Shed {
                                        t_ms: now,
                                        id: o.id,
                                        cause: cause.to_csv(),
                                    });
                                }
                                shed.push(ShedRecord {
                                    id: o.id,
                                    arrival_ms: o.arrival_ms,
                                    attempts: attempts.get(&o.id).copied().unwrap_or(1),
                                    cause,
                                });
                                // The kernel left the system: closed-loop
                                // sources must not wait for it forever.
                                source.on_completion(now, o.id);
                            }
                        }
                        if stranded {
                            continue;
                        }
                        break; // drained and idle everywhere: done
                    }
                }
            }
            Some((t, kind)) => {
                debug_assert!(t >= now, "event time moved backwards");
                now = t.max(now);
                match kind {
                    EV_FAULT => {
                        let ev = &timeline[fault_idx];
                        fault_idx += 1;
                        let d = ev.device;
                        if traced {
                            let action = match ev.action {
                                FaultAction::Down => "down".to_string(),
                                FaultAction::Recover => "recover".to_string(),
                                FaultAction::Slow(factor) => format!("slow:{factor}"),
                            };
                            sink.record(TraceEvent::Fault { t_ms: now, device: d, action });
                        }
                        match ev.action {
                            FaultAction::Down => {
                                if devs[d].health != Health::Down {
                                    let dev = &mut devs[d];
                                    dev.health = Health::Down;
                                    // The executing batch's remainder is
                                    // abandoned: give back the residual
                                    // busy time and retract the records
                                    // and completion events of members
                                    // that had not finished yet.
                                    if dev.free_at > now {
                                        dev.busy_ms -= dev.free_at - now;
                                        dev.free_at = now;
                                    }
                                    let mut orphans: Vec<Open> = Vec::new();
                                    let mut aborted: Vec<u64> = Vec::new();
                                    for (finish, o) in std::mem::take(&mut dev.running) {
                                        if finish > now {
                                            aborted.push(o.id);
                                            orphans.push(o);
                                        }
                                    }
                                    for b in dev.queue.drain(..) {
                                        orphans.extend(b.members);
                                    }
                                    orphans.append(&mut dev.pending);
                                    if !aborted.is_empty() {
                                        kernels.retain(|k| {
                                            !(k.device == d && aborted.contains(&k.id))
                                        });
                                        let heap = std::mem::take(&mut completions);
                                        completions = heap
                                            .into_iter()
                                            .filter(|Reverse((_, id, dd))| {
                                                !(*dd == d && aborted.contains(id))
                                            })
                                            .collect();
                                    }
                                    // Hand every orphan back to the
                                    // router; it re-routes them under
                                    // the post-crash health state.
                                    for o in orphans {
                                        devs[d].outstanding -= 1;
                                        n_rerouted += 1;
                                        to_route.push_back((
                                            now,
                                            Arrival {
                                                id: o.id,
                                                at_ms: o.arrival_ms,
                                                profile: o.profile,
                                            },
                                        ));
                                    }
                                }
                            }
                            FaultAction::Recover => {
                                let dev = &mut devs[d];
                                if dev.health == Health::Down {
                                    dev.health = if dev.slow > 1.0 {
                                        Health::Degraded
                                    } else {
                                        Health::Healthy
                                    };
                                    dev.free_at = dev.free_at.max(now);
                                }
                            }
                            FaultAction::Slow(factor) => {
                                let dev = &mut devs[d];
                                dev.slow = factor;
                                if dev.health != Health::Down {
                                    dev.health = if factor > 1.0 {
                                        Health::Degraded
                                    } else {
                                        Health::Healthy
                                    };
                                }
                            }
                        }
                    }
                    EV_ROUTE => {
                        let (_, a) = to_route.pop_front().expect("peeked");
                        device_loads(&mut devs, now, needs_pricing, &mut loads);
                        let view = FleetView { now_ms: now, devices: &loads };
                        let d = route.route(&a.profile, &view).min(devs.len() - 1);
                        if traced {
                            sink.record(TraceEvent::RouteDecision {
                                t_ms: now,
                                id: a.id,
                                device: d,
                                policy: route_name.clone(),
                                outstanding: loads.iter().map(|l| l.outstanding).collect(),
                                free_at_ms: loads.iter().map(|l| l.free_at_ms).collect(),
                            });
                        }
                        if let Some(lf) = launchfail {
                            let attempt = attempts.entry(a.id).or_insert(0);
                            *attempt += 1;
                            if lf.fails(a.id, *attempt) {
                                n_launch_failures += 1;
                                route.on_outcome(d, false, now);
                                if traced {
                                    sink.record(TraceEvent::Fault {
                                        t_ms: now,
                                        device: d,
                                        action: "launchfail".to_string(),
                                    });
                                }
                                if *attempt >= retry.max_attempts {
                                    let cause = ShedCause::RetryCap { attempts: *attempt };
                                    if traced {
                                        sink.record(TraceEvent::Shed {
                                            t_ms: now,
                                            id: a.id,
                                            cause: cause.to_csv(),
                                        });
                                    }
                                    shed.push(ShedRecord {
                                        id: a.id,
                                        arrival_ms: a.at_ms,
                                        attempts: *attempt,
                                        cause,
                                    });
                                    source.on_completion(now, a.id);
                                } else {
                                    let back = retry.backoff_ms(a.id, *attempt);
                                    if traced {
                                        sink.record(TraceEvent::Retry {
                                            t_ms: now,
                                            id: a.id,
                                            attempt: *attempt,
                                            backoff_ms: back,
                                        });
                                    }
                                    retry_q.push(Reverse((EventTime(now + back), a.id)));
                                    parked.insert(a.id, a);
                                }
                                continue;
                            }
                            route.on_outcome(d, true, now);
                        }
                        devs[d].outstanding += 1;
                        devs[d].pending.push(Open {
                            id: a.id,
                            arrival_ms: a.at_ms,
                            route_ms: now,
                            profile: a.profile,
                        });
                    }
                    EV_COMPLETION => {
                        let Reverse((_, id, d)) = completions.pop().expect("peeked");
                        devs[d].outstanding -= 1;
                        source.on_completion(now, id);
                    }
                    EV_BATCH_START => {
                        let (_, d) = start.expect("batch-start chosen from a queued batch");
                        let dev = &mut devs[d];
                        let Closed {
                            batch,
                            close_ms,
                            ready_ms,
                            members,
                            order,
                            evals,
                        } = dev.queue.pop_front().expect("peeked");
                        let profiles: Vec<KernelProfile> =
                            members.iter().map(|m| m.profile.clone()).collect();
                        let report = dev.backend.execute(&dev.gpu, &profiles, &order);
                        let mut makespan = if report.makespan_ms.is_nan() {
                            // Unsimulable batch: serve it in zero time
                            // rather than wedging the queue (validated
                            // sources never hit this; the report counts
                            // it).
                            n_unsimulable += 1;
                            0.0
                        } else {
                            report.makespan_ms
                        };
                        // Straggler stretch (inert at the nominal 1.0:
                        // the fault-free path sees no extra float op).
                        let stretch = dev.slow != 1.0;
                        if stretch {
                            makespan *= dev.slow;
                        }
                        dev.free_at = now + makespan;
                        dev.busy_ms += makespan;
                        if traced {
                            sink.record(TraceEvent::BatchStart {
                                t_ms: now,
                                device: d,
                                batch,
                                n: members.len(),
                                order: order.clone(),
                            });
                            // Future-stamped: the virtual clock already
                            // knows when this batch finishes.
                            sink.record(TraceEvent::BatchFinish {
                                t_ms: now + makespan,
                                device: d,
                                batch,
                                makespan_ms: makespan,
                            });
                        }
                        let n_members = members.len();
                        let mut finish_dt = vec![0.0f64; n_members];
                        for o in &report.outcomes {
                            let m = &members[o.index];
                            let mut dt = if o.finish_ms.is_nan() { 0.0 } else { o.finish_ms };
                            if stretch {
                                dt *= dev.slow;
                            }
                            finish_dt[o.index] = dt;
                            let finish = now + dt;
                            kernels.push(FleetKernelRecord {
                                id: m.id,
                                device: d,
                                arrival_ms: m.arrival_ms,
                                route_ms: m.route_ms,
                                close_ms,
                                start_ms: now,
                                finish_ms: finish,
                                batch,
                                position: o.position,
                            });
                            completions.push(Reverse((EventTime(finish), m.id, d)));
                        }
                        // Keep the members with their finish times so a
                        // crash can orphan the unfinished remainder.
                        dev.running.clear();
                        for (i, m) in members.into_iter().enumerate() {
                            dev.running.push((now + finish_dt[i], m));
                        }
                        batches.push(FleetBatchRecord {
                            id: batch,
                            device: d,
                            n: n_members,
                            close_ms,
                            ready_ms,
                            start_ms: now,
                            makespan_ms: makespan,
                            evals,
                            order,
                        });
                    }
                    EV_ARRIVAL => {
                        let a = source.pop(now);
                        if traced {
                            sink.record(TraceEvent::Arrival { t_ms: now, id: a.id });
                        }
                        // Admission gate: skipped entirely under `none`
                        // (bit-identity), priced only when the policy
                        // asks for it. Only fresh arrivals are gated —
                        // retries and crash orphans were admitted once.
                        let admit = if gate_active {
                            let depth = to_route.len()
                                + devs.iter().map(|d| d.outstanding).sum::<usize>();
                            let mut oldest = f64::INFINITY;
                            if let Some((_, front)) = to_route.front() {
                                oldest = oldest.min(front.at_ms);
                            }
                            for dev in &devs {
                                for m in &dev.pending {
                                    oldest = oldest.min(m.arrival_ms);
                                }
                                for b in &dev.queue {
                                    for m in &b.members {
                                        oldest = oldest.min(m.arrival_ms);
                                    }
                                }
                            }
                            let oldest_wait_ms = if oldest.is_finite() {
                                (now - oldest).max(0.0)
                            } else {
                                0.0
                            };
                            let predicted_sojourn_ms = if admission_pricing {
                                // Admissible: the arrival waits at least
                                // the best up device's priced backlog.
                                devs.iter_mut()
                                    .filter(|d| d.health != Health::Down)
                                    .map(|d| price_backlog(d, now))
                                    .fold(f64::INFINITY, f64::min)
                            } else {
                                f64::NAN
                            };
                            let ok = admission.admit(&AdmissionState {
                                now_ms: now,
                                queue_depth: depth,
                                oldest_wait_ms,
                                predicted_sojourn_ms,
                            });
                            if traced {
                                sink.record(TraceEvent::Admission {
                                    t_ms: now,
                                    id: a.id,
                                    policy: admission_name.clone(),
                                    admitted: ok,
                                    queue_depth: depth,
                                    predicted_sojourn_ms,
                                });
                            }
                            ok
                        } else {
                            true
                        };
                        if admit {
                            to_route.push_back((now, a));
                        } else {
                            let cause = ShedCause::Rejected {
                                policy: admission_name.clone(),
                            };
                            if traced {
                                sink.record(TraceEvent::Shed {
                                    t_ms: now,
                                    id: a.id,
                                    cause: cause.to_csv(),
                                });
                            }
                            shed.push(ShedRecord {
                                id: a.id,
                                arrival_ms: a.at_ms,
                                attempts: 0,
                                cause,
                            });
                            // The kernel left the system: closed-loop
                            // sources must not wait for it forever.
                            source.on_completion(now, a.id);
                        }
                    }
                    EV_RETRY => {
                        let Reverse((_, id)) = retry_q.pop().expect("peeked");
                        let a = parked.remove(&id).expect("parked retry payload");
                        to_route.push_back((now, a));
                    }
                    _ => {} // EV_RECHECK: the policies re-decide above
                }
            }
        }
    }

    let span_ms = kernels.iter().map(|k| k.finish_ms).fold(0.0, f64::max);
    kernels.sort_by_key(|k| k.id);
    shed.sort_by_key(|s| s.id);
    FleetReport {
        source: source_name,
        route: route_name,
        window: window_name,
        reorderer: reorderer.name(),
        backend: backend_name,
        admission: admission_name,
        kernels,
        batches,
        span_ms,
        device_busy_ms: devs.iter().map(|d| d.busy_ms).collect(),
        decision_evals,
        n_unsimulable,
        n_degraded_decisions,
        n_rerouted,
        n_launch_failures,
        n_fault_events: timeline.len(),
        shed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::SimulatorBackend;
    use crate::fault::RetryPolicy;
    use crate::fleet::route::parse_route_policy;
    use crate::online::arrivals::{ReplaySource, Trace};
    use crate::online::window::parse_window_policy;

    fn sim() -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
        Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)
    }

    fn run(fleet: &FleetSpec, route: &str, family: &str, n: usize, rate: f64) -> FleetReport {
        let gpu = GpuSpec::gtx580();
        let trace = Trace::poisson(family, n, rate, 7);
        let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
        simulate_fleet(
            fleet,
            source,
            parse_route_policy(route).unwrap(),
            &|| parse_window_policy("linger:6:30").unwrap(),
            &OnlineReorderer::fifo(),
            sim().as_ref(),
            &OnlineOpts::default(),
        )
    }

    fn run_faulty(
        fleet: &FleetSpec,
        route: &str,
        family: &str,
        n: usize,
        rate: f64,
        faults: &FaultConfig,
    ) -> FleetReport {
        let gpu = GpuSpec::gtx580();
        let trace = Trace::poisson(family, n, rate, 7);
        let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
        simulate_fleet_with_faults(
            fleet,
            source,
            parse_route_policy(route).unwrap(),
            &|| parse_window_policy("linger:6:30").unwrap(),
            &OnlineReorderer::fifo(),
            sim().as_ref(),
            &OnlineOpts::default(),
            faults,
        )
    }

    #[test]
    fn conservation_and_timestamp_ordering_across_devices() {
        let fleet = FleetSpec::homogeneous(3);
        let r = run(&fleet, "jsq", "uniform", 30, 400.0);
        assert_eq!(r.kernels.len(), 30);
        assert_eq!(r.batches.iter().map(|b| b.n).sum::<usize>(), 30);
        assert!(r.batches.iter().all(|b| b.n >= 1));
        let ids: Vec<u64> = r.kernels.iter().map(|k| k.id).collect();
        assert_eq!(ids, (0..30).collect::<Vec<_>>());
        for k in &r.kernels {
            assert!(k.device < 3, "{k:?}");
            assert!(k.arrival_ms <= k.route_ms, "{k:?}");
            assert!(k.route_ms <= k.close_ms, "{k:?}");
            assert!(k.close_ms <= k.start_ms, "{k:?}");
            assert!(k.start_ms <= k.finish_ms, "{k:?}");
        }
        // Each device is serial: its batches never overlap.
        for d in 0..3 {
            let mine: Vec<&FleetBatchRecord> =
                r.batches.iter().filter(|b| b.device == d).collect();
            for w in mine.windows(2) {
                assert!(w[1].start_ms >= w[0].start_ms + w[0].makespan_ms - 1e-9);
            }
        }
        assert_eq!(r.n_unsimulable, 0);
        assert_eq!(r.device_busy_ms.len(), 3);
        // No faults: all fault accounting is zero.
        assert!(r.shed.is_empty());
        assert_eq!(r.n_rerouted, 0);
        assert_eq!(r.n_launch_failures, 0);
        assert_eq!(r.n_fault_events, 0);
    }

    #[test]
    fn jsq_uses_every_device_under_load() {
        let fleet = FleetSpec::homogeneous(3);
        let r = run(&fleet, "jsq", "uniform", 48, 2000.0);
        let counts = r.device_kernel_counts();
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }

    #[test]
    fn single_device_fleet_matches_the_online_engine() {
        // D=1 routing is a no-op, so the fleet engine must reproduce
        // simulate_online's timestamps bit-for-bit — same events, same
        // tie-breaks.
        let gpu = GpuSpec::gtx580();
        let trace = Trace::poisson("skewed", 24, 300.0, 11);
        let fleet = FleetSpec::homogeneous(1);
        let reorderer = OnlineReorderer::search("local:3", 200).unwrap();
        let f = simulate_fleet(
            &fleet,
            Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap()),
            parse_route_policy("roundrobin").unwrap(),
            &|| parse_window_policy("linger:6:25").unwrap(),
            &reorderer,
            sim().as_ref(),
            &OnlineOpts::default(),
        );
        let o = crate::online::simulate_online(
            &gpu,
            Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap()),
            parse_window_policy("linger:6:25").unwrap(),
            &reorderer,
            sim().as_ref(),
            &OnlineOpts::default(),
        );
        assert_eq!(f.kernels.len(), o.kernels.len());
        for (fk, ok) in f.kernels.iter().zip(&o.kernels) {
            assert_eq!(fk.id, ok.id);
            assert_eq!(fk.finish_ms.to_bits(), ok.finish_ms.to_bits(), "{fk:?} vs {ok:?}");
            assert_eq!(fk.start_ms.to_bits(), ok.start_ms.to_bits());
        }
        assert_eq!(f.span_ms.to_bits(), o.span_ms.to_bits());
    }

    #[test]
    fn lrw_pricing_runs_and_serves_everything() {
        let fleet = FleetSpec::parse("1,0.5").unwrap();
        let r = run(&fleet, "lrw", "skewed", 32, 800.0);
        assert_eq!(r.kernels.len(), 32);
        assert!(r.kernels.iter().all(|k| k.device < 2));
    }

    #[test]
    fn out_of_range_route_is_clamped() {
        struct Wild;
        impl RoutePolicy for Wild {
            fn name(&self) -> String {
                "wild".into()
            }
            fn route(&mut self, _k: &KernelProfile, _f: &FleetView<'_>) -> usize {
                usize::MAX
            }
        }
        let gpu = GpuSpec::gtx580();
        let trace = Trace::poisson("uniform", 8, 200.0, 3);
        let r = simulate_fleet(
            &FleetSpec::homogeneous(2),
            Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap()),
            Box::new(Wild),
            &|| parse_window_policy("fixed:4").unwrap(),
            &OnlineReorderer::fifo(),
            sim().as_ref(),
            &OnlineOpts::default(),
        );
        assert_eq!(r.kernels.len(), 8);
        assert!(r.kernels.iter().all(|k| k.device == 1));
    }

    #[test]
    fn crash_orphans_reroute_and_nothing_is_lost() {
        let fleet = FleetSpec::homogeneous(2);
        let faults = FaultConfig {
            plan: FaultPlan::parse("crash:0@20").unwrap(),
            retry: RetryPolicy::default(),
        };
        let r = run_faulty(&fleet, "jsq", "uniform", 32, 600.0, &faults);
        // jsq routes around the dead device: everything completes.
        assert_eq!(r.kernels.len() + r.shed.len(), 32);
        assert!(r.shed.is_empty(), "{:?}", r.shed);
        assert!(
            r.kernels.iter().all(|k| k.device == 1 || k.finish_ms <= 20.0 + 1e-9),
            "no kernel may finish on device 0 after the crash"
        );
        assert_eq!(r.n_fault_events, 1);
    }

    #[test]
    fn blind_routing_under_a_permanent_crash_sheds_with_causes() {
        let fleet = FleetSpec::homogeneous(2);
        let faults = FaultConfig {
            plan: FaultPlan::parse("crash:0@5").unwrap(),
            retry: RetryPolicy::default(),
        };
        let r = run_faulty(&fleet, "roundrobin", "uniform", 24, 600.0, &faults);
        // Round-robin keeps dealing to the dead device; those kernels
        // are shed at drain, with a cause — the conservation invariant.
        assert_eq!(r.kernels.len() + r.shed.len(), 24);
        assert!(!r.shed.is_empty());
        assert!(
            r.shed
                .iter()
                .all(|s| s.cause.to_string().contains("crashed device 0")),
            "{:?}",
            r.shed
        );
        assert!(r.kernels.iter().all(|k| k.device == 1 || k.finish_ms <= 5.0 + 1e-9));
    }

    #[test]
    fn admission_gate_sheds_with_rejected_cause_and_conserves() {
        let gpu = GpuSpec::gtx580();
        let fleet = FleetSpec::homogeneous(2);
        let trace = Trace::poisson("uniform", 40, 3000.0, 7);
        let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
        let mut adm = crate::admission::parse_admission_policy("bound:4").unwrap();
        let r = simulate_fleet_with_admission(
            &fleet,
            source,
            parse_route_policy("jsq").unwrap(),
            &|| parse_window_policy("linger:6:30").unwrap(),
            &OnlineReorderer::fifo(),
            sim().as_ref(),
            &OnlineOpts::default(),
            &FaultConfig::default(),
            adm.as_mut(),
        );
        assert_eq!(r.kernels.len() + r.shed.len(), 40);
        assert!(!r.shed.is_empty(), "a 4-deep bound under burst load must shed");
        assert!(r
            .shed
            .iter()
            .all(|s| matches!(s.cause, ShedCause::Rejected { .. }) && s.attempts == 0));
        assert_eq!(r.admission, "bound:4");
        let mut ids: Vec<u64> = r
            .kernels
            .iter()
            .map(|k| k.id)
            .chain(r.shed.iter().map(|s| s.id))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn empty_plan_through_the_fault_entry_point_is_bit_identical() {
        let fleet = FleetSpec::parse("1,0.5").unwrap();
        let a = run(&fleet, "lrw", "skewed", 32, 800.0);
        let b = run_faulty(&fleet, "lrw", "skewed", 32, 800.0, &FaultConfig::default());
        assert_eq!(a.kernels.len(), b.kernels.len());
        for (x, y) in a.kernels.iter().zip(&b.kernels) {
            assert_eq!(x.finish_ms.to_bits(), y.finish_ms.to_bits());
            assert_eq!(x.device, y.device);
        }
        assert_eq!(a.span_ms.to_bits(), b.span_ms.to_bits());
    }

    #[test]
    fn plans_naming_missing_devices_panic_with_context() {
        let fleet = FleetSpec::homogeneous(2);
        let faults = FaultConfig {
            plan: FaultPlan::parse("crash:7@5").unwrap(),
            retry: RetryPolicy::default(),
        };
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_faulty(&fleet, "jsq", "uniform", 4, 200.0, &faults)
        }))
        .unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("device 7"), "{msg}");
    }
}
