//! The fleet event loop: arrivals → route → per-device reorder windows.
//!
//! [`simulate_fleet`] extends the single-device virtual-clock simulation
//! ([`crate::online::simulate_online`]) to `D` devices, each with its
//! own [`WindowPolicy`] instance, its own batch queue and its own
//! backend, with a [`RoutePolicy`] deciding which device every arriving
//! kernel joins. Time is still a plain `f64` of virtual milliseconds,
//! the loop is still O(events), and a run is still a pure function of
//! its configuration: equal (arrival seed, route policy, window policy,
//! strategy seed, backend) produce **bit-identical** per-kernel
//! timestamps on every machine (`tests/fleet_determinism.rs` pins it).
//!
//! Five event kinds drive the loop, processed in this fixed priority at
//! equal times:
//!
//! 1. **routing decision** — a popped arrival is placed on a device;
//! 2. **completion** — a kernel's model finish time passed;
//! 3. **batch start** — a device is free and a closed window's decision
//!    overhead has elapsed (device ties break toward the lowest index);
//! 4. **arrival** — the source's next kernel enters the router;
//! 5. **recheck** — some device's [`WindowPolicy`] `Wait` deadline
//!    landed.
//!
//! Every device's window policy is consulted after every event; the
//! first device (by index) whose policy says `Close` runs the shared
//! [`OnlineReorderer`] over its own pending kernels and queues the
//! batch behind its own device.

use super::report::{FleetBatchRecord, FleetKernelRecord, FleetReport};
use super::route::{DeviceLoad, FleetView, RoutePolicy};
use super::spec::FleetSpec;
use crate::exec::ExecutionBackend;
use crate::gpu::{GpuSpec, KernelProfile};
use crate::online::arrivals::{Arrival, ArrivalSource};
use crate::online::window::{WindowDecision, WindowPolicy, WindowState};
use crate::online::{OnlineOpts, OnlineReorderer};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Totally ordered f64 for the completion heap (event times are always
/// finite).
#[derive(Debug, Clone, Copy, PartialEq)]
struct EventTime(f64);

impl Eq for EventTime {}

impl PartialOrd for EventTime {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for EventTime {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// A kernel waiting in a device's open reorder window.
struct Open {
    id: u64,
    arrival_ms: f64,
    route_ms: f64,
    profile: KernelProfile,
}

/// A closed window queued behind its device.
struct Closed {
    batch: u64,
    close_ms: f64,
    /// Close time plus decision overhead; service cannot start earlier.
    ready_ms: f64,
    members: Vec<Open>,
    order: Vec<usize>,
    evals: u64,
}

/// One device's complete scheduling state.
struct Dev {
    gpu: GpuSpec,
    window: Box<dyn WindowPolicy>,
    backend: Box<dyn ExecutionBackend>,
    pending: Vec<Open>,
    queue: VecDeque<Closed>,
    free_at: f64,
    /// Kernels routed here and not yet completed.
    outstanding: usize,
    busy_ms: f64,
    recheck: Option<f64>,
}

/// Event priorities at equal times (lower wins).
const EV_ROUTE: u8 = 0;
const EV_COMPLETION: u8 = 1;
const EV_BATCH_START: u8 = 2;
const EV_ARRIVAL: u8 = 3;
const EV_RECHECK: u8 = 4;

/// Close device `dev`'s open window at `now`: reorder within the
/// per-decision budget and queue the batch behind the device. Returns
/// the evaluations the decision spent.
fn close_window(
    dev: &mut Dev,
    now: f64,
    batch_id: u64,
    decision_ms_per_eval: f64,
    reorderer: &OnlineReorderer,
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
) -> u64 {
    let members = std::mem::take(&mut dev.pending);
    let profiles: Vec<KernelProfile> = members.iter().map(|m| m.profile.clone()).collect();
    let decision = reorderer.decide(&dev.gpu, &profiles, make_backend);
    let evals = decision.evals;
    dev.queue.push_back(Closed {
        batch: batch_id,
        close_ms: now,
        ready_ms: now + decision_ms_per_eval * evals as f64,
        members,
        order: decision.order,
        evals,
    });
    evals
}

/// Admissible lower bound (ms) on everything device `dev` still owes:
/// the executing batch's remainder plus the backend's suffix bound over
/// the backlog (open window + queued batches).
fn price_backlog(dev: &mut Dev, now: f64) -> f64 {
    let residual = (dev.free_at - now).max(0.0);
    let mut profiles: Vec<KernelProfile> =
        dev.pending.iter().map(|o| o.profile.clone()).collect();
    for b in &dev.queue {
        profiles.extend(b.members.iter().map(|o| o.profile.clone()));
    }
    if profiles.is_empty() {
        return residual;
    }
    let all: Vec<usize> = (0..profiles.len()).collect();
    let mut prepared = dev.backend.prepare(&dev.gpu, &profiles);
    let lb = prepared.suffix_lower_bound(&all);
    // Backends without a bound seam report -inf; price the backlog as
    // free rather than poisoning the score.
    residual + if lb.is_finite() { lb.max(0.0) } else { 0.0 }
}

/// Build the per-device snapshot a [`RoutePolicy`] decides over.
/// Backlog pricing costs a backend `prepare` per device, so it only
/// happens when the policy asked for it ([`RoutePolicy::needs_pricing`]).
fn device_loads(devs: &mut [Dev], now: f64, price: bool) -> Vec<DeviceLoad> {
    let mut loads = Vec::with_capacity(devs.len());
    for (d, dev) in devs.iter_mut().enumerate() {
        let backlog_lb_ms = if price { price_backlog(dev, now) } else { f64::NAN };
        loads.push(DeviceLoad {
            device: d,
            outstanding: dev.outstanding,
            n_pending: dev.pending.len(),
            queued_batches: dev.queue.len(),
            free_at_ms: dev.free_at,
            peak_compute: dev.gpu.peak_compute(),
            backlog_lb_ms,
        });
    }
    loads
}

/// Run the fleet scheduler over one arrival stream. See the module docs
/// for the event model; the returned [`FleetReport`] carries every
/// per-kernel timestamp with its device.
pub fn simulate_fleet(
    fleet: &FleetSpec,
    mut source: Box<dyn ArrivalSource>,
    mut route: Box<dyn RoutePolicy>,
    make_window: &dyn Fn() -> Box<dyn WindowPolicy>,
    reorderer: &OnlineReorderer,
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
    opts: &OnlineOpts,
) -> FleetReport {
    assert!(!fleet.devices.is_empty(), "simulate_fleet needs at least one device");
    let mut devs: Vec<Dev> = fleet
        .devices
        .iter()
        .map(|gpu| Dev {
            gpu: gpu.clone(),
            window: make_window(),
            backend: make_backend(),
            pending: Vec::new(),
            queue: VecDeque::new(),
            free_at: 0.0,
            outstanding: 0,
            busy_ms: 0.0,
            recheck: None,
        })
        .collect();
    let source_name = source.name();
    let route_name = route.name();
    let window_name = devs[0].window.name();
    let backend_name = devs[0].backend.name().to_string();
    let needs_pricing = route.needs_pricing();
    let decision_ms_per_eval = if opts.decision_ms_per_eval.is_finite() {
        opts.decision_ms_per_eval.max(0.0)
    } else {
        0.0
    };

    let mut now = 0.0f64;
    // Arrivals popped from the source but not yet placed on a device,
    // with the time each one entered the router.
    let mut to_route: VecDeque<(f64, Arrival)> = VecDeque::new();
    // Min-heap of (finish time, kernel id, device) completion events.
    let mut completions: BinaryHeap<Reverse<(EventTime, u64, usize)>> = BinaryHeap::new();
    let mut next_batch = 0u64;

    let mut kernels: Vec<FleetKernelRecord> = Vec::new();
    let mut batches: Vec<FleetBatchRecord> = Vec::new();
    let mut decision_evals = 0u64;
    let mut n_unsimulable = 0usize;

    loop {
        // Ask every device's policy about its open window. Closing never
        // advances time, so each policy always sees the post-close state
        // before the clock moves again.
        let mut close_dev: Option<usize> = None;
        for (d, dev) in devs.iter_mut().enumerate() {
            dev.recheck = None;
            if dev.pending.is_empty() {
                continue;
            }
            let state = WindowState {
                now_ms: now,
                n_pending: dev.pending.len(),
                oldest_arrival_ms: dev.pending[0].arrival_ms,
                device_free_at_ms: dev.free_at,
                queued_batches: dev.queue.len(),
            };
            match dev.window.decide(&state) {
                WindowDecision::Close => {
                    close_dev = Some(d);
                    break;
                }
                WindowDecision::Wait { recheck_at_ms } => {
                    debug_assert!(
                        recheck_at_ms.map_or(true, |t| t > now),
                        "window policy returned a non-future recheck deadline"
                    );
                    dev.recheck = recheck_at_ms;
                }
            }
        }
        if let Some(d) = close_dev {
            decision_evals += close_window(
                &mut devs[d],
                now,
                next_batch,
                decision_ms_per_eval,
                reorderer,
                make_backend,
            );
            next_batch += 1;
            continue;
        }

        // Earliest event, ties broken by the fixed priority order
        // (batch-start device ties break toward the lowest index by the
        // strict `<` scan).
        let t_route = to_route.front().map(|(t, _)| *t);
        let t_completion = completions.peek().map(|Reverse((t, _, _))| t.0);
        let mut start: Option<(f64, usize)> = None;
        for (d, dev) in devs.iter().enumerate() {
            if let Some(b) = dev.queue.front() {
                let t = b.ready_ms.max(dev.free_at);
                if start.map_or(true, |(bt, _)| t < bt) {
                    start = Some((t, d));
                }
            }
        }
        let t_arrival = source.next_at();
        let t_recheck = devs.iter().filter_map(|d| d.recheck).reduce(f64::min);
        let candidates = [
            (t_route, EV_ROUTE),
            (t_completion, EV_COMPLETION),
            (start.map(|(t, _)| t), EV_BATCH_START),
            (t_arrival, EV_ARRIVAL),
            (t_recheck, EV_RECHECK),
        ];
        let mut next: Option<(f64, u8)> = None;
        for (t, kind) in candidates {
            let Some(t) = t else { continue };
            let better = match next {
                None => true,
                Some((bt, bk)) => t < bt || (t == bt && kind < bk),
            };
            if better {
                next = Some((t, kind));
            }
        }

        match next {
            None => {
                // End-of-stream drain: nothing else can ever happen, so
                // open windows close regardless of policy, lowest device
                // first (a fixed:<k> window would otherwise strand its
                // remainder forever).
                match devs.iter().position(|d| !d.pending.is_empty()) {
                    None => break, // drained and idle everywhere: done
                    Some(d) => {
                        decision_evals += close_window(
                            &mut devs[d],
                            now,
                            next_batch,
                            decision_ms_per_eval,
                            reorderer,
                            make_backend,
                        );
                        next_batch += 1;
                    }
                }
            }
            Some((t, kind)) => {
                debug_assert!(t >= now, "event time moved backwards");
                now = t.max(now);
                match kind {
                    EV_ROUTE => {
                        let (_, a) = to_route.pop_front().expect("peeked");
                        let loads = device_loads(&mut devs, now, needs_pricing);
                        let view = FleetView { now_ms: now, devices: &loads };
                        let d = route.route(&a.profile, &view).min(devs.len() - 1);
                        devs[d].outstanding += 1;
                        devs[d].pending.push(Open {
                            id: a.id,
                            arrival_ms: a.at_ms,
                            route_ms: now,
                            profile: a.profile,
                        });
                    }
                    EV_COMPLETION => {
                        let Reverse((_, id, d)) = completions.pop().expect("peeked");
                        devs[d].outstanding -= 1;
                        source.on_completion(now, id);
                    }
                    EV_BATCH_START => {
                        let (_, d) = start.expect("batch-start chosen from a queued batch");
                        let dev = &mut devs[d];
                        let b = dev.queue.pop_front().expect("peeked");
                        let profiles: Vec<KernelProfile> =
                            b.members.iter().map(|m| m.profile.clone()).collect();
                        let report = dev.backend.execute(&dev.gpu, &profiles, &b.order);
                        let makespan = if report.makespan_ms.is_nan() {
                            // Unsimulable batch: serve it in zero time
                            // rather than wedging the queue (validated
                            // sources never hit this; the report counts
                            // it).
                            n_unsimulable += 1;
                            0.0
                        } else {
                            report.makespan_ms
                        };
                        dev.free_at = now + makespan;
                        dev.busy_ms += makespan;
                        for o in &report.outcomes {
                            let m = &b.members[o.index];
                            let dt = if o.finish_ms.is_nan() { 0.0 } else { o.finish_ms };
                            let finish = now + dt;
                            kernels.push(FleetKernelRecord {
                                id: m.id,
                                device: d,
                                arrival_ms: m.arrival_ms,
                                route_ms: m.route_ms,
                                close_ms: b.close_ms,
                                start_ms: now,
                                finish_ms: finish,
                                batch: b.batch,
                                position: o.position,
                            });
                            completions.push(Reverse((EventTime(finish), m.id, d)));
                        }
                        batches.push(FleetBatchRecord {
                            id: b.batch,
                            device: d,
                            n: b.members.len(),
                            close_ms: b.close_ms,
                            ready_ms: b.ready_ms,
                            start_ms: now,
                            makespan_ms: makespan,
                            evals: b.evals,
                            order: b.order,
                        });
                    }
                    EV_ARRIVAL => {
                        let a = source.pop(now);
                        to_route.push_back((now, a));
                    }
                    _ => {} // EV_RECHECK: the policies re-decide above
                }
            }
        }
    }

    let span_ms = kernels.iter().map(|k| k.finish_ms).fold(0.0, f64::max);
    kernels.sort_by_key(|k| k.id);
    FleetReport {
        source: source_name,
        route: route_name,
        window: window_name,
        reorderer: reorderer.name(),
        backend: backend_name,
        kernels,
        batches,
        span_ms,
        device_busy_ms: devs.iter().map(|d| d.busy_ms).collect(),
        decision_evals,
        n_unsimulable,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::SimulatorBackend;
    use crate::fleet::route::parse_route_policy;
    use crate::online::arrivals::{ReplaySource, Trace};
    use crate::online::window::parse_window_policy;

    fn sim() -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
        Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)
    }

    fn run(fleet: &FleetSpec, route: &str, family: &str, n: usize, rate: f64) -> FleetReport {
        let gpu = GpuSpec::gtx580();
        let trace = Trace::poisson(family, n, rate, 7);
        let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
        simulate_fleet(
            fleet,
            source,
            parse_route_policy(route).unwrap(),
            &|| parse_window_policy("linger:6:30").unwrap(),
            &OnlineReorderer::fifo(),
            sim().as_ref(),
            &OnlineOpts::default(),
        )
    }

    #[test]
    fn conservation_and_timestamp_ordering_across_devices() {
        let fleet = FleetSpec::homogeneous(3);
        let r = run(&fleet, "jsq", "uniform", 30, 400.0);
        assert_eq!(r.kernels.len(), 30);
        assert_eq!(r.batches.iter().map(|b| b.n).sum::<usize>(), 30);
        assert!(r.batches.iter().all(|b| b.n >= 1));
        let ids: Vec<u64> = r.kernels.iter().map(|k| k.id).collect();
        assert_eq!(ids, (0..30).collect::<Vec<_>>());
        for k in &r.kernels {
            assert!(k.device < 3, "{k:?}");
            assert!(k.arrival_ms <= k.route_ms, "{k:?}");
            assert!(k.route_ms <= k.close_ms, "{k:?}");
            assert!(k.close_ms <= k.start_ms, "{k:?}");
            assert!(k.start_ms <= k.finish_ms, "{k:?}");
        }
        // Each device is serial: its batches never overlap.
        for d in 0..3 {
            let mine: Vec<&FleetBatchRecord> =
                r.batches.iter().filter(|b| b.device == d).collect();
            for w in mine.windows(2) {
                assert!(w[1].start_ms >= w[0].start_ms + w[0].makespan_ms - 1e-9);
            }
        }
        assert_eq!(r.n_unsimulable, 0);
        assert_eq!(r.device_busy_ms.len(), 3);
    }

    #[test]
    fn jsq_uses_every_device_under_load() {
        let fleet = FleetSpec::homogeneous(3);
        let r = run(&fleet, "jsq", "uniform", 48, 2000.0);
        let counts = r.device_kernel_counts();
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }

    #[test]
    fn single_device_fleet_matches_the_online_engine() {
        // D=1 routing is a no-op, so the fleet engine must reproduce
        // simulate_online's timestamps bit-for-bit — same events, same
        // tie-breaks.
        let gpu = GpuSpec::gtx580();
        let trace = Trace::poisson("skewed", 24, 300.0, 11);
        let fleet = FleetSpec::homogeneous(1);
        let reorderer = OnlineReorderer::search("local:3", 200).unwrap();
        let f = simulate_fleet(
            &fleet,
            Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap()),
            parse_route_policy("roundrobin").unwrap(),
            &|| parse_window_policy("linger:6:25").unwrap(),
            &reorderer,
            sim().as_ref(),
            &OnlineOpts::default(),
        );
        let o = crate::online::simulate_online(
            &gpu,
            Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap()),
            parse_window_policy("linger:6:25").unwrap(),
            &reorderer,
            sim().as_ref(),
            &OnlineOpts::default(),
        );
        assert_eq!(f.kernels.len(), o.kernels.len());
        for (fk, ok) in f.kernels.iter().zip(&o.kernels) {
            assert_eq!(fk.id, ok.id);
            assert_eq!(fk.finish_ms.to_bits(), ok.finish_ms.to_bits(), "{fk:?} vs {ok:?}");
            assert_eq!(fk.start_ms.to_bits(), ok.start_ms.to_bits());
        }
        assert_eq!(f.span_ms.to_bits(), o.span_ms.to_bits());
    }

    #[test]
    fn lrw_pricing_runs_and_serves_everything() {
        let fleet = FleetSpec::parse("1,0.5").unwrap();
        let r = run(&fleet, "lrw", "skewed", 32, 800.0);
        assert_eq!(r.kernels.len(), 32);
        assert!(r.kernels.iter().all(|k| k.device < 2));
    }

    #[test]
    fn out_of_range_route_is_clamped() {
        struct Wild;
        impl RoutePolicy for Wild {
            fn name(&self) -> String {
                "wild".into()
            }
            fn route(&mut self, _k: &KernelProfile, _f: &FleetView<'_>) -> usize {
                usize::MAX
            }
        }
        let gpu = GpuSpec::gtx580();
        let trace = Trace::poisson("uniform", 8, 200.0, 3);
        let r = simulate_fleet(
            &FleetSpec::homogeneous(2),
            Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap()),
            Box::new(Wild),
            &|| parse_window_policy("fixed:4").unwrap(),
            &OnlineReorderer::fifo(),
            sim().as_ref(),
            &OnlineOpts::default(),
        );
        assert_eq!(r.kernels.len(), 8);
        assert!(r.kernels.iter().all(|k| k.device == 1));
    }
}
