//! [`FleetSimConfig`] — the builder form of the fleet-simulation entry
//! point.
//!
//! [`simulate_fleet_with_admission`](crate::fleet::simulate_fleet_with_admission)
//! grew to nine positional arguments, six of which almost every caller
//! sets to the same defaults. This builder owns every piece, defaults
//! the optional ones (round-robin routing, `fixed:8` windows, FIFO
//! reordering, the simulator backend, default [`OnlineOpts`], no
//! faults, no admission gate), and runs the *same* engine — a
//! [`FleetSimConfig::run`] with every setter spelled out is
//! argument-for-argument the positional call, so reports are
//! bit-identical between the two forms.
//!
//! ```
//! use kreorder::fleet::{FleetSimConfig, FleetSpec};
//! use kreorder::online::{ReplaySource, Trace};
//! use kreorder::gpu::GpuSpec;
//!
//! let gpu = GpuSpec::gtx580();
//! let trace = Trace::poisson("skewed", 16, 300.0, 3);
//! let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
//! let report = FleetSimConfig::new(FleetSpec::homogeneous(2), source)
//!     .route_named("jsq")
//!     .unwrap()
//!     .window_named("linger:6:30")
//!     .unwrap()
//!     .run();
//! assert_eq!(report.kernels.len(), 16);
//! ```

use crate::admission::{AdmissionPolicy, NoAdmission};
use crate::exec::{ExecutionBackend, SimulatorBackend};
use crate::fault::{FaultConfig, FaultPlan, RetryPolicy};
use crate::fleet::{
    parse_route_policy, simulate_fleet_with_admission, FleetReport, FleetSpec, RoutePolicy,
};
use crate::online::{
    parse_window_policy, ArrivalSource, OnlineOpts, OnlineReorderer, WindowPolicy,
};
use crate::registry::ParseError;

/// Owned configuration for one fleet simulation; see the module docs.
/// Build with [`FleetSimConfig::new`] (the two pieces with no sensible
/// default: the fleet and the arrival stream), override the rest with
/// the setters, and [`run`](FleetSimConfig::run).
pub struct FleetSimConfig {
    fleet: FleetSpec,
    source: Box<dyn ArrivalSource>,
    route: Box<dyn RoutePolicy>,
    make_window: Box<dyn Fn() -> Box<dyn WindowPolicy>>,
    reorderer: OnlineReorderer,
    make_backend: Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync>,
    opts: OnlineOpts,
    faults: FaultConfig,
    admission: Box<dyn AdmissionPolicy>,
}

impl FleetSimConfig {
    /// A config with the given fleet and arrival stream and every other
    /// piece at its default: `roundrobin` routing, `fixed:8` windows,
    /// FIFO reordering, the simulator backend, default [`OnlineOpts`],
    /// no faults, no admission gate.
    pub fn new(fleet: FleetSpec, source: Box<dyn ArrivalSource>) -> FleetSimConfig {
        FleetSimConfig {
            fleet,
            source,
            route: parse_route_policy("roundrobin").expect("roundrobin is registered"),
            make_window: Box::new(|| {
                parse_window_policy("fixed:8").expect("fixed:8 is a valid window spelling")
            }),
            reorderer: OnlineReorderer::fifo(),
            make_backend: Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>),
            opts: OnlineOpts::default(),
            faults: FaultConfig::default(),
            admission: Box::new(NoAdmission),
        }
    }

    /// Set the route policy.
    pub fn route(mut self, route: Box<dyn RoutePolicy>) -> Self {
        self.route = route;
        self
    }

    /// Set the route policy by registry spelling (`"jsq"`, `"lrw"`,
    /// `"p2c:<seed>"`, …).
    pub fn route_named(self, spelling: &str) -> Result<Self, ParseError> {
        let route = crate::registry::parse_route(spelling)?;
        Ok(self.route(route))
    }

    /// Set the per-device window-policy factory (each device gets its
    /// own instance, so stateful policies do not share state).
    pub fn window(mut self, make_window: Box<dyn Fn() -> Box<dyn WindowPolicy>>) -> Self {
        self.make_window = make_window;
        self
    }

    /// Set the window policy by registry spelling (`"fixed:<k>"`,
    /// `"linger:<k>:<ms>"`, `"adaptive:<k>:<ms>"`).
    pub fn window_named(self, spelling: &str) -> Result<Self, ParseError> {
        // Validate once at configuration time; the factory re-parses the
        // canonical spelling per device.
        let canonical = crate::registry::parse_window(spelling)?.name();
        Ok(self.window(Box::new(move || {
            parse_window_policy(&canonical).expect("canonical window names reparse")
        })))
    }

    /// Set the per-window reorder decision.
    pub fn reorderer(mut self, reorderer: OnlineReorderer) -> Self {
        self.reorderer = reorderer;
        self
    }

    /// Set the execution-backend factory (each device gets its own).
    pub fn backend(
        mut self,
        make_backend: Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync>,
    ) -> Self {
        self.make_backend = make_backend;
        self
    }

    /// Set the engine options (decision-cost model).
    pub fn opts(mut self, opts: OnlineOpts) -> Self {
        self.opts = opts;
        self
    }

    /// Set the full fault configuration (plan + retry policy).
    pub fn faults(mut self, faults: FaultConfig) -> Self {
        self.faults = faults;
        self
    }

    /// Set just the fault plan, keeping the default retry policy.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.faults.plan = plan;
        self
    }

    /// Set just the retry policy.
    pub fn retry(mut self, retry: RetryPolicy) -> Self {
        self.faults.retry = retry;
        self
    }

    /// Set the admission policy gating arrivals (default
    /// [`NoAdmission`], a strict engine no-op).
    pub fn admission(mut self, admission: Box<dyn AdmissionPolicy>) -> Self {
        self.admission = admission;
        self
    }

    /// Set the admission policy by registry spelling (`"none"`,
    /// `"bound:<q>"`, `"deadline:<slo_ms>"`,
    /// `"codel:<target_ms>:<interval_ms>"`).
    pub fn admission_named(self, spelling: &str) -> Result<Self, ParseError> {
        let admission = crate::registry::parse_admission(spelling)?;
        Ok(self.admission(admission))
    }

    /// Run the simulation — exactly
    /// [`simulate_fleet_with_admission`](crate::fleet::simulate_fleet_with_admission)
    /// with this config's pieces in positional order, so the two forms
    /// produce bit-identical reports (and, under the default
    /// [`NoAdmission`], bit-identical to
    /// [`simulate_fleet_with_faults`](crate::fleet::simulate_fleet_with_faults)).
    pub fn run(self) -> FleetReport {
        let FleetSimConfig {
            fleet,
            source,
            route,
            make_window,
            reorderer,
            make_backend,
            opts,
            faults,
            mut admission,
        } = self;
        simulate_fleet_with_admission(
            &fleet,
            source,
            route,
            make_window.as_ref(),
            &reorderer,
            make_backend.as_ref(),
            &opts,
            &faults,
            admission.as_mut(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuSpec;
    use crate::online::{ReplaySource, Trace};

    fn source(n: usize, seed: u64) -> Box<dyn ArrivalSource> {
        let gpu = GpuSpec::gtx580();
        let trace = Trace::poisson("skewed", n, 400.0, seed);
        Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap())
    }

    #[test]
    fn defaults_run_and_conserve_kernels() {
        let r = FleetSimConfig::new(FleetSpec::homogeneous(2), source(20, 5)).run();
        assert_eq!(r.kernels.len(), 20);
        assert_eq!(r.route, "roundrobin");
        assert_eq!(r.window, "fixed:8");
    }

    #[test]
    fn builder_run_bit_matches_the_positional_call() {
        let fleet = FleetSpec::parse("1,0.5").unwrap();
        let reorderer = OnlineReorderer::search("local:0", 200).unwrap();
        let faults = FaultConfig {
            plan: FaultPlan::parse("slowdown:1@50:2").unwrap(),
            retry: RetryPolicy::new(3, 1),
        };
        let built = FleetSimConfig::new(fleet.clone(), source(18, 9))
            .route_named("jsq")
            .unwrap()
            .window_named("linger:6:30")
            .unwrap()
            .reorderer(reorderer.clone())
            .opts(OnlineOpts::default())
            .faults(faults.clone())
            .run();
        let positional = simulate_fleet_with_faults(
            &fleet,
            source(18, 9),
            parse_route_policy("jsq").unwrap(),
            &|| parse_window_policy("linger:6:30").unwrap(),
            &reorderer,
            &|| Box::new(crate::exec::SimulatorBackend::new()) as Box<dyn ExecutionBackend>,
            &OnlineOpts::default(),
            &faults,
        );
        assert_eq!(built.kernels.len(), positional.kernels.len());
        assert_eq!(built.span_ms.to_bits(), positional.span_ms.to_bits());
        for (a, b) in built.kernels.iter().zip(positional.kernels.iter()) {
            assert_eq!(a.finish_ms.to_bits(), b.finish_ms.to_bits());
            assert_eq!(a.device, b.device);
        }
    }

    #[test]
    fn bad_spellings_surface_the_uniform_error() {
        let err = FleetSimConfig::new(FleetSpec::homogeneous(1), source(4, 1))
            .route_named("blorp")
            .unwrap_err();
        assert_eq!(err.kind, "route");
        assert!(err.to_string().contains("blorp"), "{err}");
        let err = FleetSimConfig::new(FleetSpec::homogeneous(1), source(4, 1))
            .window_named("blorp")
            .unwrap_err();
        assert_eq!(err.kind, "window");
        let err = FleetSimConfig::new(FleetSpec::homogeneous(1), source(4, 1))
            .admission_named("blorp")
            .unwrap_err();
        assert_eq!(err.kind, "admission");
    }

    #[test]
    fn admission_named_gates_arrivals_and_conserves() {
        let r = FleetSimConfig::new(FleetSpec::homogeneous(1), source(30, 5))
            .admission_named("bound:2")
            .unwrap()
            .run();
        assert_eq!(r.admission, "bound:2");
        assert_eq!(r.kernels.len() + r.shed.len(), 30);
        // The default config is ungated.
        let ungated = FleetSimConfig::new(FleetSpec::homogeneous(1), source(30, 5)).run();
        assert_eq!(ungated.admission, "none");
        assert!(ungated.shed.is_empty());
    }
}
