//! # kreorder — Reordering GPU Kernel Launches for Efficient Concurrent Execution
//!
//! Full-system reproduction of Li, Narayana & El-Ghazawi (2015):
//! *"Reordering GPU Kernel Launches to Enable Efficient Concurrent
//! Execution"*, on a three-layer Rust + JAX + Pallas stack.
//!
//! The paper observes that Fermi-class GPUs dispatch thread blocks strictly
//! in kernel launch order, so the **order** in which independent kernels are
//! issued determines how blocks pack onto streaming multiprocessors (SMs),
//! how balanced per-SM resource usage is (registers / shared memory / warps
//! / resident blocks), and whether compute-bound kernels overlap with
//! memory-bound ones. Its contribution is a greedy scheduler (Algorithm 1)
//! that derives a near-optimal launch order from static per-kernel profiles.
//!
//! This crate generalizes that single policy/single substrate design into
//! two pluggable seams:
//!
//! * [`sched::LaunchPolicy`] — *how to order* a batch. Algorithm 1 and
//!   the paper's baselines (FIFO / reverse / random) plus shortest-job-
//!   first and a Kernelet-style greedy co-schedule, all behind one trait
//!   with a string registry ([`sched::registry::parse`]).
//! * [`exec::ExecutionBackend`] — *how to run* an ordered batch. The
//!   event-driven fluid simulator, the paper's analytic round model, and
//!   (with `--features pjrt`) real PJRT execution of AOT-compiled HLO.
//!
//! The coordinator, the CLI, the benches and the experiment harness all
//! dispatch through these trait objects, so new policies and substrates
//! plug in without touching any of them.
//!
//! ## The sweep hot path: prepared workloads + prefix checkpoints
//!
//! The paper's methodology is an exhaustive sweep of all `n!` launch
//! orders, so evaluating *one order of a fixed workload* is the hot path
//! of the whole system. Two layers make it fast without changing any
//! result bit:
//!
//! * [`exec::ExecutionBackend::prepare`] returns an
//!   [`exec::PreparedWorkload`]: kernel constants, the jittered
//!   block-work table, validation and every scratch buffer are hoisted
//!   out of the per-order loop (the simulator's reusable state is
//!   [`sim::SimState`], with an explicit `reset()` instead of per-call
//!   construction). After warm-up, evaluating an order performs **no
//!   heap allocation** (pinned by `tests/zero_alloc.rs`).
//! * Model backends additionally support **prefix checkpointing**: the
//!   state at the instant a shared prefix's last block is dispatched is
//!   snapshotted once and restored per sibling suffix. [`perm::sweep`]
//!   enumerates suffixes as a lexicographic prefix tree to maximize that
//!   sharing, with results bit-identical to the naive per-permutation
//!   path (`tests/sweep_equivalence.rs` is the golden suite).
//! * On the same seam, [`exec::PrefixCursor`] makes **anytime search
//!   suffix-priced**: a depth-addressable checkpoint stack anchored
//!   along the incumbent lets every candidate move (swap / shift /
//!   insertion) re-simulate only past its first touched position,
//!   bit-identically to full evaluation
//!   (`tests/incremental_equivalence.rs` pins whole trajectories). A
//!   new backend implements the `checkpoint_*` methods once and gets
//!   fast sweeps, branch-and-bound pruning *and* fast anytime search
//!   for free.
//!
//! Workloads with repeated kernels get a second, orthogonal collapse:
//! [`gpu::KernelProfile::model_identical`] kernels are bit-interchangeable
//! in every model backend, so [`search::BranchAndBound`] expands one
//! class representative per tree node ([`gpu::equivalence_classes`];
//! `∏ m_c!` fewer subtrees, results still bit-identical to the sweep
//! including tie-breaks) and [`perm::sweep_stats_sym`] evaluates one
//! canonical order per orbit with multiplicity weighting.
//!
//! ## Sweeping large n: memory
//!
//! [`perm::SweepResult`] stores every permutation's makespan: `n! × 8`
//! bytes — fine through n = 10 (~29 MB), marginal at n = 11 (~320 MB),
//! prohibitive at n = 12 (~3.8 GB). [`perm::sweep_stats`] runs the same
//! checkpointed sweep in streaming mode: [`perm::SweepStats`] keeps
//! exact best/worst makespans *and orders*, count and mean, plus a
//! fixed-resolution histogram (default 4096 bins ≈ 32 KB) for percentile
//! ranks — constant memory in `n`, so n = 11–12 sweeps fit comfortably.
//! Histogram answers are approximate at bin resolution (best/worst stay
//! exact); the error bounds are documented and pinned on `SweepStats`.
//!
//! ## Beyond the factorial wall: the search seam
//!
//! Past n ≈ 12 no sweep variant helps — `12! ≈ 4.8 × 10⁸` evaluations —
//! yet real reorder windows hold dozens of kernels. [`search`] treats
//! order selection as a search problem over the *same* prepared /
//! checkpointed evaluation engine, behind the [`search::SearchStrategy`]
//! trait with its own string registry ([`search::parse_strategy`]).
//! Choosing a tool:
//!
//! * **n ≤ ~10, want the full distribution** (percentile ranks, Table 3
//!   columns) → [`perm::sweep`]; n = 11–12 → [`perm::sweep_stats`].
//! * **n ≈ 8–20, want the provable optimum only** →
//!   [`search::BranchAndBound`] (`"bnb"`): the sweep's prefix tree plus
//!   admissible fluid-model bounds
//!   ([`exec::PreparedWorkload::suffix_lower_bound`]); bit-identical
//!   best makespan *and* tie-broken best order to the exhaustive sweep.
//! * **larger n, or a latency cap** → anytime strategies
//!   (`"anneal:<seed>"`, `"local:<seed>"`) under a [`search::SearchBudget`];
//!   the incumbent trajectory is reproducible from `(seed, evals)`.
//! * **in the serving path** → the `search[:<strategy>[:<budget>]]`
//!   launch policy ([`search::SearchPolicy`]): exact for small windows,
//!   budgeted anytime search for large ones.
//! * **online, kernels still arriving** → the [`online`] subsystem (see
//!   below and `src/search/README.md` for the full online-vs-offline
//!   decision guide).
//!
//! ## The dependency model: DAG workloads
//!
//! The paper's sweep assumes the kernels are mutually *independent* —
//! any of the `n!` launch orders is legal. Real inference and training
//! graphs are not: a kernel may consume another's output, so only the
//! *linear extensions* of a precedence DAG are launchable. The
//! [`workloads::Workload`] type carries kernels plus optional
//! `(pred, succ)` edges (builder spellings
//! [`workloads::Workload::with_dep`] / `with_chain`, CSV round-trip via
//! [`workloads::parse_deps`] / `deps_to_csv`), validated into a
//! [`workloads::DepGraph`] — cycles, self-loops and out-of-range edges
//! are rejected with actionable errors. Every layer above understands
//! it:
//!
//! * [`perm::sweep_dag`] / [`perm::sweep_stats_dag_with`] enumerate
//!   **only topological orders** (the same lexicographic prefix tree,
//!   skipping infeasible prefixes) — bit-identical to filtering the
//!   naive sweep, and often *far* smaller: a chain has one extension,
//!   not `n!`.
//! * Every [`search::SearchStrategy`] has a
//!   [`search::SearchStrategy::search_dag`] entry point:
//!   branch-and-bound prunes to topological prefixes with its symmetry
//!   collapse refined by dependency signature, and the anytime
//!   strategies propose feasibility-checked moves (infeasible proposals
//!   are charged but never simulated) — all bit-identical to the
//!   constrained sweep where exhaustion is covered, and bit-identical
//!   to their independent-workload behavior when `deps` is empty.
//! * The online layer takes a within-window dependency template
//!   ([`online::OnlineReorderer::with_deps`]); template edges point
//!   forward in arrival order so FIFO stays feasible and the never-
//!   worse-than-FIFO guard is unchanged.
//! * DAG-shaped scenario families ([`workloads::DAG_SCENARIOS`]:
//!   `chain`, `fanout`, `fanin`, `layered`, `mlinfer`) mirror the
//!   independent families for benches and the CLI (`--deps`, DAG
//!   spellings in `kreorder search`).
//!
//! ## Online: when ordering competes with time
//!
//! Everything above assumes the batch is in hand. The [`online`] module
//! is the streaming regime — launch requests arrive over time and every
//! queued kernel pays latency while its reorder window stays open:
//!
//! * seeded **arrival processes** ([`online::ArrivalSpec`]: `poisson`,
//!   `bursty`, closed-loop, `replay` of a recorded [`online::Trace`])
//!   draw kernels from the [`workloads`] scenario families;
//! * pluggable [`online::WindowPolicy`] implementations decide *when* a
//!   window closes (`fixed:<k>`, `linger:<k>:<ms>` — the latency-SLO
//!   bound — and occupancy-aware `adaptive:<k>:<ms>`); the thread
//!   coordinator's dispatcher batches through the **same trait**
//!   ([`coordinator::CoordinatorBuilder::window_policy`]), with its
//!   linger clock injectable ([`coordinator::BatchClock`]) so batching
//!   is deterministic under test;
//! * an [`online::OnlineReorderer`] picks each window's order inside a
//!   per-decision [`search::SearchBudget`] — exhaustive when the budget
//!   provably covers `n!`, any registered anytime strategy beyond,
//!   never worse than FIFO;
//! * [`online::simulate_online`] runs it all on a **virtual clock**
//!   (discrete-event, no wall sleeping): per-kernel queue-wait /
//!   service / sojourn times are bit-identical per (arrival seed,
//!   strategy seed, window policy) — `tests/online_determinism.rs` pins
//!   replay, and `benches/online_latency.rs` gates reordered-vs-FIFO
//!   p99 sojourn per arrival regime into `BENCH_online.json`, with the
//!   clairvoyant [`online::offline_oracle`] pricing what onlineness
//!   cost.
//!
//! ## Fleet: when *which device* competes with *what order*
//!
//! The [`fleet`] subsystem scales the online layer out to D (possibly
//! heterogeneous) devices, each running its own window + reorder loop:
//!
//! * a [`fleet::RoutePolicy`] registry (`roundrobin`, `jsq`, `lrw`,
//!   `p2c:<seed>`, `affinity`) decides which device every arriving
//!   kernel joins — `lrw` prices each device's backlog with the
//!   backend's admissible [`exec::PreparedWorkload::suffix_lower_bound`]
//!   and `affinity` co-locates model-identical kernels so the search
//!   layer's symmetry collapse keeps paying;
//! * a [`fleet::FleetSpec`] describes the devices, heterogeneity as
//!   per-device speed factors (`--devices 1,1,0.5`);
//! * [`fleet::simulate_fleet`] extends the virtual-clock loop to D
//!   devices (routing decision < completion < batch start < arrival <
//!   recheck at equal times) with the same bit-identical-replay
//!   contract (`tests/fleet_determinism.rs`), and the
//!   [`fleet::FleetReport`] rolls up per-device utilization/imbalance
//!   plus fleet-wide sojourn percentiles against the clairvoyant
//!   [`fleet::fleet_lower_bound`];
//! * the live thread coordinator routes through the same trait
//!   ([`coordinator::CoordinatorBuilder::route_policy`]), and
//!   `benches/fleet_routing.rs` hard-gates routed-vs-`roundrobin` p99
//!   sojourn into `BENCH_fleet.json`.
//!
//! ## Fault tolerance: when devices crash, straggle, or drop launches
//!
//! The [`fault`] module makes failure a *deterministic input* instead of
//! an accident: a [`fault::FaultPlan`] scripts device crashes (with
//! optional recovery), slowdown stragglers and seeded per-launch
//! failures, parsed from a spec string (`crash:0@50;slowdown:2@10:2.5;
//! launchfail:0.05:7`) or drawn from a seeded generator, and
//! [`fleet::simulate_fleet_with_faults`] threads it through the fleet
//! engine as a first-class event kind (faults fire *before* routing at
//! equal times):
//!
//! * a **crash** retracts the device's in-flight batch and re-routes
//!   every orphaned kernel through the live [`fleet::RoutePolicy`] —
//!   [`fleet::DeviceLoad`] carries a [`fleet::Health`] state, so the
//!   load-aware policies steer around `Down` devices and the
//!   `circuit:<inner>` wrapper ([`fleet::Circuit`]) trips per-device
//!   breakers on repeated launch failures;
//! * **launch failures** retry under a [`fault::RetryPolicy`] — seeded
//!   exponential backoff with jitter, a max-attempts cap, and every
//!   capped kernel recorded as a [`fleet::ShedRecord`] with its cause
//!   (the conservation invariant `completed + shed == arrivals` is
//!   pinned by `tests/fault_recovery.rs`);
//! * **degraded decisions** — windows on slowed devices, or searches
//!   whose budget ran out before beating FIFO — fall back to FIFO order
//!   and are counted (`n_degraded_decisions`) rather than hidden;
//! * the whole run stays **bit-identical** per (fault plan, fault seed,
//!   arrival seed, config): backoff and failure draws are pure functions
//!   of `(seed, kernel id, attempt)`, an empty plan is a strict no-op
//!   (the `D = 1` run bit-matches [`online::simulate_online`]), and
//!   `benches/fault_tolerance.rs` gates health-aware rerouting against
//!   a health-blind baseline into `BENCH_faults.json`;
//! * the live [`coordinator`] gets the same posture: a panicking device
//!   worker fails only its own in-flight batch (failure-sentinel
//!   responses, panic message surfaced in
//!   [`coordinator::ServiceStats`]), and its queue re-routes to live
//!   workers instead of poisoning shutdown.
//!
//! ## Overload protection: admission control and the degradation ladder
//!
//! Fault tolerance handles a *broken* fleet; the [`admission`] module
//! handles a *drowning* one — offered load beyond what reordering can
//! absorb. Degradation is an explicit three-rung ladder, each rung
//! counted, never silent:
//!
//! 1. **budgeted reorder** — the normal regime: windows close, search
//!    runs under its budget;
//! 2. **FIFO passthrough** — decisions that cannot beat FIFO in budget
//!    fall back and are counted (`n_degraded_decisions`);
//! 3. **admission shed** — an [`admission::AdmissionPolicy`] gate in
//!    front of the queue refuses arrivals outright: `bound:<q>` (hard
//!    occupancy cap), `deadline:<slo_ms>` (shed when the admissible
//!    [`exec::PreparedWorkload::suffix_lower_bound`]-priced sojourn
//!    predicts an SLO violation), `codel:<target>:<interval>` (CoDel:
//!    drop only *standing* queues). Rejections are first-class
//!    [`online::ShedRecord`]s with [`online::ShedCause::Rejected`]
//!    (closed-loop sources are notified, so they never starve), and
//!    `admitted + rejected + shed == arrivals` holds everywhere
//!    (`tests/overload_protection.rs`).
//!
//! All three layers share the gate: [`online::simulate_online_with_admission`]
//! and [`fleet::simulate_fleet_with_admission`] gate arrivals at the
//! virtual clock (with `admission=none` a strict bit-identical no-op),
//! and the live [`coordinator`] ingests submissions through a lock-free
//! [`coordinator::IngestQueue`] whose in-flight depth feeds
//! [`coordinator::Coordinator::try_submit`] — explicit
//! [`coordinator::BackpressureError`]s instead of unbounded queueing.
//! `benches/overload.rs` drives 1.5x and 3x overload and hard-gates
//! conservation, deadline-admitted p99 ≤ SLO at sustained goodput, and
//! the `none`-vs-`bound` queue-growth pathology into
//! `BENCH_overload.json`.
//!
//! ## Migration: the fleet entry point and the unified registries
//!
//! Two API consolidations, both backward compatible:
//!
//! * [`fleet::FleetSimConfig`] is the **preferred** way to run a fleet
//!   simulation. The positional
//!   [`fleet::simulate_fleet_with_faults`] (eight arguments) and its
//!   [`fleet::simulate_fleet`] / [`online::simulate_online`] thin
//!   wrappers keep working unchanged — the builder calls the same
//!   engine argument-for-argument, so reports are bit-identical — but
//!   new call sites should use the builder: defaults for the five
//!   pieces almost everyone leaves alone, named setters for the rest,
//!   and uniform [`registry::ParseError`]s from the `*_named` setters.
//! * [`registry`] is the uniform front door over the eight string
//!   registries (policy / strategy / route / window / arrivals /
//!   fault-plan / admission / trace): one [`registry::ParseError`] carrying the kind, the
//!   echoed input and that kind's cheat sheet, plus
//!   [`registry::kinds`] / [`registry::list`] backing the
//!   `kreorder list [--kind <k>]` subcommand. The per-subsystem
//!   parsers and their typed errors remain the sources of truth.
//!
//! ## Observability: typed trace events across every layer
//!
//! Reports say *what* happened; the [`obs`] subsystem records *why*.
//! Every execution layer — the online engine
//! ([`online::simulate_online_traced`]), the fleet engine with its
//! fault/admission variants ([`fleet::simulate_fleet_traced`]), and the
//! live thread coordinator
//! ([`coordinator::CoordinatorBuilder::trace_sink`], wall-clock
//! stamped) — emits typed [`obs::TraceEvent`]s at each decision point:
//! arrival, admission verdict (with the priced bound), window
//! close/wait (with occupancy), reorder decision (strategy, evals,
//! FIFO-guard outcome, chosen-vs-FIFO makespan), route choice (with the
//! per-device load snapshot), batch start/finish, fault, retry, shed
//! and worker panic; anytime-search incumbent trajectories down-sample
//! into the same stream ([`obs::trajectory_events`]). A
//! [`obs::TraceSink`] receives them — `none` (strict no-op), `ring:<cap>`
//! (bounded in-memory) or `jsonl:<path>` — the eighth [`registry`]
//! kind. The safety contract mirrors `admission=none`: under the
//! [`obs::NoTrace`] sink every engine is **bit-identical and
//! allocation-free** versus the untraced entry points (which literally
//! delegate through the traced ones), and under `ring`/`jsonl` the
//! event stream itself is bit-deterministic per (seed, config) — pinned
//! by `tests/trace_observability.rs`. [`obs::export`] renders streams
//! as Chrome trace-event JSON (per-device lanes, crash-clipped batch
//! spans; loads in `chrome://tracing` / Perfetto, structurally checked
//! by [`obs::export::validate_chrome_trace`]) and folds them into a
//! deterministic [`obs::Counters`] snapshot; the CLI surfaces both as
//! `--trace FILE[:SINK]` on `serve` / `fleet` / `fault` / `search` and
//! `kreorder trace inspect FILE`.
//!
//! CI enforces the quality contract (`benches/search_quality.rs`,
//! smoke-run per push): branch-and-bound must bit-match the sweep on
//! every scenario family at n ≤ 8 on both model backends, each anytime
//! strategy at a 10 k-evaluation budget must beat the 90th percentile
//! of the n = 10 sweep distribution, and cursor-evaluated strategies
//! must produce bit-identical outcomes to full evaluation (with their
//! evals/s ratio recorded as the anytime-throughput trajectory);
//! `BENCH_search.json` / `BENCH_sweep.json` are uploaded as artifacts,
//! checkpointed sweep throughput is hard-gated against the committed
//! `BENCH_baseline.json`, and the anytime-throughput floors warn until
//! calibrated (tolerances documented in `.github/workflows/ci.yml`).
//!
//! ## Crate layout
//!
//! | module | role |
//! |---|---|
//! | [`gpu`] | GPU & kernel parameter model (Table 1 of the paper) |
//! | [`sim`] | event-driven concurrent-execution simulator (the hardware substrate) |
//! | [`sched`] | [`sched::LaunchPolicy`] trait, Algorithm 1 + baselines, string registry |
//! | [`exec`] | [`exec::ExecutionBackend`] trait: simulator / analytic / PJRT substrates |
//! | [`perm`] | permutation-space sweeps, checkpointed + streaming (Table 3 / Fig. 1) |
//! | [`search`] | [`search::SearchStrategy`]: exact branch-and-bound + anytime metaheuristics for n ≫ 12 |
//! | [`online`] | streaming scheduler: arrival processes, [`online::WindowPolicy`], virtual-clock engine, latency SLOs |
//! | [`fleet`] | multi-device dispatch: [`fleet::RoutePolicy`] registry, heterogeneous [`fleet::FleetSpec`], fleet-scale virtual-clock engine |
//! | [`fault`] | deterministic fault injection: [`fault::FaultPlan`] (crash / slowdown / launch-failure scripts), seeded [`fault::RetryPolicy`], recovery accounting |
//! | [`admission`] | overload protection: [`admission::AdmissionPolicy`] registry (`bound` / `deadline` / `codel`), shed accounting, coordinator backpressure |
//! | [`obs`] | structured tracing: [`obs::TraceSink`] registry (`none` / `ring` / `jsonl`), typed [`obs::TraceEvent`]s, Chrome trace export + [`obs::Counters`] |
//! | [`profile`] | artifact profile loading (the "CUDA profiler" stand-in) |
//! | `runtime` | PJRT execution of AOT-compiled HLO kernels (feature `pjrt`) |
//! | [`coordinator`] | [`coordinator::CoordinatorBuilder`]: batching + reordering + multi-device dispatch |
//! | [`workloads`] | the paper's six experiments (Table 2) + synthetic generators + named scenario families |
//! | [`metrics`] | percentiles, histograms, report tables |
//!
//! ## Quickstart
//!
//! ```no_run
//! use kreorder::exec::{ExecutionBackend, SimulatorBackend};
//! use kreorder::gpu::GpuSpec;
//! use kreorder::sched::registry;
//! use kreorder::workloads;
//!
//! let gpu = GpuSpec::gtx580();
//! let kernels = workloads::epbsessw_8();
//!
//! // Pick a policy by name (any registry spelling works: "fifo",
//! // "random:42", "algorithm1", "sjf", "coschedule", …).
//! let policy = registry::parse("algorithm1").unwrap();
//! let order = policy.order(&gpu, &kernels);
//!
//! // Time it on an execution backend.
//! let mut backend = SimulatorBackend::new();
//! let t = backend.execute(&gpu, &kernels, &order).makespan_ms;
//! println!("{} makespan: {t:.2} ms", policy.name());
//! ```
//!
//! ## Writing your own policy or backend
//!
//! A policy is one `impl`; it immediately works everywhere a registry
//! policy does (pass it to [`coordinator::CoordinatorBuilder::policy`],
//! compare it in the benches, …). Same for a backend:
//!
//! ```
//! use kreorder::exec::{BackendReport, ExecutionBackend, KernelOutcome};
//! use kreorder::gpu::{GpuSpec, KernelProfile};
//! use kreorder::sched::LaunchPolicy;
//!
//! /// Launch the widest (most warps per block) kernels first.
//! struct WidestFirst;
//!
//! impl LaunchPolicy for WidestFirst {
//!     fn name(&self) -> String {
//!         "widest-first".into()
//!     }
//!     fn order(&self, _gpu: &GpuSpec, kernels: &[KernelProfile]) -> Vec<usize> {
//!         let mut idx: Vec<usize> = (0..kernels.len()).collect();
//!         idx.sort_by_key(|&i| std::cmp::Reverse(kernels[i].warps_per_block));
//!         idx
//!     }
//! }
//!
//! /// A backend that "runs" each kernel in zero time (dry-run probe).
//! struct NullBackend;
//!
//! impl ExecutionBackend for NullBackend {
//!     fn name(&self) -> &str {
//!         "null"
//!     }
//!     fn execute(
//!         &mut self,
//!         _gpu: &GpuSpec,
//!         _kernels: &[KernelProfile],
//!         order: &[usize],
//!     ) -> BackendReport {
//!         let outcomes = order
//!             .iter()
//!             .enumerate()
//!             .map(|(position, &index)| KernelOutcome {
//!                 index,
//!                 position,
//!                 checksum: f64::NAN,
//!                 wall_ms: 0.0,
//!                 finish_ms: 0.0,
//!                 failed: false,
//!             })
//!             .collect();
//!         BackendReport {
//!             backend: "null".into(),
//!             makespan_ms: 0.0,
//!             wall_ms: 0.0,
//!             outcomes,
//!         }
//!     }
//! }
//!
//! let gpu = GpuSpec::gtx580();
//! let kernels = kreorder::workloads::epbsessw_8();
//! let order = WidestFirst.order(&gpu, &kernels);
//! let report = NullBackend.execute(&gpu, &kernels, &order);
//! assert_eq!(report.outcomes.len(), kernels.len());
//! ```

pub mod admission;
pub mod coordinator;
pub mod exec;
pub mod fault;
pub mod fleet;
pub mod gpu;
pub mod metrics;
pub mod obs;
pub mod online;
pub mod perm;
pub mod profile;
pub mod registry;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sched;
pub mod search;
pub mod sim;
pub mod util;
pub mod workloads;
