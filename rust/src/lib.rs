//! # kreorder — Reordering GPU Kernel Launches for Efficient Concurrent Execution
//!
//! Full-system reproduction of Li, Narayana & El-Ghazawi (2015):
//! *"Reordering GPU Kernel Launches to Enable Efficient Concurrent
//! Execution"*, on a three-layer Rust + JAX + Pallas stack.
//!
//! The paper observes that Fermi-class GPUs dispatch thread blocks strictly
//! in kernel launch order, so the **order** in which independent kernels are
//! issued determines how blocks pack onto streaming multiprocessors (SMs),
//! how balanced per-SM resource usage is (registers / shared memory / warps
//! / resident blocks), and whether compute-bound kernels overlap with
//! memory-bound ones. Its contribution is a greedy scheduler (Algorithm 1)
//! that derives a near-optimal launch order from static per-kernel profiles.
//!
//! ## Crate layout
//!
//! | module | role |
//! |---|---|
//! | [`gpu`] | GPU & kernel parameter model (Table 1 of the paper) |
//! | [`sim`] | event-driven concurrent-execution simulator (the hardware substrate) |
//! | [`sched`] | Algorithm 1 + baseline launch-order policies |
//! | [`perm`] | permutation-space sweeps (Table 3 / Fig. 1 evaluation) |
//! | [`profile`] | artifact profile loading (the "CUDA profiler" stand-in) |
//! | [`runtime`] | PJRT execution of AOT-compiled HLO kernels |
//! | [`coordinator`] | the deployable launch coordinator (batching + reordering service) |
//! | [`workloads`] | the paper's six experiments (Table 2) + synthetic generators |
//! | [`metrics`] | percentiles, histograms, report tables |
//!
//! ## Quickstart
//!
//! ```no_run
//! use kreorder::{gpu::GpuSpec, sched, sim, workloads};
//!
//! let gpu = GpuSpec::gtx580();
//! let kernels = workloads::epbsessw_8();
//! let order = sched::reorder(&gpu, &kernels);
//! let t = sim::simulate_order(&gpu, &kernels, &order.order).makespan_ms;
//! println!("reordered makespan: {t:.2} ms");
//! ```

pub mod coordinator;
pub mod gpu;
pub mod metrics;
pub mod perm;
pub mod profile;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod util;
pub mod workloads;
