//! GPU and kernel parameter model — Table 1 of the paper.
//!
//! The first three rows of Table 1 are GPU constants ([`GpuSpec`]); the
//! remainder are per-kernel quantities obtained from a profiling pass
//! ([`KernelProfile`]). Resource arithmetic is factored into
//! [`ResourceVec`] so occupancy math, the scheduler's fit tests, and the
//! simulator all share one implementation.

mod resources;
mod spec;

pub use resources::ResourceVec;
pub use spec::GpuSpec;

/// Which benchmark application a kernel instance comes from.
///
/// The paper uses NPB EP (memory-bound, R=3.11), BlackScholes
/// (compute-bound, R=11.1), VMD Electrostatics and Smith-Waterman.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AppKind {
    Ep,
    BlackScholes,
    Electrostatics,
    SmithWaterman,
    /// Synthetic / generated kernels (workload generator, tests).
    Synthetic,
}

impl AppKind {
    /// Short display tag, matching the paper's experiment names.
    pub fn tag(&self) -> &'static str {
        match self {
            AppKind::Ep => "EP",
            AppKind::BlackScholes => "BS",
            AppKind::Electrostatics => "ES",
            AppKind::SmithWaterman => "SW",
            AppKind::Synthetic => "SYN",
        }
    }
}

/// Static profile of one kernel launch — the per-kernel rows of Table 1.
///
/// `regs_per_block`, `shmem_per_block` and `warps_per_block` are *per thread
/// block*; the paper's per-kernel aggregates (`N_reg_i`, `N_shm_i`,
/// `N_warp_i`) are the per-SM footprints these induce when the grid spreads
/// round-robin over the SMs — see [`KernelProfile::per_sm_footprint`].
#[derive(Debug, Clone, PartialEq)]
pub struct KernelProfile {
    /// Human-readable instance name, e.g. `"EP#3(shm=24K)"`.
    pub name: String,
    /// Source application.
    pub app: AppKind,
    /// Grid size: number of thread blocks (`N_tblk_i`).
    pub n_blocks: u32,
    /// Registers consumed by one block (threads/block × regs/thread).
    pub regs_per_block: u32,
    /// Shared-memory bytes consumed by one block.
    pub shmem_per_block: u32,
    /// Warps per block (threads/block ÷ 32).
    pub warps_per_block: u32,
    /// Instructions/bytes ratio `R_i` from the profiler.
    pub ratio: f64,
    /// Compute work per block, in abstract instruction units. Sets the
    /// kernel's standalone runtime in the simulator.
    pub work_per_block: f64,
    /// Which AOT artifact executes this kernel's real payload (empty for
    /// purely simulated kernels).
    pub artifact: String,
}

impl KernelProfile {
    /// Memory traffic per block implied by the instruction/byte ratio:
    /// `R_i = instructions / bytes` ⇒ `bytes = instructions / R_i`.
    pub fn mem_per_block(&self) -> f64 {
        if self.ratio <= 0.0 {
            0.0
        } else {
            self.work_per_block / self.ratio
        }
    }

    /// Total compute work of the whole grid.
    pub fn total_work(&self) -> f64 {
        self.work_per_block * self.n_blocks as f64
    }

    /// Total memory traffic of the whole grid.
    pub fn total_mem(&self) -> f64 {
        self.mem_per_block() * self.n_blocks as f64
    }

    /// Resource demand of a single block.
    pub fn block_resources(&self) -> ResourceVec {
        ResourceVec {
            regs: self.regs_per_block as f64,
            shmem: self.shmem_per_block as f64,
            warps: self.warps_per_block as f64,
            blocks: 1.0,
        }
    }

    /// The paper's per-kernel aggregate (`N_reg_i`, `N_shm_i`, `N_warp_i`):
    /// the footprint this kernel leaves **on one SM** when its grid is
    /// distributed round-robin over `gpu.n_sm` multiprocessors.
    ///
    /// E.g. EP with grid 32 on a 16-SM GPU places 2 blocks per SM, so its
    /// per-SM warp footprint is `2 × warps_per_block`.
    pub fn per_sm_footprint(&self, gpu: &GpuSpec) -> ResourceVec {
        let blocks_per_sm = (self.n_blocks as f64 / gpu.n_sm as f64).ceil();
        self.block_resources() * blocks_per_sm
    }

    /// Can a single block of this kernel ever fit on an SM of `gpu`?
    pub fn block_fits(&self, gpu: &GpuSpec) -> bool {
        self.block_resources().fits_within(&gpu.sm_capacity())
    }

    /// Max resident blocks of this kernel alone on one SM (classic CUDA
    /// occupancy calculation: the binding resource decides).
    pub fn max_blocks_per_sm(&self, gpu: &GpuSpec) -> u32 {
        let cap = gpu.sm_capacity();
        let b = self.block_resources();
        let mut m = gpu.blocks_per_sm;
        if b.regs > 0.0 {
            m = m.min((cap.regs / b.regs) as u32);
        }
        if b.shmem > 0.0 {
            m = m.min((cap.shmem / b.shmem) as u32);
        }
        if b.warps > 0.0 {
            m = m.min((cap.warps / b.warps) as u32);
        }
        m
    }

    /// Is this kernel memory-bound relative to the GPU's balanced ratio?
    pub fn memory_bound(&self, gpu: &GpuSpec) -> bool {
        self.ratio < gpu.balanced_ratio
    }

    /// Are two kernels **model-identical** — interchangeable in every
    /// timing model and payload?
    ///
    /// True when every execution-relevant field matches exactly (floats
    /// compared by bits): grid size, per-block resources, ratio, work,
    /// source app and payload artifact. `name` is display-only and
    /// excluded. Both model backends time a kernel solely from these
    /// fields (per-block jitter depends on the block index only, never on
    /// the kernel — see `sim::engine`), so swapping two model-identical
    /// kernels in a launch order leaves the makespan **bit-identical**.
    /// This is the contract behind the symmetry collapse in
    /// [`crate::search::BranchAndBound`] and
    /// [`crate::perm::sweep_stats_sym`].
    pub fn model_identical(&self, other: &KernelProfile) -> bool {
        self.app == other.app
            && self.n_blocks == other.n_blocks
            && self.regs_per_block == other.regs_per_block
            && self.shmem_per_block == other.shmem_per_block
            && self.warps_per_block == other.warps_per_block
            && self.ratio.to_bits() == other.ratio.to_bits()
            && self.work_per_block.to_bits() == other.work_per_block.to_bits()
            && self.artifact == other.artifact
    }
}

/// Partition a workload into [`KernelProfile::model_identical`]
/// equivalence classes: `class_of[i]` is the smallest index whose profile
/// is model-identical to `kernels[i]` (so a kernel with no duplicate maps
/// to itself). O(n²) exact-field comparisons — the workloads this serves
/// (search windows, sweeps) hold at most a few dozen kernels.
pub fn equivalence_classes(kernels: &[KernelProfile]) -> Vec<usize> {
    (0..kernels.len())
        .map(|i| {
            (0..i)
                .find(|&j| kernels[j].model_identical(&kernels[i]))
                .unwrap_or(i)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ep() -> KernelProfile {
        KernelProfile {
            name: "EP".into(),
            app: AppKind::Ep,
            n_blocks: 32,
            regs_per_block: 2560,
            shmem_per_block: 8192,
            warps_per_block: 4,
            ratio: 3.11,
            work_per_block: 1000.0,
            artifact: String::new(),
        }
    }

    #[test]
    fn mem_per_block_from_ratio() {
        let k = ep();
        assert!((k.mem_per_block() - 1000.0 / 3.11).abs() < 1e-9);
        assert!((k.total_mem() - 32.0 * 1000.0 / 3.11).abs() < 1e-6);
    }

    #[test]
    fn zero_ratio_means_no_memory() {
        let mut k = ep();
        k.ratio = 0.0;
        assert_eq!(k.mem_per_block(), 0.0);
    }

    #[test]
    fn per_sm_footprint_round_robin() {
        let gpu = GpuSpec::gtx580();
        let k = ep(); // 32 blocks on 16 SMs -> 2 blocks/SM
        let f = k.per_sm_footprint(&gpu);
        assert_eq!(f.warps, 8.0);
        assert_eq!(f.shmem, 16384.0);
        assert_eq!(f.blocks, 2.0);
    }

    #[test]
    fn per_sm_footprint_rounds_up() {
        let gpu = GpuSpec::gtx580();
        let mut k = ep();
        k.n_blocks = 17; // 17 blocks on 16 SMs -> ceil = 2 per SM
        assert_eq!(k.per_sm_footprint(&gpu).blocks, 2.0);
    }

    #[test]
    fn occupancy_limited_by_shmem() {
        let gpu = GpuSpec::gtx580();
        let mut k = ep();
        k.shmem_per_block = 24 * 1024; // 48K/24K = 2 blocks
        assert_eq!(k.max_blocks_per_sm(&gpu), 2);
    }

    #[test]
    fn occupancy_limited_by_warps() {
        let gpu = GpuSpec::gtx580();
        let mut k = ep();
        k.shmem_per_block = 0;
        k.warps_per_block = 24; // 48/24 = 2
        assert_eq!(k.max_blocks_per_sm(&gpu), 2);
    }

    #[test]
    fn occupancy_limited_by_block_slots() {
        let gpu = GpuSpec::gtx580();
        let mut k = ep();
        k.shmem_per_block = 0;
        k.regs_per_block = 1;
        k.warps_per_block = 1;
        assert_eq!(k.max_blocks_per_sm(&gpu), gpu.blocks_per_sm);
    }

    #[test]
    fn memory_bound_classification() {
        let gpu = GpuSpec::gtx580();
        let mut k = ep();
        assert!(k.memory_bound(&gpu)); // 3.11 < 4.11
        k.ratio = 11.1;
        assert!(!k.memory_bound(&gpu));
    }

    #[test]
    fn oversized_block_does_not_fit(){
        let gpu = GpuSpec::gtx580();
        let mut k = ep();
        k.shmem_per_block = 49 * 1024;
        assert!(!k.block_fits(&gpu));
    }

    #[test]
    fn model_identity_ignores_name_only() {
        let a = ep();
        let mut b = ep();
        b.name = "EP(renamed)".into();
        assert!(a.model_identical(&b), "name must not split classes");
        // Every execution-relevant field splits the class.
        for mutate in [
            (|k: &mut KernelProfile| k.n_blocks += 1) as fn(&mut KernelProfile),
            |k| k.regs_per_block += 1,
            |k| k.shmem_per_block += 1,
            |k| k.warps_per_block += 1,
            |k| k.ratio += 1e-12,
            |k| k.work_per_block += 1e-9,
            |k| k.artifact = "other".into(),
            |k| k.app = AppKind::Synthetic,
        ] {
            let mut c = ep();
            mutate(&mut c);
            assert!(!a.model_identical(&c));
        }
    }

    #[test]
    fn equivalence_classes_map_to_smallest_duplicate() {
        let a = ep();
        let mut b = ep();
        b.name = "EP#2".into(); // same class as a despite the name
        let mut c = ep();
        c.ratio = 9.0; // its own class
        let ks = vec![a.clone(), c.clone(), b, a, c];
        assert_eq!(equivalence_classes(&ks), vec![0, 1, 0, 0, 1]);
        // All-distinct workload: identity mapping.
        let mut d = ep();
        d.n_blocks = 7;
        assert_eq!(equivalence_classes(&[ep(), d]), vec![0, 1]);
        assert_eq!(equivalence_classes(&[]), Vec::<usize>::new());
    }
}
