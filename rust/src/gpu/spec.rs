//! GPU hardware constants — the first three rows of the paper's Table 1,
//! plus the fluid-timing calibration constants used by the simulator.

use super::ResourceVec;

/// Architectural description of the simulated GPU.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    /// `N_SM` — number of streaming multiprocessors.
    pub n_sm: u32,
    /// `N_reg_SM` — registers per SM.
    pub regs_per_sm: u32,
    /// `N_shm_SM` — shared-memory bytes per SM.
    pub shmem_per_sm: u32,
    /// `N_warp_SM` — max resident warps per SM.
    pub warps_per_sm: u32,
    /// `N_blk_SM` — max resident blocks per SM.
    pub blocks_per_sm: u32,
    /// `R_B` — the balanced instructions/bytes ratio for this GPU.
    pub balanced_ratio: f64,
    /// Peak per-SM compute throughput, abstract instruction units per ms,
    /// reached when at least [`GpuSpec::warps_to_saturate`] warps are
    /// resident (below that, latency is not hidden and throughput scales
    /// with warp count).
    pub compute_rate_per_sm: f64,
    /// Warps needed to saturate one SM's issue pipeline. On Fermi the
    /// full warp complement is needed to hide DRAM latency, which is why
    /// launch orders that strand SMs at low occupancy are so expensive.
    pub warps_to_saturate: u32,
    /// Relative per-block execution-time variation (branch divergence,
    /// DRAM row locality, …): block work is scaled by a deterministic
    /// per-(kernel, block) factor in `1 ± block_jitter`. This is what
    /// makes the permutation-time distribution continuous, as measured on
    /// hardware, rather than collapsing into a handful of round-count
    /// ties.
    pub block_jitter: f64,
}

impl GpuSpec {
    /// The paper's experimental platform: NVIDIA GTX580
    /// (16 SMs, R_B = 4.11, 32K regs, 48 warps, 48 KiB shmem, 8 blocks).
    pub fn gtx580() -> Self {
        GpuSpec {
            n_sm: 16,
            regs_per_sm: 32 * 1024,
            shmem_per_sm: 48 * 1024,
            warps_per_sm: 48,
            blocks_per_sm: 8,
            balanced_ratio: 4.11,
            // Calibrated so the simulated EpBs-6 optimum lands near the
            // paper's ~100 ms scale (see workloads::tests and
            // EXPERIMENTS.md). All Table-3 comparisons are scale-free.
            compute_rate_per_sm: 1000.0,
            // ~16 resident warps hide ALU/issue latency on Fermi; this is
            // also the value that makes the paper's cross-experiment
            // timings mutually consistent (EP ≈ 35 ms inside EP-6-shm's
            // low-occupancy rounds vs ≈ 100 ms inside EpBs-6's fully
            // packed rounds — exactly the paper's optima).
            warps_to_saturate: 16,
            block_jitter: 0.10,
        }
    }

    /// The same machine with deterministic timing (no per-block jitter):
    /// used by tests that assert exact makespans.
    pub fn deterministic(mut self) -> Self {
        self.block_jitter = 0.0;
        self
    }

    /// Resource capacity of a single SM.
    pub fn sm_capacity(&self) -> ResourceVec {
        ResourceVec {
            regs: self.regs_per_sm as f64,
            shmem: self.shmem_per_sm as f64,
            warps: self.warps_per_sm as f64,
            blocks: self.blocks_per_sm as f64,
        }
    }

    /// Aggregate GPU-wide compute throughput (instruction units / ms).
    pub fn peak_compute(&self) -> f64 {
        self.compute_rate_per_sm * self.n_sm as f64
    }

    /// Global memory bandwidth in bytes/ms, derived from the balanced
    /// ratio: a kernel with `R_i = R_B` at full occupancy is exactly
    /// compute- and bandwidth-limited at the same time.
    pub fn memory_bandwidth(&self) -> f64 {
        self.peak_compute() / self.balanced_ratio
    }

    /// A lower bound on the makespan of any schedule of the given total
    /// compute work and memory traffic: no order can beat peak rates.
    pub fn makespan_lower_bound(&self, total_work: f64, total_mem: f64) -> f64 {
        (total_work / self.peak_compute()).max(total_mem / self.memory_bandwidth())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gtx580_constants_match_paper() {
        let g = GpuSpec::gtx580();
        assert_eq!(g.n_sm, 16);
        assert_eq!(g.regs_per_sm, 32768);
        assert_eq!(g.shmem_per_sm, 49152);
        assert_eq!(g.warps_per_sm, 48);
        assert_eq!(g.blocks_per_sm, 8);
        assert!((g.balanced_ratio - 4.11).abs() < 1e-12);
    }

    #[test]
    fn bandwidth_balances_at_rb() {
        let g = GpuSpec::gtx580();
        // total_work / peak == total_mem / bandwidth when work/mem == R_B.
        let work = 1.0e6;
        let mem = work / g.balanced_ratio;
        let t_c = work / g.peak_compute();
        let t_m = mem / g.memory_bandwidth();
        assert!((t_c - t_m).abs() < 1e-9);
    }

    #[test]
    fn lower_bound_is_max_of_both_limits() {
        let g = GpuSpec::gtx580();
        let lb = g.makespan_lower_bound(1.0e6, 1.0);
        assert!((lb - 1.0e6 / g.peak_compute()).abs() < 1e-12);
        let lb2 = g.makespan_lower_bound(1.0, 1.0e6);
        assert!((lb2 - 1.0e6 / g.memory_bandwidth()).abs() < 1e-12);
    }
}
