//! Four-dimensional SM resource vectors: registers, shared memory, warps,
//! resident-block slots. One shared implementation of the arithmetic used by
//! occupancy math, the scheduler's fit tests, and the simulator's
//! per-SM accounting.

use std::ops::{Add, AddAssign, Mul, Sub, SubAssign};

/// A point in SM resource space.
///
/// Stored as `f64` because the scheduler treats combined profiles as
/// continuous quantities (fractions of capacity) — see Algorithm 1's
/// normalized leftover terms.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ResourceVec {
    pub regs: f64,
    pub shmem: f64,
    pub warps: f64,
    pub blocks: f64,
}

impl ResourceVec {
    pub const ZERO: ResourceVec = ResourceVec {
        regs: 0.0,
        shmem: 0.0,
        warps: 0.0,
        blocks: 0.0,
    };

    /// `self` fits inside `cap` on every dimension (with a tiny epsilon so
    /// exact-capacity packs — the common case in the paper's experiments —
    /// are accepted despite float arithmetic).
    pub fn fits_within(&self, cap: &ResourceVec) -> bool {
        const EPS: f64 = 1e-9;
        self.regs <= cap.regs + EPS
            && self.shmem <= cap.shmem + EPS
            && self.warps <= cap.warps + EPS
            && self.blocks <= cap.blocks + EPS
    }

    /// Component-wise max.
    pub fn max(&self, other: &ResourceVec) -> ResourceVec {
        ResourceVec {
            regs: self.regs.max(other.regs),
            shmem: self.shmem.max(other.shmem),
            warps: self.warps.max(other.warps),
            blocks: self.blocks.max(other.blocks),
        }
    }

    /// Largest utilization fraction across dimensions, `self` relative to
    /// `cap`: the *binding* resource. 1.0 = some resource exhausted.
    pub fn max_utilization(&self, cap: &ResourceVec) -> f64 {
        let mut u: f64 = 0.0;
        if cap.regs > 0.0 {
            u = u.max(self.regs / cap.regs);
        }
        if cap.shmem > 0.0 {
            u = u.max(self.shmem / cap.shmem);
        }
        if cap.warps > 0.0 {
            u = u.max(self.warps / cap.warps);
        }
        if cap.blocks > 0.0 {
            u = u.max(self.blocks / cap.blocks);
        }
        u
    }

    /// All components are ≥ 0 (used by debug assertions in the simulator).
    pub fn non_negative(&self) -> bool {
        const EPS: f64 = -1e-9;
        self.regs >= EPS && self.shmem >= EPS && self.warps >= EPS && self.blocks >= EPS
    }
}

impl Add for ResourceVec {
    type Output = ResourceVec;
    fn add(self, o: ResourceVec) -> ResourceVec {
        ResourceVec {
            regs: self.regs + o.regs,
            shmem: self.shmem + o.shmem,
            warps: self.warps + o.warps,
            blocks: self.blocks + o.blocks,
        }
    }
}

impl AddAssign for ResourceVec {
    fn add_assign(&mut self, o: ResourceVec) {
        *self = *self + o;
    }
}

impl Sub for ResourceVec {
    type Output = ResourceVec;
    fn sub(self, o: ResourceVec) -> ResourceVec {
        ResourceVec {
            regs: self.regs - o.regs,
            shmem: self.shmem - o.shmem,
            warps: self.warps - o.warps,
            blocks: self.blocks - o.blocks,
        }
    }
}

impl SubAssign for ResourceVec {
    fn sub_assign(&mut self, o: ResourceVec) {
        *self = *self - o;
    }
}

impl Mul<f64> for ResourceVec {
    type Output = ResourceVec;
    fn mul(self, s: f64) -> ResourceVec {
        ResourceVec {
            regs: self.regs * s,
            shmem: self.shmem * s,
            warps: self.warps * s,
            blocks: self.blocks * s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(regs: f64, shmem: f64, warps: f64, blocks: f64) -> ResourceVec {
        ResourceVec {
            regs,
            shmem,
            warps,
            blocks,
        }
    }

    #[test]
    fn add_sub_roundtrip() {
        let a = v(1.0, 2.0, 3.0, 4.0);
        let b = v(0.5, 0.5, 0.5, 0.5);
        assert_eq!(a + b - b, a);
    }

    #[test]
    fn fits_within_each_dimension_binds() {
        let cap = v(10.0, 10.0, 10.0, 10.0);
        assert!(v(10.0, 10.0, 10.0, 10.0).fits_within(&cap));
        assert!(!v(10.1, 0.0, 0.0, 0.0).fits_within(&cap));
        assert!(!v(0.0, 10.1, 0.0, 0.0).fits_within(&cap));
        assert!(!v(0.0, 0.0, 10.1, 0.0).fits_within(&cap));
        assert!(!v(0.0, 0.0, 0.0, 10.1).fits_within(&cap));
    }

    #[test]
    fn fits_within_tolerates_float_noise() {
        let cap = v(48.0, 48.0, 48.0, 8.0);
        let x = v(16.0, 16.0, 16.0, 2.0) + v(32.0, 32.0, 32.0, 6.0);
        assert!(x.fits_within(&cap));
    }

    #[test]
    fn max_utilization_picks_binding_resource() {
        let cap = v(100.0, 100.0, 100.0, 10.0);
        let x = v(50.0, 80.0, 20.0, 1.0);
        assert!((x.max_utilization(&cap) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn max_utilization_ignores_zero_capacity() {
        let cap = v(100.0, 0.0, 0.0, 0.0);
        assert_eq!(v(25.0, 5.0, 5.0, 5.0).max_utilization(&cap), 0.25);
    }

    #[test]
    fn scale() {
        assert_eq!(v(1.0, 2.0, 3.0, 4.0) * 2.0, v(2.0, 4.0, 6.0, 8.0));
    }

    #[test]
    fn componentwise_max() {
        let a = v(1.0, 5.0, 2.0, 8.0);
        let b = v(3.0, 1.0, 4.0, 6.0);
        assert_eq!(a.max(&b), v(3.0, 5.0, 4.0, 8.0));
    }
}
