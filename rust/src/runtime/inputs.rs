//! Deterministic input synthesis for AOT artifacts.
//!
//! Conventions (mirrors `python/compile/model.py` — keep in sync):
//!
//! * `uint32[n]` inputs are seed-offset index vectors: `seed + arange(n)`.
//!   (EP seeds, BlackScholes option indices, ES point/atom seeds — the
//!   graphs hash these in-graph, so the u32 stream fully determines the
//!   numerics.)
//! * `int32[...]` inputs are token-id tensors: SplitMix64 stream mod 4
//!   (the Smith-Waterman alphabet).

use crate::profile::InputSpec;
use crate::util::SplitMix64;
use anyhow::{bail, Result};

/// Build one literal per input spec.
pub fn synthesize_inputs(specs: &[InputSpec], seed: u64) -> Result<Vec<xla::Literal>> {
    let mut out = Vec::with_capacity(specs.len());
    for (arg_idx, spec) in specs.iter().enumerate() {
        // Each argument gets a decorrelated stream.
        let arg_seed = seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(arg_idx as u64 + 1));
        out.push(synthesize_one(spec, arg_seed)?);
    }
    Ok(out)
}

fn synthesize_one(spec: &InputSpec, seed: u64) -> Result<xla::Literal> {
    let n = spec.numel();
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    let lit = match spec.dtype.as_str() {
        "uint32" => {
            let base = (seed & 0xFFFF_FFFF) as u32;
            let data: Vec<u32> = (0..n as u32).map(|i| base.wrapping_add(i)).collect();
            xla::Literal::vec1(&data)
        }
        "int32" => {
            let mut rng = SplitMix64::new(seed);
            let data: Vec<i32> = (0..n).map(|_| (rng.next_u32() % 4) as i32).collect();
            xla::Literal::vec1(&data)
        }
        "float32" => {
            let mut rng = SplitMix64::new(seed);
            let data: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32).collect();
            xla::Literal::vec1(&data)
        }
        other => bail!("unsupported input dtype `{other}`"),
    };
    if spec.shape.len() == 1 {
        Ok(lit)
    } else {
        Ok(lit.reshape(&dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(shape: &[usize], dtype: &str) -> InputSpec {
        InputSpec {
            shape: shape.to_vec(),
            dtype: dtype.into(),
        }
    }

    #[test]
    fn u32_is_seeded_arange() {
        let l = synthesize_one(&spec(&[8], "uint32"), 100).unwrap();
        let v = l.to_vec::<u32>().unwrap();
        assert_eq!(v, (100u32..108).collect::<Vec<_>>());
    }

    #[test]
    fn i32_tokens_in_alphabet() {
        let l = synthesize_one(&spec(&[4, 6], "int32"), 7).unwrap();
        let v = l.to_vec::<i32>().unwrap();
        assert_eq!(v.len(), 24);
        assert!(v.iter().all(|&t| (0..4).contains(&t)));
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = synthesize_one(&spec(&[16], "int32"), 1).unwrap();
        let b = synthesize_one(&spec(&[16], "int32"), 1).unwrap();
        let c = synthesize_one(&spec(&[16], "int32"), 2).unwrap();
        assert_eq!(a.to_vec::<i32>().unwrap(), b.to_vec::<i32>().unwrap());
        assert_ne!(a.to_vec::<i32>().unwrap(), c.to_vec::<i32>().unwrap());
    }

    #[test]
    fn shape_is_respected() {
        let l = synthesize_one(&spec(&[3, 5], "uint32"), 0).unwrap();
        let shape = l.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3, 5]);
    }

    #[test]
    fn unknown_dtype_rejected() {
        assert!(synthesize_one(&spec(&[4], "complex64"), 0).is_err());
    }

    #[test]
    fn per_argument_streams_differ() {
        let ls = synthesize_inputs(&[spec(&[8], "int32"), spec(&[8], "int32")], 5).unwrap();
        assert_ne!(
            ls[0].to_vec::<i32>().unwrap(),
            ls[1].to_vec::<i32>().unwrap()
        );
    }
}
