//! PJRT runtime — loads AOT-compiled HLO artifacts and executes them on
//! the CPU PJRT client from the Rust request path (Python is never
//! involved at runtime; see DESIGN.md §3).
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits `HloModuleProto`s with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see `/opt/xla-example/README.md`).

mod inputs;

pub use inputs::synthesize_inputs;

use crate::profile::ArtifactStore;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Output of one kernel execution.
#[derive(Debug, Clone)]
pub struct ExecutionOutput {
    /// Flattened f32 view of each output leaf (our kernels all produce
    /// f32 leaves; lowering uses `return_tuple=True`).
    pub outputs: Vec<Vec<f32>>,
    /// Wall-clock execution time on the CPU PJRT client.
    pub wall_ms: f64,
}

impl ExecutionOutput {
    /// A small stable fingerprint of the numeric output (sum of leaves),
    /// used by integration tests and the serving example's sanity checks.
    pub fn checksum(&self) -> f64 {
        self.outputs
            .iter()
            .map(|leaf| leaf.iter().map(|&x| x as f64).sum::<f64>())
            .sum()
    }
}

/// A PJRT client plus a cache of compiled executables, keyed by variant
/// name. Compilation happens once per variant (at first use or via
/// [`Runtime::preload`]); execution is cheap thereafter.
pub struct Runtime {
    client: xla::PjRtClient,
    store: ArtifactStore,
    // Mutex (not RwLock): compilation is rare, execution takes &self on
    // the executable handle which is not Sync-shareable across the C API.
    executables: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Create a CPU PJRT runtime over an artifact store.
    pub fn new(store: ArtifactStore) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            store,
            executables: Mutex::new(HashMap::new()),
        })
    }

    /// Convenience: load the default artifacts directory.
    pub fn from_default_artifacts() -> Result<Self> {
        Runtime::new(ArtifactStore::load(ArtifactStore::default_dir())?)
    }

    /// PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The artifact store backing this runtime.
    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }

    /// Compile a variant ahead of time (no-op if already cached).
    pub fn preload(&self, variant: &str) -> Result<()> {
        self.ensure_compiled(variant)
    }

    /// Compile every variant in the manifest.
    pub fn preload_all(&self) -> Result<()> {
        for name in self.store.variant_names() {
            self.preload(&name)?;
        }
        Ok(())
    }

    fn ensure_compiled(&self, variant: &str) -> Result<()> {
        {
            let cache = self.executables.lock().unwrap();
            if cache.contains_key(variant) {
                return Ok(());
            }
        }
        let path = self.store.hlo_path(variant)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling variant `{variant}`"))?;
        self.executables.lock().unwrap().insert(variant.to_string(), exe);
        Ok(())
    }

    /// Execute a variant with deterministic inputs derived from `seed`.
    ///
    /// Inputs are synthesized from the manifest's shape/dtype specs using
    /// the same conventions as `python/compile/model.py`, so numerics are
    /// reproducible given (variant, seed).
    pub fn execute(&self, variant: &str, seed: u64) -> Result<ExecutionOutput> {
        self.ensure_compiled(variant)?;
        let entry = self.store.variant(variant)?;
        let literals = synthesize_inputs(&entry.inputs, seed)?;

        let t0 = Instant::now();
        let cache = self.executables.lock().unwrap();
        let exe = cache.get(variant).expect("compiled above");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing `{variant}`"))?;
        let root = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        drop(cache);

        // Lowered with return_tuple=True: the root is always a tuple.
        let leaves = root.to_tuple().context("decomposing result tuple")?;
        let mut outputs = Vec::with_capacity(leaves.len());
        for leaf in leaves {
            outputs.push(leaf.to_vec::<f32>().context("reading f32 leaf")?);
        }
        Ok(ExecutionOutput { outputs, wall_ms })
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("platform", &self.platform())
            .field("artifacts", &self.store.dir)
            .finish()
    }
}
