//! Exact branch-and-bound over the checkpointed prefix tree.
//!
//! The solver walks the *same* lexicographic prefix tree as
//! [`crate::perm::sweep`]'s checkpointed mode — one
//! [`PreparedWorkload::checkpoint_push`] per internal node, the last two
//! positions completed directly from the parent checkpoint — but before
//! descending into a node it asks the backend for an admissible lower
//! bound on every completion of that prefix
//! ([`PreparedWorkload::suffix_lower_bound`]) and prunes the subtree when
//! the bound exceeds the shared incumbent.
//!
//! # Exactness and determinism
//!
//! * Pruning requires `bound > incumbent · (1 + ε)` with ε = 1e-9: a
//!   pruned subtree therefore contains no makespan below **or equal to**
//!   the final optimum (the margin absorbs last-ulp rounding in bound
//!   arithmetic), so the optimum *and* the full set of its bit-exact ties
//!   are always visited. Merging per-task results with the sweep's
//!   lexicographic tie-break then yields a result bit-identical to
//!   exhaustive [`crate::perm::sweep`] — same `best_ms`, same
//!   `best_order` — regardless of thread timing.
//! * Evaluations are spread over the sweep's `n·(n-1)` first-two-position
//!   prefix tasks via the work-stealing pool; the incumbent is a shared
//!   atomic so a bound proven in one task prunes every other.
//! * Under an exhausted [`SearchBudget`] the result degrades to a best
//!   incumbent (`complete = false`); how far each task got then depends
//!   on scheduling, so only unbudgeted runs are bit-reproducible.
//!
//! The warm start is Algorithm 1's order: the paper shows it lands above
//! the 90th percentile, so the very first bound checks already prune
//! against a near-optimal incumbent.

use super::{improves, BackendFactory, IncumbentSample, SearchBudget, SearchOutcome, SearchStrategy};
use crate::exec::PreparedWorkload;
use crate::gpu::{GpuSpec, KernelProfile};
use crate::perm::position_prefixes;
use crate::sched::reorder;
use crate::util::{default_threads, parallel_map};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Relative pruning margin: a subtree is cut only when its bound exceeds
/// the incumbent by more than this factor, so ulp-level rounding in the
/// bound arithmetic can never discard a bit-exact tie of the optimum.
const PRUNE_MARGIN: f64 = 1e-9;

/// Trees up to this size run as ONE sequential task (single backend,
/// single prepared handle, no thread pool): at ≤ 6! + 1 evaluations the
/// n·(n-1)-task parallel split would spend more on thread spawn/join and
/// per-task `prepare` than on the search itself — this is the
/// coordinator's per-batch path, where that overhead dominates. Results
/// are identical either way (same tree, same tie-breaks).
const SEQUENTIAL_MAX_N: usize = 6;

/// Exact branch-and-bound launch-order solver (registry spelling
/// `"bnb"`). See the module docs for the exactness argument.
#[derive(Debug, Clone, Copy, Default)]
pub struct BranchAndBound;

/// Shared monotone-minimum incumbent (f64 bits in an `AtomicU64`).
struct SharedIncumbent(AtomicU64);

impl SharedIncumbent {
    fn new(initial: f64) -> Self {
        let v = if initial.is_nan() {
            f64::INFINITY
        } else {
            initial
        };
        SharedIncumbent(AtomicU64::new(v.to_bits()))
    }

    fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    fn offer(&self, t: f64) {
        if t.is_nan() {
            return;
        }
        let mut cur = self.0.load(Ordering::Relaxed);
        while t < f64::from_bits(cur) {
            match self
                .0
                .compare_exchange_weak(cur, t.to_bits(), Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }
}

/// Per-task accumulator, merged with the sweep's lexicographic
/// tie-breaks.
struct Partial {
    best_ms: f64,
    best_order: Vec<usize>,
    evals: u64,
    pruned: u64,
    stopped: bool,
}

impl Partial {
    fn new() -> Self {
        Partial {
            best_ms: f64::INFINITY,
            best_order: Vec::new(),
            evals: 0,
            pruned: 0,
            stopped: false,
        }
    }

    #[inline]
    fn record(&mut self, t: f64, order: &[usize], incumbent: &SharedIncumbent) {
        self.evals += 1;
        if improves(t, order, self.best_ms, &self.best_order) {
            self.best_ms = t;
            self.best_order.clear();
            self.best_order.extend_from_slice(order);
        }
        incumbent.offer(t);
    }
}

/// Budget shared by every task.
struct Limits {
    evals: AtomicU64,
    max_evals: u64,
    deadline: Option<Instant>,
}

impl Limits {
    /// Claim one evaluation; `false` once the budget is spent.
    #[inline]
    fn claim(&self) -> bool {
        if self.evals.fetch_add(1, Ordering::Relaxed) >= self.max_evals {
            return false;
        }
        if let Some(d) = self.deadline {
            if Instant::now() >= d {
                return false;
            }
        }
        true
    }
}

impl SearchStrategy for BranchAndBound {
    fn name(&self) -> String {
        "bnb".into()
    }

    fn search(
        &self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        make_backend: &BackendFactory,
        budget: &SearchBudget,
    ) -> SearchOutcome {
        let t_start = Instant::now();
        let n = kernels.len();
        assert!(n >= 1, "empty workload");

        // Warm start: Algorithm 1's order seeds the incumbent.
        let seed_order = reorder(gpu, kernels).order;
        let seed_ms = {
            let mut b = make_backend();
            b.prepare(gpu, kernels).execute_order(&seed_order)
        };
        let mut trajectory = vec![IncumbentSample {
            eval: 1,
            best_ms: seed_ms,
        }];
        if seed_ms.is_nan() {
            // Unsimulable workload: nothing to search.
            return SearchOutcome {
                strategy: self.name(),
                best_ms: f64::NAN,
                best_order: seed_order,
                evals: 1,
                complete: false,
                trajectory,
                pruned_subtrees: 0,
                wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
            };
        }

        let incumbent = SharedIncumbent::new(seed_ms);
        let limits = Limits {
            evals: AtomicU64::new(1), // the warm start spent one
            max_evals: budget.max_evals.unwrap_or(u64::MAX),
            deadline: budget.max_wall.map(|d| t_start + d),
        };

        // One empty-prefix task (sequential, shared nothing) for small
        // trees; the sweep's first-two-position split beyond.
        let prefixes = if n <= SEQUENTIAL_MAX_N {
            vec![Vec::new()]
        } else {
            position_prefixes(n)
        };
        let partials: Vec<Partial> = parallel_map(prefixes.len(), default_threads(), |pi| {
            let mut backend = make_backend();
            let mut p = Partial::new();
            bnb_task(
                gpu,
                kernels,
                backend.as_mut(),
                &prefixes[pi],
                &incumbent,
                &limits,
                &mut p,
            );
            p
        });

        let mut best_ms = seed_ms;
        let mut best_order = seed_order;
        let mut pruned = 0u64;
        let mut stopped = false;
        // Evaluations actually performed: the warm start plus each
        // task's exact count. (The shared claim counter also ticks for
        // *denied* claims — e.g. every task hitting a wall deadline — so
        // it over-reports and is used for budget decisions only.)
        let mut evals = 1u64;
        for p in partials {
            pruned += p.pruned;
            stopped |= p.stopped;
            evals += p.evals;
            if improves(p.best_ms, &p.best_order, best_ms, &best_order) {
                best_ms = p.best_ms;
                best_order = p.best_order;
            }
        }
        if best_ms < trajectory[0].best_ms {
            trajectory.push(IncumbentSample { eval: evals, best_ms });
        }
        SearchOutcome {
            strategy: self.name(),
            best_ms,
            best_order,
            evals,
            complete: !stopped,
            trajectory,
            pruned_subtrees: pruned,
            wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
        }
    }
}

/// Solve one first-two-position prefix task.
fn bnb_task(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    backend: &mut dyn crate::exec::ExecutionBackend,
    prefix: &[usize],
    incumbent: &SharedIncumbent,
    limits: &Limits,
    out: &mut Partial,
) {
    let n = kernels.len();
    let mut prepared = backend.prepare(gpu, kernels);
    let mut order: Vec<usize> = Vec::with_capacity(n);
    order.extend_from_slice(prefix);

    if !prepared.supports_checkpoints() {
        // No checkpoints ⇒ no bounds either (`suffix_lower_bound` needs a
        // prefix state): degrade to flat enumeration of this task's
        // suffixes with incumbent tracking only.
        let mut rest: Vec<usize> = (0..n).filter(|i| !prefix.contains(i)).collect();
        if rest.is_empty() {
            if limits.claim() {
                let t = prepared.execute_order(&order);
                out.record(t, &order, incumbent);
            } else {
                out.stopped = true;
            }
            return;
        }
        let plen = prefix.len();
        // `for_each_permutation` cannot early-exit; skip the evaluation
        // once the budget is gone (enumeration itself is cheap).
        crate::perm::for_each_permutation(&mut rest, &mut |suffix| {
            if out.stopped {
                return;
            }
            if !limits.claim() {
                out.stopped = true;
                return;
            }
            order.truncate(plen);
            order.extend_from_slice(suffix);
            let t = prepared.execute_order(&order);
            out.record(t, &order, incumbent);
        });
        return;
    }

    let mut used = vec![false; n];
    for &k in prefix {
        prepared.checkpoint_push(k);
        used[k] = true;
    }
    let mut remaining_buf: Vec<usize> = Vec::with_capacity(n);
    dfs(
        prepared.as_mut(),
        &mut used,
        &mut order,
        &mut remaining_buf,
        n,
        incumbent,
        limits,
        out,
    );
    for _ in prefix {
        prepared.checkpoint_pop();
    }
}

/// Depth-first descent: the caller has pushed checkpoints for every
/// kernel in `order`.
#[allow(clippy::too_many_arguments)]
fn dfs(
    prepared: &mut dyn PreparedWorkload,
    used: &mut [bool],
    order: &mut Vec<usize>,
    remaining_buf: &mut Vec<usize>,
    n: usize,
    incumbent: &SharedIncumbent,
    limits: &Limits,
    out: &mut Partial,
) {
    if out.stopped {
        return;
    }
    match n - order.len() {
        0 => {
            if !limits.claim() {
                out.stopped = true;
                return;
            }
            let t = prepared.execute_suffix(&[]);
            out.record(t, order, incumbent);
        }
        1 => {
            if !limits.claim() {
                out.stopped = true;
                return;
            }
            let k = used.iter().position(|u| !u).expect("one kernel left");
            order.push(k);
            let t = prepared.execute_suffix(&order[n - 1..]);
            out.record(t, order, incumbent);
            order.pop();
        }
        2 => {
            let a = used.iter().position(|u| !u).expect("two kernels left");
            let b = used[a + 1..]
                .iter()
                .position(|u| !u)
                .map(|i| a + 1 + i)
                .expect("two kernels left");
            for (x, y) in [(a, b), (b, a)] {
                if !limits.claim() {
                    out.stopped = true;
                    return;
                }
                order.push(x);
                order.push(y);
                let t = prepared.execute_suffix(&order[n - 2..]);
                out.record(t, order, incumbent);
                order.pop();
                order.pop();
            }
        }
        _ => {
            // Bound check before descending: prune when no completion of
            // this prefix can beat (or bit-exactly tie) the incumbent.
            remaining_buf.clear();
            remaining_buf.extend((0..n).filter(|&k| !used[k]));
            let lb = prepared.suffix_lower_bound(remaining_buf);
            if lb > incumbent.get() * (1.0 + PRUNE_MARGIN) {
                out.pruned += 1;
                return;
            }
            for k in 0..n {
                if used[k] {
                    continue;
                }
                used[k] = true;
                order.push(k);
                prepared.checkpoint_push(k);
                dfs(
                    prepared,
                    used,
                    order,
                    remaining_buf,
                    n,
                    incumbent,
                    limits,
                    out,
                );
                prepared.checkpoint_pop();
                order.pop();
                used[k] = false;
                if out.stopped {
                    return;
                }
            }
        }
    }
}
