//! Exact branch-and-bound over the checkpointed prefix tree.
//!
//! The solver walks the *same* lexicographic prefix tree as
//! [`crate::perm::sweep`]'s checkpointed mode — one
//! [`PreparedWorkload::checkpoint_push`] per internal node, the last two
//! positions completed directly from the parent checkpoint — but before
//! descending into a node it asks the backend for an admissible lower
//! bound on every completion of that prefix
//! ([`PreparedWorkload::suffix_lower_bound`]) and prunes the subtree when
//! the bound exceeds the shared incumbent.
//!
//! # Exactness and determinism
//!
//! * Pruning requires `bound > incumbent · (1 + ε)` with ε = 1e-9: a
//!   pruned subtree therefore contains no makespan below **or equal to**
//!   the final optimum (the margin absorbs last-ulp rounding in bound
//!   arithmetic), so the optimum *and* the full set of its bit-exact ties
//!   are always visited. Merging per-task results with the sweep's
//!   lexicographic tie-break then yields a result bit-identical to
//!   exhaustive [`crate::perm::sweep`] — same `best_ms`, same
//!   `best_order` — regardless of thread timing.
//! * Evaluations are spread over the sweep's `n·(n-1)` first-two-position
//!   prefix tasks via the work-stealing pool; the incumbent is a shared
//!   atomic so a bound proven in one task prunes every other.
//! * Under an exhausted [`SearchBudget`] the result degrades to a best
//!   incumbent (`complete = false`); how far each task got then depends
//!   on scheduling, so only unbudgeted runs are bit-reproducible.
//!
//! # Identical-kernel symmetry collapse
//!
//! Real kernel graphs repeat kernels: an ACS-style app submits many
//! instances of the same profiled kernel, and every within-class
//! reordering of [`crate::gpu::KernelProfile::model_identical`] kernels
//! yields a **bit-identical** makespan (per-block jitter depends on the
//! block index only). The solver therefore expands, at every tree node,
//! only the *smallest unused index of each equivalence class*
//! ([`crate::gpu::equivalence_classes`]) — enumerating exactly the
//! orders whose class members appear in ascending index order. The
//! sweep's lexicographically tie-broken optimum is such an order (any
//! tied optimum with class members out of order has a smaller in-order
//! twin with the same bits), so results stay bit-identical to the
//! exhaustive sweep while the tree shrinks by `∏ m_c!` for class sizes
//! `m_c` — a factorial factor per duplicated kernel. Disable with
//! [`BranchAndBound::without_symmetry`] for exotic substrates whose
//! timing depends on more than the profile fields (both model backends
//! honor the contract; `tests/incremental_equivalence.rs` pins
//! with == without).
//!
//! The warm start is Algorithm 1's order: the paper shows it lands above
//! the 90th percentile, so the very first bound checks already prune
//! against a near-optimal incumbent.
//!
//! # Dependency-aware search
//!
//! [`SearchStrategy::search_dag`] restricts the same tree to
//! topological orders of the workload's precedence DAG: infeasible
//! kernels are skipped per node ([`crate::workloads::DepGraph::is_free`]
//! — an entire subtree gone before any bound is computed), the symmetry
//! collapse merges only kernels with identical dependency *signatures*
//! (pred/succ masks) on top of model identity, and the warm start is
//! repaired to feasibility. `suffix_lower_bound` stays admissible
//! unchanged: a bound over all completions lower-bounds the topological
//! subset. Unbudgeted results are bit-identical to
//! [`crate::perm::sweep_dag_with`].

use super::{improves, BackendFactory, IncumbentSample, SearchBudget, SearchOutcome, SearchStrategy};
use crate::exec::PreparedWorkload;
use crate::gpu::{equivalence_classes, GpuSpec, KernelProfile};
use crate::perm::{canonical_prefix, class_blocked, position_prefixes};
use crate::sched::reorder;
use crate::util::{default_threads, parallel_map};
use crate::workloads::{DepGraph, Workload};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Relative pruning margin: a subtree is cut only when its bound exceeds
/// the incumbent by more than this factor, so ulp-level rounding in the
/// bound arithmetic can never discard a bit-exact tie of the optimum.
const PRUNE_MARGIN: f64 = 1e-9;

/// Trees up to this size run as ONE sequential task (single backend,
/// single prepared handle, no thread pool): at ≤ 6! + 1 evaluations the
/// n·(n-1)-task parallel split would spend more on thread spawn/join and
/// per-task `prepare` than on the search itself — this is the
/// coordinator's per-batch path, where that overhead dominates. Results
/// are identical either way (same tree, same tie-breaks).
const SEQUENTIAL_MAX_N: usize = 6;

/// Exact branch-and-bound launch-order solver (registry spelling
/// `"bnb"`). See the module docs for the exactness argument and the
/// identical-kernel symmetry collapse.
#[derive(Debug, Clone, Copy)]
pub struct BranchAndBound {
    /// Expand one representative per [`crate::gpu::equivalence_classes`]
    /// class per node (default `true`; results are bit-identical either
    /// way, the collapse only shrinks the tree).
    pub symmetry: bool,
}

impl BranchAndBound {
    pub fn new() -> Self {
        BranchAndBound { symmetry: true }
    }

    /// The solver with the identical-kernel collapse disabled — the
    /// full-enumeration reference of the equivalence pins and of
    /// `kreorder search --compare-eval`, and an escape hatch for
    /// substrates whose timing depends on more than the profile fields.
    pub fn without_symmetry() -> Self {
        BranchAndBound { symmetry: false }
    }
}

impl Default for BranchAndBound {
    fn default() -> Self {
        BranchAndBound::new()
    }
}

/// Shared monotone-minimum incumbent (f64 bits in an `AtomicU64`).
struct SharedIncumbent(AtomicU64);

impl SharedIncumbent {
    fn new(initial: f64) -> Self {
        let v = if initial.is_nan() {
            f64::INFINITY
        } else {
            initial
        };
        SharedIncumbent(AtomicU64::new(v.to_bits()))
    }

    fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    fn offer(&self, t: f64) {
        if t.is_nan() {
            return;
        }
        let mut cur = self.0.load(Ordering::Relaxed);
        while t < f64::from_bits(cur) {
            match self
                .0
                .compare_exchange_weak(cur, t.to_bits(), Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }
}

/// Per-task accumulator, merged with the sweep's lexicographic
/// tie-breaks.
struct Partial {
    best_ms: f64,
    best_order: Vec<usize>,
    evals: u64,
    pruned: u64,
    stopped: bool,
}

impl Partial {
    fn new() -> Self {
        Partial {
            best_ms: f64::INFINITY,
            best_order: Vec::new(),
            evals: 0,
            pruned: 0,
            stopped: false,
        }
    }

    #[inline]
    fn record(&mut self, t: f64, order: &[usize], incumbent: &SharedIncumbent) {
        self.evals += 1;
        if improves(t, order, self.best_ms, &self.best_order) {
            self.best_ms = t;
            self.best_order.clear();
            self.best_order.extend_from_slice(order);
        }
        incumbent.offer(t);
    }
}

/// Budget shared by every task.
struct Limits {
    evals: AtomicU64,
    max_evals: u64,
    deadline: Option<Instant>,
}

impl Limits {
    /// Claim one evaluation; `false` once the budget is spent.
    #[inline]
    fn claim(&self) -> bool {
        if self.evals.fetch_add(1, Ordering::Relaxed) >= self.max_evals {
            return false;
        }
        if let Some(d) = self.deadline {
            if Instant::now() >= d {
                return false;
            }
        }
        true
    }
}

impl SearchStrategy for BranchAndBound {
    fn name(&self) -> String {
        "bnb".into()
    }

    fn search(
        &self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        make_backend: &BackendFactory,
        budget: &SearchBudget,
    ) -> SearchOutcome {
        let t_start = Instant::now();
        let n = kernels.len();
        assert!(n >= 1, "empty workload");

        // Warm start: Algorithm 1's order seeds the incumbent.
        let seed_order = reorder(gpu, kernels).order;
        let seed_ms = {
            let mut b = make_backend();
            b.prepare(gpu, kernels).execute_order(&seed_order)
        };
        let mut trajectory = vec![IncumbentSample {
            eval: 1,
            best_ms: seed_ms,
        }];
        if seed_ms.is_nan() {
            // Unsimulable workload: nothing to search.
            return SearchOutcome {
                strategy: self.name(),
                best_ms: f64::NAN,
                best_order: seed_order,
                evals: 1,
                complete: false,
                trajectory,
                pruned_subtrees: 0,
                wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
            };
        }

        let incumbent = SharedIncumbent::new(seed_ms);
        let limits = Limits {
            evals: AtomicU64::new(1), // the warm start spent one
            max_evals: budget.max_evals.unwrap_or(u64::MAX),
            deadline: budget.max_wall.map(|d| t_start + d),
        };

        // Identical-kernel collapse: expand one representative per class
        // per node (the no-checkpoint fallback filters canonically
        // instead). `None` disables the collapse everywhere.
        let class_of = if self.symmetry {
            Some(equivalence_classes(kernels))
        } else {
            None
        };
        let classes = class_of.as_deref();

        // One empty-prefix task (sequential, shared nothing) for small
        // trees; the sweep's first-two-position split beyond — with the
        // non-canonical prefixes (a duplicate kernel ahead of a
        // smaller-indexed class sibling) dropped entirely.
        let mut prefixes = if n <= SEQUENTIAL_MAX_N {
            vec![Vec::new()]
        } else {
            position_prefixes(n)
        };
        if let Some(cls) = classes {
            prefixes.retain(|p| canonical_prefix(p, cls));
        }
        let partials: Vec<Partial> = parallel_map(prefixes.len(), default_threads(), |pi| {
            let mut backend = make_backend();
            let mut p = Partial::new();
            bnb_task(
                gpu,
                kernels,
                backend.as_mut(),
                &prefixes[pi],
                classes,
                &incumbent,
                &limits,
                &mut p,
            );
            p
        });

        let mut best_ms = seed_ms;
        let mut best_order = seed_order;
        let mut pruned = 0u64;
        let mut stopped = false;
        // Evaluations actually performed: the warm start plus each
        // task's exact count. (The shared claim counter also ticks for
        // *denied* claims — e.g. every task hitting a wall deadline — so
        // it over-reports and is used for budget decisions only.)
        let mut evals = 1u64;
        for p in partials {
            pruned += p.pruned;
            stopped |= p.stopped;
            evals += p.evals;
            if improves(p.best_ms, &p.best_order, best_ms, &best_order) {
                best_ms = p.best_ms;
                best_order = p.best_order;
            }
        }
        if best_ms < trajectory[0].best_ms {
            trajectory.push(IncumbentSample { eval: evals, best_ms });
        }
        SearchOutcome {
            strategy: self.name(),
            best_ms,
            best_order,
            evals,
            complete: !stopped,
            trajectory,
            pruned_subtrees: pruned,
            wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
        }
    }

    /// Dependency-aware exact search: the same bounded DFS, but a node
    /// expands kernel `k` only when every predecessor is already placed
    /// ([`DepGraph::is_free`]) — infeasible prefixes prune whole
    /// subtrees before any bound is computed — and the symmetry
    /// collapse merges only kernels with identical **dependency
    /// signatures** on top of model identity (an edge between two
    /// kernels forces different signatures, so merged kernels are never
    /// precedence-related and within-class reorderings of a topological
    /// order stay topological). The warm start is Algorithm 1's order
    /// repaired into a topological order ([`DepGraph::repair`] — the
    /// identity repair when no deps exist). Runs as one sequential task
    /// (the constrained tree is already small), so even budgeted runs
    /// are bit-reproducible; unbudgeted results are bit-identical to
    /// [`crate::perm::sweep_dag_with`], lexicographic tie-break
    /// included.
    fn search_dag(
        &self,
        gpu: &GpuSpec,
        workload: &Workload,
        make_backend: &BackendFactory,
        budget: &SearchBudget,
    ) -> SearchOutcome {
        let graph = super::dag_graph_or_panic(workload);
        if !graph.has_deps() {
            return self.search(gpu, &workload.kernels, make_backend, budget);
        }
        let kernels = &workload.kernels;
        let t_start = Instant::now();
        let n = kernels.len();
        assert!(n >= 1, "empty workload");

        // Warm start: Algorithm 1's order, repaired to feasibility.
        let seed_order = graph.repair(&reorder(gpu, kernels).order);
        let seed_ms = {
            let mut b = make_backend();
            b.prepare(gpu, kernels).execute_order(&seed_order)
        };
        let mut trajectory = vec![IncumbentSample {
            eval: 1,
            best_ms: seed_ms,
        }];
        if seed_ms.is_nan() {
            return SearchOutcome {
                strategy: self.name(),
                best_ms: f64::NAN,
                best_order: seed_order,
                evals: 1,
                complete: false,
                trajectory,
                pruned_subtrees: 0,
                wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
            };
        }

        let incumbent = SharedIncumbent::new(seed_ms);
        let limits = Limits {
            evals: AtomicU64::new(1), // the warm start spent one
            max_evals: budget.max_evals.unwrap_or(u64::MAX),
            deadline: budget.max_wall.map(|d| t_start + d),
        };
        let class_of = if self.symmetry {
            Some(dag_refined_classes(kernels, &graph))
        } else {
            None
        };
        let classes = class_of.as_deref();

        let mut backend = make_backend();
        let mut p = Partial::new();
        dag_bnb_task(
            gpu,
            kernels,
            backend.as_mut(),
            &graph,
            classes,
            &incumbent,
            &limits,
            &mut p,
        );

        let mut best_ms = seed_ms;
        let mut best_order = seed_order;
        let evals = 1 + p.evals;
        if improves(p.best_ms, &p.best_order, best_ms, &best_order) {
            best_ms = p.best_ms;
            best_order = p.best_order;
        }
        if best_ms < trajectory[0].best_ms {
            trajectory.push(IncumbentSample { eval: evals, best_ms });
        }
        SearchOutcome {
            strategy: self.name(),
            best_ms,
            best_order,
            evals,
            complete: !p.stopped,
            trajectory,
            pruned_subtrees: p.pruned,
            wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
        }
    }
}

/// Model equivalence classes refined by dependency signature:
/// `class_of[k]` is the smallest index that is model-identical to `k`
/// *and* shares its (pred, succ) masks. Signature-equal kernels are
/// never precedence-related (an edge would put each in the other's
/// mask), so exchanging them inside a topological order yields another
/// topological order with a bit-identical makespan — the collapse
/// stays exact under dependencies.
fn dag_refined_classes(kernels: &[KernelProfile], graph: &DepGraph) -> Vec<usize> {
    let model = equivalence_classes(kernels);
    let n = kernels.len();
    let mut out = vec![0usize; n];
    for k in 0..n {
        out[k] = (0..k)
            .find(|&j| model[j] == model[k] && graph.signature(j) == graph.signature(k))
            .unwrap_or(k);
    }
    out
}

/// Solve the whole dependency-constrained tree as one sequential task.
#[allow(clippy::too_many_arguments)]
fn dag_bnb_task(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    backend: &mut dyn crate::exec::ExecutionBackend,
    graph: &DepGraph,
    classes: Option<&[usize]>,
    incumbent: &SharedIncumbent,
    limits: &Limits,
    out: &mut Partial,
) {
    let n = kernels.len();
    let mut prepared = backend.prepare(gpu, kernels);

    if !prepared.supports_checkpoints() {
        // No checkpoints ⇒ no bounds: flat enumeration filtered down to
        // canonical topological orders.
        let mut rest: Vec<usize> = (0..n).collect();
        crate::perm::for_each_permutation(&mut rest, &mut |perm| {
            if out.stopped || !graph.is_topological(perm) {
                return;
            }
            if classes.is_some_and(|cls| !canonical_prefix(perm, cls)) {
                return;
            }
            if !limits.claim() {
                out.stopped = true;
                return;
            }
            let t = prepared.execute_order(perm);
            out.record(t, perm, incumbent);
        });
        return;
    }

    let mut used = vec![false; n];
    let mut order: Vec<usize> = Vec::with_capacity(n);
    let mut remaining_buf: Vec<usize> = Vec::with_capacity(n);
    dag_dfs(
        prepared.as_mut(),
        &mut used,
        0u64,
        &mut order,
        &mut remaining_buf,
        n,
        graph,
        classes,
        incumbent,
        limits,
        out,
    );
}

/// [`dfs`] restricted to topological orders: each node expands only
/// dependency-free kernels (their subtrees are pruned before any bound
/// is computed) and applies the signature-refined symmetry skip.
#[allow(clippy::too_many_arguments)]
fn dag_dfs(
    prepared: &mut dyn PreparedWorkload,
    used: &mut [bool],
    used_mask: u64,
    order: &mut Vec<usize>,
    remaining_buf: &mut Vec<usize>,
    n: usize,
    graph: &DepGraph,
    classes: Option<&[usize]>,
    incumbent: &SharedIncumbent,
    limits: &Limits,
    out: &mut Partial,
) {
    if out.stopped {
        return;
    }
    match n - order.len() {
        0 => {
            if !limits.claim() {
                out.stopped = true;
                return;
            }
            let t = prepared.execute_suffix(&[]);
            out.record(t, order, incumbent);
        }
        1 => {
            // The lone remaining kernel is always free.
            if !limits.claim() {
                out.stopped = true;
                return;
            }
            let k = used.iter().position(|u| !u).expect("one kernel left");
            order.push(k);
            let t = prepared.execute_suffix(&order[n - 1..]);
            out.record(t, order, incumbent);
            order.pop();
        }
        2 => {
            let a = used.iter().position(|u| !u).expect("two kernels left");
            let b = used[a + 1..]
                .iter()
                .position(|u| !u)
                .map(|i| a + 1 + i)
                .expect("two kernels left");
            let twins = classes.is_some_and(|cls| cls[a] == cls[b]);
            for (x, y) in [(a, b), (b, a)] {
                if twins && x == b {
                    continue; // out-of-order twin of (a, b)
                }
                // Only the first of the pair needs a feasibility check:
                // the kernel placed last has every predecessor placed.
                if !graph.is_free(x, used_mask) {
                    continue;
                }
                if !limits.claim() {
                    out.stopped = true;
                    return;
                }
                order.push(x);
                order.push(y);
                let t = prepared.execute_suffix(&order[n - 2..]);
                out.record(t, order, incumbent);
                order.pop();
                order.pop();
            }
        }
        _ => {
            remaining_buf.clear();
            remaining_buf.extend((0..n).filter(|&k| !used[k]));
            let lb = prepared.suffix_lower_bound(remaining_buf);
            if lb > incumbent.get() * (1.0 + PRUNE_MARGIN) {
                out.pruned += 1;
                return;
            }
            for k in 0..n {
                if used[k]
                    || !graph.is_free(k, used_mask)
                    || symmetry_skipped(k, used, classes)
                {
                    continue;
                }
                used[k] = true;
                order.push(k);
                prepared.checkpoint_push(k);
                dag_dfs(
                    prepared,
                    used,
                    used_mask | (1 << k),
                    order,
                    remaining_buf,
                    n,
                    graph,
                    classes,
                    incumbent,
                    limits,
                    out,
                );
                prepared.checkpoint_pop();
                order.pop();
                used[k] = false;
                if out.stopped {
                    return;
                }
            }
        }
    }
}

/// Solve one first-two-position prefix task.
#[allow(clippy::too_many_arguments)]
fn bnb_task(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    backend: &mut dyn crate::exec::ExecutionBackend,
    prefix: &[usize],
    classes: Option<&[usize]>,
    incumbent: &SharedIncumbent,
    limits: &Limits,
    out: &mut Partial,
) {
    let n = kernels.len();
    let mut prepared = backend.prepare(gpu, kernels);
    let mut order: Vec<usize> = Vec::with_capacity(n);
    order.extend_from_slice(prefix);

    if !prepared.supports_checkpoints() {
        // No checkpoints ⇒ no bounds either (`suffix_lower_bound` needs a
        // prefix state): degrade to flat enumeration of this task's
        // suffixes with incumbent tracking only. The symmetry collapse
        // still applies (the solver's `symmetry` flag asserts the
        // interchangeability contract regardless of substrate): the
        // canonical prefixes were kept by the caller, and non-canonical
        // *orders* are filtered here before spending an evaluation.
        let mut rest: Vec<usize> = (0..n).filter(|i| !prefix.contains(i)).collect();
        if rest.is_empty() {
            if limits.claim() {
                let t = prepared.execute_order(&order);
                out.record(t, &order, incumbent);
            } else {
                out.stopped = true;
            }
            return;
        }
        let plen = prefix.len();
        // `for_each_permutation` cannot early-exit; skip the evaluation
        // once the budget is gone (enumeration itself is cheap).
        crate::perm::for_each_permutation(&mut rest, &mut |suffix| {
            if out.stopped {
                return;
            }
            order.truncate(plen);
            order.extend_from_slice(suffix);
            if classes.is_some_and(|cls| !canonical_prefix(&order, cls)) {
                return;
            }
            if !limits.claim() {
                out.stopped = true;
                return;
            }
            let t = prepared.execute_order(&order);
            out.record(t, &order, incumbent);
        });
        return;
    }

    let mut used = vec![false; n];
    for &k in prefix {
        prepared.checkpoint_push(k);
        used[k] = true;
    }
    let mut remaining_buf: Vec<usize> = Vec::with_capacity(n);
    dfs(
        prepared.as_mut(),
        &mut used,
        &mut order,
        &mut remaining_buf,
        n,
        classes,
        incumbent,
        limits,
        out,
    );
    for _ in prefix {
        prepared.checkpoint_pop();
    }
}

/// Symmetry skip: `k` may be expanded only when no smaller unused index
/// shares its equivalence class (one representative per class per node —
/// the rule itself lives in [`crate::perm`] so this solver and the
/// collapsed sweep can never disagree on the canonical set).
#[inline]
fn symmetry_skipped(k: usize, used: &[bool], classes: Option<&[usize]>) -> bool {
    classes.is_some_and(|cls| class_blocked(k, used, cls))
}

/// Depth-first descent: the caller has pushed checkpoints for every
/// kernel in `order`.
#[allow(clippy::too_many_arguments)]
fn dfs(
    prepared: &mut dyn PreparedWorkload,
    used: &mut [bool],
    order: &mut Vec<usize>,
    remaining_buf: &mut Vec<usize>,
    n: usize,
    classes: Option<&[usize]>,
    incumbent: &SharedIncumbent,
    limits: &Limits,
    out: &mut Partial,
) {
    if out.stopped {
        return;
    }
    match n - order.len() {
        0 => {
            if !limits.claim() {
                out.stopped = true;
                return;
            }
            let t = prepared.execute_suffix(&[]);
            out.record(t, order, incumbent);
        }
        1 => {
            if !limits.claim() {
                out.stopped = true;
                return;
            }
            let k = used.iter().position(|u| !u).expect("one kernel left");
            order.push(k);
            let t = prepared.execute_suffix(&order[n - 1..]);
            out.record(t, order, incumbent);
            order.pop();
        }
        2 => {
            let a = used.iter().position(|u| !u).expect("two kernels left");
            let b = used[a + 1..]
                .iter()
                .position(|u| !u)
                .map(|i| a + 1 + i)
                .expect("two kernels left");
            // Model-identical last pair: (b, a) is the out-of-order twin
            // of (a, b) with bit-identical makespan — skip it.
            let twins = classes.is_some_and(|cls| cls[a] == cls[b]);
            for (x, y) in [(a, b), (b, a)] {
                if twins && x == b {
                    continue;
                }
                if !limits.claim() {
                    out.stopped = true;
                    return;
                }
                order.push(x);
                order.push(y);
                let t = prepared.execute_suffix(&order[n - 2..]);
                out.record(t, order, incumbent);
                order.pop();
                order.pop();
            }
        }
        _ => {
            // Bound check before descending: prune when no completion of
            // this prefix can beat (or bit-exactly tie) the incumbent.
            remaining_buf.clear();
            remaining_buf.extend((0..n).filter(|&k| !used[k]));
            let lb = prepared.suffix_lower_bound(remaining_buf);
            if lb > incumbent.get() * (1.0 + PRUNE_MARGIN) {
                out.pruned += 1;
                return;
            }
            for k in 0..n {
                if used[k] || symmetry_skipped(k, used, classes) {
                    continue;
                }
                used[k] = true;
                order.push(k);
                prepared.checkpoint_push(k);
                dfs(
                    prepared,
                    used,
                    order,
                    remaining_buf,
                    n,
                    classes,
                    incumbent,
                    limits,
                    out,
                );
                prepared.checkpoint_pop();
                order.pop();
                used[k] = false;
                if out.stopped {
                    return;
                }
            }
        }
    }
}
