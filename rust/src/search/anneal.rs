//! Seeded simulated annealing over launch orders.
//!
//! The state space is the set of permutations; a move either swaps two
//! positions or shifts one kernel to another position (remove + insert —
//! the insertion neighborhood matters because the fluid model's
//! head-of-line blocking makes *where* a kernel sits in the dispatch
//! stream, not just which kernels it is adjacent to, determine packing).
//! Temperature follows a geometric schedule from 10 % of the warm-start
//! makespan down to 10⁻⁴ of it across the evaluation budget.
//!
//! Warm start: Algorithm 1's order — the paper's greedy already sits
//! above the 90th percentile, so annealing spends its budget improving a
//! good order instead of escaping a random one. Every random choice
//! comes from one [`SplitMix64`] stream, so `(seed, max_evals)` fully
//! determines the incumbent trajectory.

use super::{
    BackendFactory, Incumbent, SearchBudget, SearchOutcome, SearchStrategy, DEFAULT_ANYTIME_EVALS,
};
use crate::gpu::{GpuSpec, KernelProfile};
use crate::sched::reorder;
use crate::util::SplitMix64;
use std::time::Instant;

/// Anytime simulated-annealing strategy (registry spelling
/// `"anneal:<seed>"`).
#[derive(Debug, Clone, Copy)]
pub struct SimulatedAnnealing {
    pub seed: u64,
}

impl SimulatedAnnealing {
    pub fn new(seed: u64) -> Self {
        SimulatedAnnealing { seed }
    }
}

impl SearchStrategy for SimulatedAnnealing {
    fn name(&self) -> String {
        format!("anneal:{}", self.seed)
    }

    fn search(
        &self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        make_backend: &BackendFactory,
        budget: &SearchBudget,
    ) -> SearchOutcome {
        let t_start = Instant::now();
        let n = kernels.len();
        assert!(n >= 1, "empty workload");
        let max_evals = budget.max_evals.unwrap_or(DEFAULT_ANYTIME_EVALS).max(1);
        let deadline = budget.max_wall.map(|d| t_start + d);

        let mut backend = make_backend();
        let mut prepared = backend.prepare(gpu, kernels);
        let mut rng = SplitMix64::new(self.seed);

        let mut cur = reorder(gpu, kernels).order;
        let mut t_cur = prepared.execute_order(&cur);
        let mut evals = 1u64;
        let mut inc = Incumbent::new();
        inc.offer(evals, t_cur, &cur);

        if t_cur.is_nan() || n < 2 {
            return SearchOutcome {
                strategy: self.name(),
                best_ms: t_cur,
                best_order: cur,
                evals,
                complete: false,
                trajectory: inc.trajectory,
                pruned_subtrees: 0,
                wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
            };
        }

        // Geometric cooling anchored to the warm start's scale.
        let temp_hi = (0.10 * t_cur).max(f64::MIN_POSITIVE);
        let temp_lo = (1e-4 * t_cur).max(f64::MIN_POSITIVE);
        let mut cand = cur.clone();

        while evals < max_evals {
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    break;
                }
            }
            cand.copy_from_slice(&cur);
            if rng.below(2) == 0 {
                // Swap two distinct positions.
                let i = rng.below(n);
                let mut j = rng.below(n - 1);
                if j >= i {
                    j += 1;
                }
                cand.swap(i, j);
            } else {
                // Shift: remove position i, reinsert at j. After the
                // removal the vector holds n-1 elements, so j ∈ 0..n
                // covers every position including "move to the end"
                // (j may reproduce the current order; that burns one
                // evaluation, which the budget accounts for).
                let i = rng.below(n);
                let j = rng.below(n);
                let v = cand.remove(i);
                cand.insert(j, v);
            }

            let t = prepared.execute_order(&cand);
            evals += 1;
            inc.offer(evals, t, &cand);

            let progress = evals as f64 / max_evals as f64;
            let temp = temp_hi * (temp_lo / temp_hi).powf(progress);
            let accept = if t.is_nan() {
                false
            } else if t <= t_cur {
                true
            } else {
                rng.next_f64() < ((t_cur - t) / temp).exp()
            };
            if accept {
                std::mem::swap(&mut cur, &mut cand);
                t_cur = t;
            }
        }

        SearchOutcome {
            strategy: self.name(),
            best_ms: inc.best_ms,
            best_order: inc.best_order,
            evals,
            complete: false,
            trajectory: inc.trajectory,
            pruned_subtrees: 0,
            wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
        }
    }
}
