//! Seeded simulated annealing over launch orders.
//!
//! The state space is the set of permutations; a move either swaps two
//! positions or shifts one kernel to another position (an in-place slice
//! rotation — the insertion neighborhood matters because the fluid
//! model's head-of-line blocking makes *where* a kernel sits in the
//! dispatch stream, not just which kernels it is adjacent to, determine
//! packing). Temperature follows a geometric schedule from 10 % of the
//! warm-start makespan down to 10⁻⁴ of it across the evaluation budget.
//!
//! Warm start: Algorithm 1's order — the paper's greedy already sits
//! above the 90th percentile, so annealing spends its budget improving a
//! good order instead of escaping a random one. Every random choice
//! comes from one [`SplitMix64`] stream, so `(seed, max_evals)` fully
//! determines the incumbent trajectory.
//!
//! # Suffix-priced evaluation
//!
//! Both moves leave the incumbent's prefix up to `min(i, j)` untouched,
//! so candidates are evaluated through a [`PrefixCursor`] anchored along
//! the incumbent: only the suffix past the move's first touched position
//! is re-simulated. Checkpoint restore is bit-exact, so the incumbent
//! trajectory is **bit-identical** to full per-candidate evaluation
//! (pinned by `tests/incremental_equivalence.rs`) — a pure speedup of
//! roughly `n / (n − E[min(i, j)]) ≈ 1.5×` on the prepared path and far
//! more against per-call `execute` backends (see
//! `benches/search_quality.rs` for the measured numbers). The loop
//! performs no heap allocation after warm-up (`tests/zero_alloc.rs`).

use super::{
    BackendFactory, Incumbent, SearchBudget, SearchOutcome, SearchStrategy, DEFAULT_ANYTIME_EVALS,
};
use crate::exec::PrefixCursor;
use crate::gpu::{GpuSpec, KernelProfile};
use crate::sched::reorder;
use crate::util::SplitMix64;
use crate::workloads::Workload;
use std::time::Instant;

/// Shift the element at position `i` to position `j` in place — the
/// allocation-free equivalent of `let v = xs.remove(i); xs.insert(j, v)`.
#[inline]
pub(crate) fn apply_shift(xs: &mut [usize], i: usize, j: usize) {
    use std::cmp::Ordering;
    match i.cmp(&j) {
        Ordering::Less => xs[i..=j].rotate_left(1),
        Ordering::Greater => xs[j..=i].rotate_right(1),
        Ordering::Equal => {}
    }
}

/// Anytime simulated-annealing strategy (registry spelling
/// `"anneal:<seed>"`).
#[derive(Debug, Clone, Copy)]
pub struct SimulatedAnnealing {
    pub seed: u64,
    /// Evaluate candidates through the prefix-reuse cursor (the default).
    /// `false` forces full per-candidate evaluation — results are
    /// bit-identical either way; the flag exists for the equivalence
    /// pins and `kreorder search --compare-eval`.
    pub incremental: bool,
}

impl SimulatedAnnealing {
    pub fn new(seed: u64) -> Self {
        SimulatedAnnealing {
            seed,
            incremental: true,
        }
    }

    /// This strategy with prefix-reuse evaluation disabled (the
    /// full-evaluation reference path; same trajectories, slower).
    pub fn full_evaluation(mut self) -> Self {
        self.incremental = false;
        self
    }

    /// The annealing loop itself, over caller-owned buffers — the
    /// allocation-free core of [`SearchStrategy::search`], exposed so
    /// `tests/zero_alloc.rs` can pin it directly.
    ///
    /// `cur` holds the warm-start order (consumed in place; left at the
    /// final accepted order) with `t_warm` its already-evaluated
    /// makespan, `cand` is same-length scratch, and `offer` receives
    /// every `(eval index, makespan, order)` triple — the caller folds
    /// them into its incumbent. `evals` continues from the caller's
    /// count (the warm start's evaluation is the caller's).
    #[allow(clippy::too_many_arguments)]
    pub fn anneal_on(
        &self,
        cursor: &mut PrefixCursor<'_>,
        cur: &mut Vec<usize>,
        cand: &mut Vec<usize>,
        t_warm: f64,
        max_evals: u64,
        deadline: Option<Instant>,
        evals: &mut u64,
        offer: &mut dyn FnMut(u64, f64, &[usize]),
    ) {
        let n = cur.len();
        debug_assert!(n >= 2);
        debug_assert_eq!(cand.len(), n);
        let mut rng = SplitMix64::new(self.seed);
        let mut t_cur = t_warm;
        // Geometric cooling anchored to the warm start's scale.
        let temp_hi = (0.10 * t_warm).max(f64::MIN_POSITIVE);
        let temp_lo = (1e-4 * t_warm).max(f64::MIN_POSITIVE);

        while *evals < max_evals {
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    break;
                }
            }
            cand.copy_from_slice(cur);
            let anchor;
            if rng.below(2) == 0 {
                // Swap two distinct positions.
                let i = rng.below(n);
                let mut j = rng.below(n - 1);
                if j >= i {
                    j += 1;
                }
                cand.swap(i, j);
                anchor = i.min(j);
            } else {
                // Shift position i to position j; i == j reproduces the
                // current order (that burns one evaluation, which the
                // budget accounts for).
                let i = rng.below(n);
                let j = rng.below(n);
                apply_shift(cand, i, j);
                anchor = i.min(j);
            }

            // Both moves leave cand[..anchor] == cur[..anchor]: evaluate
            // only the suffix, growing the cursor's anchor along the
            // incumbent as needed.
            let t = cursor.eval_anchored(cand, anchor);
            *evals += 1;
            offer(*evals, t, cand);

            let progress = *evals as f64 / max_evals as f64;
            let temp = temp_hi * (temp_lo / temp_hi).powf(progress);
            let accept = if t.is_nan() {
                false
            } else if t <= t_cur {
                true
            } else {
                rng.next_f64() < ((t_cur - t) / temp).exp()
            };
            if accept {
                std::mem::swap(cur, cand);
                t_cur = t;
            }
        }
    }
}

impl SearchStrategy for SimulatedAnnealing {
    fn name(&self) -> String {
        format!("anneal:{}", self.seed)
    }

    fn search(
        &self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        make_backend: &BackendFactory,
        budget: &SearchBudget,
    ) -> SearchOutcome {
        let t_start = Instant::now();
        let n = kernels.len();
        assert!(n >= 1, "empty workload");
        let max_evals = budget.max_evals.unwrap_or(DEFAULT_ANYTIME_EVALS).max(1);
        let deadline = budget.max_wall.map(|d| t_start + d);

        let mut backend = make_backend();
        let prepared = backend.prepare(gpu, kernels);
        let mut cursor = if self.incremental {
            PrefixCursor::new(prepared)
        } else {
            PrefixCursor::new_full(prepared)
        };

        let mut cur = reorder(gpu, kernels).order;
        let t_warm = cursor.eval(&cur);
        let mut evals = 1u64;
        let mut inc = Incumbent::new();
        inc.offer(evals, t_warm, &cur);

        if t_warm.is_nan() || n < 2 {
            return SearchOutcome {
                strategy: self.name(),
                best_ms: t_warm,
                best_order: cur,
                evals,
                complete: false,
                trajectory: inc.trajectory,
                pruned_subtrees: 0,
                wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
            };
        }

        let mut cand = cur.clone();
        self.anneal_on(
            &mut cursor,
            &mut cur,
            &mut cand,
            t_warm,
            max_evals,
            deadline,
            &mut evals,
            &mut |e, t, o| inc.offer(e, t, o),
        );

        SearchOutcome {
            strategy: self.name(),
            best_ms: inc.best_ms,
            best_order: inc.best_order,
            evals,
            complete: false,
            trajectory: inc.trajectory,
            pruned_subtrees: 0,
            wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
        }
    }

    /// Dependency-aware annealing. Small constrained spaces (n ≤ 8 with
    /// the budget covering every linear extension, or unlimited) are
    /// answered **exactly** via the constrained sweep — bit-identical
    /// to [`crate::perm::sweep_dag_with`], which is what the
    /// `benches/search_quality.rs` DAG gate holds this strategy to.
    /// Beyond that the annealing loop runs with **feasibility-rejecting
    /// moves**: the usual seeded swap/shift proposals, but a candidate
    /// that is not a topological order is rejected *without simulation*.
    /// Every proposal (evaluated or rejected) charges one budget unit —
    /// a chain-like DAG rejects almost everything, and charging
    /// proposals keeps the loop finite and the trajectory a pure
    /// function of `(seed, budget)`. Warm start and acceptance are
    /// otherwise unchanged; the warm start is Algorithm 1's order
    /// repaired to feasibility, and [`PrefixCursor`] anchoring still
    /// applies (a rejected move touches no cursor state).
    fn search_dag(
        &self,
        gpu: &GpuSpec,
        workload: &Workload,
        make_backend: &BackendFactory,
        budget: &SearchBudget,
    ) -> SearchOutcome {
        let graph = super::dag_graph_or_panic(workload);
        if !graph.has_deps() {
            return self.search(gpu, &workload.kernels, make_backend, budget);
        }
        if super::dag_exact_covered(&graph, budget) {
            return super::exact_dag_outcome(
                self.name(),
                gpu,
                &workload.kernels,
                &graph,
                make_backend,
            );
        }
        let kernels = &workload.kernels;
        let t_start = Instant::now();
        let n = kernels.len();
        let max_evals = budget.max_evals.unwrap_or(DEFAULT_ANYTIME_EVALS).max(1);
        let deadline = budget.max_wall.map(|d| t_start + d);

        let mut backend = make_backend();
        let prepared = backend.prepare(gpu, kernels);
        let mut cursor = if self.incremental {
            PrefixCursor::new(prepared)
        } else {
            PrefixCursor::new_full(prepared)
        };

        let mut cur = graph.repair(&reorder(gpu, kernels).order);
        let t_warm = cursor.eval(&cur);
        let mut evals = 1u64;
        let mut inc = Incumbent::new();
        inc.offer(evals, t_warm, &cur);

        if t_warm.is_nan() || n < 2 {
            return SearchOutcome {
                strategy: self.name(),
                best_ms: t_warm,
                best_order: cur,
                evals,
                complete: false,
                trajectory: inc.trajectory,
                pruned_subtrees: 0,
                wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
            };
        }

        let mut cand = cur.clone();
        let mut rng = SplitMix64::new(self.seed);
        let mut t_cur = t_warm;
        let temp_hi = (0.10 * t_warm).max(f64::MIN_POSITIVE);
        let temp_lo = (1e-4 * t_warm).max(f64::MIN_POSITIVE);

        while evals < max_evals {
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    break;
                }
            }
            cand.copy_from_slice(&cur);
            let anchor;
            if rng.below(2) == 0 {
                let i = rng.below(n);
                let mut j = rng.below(n - 1);
                if j >= i {
                    j += 1;
                }
                cand.swap(i, j);
                anchor = i.min(j);
            } else {
                let i = rng.below(n);
                let j = rng.below(n);
                apply_shift(&mut cand, i, j);
                anchor = i.min(j);
            }
            evals += 1;
            if !graph.is_topological(&cand) {
                continue; // rejected unsimulated; the proposal is charged
            }
            let t = cursor.eval_anchored(&cand, anchor);
            inc.offer(evals, t, &cand);

            let progress = evals as f64 / max_evals as f64;
            let temp = temp_hi * (temp_lo / temp_hi).powf(progress);
            let accept = if t.is_nan() {
                false
            } else if t <= t_cur {
                true
            } else {
                rng.next_f64() < ((t_cur - t) / temp).exp()
            };
            if accept {
                std::mem::swap(&mut cur, &mut cand);
                t_cur = t;
            }
        }

        SearchOutcome {
            strategy: self.name(),
            best_ms: inc.best_ms,
            best_order: inc.best_order,
            evals,
            complete: false,
            trajectory: inc.trajectory,
            pruned_subtrees: 0,
            wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_shift_matches_remove_insert() {
        let n = 7usize;
        for i in 0..n {
            for j in 0..n {
                let mut rotated: Vec<usize> = (0..n).collect();
                apply_shift(&mut rotated, i, j);
                let mut reference: Vec<usize> = (0..n).collect();
                let v = reference.remove(i);
                reference.insert(j, v);
                assert_eq!(rotated, reference, "shift {i} -> {j}");
            }
        }
    }
}
