//! First-improvement local search over launch orders with seeded
//! restarts.
//!
//! One descent scans the swap neighborhood (all position pairs), then
//! the insertion neighborhood (move one kernel to another position),
//! accepting the first strictly improving move and rescanning; a full
//! pass with no improvement is a local optimum. The search then restarts
//! from a seeded random shuffle and keeps the global incumbent, until
//! the evaluation budget is spent.
//!
//! First-improvement (rather than best-improvement) is deliberate: under
//! a fixed evaluation budget it converts more of the budget into
//! accepted moves, which is what the anytime quality gate measures. The
//! first descent starts from Algorithm 1's order; all randomness comes
//! from one [`SplitMix64`] stream, so `(seed, max_evals)` fully
//! determines the incumbent trajectory.

use super::{
    BackendFactory, Incumbent, SearchBudget, SearchOutcome, SearchStrategy, DEFAULT_ANYTIME_EVALS,
};
use crate::gpu::{GpuSpec, KernelProfile};
use crate::sched::reorder;
use crate::util::SplitMix64;
use std::time::Instant;

/// Anytime insertion/swap local-search strategy (registry spelling
/// `"local:<seed>"`).
#[derive(Debug, Clone, Copy)]
pub struct LocalSearch {
    pub seed: u64,
}

impl LocalSearch {
    pub fn new(seed: u64) -> Self {
        LocalSearch { seed }
    }
}

impl SearchStrategy for LocalSearch {
    fn name(&self) -> String {
        format!("local:{}", self.seed)
    }

    fn search(
        &self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        make_backend: &BackendFactory,
        budget: &SearchBudget,
    ) -> SearchOutcome {
        let t_start = Instant::now();
        let n = kernels.len();
        assert!(n >= 1, "empty workload");
        let max_evals = budget.max_evals.unwrap_or(DEFAULT_ANYTIME_EVALS).max(1);
        let deadline = budget.max_wall.map(|d| t_start + d);
        let out_of_time = || deadline.is_some_and(|d| Instant::now() >= d);

        let mut backend = make_backend();
        let mut prepared = backend.prepare(gpu, kernels);
        let mut rng = SplitMix64::new(self.seed);

        let mut cur = reorder(gpu, kernels).order;
        let mut t_cur = prepared.execute_order(&cur);
        let mut evals = 1u64;
        let mut inc = Incumbent::new();
        inc.offer(evals, t_cur, &cur);

        if t_cur.is_nan() || n < 2 {
            return SearchOutcome {
                strategy: self.name(),
                best_ms: t_cur,
                best_order: cur,
                evals,
                complete: false,
                trajectory: inc.trajectory,
                pruned_subtrees: 0,
                wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
            };
        }

        let mut cand = cur.clone();
        'search: while evals < max_evals && !out_of_time() {
            // One first-improvement descent to a local optimum.
            let mut improved = true;
            while improved {
                improved = false;
                // Swap neighborhood.
                'swaps: for i in 0..n - 1 {
                    for j in i + 1..n {
                        if evals >= max_evals || out_of_time() {
                            break 'search;
                        }
                        cand.copy_from_slice(&cur);
                        cand.swap(i, j);
                        let t = prepared.execute_order(&cand);
                        evals += 1;
                        inc.offer(evals, t, &cand);
                        if t < t_cur {
                            cur.copy_from_slice(&cand);
                            t_cur = t;
                            improved = true;
                            break 'swaps;
                        }
                    }
                }
                if improved {
                    continue;
                }
                // Insertion neighborhood. After `remove(i)` the candidate
                // has n-1 elements, so valid insert positions are 0..=n-1
                // inclusive — iterating to n-1 keeps "move to the end"
                // reachable.
                'shifts: for i in 0..n {
                    for j in 0..n {
                        if evals >= max_evals || out_of_time() {
                            break 'search;
                        }
                        cand.copy_from_slice(&cur);
                        let v = cand.remove(i);
                        cand.insert(j, v);
                        if cand == cur {
                            continue; // no-op shift
                        }
                        let t = prepared.execute_order(&cand);
                        evals += 1;
                        inc.offer(evals, t, &cand);
                        if t < t_cur {
                            cur.copy_from_slice(&cand);
                            t_cur = t;
                            improved = true;
                            break 'shifts;
                        }
                    }
                }
            }
            // Local optimum: seeded restart.
            if evals >= max_evals {
                break;
            }
            rng.shuffle(&mut cur);
            t_cur = prepared.execute_order(&cur);
            evals += 1;
            inc.offer(evals, t_cur, &cur);
        }

        SearchOutcome {
            strategy: self.name(),
            best_ms: inc.best_ms,
            best_order: inc.best_order,
            evals,
            complete: false,
            trajectory: inc.trajectory,
            pruned_subtrees: 0,
            wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
        }
    }
}
