//! First-improvement local search over launch orders with seeded
//! restarts.
//!
//! One descent scans the swap neighborhood (all position pairs), then
//! the insertion neighborhood (move one kernel to another position),
//! accepting the first strictly improving move and rescanning; a full
//! pass with no improvement is a local optimum. The search then restarts
//! from a seeded random shuffle and keeps the global incumbent, until
//! the evaluation budget is spent.
//!
//! First-improvement (rather than best-improvement) is deliberate: under
//! a fixed evaluation budget it converts more of the budget into
//! accepted moves, which is what the anytime quality gate measures. The
//! first descent starts from Algorithm 1's order; all randomness comes
//! from one [`SplitMix64`] stream, so `(seed, max_evals)` fully
//! determines the incumbent trajectory.
//!
//! # Suffix-priced evaluation
//!
//! A swap at `(i, j)` or an insertion between `i` and `j` leaves the
//! incumbent's prefix up to `min(i, j)` untouched, so candidates are
//! evaluated through a [`PrefixCursor`]: the checkpoint stack grows
//! along the incumbent as the scan's leading position advances, and each
//! candidate re-simulates only its suffix. Bit-identical to full
//! evaluation (pinned by `tests/incremental_equivalence.rs`), and the
//! descent loop performs no heap allocation after warm-up
//! (`tests/zero_alloc.rs`).

use super::anneal::apply_shift;
use super::{
    BackendFactory, Incumbent, SearchBudget, SearchOutcome, SearchStrategy, DEFAULT_ANYTIME_EVALS,
};
use crate::exec::PrefixCursor;
use crate::gpu::{GpuSpec, KernelProfile};
use crate::sched::reorder;
use crate::util::SplitMix64;
use crate::workloads::{DepGraph, Workload};
use std::time::Instant;

/// Anytime insertion/swap local-search strategy (registry spelling
/// `"local:<seed>"`).
#[derive(Debug, Clone, Copy)]
pub struct LocalSearch {
    pub seed: u64,
    /// Evaluate candidates through the prefix-reuse cursor (the default).
    /// `false` forces full per-candidate evaluation — results are
    /// bit-identical either way; the flag exists for the equivalence
    /// pins and `kreorder search --compare-eval`.
    pub incremental: bool,
}

impl LocalSearch {
    pub fn new(seed: u64) -> Self {
        LocalSearch {
            seed,
            incremental: true,
        }
    }

    /// This strategy with prefix-reuse evaluation disabled (the
    /// full-evaluation reference path; same trajectories, slower).
    pub fn full_evaluation(mut self) -> Self {
        self.incremental = false;
        self
    }

    /// One first-improvement descent from `cur` (whose makespan is
    /// `t_cur`) to a local optimum, over caller-owned buffers — the
    /// allocation-free core of [`SearchStrategy::search`], exposed so
    /// `tests/zero_alloc.rs` can pin it directly.
    ///
    /// Returns `(t_final, stopped)` where `stopped` is `true` when the
    /// descent ended because the evaluation budget or deadline ran out
    /// (rather than at a local optimum); `cur` is left at the last
    /// accepted order and `offer` received every evaluation.
    #[allow(clippy::too_many_arguments)]
    pub fn descend_on(
        &self,
        cursor: &mut PrefixCursor<'_>,
        cur: &mut Vec<usize>,
        cand: &mut Vec<usize>,
        t_cur: f64,
        max_evals: u64,
        deadline: Option<Instant>,
        evals: &mut u64,
        offer: &mut dyn FnMut(u64, f64, &[usize]),
    ) -> (f64, bool) {
        let n = cur.len();
        debug_assert!(n >= 2);
        debug_assert_eq!(cand.len(), n);
        let out_of_time = || deadline.is_some_and(|d| Instant::now() >= d);
        let mut t_cur = t_cur;
        let mut improved = true;
        while improved {
            improved = false;
            // Swap neighborhood: candidates at leading position i share
            // the incumbent's prefix of length i.
            'swaps: for i in 0..n - 1 {
                for j in i + 1..n {
                    if *evals >= max_evals || out_of_time() {
                        return (t_cur, true);
                    }
                    cand.copy_from_slice(cur);
                    cand.swap(i, j);
                    let t = cursor.eval_anchored(cand, i);
                    *evals += 1;
                    offer(*evals, t, cand);
                    if t < t_cur {
                        cur.copy_from_slice(cand);
                        t_cur = t;
                        improved = true;
                        break 'swaps;
                    }
                }
            }
            if improved {
                continue;
            }
            // Insertion neighborhood: shift position i to position j
            // (i == j is the identity and is skipped without spending an
            // evaluation, exactly like the old `cand == cur` test).
            'shifts: for i in 0..n {
                for j in 0..n {
                    if i == j {
                        continue;
                    }
                    if *evals >= max_evals || out_of_time() {
                        return (t_cur, true);
                    }
                    cand.copy_from_slice(cur);
                    apply_shift(cand, i, j);
                    let t = cursor.eval_anchored(cand, i.min(j));
                    *evals += 1;
                    offer(*evals, t, cand);
                    if t < t_cur {
                        cur.copy_from_slice(cand);
                        t_cur = t;
                        improved = true;
                        break 'shifts;
                    }
                }
            }
        }
        (t_cur, false)
    }

    /// [`LocalSearch::descend_on`] with feasibility-rejecting moves: a
    /// candidate that is not a topological order of `graph` is rejected
    /// without simulation, but the proposal still charges one budget
    /// unit (keeps the descent finite on chain-like DAGs and the
    /// trajectory a pure function of `(seed, budget)`).
    #[allow(clippy::too_many_arguments)]
    fn dag_descend_on(
        &self,
        cursor: &mut PrefixCursor<'_>,
        graph: &DepGraph,
        cur: &mut Vec<usize>,
        cand: &mut Vec<usize>,
        t_cur: f64,
        max_evals: u64,
        deadline: Option<Instant>,
        evals: &mut u64,
        offer: &mut dyn FnMut(u64, f64, &[usize]),
    ) -> (f64, bool) {
        let n = cur.len();
        debug_assert!(n >= 2);
        let out_of_time = || deadline.is_some_and(|d| Instant::now() >= d);
        let mut t_cur = t_cur;
        let mut improved = true;
        while improved {
            improved = false;
            'swaps: for i in 0..n - 1 {
                for j in i + 1..n {
                    if *evals >= max_evals || out_of_time() {
                        return (t_cur, true);
                    }
                    cand.copy_from_slice(cur);
                    cand.swap(i, j);
                    *evals += 1;
                    if !graph.is_topological(cand) {
                        continue;
                    }
                    let t = cursor.eval_anchored(cand, i);
                    offer(*evals, t, cand);
                    if t < t_cur {
                        cur.copy_from_slice(cand);
                        t_cur = t;
                        improved = true;
                        break 'swaps;
                    }
                }
            }
            if improved {
                continue;
            }
            'shifts: for i in 0..n {
                for j in 0..n {
                    if i == j {
                        continue;
                    }
                    if *evals >= max_evals || out_of_time() {
                        return (t_cur, true);
                    }
                    cand.copy_from_slice(cur);
                    apply_shift(cand, i, j);
                    *evals += 1;
                    if !graph.is_topological(cand) {
                        continue;
                    }
                    let t = cursor.eval_anchored(cand, i.min(j));
                    offer(*evals, t, cand);
                    if t < t_cur {
                        cur.copy_from_slice(cand);
                        t_cur = t;
                        improved = true;
                        break 'shifts;
                    }
                }
            }
        }
        (t_cur, false)
    }
}

impl SearchStrategy for LocalSearch {
    fn name(&self) -> String {
        format!("local:{}", self.seed)
    }

    fn search(
        &self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        make_backend: &BackendFactory,
        budget: &SearchBudget,
    ) -> SearchOutcome {
        let t_start = Instant::now();
        let n = kernels.len();
        assert!(n >= 1, "empty workload");
        let max_evals = budget.max_evals.unwrap_or(DEFAULT_ANYTIME_EVALS).max(1);
        let deadline = budget.max_wall.map(|d| t_start + d);
        let out_of_time = || deadline.is_some_and(|d| Instant::now() >= d);

        let mut backend = make_backend();
        let prepared = backend.prepare(gpu, kernels);
        let mut cursor = if self.incremental {
            PrefixCursor::new(prepared)
        } else {
            PrefixCursor::new_full(prepared)
        };
        let mut rng = SplitMix64::new(self.seed);

        let mut cur = reorder(gpu, kernels).order;
        let mut t_cur = cursor.eval(&cur);
        let mut evals = 1u64;
        let mut inc = Incumbent::new();
        inc.offer(evals, t_cur, &cur);

        if t_cur.is_nan() || n < 2 {
            return SearchOutcome {
                strategy: self.name(),
                best_ms: t_cur,
                best_order: cur,
                evals,
                complete: false,
                trajectory: inc.trajectory,
                pruned_subtrees: 0,
                wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
            };
        }

        let mut cand = cur.clone();
        while evals < max_evals && !out_of_time() {
            // One first-improvement descent to a local optimum.
            let (t, stopped) = self.descend_on(
                &mut cursor,
                &mut cur,
                &mut cand,
                t_cur,
                max_evals,
                deadline,
                &mut evals,
                &mut |e, t, o| inc.offer(e, t, o),
            );
            t_cur = t;
            if stopped || evals >= max_evals {
                break;
            }
            // Local optimum: seeded restart.
            rng.shuffle(&mut cur);
            t_cur = cursor.eval(&cur);
            evals += 1;
            inc.offer(evals, t_cur, &cur);
        }

        SearchOutcome {
            strategy: self.name(),
            best_ms: inc.best_ms,
            best_order: inc.best_order,
            evals,
            complete: false,
            trajectory: inc.trajectory,
            pruned_subtrees: 0,
            wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
        }
    }

    /// Dependency-aware local search. Small constrained spaces (n ≤ 8
    /// with the budget covering every linear extension, or unlimited)
    /// are answered **exactly** via the constrained sweep —
    /// bit-identical to [`crate::perm::sweep_dag_with`], which is what
    /// the `benches/search_quality.rs` DAG gate holds this strategy to.
    /// Beyond that: first-improvement descent with
    /// feasibility-rejecting moves ([`LocalSearch::dag_descend_on`]),
    /// warm-started from Algorithm 1's order repaired to feasibility;
    /// seeded restarts shuffle and then repair
    /// ([`DepGraph::repair`]), so every restart is a topological order
    /// and the whole run stays deterministic per `(seed, budget)`.
    fn search_dag(
        &self,
        gpu: &GpuSpec,
        workload: &Workload,
        make_backend: &BackendFactory,
        budget: &SearchBudget,
    ) -> SearchOutcome {
        let graph = super::dag_graph_or_panic(workload);
        if !graph.has_deps() {
            return self.search(gpu, &workload.kernels, make_backend, budget);
        }
        if super::dag_exact_covered(&graph, budget) {
            return super::exact_dag_outcome(
                self.name(),
                gpu,
                &workload.kernels,
                &graph,
                make_backend,
            );
        }
        let kernels = &workload.kernels;
        let t_start = Instant::now();
        let n = kernels.len();
        let max_evals = budget.max_evals.unwrap_or(DEFAULT_ANYTIME_EVALS).max(1);
        let deadline = budget.max_wall.map(|d| t_start + d);
        let out_of_time = || deadline.is_some_and(|d| Instant::now() >= d);

        let mut backend = make_backend();
        let prepared = backend.prepare(gpu, kernels);
        let mut cursor = if self.incremental {
            PrefixCursor::new(prepared)
        } else {
            PrefixCursor::new_full(prepared)
        };
        let mut rng = SplitMix64::new(self.seed);

        let mut cur = graph.repair(&reorder(gpu, kernels).order);
        let mut t_cur = cursor.eval(&cur);
        let mut evals = 1u64;
        let mut inc = Incumbent::new();
        inc.offer(evals, t_cur, &cur);

        if t_cur.is_nan() || n < 2 {
            return SearchOutcome {
                strategy: self.name(),
                best_ms: t_cur,
                best_order: cur,
                evals,
                complete: false,
                trajectory: inc.trajectory,
                pruned_subtrees: 0,
                wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
            };
        }

        let mut cand = cur.clone();
        while evals < max_evals && !out_of_time() {
            let (t, stopped) = self.dag_descend_on(
                &mut cursor,
                &graph,
                &mut cur,
                &mut cand,
                t_cur,
                max_evals,
                deadline,
                &mut evals,
                &mut |e, t, o| inc.offer(e, t, o),
            );
            t_cur = t;
            if stopped || evals >= max_evals {
                break;
            }
            // Local optimum: seeded restart, repaired to feasibility.
            rng.shuffle(&mut cur);
            let repaired = graph.repair(&cur);
            cur.copy_from_slice(&repaired);
            t_cur = cursor.eval(&cur);
            evals += 1;
            inc.offer(evals, t_cur, &cur);
        }

        SearchOutcome {
            strategy: self.name(),
            best_ms: inc.best_ms,
            best_order: inc.best_order,
            evals,
            complete: false,
            trajectory: inc.trajectory,
            pruned_subtrees: 0,
            wall_ms: t_start.elapsed().as_secs_f64() * 1e3,
        }
    }
}
