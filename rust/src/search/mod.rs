//! Launch-order **search** — finding good orders when `n!` is out of
//! reach.
//!
//! [`crate::perm::sweep`] answers "what does the whole permutation space
//! look like", but the factorial wall lands near n = 12 even on the
//! checkpointed hot path. Real reorder windows (shared-cloud streams,
//! irregular kernel graphs) hold dozens of pending kernels, so this
//! module treats order selection as a *search problem* over the same
//! evaluation engine the sweeps use — [`crate::exec::PreparedWorkload`]
//! with prefix checkpointing — behind one trait:
//!
//! * [`BranchAndBound`] (`"bnb"`) — exact. Walks the same lexicographic
//!   prefix tree as the checkpointed sweep but prunes every subtree
//!   whose admissible lower bound
//!   ([`crate::exec::PreparedWorkload::suffix_lower_bound`], derived
//!   from the fluid model's residual-work / occupancy / bandwidth
//!   invariants) exceeds the incumbent, and collapses
//!   profile-identical kernels to one representative per tree node
//!   ([`crate::gpu::equivalence_classes`] — a `∏ m_c!` tree shrink on
//!   workloads with repeated kernels). Bit-identical optima to
//!   [`crate::perm::sweep`] — including the lexicographic tie-break on
//!   the optimal order — at a fraction of the evaluations; practical to
//!   n ≈ 16–20 where enumeration is impossible.
//! * [`SimulatedAnnealing`] (`"anneal:<seed>"`) — anytime. Seeded
//!   swap/shift moves over launch orders under a geometric cooling
//!   schedule, warm-started from Algorithm 1's order.
//! * [`LocalSearch`] (`"local:<seed>"`) — anytime. First-improvement
//!   descent over the swap + insertion neighborhoods with seeded random
//!   restarts at local optima.
//!
//! Both anytime strategies price each candidate move by its **suffix**:
//! evaluation goes through [`crate::exec::PrefixCursor`], which keeps a
//! checkpoint stack anchored along the incumbent and re-simulates only
//! past the move's first touched position — bit-identical to full
//! evaluation (checkpoint restore is pinned bit-exact), so trajectories
//! are unchanged and the speedup is pure.
//!
//! Every strategy consumes a [`SearchBudget`] (evaluations and/or wall
//! time) and reports a [`SearchOutcome`] carrying the incumbent
//! **trajectory** — each improvement stamped with its evaluation index —
//! so an anytime result is reproducible from `(seed, budget)` alone and
//! quality-vs-budget curves fall out of one run
//! (`benches/search_quality.rs` gates them in CI).
//!
//! Spellings mirror [`crate::sched::registry`]: [`parse_strategy`] maps
//! `"bnb"`, `"anneal:7"`, `"local:3"` onto trait objects, and the
//! [`SearchPolicy`] launch policy (registry spelling
//! `"search[:<strategy>[:<budget>]]"`) lets the coordinator delegate
//! ordering to budgeted search: exact for small windows, anytime beyond
//! [`SearchPolicy::exact_max_n`]. The online streaming scheduler
//! ([`crate::online::OnlineReorderer`]) consumes the same registry per
//! reorder window under a per-decision budget — see
//! `src/search/README.md` for the full offline-vs-online decision
//! guide.

mod anneal;
mod bnb;
mod local;

pub use anneal::SimulatedAnnealing;
pub use bnb::BranchAndBound;
pub use local::LocalSearch;

use crate::exec::{ExecutionBackend, SimulatorBackend};
use crate::gpu::{GpuSpec, KernelProfile};
use crate::sched::LaunchPolicy;
use crate::workloads::{DepGraph, Workload};
use std::time::Duration;

/// Backend factory shared by search strategies (one backend per worker,
/// exactly like [`crate::perm::sweep_with`]).
pub type BackendFactory = dyn Fn() -> Box<dyn ExecutionBackend> + Sync;

/// How much work a search run may spend. Both limits are optional; when
/// both are `None` the strategy runs to its natural completion (exact
/// strategies prove optimality, anytime strategies fall back to their
/// default evaluation budget).
///
/// Evaluation budgets are the *reproducible* limit: a strategy driven by
/// `(seed, max_evals)` alone yields a bit-identical
/// [`SearchOutcome::trajectory`] on every run. Wall-clock budgets are for
/// production latency caps and make trajectories machine-dependent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SearchBudget {
    /// Maximum number of order evaluations (calls into the prepared
    /// workload), counted across all worker threads.
    pub max_evals: Option<u64>,
    /// Maximum wall-clock time.
    pub max_wall: Option<Duration>,
}

impl SearchBudget {
    /// Evaluation-count budget (the reproducible kind).
    pub fn evals(n: u64) -> Self {
        SearchBudget {
            max_evals: Some(n),
            max_wall: None,
        }
    }

    /// No limits: exact strategies prove optimality, anytime strategies
    /// use their default evaluation budget.
    pub fn unlimited() -> Self {
        SearchBudget {
            max_evals: None,
            max_wall: None,
        }
    }

    /// Add a wall-clock cap to this budget.
    pub fn with_wall(mut self, d: Duration) -> Self {
        self.max_wall = Some(d);
        self
    }
}

impl Default for SearchBudget {
    /// 10 000 evaluations — the budget the CI quality gate holds anytime
    /// strategies to (`benches/search_quality.rs`).
    fn default() -> Self {
        SearchBudget::evals(DEFAULT_ANYTIME_EVALS)
    }
}

/// Default evaluation budget for anytime strategies when none is given.
pub const DEFAULT_ANYTIME_EVALS: u64 = 10_000;

/// One incumbent improvement: after `eval` evaluations the best-known
/// makespan dropped to `best_ms`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IncumbentSample {
    pub eval: u64,
    pub best_ms: f64,
}

/// What a search run found.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// The strategy's registry spelling (e.g. `"anneal:7"`).
    pub strategy: String,
    /// Best makespan found (`NaN` if the workload is unsimulable).
    pub best_ms: f64,
    /// The order achieving it — always a permutation of the workload.
    pub best_order: Vec<usize>,
    /// Order evaluations actually spent.
    pub evals: u64,
    /// `true` iff the result is *provably optimal* (branch-and-bound ran
    /// to completion without exhausting its budget, or a DAG search
    /// exhaustively enumerated the constrained space). Anytime
    /// strategies report `false` except on that small-`n` DAG exact
    /// path.
    pub complete: bool,
    /// Incumbent improvements in evaluation order. Deterministic for the
    /// seeded anytime strategies under an evaluation budget; for the
    /// parallel exact solver only the final entry is meaningful.
    pub trajectory: Vec<IncumbentSample>,
    /// Subtrees cut by the admissible bound (exact solver only; anytime
    /// strategies report 0).
    pub pruned_subtrees: u64,
    /// Wall-clock time of the whole search (reporting only — never
    /// compare for determinism).
    pub wall_ms: f64,
}

/// A launch-order search strategy over one workload.
///
/// Implementations evaluate orders exclusively through
/// [`crate::exec::ExecutionBackend::prepare`] handles built from
/// `make_backend`, so any substrate that implements the prepared seam —
/// including checkpoint-free ones — is searchable.
pub trait SearchStrategy: Send + Sync {
    /// Registry spelling (accepted back by [`parse_strategy`]).
    fn name(&self) -> String;

    /// Search for a good launch order within `budget`.
    fn search(
        &self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        make_backend: &BackendFactory,
        budget: &SearchBudget,
    ) -> SearchOutcome;

    /// Search a **dependency-aware** workload: only topological orders
    /// of `workload`'s precedence DAG are evaluated or returned. A
    /// workload without edges must behave bit-identically to
    /// [`SearchStrategy::search`] (the default and every built-in
    /// strategy delegate). For constrained workloads the default runs
    /// the exhaustive constrained sweep
    /// ([`crate::perm::sweep_dag_with`]) — exact, but priced at the
    /// graph's full linear-extension count; the built-in strategies
    /// override it with their own dependency-aware search.
    ///
    /// # Panics
    /// On a malformed dependency list — validate with
    /// [`crate::workloads::validate_dag_workload`] first.
    fn search_dag(
        &self,
        gpu: &GpuSpec,
        workload: &Workload,
        make_backend: &BackendFactory,
        budget: &SearchBudget,
    ) -> SearchOutcome {
        let graph = dag_graph_or_panic(workload);
        if !graph.has_deps() {
            return self.search(gpu, &workload.kernels, make_backend, budget);
        }
        let _ = budget; // exhaustive: exactness over budget adherence
        exact_dag_outcome(self.name(), gpu, &workload.kernels, &graph, make_backend)
    }
}

/// Compile a workload's dependency list, panicking with the actionable
/// [`crate::workloads::DagError`] message on malformed input — the
/// shared entry guard of every [`SearchStrategy::search_dag`].
pub(crate) fn dag_graph_or_panic(workload: &Workload) -> DepGraph {
    workload
        .dep_graph()
        .unwrap_or_else(|e| panic!("invalid dependency workload: {e}"))
}

/// Largest `n` for which an anytime strategy's [`SearchStrategy::search_dag`]
/// may run the exact constrained sweep instead of sampling moves. Mirrors
/// [`crate::online::OnlineReorderer`]'s exact-vs-anytime cut (8! = 40 320
/// evaluations worst case, and DAG constraints only shrink that).
pub(crate) const DAG_EXACT_MAX_N: usize = 8;

/// Should an anytime strategy answer a DAG search exactly? Yes when the
/// workload is small (`n` ≤ [`DAG_EXACT_MAX_N`]) and the evaluation
/// budget provably covers the whole constrained space (an unlimited
/// budget always does). This is what pins the anytime strategies
/// bit-identical to the filtered exhaustive sweep at small `n`
/// (`benches/search_quality.rs` gates it on every DAG family).
pub(crate) fn dag_exact_covered(graph: &DepGraph, budget: &SearchBudget) -> bool {
    if graph.n() > DAG_EXACT_MAX_N {
        return false;
    }
    match (budget.max_evals, graph.linear_extension_count()) {
        (None, Some(_)) => true,
        (Some(cap), Some(ext)) => ext <= cap as u128,
        _ => false,
    }
}

/// Run the exhaustive constrained sweep and wrap it as a provably
/// complete [`SearchOutcome`] — best makespan *and* order bit-identical
/// to [`crate::perm::sweep_dag_with`] (same lexicographic tie-break).
pub(crate) fn exact_dag_outcome(
    strategy: String,
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    graph: &DepGraph,
    make_backend: &BackendFactory,
) -> SearchOutcome {
    let t0 = std::time::Instant::now();
    let r = crate::perm::sweep_dag_with(gpu, kernels, graph, make_backend);
    SearchOutcome {
        strategy,
        best_ms: r.best_ms,
        best_order: r.best_order.clone(),
        evals: r.n_perms as u64,
        complete: true,
        trajectory: vec![IncumbentSample {
            eval: r.n_perms as u64,
            best_ms: r.best_ms,
        }],
        pruned_subtrees: 0,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    }
}

/// The sweep's exact incumbent predicate — a strictly better makespan,
/// or a bit-exact tie broken toward the lexicographically smaller
/// order. Every search path (anytime incumbents, branch-and-bound
/// per-task bests, the parallel merge) must share this one definition:
/// bnb's bit-identity to [`crate::perm::sweep`] depends on the
/// tie-break never drifting between copies. NaN never improves.
#[inline]
pub(crate) fn improves(t_ms: f64, order: &[usize], best_ms: f64, best_order: &[usize]) -> bool {
    t_ms < best_ms || (t_ms == best_ms && order < best_order)
}

/// Sequential incumbent tracker shared by the anytime strategies: exact
/// lexicographic tie-breaks (identical to [`crate::perm::sweep`]) and
/// improvement-trajectory recording.
pub(crate) struct Incumbent {
    pub best_ms: f64,
    pub best_order: Vec<usize>,
    pub trajectory: Vec<IncumbentSample>,
}

impl Default for Incumbent {
    fn default() -> Self {
        Incumbent::new()
    }
}

impl Incumbent {
    pub fn new() -> Self {
        Incumbent {
            best_ms: f64::INFINITY,
            best_order: Vec::new(),
            trajectory: Vec::new(),
        }
    }

    /// Fold one evaluated order in. NaN (unsimulable) never wins.
    pub fn offer(&mut self, eval: u64, t_ms: f64, order: &[usize]) {
        if improves(t_ms, order, self.best_ms, &self.best_order) {
            let improved = t_ms < self.best_ms;
            self.best_ms = t_ms;
            self.best_order.clear();
            self.best_order.extend_from_slice(order);
            if improved {
                self.trajectory.push(IncumbentSample {
                    eval,
                    best_ms: t_ms,
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Strategy registry (mirrors sched::registry)
// ---------------------------------------------------------------------------

/// One registered strategy: canonical spelling, aliases, description and
/// constructor (seeded spellings use seed 0 here; [`parse_strategy`]
/// handles the `:<seed>` parameter directly).
pub struct StrategyEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub description: &'static str,
    make: fn() -> Box<dyn SearchStrategy>,
}

/// The strategy registry — single source of truth for spellings.
pub static STRATEGIES: &[StrategyEntry] = &[
    StrategyEntry {
        name: "bnb",
        aliases: &["exact", "branch-and-bound"],
        description: "exact branch-and-bound over the checkpointed prefix tree (provably optimal)",
        make: || Box::new(BranchAndBound::new()),
    },
    StrategyEntry {
        name: "anneal:<seed>",
        aliases: &["sa:<seed>"],
        description: "anytime seeded simulated annealing (swap/shift moves, geometric cooling)",
        make: || Box::new(SimulatedAnnealing::new(0)),
    },
    StrategyEntry {
        name: "local:<seed>",
        aliases: &["ls:<seed>"],
        description: "anytime first-improvement swap/insertion local search with seeded restarts",
        make: || Box::new(LocalSearch::new(0)),
    },
];

/// Error for unknown strategy spellings; `Display` lists the valid names.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StrategyParseError {
    pub input: String,
}

impl std::fmt::Display for StrategyParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let names: Vec<&str> = STRATEGIES.iter().map(|e| e.name).collect();
        write!(
            f,
            "unknown search strategy `{}` — valid strategies: {}",
            self.input,
            names.join(", ")
        )
    }
}

impl std::error::Error for StrategyParseError {}

/// Parse a strategy spelling into a trait object.
///
/// ```
/// let s = kreorder::search::parse_strategy("anneal:42").unwrap();
/// assert_eq!(s.name(), "anneal:42");
/// assert!(kreorder::search::parse_strategy("nope").is_err());
/// ```
pub fn parse_strategy(s: &str) -> Result<Box<dyn SearchStrategy>, StrategyParseError> {
    let lower = s.to_ascii_lowercase();
    let err = || StrategyParseError { input: s.into() };
    let (head, param) = match lower.split_once(':') {
        Some((h, p)) => (h, Some(p)),
        None => (lower.as_str(), None),
    };
    let seed = |p: Option<&str>| -> Result<u64, StrategyParseError> {
        match p {
            None => Ok(0),
            Some(x) => x.parse().map_err(|_| err()),
        }
    };
    match head {
        "bnb" | "exact" | "branch-and-bound" if param.is_none() => {
            Ok(Box::new(BranchAndBound::new()))
        }
        "anneal" | "sa" => Ok(Box::new(SimulatedAnnealing::new(seed(param)?))),
        "local" | "ls" => Ok(Box::new(LocalSearch::new(seed(param)?))),
        _ => Err(err()),
    }
}

/// Parse a strategy spelling into its **reference configuration**: the
/// anytime strategies with prefix-reuse (cursor) evaluation disabled,
/// branch-and-bound with the identical-kernel symmetry collapse
/// disabled. Results are bit-identical to [`parse_strategy`]'s fast
/// configurations by construction — this exists so
/// `kreorder search --compare-eval` and the equivalence pins can verify
/// exactly that while measuring the speedup.
pub fn parse_strategy_reference(s: &str) -> Result<Box<dyn SearchStrategy>, StrategyParseError> {
    // Derive from the one real parser (aliases, seed handling, errors all
    // live there) and rebuild the reference config from the *canonical*
    // name it reports — so the two paths cannot drift on spellings. A
    // future strategy without a reference configuration falls through to
    // an error instead of silently diverging.
    let canonical = parse_strategy(s)?.name();
    let (head, param) = match canonical.split_once(':') {
        Some((h, p)) => (h, Some(p)),
        None => (canonical.as_str(), None),
    };
    let seed = param
        .map(|p| p.parse::<u64>().expect("canonical names carry numeric seeds"))
        .unwrap_or(0);
    match head {
        "bnb" => Ok(Box::new(BranchAndBound::without_symmetry())),
        "anneal" => Ok(Box::new(SimulatedAnnealing::new(seed).full_evaluation())),
        "local" => Ok(Box::new(LocalSearch::new(seed).full_evaluation())),
        _ => Err(StrategyParseError { input: s.into() }),
    }
}

/// One representative instance of every registered strategy (seeded
/// strategies use seed 0).
pub fn all_strategies() -> Vec<Box<dyn SearchStrategy>> {
    STRATEGIES.iter().map(|e| (e.make)()).collect()
}

/// Human-readable registry table (one line per strategy).
pub fn strategy_help_table() -> String {
    let mut out = String::new();
    for e in STRATEGIES {
        let alias_note = if e.aliases.is_empty() {
            String::new()
        } else {
            format!(" (aliases: {})", e.aliases.join(", "))
        };
        out.push_str(&format!("  {:<20} {}{alias_note}\n", e.name, e.description));
    }
    out
}

// ---------------------------------------------------------------------------
// Coordinator integration: search as a launch policy
// ---------------------------------------------------------------------------

/// Default evaluation budget for [`SearchPolicy`] — small enough that a
/// per-batch search stays in the coordinator's latency envelope, large
/// enough that the exact path (n ≤ [`SearchPolicy::exact_max_n`], whose
/// full tree is 5! + 1 = 121 evaluations) always runs to completion.
/// Past the cap the incumbent is still at least as good as the
/// Algorithm 1 warm start.
pub const DEFAULT_POLICY_EVALS: u64 = 256;

/// A [`LaunchPolicy`] that delegates order selection to budgeted search
/// on the simulator model: exact branch-and-bound for windows of up to
/// [`SearchPolicy::exact_max_n`] kernels, the configured anytime
/// strategy beyond that. Registry spelling:
/// `search[:<strategy>[:<budget-evals>]]` (e.g. `search:anneal:7:5000`).
#[derive(Debug, Clone)]
pub struct SearchPolicy {
    /// Anytime strategy spelling used for windows larger than
    /// `exact_max_n` (e.g. `"local:0"`, `"anneal:7"`).
    pub strategy: String,
    /// Evaluation budget per batch.
    pub budget_evals: u64,
    /// Window sizes up to this run exact branch-and-bound instead. The
    /// default (5) is the largest n whose full tree (n! + warm start)
    /// provably fits the default budget, so the exact path always runs
    /// to completion — a budget-exhausted *parallel* solve is not
    /// bit-reproducible, and a policy must be deterministic.
    pub exact_max_n: usize,
}

impl SearchPolicy {
    pub fn new() -> Self {
        SearchPolicy {
            strategy: "local:0".into(),
            budget_evals: DEFAULT_POLICY_EVALS,
            exact_max_n: 5,
        }
    }

    /// Policy with an explicit anytime strategy and evaluation budget.
    /// The spelling is validated at parse time by
    /// [`crate::sched::registry::parse`]; an invalid spelling here makes
    /// [`SearchPolicy::order`] fall back to the warm-start order.
    pub fn with(strategy: impl Into<String>, budget_evals: u64) -> Self {
        SearchPolicy {
            strategy: strategy.into(),
            budget_evals,
            exact_max_n: 5,
        }
    }
}

impl Default for SearchPolicy {
    fn default() -> Self {
        SearchPolicy::new()
    }
}

/// `n! + 1` (the exact solver's worst-case evaluation count for `n`
/// kernels, warm start included), or `None` on overflow. Shared with
/// [`crate::online::OnlineReorderer`], whose exact-vs-anytime cut uses
/// the same budget-coverage rule.
pub(crate) fn exact_tree_evals(n: usize) -> Option<u64> {
    let mut f: u64 = 1;
    for i in 2..=n as u64 {
        f = f.checked_mul(i)?;
    }
    f.checked_add(1)
}

impl LaunchPolicy for SearchPolicy {
    fn name(&self) -> String {
        format!("search:{}:{}", self.strategy, self.budget_evals)
    }

    fn order(&self, gpu: &GpuSpec, kernels: &[KernelProfile]) -> Vec<usize> {
        let n = kernels.len();
        if n <= 1 {
            return (0..n).collect();
        }
        let factory: &BackendFactory = &|| Box::new(SimulatorBackend::new());
        let budget = SearchBudget::evals(self.budget_evals);
        // The exact path runs only when the budget provably covers the
        // whole tree: a budget-exhausted *parallel* branch-and-bound is
        // not run-to-run deterministic, and a policy must be.
        let exact_ok = n <= self.exact_max_n
            && exact_tree_evals(n).is_some_and(|need| need <= self.budget_evals);
        let outcome = if exact_ok {
            BranchAndBound::new().search(gpu, kernels, factory, &budget)
        } else {
            match parse_strategy(&self.strategy) {
                // Same determinism rule for directly-constructed
                // policies: only anytime strategies may run budgeted.
                Ok(s) if s.name() != "bnb" => s.search(gpu, kernels, factory, &budget),
                // Invalid or non-anytime strategy spellings (the
                // registry rejects these at parse time): degrade to the
                // greedy order rather than panic inside the coordinator.
                _ => return crate::sched::reorder(gpu, kernels).order,
            }
        };
        if outcome.best_order.len() == n {
            outcome.best_order
        } else {
            (0..n).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::scenario_by_id;

    #[test]
    fn every_registry_spelling_parses() {
        for s in [
            "bnb",
            "exact",
            "branch-and-bound",
            "anneal",
            "anneal:42",
            "sa:7",
            "local",
            "local:3",
            "ls:0",
            "BNB",
            "Anneal:5",
        ] {
            assert!(parse_strategy(s).is_ok(), "{s}");
        }
    }

    #[test]
    fn bad_spellings_error_and_list_names() {
        for s in ["nope", "anneal:x", "local:", "bnb:3"] {
            let err = parse_strategy(s).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(s), "{msg}");
            for name in ["bnb", "anneal:<seed>", "local:<seed>"] {
                assert!(msg.contains(name), "missing {name} in: {msg}");
            }
        }
    }

    #[test]
    fn names_round_trip_through_parse() {
        for s in all_strategies() {
            let reparsed = parse_strategy(&s.name()).unwrap_or_else(|e| panic!("{e}"));
            assert_eq!(reparsed.name(), s.name());
        }
        assert_eq!(parse_strategy("sa:9").unwrap().name(), "anneal:9");
        assert_eq!(parse_strategy("ls:9").unwrap().name(), "local:9");
    }

    #[test]
    fn reference_spellings_parse_and_share_names() {
        // The reference (full-evaluation / no-symmetry) configurations
        // accept exactly the registry spellings and keep the same names:
        // they differ only in evaluation mechanics, never in results.
        for s in ["bnb", "anneal:7", "local:3", "sa:1", "ls:2"] {
            let fast = parse_strategy(s).unwrap();
            let reference = parse_strategy_reference(s).unwrap();
            assert_eq!(fast.name(), reference.name(), "{s}");
        }
        assert!(parse_strategy_reference("nope").is_err());
        assert!(parse_strategy_reference("bnb:3").is_err());
    }

    #[test]
    fn help_table_covers_registry() {
        let t = strategy_help_table();
        for e in STRATEGIES {
            assert!(t.contains(e.name));
        }
    }

    #[test]
    fn search_policy_emits_permutation_on_both_paths() {
        let gpu = crate::gpu::GpuSpec::gtx580();
        let policy = SearchPolicy::with("local:1", 200);
        // Exact path (n ≤ exact_max_n) …
        let small = scenario_by_id("uniform").unwrap().workload(&gpu, 5, 3);
        let order = policy.order(&gpu, &small);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..5).collect::<Vec<_>>());
        // … and the anytime path.
        let large = scenario_by_id("uniform").unwrap().workload(&gpu, 9, 3);
        let order = policy.order(&gpu, &large);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn search_policy_never_runs_nondeterministic_bnb() {
        let gpu = crate::gpu::GpuSpec::gtx580();
        // A directly-constructed bnb strategy (the registry rejects the
        // spelling) degrades to the deterministic greedy order instead
        // of running a budget-capped parallel solve.
        let ks = scenario_by_id("uniform").unwrap().workload(&gpu, 8, 1);
        let p = SearchPolicy::with("bnb", 100);
        assert_eq!(p.order(&gpu, &ks), crate::sched::reorder(&gpu, &ks).order);
        // A budget below the exact tree (5! + 1 = 121) routes even a
        // small window to the sequential anytime strategy; two runs must
        // agree exactly.
        let small = scenario_by_id("uniform").unwrap().workload(&gpu, 5, 1);
        let p = SearchPolicy::with("local:0", 50);
        assert_eq!(p.order(&gpu, &small), p.order(&gpu, &small));
    }

    #[test]
    fn search_dag_empty_deps_matches_plain_search() {
        // Acceptance criterion: a workload without edges must behave
        // bit-identically to the pre-DAG search on every strategy.
        let gpu = crate::gpu::GpuSpec::gtx580();
        let ks = scenario_by_id("uniform").unwrap().workload(&gpu, 6, 3);
        let w = Workload::independent(ks.clone());
        let factory: &BackendFactory = &|| Box::new(SimulatorBackend::new());
        let budget = SearchBudget::evals(500);
        for s in all_strategies() {
            let a = s.search_dag(&gpu, &w, factory, &budget);
            let b = s.search(&gpu, &ks, factory, &budget);
            assert_eq!(a.best_ms.to_bits(), b.best_ms.to_bits(), "{}", s.name());
            assert_eq!(a.best_order, b.best_order, "{}", s.name());
            assert_eq!(a.evals, b.evals, "{}", s.name());
        }
    }

    #[test]
    fn search_dag_exact_matches_constrained_sweep_on_all_strategies() {
        // Unbudgeted DAG search — exact bnb and the anytime strategies'
        // small-n exact path alike — must be bit-identical to the
        // filtered exhaustive sweep, lexicographic tie-break included.
        let gpu = crate::gpu::GpuSpec::gtx580();
        let w = crate::workloads::dag_scenario_by_id("layered")
            .unwrap()
            .workload(&gpu, 6, 5);
        let graph = w.dep_graph().unwrap();
        assert!(graph.has_deps());
        let factory: &BackendFactory = &|| Box::new(SimulatorBackend::new());
        let sweep = crate::perm::sweep_dag_with(&gpu, &w.kernels, &graph, factory);
        for s in all_strategies() {
            let out = s.search_dag(&gpu, &w, factory, &SearchBudget::unlimited());
            assert_eq!(out.best_ms.to_bits(), sweep.best_ms.to_bits(), "{}", s.name());
            assert_eq!(out.best_order, sweep.best_order, "{}", s.name());
            assert!(out.complete, "{}", s.name());
            assert!(graph.is_topological(&out.best_order), "{}", s.name());
        }
    }

    #[test]
    fn budgeted_dag_search_is_deterministic_and_feasible() {
        // Past the exact cut (n > 8), anytime DAG search runs the
        // feasibility-rejecting move loops: two runs must agree exactly,
        // every returned order must be topological, and the proposal
        // budget must be respected. Budgeted DAG bnb is sequential, so
        // it is deterministic too.
        let gpu = crate::gpu::GpuSpec::gtx580();
        let w = crate::workloads::dag_scenario_by_id("layered")
            .unwrap()
            .workload(&gpu, 10, 2);
        let graph = w.dep_graph().unwrap();
        let factory: &BackendFactory = &|| Box::new(SimulatorBackend::new());
        for spell in ["anneal:7", "local:3"] {
            let s = parse_strategy(spell).unwrap();
            let budget = SearchBudget::evals(400);
            let a = s.search_dag(&gpu, &w, factory, &budget);
            let b = s.search_dag(&gpu, &w, factory, &budget);
            assert_eq!(a.best_ms.to_bits(), b.best_ms.to_bits(), "{spell}");
            assert_eq!(a.best_order, b.best_order, "{spell}");
            assert_eq!(a.evals, b.evals, "{spell}");
            assert!(a.evals <= 400, "{spell}: {}", a.evals);
            assert!(graph.is_topological(&a.best_order), "{spell}");
        }
        let s = parse_strategy("bnb").unwrap();
        let budget = SearchBudget::evals(50);
        let a = s.search_dag(&gpu, &w, factory, &budget);
        let b = s.search_dag(&gpu, &w, factory, &budget);
        assert_eq!(a.best_ms.to_bits(), b.best_ms.to_bits());
        assert_eq!(a.best_order, b.best_order);
        assert!(!a.complete);
        assert!(graph.is_topological(&a.best_order));
    }

    #[test]
    fn search_policy_name_spells_its_config() {
        assert_eq!(
            SearchPolicy::with("anneal:7", 500).name(),
            "search:anneal:7:500"
        );
    }
}
