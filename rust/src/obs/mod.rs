//! **Observability** — structured tracing threaded through every
//! execution layer.
//!
//! End-of-run aggregates (`OnlineReport`, `FleetReport`, `BENCH_*.json`)
//! say *what* happened; this module records *why*: every window-close
//! verdict, admission decision, reorder outcome (incumbent vs FIFO),
//! route choice (with the per-device load snapshot it saw), batch
//! start/finish, fault, retry, shed and worker panic becomes a typed
//! [`TraceEvent`] on the run's virtual clock (wall clock in the live
//! coordinator). A [`TraceSink`] receives them; the registry spellings
//! (the eighth [`crate::registry`] kind):
//!
//! | spelling | behavior |
//! |---|---|
//! | `none` | strict no-op: the engines skip event construction entirely |
//! | `ring:<cap>` | bounded in-memory recorder keeping the last `cap` events |
//! | `jsonl:<path>` | buffer JSON lines in memory; write `<path>` on [`TraceSink::flush`] |
//!
//! The contract that makes tracing safe to leave wired in everywhere is
//! the same discipline `admission=none` established: **the sink
//! observes, never perturbs**. With [`NoTrace`] every engine is
//! bit-identical (timestamps and reports) *and allocation-free* versus
//! the untraced entry points — all event construction sits behind one
//! `if !sink.is_noop()` branch per site, and the public untraced
//! functions literally delegate to the traced ones with a [`NoTrace`]
//! sink (pinned in `tests/trace_observability.rs`). With `ring`/`jsonl`
//! the event stream is bit-deterministic per (seed, config), so traces
//! are replay artifacts, not approximations.
//!
//! [`export`] turns recorded streams into artifacts: JSON-lines
//! round-tripping, Chrome trace-event JSON (one lane per device; loads
//! in `chrome://tracing` and Perfetto) and a deterministic
//! [`Counters`] snapshot. The CLI surfaces all of it as
//! `--trace FILE[:SINK]` on `serve`/`fleet`/`fault`/`search` and
//! `kreorder trace inspect FILE`.

use std::collections::VecDeque;
use std::fmt;

pub mod export;

/// One observed decision or state transition, stamped with the run's
/// virtual-clock time (`t_ms`; milliseconds since wall-clock service
/// start in the live coordinator). Every variant carries its
/// device/kernel provenance so a stream can be sliced per lane.
///
/// The serialized spelling (one JSON object per event) is
/// [`export::to_jsonl_line`]; it round-trips through
/// [`export::parse_jsonl_line`].
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A kernel arrived (was submitted).
    Arrival { t_ms: f64, id: u64 },
    /// An admission policy ruled on an arrival. `predicted_sojourn_ms`
    /// is the priced bound the policy saw (`NaN` when unpriced).
    Admission {
        t_ms: f64,
        id: u64,
        policy: String,
        admitted: bool,
        queue_depth: usize,
        predicted_sojourn_ms: f64,
    },
    /// A window policy ruled Close (`close = true`) or Wait, seeing
    /// `n_pending` open kernels and `queued_batches` closed-but-unstarted
    /// batches on `device`.
    WindowDecide {
        t_ms: f64,
        device: usize,
        n_pending: usize,
        queued_batches: usize,
        close: bool,
    },
    /// A reorder decision for a closing batch: the strategy spelling,
    /// evaluations spent, whether the FIFO guard degraded the decision,
    /// and the modeled makespans of the chosen order vs FIFO arrival
    /// order (recomputed on a fresh backend — observation only).
    ReorderDecision {
        t_ms: f64,
        device: usize,
        batch: u64,
        n: usize,
        strategy: String,
        evals: u64,
        degraded: bool,
        chosen_ms: f64,
        fifo_ms: f64,
    },
    /// A routing policy placed kernel `id` on `device`, seeing the
    /// per-device load snapshot (`outstanding` kernels and `free_at_ms`)
    /// it decided against.
    RouteDecision {
        t_ms: f64,
        id: u64,
        device: usize,
        policy: String,
        outstanding: Vec<usize>,
        free_at_ms: Vec<f64>,
    },
    /// A batch began service on `device` in launch order `order`
    /// (positions into the batch).
    BatchStart {
        t_ms: f64,
        device: usize,
        batch: u64,
        n: usize,
        order: Vec<usize>,
    },
    /// A batch completed service.
    BatchFinish { t_ms: f64, device: usize, batch: u64, makespan_ms: f64 },
    /// A fault-plan action fired on `device` (`"down"`, `"recover"`,
    /// `"slow:<factor>"`) or a launch failure was injected
    /// (`"launchfail"`).
    Fault { t_ms: f64, device: usize, action: String },
    /// A failed launch was parked for its `attempt`-th retry after
    /// `backoff_ms` of exponential backoff.
    Retry { t_ms: f64, id: u64, attempt: u32, backoff_ms: f64 },
    /// A kernel left the system unserved; `cause` is the stable
    /// [`crate::fleet::ShedCause::to_csv`] spelling.
    Shed { t_ms: f64, id: u64, cause: String },
    /// A coordinator device worker caught a panic (live path only).
    WorkerPanic { t_ms: f64, device: usize, message: String },
    /// An anytime-search incumbent improved: `best_ms` at evaluation
    /// `eval` under `strategy`. Emitted from recorded trajectories by
    /// [`trajectory_events`]; carries no clock (search is offline).
    Incumbent { eval: u64, best_ms: f64, strategy: String },
}

impl TraceEvent {
    /// Stable machine spelling of the variant, used as the `"type"`
    /// field of the JSON-lines form.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::Arrival { .. } => "arrival",
            TraceEvent::Admission { .. } => "admission",
            TraceEvent::WindowDecide { .. } => "window",
            TraceEvent::ReorderDecision { .. } => "reorder",
            TraceEvent::RouteDecision { .. } => "route",
            TraceEvent::BatchStart { .. } => "batch-start",
            TraceEvent::BatchFinish { .. } => "batch-finish",
            TraceEvent::Fault { .. } => "fault",
            TraceEvent::Retry { .. } => "retry",
            TraceEvent::Shed { .. } => "shed",
            TraceEvent::WorkerPanic { .. } => "panic",
            TraceEvent::Incumbent { .. } => "incumbent",
        }
    }

    /// The event's clock stamp (`None` for [`TraceEvent::Incumbent`],
    /// which is indexed by evaluation count, not time).
    pub fn t_ms(&self) -> Option<f64> {
        match self {
            TraceEvent::Arrival { t_ms, .. }
            | TraceEvent::Admission { t_ms, .. }
            | TraceEvent::WindowDecide { t_ms, .. }
            | TraceEvent::ReorderDecision { t_ms, .. }
            | TraceEvent::RouteDecision { t_ms, .. }
            | TraceEvent::BatchStart { t_ms, .. }
            | TraceEvent::BatchFinish { t_ms, .. }
            | TraceEvent::Fault { t_ms, .. }
            | TraceEvent::Retry { t_ms, .. }
            | TraceEvent::Shed { t_ms, .. }
            | TraceEvent::WorkerPanic { t_ms, .. } => Some(*t_ms),
            TraceEvent::Incumbent { .. } => None,
        }
    }
}

/// Receiver side of the tracing seam. Implementations must be cheap to
/// call on the engines' hot paths and must never influence what the
/// engines do: `record` has no return value the caller could branch on.
///
/// Engines check [`is_noop`](TraceSink::is_noop) **once** and skip all
/// event construction when it holds — that branch is what makes
/// `trace=none` allocation-free, not any property of [`NoTrace`]
/// itself.
pub trait TraceSink: Send {
    /// Canonical registry spelling (reparsing it yields an equivalent
    /// sink).
    fn name(&self) -> String;

    /// `true` only for [`NoTrace`]: callers skip event construction
    /// entirely, which is what pins traced engines bit-identical and
    /// allocation-free to the untraced ones under `none`.
    fn is_noop(&self) -> bool {
        false
    }

    /// Record one event. Must not fail and must not observe-then-act:
    /// sinks never feed back into the run.
    fn record(&mut self, ev: TraceEvent);

    /// Commit buffered output (the `jsonl` sink writes its file here;
    /// in-memory sinks are a no-op). Callers flush once, after the run.
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// `none`: the strict no-op sink. See [`TraceSink::is_noop`].
#[derive(Debug, Clone, Copy, Default)]
pub struct NoTrace;

impl TraceSink for NoTrace {
    fn name(&self) -> String {
        "none".into()
    }
    fn is_noop(&self) -> bool {
        true
    }
    fn record(&mut self, _ev: TraceEvent) {}
}

/// `ring:<cap>`: bounded in-memory recorder keeping the most recent
/// `cap` events. The CLI's `--trace FILE:chrome` path records into a
/// large ring and exports after the run.
#[derive(Debug, Clone)]
pub struct RingSink {
    cap: usize,
    buf: VecDeque<TraceEvent>,
}

impl RingSink {
    /// `cap` is clamped to ≥ 1 (a zero-capacity recorder records
    /// nothing and would silently violate the replay contract).
    pub fn new(cap: usize) -> RingSink {
        RingSink { cap: cap.max(1), buf: VecDeque::new() }
    }

    /// The retained events, oldest first.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.buf.iter().cloned().collect()
    }

    /// Number of retained events (≤ cap).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing has been recorded (or everything was evicted).
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

impl TraceSink for RingSink {
    fn name(&self) -> String {
        format!("ring:{}", self.cap)
    }
    fn record(&mut self, ev: TraceEvent) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(ev);
    }
}

/// `jsonl:<path>`: serialize each event to one JSON line
/// ([`export::to_jsonl_line`]) in memory, and write the whole file on
/// [`TraceSink::flush`]. Parsing the spelling never touches the
/// filesystem — hostile-input tables parse arbitrary spellings — and
/// neither does recording; only an explicit flush creates `<path>`.
#[derive(Debug, Clone)]
pub struct JsonlSink {
    path: String,
    lines: Vec<String>,
}

impl JsonlSink {
    pub fn new(path: &str) -> JsonlSink {
        JsonlSink { path: path.to_string(), lines: Vec::new() }
    }

    /// The serialized lines buffered so far (no trailing newline per
    /// entry), for tests and in-process inspection.
    pub fn lines(&self) -> &[String] {
        &self.lines
    }
}

impl TraceSink for JsonlSink {
    fn name(&self) -> String {
        format!("jsonl:{}", self.path)
    }
    fn record(&mut self, ev: TraceEvent) {
        self.lines.push(export::to_jsonl_line(&ev));
    }
    fn flush(&mut self) -> std::io::Result<()> {
        let mut text = String::new();
        for line in &self.lines {
            text.push_str(line);
            text.push('\n');
        }
        std::fs::write(&self.path, text)
    }
}

/// Rejected trace-sink spelling; lists the valid forms.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceParseError {
    pub input: String,
}

impl fmt::Display for TraceParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown trace sink `{}` — valid sinks: none, ring:<cap>, \
             jsonl:<path> (cap ≥ 1; path non-empty; parsing never touches \
             the filesystem)",
            self.input
        )
    }
}

impl std::error::Error for TraceParseError {}

/// Parse a trace-sink spelling (see the module table). `ring` caps must
/// be ≥ 1 with no trailing garbage; `jsonl` paths are everything after
/// the first `:` and may themselves contain colons. Parsing never
/// creates or opens files.
pub fn parse_trace_sink(spec: &str) -> Result<Box<dyn TraceSink>, TraceParseError> {
    let err = || TraceParseError { input: spec.to_string() };
    let trimmed = spec.trim();
    if trimmed == "none" {
        return Ok(Box::new(NoTrace));
    }
    if let Some(rest) = trimmed.strip_prefix("ring:") {
        let cap: usize = rest.parse().map_err(|_| err())?;
        if cap == 0 {
            return Err(err());
        }
        return Ok(Box::new(RingSink::new(cap)));
    }
    if let Some(path) = trimmed.strip_prefix("jsonl:") {
        if path.is_empty() {
            return Err(err());
        }
        return Ok(Box::new(JsonlSink::new(path)));
    }
    Err(err())
}

/// One line per registered trace-sink spelling, for `kreorder list
/// --kind trace` and the shared registry cheat sheet.
pub fn trace_help_table() -> String {
    let rows: [(&str, &str); 3] = [
        ("none", "strict no-op (default; engines bit-identical and allocation-free)"),
        ("ring:<cap>", "bounded in-memory recorder keeping the last <cap> events"),
        (
            "jsonl:<path>",
            "buffer one JSON line per event; write <path> on flush after the run",
        ),
    ];
    let mut s = String::new();
    for (name, desc) in rows {
        s.push_str(&format!("  {name:<32} {desc}\n"));
    }
    s
}

/// Down-sample a recorded anytime-search trajectory into
/// [`TraceEvent::Incumbent`] events: every `sample`-th improvement
/// (`sample` clamped to ≥ 1) plus always the final incumbent, so the
/// converged value is never sampled away. Deterministic: a pure
/// function of the outcome.
pub fn trajectory_events(out: &crate::search::SearchOutcome, sample: u64) -> Vec<TraceEvent> {
    let step = sample.max(1) as usize;
    let last = out.trajectory.len().wrapping_sub(1);
    out.trajectory
        .iter()
        .enumerate()
        .filter(|(i, _)| i % step == 0 || *i == last)
        .map(|(_, s)| TraceEvent::Incumbent {
            eval: s.eval,
            best_ms: s.best_ms,
            strategy: out.strategy.clone(),
        })
        .collect()
}

/// Deterministic counters/gauges snapshot derived from an event stream
/// — the `kreorder trace inspect` summary. All maps are
/// [`std::collections::BTreeMap`] so rendering order is stable.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Counters {
    /// Total events in the stream.
    pub n_events: usize,
    /// [`TraceEvent::Arrival`] count.
    pub arrivals: u64,
    /// Admission verdicts that admitted / rejected.
    pub admitted: u64,
    pub rejected: u64,
    /// Batches started / finished.
    pub batches_started: u64,
    pub batches_finished: u64,
    /// Kernels launched (sum of started-batch sizes).
    pub kernels_launched: u64,
    /// Kernels shed, keyed by stable cause spelling.
    pub sheds_by_cause: std::collections::BTreeMap<String, u64>,
    /// Queue depth at end of stream: arrivals − launched − shed
    /// (negative only on truncated ring streams).
    pub final_queue_depth: i64,
    /// Kernels in flight (started, not yet finished) at end of stream,
    /// and the high-water mark over the stream.
    pub final_in_flight: usize,
    pub max_in_flight: usize,
    /// Fault actions, retries and worker panics observed.
    pub faults: u64,
    pub retries: u64,
    pub panics: u64,
    /// Reorder-decision evaluations spent, and that spend as a rate
    /// over the stream's virtual span.
    pub reorder_evals: u64,
    pub evals_per_s: f64,
    /// Stream span: max minus min clock stamp (0 for ≤ 1 stamped
    /// events).
    pub span_ms: f64,
}

impl Counters {
    /// Fold an event stream into the snapshot. Pure and deterministic:
    /// identical streams yield identical (bit-equal) snapshots.
    pub fn from_events(events: &[TraceEvent]) -> Counters {
        let mut c = Counters { n_events: events.len(), ..Counters::default() };
        let mut launched: i64 = 0;
        let mut shed_total: i64 = 0;
        let mut in_flight_sizes: std::collections::BTreeMap<(usize, u64), usize> =
            std::collections::BTreeMap::new();
        let mut in_flight: usize = 0;
        let (mut t_lo, mut t_hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for ev in events {
            if let Some(t) = ev.t_ms() {
                t_lo = t_lo.min(t);
                t_hi = t_hi.max(t);
            }
            match ev {
                TraceEvent::Arrival { .. } => c.arrivals += 1,
                TraceEvent::Admission { admitted, .. } => {
                    if *admitted {
                        c.admitted += 1;
                    } else {
                        c.rejected += 1;
                    }
                }
                TraceEvent::ReorderDecision { evals, .. } => c.reorder_evals += *evals,
                TraceEvent::BatchStart { device, batch, n, .. } => {
                    c.batches_started += 1;
                    c.kernels_launched += *n as u64;
                    launched += *n as i64;
                    in_flight_sizes.insert((*device, *batch), *n);
                    in_flight += *n;
                    c.max_in_flight = c.max_in_flight.max(in_flight);
                }
                TraceEvent::BatchFinish { device, batch, .. } => {
                    c.batches_finished += 1;
                    let n = in_flight_sizes.remove(&(*device, *batch)).unwrap_or(0);
                    in_flight = in_flight.saturating_sub(n);
                }
                TraceEvent::Shed { cause, .. } => {
                    shed_total += 1;
                    *c.sheds_by_cause.entry(cause.clone()).or_insert(0) += 1;
                }
                TraceEvent::Fault { .. } => c.faults += 1,
                TraceEvent::Retry { .. } => c.retries += 1,
                TraceEvent::WorkerPanic { .. } => c.panics += 1,
                TraceEvent::WindowDecide { .. }
                | TraceEvent::RouteDecision { .. }
                | TraceEvent::Incumbent { .. } => {}
            }
        }
        c.final_queue_depth = c.arrivals as i64 - launched - shed_total;
        c.final_in_flight = in_flight;
        c.span_ms = if t_hi > t_lo { t_hi - t_lo } else { 0.0 };
        c.evals_per_s = if c.span_ms > 0.0 {
            c.reorder_evals as f64 / (c.span_ms / 1e3)
        } else {
            0.0
        };
        c
    }

    /// Multi-line human rendering with deterministic ordering.
    pub fn render(&self) -> String {
        let mut s = format!(
            "{} events over {:.2} ms | {} arrivals | {} batches started, {} finished | \
             {} kernels launched\n",
            self.n_events,
            self.span_ms,
            self.arrivals,
            self.batches_started,
            self.batches_finished,
            self.kernels_launched,
        );
        s.push_str(&format!(
            "  queue depth (final) {:>6} | in flight (final/max) {}/{}\n",
            self.final_queue_depth, self.final_in_flight, self.max_in_flight,
        ));
        s.push_str(&format!(
            "  admission: {} admitted, {} rejected | faults {} | retries {} | panics {}\n",
            self.admitted, self.rejected, self.faults, self.retries, self.panics,
        ));
        s.push_str(&format!(
            "  reorder evals {} ({:.1} evals/s over the span)",
            self.reorder_evals, self.evals_per_s,
        ));
        if !self.sheds_by_cause.is_empty() {
            let total: u64 = self.sheds_by_cause.values().sum();
            s.push_str(&format!("\n  sheds: {total} total"));
            for (cause, n) in &self.sheds_by_cause {
                s.push_str(&format!("\n    {cause:<24} {n}"));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev_arrival(t: f64, id: u64) -> TraceEvent {
        TraceEvent::Arrival { t_ms: t, id }
    }

    #[test]
    fn none_is_the_noop_and_names_itself() {
        let mut s = parse_trace_sink("none").unwrap();
        assert!(s.is_noop());
        assert_eq!(s.name(), "none");
        s.record(ev_arrival(0.0, 0));
        assert!(s.flush().is_ok());
    }

    #[test]
    fn ring_keeps_the_most_recent_cap_events() {
        let mut r = RingSink::new(3);
        assert!(!r.is_noop());
        for i in 0..5 {
            r.record(ev_arrival(i as f64, i));
        }
        assert_eq!(r.len(), 3);
        let ids: Vec<u64> = r
            .snapshot()
            .iter()
            .map(|e| match e {
                TraceEvent::Arrival { id, .. } => *id,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, vec![2, 3, 4]);
        assert_eq!(r.name(), "ring:3");
        // Zero caps clamp rather than silently recording nothing.
        assert_eq!(RingSink::new(0).name(), "ring:1");
    }

    #[test]
    fn jsonl_buffers_in_memory_until_flush() {
        let mut s = JsonlSink::new("/nonexistent-dir/never-created.jsonl");
        s.record(ev_arrival(1.5, 7));
        assert_eq!(s.lines().len(), 1);
        assert!(s.lines()[0].contains("\"arrival\""));
        // The path was never touched by parsing or recording; only
        // flush would, and this one fails loudly instead of silently.
        assert!(s.flush().is_err());
    }

    #[test]
    fn hostile_spellings_are_rejected_with_the_echoed_input() {
        for bad in [
            "", " ", "zzz", "none:1", "ring", "ring:", "ring:0", "ring:x", "ring:-1",
            "ring:4:9", "jsonl", "jsonl:", "🚀",
        ] {
            let e = parse_trace_sink(bad).unwrap_err();
            assert!(e.to_string().contains(bad), "`{bad}`: {e}");
            assert!(e.to_string().contains("valid sinks"), "{e}");
        }
    }

    #[test]
    fn canonical_names_reparse() {
        for spec in ["none", "ring:256", "jsonl:/tmp/x.jsonl"] {
            let s = parse_trace_sink(spec).unwrap();
            assert_eq!(s.name(), spec);
            assert_eq!(parse_trace_sink(&s.name()).unwrap().name(), spec);
        }
        // jsonl paths may contain further colons.
        assert_eq!(
            parse_trace_sink("jsonl:a:b.jsonl").unwrap().name(),
            "jsonl:a:b.jsonl"
        );
    }

    #[test]
    fn help_table_names_every_spelling() {
        let t = trace_help_table();
        for name in ["none", "ring", "jsonl"] {
            assert!(t.contains(name), "{t}");
        }
        assert_eq!(t.lines().count(), 3);
    }

    #[test]
    fn counters_fold_a_stream_deterministically() {
        let events = vec![
            ev_arrival(0.0, 0),
            ev_arrival(1.0, 1),
            ev_arrival(2.0, 2),
            TraceEvent::Admission {
                t_ms: 2.0,
                id: 2,
                policy: "bound:1".into(),
                admitted: false,
                queue_depth: 2,
                predicted_sojourn_ms: f64::NAN,
            },
            TraceEvent::Shed { t_ms: 2.0, id: 2, cause: "rejected:bound:1".into() },
            TraceEvent::ReorderDecision {
                t_ms: 3.0,
                device: 0,
                batch: 0,
                n: 2,
                strategy: "local:64".into(),
                evals: 64,
                degraded: false,
                chosen_ms: 9.0,
                fifo_ms: 10.0,
            },
            TraceEvent::BatchStart {
                t_ms: 3.0,
                device: 0,
                batch: 0,
                n: 2,
                order: vec![1, 0],
            },
            TraceEvent::BatchFinish { t_ms: 12.0, device: 0, batch: 0, makespan_ms: 9.0 },
        ];
        let c = Counters::from_events(&events);
        assert_eq!(c.n_events, 8);
        assert_eq!(c.arrivals, 3);
        assert_eq!(c.rejected, 1);
        assert_eq!(c.kernels_launched, 2);
        assert_eq!(c.final_queue_depth, 0);
        assert_eq!(c.max_in_flight, 2);
        assert_eq!(c.final_in_flight, 0);
        assert_eq!(c.sheds_by_cause.get("rejected:bound:1"), Some(&1));
        assert_eq!(c.reorder_evals, 64);
        assert_eq!(c.span_ms, 12.0);
        assert!((c.evals_per_s - 64.0 / 0.012).abs() < 1e-9);
        assert_eq!(c, Counters::from_events(&events));
        let r = c.render();
        assert!(r.contains("3 arrivals"), "{r}");
        assert!(r.contains("rejected:bound:1"), "{r}");
    }

    #[test]
    fn empty_stream_counters_are_all_zero() {
        let c = Counters::from_events(&[]);
        assert_eq!(c, Counters::default());
        assert_eq!(c.span_ms, 0.0);
        assert_eq!(c.evals_per_s, 0.0);
    }
}
