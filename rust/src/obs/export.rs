//! Turning recorded [`TraceEvent`] streams into artifacts: JSON-lines
//! round-tripping, Chrome trace-event JSON for `chrome://tracing` /
//! Perfetto, and a minimal validator for the exported form.
//!
//! Everything here is pure string work over an already-recorded stream
//! — no I/O, no clocks — so exports are bit-deterministic functions of
//! the events, which are themselves bit-deterministic per (seed,
//! config). Numbers are written with Rust's shortest round-trip `f64`
//! formatting, so `to_jsonl_line` → [`parse_jsonl_line`] is exact
//! (non-finite values serialize as `null` and parse back as `NaN`).
//!
//! The Chrome export reconstructs **spans** from the stream rather than
//! translating events one-for-one: each device is a lane (`tid` =
//! device index), each batch a `B`/`E` span, and a crash (`down` fault)
//! on a device *clips* any span still running there to the crash time —
//! otherwise an orphaned batch's recorded finish could land after a
//! post-recovery batch had already started, breaking the per-lane
//! timestamp monotonicity that timeline viewers (and
//! [`validate_chrome_trace`]) require. Instant events (faults, sheds,
//! retries, rejected admissions, panics) ride a dedicated control lane
//! (`tid` = device count).

use super::TraceEvent;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Escape a string for embedding in a JSON string literal.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Shortest round-trip JSON number (`null` for non-finite values).
fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".into()
    }
}

fn usizes(xs: &[usize]) -> String {
    let body: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
    format!("[{}]", body.join(","))
}

fn f64s(xs: &[f64]) -> String {
    let body: Vec<String> = xs.iter().map(|x| num(*x)).collect();
    format!("[{}]", body.join(","))
}

/// Serialize one event as a single JSON object line — the `jsonl` sink
/// format. Field order is fixed, so identical events yield identical
/// bytes (the replay-artifact contract).
pub fn to_jsonl_line(ev: &TraceEvent) -> String {
    match ev {
        TraceEvent::Arrival { t_ms, id } => {
            format!(r#"{{"type":"arrival","t_ms":{},"id":{id}}}"#, num(*t_ms))
        }
        TraceEvent::Admission { t_ms, id, policy, admitted, queue_depth, predicted_sojourn_ms } => {
            format!(
                r#"{{"type":"admission","t_ms":{},"id":{id},"policy":"{}","admitted":{admitted},"queue_depth":{queue_depth},"predicted_sojourn_ms":{}}}"#,
                num(*t_ms),
                esc(policy),
                num(*predicted_sojourn_ms)
            )
        }
        TraceEvent::WindowDecide { t_ms, device, n_pending, queued_batches, close } => {
            format!(
                r#"{{"type":"window","t_ms":{},"device":{device},"n_pending":{n_pending},"queued_batches":{queued_batches},"close":{close}}}"#,
                num(*t_ms)
            )
        }
        TraceEvent::ReorderDecision {
            t_ms,
            device,
            batch,
            n,
            strategy,
            evals,
            degraded,
            chosen_ms,
            fifo_ms,
        } => {
            format!(
                r#"{{"type":"reorder","t_ms":{},"device":{device},"batch":{batch},"n":{n},"strategy":"{}","evals":{evals},"degraded":{degraded},"chosen_ms":{},"fifo_ms":{}}}"#,
                num(*t_ms),
                esc(strategy),
                num(*chosen_ms),
                num(*fifo_ms)
            )
        }
        TraceEvent::RouteDecision { t_ms, id, device, policy, outstanding, free_at_ms } => {
            format!(
                r#"{{"type":"route","t_ms":{},"id":{id},"device":{device},"policy":"{}","outstanding":{},"free_at_ms":{}}}"#,
                num(*t_ms),
                esc(policy),
                usizes(outstanding),
                f64s(free_at_ms)
            )
        }
        TraceEvent::BatchStart { t_ms, device, batch, n, order } => {
            format!(
                r#"{{"type":"batch-start","t_ms":{},"device":{device},"batch":{batch},"n":{n},"order":{}}}"#,
                num(*t_ms),
                usizes(order)
            )
        }
        TraceEvent::BatchFinish { t_ms, device, batch, makespan_ms } => {
            format!(
                r#"{{"type":"batch-finish","t_ms":{},"device":{device},"batch":{batch},"makespan_ms":{}}}"#,
                num(*t_ms),
                num(*makespan_ms)
            )
        }
        TraceEvent::Fault { t_ms, device, action } => {
            format!(
                r#"{{"type":"fault","t_ms":{},"device":{device},"action":"{}"}}"#,
                num(*t_ms),
                esc(action)
            )
        }
        TraceEvent::Retry { t_ms, id, attempt, backoff_ms } => {
            format!(
                r#"{{"type":"retry","t_ms":{},"id":{id},"attempt":{attempt},"backoff_ms":{}}}"#,
                num(*t_ms),
                num(*backoff_ms)
            )
        }
        TraceEvent::Shed { t_ms, id, cause } => {
            format!(
                r#"{{"type":"shed","t_ms":{},"id":{id},"cause":"{}"}}"#,
                num(*t_ms),
                esc(cause)
            )
        }
        TraceEvent::WorkerPanic { t_ms, device, message } => {
            format!(
                r#"{{"type":"panic","t_ms":{},"device":{device},"message":"{}"}}"#,
                num(*t_ms),
                esc(message)
            )
        }
        TraceEvent::Incumbent { eval, best_ms, strategy } => {
            format!(
                r#"{{"type":"incumbent","eval":{eval},"best_ms":{},"strategy":"{}"}}"#,
                num(*best_ms),
                esc(strategy)
            )
        }
    }
}

/// Parse one JSON line back into its event — the exact inverse of
/// [`to_jsonl_line`] (`null` numbers become `NaN`). Errors name the
/// missing or mistyped field.
pub fn parse_jsonl_line(line: &str) -> Result<TraceEvent, String> {
    let o = Json::parse(line).map_err(|e| format!("trace line is not JSON: {e}"))?;
    let ty = o
        .get("type")
        .and_then(|j| j.as_str())
        .ok_or_else(|| "trace line has no `type` field".to_string())?
        .to_string();
    let f = |k: &str| -> Result<f64, String> {
        match o.get(k) {
            Some(Json::Null) => Ok(f64::NAN),
            Some(j) => j.as_f64().ok_or_else(|| format!("field `{k}` is not a number")),
            None => Err(format!("missing field `{k}` on `{ty}`")),
        }
    };
    let u = |k: &str| -> Result<u64, String> { Ok(f(k)? as u64) };
    let us = |k: &str| -> Result<usize, String> { Ok(f(k)? as usize) };
    let s = |k: &str| -> Result<String, String> {
        o.get(k)
            .and_then(|j| j.as_str())
            .map(str::to_string)
            .ok_or_else(|| format!("missing string field `{k}` on `{ty}`"))
    };
    let b = |k: &str| -> Result<bool, String> {
        match o.get(k) {
            Some(Json::Bool(v)) => Ok(*v),
            _ => Err(format!("missing bool field `{k}` on `{ty}`")),
        }
    };
    let arr = |k: &str| -> Result<&[Json], String> {
        o.get(k)
            .and_then(|j| j.as_arr())
            .ok_or_else(|| format!("missing array field `{k}` on `{ty}`"))
    };
    Ok(match ty.as_str() {
        "arrival" => TraceEvent::Arrival { t_ms: f("t_ms")?, id: u("id")? },
        "admission" => TraceEvent::Admission {
            t_ms: f("t_ms")?,
            id: u("id")?,
            policy: s("policy")?,
            admitted: b("admitted")?,
            queue_depth: us("queue_depth")?,
            predicted_sojourn_ms: f("predicted_sojourn_ms")?,
        },
        "window" => TraceEvent::WindowDecide {
            t_ms: f("t_ms")?,
            device: us("device")?,
            n_pending: us("n_pending")?,
            queued_batches: us("queued_batches")?,
            close: b("close")?,
        },
        "reorder" => TraceEvent::ReorderDecision {
            t_ms: f("t_ms")?,
            device: us("device")?,
            batch: u("batch")?,
            n: us("n")?,
            strategy: s("strategy")?,
            evals: u("evals")?,
            degraded: b("degraded")?,
            chosen_ms: f("chosen_ms")?,
            fifo_ms: f("fifo_ms")?,
        },
        "route" => TraceEvent::RouteDecision {
            t_ms: f("t_ms")?,
            id: u("id")?,
            device: us("device")?,
            policy: s("policy")?,
            outstanding: arr("outstanding")?
                .iter()
                .map(|j| j.as_f64().map(|v| v as usize))
                .collect::<Option<Vec<usize>>>()
                .ok_or("non-numeric entry in `outstanding`")?,
            free_at_ms: arr("free_at_ms")?
                .iter()
                .map(|j| match j {
                    Json::Null => Some(f64::NAN),
                    j => j.as_f64(),
                })
                .collect::<Option<Vec<f64>>>()
                .ok_or("non-numeric entry in `free_at_ms`")?,
        },
        "batch-start" => TraceEvent::BatchStart {
            t_ms: f("t_ms")?,
            device: us("device")?,
            batch: u("batch")?,
            n: us("n")?,
            order: arr("order")?
                .iter()
                .map(|j| j.as_f64().map(|v| v as usize))
                .collect::<Option<Vec<usize>>>()
                .ok_or("non-numeric entry in `order`")?,
        },
        "batch-finish" => TraceEvent::BatchFinish {
            t_ms: f("t_ms")?,
            device: us("device")?,
            batch: u("batch")?,
            makespan_ms: f("makespan_ms")?,
        },
        "fault" => TraceEvent::Fault {
            t_ms: f("t_ms")?,
            device: us("device")?,
            action: s("action")?,
        },
        "retry" => TraceEvent::Retry {
            t_ms: f("t_ms")?,
            id: u("id")?,
            attempt: u("attempt")? as u32,
            backoff_ms: f("backoff_ms")?,
        },
        "shed" => TraceEvent::Shed { t_ms: f("t_ms")?, id: u("id")?, cause: s("cause")? },
        "panic" => TraceEvent::WorkerPanic {
            t_ms: f("t_ms")?,
            device: us("device")?,
            message: s("message")?,
        },
        "incumbent" => TraceEvent::Incumbent {
            eval: u("eval")?,
            best_ms: f("best_ms")?,
            strategy: s("strategy")?,
        },
        other => return Err(format!("unknown trace event type `{other}`")),
    })
}

/// Serialize a whole stream as JSON lines (one event per line, trailing
/// newline).
pub fn jsonl(events: &[TraceEvent]) -> String {
    let mut s = String::new();
    for ev in events {
        s.push_str(&to_jsonl_line(ev));
        s.push('\n');
    }
    s
}

/// Parse a JSON-lines stream back into events (blank lines tolerated).
/// Errors carry the 1-based line number of the offending line.
pub fn events_from_jsonl(text: &str) -> Result<Vec<TraceEvent>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        out.push(parse_jsonl_line(line).map_err(|e| format!("line {}: {e}", i + 1))?);
    }
    Ok(out)
}

/// Render an event stream as Chrome trace-event JSON (the
/// `{"traceEvents":[…]}` object form; loads in `chrome://tracing` and
/// Perfetto). One lane per device carries the reconstructed batch
/// spans; lane `D` (one past the last device) carries instant markers
/// for faults, sheds, retries, rejected admissions and panics.
/// Timestamps are microseconds (`t_ms × 1000`). Crash clipping and
/// determinism are documented at the module level; the output always
/// passes [`validate_chrome_trace`].
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    // Lane count: one per device mentioned anywhere, minimum one.
    let mut n_devices = 1usize;
    for ev in events {
        let d = match ev {
            TraceEvent::WindowDecide { device, .. }
            | TraceEvent::ReorderDecision { device, .. }
            | TraceEvent::RouteDecision { device, .. }
            | TraceEvent::BatchStart { device, .. }
            | TraceEvent::BatchFinish { device, .. }
            | TraceEvent::Fault { device, .. }
            | TraceEvent::WorkerPanic { device, .. } => Some(*device),
            _ => None,
        };
        if let Some(d) = d {
            n_devices = n_devices.max(d + 1);
        }
    }

    // Reconstruct batch spans and collect per-device crash times.
    struct Span {
        start_ms: f64,
        end_ms: Option<f64>,
        batch: u64,
        n: usize,
        order: Vec<usize>,
    }
    let mut spans: Vec<Vec<Span>> = (0..n_devices).map(|_| Vec::new()).collect();
    let mut open: BTreeMap<(usize, u64), usize> = BTreeMap::new();
    let mut downs: Vec<Vec<f64>> = (0..n_devices).map(|_| Vec::new()).collect();
    let mut last_t = 0.0f64;
    for ev in events {
        if let Some(t) = ev.t_ms() {
            last_t = last_t.max(t);
        }
        match ev {
            TraceEvent::BatchStart { t_ms, device, batch, n, order } => {
                open.insert((*device, *batch), spans[*device].len());
                spans[*device].push(Span {
                    start_ms: *t_ms,
                    end_ms: None,
                    batch: *batch,
                    n: *n,
                    order: order.clone(),
                });
            }
            TraceEvent::BatchFinish { t_ms, device, batch, .. } => {
                // A finish whose start was evicted from a ring is dropped:
                // a span needs both ends.
                if let Some(i) = open.remove(&(*device, *batch)) {
                    spans[*device][i].end_ms = Some(*t_ms);
                }
            }
            TraceEvent::Fault { t_ms, device, action } if action == "down" => {
                downs[*device].push(*t_ms);
            }
            _ => {}
        }
    }
    // Clip: a `down` fault interrupts any span still running on its
    // device — the span ends at the crash, keeping lanes monotone even
    // though the orphaned finish (if any) was stamped later.
    for (d, dev_spans) in spans.iter_mut().enumerate() {
        for sp in dev_spans.iter_mut() {
            let crash = downs[d].iter().copied().find(|&t| t >= sp.start_ms);
            sp.end_ms = match (sp.end_ms, crash) {
                (Some(e), Some(c)) if c < e => Some(c),
                (Some(e), _) => Some(e),
                (None, Some(c)) => Some(c),
                (None, None) => Some(last_t.max(sp.start_ms)),
            };
        }
        dev_spans.sort_by(|a, b| a.start_ms.total_cmp(&b.start_ms));
    }

    let mut lines: Vec<String> = Vec::new();
    // Lane names first (metadata events carry no timestamp).
    for d in 0..n_devices {
        lines.push(format!(
            r#"{{"name":"thread_name","ph":"M","pid":0,"tid":{d},"args":{{"name":"device {d}"}}}}"#
        ));
    }
    lines.push(format!(
        r#"{{"name":"thread_name","ph":"M","pid":0,"tid":{n_devices},"args":{{"name":"control"}}}}"#
    ));
    // Batch spans, per device ascending, in start order.
    for (d, dev_spans) in spans.iter().enumerate() {
        for sp in dev_spans {
            let end = sp.end_ms.unwrap_or(sp.start_ms);
            lines.push(format!(
                r#"{{"name":"batch {} (n={})","cat":"batch","ph":"B","pid":0,"tid":{d},"ts":{},"args":{{"order":{}}}}}"#,
                sp.batch,
                sp.n,
                num(sp.start_ms * 1e3),
                usizes(&sp.order)
            ));
            lines.push(format!(
                r#"{{"name":"batch {} (n={})","cat":"batch","ph":"E","pid":0,"tid":{d},"ts":{}}}"#,
                sp.batch,
                sp.n,
                num(end.max(sp.start_ms) * 1e3)
            ));
        }
    }
    // Control-lane instants, in stream (clock) order.
    for ev in events {
        let (t, name, extra) = match ev {
            TraceEvent::Fault { t_ms, device, action } => {
                (*t_ms, format!("fault: {}", esc(action)), format!(r#""device":{device}"#))
            }
            TraceEvent::Shed { t_ms, id, cause } => {
                (*t_ms, format!("shed: {}", esc(cause)), format!(r#""id":{id}"#))
            }
            TraceEvent::Retry { t_ms, id, attempt, .. } => {
                (*t_ms, format!("retry #{attempt}"), format!(r#""id":{id}"#))
            }
            TraceEvent::Admission { t_ms, id, policy, admitted: false, .. } => {
                (*t_ms, format!("rejected: {}", esc(policy)), format!(r#""id":{id}"#))
            }
            TraceEvent::WorkerPanic { t_ms, device, .. } => {
                (*t_ms, "panic".to_string(), format!(r#""device":{device}"#))
            }
            _ => continue,
        };
        lines.push(format!(
            r#"{{"name":"{name}","cat":"control","ph":"i","s":"t","pid":0,"tid":{n_devices},"ts":{},"args":{{{extra}}}}}"#,
            num(t * 1e3)
        ));
    }
    format!("{{\"traceEvents\":[\n{}\n]}}\n", lines.join(",\n"))
}

/// What [`validate_chrome_trace`] measured while checking.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChromeSummary {
    /// Total events (including metadata).
    pub n_events: usize,
    /// Completed `B`/`E` spans.
    pub n_spans: usize,
    /// Distinct `(pid, tid)` lanes carrying timestamped events.
    pub n_lanes: usize,
    /// Largest timestamp seen, in microseconds.
    pub max_ts_us: f64,
}

/// Minimal structural validator for Chrome trace-event JSON: the
/// top-level object must carry a `traceEvents` array; every non-metadata
/// event needs `ph`/`pid`/`tid`/`ts`; `B`/`E` must balance per lane and
/// timestamps must be monotone non-decreasing per lane (what timeline
/// viewers actually require). Returns a [`ChromeSummary`] on success.
pub fn validate_chrome_trace(text: &str) -> Result<ChromeSummary, String> {
    let root = Json::parse(text).map_err(|e| format!("not JSON: {e}"))?;
    let events = root
        .get("traceEvents")
        .and_then(|j| j.as_arr())
        .ok_or_else(|| "missing top-level `traceEvents` array".to_string())?;
    let mut depth: BTreeMap<(i64, i64), usize> = BTreeMap::new();
    let mut last_ts: BTreeMap<(i64, i64), f64> = BTreeMap::new();
    let mut n_spans = 0usize;
    let mut max_ts = 0.0f64;
    for (i, e) in events.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(|j| j.as_str())
            .ok_or_else(|| format!("event {i}: missing `ph`"))?;
        if ph == "M" {
            continue;
        }
        let lane_of = |k: &str| -> Result<i64, String> {
            e.get(k)
                .and_then(|j| j.as_f64())
                .map(|v| v as i64)
                .ok_or_else(|| format!("event {i}: missing `{k}`"))
        };
        let lane = (lane_of("pid")?, lane_of("tid")?);
        let ts = e
            .get("ts")
            .and_then(|j| j.as_f64())
            .ok_or_else(|| format!("event {i}: missing `ts`"))?;
        if let Some(&prev) = last_ts.get(&lane) {
            if ts < prev {
                return Err(format!(
                    "event {i}: timestamp {ts} goes backwards on lane {lane:?} (last {prev})"
                ));
            }
        }
        last_ts.insert(lane, ts);
        max_ts = max_ts.max(ts);
        match ph {
            "B" => *depth.entry(lane).or_insert(0) += 1,
            "E" => {
                let d = depth.entry(lane).or_insert(0);
                if *d == 0 {
                    return Err(format!("event {i}: `E` with no open span on lane {lane:?}"));
                }
                *d -= 1;
                n_spans += 1;
            }
            _ => {}
        }
    }
    for (lane, d) in &depth {
        if *d != 0 {
            return Err(format!("{d} unclosed span(s) on lane {lane:?}"));
        }
    }
    Ok(ChromeSummary {
        n_events: events.len(),
        n_spans,
        n_lanes: last_ts.len(),
        max_ts_us: max_ts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent::Arrival { t_ms: 0.5, id: 0 },
            TraceEvent::Admission {
                t_ms: 0.5,
                id: 0,
                policy: "bound:4".into(),
                admitted: true,
                queue_depth: 1,
                predicted_sojourn_ms: f64::NAN,
            },
            TraceEvent::WindowDecide {
                t_ms: 1.0,
                device: 0,
                n_pending: 2,
                queued_batches: 0,
                close: true,
            },
            TraceEvent::ReorderDecision {
                t_ms: 1.0,
                device: 0,
                batch: 0,
                n: 2,
                strategy: "local:64".into(),
                evals: 64,
                degraded: false,
                chosen_ms: 9.25,
                fifo_ms: 10.5,
            },
            TraceEvent::RouteDecision {
                t_ms: 1.5,
                id: 1,
                device: 1,
                policy: "jsq".into(),
                outstanding: vec![2, 0],
                free_at_ms: vec![10.0, 0.0],
            },
            TraceEvent::BatchStart { t_ms: 2.0, device: 0, batch: 0, n: 2, order: vec![1, 0] },
            TraceEvent::BatchFinish { t_ms: 11.25, device: 0, batch: 0, makespan_ms: 9.25 },
            TraceEvent::Fault { t_ms: 12.0, device: 1, action: "down".into() },
            TraceEvent::Retry { t_ms: 12.5, id: 3, attempt: 2, backoff_ms: 4.0 },
            TraceEvent::Shed { t_ms: 13.0, id: 3, cause: "retry-cap:4".into() },
            TraceEvent::WorkerPanic { t_ms: 14.0, device: 0, message: "boom \"quoted\"".into() },
            TraceEvent::Incumbent { eval: 128, best_ms: 9.25, strategy: "anneal:2000:17".into() },
        ]
    }

    #[test]
    fn jsonl_round_trips_every_variant() {
        for ev in sample_events() {
            let line = to_jsonl_line(&ev);
            let back = parse_jsonl_line(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
            // NaN != NaN, so compare through a second serialization.
            assert_eq!(line, to_jsonl_line(&back), "{line}");
        }
        let text = jsonl(&sample_events());
        let back = events_from_jsonl(&text).unwrap();
        assert_eq!(back.len(), sample_events().len());
        assert_eq!(jsonl(&back), text);
    }

    #[test]
    fn jsonl_rejects_hostile_lines_with_line_numbers() {
        for bad in ["not json", "{}", r#"{"type":"zzz"}"#, r#"{"type":"arrival"}"#] {
            assert!(parse_jsonl_line(bad).is_err(), "{bad}");
        }
        let err = events_from_jsonl("{\"type\":\"arrival\",\"t_ms\":0,\"id\":0}\nnope\n")
            .unwrap_err();
        assert!(err.starts_with("line 2:"), "{err}");
    }

    #[test]
    fn chrome_export_validates_and_builds_device_lanes() {
        let json = chrome_trace_json(&sample_events());
        let summary = validate_chrome_trace(&json).unwrap();
        assert_eq!(summary.n_spans, 1);
        // Lanes: device 0 (span) + control (instants). Device 1 had no
        // timestamped span events, so it contributes only metadata.
        assert!(summary.n_lanes >= 2, "{summary:?}");
        assert!(json.contains(r#""name":"device 0""#), "{json}");
        assert!(json.contains(r#""name":"device 1""#), "{json}");
        assert!(json.contains(r#""name":"control""#), "{json}");
        assert!(json.contains(r#""name":"fault: down""#), "{json}");
        // µs conversion: batch start at 2 ms → ts 2000.
        assert!(json.contains(r#""ph":"B","pid":0,"tid":0,"ts":2000"#), "{json}");
    }

    #[test]
    fn chrome_export_clips_spans_at_device_crashes() {
        // Batch starts at 10 on device 0, its finish would land at 30,
        // but the device goes down at 15 and a post-recovery batch runs
        // 20→25. Unclipped, lane 0 would go 10,30,20,25 — backwards.
        let events = vec![
            TraceEvent::BatchStart { t_ms: 10.0, device: 0, batch: 0, n: 1, order: vec![0] },
            TraceEvent::Fault { t_ms: 15.0, device: 0, action: "down".into() },
            TraceEvent::Fault { t_ms: 18.0, device: 0, action: "recover".into() },
            TraceEvent::BatchStart { t_ms: 20.0, device: 0, batch: 1, n: 1, order: vec![0] },
            TraceEvent::BatchFinish { t_ms: 25.0, device: 0, batch: 1, makespan_ms: 5.0 },
            TraceEvent::BatchFinish { t_ms: 30.0, device: 0, batch: 0, makespan_ms: 20.0 },
        ];
        let json = chrome_trace_json(&events);
        let summary = validate_chrome_trace(&json).unwrap();
        assert_eq!(summary.n_spans, 2);
        // The orphaned span ends at the crash (15 ms → 15000 µs).
        assert!(json.contains(r#""ph":"E","pid":0,"tid":0,"ts":15000"#), "{json}");
    }

    #[test]
    fn chrome_export_of_an_empty_stream_still_validates() {
        let json = chrome_trace_json(&[]);
        let summary = validate_chrome_trace(&json).unwrap();
        assert_eq!(summary.n_spans, 0);
        assert_eq!(summary.n_lanes, 0);
    }

    #[test]
    fn validator_rejects_broken_traces() {
        for (text, needle) in [
            ("nope", "not JSON"),
            ("{}", "traceEvents"),
            (r#"{"traceEvents":[{"pid":0}]}"#, "missing `ph`"),
            (r#"{"traceEvents":[{"ph":"B","pid":0,"tid":0}]}"#, "missing `ts`"),
            (
                r#"{"traceEvents":[{"ph":"E","pid":0,"tid":0,"ts":1}]}"#,
                "no open span",
            ),
            (
                r#"{"traceEvents":[{"ph":"B","pid":0,"tid":0,"ts":1}]}"#,
                "unclosed",
            ),
            (
                r#"{"traceEvents":[{"ph":"i","s":"t","pid":0,"tid":0,"ts":5},{"ph":"i","s":"t","pid":0,"tid":0,"ts":4}]}"#,
                "backwards",
            ),
        ] {
            let err = validate_chrome_trace(text).unwrap_err();
            assert!(err.contains(needle), "`{needle}` not in: {err}");
        }
    }
}
