//! **Admission control** — overload protection at the front door.
//!
//! Reordering assumes every submitted kernel eventually runs. Under
//! sustained offered load above capacity that assumption fails in the
//! worst possible way: queues grow without bound, every sojourn tends to
//! infinity, and the reorder decisions themselves become the bottleneck.
//! This module is the *last* rung of the explicit degradation ladder
//!
//! 1. **budgeted reorder** — the normal mode;
//! 2. **FIFO passthrough** — a decision that cannot beat FIFO in budget
//!    serves arrival order (counted as `n_degraded_decisions`);
//! 3. **admission shed** — an arrival that would violate the service's
//!    stability or latency contract is *rejected at the door*, recorded
//!    as a first-class [`crate::fleet::ShedRecord`] with a
//!    [`crate::fleet::ShedCause::Rejected`] cause, and its closed-loop
//!    source notified so clients never starve.
//!
//! An [`AdmissionPolicy`] inspects an [`AdmissionState`] snapshot at
//! each arrival and answers admit/reject. The registry spellings:
//!
//! | spelling | behavior |
//! |---|---|
//! | `none` | admit everything (the default; a strict engine no-op) |
//! | `bound:<q>` | hard cap: reject while ≥ q kernels are in the system |
//! | `deadline:<slo_ms>` | reject when the priced backlog says the SLO would be violated |
//! | `codel:<target_ms>:<interval_ms>` | CoDel-style: drop when queue delay stays above target for a full interval |
//!
//! `deadline` prices the backlog through the backend's admissible
//! [`crate::exec::PreparedWorkload::suffix_lower_bound`] — the same
//! pricing seam `lrw` routing uses. Because the bound is admissible
//! (never overestimates) the policy admits while the *priced* backlog
//! stays within **half** the SLO; the factor-two headroom covers bound
//! slack, the admitted kernel's own service time and the simulator's
//! per-block jitter, so admitted kernels meet the full SLO in practice
//! (HARD-gated in `benches/overload.rs`). `codel` needs no pricing: it
//! watches the realized queue delay (the age of the oldest waiting
//! kernel) and, per CoDel, only drops once the delay has stayed above
//! `target_ms` for a continuous `interval_ms`, so bursts ride through
//! and only *standing* queues shed.
//!
//! The same trait gates all three execution layers: the online engine
//! ([`crate::online::simulate_online_with_admission`]), the fleet
//! engine ([`crate::fleet::simulate_fleet_with_admission`]) and the
//! live thread coordinator
//! ([`crate::coordinator::CoordinatorBuilder::admission`], where
//! [`crate::coordinator::Coordinator::try_submit`] returns an explicit
//! backpressure error instead of queueing unboundedly; the live path
//! cannot price backlogs, so `deadline` degrades to admit-all there —
//! the same fallback `lrw` routing takes).

use std::fmt;

/// What the gatekeeper sees at one arrival: a snapshot of system
/// occupancy at the arrival's virtual (or wall) time.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionState {
    /// The arrival's timestamp (virtual ms in the engines, ms since
    /// service start in the coordinator).
    pub now_ms: f64,
    /// Kernels currently in the system and not yet completed (pending
    /// windows + queued batches + in flight).
    pub queue_depth: usize,
    /// Age of the oldest kernel still waiting for service (0 when the
    /// system is empty) — the realized queue-delay signal CoDel watches.
    pub oldest_wait_ms: f64,
    /// Admissible lower bound on this arrival's sojourn (residual busy
    /// time + `suffix_lower_bound` of the backlog; the online path
    /// includes the arrival itself, the fleet path prices the best
    /// currently-up device). `NaN` when the caller did not price — engines
    /// only pay for pricing when [`AdmissionPolicy::needs_pricing`] says
    /// so, and the live coordinator never can.
    pub predicted_sojourn_ms: f64,
}

/// A policy deciding, per arrival, whether the kernel enters the system
/// at all. Implementations may be stateful (CoDel is); the engines call
/// [`admit`](AdmissionPolicy::admit) exactly once per arrival, in
/// arrival order, so state advances deterministically on the virtual
/// clock.
pub trait AdmissionPolicy: Send {
    /// Canonical registry spelling (reparsing it yields an equivalent
    /// policy).
    fn name(&self) -> String;

    /// Whether [`AdmissionState::predicted_sojourn_ms`] must be priced
    /// before calling [`admit`](AdmissionPolicy::admit). Pricing walks
    /// the backlog through the backend's admissible bound — engines
    /// skip that cost for policies that never read it.
    fn needs_pricing(&self) -> bool {
        false
    }

    /// `true` only for [`NoAdmission`]: engines skip the entire gate
    /// (no state snapshot, no pricing), which is what makes
    /// `admission=none` a strict, bit-identical no-op.
    fn is_noop(&self) -> bool {
        false
    }

    /// Admit (`true`) or reject (`false`) the arrival `state` describes.
    fn admit(&mut self, state: &AdmissionState) -> bool;
}

/// `none`: admit everything. [`AdmissionPolicy::is_noop`] lets the
/// engines bypass the gate entirely, so runs under `none` are
/// bit-identical to the pre-admission engines (pinned in
/// `tests/overload_protection.rs`).
#[derive(Debug, Clone, Copy, Default)]
pub struct NoAdmission;

impl AdmissionPolicy for NoAdmission {
    fn name(&self) -> String {
        "none".into()
    }
    fn is_noop(&self) -> bool {
        true
    }
    fn admit(&mut self, _state: &AdmissionState) -> bool {
        true
    }
}

/// `bound:<q>`: a hard cap on system occupancy — reject while `q` or
/// more kernels are already in the system. The classic bounded-queue
/// backpressure: keeps memory and worst-case queue delay finite at the
/// price of shedding indiscriminately under overload.
#[derive(Debug, Clone, Copy)]
pub struct BoundAdmission {
    cap: usize,
}

impl BoundAdmission {
    /// Cap is clamped to ≥ 1 (a zero cap would reject every kernel of
    /// an empty system).
    pub fn new(cap: usize) -> BoundAdmission {
        BoundAdmission { cap: cap.max(1) }
    }
}

impl AdmissionPolicy for BoundAdmission {
    fn name(&self) -> String {
        format!("bound:{}", self.cap)
    }
    fn admit(&mut self, state: &AdmissionState) -> bool {
        state.queue_depth < self.cap
    }
}

/// `deadline:<slo_ms>`: shed on predicted SLO violation. Admits while
/// the arrival's priced sojourn lower bound stays within *half* the
/// SLO; see the module docs for why the headroom factor exists. An
/// unpriced snapshot (`NaN`) admits — the policy degrades to `none`
/// rather than shedding blind.
#[derive(Debug, Clone, Copy)]
pub struct DeadlineAdmission {
    slo_ms: f64,
}

/// The admissible-bound headroom `deadline` keeps between its priced
/// admit threshold and the SLO it protects (threshold = SLO /
/// `DEADLINE_HEADROOM`).
pub const DEADLINE_HEADROOM: f64 = 2.0;

impl DeadlineAdmission {
    pub fn new(slo_ms: f64) -> DeadlineAdmission {
        DeadlineAdmission { slo_ms }
    }
}

impl AdmissionPolicy for DeadlineAdmission {
    fn name(&self) -> String {
        format!("deadline:{}", self.slo_ms)
    }
    fn needs_pricing(&self) -> bool {
        true
    }
    fn admit(&mut self, state: &AdmissionState) -> bool {
        // NaN comparison is false on both sides: an unpriced snapshot
        // admits.
        !(state.predicted_sojourn_ms > self.slo_ms / DEADLINE_HEADROOM)
    }
}

/// `codel:<target_ms>:<interval_ms>`: CoDel-style sojourn-based
/// dropping on the realized queue delay. While the oldest waiting
/// kernel is younger than `target_ms` everything is admitted and the
/// above-target timer resets; once the delay has stayed above target
/// for a continuous `interval_ms`, one arrival is dropped and the
/// timer restarts. Bursts shorter than the interval ride through
/// untouched; standing queues shed at a bounded, deterministic rate.
#[derive(Debug, Clone, Copy)]
pub struct CoDelAdmission {
    target_ms: f64,
    interval_ms: f64,
    /// When the queue delay last rose above target (`None` while below).
    above_since_ms: Option<f64>,
}

impl CoDelAdmission {
    pub fn new(target_ms: f64, interval_ms: f64) -> CoDelAdmission {
        CoDelAdmission {
            target_ms,
            interval_ms,
            above_since_ms: None,
        }
    }
}

impl AdmissionPolicy for CoDelAdmission {
    fn name(&self) -> String {
        format!("codel:{}:{}", self.target_ms, self.interval_ms)
    }
    fn admit(&mut self, state: &AdmissionState) -> bool {
        if state.oldest_wait_ms <= self.target_ms {
            self.above_since_ms = None;
            return true;
        }
        match self.above_since_ms {
            None => {
                self.above_since_ms = Some(state.now_ms);
                true
            }
            Some(t0) if state.now_ms - t0 >= self.interval_ms => {
                // Drop one and restart the interval from now.
                self.above_since_ms = Some(state.now_ms);
                false
            }
            Some(_) => true,
        }
    }
}

/// Rejected admission spelling; lists the valid forms.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AdmissionParseError {
    pub input: String,
}

impl fmt::Display for AdmissionParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown admission policy `{}` — valid policies: none, bound:<q>, \
             deadline:<slo_ms>, codel:<target_ms>:<interval_ms> \
             (q ≥ 1; all times finite and > 0)",
            self.input
        )
    }
}

impl std::error::Error for AdmissionParseError {}

/// Parse an admission-policy spelling (see the module table). Times
/// must be finite and strictly positive; the bound cap at least 1;
/// trailing garbage is rejected.
pub fn parse_admission_policy(
    spec: &str,
) -> Result<Box<dyn AdmissionPolicy>, AdmissionParseError> {
    let err = || AdmissionParseError {
        input: spec.to_string(),
    };
    let lower = spec.trim().to_ascii_lowercase();
    let mut parts = lower.split(':');
    let head = parts.next().unwrap_or("");

    // Positive-finite millisecond argument.
    let ms = |s: Option<&str>| -> Result<f64, AdmissionParseError> {
        let v: f64 = s.ok_or_else(err)?.parse().map_err(|_| err())?;
        if v.is_finite() && v > 0.0 {
            Ok(v)
        } else {
            Err(err())
        }
    };

    let policy: Box<dyn AdmissionPolicy> = match head {
        "none" => Box::new(NoAdmission),
        "bound" => {
            let q: usize = parts.next().ok_or_else(err)?.parse().map_err(|_| err())?;
            if q == 0 {
                return Err(err());
            }
            Box::new(BoundAdmission::new(q))
        }
        "deadline" => Box::new(DeadlineAdmission::new(ms(parts.next())?)),
        "codel" => {
            let target = ms(parts.next())?;
            let interval = ms(parts.next())?;
            Box::new(CoDelAdmission::new(target, interval))
        }
        _ => return Err(err()),
    };
    if parts.next().is_some() {
        return Err(err());
    }
    Ok(policy)
}

/// One line per registered admission spelling, for `kreorder list
/// --kind admission` and the shared registry cheat sheet.
pub fn admission_help_table() -> String {
    let rows: [(&str, &str); 4] = [
        ("none", "admit everything (default; strict engine no-op)"),
        (
            "bound:<q>",
            "hard occupancy cap: reject while >= q kernels are in the system",
        ),
        (
            "deadline:<slo_ms>",
            "shed on predicted SLO violation (admissible suffix-bound pricing, 2x headroom)",
        ),
        (
            "codel:<target_ms>:<interval_ms>",
            "CoDel: drop once queue delay stays above target for a full interval",
        ),
    ];
    let mut s = String::new();
    for (name, desc) in rows {
        s.push_str(&format!("  {name:<32} {desc}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(depth: usize, oldest: f64, predicted: f64, now: f64) -> AdmissionState {
        AdmissionState {
            now_ms: now,
            queue_depth: depth,
            oldest_wait_ms: oldest,
            predicted_sojourn_ms: predicted,
        }
    }

    #[test]
    fn none_admits_everything_and_is_the_noop() {
        let mut p = parse_admission_policy("none").unwrap();
        assert!(p.is_noop());
        assert!(!p.needs_pricing());
        assert!(p.admit(&state(1_000_000, 1e9, 1e9, 0.0)));
        assert_eq!(p.name(), "none");
    }

    #[test]
    fn bound_caps_occupancy() {
        let mut p = parse_admission_policy("bound:4").unwrap();
        assert!(!p.is_noop());
        assert!(p.admit(&state(0, 0.0, f64::NAN, 0.0)));
        assert!(p.admit(&state(3, 0.0, f64::NAN, 0.0)));
        assert!(!p.admit(&state(4, 0.0, f64::NAN, 0.0)));
        assert!(!p.admit(&state(400, 0.0, f64::NAN, 0.0)));
        assert_eq!(p.name(), "bound:4");
    }

    #[test]
    fn deadline_prices_against_half_the_slo_and_admits_unpriced() {
        let mut p = parse_admission_policy("deadline:100").unwrap();
        assert!(p.needs_pricing());
        assert!(p.admit(&state(0, 0.0, 49.0, 0.0)));
        assert!(p.admit(&state(0, 0.0, 50.0, 0.0)));
        assert!(!p.admit(&state(0, 0.0, 50.1, 0.0)));
        // Unpriced (NaN) snapshots admit: degrade to none, never shed blind.
        assert!(p.admit(&state(0, 0.0, f64::NAN, 0.0)));
        assert_eq!(p.name(), "deadline:100");
    }

    #[test]
    fn codel_drops_only_standing_queues() {
        let mut p = parse_admission_policy("codel:5:20").unwrap();
        // Below target: admit, timer clear.
        assert!(p.admit(&state(1, 3.0, f64::NAN, 0.0)));
        // Above target starts the timer but still admits…
        assert!(p.admit(&state(4, 8.0, f64::NAN, 10.0)));
        assert!(p.admit(&state(4, 9.0, f64::NAN, 25.0)));
        // …a full interval above target drops exactly one…
        assert!(!p.admit(&state(4, 9.0, f64::NAN, 30.0)));
        // …and the interval restarts (not an immediate second drop).
        assert!(p.admit(&state(4, 9.0, f64::NAN, 31.0)));
        // Dropping below target resets the state machine entirely.
        assert!(p.admit(&state(0, 1.0, f64::NAN, 40.0)));
        assert!(p.admit(&state(4, 9.0, f64::NAN, 60.0)));
        assert!(p.admit(&state(4, 9.0, f64::NAN, 79.0)));
        assert!(!p.admit(&state(4, 9.0, f64::NAN, 80.0)));
    }

    #[test]
    fn burst_shorter_than_interval_rides_through() {
        let mut p = CoDelAdmission::new(5.0, 100.0);
        for t in 0..50 {
            assert!(p.admit(&state(10, 50.0, f64::NAN, t as f64)), "t={t}");
        }
        // Queue drains before the interval elapses: nothing was dropped.
        assert!(p.admit(&state(0, 0.0, f64::NAN, 50.0)));
    }

    #[test]
    fn canonical_names_reparse() {
        for spec in ["none", "bound:64", "deadline:50", "codel:5:100"] {
            let p = parse_admission_policy(spec).unwrap();
            let q = parse_admission_policy(&p.name()).unwrap();
            assert_eq!(p.name(), q.name());
        }
    }

    #[test]
    fn hostile_spellings_are_rejected_with_the_echoed_input() {
        for bad in [
            "",
            "zzz",
            "bound",
            "bound:",
            "bound:0",
            "bound:-3",
            "bound:x",
            "bound:4:9",
            "deadline",
            "deadline:",
            "deadline:-5",
            "deadline:0",
            "deadline:nan",
            "deadline:inf",
            "deadline:50:9",
            "codel",
            "codel:5",
            "codel:0:5",
            "codel:5:0",
            "codel:-1:5",
            "codel:5:nan",
            "codel:5:5:9",
            "none:1",
        ] {
            let e = parse_admission_policy(bad).unwrap_err();
            assert!(e.to_string().contains(bad), "`{bad}`: {e}");
            assert!(e.to_string().contains("valid policies"), "{e}");
        }
    }

    #[test]
    fn help_table_names_every_spelling() {
        let t = admission_help_table();
        for name in ["none", "bound", "deadline", "codel"] {
            assert!(t.contains(name), "{t}");
        }
        assert!(t.lines().count() >= 4);
    }
}
