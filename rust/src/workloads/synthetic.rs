//! Seeded synthetic workload generator — used by property tests, the
//! serving example's request stream, and scaling studies beyond the
//! paper's fixed experiments.

use crate::gpu::{AppKind, GpuSpec, KernelProfile};
use crate::util::SplitMix64;

/// Generate `n` random-but-plausible kernels. Every kernel is guaranteed
/// to pass [`crate::sim::validate_workload`] against `gpu`.
///
/// The distribution deliberately mixes memory-bound and compute-bound
/// kernels (ratio log-uniform in [0.5, 10·R_B]) and spans the occupancy
/// range from tiny (2 warps) to SM-filling.
pub fn synthetic_workload(gpu: &GpuSpec, n: usize, seed: u64) -> Vec<KernelProfile> {
    let mut rng = SplitMix64::new(seed);
    let apps = [
        AppKind::Ep,
        AppKind::BlackScholes,
        AppKind::Electrostatics,
        AppKind::SmithWaterman,
    ];
    let artifacts = [
        "ep_16k",
        "blackscholes_16k",
        "electrostatics_1kx512",
        "smith_waterman_64x48",
    ];
    (0..n)
        .map(|i| {
            let app_i = rng.below(apps.len());
            // Warps per block: 2..=min(16, capacity).
            let warps = 2 + rng.below(15.min(gpu.warps_per_sm as usize / 2)) as u32;
            // Shared memory: 0 or a multiple of 4K up to half the SM.
            let shmem = if rng.next_f64() < 0.5 {
                0
            } else {
                (1 + rng.below((gpu.shmem_per_sm / 2 / 4096) as usize) as u32) * 4096
            };
            // Registers per thread 16..40.
            let regs = (16 + rng.below(25) as u32) * warps * 32;
            // Grid: 1–6 blocks per SM.
            let grid = gpu.n_sm * (1 + rng.below(6) as u32);
            // Ratio log-uniform across the memory/compute divide.
            let log_lo = (0.5f64).ln();
            let log_hi = (gpu.balanced_ratio * 10.0).ln();
            let ratio = (log_lo + (log_hi - log_lo) * rng.next_f64()).exp();
            let work = rng.range_f64(2_000.0, 20_000.0);
            KernelProfile {
                name: format!("SYN#{i}"),
                app: apps[app_i],
                n_blocks: grid,
                regs_per_block: regs.min(gpu.regs_per_sm),
                shmem_per_block: shmem.min(gpu.shmem_per_sm),
                warps_per_block: warps,
                ratio,
                work_per_block: work,
                artifact: artifacts[app_i].into(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::validate_workload;

    #[test]
    fn generated_workloads_always_valid() {
        let gpu = GpuSpec::gtx580();
        for seed in 0..50 {
            let ks = synthetic_workload(&gpu, 8, seed);
            assert_eq!(ks.len(), 8);
            validate_workload(&gpu, &ks).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let gpu = GpuSpec::gtx580();
        assert_eq!(
            synthetic_workload(&gpu, 6, 9),
            synthetic_workload(&gpu, 6, 9)
        );
        assert_ne!(
            synthetic_workload(&gpu, 6, 9),
            synthetic_workload(&gpu, 6, 10)
        );
    }

    #[test]
    fn mixes_bound_types() {
        let gpu = GpuSpec::gtx580();
        let ks = synthetic_workload(&gpu, 64, 1234);
        let mem = ks.iter().filter(|k| k.memory_bound(&gpu)).count();
        assert!(mem > 8, "too few memory-bound: {mem}");
        assert!(mem < 56, "too few compute-bound: {}", 64 - mem);
    }
}
