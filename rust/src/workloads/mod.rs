//! The paper's experiment workloads (Table 2), a seeded synthetic
//! workload generator, and named [`Scenario`] families for the search
//! subsystem's quality gates (see `scenarios`).
//!
//! Parameter notes (Table 2, GTX580):
//!
//! * The paper's per-kernel quantities `N_shm_i` / `N_warp_i` are **per-SM
//!   footprints** under even round-robin block distribution: e.g.
//!   `EP-6-grid` lists `N_warp_i = 4…24` for grid sizes 16…96 at block
//!   size 128 — (grid/16 SMs) blocks per SM × 4 warps per block.
//! * Each application instance has a fixed **total** amount of work (EP is
//!   M=24 samples; BS is a fixed option count), so `work_per_block`
//!   scales inversely with grid size: more blocks = less work per block.
//! * Absolute work constants are calibrated so simulated optima land near
//!   the paper's millisecond scale (EXPERIMENTS.md §Calibration); all
//!   Table-3 comparison columns are scale-free.

mod apps;
pub mod dag;
mod experiments;
mod scenarios;
mod synthetic;

pub use apps::{blackscholes, electrostatics, ep, smith_waterman};
pub use dag::{
    deps_to_csv, parse_deps, validate_dag_workload, DagError, DagWorkloadError, DepGraph,
    DepsParseError, Workload, MAX_DAG_KERNELS,
};
pub use experiments::{
    all_experiments, bs_6_blk, by_id, ep_6_grid, ep_6_shm, epbs_6, epbs_6_shm, epbsessw_8,
    Experiment,
};
pub use scenarios::{
    all_dag_scenarios, all_scenarios, dag_scenario_by_id, dag_scenario_ids, scenario_by_id,
    scenario_ids, DagScenario, Scenario, DAG_SCENARIOS, SCENARIOS,
};
pub use synthetic::synthetic_workload;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuSpec;
    use crate::sim::validate_workload;

    #[test]
    fn all_experiments_are_simulable() {
        let gpu = GpuSpec::gtx580();
        for e in all_experiments() {
            validate_workload(&gpu, &e.kernels)
                .unwrap_or_else(|err| panic!("{}: {err}", e.id));
        }
    }

    #[test]
    fn experiment_ids_unique_and_resolvable() {
        let all = all_experiments();
        for e in &all {
            let found = by_id(e.id).expect("by_id");
            assert_eq!(found.kernels.len(), e.kernels.len());
        }
        let mut ids: Vec<&str> = all.iter().map(|e| e.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), all.len());
    }

    #[test]
    fn six_experiments_match_paper_sizes() {
        // Table 2: five 6-kernel experiments + one 8-kernel experiment.
        let all = all_experiments();
        assert_eq!(all.len(), 6);
        let sizes: Vec<usize> = all.iter().map(|e| e.kernels.len()).collect();
        assert_eq!(sizes, vec![6, 6, 6, 6, 6, 8]);
    }

    #[test]
    fn by_id_unknown_is_none() {
        assert!(by_id("nonsense").is_none());
    }
}
