//! Kernel-profile constructors for the paper's four benchmark
//! applications. Occupancy parameters (registers per thread, ratios) match
//! the paper's CUDA-profiler characterization on the GTX580; work
//! constants are the simulator calibration (see module docs).

use crate::gpu::{AppKind, KernelProfile};

/// NPB EP (M=24): the paper's memory-bound exemplar, `R_ep = 3.11 < R_B`.
pub const EP_RATIO: f64 = 3.11;
/// BlackScholes (4M options): compute-bound, `R_bs = 11.1 > R_B`.
pub const BS_RATIO: f64 = 11.1;
/// VMD Electrostatics (40K atoms): strongly compute-bound (n² FLOPs over
/// n data; our XLA cost analysis of the Pallas ES kernel measures the
/// highest instructions/byte of the four apps).
pub const ES_RATIO: f64 = 16.0;
/// Smith-Waterman: DP table streaming, memory-bound.
pub const SW_RATIO: f64 = 1.8;

/// Total simulator work units for one full EP instance (M = 24).
pub const EP_TOTAL_WORK: f64 = 140_000.0;
/// Total work for one BlackScholes instance at the 4M-option size used in
/// `BS-6-blk` (the stand-alone BS experiment).
pub const BS_TOTAL_WORK_4M: f64 = 1_500_000.0;
/// BS instance size used in the mixed `EpBs-*` experiments (the paper's
/// optima there imply a smaller option count per kernel: the mixed-round
/// BS finishes well inside EP's runtime, which is how the optimum hides
/// the stranded kernel's tail).
pub const BS_TOTAL_WORK_MIXED: f64 = 140_000.0;
/// ES / SW totals used in `EpBsEsSw-8`.
pub const ES_TOTAL_WORK: f64 = 240_000.0;
pub const SW_TOTAL_WORK: f64 = 120_000.0;

/// Registers per thread from the profiler: EP 16, BS 26, ES 30, SW 20.
const EP_REGS_PER_THREAD: u32 = 16;
const BS_REGS_PER_THREAD: u32 = 26;
const ES_REGS_PER_THREAD: u32 = 30;
const SW_REGS_PER_THREAD: u32 = 20;

/// An EP kernel instance: `grid` blocks of 128 threads (4 warps), with
/// `shmem` bytes of shared memory per block and the full M=24 workload.
pub fn ep(tag: &str, grid: u32, shmem_per_block: u32) -> KernelProfile {
    let threads = 128;
    KernelProfile {
        name: format!("EP{tag}"),
        app: AppKind::Ep,
        n_blocks: grid,
        regs_per_block: EP_REGS_PER_THREAD * threads,
        shmem_per_block,
        warps_per_block: threads / 32,
        ratio: EP_RATIO,
        work_per_block: EP_TOTAL_WORK / grid as f64,
        artifact: "ep_16k".into(),
    }
}

/// A BlackScholes kernel instance: `grid` blocks × `block_size` threads,
/// `total_work` spread over the grid.
pub fn blackscholes(
    tag: &str,
    grid: u32,
    block_size: u32,
    shmem_per_block: u32,
    total_work: f64,
) -> KernelProfile {
    KernelProfile {
        name: format!("BS{tag}"),
        app: AppKind::BlackScholes,
        n_blocks: grid,
        regs_per_block: BS_REGS_PER_THREAD * block_size,
        shmem_per_block,
        warps_per_block: block_size / 32,
        ratio: BS_RATIO,
        work_per_block: total_work / grid as f64,
        artifact: "blackscholes_16k".into(),
    }
}

/// An Electrostatics kernel instance (VMD direct Coulomb summation).
pub fn electrostatics(tag: &str, grid: u32, block_size: u32, shmem_per_block: u32) -> KernelProfile {
    KernelProfile {
        name: format!("ES{tag}"),
        app: AppKind::Electrostatics,
        n_blocks: grid,
        regs_per_block: ES_REGS_PER_THREAD * block_size,
        shmem_per_block,
        warps_per_block: block_size / 32,
        ratio: ES_RATIO,
        work_per_block: ES_TOTAL_WORK / grid as f64,
        artifact: "electrostatics_1kx512".into(),
    }
}

/// A Smith-Waterman kernel instance (batched local alignment).
pub fn smith_waterman(tag: &str, grid: u32, block_size: u32, shmem_per_block: u32) -> KernelProfile {
    KernelProfile {
        name: format!("SW{tag}"),
        app: AppKind::SmithWaterman,
        n_blocks: grid,
        regs_per_block: SW_REGS_PER_THREAD * block_size,
        shmem_per_block,
        warps_per_block: block_size / 32,
        ratio: SW_RATIO,
        work_per_block: SW_TOTAL_WORK / grid as f64,
        artifact: "smith_waterman_64x48".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuSpec;

    #[test]
    fn ep_matches_table1_shape() {
        let k = ep("#1", 16, 8192);
        assert_eq!(k.warps_per_block, 4);
        assert_eq!(k.regs_per_block, 2048);
        assert_eq!(k.n_blocks, 16);
        assert!((k.total_work() - EP_TOTAL_WORK).abs() < 1e-9);
    }

    #[test]
    fn total_work_invariant_under_grid() {
        // The paper's EP-6-grid: same kernel, different grid -> same total.
        for grid in [16, 32, 48, 64, 80, 96] {
            let k = ep("x", grid, 0);
            assert!((k.total_work() - EP_TOTAL_WORK).abs() < 1e-6);
        }
    }

    #[test]
    fn bs_warps_track_block_size() {
        for (bs, w) in [(64, 2), (128, 4), (1024, 32)] {
            let k = blackscholes("x", 32, bs, 0, BS_TOTAL_WORK_4M);
            assert_eq!(k.warps_per_block, w);
        }
    }

    #[test]
    fn ratios_straddle_rb() {
        let gpu = GpuSpec::gtx580();
        assert!(ep("m", 16, 0).memory_bound(&gpu));
        assert!(smith_waterman("m", 16, 192, 0).memory_bound(&gpu));
        assert!(!blackscholes("c", 32, 256, 0, 1e5).memory_bound(&gpu));
        assert!(!electrostatics("c", 64, 128, 0).memory_bound(&gpu));
    }

    #[test]
    fn all_apps_fit_on_an_sm() {
        let gpu = GpuSpec::gtx580();
        assert!(ep("a", 16, 48 * 1024).block_fits(&gpu));
        assert!(blackscholes("b", 32, 1024, 0, 1e5).block_fits(&gpu));
        assert!(electrostatics("c", 64, 128, 0).block_fits(&gpu));
        assert!(smith_waterman("d", 16, 192, 24 * 1024).block_fits(&gpu));
    }
}
