//! Dependency-aware workloads: kernels plus precedence edges.
//!
//! Everything before this module assumed independent kernels — any of the
//! `n!` launch orders was admissible. Real workloads are kernel *graphs*
//! (ACS, GOLDYLOC in PAPERS.md): a kernel may consume another's output,
//! so only **topological orders** of the precedence DAG may be launched.
//! [`Workload`] carries the kernels and the edge list; [`DepGraph`] is
//! the validated, bitmask-compiled form every searcher consumes:
//!
//! * `pred_masks[k]` — the set of kernels that must finish before `k`
//!   launches, as a `u64` bitmask (hence the 64-kernel ceiling, far above
//!   the `n ≤ 12` sweep wall and any search workload to date).
//! * [`DepGraph::is_free`] answers prefix feasibility in one AND: kernel
//!   `k` may extend a prefix iff `pred_masks[k] & !used == 0`. Infeasible
//!   prefixes prune their entire subtree of the lexicographic sweep tree
//!   for free.
//! * [`DepGraph::linear_extension_count`] prices the constrained space —
//!   the DAG analogue of `n!` — via the standard subset DP, so benches
//!   can report how much the deps shrink the search.
//!
//! Construction is builder-style ([`Workload::with_dep`] /
//! [`Workload::with_chain`]), validation is explicit
//! ([`Workload::dep_graph`] rejects out-of-range edges, self-loops and
//! cycles with actionable errors), and the edge list round-trips through
//! the `kreorder-deps` CSV format ([`deps_to_csv`] / [`parse_deps`], also
//! the CLI's inline `0->2;1->2` spelling).

use crate::gpu::{GpuSpec, KernelProfile};
use crate::sim::{validate_workload, SimError};

/// Hard ceiling on dependency-aware workload size: predecessor sets are
/// `u64` bitmasks. Independent workloads (no deps) are not affected.
pub const MAX_DAG_KERNELS: usize = 64;

/// A batch of kernels plus optional precedence edges `(pred, succ)`:
/// `pred` must finish before `succ` may launch. An empty `deps` list is
/// the classic independent-kernel workload — every consumer treats it
/// bit-identically to the pre-DAG code paths.
#[derive(Debug, Clone, Default)]
pub struct Workload {
    pub kernels: Vec<KernelProfile>,
    pub deps: Vec<(usize, usize)>,
}

impl Workload {
    /// An independent-kernel workload (no precedence edges).
    pub fn independent(kernels: Vec<KernelProfile>) -> Self {
        Workload {
            kernels,
            deps: Vec::new(),
        }
    }

    /// A workload with an explicit edge list (validated lazily by
    /// [`Workload::dep_graph`]).
    pub fn new(kernels: Vec<KernelProfile>, deps: Vec<(usize, usize)>) -> Self {
        Workload { kernels, deps }
    }

    /// Builder: add one precedence edge `pred -> succ`.
    pub fn with_dep(mut self, pred: usize, succ: usize) -> Self {
        self.deps.push((pred, succ));
        self
    }

    /// Builder: add a chain `ks[0] -> ks[1] -> …` of precedence edges.
    pub fn with_chain(mut self, ks: &[usize]) -> Self {
        for w in ks.windows(2) {
            self.deps.push((w[0], w[1]));
        }
        self
    }

    /// Number of kernels.
    pub fn n(&self) -> usize {
        self.kernels.len()
    }

    /// Whether any precedence edges are present.
    pub fn has_deps(&self) -> bool {
        !self.deps.is_empty()
    }

    /// Compile and validate the precedence edges into a [`DepGraph`].
    /// Rejects out-of-range endpoints, self-loops, cycles, and workloads
    /// past the 64-kernel bitmask ceiling.
    pub fn dep_graph(&self) -> Result<DepGraph, DagError> {
        DepGraph::build(self.kernels.len(), &self.deps)
    }

    /// The dependency edges in the `kreorder-deps` CSV format (round-trips
    /// through [`parse_deps`]).
    pub fn deps_to_csv(&self) -> String {
        deps_to_csv(&self.deps)
    }
}

/// Validate a dependency-aware workload end to end: every kernel must be
/// simulable ([`crate::sim::validate_workload`]) and the edges must form
/// a DAG over the kernel indices. Returns the compiled [`DepGraph`].
pub fn validate_dag_workload(gpu: &GpuSpec, w: &Workload) -> Result<DepGraph, DagWorkloadError> {
    validate_workload(gpu, &w.kernels).map_err(DagWorkloadError::Kernels)?;
    w.dep_graph().map_err(DagWorkloadError::Deps)
}

/// Either half of [`validate_dag_workload`] can fail.
#[derive(Debug, Clone)]
pub enum DagWorkloadError {
    Kernels(SimError),
    Deps(DagError),
}

impl std::fmt::Display for DagWorkloadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DagWorkloadError::Kernels(e) => write!(f, "{e}"),
            DagWorkloadError::Deps(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for DagWorkloadError {}

/// Validated, bitmask-compiled precedence constraints over `n` kernels.
/// The searchers' single source of feasibility truth: a launch order is
/// admissible iff it is a topological order of this graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DepGraph {
    n: usize,
    /// `pred_masks[k]`: bitmask of kernels that must precede `k`.
    pred_masks: Vec<u64>,
    /// `succ_masks[k]`: bitmask of kernels that `k` must precede.
    succ_masks: Vec<u64>,
}

impl DepGraph {
    /// The unconstrained graph over `n` kernels (every order feasible).
    pub fn empty(n: usize) -> Self {
        DepGraph {
            n,
            pred_masks: vec![0; n],
            succ_masks: vec![0; n],
        }
    }

    /// Compile `deps` over `n` kernels, rejecting malformed input with an
    /// actionable error. Duplicate edges are tolerated (masks dedup).
    pub fn build(n: usize, deps: &[(usize, usize)]) -> Result<Self, DagError> {
        if !deps.is_empty() && n > MAX_DAG_KERNELS {
            return Err(DagError::TooManyKernels { n });
        }
        let mut g = DepGraph::empty(n);
        for &(pred, succ) in deps {
            if pred >= n || succ >= n {
                return Err(DagError::EdgeOutOfRange { pred, succ, n });
            }
            if pred == succ {
                return Err(DagError::SelfLoop { kernel: pred });
            }
            g.pred_masks[succ] |= 1 << pred;
            g.succ_masks[pred] |= 1 << succ;
        }
        // Kahn's algorithm: repeatedly place free kernels; anything left
        // over participates in (or depends on) a cycle.
        let mut used = 0u64;
        let mut placed = 0usize;
        loop {
            let mut progressed = false;
            for k in 0..n {
                if used & (1 << k) == 0 && g.pred_masks[k] & !used == 0 {
                    used |= 1 << k;
                    placed += 1;
                    progressed = true;
                }
            }
            if !progressed {
                break;
            }
        }
        if placed != n {
            let stuck: Vec<usize> = (0..n).filter(|k| used & (1 << k) == 0).collect();
            return Err(DagError::Cycle { stuck });
        }
        Ok(g)
    }

    /// Number of kernels the graph constrains.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Whether any edge exists (`false` ⇒ every order is feasible and
    /// every consumer takes its pre-DAG fast path).
    pub fn has_deps(&self) -> bool {
        self.pred_masks.iter().any(|&m| m != 0)
    }

    /// Predecessor bitmask of kernel `k`.
    pub fn pred_mask(&self, k: usize) -> u64 {
        self.pred_masks[k]
    }

    /// Successor bitmask of kernel `k`.
    pub fn succ_mask(&self, k: usize) -> u64 {
        self.succ_masks[k]
    }

    /// The dependency signature of kernel `k` — two kernels are
    /// interchangeable under the precedence constraints iff their
    /// signatures match (and an edge between them forces a mismatch, so
    /// signature-equal kernels are never related).
    pub fn signature(&self, k: usize) -> (u64, u64) {
        (self.pred_masks[k], self.succ_masks[k])
    }

    /// Prefix feasibility in one AND: may `k` extend a prefix whose
    /// placed kernels are `used` (bitmask)?
    #[inline]
    pub fn is_free(&self, k: usize, used: u64) -> bool {
        self.pred_masks[k] & !used == 0
    }

    /// Is `order` a topological order (a permutation of `0..n` where
    /// every kernel follows all of its predecessors)?
    pub fn is_topological(&self, order: &[usize]) -> bool {
        if order.len() != self.n {
            return false;
        }
        let mut used = 0u64;
        for &k in order {
            if k >= self.n || used & (1 << k) != 0 || !self.is_free(k, used) {
                return false;
            }
            used |= 1 << k;
        }
        true
    }

    /// Greedy **stable topological repair** of a suggested order: place,
    /// at each step, the earliest not-yet-placed kernel of `suggestion`
    /// whose predecessors are all placed. For an empty graph this returns
    /// `suggestion` verbatim; for `suggestion == 0..n` it returns the
    /// lexicographically smallest topological order. Deterministic; the
    /// DAG-aware searchers use it to make the Algorithm-1 warm start and
    /// restart shuffles feasible without changing them when no deps exist.
    pub fn repair(&self, suggestion: &[usize]) -> Vec<usize> {
        debug_assert_eq!(suggestion.len(), self.n);
        let mut used = 0u64;
        let mut out = Vec::with_capacity(self.n);
        for _ in 0..self.n {
            let k = suggestion
                .iter()
                .copied()
                .find(|&k| used & (1 << k) == 0 && self.is_free(k, used))
                .expect("a validated DAG always has a free kernel");
            used |= 1 << k;
            out.push(k);
        }
        out
    }

    /// The lexicographically smallest topological order — the DAG
    /// analogue of the identity order (and exactly the identity when no
    /// deps exist). Reference order for histograms and FIFO baselines.
    pub fn first_topological_order(&self) -> Vec<usize> {
        let identity: Vec<usize> = (0..self.n).collect();
        self.repair(&identity)
    }

    /// Number of topological orders (linear extensions) — the DAG
    /// analogue of `n!` — by the standard subset DP. `None` past n = 20,
    /// where the `2^n` table stops being reasonable (every exhaustive
    /// consumer is long past its wall there anyway).
    pub fn linear_extension_count(&self) -> Option<u128> {
        let n = self.n;
        if n > 20 {
            return None;
        }
        if n == 0 {
            return Some(1);
        }
        let mut dp = vec![0u128; 1usize << n];
        dp[0] = 1;
        for mask in 0..(1usize << n) {
            if dp[mask] == 0 {
                continue;
            }
            for k in 0..n {
                let bit = 1u64 << k;
                if mask as u64 & bit == 0 && self.is_free(k, mask as u64) {
                    dp[mask | bit as usize] += dp[mask];
                }
            }
        }
        Some(dp[(1usize << n) - 1])
    }
}

/// Malformed precedence edges, with enough context to fix them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DagError {
    /// An edge endpoint is not a kernel index of this workload.
    EdgeOutOfRange { pred: usize, succ: usize, n: usize },
    /// An edge `k -> k`.
    SelfLoop { kernel: usize },
    /// The edges admit no topological order; `stuck` lists every kernel
    /// that participates in (or depends on) a cycle.
    Cycle { stuck: Vec<usize> },
    /// More kernels than the u64 predecessor bitmasks can address.
    TooManyKernels { n: usize },
}

impl std::fmt::Display for DagError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DagError::EdgeOutOfRange { pred, succ, n } => write!(
                f,
                "dependency edge `{pred}->{succ}` is out of range for a {n}-kernel workload — \
                 kernel indices run 0..={}",
                n.saturating_sub(1)
            ),
            DagError::SelfLoop { kernel } => write!(
                f,
                "dependency edge `{kernel}->{kernel}` is a self-loop — a kernel cannot precede \
                 itself"
            ),
            DagError::Cycle { stuck } => write!(
                f,
                "dependency edges form a cycle through kernels {stuck:?} — precedence must be a \
                 DAG (no topological order exists); remove one edge of the cycle"
            ),
            DagError::TooManyKernels { n } => write!(
                f,
                "{n} kernels exceed the {MAX_DAG_KERNELS}-kernel dependency ceiling (predecessor \
                 sets are u64 bitmasks) — split the workload or drop the deps"
            ),
        }
    }
}

impl std::error::Error for DagError {}

/// A dependency spelling that did not parse; `Display` echoes the
/// offending clause and lists the valid forms.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DepsParseError {
    pub input: String,
    pub reason: String,
}

impl std::fmt::Display for DepsParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "invalid dependency clause `{}`: {} — valid clauses: `<pred>-><succ>` or \
             `<pred>,<succ>` (kernel indices), joined with `;` or newlines; `#` comments and \
             blank clauses are skipped",
            self.input, self.reason
        )
    }
}

impl std::error::Error for DepsParseError {}

/// Parse a dependency edge list. Accepts the CLI's inline spelling
/// (`0->2;1->2`) and the `kreorder-deps` CSV format emitted by
/// [`deps_to_csv`] (one `pred,succ` row per line, `#` comments); the two
/// may be mixed. Range/cycle checking happens later, against a concrete
/// workload, in [`DepGraph::build`].
pub fn parse_deps(text: &str) -> Result<Vec<(usize, usize)>, DepsParseError> {
    let mut out = Vec::new();
    for raw in text.split(['\n', ';']) {
        let clause = raw.trim();
        if clause.is_empty() || clause.starts_with('#') || clause == "pred,succ" {
            continue;
        }
        let (a, b) = clause
            .split_once("->")
            .or_else(|| clause.split_once(','))
            .ok_or_else(|| DepsParseError {
                input: clause.to_string(),
                reason: "expected `<pred>-><succ>` or `<pred>,<succ>`".to_string(),
            })?;
        let parse_idx = |s: &str, side: &str| -> Result<usize, DepsParseError> {
            s.trim().parse().map_err(|_| DepsParseError {
                input: clause.to_string(),
                reason: format!("{side} kernel index `{}` must be a non-negative integer", s.trim()),
            })
        };
        out.push((parse_idx(a, "pred")?, parse_idx(b, "succ")?));
    }
    Ok(out)
}

/// The `kreorder-deps` CSV format: header, then one `pred,succ` row per
/// edge. Round-trips through [`parse_deps`].
pub fn deps_to_csv(deps: &[(usize, usize)]) -> String {
    let mut s = String::from("# kreorder-deps v1\npred,succ\n");
    for &(p, q) in deps {
        s.push_str(&format!("{p},{q}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_and_masks() {
        let w = Workload::independent(Vec::new())
            .with_dep(0, 2)
            .with_chain(&[1, 2, 3]);
        assert_eq!(w.deps, vec![(0, 2), (1, 2), (2, 3)]);
        let g = DepGraph::build(4, &w.deps).unwrap();
        assert!(g.has_deps());
        assert_eq!(g.pred_mask(2), 0b0011);
        assert_eq!(g.succ_mask(2), 0b1000);
        assert_eq!(g.signature(0), (0, 0b0100));
    }

    #[test]
    fn build_rejects_malformed_edges() {
        let e = DepGraph::build(3, &[(0, 3)]).unwrap_err();
        assert!(matches!(e, DagError::EdgeOutOfRange { pred: 0, succ: 3, n: 3 }));
        let msg = e.to_string();
        assert!(msg.contains("`0->3`") && msg.contains("3-kernel"), "{msg}");

        let e = DepGraph::build(3, &[(1, 1)]).unwrap_err();
        assert!(matches!(e, DagError::SelfLoop { kernel: 1 }));
        assert!(e.to_string().contains("`1->1`"), "{e}");

        let e = DepGraph::build(3, &[(0, 1), (1, 2), (2, 0)]).unwrap_err();
        match &e {
            DagError::Cycle { stuck } => assert_eq!(stuck, &vec![0, 1, 2]),
            other => panic!("expected cycle, got {other:?}"),
        }
        assert!(e.to_string().contains("cycle"), "{e}");

        let e = DepGraph::build(65, &[(0, 64)]).unwrap_err();
        assert!(matches!(e, DagError::TooManyKernels { n: 65 }));
        // No deps: large n stays fine (independent workloads unaffected).
        assert!(DepGraph::build(65, &[]).is_ok());
    }

    #[test]
    fn cycle_report_excludes_unrelated_kernels() {
        // 3 -> 4 is fine; 0/1 cycle, 2 depends on the cycle.
        let e = DepGraph::build(5, &[(0, 1), (1, 0), (1, 2), (3, 4)]).unwrap_err();
        match e {
            DagError::Cycle { stuck } => assert_eq!(stuck, vec![0, 1, 2]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn feasibility_and_topological_checks() {
        let g = DepGraph::build(3, &[(0, 1), (0, 2)]).unwrap();
        assert!(g.is_free(0, 0));
        assert!(!g.is_free(1, 0));
        assert!(g.is_free(1, 0b001));
        assert!(g.is_topological(&[0, 1, 2]));
        assert!(g.is_topological(&[0, 2, 1]));
        assert!(!g.is_topological(&[1, 0, 2]));
        assert!(!g.is_topological(&[0, 1])); // wrong length
        assert!(!g.is_topological(&[0, 1, 1])); // not a permutation
    }

    #[test]
    fn repair_is_stable_and_identity_when_unconstrained() {
        let g = DepGraph::empty(4);
        assert_eq!(g.repair(&[2, 0, 3, 1]), vec![2, 0, 3, 1]);

        let g = DepGraph::build(4, &[(3, 0)]).unwrap();
        // 0 is blocked until 3 is placed; everything else keeps its slot.
        assert_eq!(g.repair(&[0, 1, 3, 2]), vec![1, 3, 0, 2]);
        assert_eq!(g.first_topological_order(), vec![1, 2, 3, 0]);
    }

    #[test]
    fn linear_extension_counts_on_hand_computed_dags() {
        // Chain: exactly one order.
        let chain = DepGraph::build(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]).unwrap();
        assert_eq!(chain.linear_extension_count(), Some(1));
        // Antichain: all n! orders.
        let anti = DepGraph::empty(5);
        assert_eq!(anti.linear_extension_count(), Some(120));
        // Fan-out from 0: root first, then any order of the rest.
        let fan = DepGraph::build(4, &[(0, 1), (0, 2), (0, 3)]).unwrap();
        assert_eq!(fan.linear_extension_count(), Some(6));
        // Two independent 2-chains: C(4,2) = 6 interleavings.
        let two = DepGraph::build(4, &[(0, 1), (2, 3)]).unwrap();
        assert_eq!(two.linear_extension_count(), Some(6));
        // Past the DP wall: priced as unknown, not wrong.
        assert_eq!(DepGraph::empty(21).linear_extension_count(), None);
        assert_eq!(DepGraph::empty(0).linear_extension_count(), Some(1));
    }

    #[test]
    fn deps_csv_round_trips() {
        let deps = vec![(0, 2), (1, 2), (2, 3)];
        let csv = deps_to_csv(&deps);
        assert!(csv.starts_with("# kreorder-deps v1"));
        assert_eq!(parse_deps(&csv).unwrap(), deps);
        // Inline CLI spelling parses to the same edges.
        assert_eq!(parse_deps("0->2; 1->2;2->3").unwrap(), deps);
        // Mixed separators and comments are fine.
        assert_eq!(parse_deps("# c\n0,2\n1->2;\n\n2,3").unwrap(), deps);
    }

    #[test]
    fn deps_parse_rejects_hostile_input() {
        for (s, needle) in [
            ("0", "expected"),
            ("a->1", "pred kernel index"),
            ("1->b", "succ kernel index"),
            ("0->-1", "succ kernel index"),
            ("->", "pred kernel index"),
            ("0->1->2", "succ kernel index"),
        ] {
            let err = parse_deps(s).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(needle), "`{s}`: expected `{needle}` in: {msg}");
            assert!(msg.contains("valid clauses"), "{msg}");
            assert!(msg.contains(&format!("`{}`", s.trim())), "input not echoed: {msg}");
        }
    }

    #[test]
    fn validate_dag_workload_checks_both_halves() {
        let gpu = GpuSpec::gtx580();
        let ks = crate::workloads::synthetic_workload(&gpu, 3, 7);
        let ok = Workload::new(ks.clone(), vec![(0, 1)]);
        assert!(validate_dag_workload(&gpu, &ok).is_ok());
        let cyclic = Workload::new(ks, vec![(0, 1), (1, 0)]);
        let err = validate_dag_workload(&gpu, &cyclic).unwrap_err();
        assert!(err.to_string().contains("cycle"), "{err}");
    }
}
