//! The six experiments of Table 2 / Table 3.

use super::apps::{blackscholes, electrostatics, ep, smith_waterman, BS_TOTAL_WORK_4M, BS_TOTAL_WORK_MIXED};
use crate::gpu::KernelProfile;

/// A named paper experiment: id (CLI / bench key), display name, kernels.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub id: &'static str,
    pub name: &'static str,
    pub kernels: Vec<KernelProfile>,
}

/// `EP-6-shm`: six EP kernels, grid 16 × block 128, varying only the
/// shared memory per block: 8K…48K.
pub fn ep_6_shm() -> Vec<KernelProfile> {
    [8u32, 16, 24, 32, 40, 48]
        .iter()
        .map(|kb| ep(&format!("-shm{kb}K"), 16, kb * 1024))
        .collect()
}

/// `EP-6-grid`: six EP kernels, block 128, no shared memory, varying only
/// the grid size 16…96 (per-SM warp footprint 4…24).
pub fn ep_6_grid() -> Vec<KernelProfile> {
    [16u32, 32, 48, 64, 80, 96]
        .iter()
        .map(|g| ep(&format!("-grid{g}"), *g, 0))
        .collect()
}

/// `BS-6-blk`: six BlackScholes kernels, grid 32, varying only the block
/// size 64…1024 (warps per block 2…32).
pub fn bs_6_blk() -> Vec<KernelProfile> {
    [64u32, 128, 256, 512, 768, 1024]
        .iter()
        .map(|b| blackscholes(&format!("-blk{b}"), 32, *b, 0, BS_TOTAL_WORK_4M))
        .collect()
}

/// `EpBs-6`: three EP kernels (per-SM warps 4) + three BlackScholes
/// kernels (per-SM warps 12: grid 32 × block 192, two blocks per SM).
pub fn epbs_6() -> Vec<KernelProfile> {
    let mut ks = Vec::new();
    for i in 1..=3 {
        ks.push(ep(&format!("#{i}"), 16, 0));
    }
    for i in 1..=3 {
        ks.push(blackscholes(&format!("#{i}"), 32, 192, 0, BS_TOTAL_WORK_MIXED));
    }
    ks
}

/// `EpBs-6-shm`: `EpBs-6` plus per-SM shared-memory footprints of
/// 16K / 24K / 48K for each application triple (BS runs two blocks per
/// SM, so its per-block figures are half the footprint).
pub fn epbs_6_shm() -> Vec<KernelProfile> {
    let mut ks = Vec::new();
    for (i, kb) in [16u32, 24, 48].iter().enumerate() {
        ks.push(ep(&format!("#{}-shm{kb}K", i + 1), 16, kb * 1024));
    }
    for (i, kb) in [16u32, 24, 48].iter().enumerate() {
        ks.push(blackscholes(
            &format!("#{}-shm{kb}K", i + 1),
            32,
            192,
            kb * 1024 / 2,
            BS_TOTAL_WORK_MIXED,
        ));
    }
    ks
}

/// `EpBsEsSw-8`: two kernels each from EP, BS, ES and SW, varying every
/// metric (`N_tblk`, `N_reg`, `N_shm`, `N_warp`, `R`) across kernels.
pub fn epbsessw_8() -> Vec<KernelProfile> {
    vec![
        ep("#1", 16, 0),
        ep("#2-shm16K", 32, 16 * 1024),
        blackscholes("#1", 32, 256, 0, BS_TOTAL_WORK_MIXED),
        blackscholes("#2", 16, 512, 0, BS_TOTAL_WORK_MIXED),
        electrostatics("#1", 32, 128, 0),
        electrostatics("#2-shm8K", 32, 256, 8 * 1024),
        smith_waterman("#1-shm24K", 16, 192, 24 * 1024),
        smith_waterman("#2-shm40K", 16, 192, 40 * 1024),
    ]
}

/// All six Table-2/Table-3 experiments, in the paper's row order.
pub fn all_experiments() -> Vec<Experiment> {
    vec![
        Experiment {
            id: "ep-6-shm",
            name: "EP-6-shm",
            kernels: ep_6_shm(),
        },
        Experiment {
            id: "ep-6-grid",
            name: "EP-6-grid",
            kernels: ep_6_grid(),
        },
        Experiment {
            id: "bs-6-blk",
            name: "BS-6-blk",
            kernels: bs_6_blk(),
        },
        Experiment {
            id: "epbs-6",
            name: "EpBs-6",
            kernels: epbs_6(),
        },
        Experiment {
            id: "epbs-6-shm",
            name: "EpBs-6-shm",
            kernels: epbs_6_shm(),
        },
        Experiment {
            id: "epbsessw-8",
            name: "EpBsEsSw-8",
            kernels: epbsessw_8(),
        },
    ]
}

/// Resolve an experiment by CLI id (case-insensitive).
pub fn by_id(id: &str) -> Option<Experiment> {
    let id = id.to_ascii_lowercase();
    all_experiments().into_iter().find(|e| e.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuSpec;

    #[test]
    fn ep_6_shm_varies_only_shmem() {
        let ks = ep_6_shm();
        for k in &ks {
            assert_eq!(k.n_blocks, 16);
            assert_eq!(k.warps_per_block, 4);
            assert!((k.ratio - 3.11).abs() < 1e-12);
        }
        let shms: Vec<u32> = ks.iter().map(|k| k.shmem_per_block / 1024).collect();
        assert_eq!(shms, vec![8, 16, 24, 32, 40, 48]);
    }

    #[test]
    fn ep_6_grid_warp_footprints_match_table2() {
        // Table 2: N_warp_i = 4, 8, 12, 16, 20, 24 per SM.
        let gpu = GpuSpec::gtx580();
        let fps: Vec<f64> = ep_6_grid()
            .iter()
            .map(|k| k.per_sm_footprint(&gpu).warps)
            .collect();
        assert_eq!(fps, vec![4.0, 8.0, 12.0, 16.0, 20.0, 24.0]);
    }

    #[test]
    fn bs_6_blk_warps_match_table2() {
        let ws: Vec<u32> = bs_6_blk().iter().map(|k| k.warps_per_block).collect();
        assert_eq!(ws, vec![2, 4, 8, 16, 24, 32]);
    }

    #[test]
    fn epbs_6_fills_one_round_exactly() {
        // 3×4 + 3×12 = 48 warps/SM — exactly the GTX580 capacity, the
        // design point of the paper's EpBs-6.
        let gpu = GpuSpec::gtx580();
        let total: f64 = epbs_6()
            .iter()
            .map(|k| k.per_sm_footprint(&gpu).warps)
            .sum();
        assert_eq!(total, 48.0);
    }

    #[test]
    fn epbs_6_shm_footprints() {
        let gpu = GpuSpec::gtx580();
        let ks = epbs_6_shm();
        let fps: Vec<f64> = ks.iter().map(|k| k.per_sm_footprint(&gpu).shmem / 1024.0).collect();
        assert_eq!(fps, vec![16.0, 24.0, 48.0, 16.0, 24.0, 48.0]);
    }

    #[test]
    fn epbsessw_8_varies_everything() {
        let ks = epbsessw_8();
        assert_eq!(ks.len(), 8);
        let distinct = |f: &dyn Fn(&KernelProfile) -> u64| {
            let mut v: Vec<u64> = ks.iter().map(f).collect();
            v.sort_unstable();
            v.dedup();
            v.len()
        };
        assert!(distinct(&|k| k.n_blocks as u64) >= 2);
        assert!(distinct(&|k| k.regs_per_block as u64) >= 4);
        assert!(distinct(&|k| k.shmem_per_block as u64) >= 4);
        assert!(distinct(&|k| k.warps_per_block as u64) >= 3);
        assert!(distinct(&|k| (k.ratio * 100.0) as u64) == 4);
    }
}
