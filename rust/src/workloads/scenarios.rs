//! Named scenario families — diverse, seeded workload generators beyond
//! the paper's six fixed experiments.
//!
//! The paper evaluates hand-built 6–8 kernel mixes; the search subsystem
//! and its CI quality gates need *families* of workloads whose structure
//! stresses different parts of the model at any `n`:
//!
//! | id | stress |
//! |---|---|
//! | `uniform` | baseline synthetic mix (log-uniform ratios, mixed occupancy) |
//! | `skewed` | heavy-tailed durations: ~20 % dominant kernels among light ones |
//! | `complementary` | memory-bound shmem hogs paired with compute-bound warp hogs |
//! | `small-large` | many near-trivial kernels hiding a few SM-filling giants |
//! | `mixed` | multi-device-style stream: each kernel drawn from a random family |
//!
//! Every generated kernel passes [`crate::sim::validate_workload`]
//! (pinned by tests across seeds and sizes), and equal `(family, n,
//! seed)` always produces the identical workload, so search results and
//! bench gates are reproducible.

use super::dag::Workload;
use super::synthetic_workload;
use crate::gpu::{AppKind, GpuSpec, KernelProfile};
use crate::util::SplitMix64;

/// One named workload family.
pub struct Scenario {
    /// Stable spelling used by the CLI and benches (e.g. `"skewed"`).
    pub id: &'static str,
    pub description: &'static str,
    gen: fn(&GpuSpec, usize, u64) -> Vec<KernelProfile>,
}

impl Scenario {
    /// Generate this family's workload of `n` kernels. Deterministic per
    /// `(n, seed)`.
    pub fn workload(&self, gpu: &GpuSpec, n: usize, seed: u64) -> Vec<KernelProfile> {
        (self.gen)(gpu, n, seed)
    }
}

/// The scenario registry.
pub static SCENARIOS: &[Scenario] = &[
    Scenario {
        id: "uniform",
        description: "baseline synthetic mix (log-uniform ratios, mixed occupancy)",
        gen: gen_uniform,
    },
    Scenario {
        id: "skewed",
        description: "heavy-tailed durations: a few dominant kernels among many light ones",
        gen: gen_skewed,
    },
    Scenario {
        id: "complementary",
        description: "resource-complementary pairs: memory-bound shmem hogs + compute warp hogs",
        gen: gen_complementary,
    },
    Scenario {
        id: "small-large",
        description: "many small kernels hiding a few SM-filling giants",
        gen: gen_small_large,
    },
    Scenario {
        id: "mixed",
        description: "multi-device style stream: every kernel drawn from a random family",
        gen: gen_mixed,
    },
];

/// All registered scenario families.
pub fn all_scenarios() -> &'static [Scenario] {
    SCENARIOS
}

/// The registered family ids, in registry order — the arrival-regime
/// axis the online bench and `kreorder serve --arrivals` sweep.
pub fn scenario_ids() -> Vec<&'static str> {
    SCENARIOS.iter().map(|s| s.id).collect()
}

/// Look a family up by its `id` spelling.
pub fn scenario_by_id(id: &str) -> Option<&'static Scenario> {
    SCENARIOS.iter().find(|s| s.id.eq_ignore_ascii_case(id))
}

/// One named **dependency-aware** workload family: kernels plus a
/// precedence DAG. Every generator emits edges only from lower to higher
/// kernel index, so the arrival (identity) order of a DAG batch is a
/// valid topological order *by construction* — the invariant the online
/// FIFO guard rests on.
pub struct DagScenario {
    /// Stable spelling used by the CLI and benches (e.g. `"chain"`).
    pub id: &'static str,
    pub description: &'static str,
    gen: fn(&GpuSpec, usize, u64) -> Workload,
}

impl DagScenario {
    /// Generate this family's `n`-kernel DAG workload. Deterministic per
    /// `(n, seed)`.
    pub fn workload(&self, gpu: &GpuSpec, n: usize, seed: u64) -> Workload {
        (self.gen)(gpu, n, seed)
    }
}

/// The DAG scenario registry.
pub static DAG_SCENARIOS: &[DagScenario] = &[
    DagScenario {
        id: "chain",
        description: "total order 0 -> 1 -> … (one linear extension: search is a no-op)",
        gen: gen_dag_chain,
    },
    DagScenario {
        id: "fanout",
        description: "kernel 0 fans out to every other kernel ((n-1)! extensions)",
        gen: gen_dag_fanout,
    },
    DagScenario {
        id: "fanin",
        description: "every kernel feeds a final reduction kernel ((n-1)! extensions)",
        gen: gen_dag_fanin,
    },
    DagScenario {
        id: "layered",
        description: "random layered DAG: seeded layers, each node fed from the previous layer",
        gen: gen_dag_layered,
    },
    DagScenario {
        id: "mlinfer",
        description: "ML-inference shape: stem, two parallel branch chains, joining head",
        gen: gen_dag_mlinfer,
    },
];

/// All registered DAG scenario families.
pub fn all_dag_scenarios() -> &'static [DagScenario] {
    DAG_SCENARIOS
}

/// The registered DAG family ids, in registry order.
pub fn dag_scenario_ids() -> Vec<&'static str> {
    DAG_SCENARIOS.iter().map(|s| s.id).collect()
}

/// Look a DAG family up by its `id` spelling.
pub fn dag_scenario_by_id(id: &str) -> Option<&'static DagScenario> {
    DAG_SCENARIOS.iter().find(|s| s.id.eq_ignore_ascii_case(id))
}

fn gen_dag_chain(gpu: &GpuSpec, n: usize, seed: u64) -> Workload {
    let mut w = Workload::independent(gen_mixed(gpu, n, seed ^ 0xDA60_0001));
    for i in 1..n {
        w.deps.push((i - 1, i));
    }
    w
}

fn gen_dag_fanout(gpu: &GpuSpec, n: usize, seed: u64) -> Workload {
    let mut w = Workload::independent(gen_skewed(gpu, n, seed ^ 0xDA60_0002));
    for i in 1..n {
        w.deps.push((0, i));
    }
    w
}

fn gen_dag_fanin(gpu: &GpuSpec, n: usize, seed: u64) -> Workload {
    let mut w = Workload::independent(gen_small_large(gpu, n, seed ^ 0xDA60_0003));
    for i in 0..n.saturating_sub(1) {
        w.deps.push((i, n - 1));
    }
    w
}

fn gen_dag_layered(gpu: &GpuSpec, n: usize, seed: u64) -> Workload {
    let mut w = Workload::independent(gen_uniform(gpu, n, seed ^ 0xDA60_0004));
    let mut rng = SplitMix64::new(seed ^ 0xDA60_0004);
    // Seeded layer sizes of 1–3; layers are assigned in index order, so
    // every edge runs lower -> higher index.
    let mut layers: Vec<(usize, usize)> = Vec::new(); // [start, end)
    let mut start = 0;
    while start < n {
        let size = (1 + rng.below(3)).min(n - start);
        layers.push((start, start + size));
        start += size;
    }
    for pair in layers.windows(2) {
        let ((ps, pe), (cs, ce)) = (pair[0], pair[1]);
        for succ in cs..ce {
            // Each node draws a nonempty subset of the previous layer:
            // one guaranteed feeder plus coin-flip extras.
            let forced = ps + rng.below(pe - ps);
            for pred in ps..pe {
                if pred == forced || rng.next_f64() < 0.5 {
                    w.deps.push((pred, succ));
                }
            }
        }
    }
    w
}

fn gen_dag_mlinfer(gpu: &GpuSpec, n: usize, seed: u64) -> Workload {
    // Stem (0) -> two parallel branch chains -> joining head (n-1): the
    // classic two-tower inference graph. Degenerate sizes collapse
    // gracefully (n=1: no edges; n=2: stem -> head; n=3: one branch).
    let mut w = Workload::independent(gen_complementary(gpu, n, seed ^ 0xDA60_0005));
    if n < 2 {
        return w;
    }
    if n == 2 {
        w.deps.push((0, 1));
        return w;
    }
    let join = n - 1;
    let mid = n - 2; // kernels 1..=mid are branch bodies
    let a_len = (mid + 1) / 2; // MSRV 1.70: no usize::div_ceil yet
    let branch_a: Vec<usize> = (1..=a_len).collect();
    let branch_b: Vec<usize> = (a_len + 1..=mid).collect();
    for branch in [&branch_a, &branch_b] {
        if branch.is_empty() {
            continue;
        }
        w.deps.push((0, branch[0]));
        for pair in branch.windows(2) {
            w.deps.push((pair[0], pair[1]));
        }
        w.deps.push((branch[branch.len() - 1], join));
    }
    w
}

fn gen_uniform(gpu: &GpuSpec, n: usize, seed: u64) -> Vec<KernelProfile> {
    synthetic_workload(gpu, n, seed)
}

/// Log-uniform ratio across the memory/compute divide (shared by several
/// families).
fn draw_ratio(gpu: &GpuSpec, rng: &mut SplitMix64, lo: f64, hi_mult: f64) -> f64 {
    let log_lo = lo.ln();
    let log_hi = (gpu.balanced_ratio * hi_mult).ln();
    (log_lo + (log_hi - log_lo) * rng.next_f64()).exp()
}

fn gen_skewed(gpu: &GpuSpec, n: usize, seed: u64) -> Vec<KernelProfile> {
    let mut rng = SplitMix64::new(seed ^ 0x5EED_0002);
    (0..n)
        .map(|i| {
            // ~1 in 5 kernels dominates the runtime by 1–2 orders of
            // magnitude: the order then hinges on what runs beside them.
            let heavy = rng.next_f64() < 0.2 || (i == 0 && n >= 4);
            let work = if heavy {
                rng.range_f64(30_000.0, 120_000.0)
            } else {
                rng.range_f64(500.0, 4_000.0)
            };
            let warps = 2 + rng.below(12) as u32;
            let shmem = if rng.next_f64() < 0.3 {
                (1 + rng.below(4) as u32) * 4096
            } else {
                0
            };
            KernelProfile {
                name: format!("SKW#{i}{}", if heavy { "-heavy" } else { "" }),
                app: AppKind::Synthetic,
                n_blocks: gpu.n_sm * (1 + rng.below(4) as u32),
                regs_per_block: ((16 + rng.below(25) as u32) * warps * 32).min(gpu.regs_per_sm),
                shmem_per_block: shmem,
                warps_per_block: warps,
                ratio: draw_ratio(gpu, &mut rng, 0.5, 8.0),
                work_per_block: work,
                artifact: String::new(),
            }
        })
        .collect()
}

fn gen_complementary(gpu: &GpuSpec, n: usize, seed: u64) -> Vec<KernelProfile> {
    let mut rng = SplitMix64::new(seed ^ 0x5EED_0003);
    let mut ks: Vec<KernelProfile> = (0..n)
        .map(|i| {
            if i % 2 == 0 {
                // Memory-bound shared-memory hog: low occupancy, heavy
                // bandwidth demand — starves when packed with its own
                // kind.
                KernelProfile {
                    name: format!("CMP#{i}-mem"),
                    app: AppKind::Synthetic,
                    n_blocks: gpu.n_sm * (1 + rng.below(2) as u32),
                    regs_per_block: 4096,
                    shmem_per_block: (3 + rng.below(3) as u32) * 4096, // 12–20 KiB
                    warps_per_block: 4,
                    ratio: rng.range_f64(0.8, 2.5),
                    work_per_block: rng.range_f64(3_000.0, 8_000.0),
                    artifact: String::new(),
                }
            } else {
                // Compute-bound warp hog: saturates issue pipelines,
                // touches little memory — the ideal round-mate above.
                KernelProfile {
                    name: format!("CMP#{i}-cmp"),
                    app: AppKind::Synthetic,
                    n_blocks: gpu.n_sm * (1 + rng.below(3) as u32),
                    regs_per_block: 12_288,
                    shmem_per_block: 0,
                    warps_per_block: 16 + rng.below(9) as u32, // 16–24
                    ratio: rng.range_f64(15.0, 60.0),
                    work_per_block: rng.range_f64(3_000.0, 8_000.0),
                    artifact: String::new(),
                }
            }
        })
        .collect();
    // Scramble the arrival order so FIFO does not accidentally
    // interleave the pairs the generator built.
    rng.shuffle(&mut ks);
    for (i, k) in ks.iter_mut().enumerate() {
        k.name = format!("{}@{i}", k.name);
    }
    ks
}

fn gen_small_large(gpu: &GpuSpec, n: usize, seed: u64) -> Vec<KernelProfile> {
    let mut rng = SplitMix64::new(seed ^ 0x5EED_0004);
    let n_large = (n / 4).max(1);
    let mut ks: Vec<KernelProfile> = (0..n)
        .map(|i| {
            if i < n_large {
                // SM-filling giant: large grid, wide blocks, real work.
                KernelProfile {
                    name: format!("SL#{i}-large"),
                    app: AppKind::Synthetic,
                    n_blocks: gpu.n_sm * (4 + rng.below(4) as u32),
                    regs_per_block: 16_384,
                    shmem_per_block: if rng.next_f64() < 0.5 { 16_384 } else { 0 },
                    warps_per_block: 16 + rng.below(17) as u32, // 16–32
                    ratio: draw_ratio(gpu, &mut rng, 1.0, 6.0),
                    work_per_block: rng.range_f64(20_000.0, 60_000.0),
                    artifact: String::new(),
                }
            } else {
                // Near-trivial filler that packs around the giants.
                KernelProfile {
                    name: format!("SL#{i}-small"),
                    app: AppKind::Synthetic,
                    n_blocks: gpu.n_sm,
                    regs_per_block: 1024,
                    shmem_per_block: 0,
                    warps_per_block: 2 + rng.below(3) as u32,
                    ratio: draw_ratio(gpu, &mut rng, 0.5, 4.0),
                    work_per_block: rng.range_f64(500.0, 2_000.0),
                    artifact: String::new(),
                }
            }
        })
        .collect();
    rng.shuffle(&mut ks);
    ks
}

fn gen_mixed(gpu: &GpuSpec, n: usize, seed: u64) -> Vec<KernelProfile> {
    // A shared-cloud request stream headed for multi-device dispatch:
    // each slot draws from a random family (with a derived seed, so the
    // mix differs from any single family's output).
    let mut rng = SplitMix64::new(seed ^ 0x5EED_0005);
    let families: [fn(&GpuSpec, usize, u64) -> Vec<KernelProfile>; 4] =
        [gen_uniform, gen_skewed, gen_complementary, gen_small_large];
    let pools: Vec<Vec<KernelProfile>> = families
        .iter()
        .map(|g| g(gpu, n, seed.wrapping_mul(0x9E37).wrapping_add(17)))
        .collect();
    (0..n)
        .map(|i| {
            let f = rng.below(pools.len());
            let mut k = pools[f][i].clone();
            k.name = format!("MIX#{i}/{}", k.name);
            k
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::validate_workload;

    #[test]
    fn every_family_generates_valid_workloads() {
        let gpu = GpuSpec::gtx580();
        for sc in all_scenarios() {
            for n in [1usize, 2, 6, 10, 24] {
                for seed in 0..8u64 {
                    let ks = sc.workload(&gpu, n, seed);
                    assert_eq!(ks.len(), n, "{} n={n} seed={seed}", sc.id);
                    validate_workload(&gpu, &ks)
                        .unwrap_or_else(|e| panic!("{} n={n} seed={seed}: {e}", sc.id));
                }
            }
        }
    }

    #[test]
    fn families_are_deterministic_per_seed() {
        let gpu = GpuSpec::gtx580();
        for sc in all_scenarios() {
            assert_eq!(sc.workload(&gpu, 8, 5), sc.workload(&gpu, 8, 5), "{}", sc.id);
            assert_ne!(sc.workload(&gpu, 8, 5), sc.workload(&gpu, 8, 6), "{}", sc.id);
        }
    }

    #[test]
    fn scenario_ids_match_registry_order() {
        let ids = scenario_ids();
        assert_eq!(ids.len(), SCENARIOS.len());
        for (id, sc) in ids.iter().zip(SCENARIOS) {
            assert_eq!(*id, sc.id);
        }
    }

    #[test]
    fn ids_unique_and_resolvable() {
        let mut ids: Vec<&str> = SCENARIOS.iter().map(|s| s.id).collect();
        for id in &ids {
            assert!(scenario_by_id(id).is_some());
            assert!(scenario_by_id(&id.to_uppercase()).is_some(), "{id} case-insensitive");
        }
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), SCENARIOS.len());
        assert!(scenario_by_id("nonsense").is_none());
    }

    #[test]
    fn skewed_family_has_heavy_tail() {
        let gpu = GpuSpec::gtx580();
        let ks = scenario_by_id("skewed").unwrap().workload(&gpu, 12, 3);
        // Heavy kernels draw ≥ 30 000 work/block, light ones ≤ 4 000 — the
        // family guarantees at least one of each for n ≥ 4.
        let works: Vec<f64> = ks.iter().map(|k| k.work_per_block).collect();
        let max = works.iter().cloned().fold(0.0f64, f64::max);
        let min = works.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 7.0, "no skew: max {max} min {min}");
    }

    #[test]
    fn complementary_family_mixes_bound_types() {
        let gpu = GpuSpec::gtx580();
        let ks = scenario_by_id("complementary").unwrap().workload(&gpu, 10, 1);
        let mem = ks.iter().filter(|k| k.memory_bound(&gpu)).count();
        assert_eq!(mem, 5, "half the kernels must be memory-bound");
        // The memory-bound half carries the shared-memory footprint.
        for k in &ks {
            if k.memory_bound(&gpu) {
                assert!(k.shmem_per_block >= 12 * 1024, "{}", k.name);
            } else {
                assert_eq!(k.shmem_per_block, 0, "{}", k.name);
            }
        }
    }

    #[test]
    fn every_dag_family_is_acyclic_with_topological_arrival_order() {
        let gpu = GpuSpec::gtx580();
        for sc in all_dag_scenarios() {
            for n in [1usize, 2, 3, 6, 8, 12] {
                for seed in 0..6u64 {
                    let w = sc.workload(&gpu, n, seed);
                    assert_eq!(w.n(), n, "{} n={n} seed={seed}", sc.id);
                    let g = crate::workloads::validate_dag_workload(&gpu, &w)
                        .unwrap_or_else(|e| panic!("{} n={n} seed={seed}: {e}", sc.id));
                    // Edges only run lower -> higher index, so arrival
                    // order is topological by construction.
                    for &(p, q) in &w.deps {
                        assert!(p < q, "{} n={n} seed={seed}: edge {p}->{q}", sc.id);
                    }
                    let identity: Vec<usize> = (0..n).collect();
                    assert!(g.is_topological(&identity), "{} n={n} seed={seed}", sc.id);
                }
            }
        }
    }

    #[test]
    fn dag_families_are_deterministic_per_seed() {
        let gpu = GpuSpec::gtx580();
        for sc in all_dag_scenarios() {
            let (a, b) = (sc.workload(&gpu, 8, 5), sc.workload(&gpu, 8, 5));
            assert_eq!(a.kernels, b.kernels, "{}", sc.id);
            assert_eq!(a.deps, b.deps, "{}", sc.id);
        }
    }

    #[test]
    fn dag_family_shapes_pin_extension_counts() {
        let gpu = GpuSpec::gtx580();
        let count = |id: &str, n: usize| {
            dag_scenario_by_id(id)
                .unwrap()
                .workload(&gpu, n, 3)
                .dep_graph()
                .unwrap()
                .linear_extension_count()
                .unwrap()
        };
        assert_eq!(count("chain", 8), 1);
        assert_eq!(count("fanout", 8), 5040); // (n-1)!
        assert_eq!(count("fanin", 8), 5040);
        // mlinfer at n=8: two 3-chains between stem and join interleave
        // in C(6,3) ways.
        assert_eq!(count("mlinfer", 8), 20);
        // Layered is seeded but always strictly below the factorial.
        let layered = count("layered", 8);
        assert!(layered >= 1 && layered < 40320, "layered: {layered}");
    }

    #[test]
    fn dag_ids_unique_resolvable_and_disjoint_from_plain_families() {
        let mut ids = dag_scenario_ids();
        for id in &ids {
            assert!(dag_scenario_by_id(id).is_some());
            assert!(dag_scenario_by_id(&id.to_uppercase()).is_some(), "{id}");
            assert!(
                scenario_by_id(id).is_none(),
                "{id} shadows a plain scenario family"
            );
        }
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), DAG_SCENARIOS.len());
        assert!(dag_scenario_by_id("nonsense").is_none());
    }

    #[test]
    fn small_large_family_has_giants_and_fillers() {
        let gpu = GpuSpec::gtx580();
        let ks = scenario_by_id("small-large").unwrap().workload(&gpu, 12, 2);
        let large = ks.iter().filter(|k| k.name.contains("large")).count();
        assert_eq!(large, 3); // n/4
        let giant_work: f64 = ks
            .iter()
            .filter(|k| k.name.contains("large"))
            .map(|k| k.total_work())
            .sum();
        let filler_work: f64 = ks
            .iter()
            .filter(|k| k.name.contains("small"))
            .map(|k| k.total_work())
            .sum();
        assert!(giant_work > filler_work, "giants must dominate total work");
    }
}
