//! [`SimulatorBackend`] — the event-driven fluid simulator behind the
//! [`ExecutionBackend`] interface. This is the substrate every experiment
//! times; its makespans are bit-identical to calling
//! [`crate::sim::simulate_order`] directly (a unit test below pins that).

use super::{BackendReport, ExecutionBackend, PreparedWorkload};
use crate::gpu::{GpuSpec, KernelProfile};
use crate::sim::{self, SimState};
use std::time::Instant;

/// Fluid-simulation backend (the GTX580 model). Stateless; cheap to
/// construct per worker thread.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimulatorBackend;

impl SimulatorBackend {
    pub fn new() -> Self {
        SimulatorBackend
    }
}

impl ExecutionBackend for SimulatorBackend {
    fn name(&self) -> &str {
        "sim"
    }

    fn execute(
        &mut self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        order: &[usize],
    ) -> BackendReport {
        let t0 = Instant::now();
        // An unsimulable workload (oversized block, empty grid) would
        // deadlock the in-order dispatcher; report NaN rather than hang.
        if sim::validate_workload(gpu, kernels).is_err() {
            return BackendReport::unsimulable("sim", t0.elapsed().as_secs_f64() * 1e3, order);
        }

        let r = sim::simulate_order(gpu, kernels, order);
        BackendReport::from_finish_times(
            "sim",
            r.makespan_ms,
            t0.elapsed().as_secs_f64() * 1e3,
            order,
            &r.kernel_finish_ms,
        )
    }

    fn prepare<'a>(
        &'a mut self,
        gpu: &'a GpuSpec,
        kernels: &'a [KernelProfile],
    ) -> Box<dyn PreparedWorkload + 'a> {
        Box::new(PreparedSim::new(gpu, kernels))
    }
}

/// Prepared fluid-simulation workload: one reusable [`SimState`]
/// (validation, kernel constants, the jittered block-work table and all
/// scratch hoisted out of the per-order loop) with full prefix-checkpoint
/// support. Makespans are bit-identical to [`SimulatorBackend::execute`].
pub struct PreparedSim {
    state: SimState,
    valid: bool,
}

impl PreparedSim {
    pub fn new(gpu: &GpuSpec, kernels: &[KernelProfile]) -> Self {
        PreparedSim {
            state: SimState::new(gpu, kernels),
            valid: sim::validate_workload(gpu, kernels).is_ok(),
        }
    }
}

impl PreparedWorkload for PreparedSim {
    fn execute_order(&mut self, order: &[usize]) -> f64 {
        if !self.valid {
            return f64::NAN;
        }
        self.state.makespan_of(order)
    }

    fn supports_checkpoints(&self) -> bool {
        self.valid
    }

    fn checkpoint_push(&mut self, kernel: usize) {
        self.state.push_prefix_kernel(kernel);
    }

    fn checkpoint_pop(&mut self) {
        self.state.pop_prefix_kernel();
    }

    fn execute_suffix(&mut self, suffix: &[usize]) -> f64 {
        self.state.finish_with(suffix)
    }

    fn supports_depth_addressing(&self) -> bool {
        self.valid
    }

    fn execute_suffix_at(&mut self, depth: usize, suffix: &[usize]) -> f64 {
        self.state.finish_from(depth, suffix)
    }

    fn suffix_lower_bound(&mut self, remaining: &[usize]) -> f64 {
        if !self.valid {
            return f64::NEG_INFINITY;
        }
        self.state.suffix_lower_bound(remaining)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::AppKind;
    use crate::util::SplitMix64;
    use crate::workloads::epbsessw_8;

    /// Refactor-equivalence pin: the simulator backend's makespan must be
    /// identical to the pre-redesign direct `sim::simulate_order` call on
    /// the paper's EpBsEsSw-8 workload, for FIFO and shuffled orders.
    #[test]
    fn makespans_identical_to_direct_simulation_on_epbsessw_8() {
        let gpu = GpuSpec::gtx580();
        let ks = epbsessw_8();
        let mut backend = SimulatorBackend::new();

        let fifo: Vec<usize> = (0..ks.len()).collect();
        let mut orders = vec![fifo.clone()];
        for seed in 0..10u64 {
            let mut o = fifo.clone();
            SplitMix64::new(seed).shuffle(&mut o);
            orders.push(o);
        }
        for order in &orders {
            let direct = sim::simulate_order(&gpu, &ks, order).makespan_ms;
            let via_trait = backend.execute(&gpu, &ks, order).makespan_ms;
            assert_eq!(direct, via_trait, "order {order:?}");
        }
    }

    #[test]
    fn outcomes_carry_finish_times_in_launch_order() {
        let gpu = GpuSpec::gtx580();
        let ks = epbsessw_8();
        let order: Vec<usize> = (0..ks.len()).rev().collect();
        let report = SimulatorBackend::new().execute(&gpu, &ks, &order);
        assert_eq!(report.outcomes.len(), ks.len());
        let max_finish = report
            .outcomes
            .iter()
            .map(|o| o.finish_ms)
            .fold(0.0f64, f64::max);
        assert!((max_finish - report.makespan_ms).abs() < 1e-9);
        for (pos, o) in report.outcomes.iter().enumerate() {
            assert_eq!(o.position, pos);
            assert_eq!(o.index, order[pos]);
            assert!(o.checksum.is_nan());
            assert!(!o.failed);
        }
        assert_eq!(report.n_failures(), 0);
        // by_index inverts the order mapping.
        let by_index = report.by_index();
        for (i, o) in by_index.iter().enumerate() {
            assert_eq!(o.index, i);
        }
    }

    #[test]
    fn prepared_matches_execute_bitwise() {
        let gpu = GpuSpec::gtx580();
        let ks = epbsessw_8();
        let mut backend = SimulatorBackend::new();
        let mut orders = Vec::new();
        for seed in 0..8u64 {
            let mut o: Vec<usize> = (0..ks.len()).collect();
            SplitMix64::new(seed).shuffle(&mut o);
            orders.push(o);
        }
        let direct: Vec<f64> = orders
            .iter()
            .map(|o| backend.execute(&gpu, &ks, o).makespan_ms)
            .collect();
        let mut prepared = backend.prepare(&gpu, &ks);
        assert!(prepared.supports_checkpoints());
        for (o, d) in orders.iter().zip(&direct) {
            assert_eq!(prepared.execute_order(o).to_bits(), d.to_bits(), "{o:?}");
        }
    }

    #[test]
    fn prepared_checkpoints_match_flat_orders() {
        let gpu = GpuSpec::gtx580();
        let ks = epbsessw_8();
        let mut backend = SimulatorBackend::new();
        let mut prepared = backend.prepare(&gpu, &ks);
        let order: Vec<usize> = vec![5, 2, 7, 0, 3, 6, 1, 4];
        let flat = prepared.execute_order(&order);
        prepared.checkpoint_push(5);
        prepared.checkpoint_push(2);
        let ck = prepared.execute_suffix(&order[2..]);
        assert_eq!(ck.to_bits(), flat.to_bits());
        // Depth-addressed completions reuse mid-stack checkpoints and
        // leave the deeper ones usable.
        assert_eq!(prepared.execute_suffix_at(1, &order[1..]).to_bits(), flat.to_bits());
        assert_eq!(prepared.execute_suffix_at(0, &order).to_bits(), flat.to_bits());
        assert_eq!(prepared.execute_suffix(&order[2..]).to_bits(), flat.to_bits());
        prepared.checkpoint_pop();
        prepared.checkpoint_pop();
    }

    #[test]
    fn unsimulable_workload_reports_nan_not_hang() {
        let gpu = GpuSpec::gtx580();
        let bad = KernelProfile {
            name: "bad".into(),
            app: AppKind::Synthetic,
            n_blocks: 1,
            regs_per_block: 512,
            shmem_per_block: 0,
            warps_per_block: 64, // > 48 warps/SM: never fits
            ratio: 2.0,
            work_per_block: 100.0,
            artifact: String::new(),
        };
        let ks = [bad];
        let report = SimulatorBackend::new().execute(&gpu, &ks, &[0]);
        assert!(report.makespan_ms.is_nan());
        assert_eq!(report.outcomes.len(), 1);
        // The prepared path agrees and refuses checkpointing.
        let mut backend = SimulatorBackend::new();
        let mut prepared = backend.prepare(&gpu, &ks);
        assert!(!prepared.supports_checkpoints());
        assert!(prepared.execute_order(&[0]).is_nan());
    }
}
