//! [`SimulatorBackend`] — the event-driven fluid simulator behind the
//! [`ExecutionBackend`] interface. This is the substrate every experiment
//! times; its makespans are bit-identical to calling
//! [`crate::sim::simulate_order`] directly (a unit test below pins that).

use super::{BackendReport, ExecutionBackend};
use crate::gpu::{GpuSpec, KernelProfile};
use crate::sim;
use std::time::Instant;

/// Fluid-simulation backend (the GTX580 model). Stateless; cheap to
/// construct per worker thread.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimulatorBackend;

impl SimulatorBackend {
    pub fn new() -> Self {
        SimulatorBackend
    }
}

impl ExecutionBackend for SimulatorBackend {
    fn name(&self) -> &str {
        "sim"
    }

    fn execute(
        &mut self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        order: &[usize],
    ) -> BackendReport {
        let t0 = Instant::now();
        // An unsimulable workload (oversized block, empty grid) would
        // deadlock the in-order dispatcher; report NaN rather than hang.
        if sim::validate_workload(gpu, kernels).is_err() {
            return BackendReport::unsimulable("sim", t0.elapsed().as_secs_f64() * 1e3, order);
        }

        let r = sim::simulate_order(gpu, kernels, order);
        BackendReport::from_finish_times(
            "sim",
            r.makespan_ms,
            t0.elapsed().as_secs_f64() * 1e3,
            order,
            &r.kernel_finish_ms,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::AppKind;
    use crate::util::SplitMix64;
    use crate::workloads::epbsessw_8;

    /// Refactor-equivalence pin: the simulator backend's makespan must be
    /// identical to the pre-redesign direct `sim::simulate_order` call on
    /// the paper's EpBsEsSw-8 workload, for FIFO and shuffled orders.
    #[test]
    fn makespans_identical_to_direct_simulation_on_epbsessw_8() {
        let gpu = GpuSpec::gtx580();
        let ks = epbsessw_8();
        let mut backend = SimulatorBackend::new();

        let fifo: Vec<usize> = (0..ks.len()).collect();
        let mut orders = vec![fifo.clone()];
        for seed in 0..10u64 {
            let mut o = fifo.clone();
            SplitMix64::new(seed).shuffle(&mut o);
            orders.push(o);
        }
        for order in &orders {
            let direct = sim::simulate_order(&gpu, &ks, order).makespan_ms;
            let via_trait = backend.execute(&gpu, &ks, order).makespan_ms;
            assert_eq!(direct, via_trait, "order {order:?}");
        }
    }

    #[test]
    fn outcomes_carry_finish_times_in_launch_order() {
        let gpu = GpuSpec::gtx580();
        let ks = epbsessw_8();
        let order: Vec<usize> = (0..ks.len()).rev().collect();
        let report = SimulatorBackend::new().execute(&gpu, &ks, &order);
        assert_eq!(report.outcomes.len(), ks.len());
        let max_finish = report
            .outcomes
            .iter()
            .map(|o| o.finish_ms)
            .fold(0.0f64, f64::max);
        assert!((max_finish - report.makespan_ms).abs() < 1e-9);
        for (pos, o) in report.outcomes.iter().enumerate() {
            assert_eq!(o.position, pos);
            assert_eq!(o.index, order[pos]);
            assert!(o.checksum.is_nan());
            assert!(!o.failed);
        }
        assert_eq!(report.n_failures(), 0);
        // by_index inverts the order mapping.
        let by_index = report.by_index();
        for (i, o) in by_index.iter().enumerate() {
            assert_eq!(o.index, i);
        }
    }

    #[test]
    fn unsimulable_workload_reports_nan_not_hang() {
        let gpu = GpuSpec::gtx580();
        let bad = KernelProfile {
            name: "bad".into(),
            app: AppKind::Synthetic,
            n_blocks: 1,
            regs_per_block: 512,
            shmem_per_block: 0,
            warps_per_block: 64, // > 48 warps/SM: never fits
            ratio: 2.0,
            work_per_block: 100.0,
            artifact: String::new(),
        };
        let report = SimulatorBackend::new().execute(&gpu, &[bad], &[0]);
        assert!(report.makespan_ms.is_nan());
        assert_eq!(report.outcomes.len(), 1);
    }
}
