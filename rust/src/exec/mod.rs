//! Execution backends — the substrate seam of the redesigned API.
//!
//! The paper evaluates one policy on one substrate (a GTX580 model). This
//! crate has three ways to "run" an ordered batch of kernels — the
//! event-driven fluid simulator, the paper's analytic round model, and
//! real PJRT execution of AOT-compiled HLO — and production use implies
//! more (other GPU models, remote executors). [`ExecutionBackend`]
//! abstracts them: the coordinator, the CLI subcommands and the
//! `table3`/`fig1`/`ablation` benches all time batches through a trait
//! object, so a new substrate plugs in without touching any of them.
//!
//! | backend | returns | feature |
//! |---|---|---|
//! | [`SimulatorBackend`] | fluid-simulated makespan + per-kernel finish times | always |
//! | [`AnalyticBackend`]  | round-model makespan estimate + round structure | always |
//! | `PjrtBackend`        | real per-kernel checksums + wall times | `pjrt` |
//!
//! For hot paths that evaluate *many orders of one workload* (the
//! permutation sweeps), [`ExecutionBackend::prepare`] returns a
//! [`PreparedWorkload`] handle that hoists per-workload setup out of the
//! loop; the model backends' handles additionally support exact
//! **prefix checkpointing** (see the trait docs).

mod analytic;
#[cfg(feature = "pjrt")]
mod pjrt;
mod simulator;

pub use analytic::AnalyticBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use simulator::SimulatorBackend;

use crate::gpu::{GpuSpec, KernelProfile};

/// Per-kernel outcome of one batch execution.
#[derive(Debug, Clone)]
pub struct KernelOutcome {
    /// Index into the submitted `kernels` slice.
    pub index: usize,
    /// Position in the launch order (0 = launched first).
    pub position: usize,
    /// Numeric fingerprint of the real output (`NaN` for model backends).
    pub checksum: f64,
    /// Wall-clock execution time of this kernel (0 for model backends).
    pub wall_ms: f64,
    /// Model time at which the kernel finished (`NaN` when the backend
    /// has no timing model).
    pub finish_ms: f64,
    /// Whether the payload failed (real backends only; model backends
    /// never fail a kernel).
    pub failed: bool,
}

/// What a backend reports for one executed batch.
#[derive(Debug, Clone)]
pub struct BackendReport {
    /// The backend's registry name (e.g. `"sim"`).
    pub backend: String,
    /// Model makespan of the batch under the given order (`NaN` when the
    /// backend measures wall time only, or the workload is unsimulable).
    pub makespan_ms: f64,
    /// Wall-clock time of the whole `execute` call.
    pub wall_ms: f64,
    /// One entry per kernel, in launch-order sequence.
    pub outcomes: Vec<KernelOutcome>,
}

impl BackendReport {
    /// Report of a *model* backend run: per-kernel model finish times
    /// (`finish_by_kernel[i]` belongs to `kernels[i]`), no payloads, no
    /// failures.
    pub fn from_finish_times(
        backend: &str,
        makespan_ms: f64,
        wall_ms: f64,
        order: &[usize],
        finish_by_kernel: &[f64],
    ) -> Self {
        let outcomes = order
            .iter()
            .enumerate()
            .map(|(position, &index)| KernelOutcome {
                index,
                position,
                checksum: f64::NAN,
                wall_ms: 0.0,
                finish_ms: finish_by_kernel[index],
                failed: false,
            })
            .collect();
        BackendReport {
            backend: backend.into(),
            makespan_ms,
            wall_ms,
            outcomes,
        }
    }

    /// Report for a workload the backend's model cannot time (e.g. a
    /// block that never fits an SM would deadlock the in-order
    /// dispatcher): all-NaN timings, no failures.
    pub fn unsimulable(backend: &str, wall_ms: f64, order: &[usize]) -> Self {
        let nan_finishes = vec![f64::NAN; order.len()];
        BackendReport::from_finish_times(backend, f64::NAN, wall_ms, order, &nan_finishes)
    }

    /// Outcomes re-indexed by batch position (`outcomes[i]` is the result
    /// of `kernels[i]`), for callers that answer per-submission.
    pub fn by_index(&self) -> Vec<&KernelOutcome> {
        let mut v: Vec<&KernelOutcome> = self.outcomes.iter().collect();
        v.sort_by_key(|o| o.index);
        v
    }

    /// Number of failed kernels.
    pub fn n_failures(&self) -> usize {
        self.outcomes.iter().filter(|o| o.failed).count()
    }
}

/// A workload prepared once so that many launch orders can be evaluated
/// cheaply — the hot-path seam of the permutation sweeps.
///
/// Obtained from [`ExecutionBackend::prepare`]. A prepared handle hoists
/// everything order-independent (kernel constants, work tables, scratch
/// buffers, validation) out of the per-order loop; after warm-up,
/// [`PreparedWorkload::execute_order`] performs no heap allocation for
/// the model backends (asserted by `tests/zero_alloc.rs`).
///
/// # Prefix checkpointing
///
/// Backends whose timing model is *prefix-incremental* — the state after
/// launching a prefix of the order does not depend on the suffix — can
/// additionally support prefix checkpoints ([`supports_checkpoints`]
/// returns `true`): [`checkpoint_push`] extends the current prefix by one
/// kernel and snapshots the model state, [`execute_suffix`] completes the
/// prefix with the remaining kernels, and [`checkpoint_pop`] backtracks.
/// Results are bit-identical to [`execute_order`] on the concatenated
/// order; the sweeps use this to share the cost of a prefix across every
/// permutation of its suffix. Both model backends (simulator and
/// analytic) support it; the default implementation does not.
///
/// [`supports_checkpoints`]: PreparedWorkload::supports_checkpoints
/// [`checkpoint_push`]: PreparedWorkload::checkpoint_push
/// [`checkpoint_pop`]: PreparedWorkload::checkpoint_pop
/// [`execute_suffix`]: PreparedWorkload::execute_suffix
/// [`execute_order`]: PreparedWorkload::execute_order
pub trait PreparedWorkload {
    /// Model makespan of one complete launch `order` (a permutation of
    /// `0..kernels.len()`); `NaN` when the backend cannot time the
    /// workload (see [`BackendReport::unsimulable`]).
    fn execute_order(&mut self, order: &[usize]) -> f64;

    /// Whether the checkpoint methods below may be called.
    fn supports_checkpoints(&self) -> bool {
        false
    }

    /// Extend the checkpointed prefix with `kernel` and snapshot the
    /// model state at that point.
    fn checkpoint_push(&mut self, kernel: usize) {
        let _ = kernel;
        panic!("prefix checkpointing unsupported (check supports_checkpoints())");
    }

    /// Drop the most recent prefix checkpoint.
    fn checkpoint_pop(&mut self) {
        panic!("prefix checkpointing unsupported (check supports_checkpoints())");
    }

    /// Complete the checkpointed prefix with `suffix` (possibly empty)
    /// and return the makespan; the checkpoint stack is left intact.
    fn execute_suffix(&mut self, suffix: &[usize]) -> f64 {
        let _ = suffix;
        panic!("prefix checkpointing unsupported (check supports_checkpoints())");
    }

    /// An **admissible lower bound** on [`execute_suffix`] over *every*
    /// permutation of `remaining` appended to the checkpointed prefix:
    /// no completion order may beat it. The branch-and-bound solver in
    /// [`crate::search`] prunes a subtree when this bound exceeds its
    /// incumbent, so a bound that is ever optimistic in the wrong
    /// direction (claims more than the true minimum) silently breaks
    /// exactness — implementations must derive it from conservative
    /// model invariants only (residual work over peak throughput,
    /// per-kernel occupancy caps, bandwidth rooflines).
    ///
    /// The default returns `f64::NEG_INFINITY` (no information): search
    /// stays correct but degrades to exhaustive enumeration.
    ///
    /// [`execute_suffix`]: PreparedWorkload::execute_suffix
    fn suffix_lower_bound(&mut self, remaining: &[usize]) -> f64 {
        let _ = remaining;
        f64::NEG_INFINITY
    }
}

/// Default [`PreparedWorkload`]: no hoisting, every order round-trips
/// through [`ExecutionBackend::execute`].
struct FallbackPrepared<'a, B: ?Sized> {
    backend: &'a mut B,
    gpu: &'a GpuSpec,
    kernels: &'a [KernelProfile],
}

impl<B: ExecutionBackend + ?Sized> PreparedWorkload for FallbackPrepared<'_, B> {
    fn execute_order(&mut self, order: &[usize]) -> f64 {
        self.backend.execute(self.gpu, self.kernels, order).makespan_ms
    }
}

/// An execution substrate: takes a workload and a launch order, runs (or
/// models) it, and reports per-kernel and whole-batch results.
///
/// `&mut self` so real backends can keep warm state (compiled-executable
/// caches, device handles). Backends need not be `Send` — the coordinator
/// constructs one per worker thread through a factory, which is how the
/// PJRT backend's thread-pinned client handles are accommodated.
pub trait ExecutionBackend {
    /// The backend's registry spelling (e.g. `"sim"`, `"analytic"`,
    /// `"pjrt"`).
    fn name(&self) -> &str;

    /// Execute `kernels` in the given launch `order` (a permutation of
    /// `0..kernels.len()`).
    fn execute(
        &mut self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        order: &[usize],
    ) -> BackendReport;

    /// Like [`ExecutionBackend::execute`], with a per-kernel payload seed
    /// (`seeds[i]` belongs to `kernels[i]`). Model backends ignore seeds;
    /// real backends use them for deterministic input synthesis. The
    /// default forwards to `execute`.
    fn execute_seeded(
        &mut self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        order: &[usize],
        seeds: &[u64],
    ) -> BackendReport {
        let _ = seeds;
        self.execute(gpu, kernels, order)
    }

    /// Prepare a workload for repeated order evaluation (the permutation-
    /// sweep hot path): hoist order-independent setup out of the loop and
    /// return a [`PreparedWorkload`] handle. The default falls back to
    /// calling [`ExecutionBackend::execute`] per order; the model backends
    /// override it with allocation-free, checkpoint-capable handles.
    fn prepare<'a>(
        &'a mut self,
        gpu: &'a GpuSpec,
        kernels: &'a [KernelProfile],
    ) -> Box<dyn PreparedWorkload + 'a> {
        Box::new(FallbackPrepared {
            backend: self,
            gpu,
            kernels,
        })
    }
}

/// Error returned for unknown backend spellings; `Display` lists the
/// valid names.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BackendParseError {
    pub input: String,
}

impl std::fmt::Display for BackendParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown backend `{}` — valid backends: sim, analytic{}",
            self.input,
            if cfg!(feature = "pjrt") {
                ", pjrt (via --artifacts)"
            } else {
                " (pjrt requires building with --features pjrt)"
            }
        )
    }
}

impl std::error::Error for BackendParseError {}

/// Parse a *model* backend spelling (`"sim"` / `"analytic"`). The PJRT
/// backend is constructed explicitly with an artifacts directory
/// (`PjrtBackend::new`, feature `pjrt`) since it needs more than a name.
pub fn parse_model_backend(s: &str) -> Result<Box<dyn ExecutionBackend>, BackendParseError> {
    match s.to_ascii_lowercase().as_str() {
        "sim" | "simulator" | "fluid" => Ok(Box::new(SimulatorBackend::new())),
        "analytic" | "rounds" => Ok(Box::new(AnalyticBackend::new())),
        _ => Err(BackendParseError { input: s.into() }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_backends_parse() {
        for s in ["sim", "simulator", "fluid", "analytic", "rounds", "SIM"] {
            assert!(parse_model_backend(s).is_ok(), "{s}");
        }
        let err = parse_model_backend("quantum").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("quantum") && msg.contains("sim") && msg.contains("analytic"));
    }

    #[test]
    fn backend_names_round_trip() {
        for s in ["sim", "analytic"] {
            assert_eq!(parse_model_backend(s).unwrap().name(), s);
        }
    }

    #[test]
    fn fallback_prepare_matches_execute() {
        // A backend that relies on the default `prepare` must evaluate
        // orders identically to its `execute`.
        struct Doubling;
        impl ExecutionBackend for Doubling {
            fn name(&self) -> &str {
                "doubling"
            }
            fn execute(
                &mut self,
                _gpu: &GpuSpec,
                _kernels: &[KernelProfile],
                order: &[usize],
            ) -> BackendReport {
                let finishes = vec![0.0; order.len()];
                BackendReport::from_finish_times(
                    "doubling",
                    2.0 * order[0] as f64 + order.len() as f64,
                    0.0,
                    order,
                    &finishes,
                )
            }
        }
        let gpu = crate::gpu::GpuSpec::gtx580();
        let kernels: Vec<KernelProfile> = Vec::new();
        let mut b = Doubling;
        let direct = b.execute(&gpu, &kernels, &[3, 1, 2]).makespan_ms;
        let mut prepared = b.prepare(&gpu, &kernels);
        assert!(!prepared.supports_checkpoints());
        assert_eq!(prepared.execute_order(&[3, 1, 2]), direct);
    }
}
