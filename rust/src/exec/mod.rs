//! Execution backends — the substrate seam of the redesigned API.
//!
//! The paper evaluates one policy on one substrate (a GTX580 model). This
//! crate has three ways to "run" an ordered batch of kernels — the
//! event-driven fluid simulator, the paper's analytic round model, and
//! real PJRT execution of AOT-compiled HLO — and production use implies
//! more (other GPU models, remote executors). [`ExecutionBackend`]
//! abstracts them: the coordinator, the CLI subcommands and the
//! `table3`/`fig1`/`ablation` benches all time batches through a trait
//! object, so a new substrate plugs in without touching any of them.
//!
//! | backend | returns | feature |
//! |---|---|---|
//! | [`SimulatorBackend`] | fluid-simulated makespan + per-kernel finish times | always |
//! | [`AnalyticBackend`]  | round-model makespan estimate + round structure | always |
//! | `PjrtBackend`        | real per-kernel checksums + wall times | `pjrt` |
//!
//! For hot paths that evaluate *many orders of one workload* (the
//! permutation sweeps), [`ExecutionBackend::prepare`] returns a
//! [`PreparedWorkload`] handle that hoists per-workload setup out of the
//! loop; the model backends' handles additionally support exact
//! **prefix checkpointing** (see the trait docs). [`PrefixCursor`]
//! layers incremental **move evaluation** on the same seam: anytime
//! search prices each candidate by its suffix past the longest prefix
//! shared with the incumbent, bit-identically to a full evaluation.

mod analytic;
#[cfg(feature = "pjrt")]
mod pjrt;
mod simulator;

pub use analytic::AnalyticBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use simulator::SimulatorBackend;

use crate::gpu::{GpuSpec, KernelProfile};

/// Per-kernel outcome of one batch execution.
#[derive(Debug, Clone)]
pub struct KernelOutcome {
    /// Index into the submitted `kernels` slice.
    pub index: usize,
    /// Position in the launch order (0 = launched first).
    pub position: usize,
    /// Numeric fingerprint of the real output (`NaN` for model backends).
    pub checksum: f64,
    /// Wall-clock execution time of this kernel (0 for model backends).
    pub wall_ms: f64,
    /// Model time at which the kernel finished (`NaN` when the backend
    /// has no timing model).
    pub finish_ms: f64,
    /// Whether the payload failed (real backends only; model backends
    /// never fail a kernel).
    pub failed: bool,
}

/// What a backend reports for one executed batch.
#[derive(Debug, Clone)]
pub struct BackendReport {
    /// The backend's registry name (e.g. `"sim"`).
    pub backend: String,
    /// Model makespan of the batch under the given order (`NaN` when the
    /// backend measures wall time only, or the workload is unsimulable).
    pub makespan_ms: f64,
    /// Wall-clock time of the whole `execute` call.
    pub wall_ms: f64,
    /// One entry per kernel, in launch-order sequence.
    pub outcomes: Vec<KernelOutcome>,
}

impl BackendReport {
    /// Report of a *model* backend run: per-kernel model finish times
    /// (`finish_by_kernel[i]` belongs to `kernels[i]`), no payloads, no
    /// failures.
    pub fn from_finish_times(
        backend: &str,
        makespan_ms: f64,
        wall_ms: f64,
        order: &[usize],
        finish_by_kernel: &[f64],
    ) -> Self {
        let outcomes = order
            .iter()
            .enumerate()
            .map(|(position, &index)| KernelOutcome {
                index,
                position,
                checksum: f64::NAN,
                wall_ms: 0.0,
                finish_ms: finish_by_kernel[index],
                failed: false,
            })
            .collect();
        BackendReport {
            backend: backend.into(),
            makespan_ms,
            wall_ms,
            outcomes,
        }
    }

    /// Report for a workload the backend's model cannot time (e.g. a
    /// block that never fits an SM would deadlock the in-order
    /// dispatcher): all-NaN timings, no failures.
    pub fn unsimulable(backend: &str, wall_ms: f64, order: &[usize]) -> Self {
        let nan_finishes = vec![f64::NAN; order.len()];
        BackendReport::from_finish_times(backend, f64::NAN, wall_ms, order, &nan_finishes)
    }

    /// Outcomes re-indexed by batch position (`outcomes[i]` is the result
    /// of `kernels[i]`), for callers that answer per-submission.
    pub fn by_index(&self) -> Vec<&KernelOutcome> {
        let mut v: Vec<&KernelOutcome> = self.outcomes.iter().collect();
        v.sort_by_key(|o| o.index);
        v
    }

    /// Number of failed kernels.
    pub fn n_failures(&self) -> usize {
        self.outcomes.iter().filter(|o| o.failed).count()
    }
}

/// A workload prepared once so that many launch orders can be evaluated
/// cheaply — the hot-path seam of the permutation sweeps.
///
/// Obtained from [`ExecutionBackend::prepare`]. A prepared handle hoists
/// everything order-independent (kernel constants, work tables, scratch
/// buffers, validation) out of the per-order loop; after warm-up,
/// [`PreparedWorkload::execute_order`] performs no heap allocation for
/// the model backends (asserted by `tests/zero_alloc.rs`).
///
/// # Prefix checkpointing
///
/// Backends whose timing model is *prefix-incremental* — the state after
/// launching a prefix of the order does not depend on the suffix — can
/// additionally support prefix checkpoints ([`supports_checkpoints`]
/// returns `true`): [`checkpoint_push`] extends the current prefix by one
/// kernel and snapshots the model state, [`execute_suffix`] completes the
/// prefix with the remaining kernels, and [`checkpoint_pop`] backtracks.
/// Results are bit-identical to [`execute_order`] on the concatenated
/// order; the sweeps use this to share the cost of a prefix across every
/// permutation of its suffix. [`execute_suffix_at`] additionally
/// completes from **any** stack level without disturbing the levels
/// above it (opt-in via [`supports_depth_addressing`]) — the seam
/// [`PrefixCursor`] builds incremental anytime-search evaluation on.
/// Both model backends (simulator and analytic) support all of it; the
/// default implementation does not — and a backend that implements the
/// `checkpoint_*` seam plus the depth-addressed completion gets fast
/// sweeps, branch-and-bound *and* fast anytime search for free.
///
/// [`supports_checkpoints`]: PreparedWorkload::supports_checkpoints
/// [`checkpoint_push`]: PreparedWorkload::checkpoint_push
/// [`checkpoint_pop`]: PreparedWorkload::checkpoint_pop
/// [`execute_suffix`]: PreparedWorkload::execute_suffix
/// [`execute_suffix_at`]: PreparedWorkload::execute_suffix_at
/// [`supports_depth_addressing`]: PreparedWorkload::supports_depth_addressing
/// [`execute_order`]: PreparedWorkload::execute_order
pub trait PreparedWorkload {
    /// Model makespan of one complete launch `order` (a permutation of
    /// `0..kernels.len()`); `NaN` when the backend cannot time the
    /// workload (see [`BackendReport::unsimulable`]).
    fn execute_order(&mut self, order: &[usize]) -> f64;

    /// Whether the checkpoint methods below may be called.
    fn supports_checkpoints(&self) -> bool {
        false
    }

    /// Extend the checkpointed prefix with `kernel` and snapshot the
    /// model state at that point.
    fn checkpoint_push(&mut self, kernel: usize) {
        let _ = kernel;
        panic!("prefix checkpointing unsupported (check supports_checkpoints())");
    }

    /// Drop the most recent prefix checkpoint.
    fn checkpoint_pop(&mut self) {
        panic!("prefix checkpointing unsupported (check supports_checkpoints())");
    }

    /// Complete the checkpointed prefix with `suffix` (possibly empty)
    /// and return the makespan; the checkpoint stack is left intact.
    fn execute_suffix(&mut self, suffix: &[usize]) -> f64 {
        let _ = suffix;
        panic!("prefix checkpointing unsupported (check supports_checkpoints())");
    }

    /// Whether [`execute_suffix_at`] may be called. Separate from
    /// [`supports_checkpoints`] so a handle that implemented the
    /// original push/pop/suffix seam keeps working (the sweeps and
    /// branch-and-bound need only that); [`PrefixCursor`] uses
    /// incremental evaluation only when *this* returns `true` and
    /// degrades to [`execute_order`] otherwise.
    ///
    /// [`execute_suffix_at`]: PreparedWorkload::execute_suffix_at
    /// [`supports_checkpoints`]: PreparedWorkload::supports_checkpoints
    /// [`execute_order`]: PreparedWorkload::execute_order
    fn supports_depth_addressing(&self) -> bool {
        false
    }

    /// [`execute_suffix`] generalized to any stack level — the
    /// depth-addressable seam behind [`PrefixCursor`]. Completes the
    /// prefix checkpointed at `depth` (`0` = the empty prefix, up to the
    /// current stack depth) with `suffix` and returns the makespan,
    /// leaving the **whole** stack — including checkpoints above `depth`
    /// — intact, so one anchored stack can serve evaluations at every
    /// divergence depth. Checkpoints are pure functions of their prefix,
    /// so the result must be bit-identical to [`execute_order`] on
    /// `prefix[..depth] ++ suffix`. Only called when
    /// [`supports_depth_addressing`] returns `true`.
    ///
    /// [`execute_suffix`]: PreparedWorkload::execute_suffix
    /// [`execute_order`]: PreparedWorkload::execute_order
    /// [`supports_depth_addressing`]: PreparedWorkload::supports_depth_addressing
    fn execute_suffix_at(&mut self, depth: usize, suffix: &[usize]) -> f64 {
        let _ = (depth, suffix);
        panic!("depth-addressable checkpointing unsupported (check supports_depth_addressing())");
    }

    /// An **admissible lower bound** on [`execute_suffix`] over *every*
    /// permutation of `remaining` appended to the checkpointed prefix:
    /// no completion order may beat it. The branch-and-bound solver in
    /// [`crate::search`] prunes a subtree when this bound exceeds its
    /// incumbent, so a bound that is ever optimistic in the wrong
    /// direction (claims more than the true minimum) silently breaks
    /// exactness — implementations must derive it from conservative
    /// model invariants only (residual work over peak throughput,
    /// per-kernel occupancy caps, bandwidth rooflines).
    ///
    /// The default returns `f64::NEG_INFINITY` (no information): search
    /// stays correct but degrades to exhaustive enumeration.
    ///
    /// [`execute_suffix`]: PreparedWorkload::execute_suffix
    fn suffix_lower_bound(&mut self, remaining: &[usize]) -> f64 {
        let _ = remaining;
        f64::NEG_INFINITY
    }
}

/// Default [`PreparedWorkload`]: no hoisting, every order round-trips
/// through [`ExecutionBackend::execute`].
struct FallbackPrepared<'a, B: ?Sized> {
    backend: &'a mut B,
    gpu: &'a GpuSpec,
    kernels: &'a [KernelProfile],
}

impl<B: ExecutionBackend + ?Sized> PreparedWorkload for FallbackPrepared<'_, B> {
    fn execute_order(&mut self, order: &[usize]) -> f64 {
        self.backend.execute(self.gpu, self.kernels, order).makespan_ms
    }
}

/// **Prefix-reuse cursor** — incremental order evaluation for anytime
/// search, the hot-path seam of [`crate::search`]'s metaheuristics.
///
/// A local-search or annealing move (swap, shift, insertion) produces a
/// candidate that shares a prefix with the incumbent up to the move's
/// first touched position, yet re-simulating it from scratch pays for the
/// whole order. The cursor keeps a checkpoint stack anchored along the
/// incumbent and prices every evaluation by its **suffix past the longest
/// common prefix** with that stack:
///
/// * [`PrefixCursor::eval`] — evaluate a complete order, restoring the
///   deepest matching checkpoint and simulating only past it. The stack
///   is never mutated.
/// * [`PrefixCursor::eval_anchored`] — same, but first extend the stack
///   along `order[..anchor]` when it is shorter (the caller passes the
///   move's divergence position, so the stack lazily grows along the
///   incumbent and every sibling move at that depth reuses it).
///
/// Results are **bit-identical** to
/// [`PreparedWorkload::execute_order`]: checkpoints are pure functions of
/// their prefix and restore is pinned bit-exact, so switching a search to
/// the cursor is a pure speedup (`tests/incremental_equivalence.rs` pins
/// whole trajectories). On a handle without checkpoint support — e.g. the
/// default [`ExecutionBackend::prepare`] fallback — every call degrades
/// to `execute_order`, so callers need no capability check.
pub struct PrefixCursor<'a> {
    prepared: Box<dyn PreparedWorkload + 'a>,
    /// Kernels currently checkpointed, in stack order (mirror of the
    /// prepared handle's stack; `prefix[..d]` ↔ checkpoint depth `d`).
    prefix: Vec<usize>,
    incremental: bool,
    evals: u64,
    reused: u64,
}

impl<'a> PrefixCursor<'a> {
    /// Wrap a freshly prepared handle (its checkpoint stack must be
    /// empty). Incremental evaluation is used whenever the handle
    /// supports depth-addressable checkpoints
    /// ([`PreparedWorkload::supports_depth_addressing`]); handles that
    /// implement only the original push/pop/suffix seam — or none of it
    /// — are evaluated through [`PreparedWorkload::execute_order`].
    pub fn new(prepared: Box<dyn PreparedWorkload + 'a>) -> Self {
        let incremental = prepared.supports_checkpoints() && prepared.supports_depth_addressing();
        PrefixCursor {
            prepared,
            prefix: Vec::new(),
            incremental,
            evals: 0,
            reused: 0,
        }
    }

    /// Wrap a prepared handle with incremental evaluation **disabled**:
    /// every call round-trips through
    /// [`PreparedWorkload::execute_order`]. The reference path of the
    /// bit-equivalence pins and of `kreorder search --compare-eval`.
    pub fn new_full(prepared: Box<dyn PreparedWorkload + 'a>) -> Self {
        PrefixCursor {
            prepared,
            prefix: Vec::new(),
            incremental: false,
            evals: 0,
            reused: 0,
        }
    }

    /// Whether evaluations actually reuse checkpoints (`false` for
    /// checkpoint-free handles and [`PrefixCursor::new_full`]).
    pub fn incremental(&self) -> bool {
        self.incremental
    }

    /// Orders evaluated through this cursor.
    pub fn evals(&self) -> u64 {
        self.evals
    }

    /// Total prefix kernels *not* re-simulated thanks to checkpoint
    /// reuse, summed over all evaluations (0 in full mode) — the
    /// numerator of the reuse ratio reported by `--compare-eval`.
    pub fn reused_kernels(&self) -> u64 {
        self.reused
    }

    /// Evaluate a complete launch `order`, reusing the deepest checkpoint
    /// that matches a prefix of it. Never mutates the stack.
    pub fn eval(&mut self, order: &[usize]) -> f64 {
        self.eval_anchored(order, 0)
    }

    /// Evaluate `order`, first extending the checkpoint stack along
    /// `order[..anchor]` when it is shallower (mismatched entries are
    /// popped). Callers pass the first position where the candidate
    /// differs from the incumbent, so the stack stays anchored along the
    /// incumbent and is shared by every move diverging at or beyond that
    /// depth; an accepted move simply re-anchors through later calls'
    /// longest-common-prefix handling.
    pub fn eval_anchored(&mut self, order: &[usize], anchor: usize) -> f64 {
        debug_assert!(anchor <= order.len());
        self.evals += 1;
        if !self.incremental {
            return self.prepared.execute_order(order);
        }
        let mut l = 0;
        while l < self.prefix.len() && l < order.len() && self.prefix[l] == order[l] {
            l += 1;
        }
        if l < anchor {
            while self.prefix.len() > l {
                self.prepared.checkpoint_pop();
                self.prefix.pop();
            }
            for &k in &order[l..anchor] {
                self.prepared.checkpoint_push(k);
                self.prefix.push(k);
            }
            l = anchor;
        }
        self.reused += l as u64;
        self.prepared.execute_suffix_at(l, &order[l..])
    }
}

/// An execution substrate: takes a workload and a launch order, runs (or
/// models) it, and reports per-kernel and whole-batch results.
///
/// `&mut self` so real backends can keep warm state (compiled-executable
/// caches, device handles). Backends need not be `Send` — the coordinator
/// constructs one per worker thread through a factory, which is how the
/// PJRT backend's thread-pinned client handles are accommodated.
pub trait ExecutionBackend {
    /// The backend's registry spelling (e.g. `"sim"`, `"analytic"`,
    /// `"pjrt"`).
    fn name(&self) -> &str;

    /// Execute `kernels` in the given launch `order` (a permutation of
    /// `0..kernels.len()`).
    fn execute(
        &mut self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        order: &[usize],
    ) -> BackendReport;

    /// Like [`ExecutionBackend::execute`], with a per-kernel payload seed
    /// (`seeds[i]` belongs to `kernels[i]`). Model backends ignore seeds;
    /// real backends use them for deterministic input synthesis. The
    /// default forwards to `execute`.
    fn execute_seeded(
        &mut self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        order: &[usize],
        seeds: &[u64],
    ) -> BackendReport {
        let _ = seeds;
        self.execute(gpu, kernels, order)
    }

    /// Prepare a workload for repeated order evaluation (the permutation-
    /// sweep hot path): hoist order-independent setup out of the loop and
    /// return a [`PreparedWorkload`] handle. The default falls back to
    /// calling [`ExecutionBackend::execute`] per order; the model backends
    /// override it with allocation-free, checkpoint-capable handles.
    fn prepare<'a>(
        &'a mut self,
        gpu: &'a GpuSpec,
        kernels: &'a [KernelProfile],
    ) -> Box<dyn PreparedWorkload + 'a> {
        Box::new(FallbackPrepared {
            backend: self,
            gpu,
            kernels,
        })
    }
}

/// Error returned for unknown backend spellings; `Display` lists the
/// valid names.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BackendParseError {
    pub input: String,
}

impl std::fmt::Display for BackendParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown backend `{}` — valid backends: sim, analytic{}",
            self.input,
            if cfg!(feature = "pjrt") {
                ", pjrt (via --artifacts)"
            } else {
                " (pjrt requires building with --features pjrt)"
            }
        )
    }
}

impl std::error::Error for BackendParseError {}

/// Parse a *model* backend spelling (`"sim"` / `"analytic"`). The PJRT
/// backend is constructed explicitly with an artifacts directory
/// (`PjrtBackend::new`, feature `pjrt`) since it needs more than a name.
pub fn parse_model_backend(s: &str) -> Result<Box<dyn ExecutionBackend>, BackendParseError> {
    match s.to_ascii_lowercase().as_str() {
        "sim" | "simulator" | "fluid" => Ok(Box::new(SimulatorBackend::new())),
        "analytic" | "rounds" => Ok(Box::new(AnalyticBackend::new())),
        _ => Err(BackendParseError { input: s.into() }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_backends_parse() {
        for s in ["sim", "simulator", "fluid", "analytic", "rounds", "SIM"] {
            assert!(parse_model_backend(s).is_ok(), "{s}");
        }
        let err = parse_model_backend("quantum").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("quantum") && msg.contains("sim") && msg.contains("analytic"));
    }

    #[test]
    fn backend_names_round_trip() {
        for s in ["sim", "analytic"] {
            assert_eq!(parse_model_backend(s).unwrap().name(), s);
        }
    }

    #[test]
    fn fallback_prepare_matches_execute() {
        // A backend that relies on the default `prepare` must evaluate
        // orders identically to its `execute`.
        struct Doubling;
        impl ExecutionBackend for Doubling {
            fn name(&self) -> &str {
                "doubling"
            }
            fn execute(
                &mut self,
                _gpu: &GpuSpec,
                _kernels: &[KernelProfile],
                order: &[usize],
            ) -> BackendReport {
                let finishes = vec![0.0; order.len()];
                BackendReport::from_finish_times(
                    "doubling",
                    2.0 * order[0] as f64 + order.len() as f64,
                    0.0,
                    order,
                    &finishes,
                )
            }
        }
        let gpu = crate::gpu::GpuSpec::gtx580();
        let kernels: Vec<KernelProfile> = Vec::new();
        let mut b = Doubling;
        let direct = b.execute(&gpu, &kernels, &[3, 1, 2]).makespan_ms;
        {
            let mut prepared = b.prepare(&gpu, &kernels);
            assert!(!prepared.supports_checkpoints());
            assert_eq!(prepared.execute_order(&[3, 1, 2]), direct);
        }
        // A cursor over a checkpoint-free handle degrades to execute_order
        // without any capability check by the caller.
        let mut cursor = PrefixCursor::new(b.prepare(&gpu, &kernels));
        assert!(!cursor.incremental());
        assert_eq!(cursor.eval_anchored(&[3, 1, 2], 2), direct);
        assert_eq!(cursor.evals(), 1);
        assert_eq!(cursor.reused_kernels(), 0);
    }

    #[test]
    fn cursor_matches_execute_order_bitwise_under_interleaved_anchors() {
        use crate::util::SplitMix64;
        let gpu = crate::gpu::GpuSpec::gtx580();
        let ks = crate::workloads::epbsessw_8();
        for factory in [
            (|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)
                as fn() -> Box<dyn ExecutionBackend>,
            || Box::new(AnalyticBackend::new()),
        ] {
            // Reference makespans from a plain prepared handle.
            let mut reference = factory();
            let mut prepared = reference.prepare(&gpu, &ks);
            let mut orders: Vec<Vec<usize>> = Vec::new();
            let mut rng = SplitMix64::new(17);
            for _ in 0..24 {
                let mut o: Vec<usize> = (0..ks.len()).collect();
                rng.shuffle(&mut o);
                orders.push(o);
            }
            let direct: Vec<f64> = orders.iter().map(|o| prepared.execute_order(o)).collect();

            // The same orders through a cursor, with anchors that force
            // every path: pure reuse, stack growth, and re-anchoring.
            let mut backend = factory();
            let mut cursor = PrefixCursor::new(backend.prepare(&gpu, &ks));
            assert!(cursor.incremental());
            for (i, (o, d)) in orders.iter().zip(&direct).enumerate() {
                let anchor = i % ks.len();
                let got = cursor.eval_anchored(o, anchor);
                assert_eq!(got.to_bits(), d.to_bits(), "order {o:?} anchor {anchor}");
                // And again with no anchor: pure reuse of whatever the
                // stack now holds.
                assert_eq!(cursor.eval(o).to_bits(), d.to_bits(), "re-eval {o:?}");
            }
            assert_eq!(cursor.evals(), 2 * orders.len() as u64);
            assert!(cursor.reused_kernels() > 0);
        }
    }
}
