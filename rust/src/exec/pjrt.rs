//! [`PjrtBackend`] — real payload execution behind the
//! [`ExecutionBackend`] interface: each kernel's AOT-compiled HLO runs on
//! the PJRT CPU client in the given launch order, producing real numerics
//! (checksums) and wall-clock timings.
//!
//! Only compiled with `--features pjrt`. The underlying PJRT handles are
//! not `Send`, so construct one backend per worker thread (the
//! coordinator's backend *factory* exists exactly for this).

use super::{BackendReport, ExecutionBackend, KernelOutcome};
use crate::gpu::{GpuSpec, KernelProfile};
use crate::profile::ArtifactStore;
use crate::runtime::Runtime;
use anyhow::Result;
use std::path::Path;
use std::time::Instant;

/// Real-execution backend over a PJRT runtime.
pub struct PjrtBackend {
    runtime: Runtime,
}

impl PjrtBackend {
    /// Load artifacts from `dir` and create a CPU PJRT client.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        Ok(PjrtBackend {
            runtime: Runtime::new(ArtifactStore::load(dir)?)?,
        })
    }

    /// Wrap an existing runtime.
    pub fn from_runtime(runtime: Runtime) -> Self {
        PjrtBackend { runtime }
    }

    /// The wrapped runtime (e.g. for preloading variants).
    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }
}

impl ExecutionBackend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn execute(
        &mut self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        order: &[usize],
    ) -> BackendReport {
        // Without explicit seeds, synthesize deterministically from batch
        // positions so repeated runs are reproducible.
        let seeds: Vec<u64> = (0..kernels.len() as u64).collect();
        self.execute_seeded(gpu, kernels, order, &seeds)
    }

    fn execute_seeded(
        &mut self,
        _gpu: &GpuSpec,
        kernels: &[KernelProfile],
        order: &[usize],
        seeds: &[u64],
    ) -> BackendReport {
        let t0 = Instant::now();
        let mut outcomes = Vec::with_capacity(order.len());
        for (position, &index) in order.iter().enumerate() {
            let k = &kernels[index];
            let seed = seeds.get(index).copied().unwrap_or(index as u64);
            let outcome = match self.runtime.execute(&k.artifact, seed) {
                Ok(out) => KernelOutcome {
                    index,
                    position,
                    checksum: out.checksum(),
                    wall_ms: out.wall_ms,
                    finish_ms: f64::NAN,
                    failed: false,
                },
                Err(e) => {
                    // Failure injection path: keep serving, mark the
                    // kernel with the failure sentinel.
                    eprintln!("kernel {} failed: {e:#}", k.name);
                    KernelOutcome {
                        index,
                        position,
                        checksum: f64::NEG_INFINITY,
                        wall_ms: 0.0,
                        finish_ms: f64::NAN,
                        failed: true,
                    }
                }
            };
            outcomes.push(outcome);
        }
        BackendReport {
            backend: "pjrt".into(),
            makespan_ms: f64::NAN,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            outcomes,
        }
    }
}

impl std::fmt::Debug for PjrtBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtBackend")
            .field("runtime", &self.runtime)
            .finish()
    }
}
