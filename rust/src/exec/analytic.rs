//! [`AnalyticBackend`] — the paper's analytic round model as an execution
//! substrate: kernels pack into execution rounds by per-SM footprint, each
//! round's duration is estimated from processor-sharing compute rates and
//! the shared bandwidth pool, and rounds execute strictly in sequence.
//!
//! Orders of magnitude cheaper than the fluid simulator (no event loop),
//! at the cost of ignoring intra-round dynamics — the A3 ablation bench
//! measures how well its round counts track simulated makespans.

use super::{BackendReport, ExecutionBackend, PreparedWorkload};
use crate::gpu::{GpuSpec, KernelProfile, ResourceVec};
use crate::sim::{self, rounds::pack_rounds};
use std::time::Instant;

/// Round-model backend. Stateless.
#[derive(Debug, Clone, Copy, Default)]
pub struct AnalyticBackend;

impl AnalyticBackend {
    pub fn new() -> Self {
        AnalyticBackend
    }
}

/// Estimated duration of one execution round: every member kernel's
/// blocks are co-resident and drain at the processor-sharing compute rate
/// `C · w_b / max(round_warps, warps_to_saturate)`; the round additionally
/// cannot beat the global memory bandwidth on its combined traffic.
fn round_duration_ms(gpu: &GpuSpec, kernels: &[KernelProfile], members: &[usize]) -> f64 {
    let round_warps: f64 = members
        .iter()
        .map(|&k| kernels[k].per_sm_footprint(gpu).warps)
        .sum();
    let denom = round_warps.max(gpu.warps_to_saturate as f64);
    let compute_ms = members
        .iter()
        .map(|&k| {
            let rate = gpu.compute_rate_per_sm * kernels[k].warps_per_block as f64 / denom;
            kernels[k].work_per_block / rate
        })
        .fold(0.0f64, f64::max);
    let mem_total: f64 = members.iter().map(|&k| kernels[k].total_mem()).sum();
    compute_ms.max(mem_total / gpu.memory_bandwidth())
}

impl ExecutionBackend for AnalyticBackend {
    fn name(&self) -> &str {
        "analytic"
    }

    fn execute(
        &mut self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        order: &[usize],
    ) -> BackendReport {
        let t0 = Instant::now();
        if sim::validate_workload(gpu, kernels).is_err() {
            return BackendReport::unsimulable(
                "analytic",
                t0.elapsed().as_secs_f64() * 1e3,
                order,
            );
        }

        let rounds = pack_rounds(gpu, kernels, order);
        let mut finish_by_kernel = vec![f64::NAN; kernels.len()];
        let mut elapsed = 0.0f64;
        for round in &rounds {
            elapsed += round_duration_ms(gpu, kernels, &round.kernels);
            for &k in &round.kernels {
                // Round granularity: every member finishes with its round.
                finish_by_kernel[k] = elapsed;
            }
        }
        BackendReport::from_finish_times(
            "analytic",
            elapsed,
            t0.elapsed().as_secs_f64() * 1e3,
            order,
            &finish_by_kernel,
        )
    }

    fn prepare<'a>(
        &'a mut self,
        gpu: &'a GpuSpec,
        kernels: &'a [KernelProfile],
    ) -> Box<dyn PreparedWorkload + 'a> {
        Box::new(PreparedAnalytic::new(gpu, kernels))
    }
}

/// Per-kernel constants hoisted out of the round-packing loop.
#[derive(Debug, Clone)]
struct AKernel {
    footprint: ResourceVec,
    /// `footprint.warps`, cached separately for the duration sum.
    warps_footprint: f64,
    warps_per_block: f64,
    work_per_block: f64,
    total_mem: f64,
}

/// Snapshot of the incremental packing state after a prefix of kernels.
#[derive(Debug, Clone, Default)]
struct ASnap {
    elapsed: f64,
    used: ResourceVec,
    cur: Vec<usize>,
}

/// Prepared round-model workload. Round packing is *prefix-incremental*
/// (a kernel joins or closes the current round based only on what came
/// before it), so the handle supports exact prefix checkpointing; the
/// makespan of any completed order is bit-identical to
/// [`AnalyticBackend::execute`] (same member order, same summation
/// order).
pub struct PreparedAnalytic {
    valid: bool,
    sm_cap: ResourceVec,
    saturate: f64,
    compute_rate: f64,
    bandwidth: f64,
    ks: Vec<AKernel>,
    // Working packing state.
    elapsed: f64,
    used: ResourceVec,
    cur: Vec<usize>,
    // Checkpoint stack: `snaps[d]` = state after `d` prefix kernels.
    snaps: Vec<ASnap>,
    depth: usize,
}

impl PreparedAnalytic {
    pub fn new(gpu: &GpuSpec, kernels: &[KernelProfile]) -> Self {
        let ks = kernels
            .iter()
            .map(|k| {
                let footprint = k.per_sm_footprint(gpu);
                AKernel {
                    footprint,
                    warps_footprint: footprint.warps,
                    warps_per_block: k.warps_per_block as f64,
                    work_per_block: k.work_per_block,
                    total_mem: k.total_mem(),
                }
            })
            .collect();
        let mut p = PreparedAnalytic {
            valid: sim::validate_workload(gpu, kernels).is_ok(),
            sm_cap: gpu.sm_capacity(),
            saturate: gpu.warps_to_saturate as f64,
            compute_rate: gpu.compute_rate_per_sm,
            bandwidth: gpu.memory_bandwidth(),
            ks,
            elapsed: 0.0,
            used: ResourceVec::ZERO,
            cur: Vec::with_capacity(kernels.len()),
            snaps: Vec::with_capacity(kernels.len() + 1),
            depth: 0,
        };
        p.save_snapshot(); // snaps[0] = empty prefix
        p
    }

    /// Same arithmetic as the free `round_duration_ms`, reading cached
    /// constants (identical values, identical fold order → identical
    /// bits; pinned by `prepared_matches_execute_bitwise`).
    fn round_duration(&self, members: &[usize]) -> f64 {
        let round_warps: f64 = members.iter().map(|&k| self.ks[k].warps_footprint).sum();
        let denom = round_warps.max(self.saturate);
        let compute_ms = members
            .iter()
            .map(|&k| {
                let rate = self.compute_rate * self.ks[k].warps_per_block / denom;
                self.ks[k].work_per_block / rate
            })
            .fold(0.0f64, f64::max);
        let mem_total: f64 = members.iter().map(|&k| self.ks[k].total_mem).sum();
        compute_ms.max(mem_total / self.bandwidth)
    }

    /// Append one kernel to the packing: close the open round if it no
    /// longer fits, then join.
    fn apply(&mut self, k: usize) {
        let f = self.ks[k].footprint;
        if !self.cur.is_empty() && !(self.used + f).fits_within(&self.sm_cap) {
            self.elapsed += self.round_duration(&self.cur);
            self.cur.clear();
            self.used = ResourceVec::ZERO;
        }
        self.used += f;
        self.cur.push(k);
    }

    /// Makespan of the current packing with the open round closed.
    fn total(&self) -> f64 {
        if self.cur.is_empty() {
            self.elapsed
        } else {
            self.elapsed + self.round_duration(&self.cur)
        }
    }

    fn save_snapshot(&mut self) {
        if self.snaps.len() == self.depth {
            // Full-capacity members up front: a later save of a different
            // (larger) open round at this depth must not reallocate.
            self.snaps.push(ASnap {
                cur: Vec::with_capacity(self.ks.len()),
                ..ASnap::default()
            });
        }
        let s = &mut self.snaps[self.depth];
        s.elapsed = self.elapsed;
        s.used = self.used;
        s.cur.clear();
        s.cur.extend_from_slice(&self.cur);
        self.depth += 1;
    }

    fn restore_top(&mut self) {
        self.restore_at(self.depth - 1);
    }

    fn restore_at(&mut self, idx: usize) {
        let s = &self.snaps[idx];
        self.elapsed = s.elapsed;
        self.used = s.used;
        self.cur.clear();
        self.cur.extend_from_slice(&s.cur);
    }
}

impl PreparedWorkload for PreparedAnalytic {
    fn execute_order(&mut self, order: &[usize]) -> f64 {
        if !self.valid {
            return f64::NAN;
        }
        self.elapsed = 0.0;
        self.used = ResourceVec::ZERO;
        self.cur.clear();
        for &k in order {
            self.apply(k);
        }
        self.total()
    }

    fn supports_checkpoints(&self) -> bool {
        self.valid
    }

    fn checkpoint_push(&mut self, kernel: usize) {
        self.restore_top();
        self.apply(kernel);
        self.save_snapshot();
    }

    fn checkpoint_pop(&mut self) {
        debug_assert!(self.depth > 1, "no prefix kernel to pop");
        self.depth -= 1;
    }

    fn execute_suffix(&mut self, suffix: &[usize]) -> f64 {
        self.restore_top();
        for &k in suffix {
            self.apply(k);
        }
        self.total()
    }

    fn supports_depth_addressing(&self) -> bool {
        self.valid
    }

    fn execute_suffix_at(&mut self, depth: usize, suffix: &[usize]) -> f64 {
        debug_assert!(depth < self.depth, "no checkpoint at depth {depth}");
        self.restore_at(depth);
        for &k in suffix {
            self.apply(k);
        }
        self.total()
    }

    /// Admissible bound from the round model's structure: rounds are
    /// sequential and partition the kernels, so from the checkpoint's
    /// `elapsed` no completion can beat
    ///
    /// * the open round's current duration (members only gain, and
    ///   `round_duration` is monotone in membership),
    /// * any remaining kernel's round-duration floor — its round's
    ///   `denom ≥ max(its warp footprint, saturate)`, so the round lasts
    ///   ≥ `work_per_block · max(footprint, saturate) / (C · w_blk)`,
    /// * the bandwidth roofline over *all* leftover memory traffic
    ///   (every round lasts ≥ its own traffic / B, and traffic sets are
    ///   disjoint across rounds).
    fn suffix_lower_bound(&mut self, remaining: &[usize]) -> f64 {
        if !self.valid {
            return f64::NEG_INFINITY;
        }
        let s = &self.snaps[self.depth - 1];
        // Same arithmetic as `round_duration` (not an algebraic
        // rearrangement), so the floor never exceeds the true duration
        // even at the last ulp — a rounded-up bound could falsely prune
        // a subtree holding a bit-exact tie of the optimum.
        let mut dur_floor = if s.cur.is_empty() {
            0.0
        } else {
            self.round_duration(&s.cur)
        };
        let mut mem_rem: f64 = s.cur.iter().map(|&k| self.ks[k].total_mem).sum();
        for &k in remaining {
            let kk = &self.ks[k];
            mem_rem += kk.total_mem;
            if kk.warps_per_block > 0.0 {
                // Minimum possible denominator for k's round; IEEE
                // division is monotone, so this mirrors round_duration's
                // `work / (C·w/denom)` at `denom = max(footprint, sat)`.
                let denom = kk.warps_footprint.max(self.saturate);
                let rate = self.compute_rate * kk.warps_per_block / denom;
                dur_floor = dur_floor.max(kk.work_per_block / rate);
            }
        }
        s.elapsed + dur_floor.max(mem_rem / self.bandwidth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{by_id, epbsessw_8};

    #[test]
    fn analytic_makespan_positive_and_orders_matter() {
        let gpu = GpuSpec::gtx580();
        let ks = epbsessw_8();
        let mut b = AnalyticBackend::new();
        let fifo: Vec<usize> = (0..ks.len()).collect();
        let rev: Vec<usize> = (0..ks.len()).rev().collect();
        let t_fifo = b.execute(&gpu, &ks, &fifo).makespan_ms;
        let t_rev = b.execute(&gpu, &ks, &rev).makespan_ms;
        assert!(t_fifo.is_finite() && t_fifo > 0.0);
        assert!(t_rev.is_finite() && t_rev > 0.0);
        // EpBsEsSw-8 is highly order-sensitive; the round model must see
        // at least *some* difference between opposite orders.
        assert!((t_fifo - t_rev).abs() > 1e-9);
    }

    #[test]
    fn kernels_finish_with_their_round_cumulatively() {
        let gpu = GpuSpec::gtx580();
        // EP-6-shm: shmem footprints force multiple rounds under FIFO.
        let ks = by_id("ep-6-shm").unwrap().kernels;
        let order: Vec<usize> = (0..ks.len()).collect();
        let report = AnalyticBackend::new().execute(&gpu, &ks, &order);
        let rounds = pack_rounds(&gpu, &ks, &order);
        assert!(rounds.len() > 1, "expected multi-round packing");
        // Finish times are non-decreasing along the launch order and the
        // last kernel finishes at the makespan.
        let finishes: Vec<f64> = report.outcomes.iter().map(|o| o.finish_ms).collect();
        for w in finishes.windows(2) {
            assert!(w[1] >= w[0] - 1e-12);
        }
        assert!((finishes.last().unwrap() - report.makespan_ms).abs() < 1e-9);
    }

    #[test]
    fn prepared_matches_execute_bitwise() {
        let gpu = GpuSpec::gtx580();
        let ks = epbsessw_8();
        let mut backend = AnalyticBackend::new();
        let orders: Vec<Vec<usize>> = vec![
            (0..ks.len()).collect(),
            (0..ks.len()).rev().collect(),
            vec![3, 0, 6, 2, 7, 1, 5, 4],
        ];
        let direct: Vec<f64> = orders
            .iter()
            .map(|o| backend.execute(&gpu, &ks, o).makespan_ms)
            .collect();
        let mut prepared = backend.prepare(&gpu, &ks);
        assert!(prepared.supports_checkpoints());
        for (o, d) in orders.iter().zip(&direct) {
            assert_eq!(prepared.execute_order(o).to_bits(), d.to_bits(), "{o:?}");
        }
        // Checkpointed evaluation of the last order agrees too.
        let o = &orders[2];
        prepared.checkpoint_push(o[0]);
        prepared.checkpoint_push(o[1]);
        assert_eq!(
            prepared.execute_suffix(&o[2..]).to_bits(),
            direct[2].to_bits()
        );
        // Depth-addressed completion from mid-stack (depth 1) and the
        // empty prefix (depth 0) leave the stack intact.
        assert_eq!(
            prepared.execute_suffix_at(1, &o[1..]).to_bits(),
            direct[2].to_bits()
        );
        assert_eq!(prepared.execute_suffix_at(0, o).to_bits(), direct[2].to_bits());
        assert_eq!(
            prepared.execute_suffix(&o[2..]).to_bits(),
            direct[2].to_bits(),
            "top checkpoint must survive mid-stack restores"
        );
        prepared.checkpoint_pop();
        prepared.checkpoint_pop();
    }

    #[test]
    fn suffix_lower_bound_never_exceeds_any_completion() {
        // Admissibility pin for the round-model pruning bound, checked
        // exhaustively over every prefix of a 5-kernel paper workload.
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = epbsessw_8()[..5].to_vec();
        let n = ks.len();
        let mut backend = AnalyticBackend::new();
        let mut prepared = backend.prepare(&gpu, &ks);

        fn check(p: &mut dyn PreparedWorkload, used: &mut [bool], n: usize) {
            let remaining: Vec<usize> = (0..n).filter(|&k| !used[k]).collect();
            let lb = p.suffix_lower_bound(&remaining);
            let mut rest = remaining.clone();
            crate::perm::for_each_permutation(&mut rest, &mut |s| {
                let t = p.execute_suffix(s);
                assert!(lb <= t * (1.0 + 1e-9), "bound {lb} > makespan {t} ({s:?})");
            });
            if remaining.is_empty() {
                let t = p.execute_suffix(&[]);
                assert!(lb <= t * (1.0 + 1e-9));
            }
            for &k in &remaining {
                used[k] = true;
                p.checkpoint_push(k);
                check(p, used, n);
                p.checkpoint_pop();
                used[k] = false;
            }
        }
        check(prepared.as_mut(), &mut vec![false; n], n);
    }

    #[test]
    fn analytic_is_bounded_below_by_bandwidth_roofline() {
        let gpu = GpuSpec::gtx580();
        let ks = epbsessw_8();
        let order: Vec<usize> = (0..ks.len()).collect();
        let t = AnalyticBackend::new().execute(&gpu, &ks, &order).makespan_ms;
        let mem: f64 = ks.iter().map(|k| k.total_mem()).sum();
        assert!(t >= mem / gpu.memory_bandwidth() - 1e-9);
    }
}
