//! [`AnalyticBackend`] — the paper's analytic round model as an execution
//! substrate: kernels pack into execution rounds by per-SM footprint, each
//! round's duration is estimated from processor-sharing compute rates and
//! the shared bandwidth pool, and rounds execute strictly in sequence.
//!
//! Orders of magnitude cheaper than the fluid simulator (no event loop),
//! at the cost of ignoring intra-round dynamics — the A3 ablation bench
//! measures how well its round counts track simulated makespans.

use super::{BackendReport, ExecutionBackend};
use crate::gpu::{GpuSpec, KernelProfile};
use crate::sim::{self, rounds::pack_rounds};
use std::time::Instant;

/// Round-model backend. Stateless.
#[derive(Debug, Clone, Copy, Default)]
pub struct AnalyticBackend;

impl AnalyticBackend {
    pub fn new() -> Self {
        AnalyticBackend
    }
}

/// Estimated duration of one execution round: every member kernel's
/// blocks are co-resident and drain at the processor-sharing compute rate
/// `C · w_b / max(round_warps, warps_to_saturate)`; the round additionally
/// cannot beat the global memory bandwidth on its combined traffic.
fn round_duration_ms(gpu: &GpuSpec, kernels: &[KernelProfile], members: &[usize]) -> f64 {
    let round_warps: f64 = members
        .iter()
        .map(|&k| kernels[k].per_sm_footprint(gpu).warps)
        .sum();
    let denom = round_warps.max(gpu.warps_to_saturate as f64);
    let compute_ms = members
        .iter()
        .map(|&k| {
            let rate = gpu.compute_rate_per_sm * kernels[k].warps_per_block as f64 / denom;
            kernels[k].work_per_block / rate
        })
        .fold(0.0f64, f64::max);
    let mem_total: f64 = members.iter().map(|&k| kernels[k].total_mem()).sum();
    compute_ms.max(mem_total / gpu.memory_bandwidth())
}

impl ExecutionBackend for AnalyticBackend {
    fn name(&self) -> &str {
        "analytic"
    }

    fn execute(
        &mut self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        order: &[usize],
    ) -> BackendReport {
        let t0 = Instant::now();
        if sim::validate_workload(gpu, kernels).is_err() {
            return BackendReport::unsimulable(
                "analytic",
                t0.elapsed().as_secs_f64() * 1e3,
                order,
            );
        }

        let rounds = pack_rounds(gpu, kernels, order);
        let mut finish_by_kernel = vec![f64::NAN; kernels.len()];
        let mut elapsed = 0.0f64;
        for round in &rounds {
            elapsed += round_duration_ms(gpu, kernels, &round.kernels);
            for &k in &round.kernels {
                // Round granularity: every member finishes with its round.
                finish_by_kernel[k] = elapsed;
            }
        }
        BackendReport::from_finish_times(
            "analytic",
            elapsed,
            t0.elapsed().as_secs_f64() * 1e3,
            order,
            &finish_by_kernel,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{by_id, epbsessw_8};

    #[test]
    fn analytic_makespan_positive_and_orders_matter() {
        let gpu = GpuSpec::gtx580();
        let ks = epbsessw_8();
        let mut b = AnalyticBackend::new();
        let fifo: Vec<usize> = (0..ks.len()).collect();
        let rev: Vec<usize> = (0..ks.len()).rev().collect();
        let t_fifo = b.execute(&gpu, &ks, &fifo).makespan_ms;
        let t_rev = b.execute(&gpu, &ks, &rev).makespan_ms;
        assert!(t_fifo.is_finite() && t_fifo > 0.0);
        assert!(t_rev.is_finite() && t_rev > 0.0);
        // EpBsEsSw-8 is highly order-sensitive; the round model must see
        // at least *some* difference between opposite orders.
        assert!((t_fifo - t_rev).abs() > 1e-9);
    }

    #[test]
    fn kernels_finish_with_their_round_cumulatively() {
        let gpu = GpuSpec::gtx580();
        // EP-6-shm: shmem footprints force multiple rounds under FIFO.
        let ks = by_id("ep-6-shm").unwrap().kernels;
        let order: Vec<usize> = (0..ks.len()).collect();
        let report = AnalyticBackend::new().execute(&gpu, &ks, &order);
        let rounds = pack_rounds(&gpu, &ks, &order);
        assert!(rounds.len() > 1, "expected multi-round packing");
        // Finish times are non-decreasing along the launch order and the
        // last kernel finishes at the makespan.
        let finishes: Vec<f64> = report.outcomes.iter().map(|o| o.finish_ms).collect();
        for w in finishes.windows(2) {
            assert!(w[1] >= w[0] - 1e-12);
        }
        assert!((finishes.last().unwrap() - report.makespan_ms).abs() < 1e-9);
    }

    #[test]
    fn analytic_is_bounded_below_by_bandwidth_roofline() {
        let gpu = GpuSpec::gtx580();
        let ks = epbsessw_8();
        let order: Vec<usize> = (0..ks.len()).collect();
        let t = AnalyticBackend::new().execute(&gpu, &ks, &order).makespan_ms;
        let mem: f64 = ks.iter().map(|k| k.total_mem()).sum();
        assert!(t >= mem / gpu.memory_bandwidth() - 1e-9);
    }
}
