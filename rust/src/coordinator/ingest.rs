//! Lock-free batched submission queue for the thread coordinator.
//!
//! Producers (`Coordinator::submit` / `try_submit`) push from any
//! thread without taking a lock; the dispatcher drains with a single
//! atomic swap per wake-up. The shape is the classic multi-producer
//! Treiber stack with a *pop-all* consumer: push is one CAS loop on the
//! head pointer, and because the consumer takes the whole chain at once
//! (swap to null, then reverse for FIFO order) there is no ABA hazard —
//! a popped node is never re-linked. The queue's depth feeds the
//! admission policies ([`crate::admission::AdmissionPolicy`]), which is
//! why it is tracked explicitly instead of recomputed.
//!
//! Only `std::sync::atomic` is used — no external queue crate — and the
//! implementation is small enough to audit: two atomics, one CAS loop,
//! one swap.

use std::ptr;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};

struct Node<T> {
    value: T,
    next: *mut Node<T>,
}

/// A multi-producer / single-swap-consumer intrusive queue. `push` is
/// lock-free from any number of threads; `pop_all` takes everything in
/// one atomic swap and returns it oldest-first.
pub struct IngestQueue<T> {
    head: AtomicPtr<Node<T>>,
    depth: AtomicUsize,
}

impl<T> IngestQueue<T> {
    pub fn new() -> IngestQueue<T> {
        IngestQueue {
            head: AtomicPtr::new(ptr::null_mut()),
            depth: AtomicUsize::new(0),
        }
    }

    /// Push one entry (lock-free; never blocks, never fails). Returns
    /// the queue depth *including* this entry, so callers can feed
    /// admission decisions without a second load.
    pub fn push(&self, value: T) -> usize {
        let node = Box::into_raw(Box::new(Node {
            value,
            next: ptr::null_mut(),
        }));
        loop {
            let head = self.head.load(Ordering::Acquire);
            // The node is not yet shared: plain write through the raw
            // pointer is sound until the CAS publishes it.
            unsafe { (*node).next = head };
            if self
                .head
                .compare_exchange_weak(head, node, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return self.depth.fetch_add(1, Ordering::AcqRel) + 1;
            }
        }
    }

    /// Take everything currently queued, oldest-first. One atomic swap;
    /// entries pushed concurrently with the swap land in the next call.
    pub fn pop_all(&self) -> Vec<T> {
        let mut head = self.head.swap(ptr::null_mut(), Ordering::AcqRel);
        if head.is_null() {
            return Vec::new();
        }
        let mut out = Vec::new();
        while !head.is_null() {
            // Each node was published exactly once by `push` and the
            // swap made this chain exclusively ours.
            let node = unsafe { Box::from_raw(head) };
            head = node.next;
            out.push(node.value);
        }
        self.depth.fetch_sub(out.len(), Ordering::AcqRel);
        // The stack yields newest-first; callers want submission order.
        out.reverse();
        out
    }

    /// Current queue depth. Exact when quiescent; under concurrent
    /// pushes it can transiently lag by the number of in-flight
    /// producers (the admission policies treat it as a load signal, not
    /// an accounting ledger).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    pub fn is_empty(&self) -> bool {
        self.head.load(Ordering::Acquire).is_null()
    }
}

impl<T> Default for IngestQueue<T> {
    fn default() -> Self {
        IngestQueue::new()
    }
}

impl<T> Drop for IngestQueue<T> {
    fn drop(&mut self) {
        // Free any nodes still queued (their values drop normally).
        drop(self.pop_all());
    }
}

// The raw head pointer is the only reason these are not derived. All
// shared mutation goes through the atomics above, and values cross
// threads exactly once (producer → consumer), so `T: Send` suffices.
unsafe impl<T: Send> Send for IngestQueue<T> {}
unsafe impl<T: Send> Sync for IngestQueue<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn pop_all_returns_submission_order() {
        let q = IngestQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.push(1), 1);
        assert_eq!(q.push(2), 2);
        assert_eq!(q.push(3), 3);
        assert_eq!(q.depth(), 3);
        assert_eq!(q.pop_all(), vec![1, 2, 3]);
        assert_eq!(q.depth(), 0);
        assert!(q.is_empty());
        assert!(q.pop_all().is_empty());
    }

    #[test]
    fn interleaved_push_and_pop_preserve_order_within_batches() {
        let q = IngestQueue::new();
        q.push("a");
        q.push("b");
        assert_eq!(q.pop_all(), vec!["a", "b"]);
        q.push("c");
        assert_eq!(q.pop_all(), vec!["c"]);
    }

    #[test]
    fn concurrent_producers_lose_nothing() {
        const PRODUCERS: usize = 4;
        const PER: usize = 500;
        let q = Arc::new(IngestQueue::new());
        let handles: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..PER {
                        q.push(p * PER + i);
                    }
                })
            })
            .collect();
        let mut seen = Vec::new();
        // Drain concurrently with the producers, then once after join.
        for _ in 0..50 {
            seen.extend(q.pop_all());
        }
        for h in handles {
            h.join().unwrap();
        }
        seen.extend(q.pop_all());
        assert_eq!(seen.len(), PRODUCERS * PER, "no entry may be lost or duplicated");
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), PRODUCERS * PER);
        assert_eq!(q.depth(), 0);
        // Per-producer FIFO: each producer's own entries drain in its
        // push order (pop_all reverses the stack correctly).
        let q2 = IngestQueue::new();
        for i in 0..100 {
            q2.push(i);
        }
        let drained = q2.pop_all();
        assert!(drained.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn dropping_a_nonempty_queue_frees_its_nodes() {
        // Values with Drop still queued at teardown must drop exactly
        // once (Miri/asan would flag the leak or double-free).
        use std::sync::atomic::AtomicUsize;
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct Counted;
        impl Drop for Counted {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::SeqCst);
            }
        }
        {
            let q = IngestQueue::new();
            q.push(Counted);
            q.push(Counted);
            q.push(Counted);
        }
        assert_eq!(DROPS.load(Ordering::SeqCst), 3);
    }
}
