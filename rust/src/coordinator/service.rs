//! Coordinator service: submission queue, reorder window, dual dispatch.

use super::stats::ServiceStats;
use crate::gpu::{GpuSpec, KernelProfile};
use crate::runtime::Runtime;
use crate::sched::Policy;
use crate::sim;
use anyhow::Result;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Simulated GPU model (defaults to the paper's GTX580).
    pub gpu: GpuSpec,
    /// Launch-order policy applied to each batch.
    pub policy: Policy,
    /// Reorder window: max launches batched together.
    pub window: usize,
    /// How long the batcher waits for more work once a batch has started
    /// filling (the "linger", as in serving systems).
    pub linger: Duration,
    /// Artifacts directory for real PJRT execution; `None` = simulate
    /// timing only (no payload execution).
    pub artifacts_dir: Option<std::path::PathBuf>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            gpu: GpuSpec::gtx580(),
            policy: Policy::Algorithm1,
            window: 8,
            linger: Duration::from_millis(2),
            artifacts_dir: None,
        }
    }
}

/// One kernel-launch request.
#[derive(Debug, Clone)]
pub struct LaunchRequest {
    /// Caller-assigned id, echoed in the response.
    pub id: u64,
    /// Static profile (occupancy + ratio) used for scheduling and
    /// simulation.
    pub profile: KernelProfile,
    /// Seed for deterministic input synthesis of the real payload.
    pub seed: u64,
}

/// The coordinator's answer to one launch.
#[derive(Debug, Clone)]
pub struct LaunchResponse {
    pub id: u64,
    /// Numeric fingerprint of the real output (`NaN` when running
    /// simulation-only).
    pub checksum: f64,
    /// Wall-clock PJRT execution time of this kernel (0 when
    /// simulation-only).
    pub exec_wall_ms: f64,
    /// Time from submission to response.
    pub latency_ms: f64,
    /// Which batch served this request and at what position of the
    /// reordered launch sequence.
    pub batch_id: u64,
    pub position: usize,
}

/// Per-batch accounting (the serving example prints these).
#[derive(Debug, Clone)]
pub struct BatchReport {
    pub batch_id: u64,
    pub n: usize,
    /// Positions into the batch, in reordered launch order.
    pub order: Vec<usize>,
    /// Simulated GTX580 makespan under FIFO (arrival) order.
    pub sim_fifo_ms: f64,
    /// Simulated makespan under the applied policy order.
    pub sim_policy_ms: f64,
    /// Wall-clock time to execute the whole batch's real payloads.
    pub exec_wall_ms: f64,
}

/// Handle for one submitted launch; resolves to the response.
pub struct LaunchHandle {
    rx: Receiver<LaunchResponse>,
}

impl LaunchHandle {
    /// Block until the coordinator answers.
    pub fn wait(self) -> Result<LaunchResponse> {
        Ok(self.rx.recv()?)
    }

    /// Block with a timeout.
    pub fn wait_timeout(self, d: Duration) -> Result<LaunchResponse> {
        Ok(self.rx.recv_timeout(d)?)
    }
}

enum Msg {
    Launch(LaunchRequest, Sender<LaunchResponse>, Instant),
    /// Close the current batch immediately.
    Flush,
    Shutdown,
}

/// The coordinator service. See module docs.
pub struct Coordinator {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<(Vec<BatchReport>, ServiceStats)>>,
}

impl Coordinator {
    /// Start the service. When `cfg.artifacts_dir` is set, the worker
    /// thread loads the PJRT runtime before accepting work (an error at
    /// first use surfaces through the response channel).
    pub fn start(cfg: CoordinatorConfig) -> Coordinator {
        let (tx, rx) = channel::<Msg>();
        let worker = std::thread::spawn(move || worker_loop(cfg, rx));
        Coordinator {
            tx,
            worker: Some(worker),
        }
    }

    /// Submit a launch; returns a handle resolving to its response.
    pub fn submit(&self, req: LaunchRequest) -> LaunchHandle {
        let (tx, rx) = channel();
        // Worker outlives all submissions (it only exits on Shutdown).
        let _ = self.tx.send(Msg::Launch(req, tx, Instant::now()));
        LaunchHandle { rx }
    }

    /// Force the current batch to close regardless of the window.
    pub fn flush(&self) {
        let _ = self.tx.send(Msg::Flush);
    }

    /// Stop the service, returning every batch report and the aggregate
    /// service statistics.
    pub fn shutdown(mut self) -> (Vec<BatchReport>, ServiceStats) {
        let _ = self.tx.send(Msg::Shutdown);
        self.worker
            .take()
            .expect("shutdown called once")
            .join()
            .expect("worker panicked")
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let _ = self.tx.send(Msg::Shutdown);
            let _ = w.join();
        }
    }
}

struct Pending {
    req: LaunchRequest,
    reply: Sender<LaunchResponse>,
    submitted: Instant,
}

fn worker_loop(cfg: CoordinatorConfig, rx: Receiver<Msg>) -> (Vec<BatchReport>, ServiceStats) {
    // The PJRT runtime must live on this thread (its handles are !Send).
    let runtime: Option<Runtime> = cfg.artifacts_dir.as_ref().map(|dir| {
        Runtime::new(
            crate::profile::ArtifactStore::load(dir).expect("artifacts load"),
        )
        .expect("PJRT client")
    });

    let mut reports = Vec::new();
    let mut stats = ServiceStats::default();
    let mut batch_id = 0u64;

    'outer: loop {
        // Block for the first request of the next batch.
        let first = match rx.recv() {
            Ok(Msg::Launch(r, tx, t)) => Pending {
                req: r,
                reply: tx,
                submitted: t,
            },
            Ok(Msg::Flush) => continue,
            Ok(Msg::Shutdown) | Err(_) => break,
        };
        let mut batch = vec![first];

        // Fill the window, lingering for stragglers.
        let deadline = Instant::now() + cfg.linger;
        while batch.len() < cfg.window {
            let now = Instant::now();
            let Some(remaining) = deadline.checked_duration_since(now) else {
                break;
            };
            match rx.recv_timeout(remaining) {
                Ok(Msg::Launch(r, tx, t)) => batch.push(Pending {
                    req: r,
                    reply: tx,
                    submitted: t,
                }),
                Ok(Msg::Flush) => break,
                Ok(Msg::Shutdown) => {
                    process_batch(&cfg, runtime.as_ref(), batch, batch_id, &mut reports, &mut stats);
                    break 'outer;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    process_batch(&cfg, runtime.as_ref(), batch, batch_id, &mut reports, &mut stats);
                    break 'outer;
                }
            }
        }

        process_batch(&cfg, runtime.as_ref(), batch, batch_id, &mut reports, &mut stats);
        batch_id += 1;
    }

    (reports, stats)
}

fn process_batch(
    cfg: &CoordinatorConfig,
    runtime: Option<&Runtime>,
    batch: Vec<Pending>,
    batch_id: u64,
    reports: &mut Vec<BatchReport>,
    stats: &mut ServiceStats,
) {
    if batch.is_empty() {
        return;
    }
    let profiles: Vec<KernelProfile> = batch.iter().map(|p| p.req.profile.clone()).collect();

    // Reorder. Fall back to FIFO if the workload fails validation (the
    // simulator cannot time it, and reordering guarantees nothing).
    let order = if sim::validate_workload(&cfg.gpu, &profiles).is_ok() {
        cfg.policy.order(&cfg.gpu, &profiles)
    } else {
        (0..profiles.len()).collect()
    };

    // Simulated GPU comparison (only meaningful for valid workloads).
    let (sim_fifo_ms, sim_policy_ms) = if sim::validate_workload(&cfg.gpu, &profiles).is_ok() {
        (
            sim::simulate_fifo(&cfg.gpu, &profiles).makespan_ms,
            sim::simulate_order(&cfg.gpu, &profiles, &order).makespan_ms,
        )
    } else {
        (f64::NAN, f64::NAN)
    };

    // Execute real payloads in the reordered sequence.
    let t_batch = Instant::now();
    for (position, &bi) in order.iter().enumerate() {
        let pending = &batch[bi];
        let (checksum, exec_wall_ms) = match runtime {
            None => (f64::NAN, 0.0),
            Some(rt) => match rt.execute(&pending.req.profile.artifact, pending.req.seed) {
                Ok(out) => (out.checksum(), out.wall_ms),
                Err(e) => {
                    // Failure injection path: report the error through the
                    // response (checksum = -inf sentinel) and keep serving.
                    eprintln!("kernel {} failed: {e:#}", pending.req.profile.name);
                    (f64::NEG_INFINITY, 0.0)
                }
            },
        };
        let resp = LaunchResponse {
            id: pending.req.id,
            checksum,
            exec_wall_ms,
            latency_ms: pending.submitted.elapsed().as_secs_f64() * 1e3,
            batch_id,
            position,
        };
        stats.record_response(&resp);
        let _ = pending.reply.send(resp);
    }
    let exec_wall_ms = t_batch.elapsed().as_secs_f64() * 1e3;

    let report = BatchReport {
        batch_id,
        n: batch.len(),
        order,
        sim_fifo_ms,
        sim_policy_ms,
        exec_wall_ms,
    };
    stats.record_batch(&report);
    reports.push(report);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::AppKind;

    fn profile(name: &str, warps: u32, ratio: f64) -> KernelProfile {
        KernelProfile {
            name: name.into(),
            app: AppKind::Synthetic,
            n_blocks: 16,
            regs_per_block: 512,
            shmem_per_block: 0,
            warps_per_block: warps,
            ratio,
            work_per_block: 500.0,
            artifact: "unused".into(),
        }
    }

    fn sim_only_cfg(window: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            window,
            linger: Duration::from_millis(20),
            artifacts_dir: None,
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn every_request_answered_exactly_once() {
        let c = Coordinator::start(sim_only_cfg(4));
        let handles: Vec<_> = (0..10)
            .map(|i| {
                c.submit(LaunchRequest {
                    id: i,
                    profile: profile(&format!("k{i}"), 4 + (i % 3) as u32 * 8, 1.0 + i as f64),
                    seed: i,
                })
            })
            .collect();
        let mut ids: Vec<u64> = handles
            .into_iter()
            .map(|h| h.wait().unwrap().id)
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        let (reports, stats) = c.shutdown();
        assert_eq!(stats.n_responses, 10);
        assert_eq!(reports.iter().map(|r| r.n).sum::<usize>(), 10);
    }

    #[test]
    fn window_bounds_batch_size() {
        let c = Coordinator::start(sim_only_cfg(3));
        let handles: Vec<_> = (0..9)
            .map(|i| {
                c.submit(LaunchRequest {
                    id: i,
                    profile: profile("k", 4, 3.0),
                    seed: 0,
                })
            })
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        let (reports, _) = c.shutdown();
        assert!(reports.iter().all(|r| r.n <= 3), "{reports:?}");
    }

    #[test]
    fn policy_improves_or_matches_fifo_in_simulation() {
        // A window of opposing-type kernels: Algorithm 1's simulated
        // makespan must not exceed FIFO's.
        let c = Coordinator::start(sim_only_cfg(4));
        let profs = [
            profile("m1", 24, 1.0),
            profile("m2", 24, 1.0),
            profile("c1", 24, 40.0),
            profile("c2", 24, 40.0),
        ];
        let handles: Vec<_> = profs
            .iter()
            .enumerate()
            .map(|(i, p)| {
                c.submit(LaunchRequest {
                    id: i as u64,
                    profile: p.clone(),
                    seed: 0,
                })
            })
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        let (reports, _) = c.shutdown();
        for r in reports.iter().filter(|r| r.n == 4) {
            assert!(r.sim_policy_ms <= r.sim_fifo_ms + 1e-9, "{r:?}");
        }
    }

    #[test]
    fn sim_only_responses_have_nan_checksum() {
        let c = Coordinator::start(sim_only_cfg(1));
        let r = c
            .submit(LaunchRequest {
                id: 7,
                profile: profile("k", 8, 2.0),
                seed: 1,
            })
            .wait()
            .unwrap();
        assert!(r.checksum.is_nan());
        assert_eq!(r.exec_wall_ms, 0.0);
        assert_eq!(r.id, 7);
    }

    #[test]
    fn invalid_profile_falls_back_to_fifo() {
        // 64 warps/block exceeds SM capacity: unsimulable -> FIFO + NaN sims.
        let c = Coordinator::start(sim_only_cfg(2));
        let bad = KernelProfile {
            warps_per_block: 64,
            ..profile("bad", 4, 2.0)
        };
        let h1 = c.submit(LaunchRequest {
            id: 0,
            profile: bad,
            seed: 0,
        });
        let h2 = c.submit(LaunchRequest {
            id: 1,
            profile: profile("ok", 4, 2.0),
            seed: 0,
        });
        assert_eq!(h1.wait().unwrap().position, 0);
        assert_eq!(h2.wait().unwrap().position, 1);
        let (reports, _) = c.shutdown();
        let r = &reports[0];
        assert!(r.sim_fifo_ms.is_nan());
    }

    #[test]
    fn flush_closes_partial_batch() {
        let mut cfg = sim_only_cfg(100);
        cfg.linger = Duration::from_secs(10); // would stall without flush
        let c = Coordinator::start(cfg);
        let h = c.submit(LaunchRequest {
            id: 0,
            profile: profile("k", 8, 2.0),
            seed: 0,
        });
        c.flush();
        let r = h.wait_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.batch_id, 0);
        c.shutdown();
    }

    #[test]
    fn drop_without_shutdown_does_not_hang() {
        let c = Coordinator::start(sim_only_cfg(2));
        drop(c);
    }
}
