//! Coordinator service: submission queue, reorder window, multi-device
//! dispatch through [`LaunchPolicy`] + [`ExecutionBackend`] trait objects.
//!
//! Thread shape:
//!
//! ```text
//! submitters --lock-free ingest, MPSC doorbell--> dispatcher
//!                          |  (batching via WindowPolicy + BatchClock)
//!                          |  RoutePolicy over live per-device queue depths
//!                          +--> device worker 0 (own ExecutionBackend)
//!                          +--> device worker 1
//!                          +--> …
//! ```
//!
//! Submissions land in a lock-free [`IngestQueue`] (push is one CAS, no
//! lock shared with other submitters) and ring the dispatcher with a
//! doorbell message. The dispatcher drains the queue with a single
//! atomic swap per wake-up but feeds entries into the reorder window
//! **one at a time**, re-running the window decision between entries —
//! so batching decisions are byte-for-byte what they were when requests
//! traveled through the channel directly (the frozen-clock determinism
//! tests pin this).
//!
//! Overload protection: [`Coordinator::try_submit`] consults the
//! configured [`crate::admission::AdmissionPolicy`]
//! ([`CoordinatorBuilder::admission`]) against the live in-flight depth
//! and returns an explicit [`BackpressureError`] instead of queueing
//! unboundedly; [`Coordinator::submit`] never rejects. On the live path
//! only the depth signal is available (sojourn prediction needs the
//! virtual-clock engines), so `bound:<q>` is the load-bearing policy
//! here and `deadline`/`codel` degrade to admitting — the documented
//! last rung of the degradation ladder (reorder → FIFO → shed) stays
//! honest: rejections are counted in [`ServiceStats::n_rejected`].
//!
//! The dispatcher owns batching only; each *device worker* owns a backend
//! instance built on its own thread by the configured factory (the PJRT
//! handles are `!Send`, so backends must be born where they run) plus a
//! [`SimulatorBackend`] used for the per-batch FIFO-vs-policy comparison.
//!
//! *When* a window closes is delegated to a
//! [`crate::online::WindowPolicy`] — the same trait the virtual-clock
//! online engine uses, so a policy tuned in simulation
//! (`kreorder serve --arrivals …`) drops into the live service
//! unchanged — including occupancy-aware policies: the workers feed
//! per-device queue depths back to the dispatcher, which forwards the
//! least-loaded device's depth to the window policy (see
//! [`CoordinatorBuilder::window_policy`]). *Where* a closed batch goes
//! is delegated to a [`crate::fleet::RoutePolicy`] reading the same
//! depths ([`CoordinatorBuilder::route_policy`]; default round-robin,
//! which preserves the historical batch-id modulo mapping). The classic
//! `window`/`linger` builder knobs are sugar for
//! [`crate::online::LingerWindow`]. All deadline arithmetic reads the
//! injectable [`BatchClock`], making batching deterministic under a
//! [`super::ManualClock`] (see `tests/integration_coordinator.rs`).
//!
//! On `shutdown`, every request already submitted — batched *or* still
//! in the channel — is dispatched and answered before the dispatcher
//! exits; only submissions racing shutdown from other threads can
//! instead observe a disconnect error from their handle.

use super::clock::{BatchClock, SystemClock};
use super::ingest::IngestQueue;
use super::stats::ServiceStats;
use crate::admission::{AdmissionPolicy, AdmissionState, NoAdmission};
use crate::exec::{ExecutionBackend, SimulatorBackend};
use crate::fleet::{
    parse_route_policy, DeviceLoad, FleetView, Health, RoundRobin, RouteParseError, RoutePolicy,
};
use crate::gpu::{GpuSpec, KernelProfile};
use crate::obs::{TraceEvent, TraceSink};
use crate::online::{LingerWindow, WindowDecision, WindowPolicy, WindowState};
use crate::registry::ParseError;
use crate::sched::{registry, Algorithm1Policy, LaunchPolicy, PolicyParseError};
use crate::sim;
use anyhow::Result;
use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Factory producing one [`ExecutionBackend`] per device worker thread.
/// Called on the worker's own thread, so the backend itself need not be
/// `Send`.
pub type BackendFactory = Arc<dyn Fn() -> Result<Box<dyn ExecutionBackend>> + Send + Sync>;

/// Shared handle to the service's optional trace sink: the dispatcher
/// and every device worker record through the same mutex. `None` means
/// untraced — no lock exists, the live path pays nothing.
type SharedTraceSink = Arc<Mutex<Box<dyn TraceSink>>>;

/// One kernel-launch request.
#[derive(Debug, Clone)]
pub struct LaunchRequest {
    /// Caller-assigned id, echoed in the response.
    pub id: u64,
    /// Static profile (occupancy + ratio) used for scheduling and
    /// simulation.
    pub profile: KernelProfile,
    /// Seed for deterministic input synthesis of the real payload.
    pub seed: u64,
}

/// The coordinator's answer to one launch.
#[derive(Debug, Clone)]
pub struct LaunchResponse {
    pub id: u64,
    /// Numeric fingerprint of the real output (`NaN` when running a model
    /// backend, `-inf` when the payload failed).
    pub checksum: f64,
    /// Wall-clock execution time of this kernel (0 for model backends).
    pub exec_wall_ms: f64,
    /// Time from submission to response (sojourn), per the batch clock.
    pub latency_ms: f64,
    /// Time from submission to window dispatch (the batching share of
    /// `latency_ms`), per the batch clock.
    pub queue_ms: f64,
    /// Which batch served this request and at what position of the
    /// reordered launch sequence.
    pub batch_id: u64,
    pub position: usize,
    /// Which device worker executed the batch.
    pub device: usize,
}

/// Per-batch accounting (the serving example prints these).
#[derive(Debug, Clone)]
pub struct BatchReport {
    pub batch_id: u64,
    /// Device worker that executed the batch.
    pub device: usize,
    pub n: usize,
    /// Positions into the batch, in reordered launch order.
    pub order: Vec<usize>,
    /// Name of the policy that produced `order`.
    pub policy: String,
    /// Name of the backend that executed the batch.
    pub backend: String,
    /// Simulated GTX580 makespan under FIFO (arrival) order.
    pub sim_fifo_ms: f64,
    /// Simulated makespan under the applied policy order.
    pub sim_policy_ms: f64,
    /// Wall-clock time to execute the whole batch's payloads.
    pub exec_wall_ms: f64,
}

/// Handle for one submitted launch; resolves to the response.
pub struct LaunchHandle {
    rx: Receiver<LaunchResponse>,
}

impl LaunchHandle {
    /// Block until the coordinator answers.
    pub fn wait(self) -> Result<LaunchResponse> {
        Ok(self.rx.recv()?)
    }

    /// Block with a timeout.
    pub fn wait_timeout(self, d: Duration) -> Result<LaunchResponse> {
        Ok(self.rx.recv_timeout(d)?)
    }
}

// ---------------------------------------------------------------------------
// Builder
// ---------------------------------------------------------------------------

/// Builder for the coordinator service.
///
/// Defaults: GTX580 model, Algorithm 1 policy, simulator backend, one
/// device, linger window (8 kernels / 2 ms) on the system clock.
///
/// ```no_run
/// use kreorder::coordinator::CoordinatorBuilder;
/// use kreorder::sched::SjfPolicy;
///
/// let coord = CoordinatorBuilder::new()
///     .policy(SjfPolicy)
///     .devices(2)
///     .window(16)
///     .start();
/// ```
pub struct CoordinatorBuilder {
    gpu: GpuSpec,
    policy: Arc<dyn LaunchPolicy>,
    backend: BackendFactory,
    devices: usize,
    window: usize,
    linger: Duration,
    window_policy: Option<Box<dyn WindowPolicy>>,
    route: Box<dyn RoutePolicy>,
    clock: Arc<dyn BatchClock>,
    admission: Box<dyn AdmissionPolicy>,
    trace: Option<SharedTraceSink>,
}

impl Default for CoordinatorBuilder {
    fn default() -> Self {
        CoordinatorBuilder {
            gpu: GpuSpec::gtx580(),
            policy: Arc::new(Algorithm1Policy::new()),
            backend: Arc::new(|| Ok(Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)),
            devices: 1,
            window: 8,
            linger: Duration::from_millis(2),
            window_policy: None,
            route: Box::new(RoundRobin::default()),
            clock: Arc::new(SystemClock),
            admission: Box::new(NoAdmission),
            trace: None,
        }
    }
}

impl CoordinatorBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Simulated GPU model (defaults to the paper's GTX580).
    pub fn gpu(mut self, gpu: GpuSpec) -> Self {
        self.gpu = gpu;
        self
    }

    /// Launch-order policy applied to each batch.
    pub fn policy<P: LaunchPolicy + 'static>(mut self, policy: P) -> Self {
        self.policy = Arc::new(policy);
        self
    }

    /// Launch-order policy as a shared trait object.
    pub fn policy_arc(mut self, policy: Arc<dyn LaunchPolicy>) -> Self {
        self.policy = policy;
        self
    }

    /// Launch-order policy by registry spelling (`"fifo"`,
    /// `"random:42"`, …).
    pub fn policy_named(self, name: &str) -> Result<Self, PolicyParseError> {
        let p = registry::parse(name)?;
        Ok(self.policy_arc(Arc::from(p)))
    }

    /// Execution-backend factory, called once per device worker on the
    /// worker's own thread.
    pub fn backend<F>(mut self, factory: F) -> Self
    where
        F: Fn() -> Result<Box<dyn ExecutionBackend>> + Send + Sync + 'static,
    {
        self.backend = Arc::new(factory);
        self
    }

    /// Convenience: the fluid-simulator backend (the default).
    pub fn simulator_backend(self) -> Self {
        self.backend(|| Ok(Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>))
    }

    /// Convenience: the analytic round-model backend.
    pub fn analytic_backend(self) -> Self {
        self.backend(|| {
            Ok(Box::new(crate::exec::AnalyticBackend::new()) as Box<dyn ExecutionBackend>)
        })
    }

    /// Convenience: real PJRT payload execution from an artifacts
    /// directory (one runtime per device worker).
    #[cfg(feature = "pjrt")]
    pub fn pjrt_backend(self, artifacts_dir: impl Into<std::path::PathBuf>) -> Self {
        let dir = artifacts_dir.into();
        self.backend(move || {
            Ok(Box::new(crate::exec::PjrtBackend::new(&dir)?) as Box<dyn ExecutionBackend>)
        })
    }

    /// Number of device workers batches are routed across (clamped to
    /// ≥ 1). See [`CoordinatorBuilder::route_policy`] for *which* device
    /// each batch goes to.
    pub fn devices(mut self, n: usize) -> Self {
        self.devices = n.max(1);
        self
    }

    /// Routing policy deciding which device worker serves each closed
    /// batch (default [`RoundRobin`], which preserves the historical
    /// `batch_id % devices` mapping). Load-aware policies (`jsq`,
    /// `affinity`, …) read the live per-device queue depths the workers
    /// feed back; pricing-based `lrw` cannot price wall-clock backlogs
    /// and falls back to queue depth here.
    pub fn route_policy<R: RoutePolicy + 'static>(mut self, route: R) -> Self {
        self.route = Box::new(route);
        self
    }

    /// Routing policy by registry spelling (`"jsq"`, `"p2c:42"`, …), per
    /// [`parse_route_policy`].
    pub fn route_policy_named(mut self, name: &str) -> Result<Self, RouteParseError> {
        self.route = parse_route_policy(name)?;
        Ok(self)
    }

    /// Reorder window: max launches batched together (clamped to ≥ 1).
    /// Sugar for the default [`LingerWindow`]; also bounds the chunk
    /// size of the shutdown drain under any custom policy.
    pub fn window(mut self, n: usize) -> Self {
        self.window = n.max(1);
        self
    }

    /// How long the batcher waits for more work once a batch has started
    /// filling (the linger bound of the default [`LingerWindow`]).
    pub fn linger(mut self, d: Duration) -> Self {
        self.linger = d;
        self
    }

    /// Replace the batching policy wholesale with any
    /// [`crate::online::WindowPolicy`]. Overrides `window`/`linger` for
    /// closing decisions; `window` still bounds shutdown-drain chunks.
    ///
    /// The dispatcher forwards real occupancy to the policy: the workers
    /// feed back per-device queue depths, and the policy's
    /// [`WindowState`] carries the least-loaded device's depth in
    /// `queued_batches` (so `device_idle()` means "some worker could
    /// take this batch right now"). An
    /// [`crate::online::AdaptiveWindow`] therefore shows the same
    /// fill-while-busy behavior here as in the online simulator. One
    /// residual gap: workers report *when* they free only by draining
    /// (depth reaching zero), so `device_free_at_ms` is always `now` and
    /// a busy-wait recheck falls back to the policy's own deadline.
    pub fn window_policy<W: WindowPolicy + 'static>(mut self, policy: W) -> Self {
        self.window_policy = Some(Box::new(policy));
        self
    }

    /// Inject the time source for batching deadlines and latency
    /// accounting (default: the system clock). A
    /// [`super::ManualClock`] makes batching deterministic for tests.
    pub fn clock(mut self, clock: Arc<dyn BatchClock>) -> Self {
        self.clock = clock;
        self
    }

    /// Admission policy consulted by [`Coordinator::try_submit`]
    /// (default [`NoAdmission`], which admits everything). The live
    /// path exposes only the in-flight depth to the policy —
    /// `bound:<q>` is the load-bearing spelling here; `deadline` and
    /// `codel` degrade to admitting (their signals need the
    /// virtual-clock engines).
    pub fn admission(mut self, admission: Box<dyn AdmissionPolicy>) -> Self {
        self.admission = admission;
        self
    }

    /// Admission policy by registry spelling (`"none"`, `"bound:<q>"`,
    /// `"deadline:<slo_ms>"`, `"codel:<target_ms>:<interval_ms>"`).
    pub fn admission_named(self, name: &str) -> Result<Self, ParseError> {
        let a = crate::registry::parse_admission(name)?;
        Ok(self.admission(a))
    }

    /// Attach a [`TraceSink`] observing the live path, stamped with the
    /// **wall clock** (milliseconds since service start per the batch
    /// clock, so a [`super::ManualClock`] freezes the stamps too):
    /// [`TraceEvent::RouteDecision`] per dispatched batch,
    /// [`TraceEvent::BatchStart`]/[`TraceEvent::BatchFinish`] spans from
    /// the device workers, and [`TraceEvent::WorkerPanic`] at the
    /// per-batch panic guard. A no-op sink (the `none` spelling) is
    /// dropped at build time, so the untraced service carries no mutex
    /// and records nothing. To inspect events after `shutdown`, keep a
    /// clone of the handle and use [`CoordinatorBuilder::trace_sink_shared`].
    pub fn trace_sink(mut self, sink: Box<dyn TraceSink>) -> Self {
        if sink.is_noop() {
            self.trace = None;
            return self;
        }
        self.trace_sink_shared(Arc::new(Mutex::new(sink)))
    }

    /// [`CoordinatorBuilder::trace_sink`] from an already-shared handle;
    /// the caller's clone still sees every event after `shutdown`.
    pub fn trace_sink_shared(mut self, sink: Arc<Mutex<Box<dyn TraceSink>>>) -> Self {
        self.trace = Some(sink);
        self
    }

    /// Start the service.
    pub fn start(mut self) -> Coordinator {
        let (tx, rx) = channel::<Msg>();
        let clock = Arc::clone(&self.clock);
        let t0 = clock.now();
        let ingest: Arc<IngestQueue<Submission>> = Arc::new(IngestQueue::new());
        let in_flight = Arc::new(AtomicUsize::new(0));
        let admission = std::mem::replace(&mut self.admission, Box::new(NoAdmission));
        let d_ingest = Arc::clone(&ingest);
        let d_in_flight = Arc::clone(&in_flight);
        let dispatcher = std::thread::spawn(move || dispatcher_loop(self, rx, d_ingest, d_in_flight));
        Coordinator {
            tx,
            clock,
            t0,
            ingest,
            admission: Mutex::new(admission),
            in_flight,
            rejected: AtomicU64::new(0),
            dispatcher: Some(dispatcher),
        }
    }
}

// ---------------------------------------------------------------------------
// Service
// ---------------------------------------------------------------------------

/// One queued submission: the request, its reply channel, and the
/// batch-clock submission timestamp.
type Submission = (LaunchRequest, Sender<LaunchResponse>, Instant);

enum Msg {
    /// Doorbell: the ingest queue has (or had) new entries.
    Ingest,
    /// Close the current batch immediately.
    Flush,
    Shutdown,
}

/// Explicit backpressure: the admission policy refused the launch.
/// Carries the policy's canonical spelling and the in-flight depth it
/// judged, so callers can log, retry later, or shed load themselves.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BackpressureError {
    /// Canonical spelling of the policy that rejected (e.g. `bound:8`).
    pub policy: String,
    /// Requests submitted but not yet answered at decision time.
    pub depth: usize,
}

impl fmt::Display for BackpressureError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "admission policy `{}` rejected the launch ({} requests in flight)",
            self.policy, self.depth
        )
    }
}

impl std::error::Error for BackpressureError {}

/// The coordinator service. See module docs; construct with
/// [`CoordinatorBuilder`].
pub struct Coordinator {
    tx: Sender<Msg>,
    clock: Arc<dyn BatchClock>,
    /// Service birth per the batch clock (admission `now_ms` origin).
    t0: Instant,
    ingest: Arc<IngestQueue<Submission>>,
    admission: Mutex<Box<dyn AdmissionPolicy>>,
    /// Requests submitted (past admission) and not yet answered.
    in_flight: Arc<AtomicUsize>,
    /// Requests refused by `try_submit`; folded into
    /// [`ServiceStats::n_rejected`] at shutdown.
    rejected: AtomicU64,
    dispatcher: Option<JoinHandle<(Vec<BatchReport>, ServiceStats)>>,
}

impl Coordinator {
    /// Shorthand for `CoordinatorBuilder::new()`.
    pub fn builder() -> CoordinatorBuilder {
        CoordinatorBuilder::new()
    }

    /// Submit a launch unconditionally; returns a handle resolving to
    /// its response. The push is lock-free; the doorbell send only
    /// wakes the dispatcher.
    pub fn submit(&self, req: LaunchRequest) -> LaunchHandle {
        let (tx, rx) = channel();
        self.in_flight.fetch_add(1, Ordering::AcqRel);
        self.ingest.push((req, tx, self.clock.now()));
        // Dispatcher outlives all submissions (it only exits on Shutdown).
        let _ = self.tx.send(Msg::Ingest);
        LaunchHandle { rx }
    }

    /// Submit a launch through the admission gate: the configured
    /// policy sees the live in-flight depth and either admits (the
    /// request proceeds exactly as [`Coordinator::submit`]) or refuses
    /// with an explicit [`BackpressureError`] — the caller is never
    /// blocked and the queue never grows past what the policy allows.
    ///
    /// Only the depth signal exists on the live path:
    /// `oldest_wait_ms` is 0 and `predicted_sojourn_ms` is NaN, so
    /// `deadline`/`codel` degrade to admitting while `bound:<q>`
    /// enforces a hard occupancy cap. Refusals are counted in
    /// [`ServiceStats::n_rejected`].
    pub fn try_submit(&self, req: LaunchRequest) -> Result<LaunchHandle, BackpressureError> {
        let depth = self.in_flight.load(Ordering::Acquire);
        // A poisoned lock means a panicked submitter, not corrupt
        // policy state (admit() has no invariants to break mid-call).
        let mut policy = self.admission.lock().unwrap_or_else(|e| e.into_inner());
        let admit = policy.is_noop() || {
            let now_ms =
                self.clock.now().saturating_duration_since(self.t0).as_secs_f64() * 1e3;
            policy.admit(&AdmissionState {
                now_ms,
                queue_depth: depth,
                oldest_wait_ms: 0.0,
                predicted_sojourn_ms: f64::NAN,
            })
        };
        if !admit {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(BackpressureError {
                policy: policy.name(),
                depth,
            });
        }
        drop(policy);
        Ok(self.submit(req))
    }

    /// Force the current batch to close regardless of the window.
    pub fn flush(&self) {
        let _ = self.tx.send(Msg::Flush);
    }

    /// Stop the service, returning every batch report (ordered by batch
    /// id) and the aggregate service statistics across all devices.
    /// Requests submitted before this call — batched or still queued —
    /// are dispatched and answered first (drain semantics). A panicked
    /// dispatcher does not propagate: shutdown still returns, with the
    /// panic recorded in the stats.
    pub fn shutdown(mut self) -> (Vec<BatchReport>, ServiceStats) {
        let _ = self.tx.send(Msg::Shutdown);
        let (reports, mut stats) =
            match self.dispatcher.take().expect("shutdown called once").join() {
                Ok(out) => out,
                Err(payload) => {
                    let mut stats = ServiceStats::default();
                    stats
                        .record_panic(format!("dispatcher panicked: {}", panic_message(&payload)));
                    (Vec::new(), stats)
                }
            };
        stats.n_rejected += self.rejected.load(Ordering::Relaxed);
        (reports, stats)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(d) = self.dispatcher.take() {
            let _ = self.tx.send(Msg::Shutdown);
            let _ = d.join();
        }
    }
}

struct Pending {
    req: LaunchRequest,
    reply: Sender<LaunchResponse>,
    submitted: Instant,
    /// Stamped when the dispatcher hands the batch to a worker.
    dispatched: Instant,
}

struct Batch {
    id: u64,
    pending: Vec<Pending>,
}

/// Render a caught panic payload (the `Box<dyn Any>` from
/// `catch_unwind`/`join`) as best-effort human text.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Batching loop: drains the lock-free ingest queue, fills reorder
/// windows per the window policy (one entry at a time, re-deciding
/// between entries), and routes complete batches across the device
/// workers per the configured [`RoutePolicy`].
fn dispatcher_loop(
    cfg: CoordinatorBuilder,
    rx: Receiver<Msg>,
    ingest: Arc<IngestQueue<Submission>>,
    in_flight: Arc<AtomicUsize>,
) -> (Vec<BatchReport>, ServiceStats) {
    // Spawn the device workers first; each builds its backend on its own
    // thread via the factory. The shared counters track batches handed
    // to each worker but not yet finished — the occupancy signal both
    // the route policy and the window policy read.
    let depths: Arc<Vec<AtomicUsize>> =
        Arc::new((0..cfg.devices).map(|_| AtomicUsize::new(0)).collect());
    let t0 = cfg.clock.now();
    let mut worker_txs: Vec<Sender<Batch>> = Vec::with_capacity(cfg.devices);
    let mut worker_handles: Vec<JoinHandle<(Vec<BatchReport>, ServiceStats)>> =
        Vec::with_capacity(cfg.devices);
    for device in 0..cfg.devices {
        let (btx, brx) = channel::<Batch>();
        let gpu = cfg.gpu.clone();
        let policy = Arc::clone(&cfg.policy);
        let factory = Arc::clone(&cfg.backend);
        let clock = Arc::clone(&cfg.clock);
        let depths = Arc::clone(&depths);
        let in_flight = Arc::clone(&in_flight);
        let trace = cfg.trace.clone();
        worker_txs.push(btx);
        worker_handles.push(std::thread::spawn(move || {
            device_loop(device, gpu, policy, factory, clock, t0, depths, in_flight, trace, brx)
        }));
    }

    let clock = cfg.clock;
    let trace = cfg.trace;
    let now_ms = |c: &Arc<dyn BatchClock>| {
        c.now().saturating_duration_since(t0).as_secs_f64() * 1e3
    };
    let mut window_policy = cfg.window_policy.unwrap_or_else(|| {
        Box::new(LingerWindow::new(cfg.window, cfg.linger.as_secs_f64() * 1e3))
    });
    let mut route = cfg.route;
    let peak_compute = cfg.gpu.peak_compute();

    let mut batch_id = 0u64;
    // Workers whose channel has closed under us (the worker thread died
    // outside its per-batch panic guard). Health-aware route policies
    // see them as Down and steer around; a failed send falls through to
    // the next live worker either way.
    let mut worker_dead = vec![false; cfg.devices];
    let mut dispatch = |mut batch: Vec<Pending>, id: u64| {
        // An empty window must never reach a worker as a zero-kernel
        // batch (guards the Flush/drain paths and any misbehaving
        // window policy).
        if batch.is_empty() {
            return;
        }
        let t = clock.now();
        for p in &mut batch {
            p.dispatched = t;
        }
        // Route on live queue depths; the window's oldest kernel stands
        // in for the whole batch (affinity keys on its class). The live
        // path cannot price wall-clock backlogs, so `backlog_lb_ms` is
        // NaN and pricing policies fall back to queue depth.
        let now = t.saturating_duration_since(t0).as_secs_f64() * 1e3;
        let loads: Vec<DeviceLoad> = depths
            .iter()
            .enumerate()
            .map(|(d, depth)| {
                let depth = depth.load(Ordering::Relaxed);
                DeviceLoad {
                    device: d,
                    outstanding: depth,
                    n_pending: 0,
                    queued_batches: depth,
                    free_at_ms: now,
                    peak_compute,
                    backlog_lb_ms: f64::NAN,
                    health: if worker_dead[d] { Health::Down } else { Health::Healthy },
                }
            })
            .collect();
        let view = FleetView {
            now_ms: now,
            devices: &loads,
        };
        let mut device = route
            .route(&batch[0].req.profile, &view)
            .min(worker_txs.len() - 1);
        if let Some(tr) = &trace {
            let mut sink = tr.lock().unwrap_or_else(|e| e.into_inner());
            sink.record(TraceEvent::RouteDecision {
                t_ms: now,
                id: batch[0].req.id,
                device,
                policy: route.name(),
                outstanding: loads.iter().map(|l| l.outstanding).collect(),
                free_at_ms: loads.iter().map(|l| l.free_at_ms).collect(),
            });
        }
        depths[device].fetch_add(1, Ordering::Relaxed);
        let mut batch = Batch { id, pending: batch };
        loop {
            match worker_txs[device].send(batch) {
                Ok(()) => break,
                // The worker's receiver is gone (its thread died). The
                // send gives the batch back: mark the worker dead and
                // re-route to the next live one.
                Err(std::sync::mpsc::SendError(b)) => {
                    depths[device].fetch_sub(1, Ordering::Relaxed);
                    worker_dead[device] = true;
                    batch = b;
                    match (0..worker_txs.len()).find(|&d| !worker_dead[d]) {
                        Some(d) => {
                            device = d;
                            depths[device].fetch_add(1, Ordering::Relaxed);
                        }
                        // Every worker is gone: dropping the batch drops
                        // the reply senders, which surfaces as recv
                        // errors at the submitters rather than a hang.
                        None => return,
                    }
                }
            }
        }
    };

    let mut batch: Vec<Pending> = Vec::new();
    let mut oldest_ms = 0.0f64;
    // Entries already swapped out of the ingest queue but not yet fed
    // to the window. Feeding one per iteration (instead of dumping a
    // whole drain into the batch) keeps the window policy's view
    // identical to the one-message-at-a-time channel era: it re-decides
    // between every pair of entries.
    let mut inbox: std::collections::VecDeque<Pending> = std::collections::VecDeque::new();
    'outer: loop {
        // Let the window policy look at the open window first.
        let now = now_ms(&clock);
        let mut recheck: Option<f64> = None;
        if !batch.is_empty() {
            // Real occupancy: the least-loaded worker's unfinished-batch
            // depth. Workers only report freeing by draining to zero, so
            // `device_free_at_ms` stays `now` and a busy policy rechecks
            // at its own deadline.
            let queued = depths
                .iter()
                .map(|d| d.load(Ordering::Relaxed))
                .min()
                .unwrap_or(0);
            let state = WindowState {
                now_ms: now,
                n_pending: batch.len(),
                oldest_arrival_ms: oldest_ms,
                device_free_at_ms: now,
                queued_batches: queued,
            };
            match window_policy.decide(&state) {
                WindowDecision::Close => {
                    dispatch(std::mem::take(&mut batch), batch_id);
                    batch_id += 1;
                    continue;
                }
                WindowDecision::Wait { recheck_at_ms } => recheck = recheck_at_ms,
            }
        }

        // Refill the inbox from the lock-free queue (one swap drains
        // everything pushed so far), then feed exactly one entry into
        // the window and loop back to re-decide.
        if inbox.is_empty() {
            for (r, tx, t) in ingest.pop_all() {
                inbox.push_back(Pending {
                    req: r,
                    reply: tx,
                    submitted: t,
                    dispatched: t,
                });
            }
        }
        if let Some(p) = inbox.pop_front() {
            if batch.is_empty() {
                // The linger deadline anchors at the request's
                // *submission* time, not its dequeue time, so ingest
                // backlog counts against the latency bound (consistent
                // with queue_ms).
                oldest_ms = p.submitted.saturating_duration_since(t0).as_secs_f64() * 1e3;
            }
            batch.push(p);
            continue;
        }

        // Inbox and ingest both empty: block on the doorbell, bounded
        // by the policy's recheck deadline when it gave one.
        let msg = match recheck {
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => break 'outer,
            },
            Some(at) => {
                let wait = Duration::from_secs_f64((at - now).max(0.0) / 1e3);
                match rx.recv_timeout(wait) {
                    Ok(m) => m,
                    // Deadline (by the real clock) passed: re-decide
                    // against the batch clock. Under a frozen manual
                    // clock the deadline never arrives by time, which
                    // is exactly the determinism tests want.
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => break 'outer,
                }
            }
        };
        match msg {
            // Woken: the next iteration's refill picks the entries up.
            Msg::Ingest => {}
            Msg::Flush => {
                if !batch.is_empty() {
                    dispatch(std::mem::take(&mut batch), batch_id);
                    batch_id += 1;
                }
            }
            Msg::Shutdown => break 'outer,
        }
    }

    // Drain: requests still in the inbox or the ingest queue at
    // shutdown were submitted before it, so they are completed rather
    // than dropped. Custom window policies drain in `window`-sized
    // chunks.
    batch.extend(inbox);
    for (r, tx, t) in ingest.pop_all() {
        batch.push(Pending {
            req: r,
            reply: tx,
            submitted: t,
            dispatched: t,
        });
    }
    while !batch.is_empty() {
        let rest = batch.split_off(cfg.window.min(batch.len()));
        let head = std::mem::replace(&mut batch, rest);
        dispatch(head, batch_id);
        batch_id += 1;
    }

    // Close the worker queues and collect their reports/stats. A worker
    // that died poisoned (outside its per-batch panic guard) must not
    // abort shutdown for the rest of the fleet: its panic is recorded
    // and every other worker's results are still collected.
    drop(worker_txs);
    let mut reports = Vec::new();
    let mut stats = ServiceStats::default();
    for (device, handle) in worker_handles.into_iter().enumerate() {
        match handle.join() {
            Ok((mut r, s)) => {
                reports.append(&mut r);
                stats.merge(&s);
            }
            Err(payload) => {
                stats.record_panic(format!(
                    "device {device} worker thread panicked: {}",
                    panic_message(&payload)
                ));
            }
        }
    }
    reports.sort_by_key(|r| r.batch_id);
    (reports, stats)
}

/// One device worker: owns its backend (plus a simulator for the
/// FIFO-vs-policy comparison) and processes batches until the queue
/// closes, decrementing its shared depth counter as each batch
/// finishes (the dispatcher's occupancy signal) and the service-wide
/// in-flight counter as each request is answered (the admission gate's
/// depth signal).
#[allow(clippy::too_many_arguments)]
fn device_loop(
    device: usize,
    gpu: GpuSpec,
    policy: Arc<dyn LaunchPolicy>,
    factory: BackendFactory,
    clock: Arc<dyn BatchClock>,
    t0: Instant,
    depths: Arc<Vec<AtomicUsize>>,
    in_flight: Arc<AtomicUsize>,
    trace: Option<SharedTraceSink>,
    rx: Receiver<Batch>,
) -> (Vec<BatchReport>, ServiceStats) {
    // Backend construction failure (e.g. PJRT client unavailable) is not
    // fatal to the service: the worker keeps serving with the failure
    // sentinel so submitters always get answers.
    let mut backend: Option<Box<dyn ExecutionBackend>> = match factory() {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("device {device}: backend construction failed: {e:#}");
            None
        }
    };
    let mut compare = SimulatorBackend::new();

    let mut reports = Vec::new();
    let mut stats = ServiceStats::default();
    while let Ok(batch) = rx.recv() {
        // A panic anywhere in the batch path (policy, backend, payload)
        // must fail only this batch's in-flight handles — never the
        // worker, never `shutdown` for the rest of the fleet. Keep the
        // reply senders so a panicked batch can still be answered with
        // the failure sentinel (handles resolve to an error response,
        // not a disconnect).
        let batch_id = batch.id;
        let fallback: Vec<(u64, Sender<LaunchResponse>)> = batch
            .pending
            .iter()
            .map(|p| (p.req.id, p.reply.clone()))
            .collect();
        let fallback_len = fallback.len();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            process_batch(
                device,
                &gpu,
                policy.as_ref(),
                backend.as_deref_mut(),
                &mut compare,
                clock.as_ref(),
                t0,
                batch,
                &mut reports,
                &mut stats,
                trace.as_ref(),
            );
        }));
        if let Err(payload) = outcome {
            let msg = panic_message(payload.as_ref());
            eprintln!("device {device}: panic while serving batch {batch_id}: {msg}");
            stats.record_panic(format!("device {device}, batch {batch_id}: {msg}"));
            if let Some(tr) = &trace {
                let t_ms = clock.now().saturating_duration_since(t0).as_secs_f64() * 1e3;
                tr.lock().unwrap_or_else(|e| e.into_inner()).record(TraceEvent::WorkerPanic {
                    t_ms,
                    device,
                    message: msg.clone(),
                });
            }
            // Answer the batch's handles with the failure sentinel. If
            // the panic struck after some responses were already sent,
            // the duplicate is harmless: each handle resolves to the
            // first (real) response it received.
            for (position, (req_id, reply)) in fallback.into_iter().enumerate() {
                let resp = LaunchResponse {
                    id: req_id,
                    checksum: f64::NEG_INFINITY,
                    exec_wall_ms: 0.0,
                    latency_ms: 0.0,
                    queue_ms: 0.0,
                    batch_id,
                    position,
                    device,
                };
                stats.record_response(&resp);
                let _ = reply.send(resp);
            }
            // The panic may have struck mid-execute and left the backend
            // in an undefined state; rebuild it before the next batch.
            backend = match factory() {
                Ok(b) => Some(b),
                Err(e) => {
                    eprintln!("device {device}: backend rebuild after panic failed: {e:#}");
                    None
                }
            };
            compare = SimulatorBackend::new();
        }
        depths[device].fetch_sub(1, Ordering::Relaxed);
        // Every request in the batch has been answered (normally or via
        // the panic sentinel): they are no longer in flight.
        in_flight.fetch_sub(fallback_len, Ordering::AcqRel);
    }
    (reports, stats)
}

#[allow(clippy::too_many_arguments)]
fn process_batch(
    device: usize,
    gpu: &GpuSpec,
    policy: &dyn LaunchPolicy,
    backend: Option<&mut dyn ExecutionBackend>,
    compare: &mut SimulatorBackend,
    clock: &dyn BatchClock,
    t0: Instant,
    batch: Batch,
    reports: &mut Vec<BatchReport>,
    stats: &mut ServiceStats,
    trace: Option<&SharedTraceSink>,
) {
    let Batch { id: batch_id, pending } = batch;
    if pending.is_empty() {
        return;
    }
    let profiles: Vec<KernelProfile> = pending.iter().map(|p| p.req.profile.clone()).collect();
    let seeds: Vec<u64> = pending.iter().map(|p| p.req.seed).collect();
    let fifo: Vec<usize> = (0..profiles.len()).collect();

    // Reorder. Fall back to FIFO if the workload fails validation (the
    // simulator cannot time it, and reordering guarantees nothing).
    let valid = sim::validate_workload(gpu, &profiles).is_ok();
    let order = if valid {
        policy.order(gpu, &profiles)
    } else {
        fifo.clone()
    };

    // Simulated GTX580 comparison (only meaningful for valid workloads).
    // Prepared once: both orders share the hoisted kernel constants and
    // block-work table instead of paying full per-call setup twice.
    let (sim_fifo_ms, sim_policy_ms) = if valid {
        let mut prepared = compare.prepare(gpu, &profiles);
        (prepared.execute_order(&fifo), prepared.execute_order(&order))
    } else {
        (f64::NAN, f64::NAN)
    };

    // The live span is wall-clock bracketed: start stamped here, finish
    // after the payloads return (contrast the virtual-clock engines,
    // which future-stamp the finish at start time).
    let mut span_start_ms = 0.0f64;
    if let Some(tr) = trace {
        span_start_ms = clock.now().saturating_duration_since(t0).as_secs_f64() * 1e3;
        tr.lock().unwrap_or_else(|e| e.into_inner()).record(TraceEvent::BatchStart {
            t_ms: span_start_ms,
            device,
            batch: batch_id,
            n: pending.len(),
            order: order.clone(),
        });
    }

    // Execute payloads in the reordered sequence through the backend.
    let (backend_name, exec_wall_ms, outcome_of) = match backend {
        Some(b) => {
            let report = b.execute_seeded(gpu, &profiles, &order, &seeds);
            let mut by_index: Vec<(f64, f64)> = vec![(f64::NAN, 0.0); profiles.len()];
            for o in &report.outcomes {
                by_index[o.index] = (o.checksum, o.wall_ms);
            }
            (report.backend, report.wall_ms, by_index)
        }
        // No backend: every payload reports the failure sentinel.
        None => (
            "unavailable".to_string(),
            0.0,
            vec![(f64::NEG_INFINITY, 0.0); profiles.len()],
        ),
    };

    let done = clock.now();
    if let Some(tr) = trace {
        let t_ms = done.saturating_duration_since(t0).as_secs_f64() * 1e3;
        tr.lock().unwrap_or_else(|e| e.into_inner()).record(TraceEvent::BatchFinish {
            t_ms,
            device,
            batch: batch_id,
            makespan_ms: (t_ms - span_start_ms).max(0.0),
        });
    }
    for (position, &bi) in order.iter().enumerate() {
        let p = &pending[bi];
        let (checksum, wall) = outcome_of[bi];
        let resp = LaunchResponse {
            id: p.req.id,
            checksum,
            exec_wall_ms: wall,
            latency_ms: done.saturating_duration_since(p.submitted).as_secs_f64() * 1e3,
            queue_ms: p.dispatched.saturating_duration_since(p.submitted).as_secs_f64() * 1e3,
            batch_id,
            position,
            device,
        };
        stats.record_response(&resp);
        let _ = p.reply.send(resp);
    }

    let report = BatchReport {
        batch_id,
        device,
        n: pending.len(),
        order,
        policy: policy.name(),
        backend: backend_name,
        sim_fifo_ms,
        sim_policy_ms,
        exec_wall_ms,
    };
    stats.record_batch(&report);
    reports.push(report);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ManualClock;
    use crate::gpu::AppKind;
    use crate::online::FixedWindow;

    fn profile(name: &str, warps: u32, ratio: f64) -> KernelProfile {
        KernelProfile {
            name: name.into(),
            app: AppKind::Synthetic,
            n_blocks: 16,
            regs_per_block: 512,
            shmem_per_block: 0,
            warps_per_block: warps,
            ratio,
            work_per_block: 500.0,
            artifact: "unused".into(),
        }
    }

    fn sim_only(window: usize) -> Coordinator {
        CoordinatorBuilder::new()
            .window(window)
            .linger(Duration::from_millis(20))
            .start()
    }

    /// A coordinator whose linger can never expire: batching is a pure
    /// function of occupancy + flush/shutdown (fully deterministic).
    fn frozen(window: usize) -> Coordinator {
        CoordinatorBuilder::new()
            .window(window)
            .linger(Duration::from_secs(3600))
            .clock(Arc::new(ManualClock::new()))
            .start()
    }

    #[test]
    fn every_request_answered_exactly_once() {
        let c = sim_only(4);
        let handles: Vec<_> = (0..10)
            .map(|i| {
                c.submit(LaunchRequest {
                    id: i,
                    profile: profile(&format!("k{i}"), 4 + (i % 3) as u32 * 8, 1.0 + i as f64),
                    seed: i,
                })
            })
            .collect();
        let mut ids: Vec<u64> = handles
            .into_iter()
            .map(|h| h.wait().unwrap().id)
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        let (reports, stats) = c.shutdown();
        assert_eq!(stats.n_responses, 10);
        assert_eq!(reports.iter().map(|r| r.n).sum::<usize>(), 10);
    }

    #[test]
    fn frozen_clock_fills_windows_exactly() {
        // With time frozen, the linger never fires: 9 submissions into a
        // window of 3 must produce exactly three full batches, on every
        // run, on any machine.
        let c = frozen(3);
        let handles: Vec<_> = (0..9)
            .map(|i| {
                c.submit(LaunchRequest {
                    id: i,
                    profile: profile("k", 4, 3.0),
                    seed: 0,
                })
            })
            .collect();
        let mut batches = Vec::new();
        for h in handles {
            let r = h.wait().unwrap();
            // Frozen clock: all latencies are exactly zero.
            assert_eq!(r.latency_ms, 0.0);
            assert_eq!(r.queue_ms, 0.0);
            batches.push(r.batch_id);
        }
        let (reports, _) = c.shutdown();
        let sizes: Vec<usize> = reports.iter().map(|r| r.n).collect();
        assert_eq!(sizes, vec![3, 3, 3]);
        batches.sort_unstable();
        batches.dedup();
        assert_eq!(batches, vec![0, 1, 2]);
    }

    #[test]
    fn custom_window_policy_controls_batching() {
        let c = CoordinatorBuilder::new()
            .window(64) // drain chunking only; FixedWindow decides closes
            .window_policy(FixedWindow::new(2))
            .clock(Arc::new(ManualClock::new()))
            .start();
        let handles: Vec<_> = (0..6)
            .map(|i| {
                c.submit(LaunchRequest {
                    id: i,
                    profile: profile("k", 8, 2.0),
                    seed: 0,
                })
            })
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        let (reports, _) = c.shutdown();
        let sizes: Vec<usize> = reports.iter().map(|r| r.n).collect();
        assert_eq!(sizes, vec![2, 2, 2]);
    }

    #[test]
    fn policy_improves_or_matches_fifo_in_simulation() {
        // A window of opposing-type kernels: Algorithm 1's simulated
        // makespan must not exceed FIFO's.
        let c = sim_only(4);
        let profs = [
            profile("m1", 24, 1.0),
            profile("m2", 24, 1.0),
            profile("c1", 24, 40.0),
            profile("c2", 24, 40.0),
        ];
        let handles: Vec<_> = profs
            .iter()
            .enumerate()
            .map(|(i, p)| {
                c.submit(LaunchRequest {
                    id: i as u64,
                    profile: p.clone(),
                    seed: 0,
                })
            })
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        let (reports, _) = c.shutdown();
        for r in reports.iter().filter(|r| r.n == 4) {
            assert!(r.sim_policy_ms <= r.sim_fifo_ms + 1e-9, "{r:?}");
            assert_eq!(r.policy, "algorithm1");
            assert_eq!(r.backend, "sim");
        }
    }

    #[test]
    fn sim_only_responses_have_nan_checksum() {
        let c = sim_only(1);
        let r = c
            .submit(LaunchRequest {
                id: 7,
                profile: profile("k", 8, 2.0),
                seed: 1,
            })
            .wait()
            .unwrap();
        assert!(r.checksum.is_nan());
        assert_eq!(r.exec_wall_ms, 0.0);
        assert_eq!(r.id, 7);
        assert_eq!(r.device, 0);
        assert!(r.queue_ms <= r.latency_ms);
    }

    #[test]
    fn invalid_profile_falls_back_to_fifo() {
        // 64 warps/block exceeds SM capacity: unsimulable -> FIFO + NaN sims.
        let c = sim_only(2);
        let bad = KernelProfile {
            warps_per_block: 64,
            ..profile("bad", 4, 2.0)
        };
        let h1 = c.submit(LaunchRequest {
            id: 0,
            profile: bad,
            seed: 0,
        });
        let h2 = c.submit(LaunchRequest {
            id: 1,
            profile: profile("ok", 4, 2.0),
            seed: 0,
        });
        assert_eq!(h1.wait().unwrap().position, 0);
        assert_eq!(h2.wait().unwrap().position, 1);
        let (reports, _) = c.shutdown();
        let r = &reports[0];
        assert!(r.sim_fifo_ms.is_nan());
    }

    #[test]
    fn flush_closes_partial_batch() {
        let c = frozen(100);
        let h = c.submit(LaunchRequest {
            id: 0,
            profile: profile("k", 8, 2.0),
            seed: 0,
        });
        c.flush();
        let r = h.wait_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.batch_id, 0);
        c.shutdown();
    }

    #[test]
    fn flush_without_pending_dispatches_nothing() {
        // A flush storm on an empty window must not emit zero-kernel
        // batches.
        let c = frozen(4);
        for _ in 0..5 {
            c.flush();
        }
        let h = c.submit(LaunchRequest {
            id: 0,
            profile: profile("k", 8, 2.0),
            seed: 0,
        });
        c.flush();
        c.flush();
        h.wait_timeout(Duration::from_secs(5)).unwrap();
        let (reports, stats) = c.shutdown();
        assert_eq!(stats.n_batches, 1);
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].n, 1);
    }

    #[test]
    fn drop_without_shutdown_does_not_hang() {
        let c = sim_only(2);
        drop(c);
    }

    #[test]
    fn try_submit_applies_backpressure_under_a_bound() {
        // Frozen clock + never-expiring linger: admitted requests sit
        // in the open window, so the in-flight depth each try_submit
        // observes is fully deterministic (the depth increments
        // synchronously on the submitter thread).
        let c = CoordinatorBuilder::new()
            .window(100)
            .linger(Duration::from_secs(3600))
            .clock(Arc::new(ManualClock::new()))
            .admission_named("bound:2")
            .unwrap()
            .start();
        let req = |id| LaunchRequest {
            id,
            profile: profile("k", 8, 2.0),
            seed: 0,
        };
        let h0 = c.try_submit(req(0)).expect("first launch admitted");
        let h1 = c.try_submit(req(1)).expect("second launch admitted");
        let err = c.try_submit(req(2)).unwrap_err();
        assert_eq!(err.policy, "bound:2");
        assert_eq!(err.depth, 2);
        assert!(err.to_string().contains("bound:2"), "{err}");
        // Plain submit bypasses the gate (backpressure is opt-in).
        let h3 = c.submit(req(3));
        c.flush();
        for h in [h0, h1, h3] {
            h.wait_timeout(Duration::from_secs(5)).unwrap();
        }
        let (reports, stats) = c.shutdown();
        assert_eq!(stats.n_responses, 3);
        assert_eq!(stats.n_rejected, 1);
        assert_eq!(reports.iter().map(|r| r.n).sum::<usize>(), 3);
        assert!(stats.summary().contains("1 rejected"), "{}", stats.summary());
        assert!(CoordinatorBuilder::new().admission_named("blorp").is_err());
    }

    #[test]
    fn try_submit_with_default_admission_never_rejects() {
        // NoAdmission short-circuits (is_noop): the gate adds no lock
        // contention and every launch is admitted.
        let c = sim_only(4);
        let handles: Vec<_> = (0..8)
            .map(|i| {
                c.try_submit(LaunchRequest {
                    id: i,
                    profile: profile("k", 8, 2.0),
                    seed: 0,
                })
                .expect("none admits everything")
            })
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        let (_, stats) = c.shutdown();
        assert_eq!(stats.n_responses, 8);
        assert_eq!(stats.n_rejected, 0);
        assert!(!stats.summary().contains("rejected"));
    }

    #[test]
    fn route_policy_named_swaps_routing() {
        let c = CoordinatorBuilder::new()
            .route_policy_named("jsq")
            .unwrap()
            .devices(2)
            .window(1)
            .linger(Duration::from_millis(5))
            .start();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                c.submit(LaunchRequest {
                    id: i,
                    profile: profile("k", 8, 2.0),
                    seed: 0,
                })
            })
            .collect();
        let devices: Vec<usize> = handles
            .into_iter()
            .map(|h| h.wait().unwrap().device)
            .collect();
        assert!(devices.iter().all(|&d| d < 2), "{devices:?}");
        let (reports, stats) = c.shutdown();
        assert_eq!(stats.n_responses, 8);
        assert_eq!(reports.iter().map(|r| r.n).sum::<usize>(), 8);
        assert!(CoordinatorBuilder::new().route_policy_named("bogus").is_err());
    }

    #[test]
    fn adaptive_window_serves_under_real_occupancy() {
        // The adaptive policy now reads real worker depths in the live
        // path; the service must still answer everything (no spin, no
        // hang) whatever the interleaving of closes and drains.
        let c = CoordinatorBuilder::new()
            .window_policy(crate::online::AdaptiveWindow::new(4, 10.0))
            .devices(2)
            .start();
        let handles: Vec<_> = (0..12)
            .map(|i| {
                c.submit(LaunchRequest {
                    id: i,
                    profile: profile("k", 8, 2.0),
                    seed: 0,
                })
            })
            .collect();
        for h in handles {
            h.wait_timeout(Duration::from_secs(10)).unwrap();
        }
        let (_, stats) = c.shutdown();
        assert_eq!(stats.n_responses, 12);
    }

    #[test]
    fn builder_swaps_policy_and_backend() {
        let c = CoordinatorBuilder::new()
            .policy_named("reverse")
            .unwrap()
            .analytic_backend()
            .window(4)
            .linger(Duration::from_millis(20))
            .start();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                c.submit(LaunchRequest {
                    id: i,
                    profile: profile(&format!("k{i}"), 4 + (i % 3) as u32 * 8, 1.0 + i as f64),
                    seed: i,
                })
            })
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        let (reports, _) = c.shutdown();
        for r in reports.iter().filter(|r| r.n == 4) {
            assert_eq!(r.policy, "reverse");
            assert_eq!(r.backend, "analytic");
            // Reverse policy: order is the reversed arrival order.
            assert_eq!(r.order, vec![3, 2, 1, 0]);
        }
    }

    #[test]
    fn worker_panic_fails_only_its_own_batch() {
        use crate::sched::LaunchPolicy;

        /// Panics on any batch holding the marker kernel — simulating a
        /// fault anywhere inside the worker's batch path.
        struct PanicOnMarker;
        impl LaunchPolicy for PanicOnMarker {
            fn name(&self) -> String {
                "panic-on-marker".into()
            }
            fn order(&self, _gpu: &GpuSpec, kernels: &[KernelProfile]) -> Vec<usize> {
                if kernels.iter().any(|k| k.name == "boom") {
                    panic!("injected test panic");
                }
                (0..kernels.len()).collect()
            }
        }

        let c = CoordinatorBuilder::new()
            .policy(PanicOnMarker)
            .window(1)
            .linger(Duration::from_millis(5))
            .start();
        let h0 = c.submit(LaunchRequest {
            id: 0,
            profile: profile("ok0", 8, 2.0),
            seed: 0,
        });
        let h1 = c.submit(LaunchRequest {
            id: 1,
            profile: profile("boom", 8, 2.0),
            seed: 0,
        });
        let h2 = c.submit(LaunchRequest {
            id: 2,
            profile: profile("ok2", 8, 2.0),
            seed: 0,
        });
        // The poisoned batch resolves to the failure sentinel — an
        // answer, not a disconnect…
        let r1 = h1.wait_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(r1.checksum, f64::NEG_INFINITY);
        // …and the neighbours are served normally by the same worker.
        let r0 = h0.wait_timeout(Duration::from_secs(10)).unwrap();
        let r2 = h2.wait_timeout(Duration::from_secs(10)).unwrap();
        assert!(r0.checksum.is_nan());
        assert!(r2.checksum.is_nan());
        // Shutdown completes (no poisoned join) and the panic is on the
        // books.
        let (reports, stats) = c.shutdown();
        assert_eq!(stats.n_responses, 3);
        assert_eq!(stats.n_worker_panics, 1);
        assert!(
            stats.panic_messages[0].contains("injected test panic"),
            "{:?}",
            stats.panic_messages
        );
        assert!(stats.summary().contains("1 worker panics"));
        // Only the surviving batches produced reports.
        assert_eq!(reports.len(), 2);
        assert!(reports.iter().all(|r| r.n == 1));
    }

    #[test]
    fn trace_sink_records_live_route_and_batch_spans() {
        /// Appends into a shared vec the test can read after shutdown
        /// (the service owns its `Box<dyn TraceSink>`, so a concrete
        /// ring's snapshot would be unreachable behind the trait).
        struct VecSink(Arc<Mutex<Vec<TraceEvent>>>);
        impl TraceSink for VecSink {
            fn name(&self) -> String {
                "vec".into()
            }
            fn record(&mut self, ev: TraceEvent) {
                self.0.lock().unwrap().push(ev);
            }
        }

        let events: Arc<Mutex<Vec<TraceEvent>>> = Arc::new(Mutex::new(Vec::new()));
        let c = CoordinatorBuilder::new()
            .window(2)
            .linger(Duration::from_millis(5))
            .trace_sink(Box::new(VecSink(Arc::clone(&events))))
            .start();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                c.submit(LaunchRequest {
                    id: i,
                    profile: profile("k", 8, 2.0),
                    seed: 0,
                })
            })
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        c.shutdown();
        let evs = events.lock().unwrap();
        let starts = evs.iter().filter(|e| matches!(e, TraceEvent::BatchStart { .. })).count();
        let finishes =
            evs.iter().filter(|e| matches!(e, TraceEvent::BatchFinish { .. })).count();
        let routes =
            evs.iter().filter(|e| matches!(e, TraceEvent::RouteDecision { .. })).count();
        assert!(starts >= 1, "served batches must leave spans");
        assert_eq!(starts, finishes, "every live span is bracketed");
        assert_eq!(routes, starts, "one route decision per dispatched batch");
        for e in evs.iter() {
            if let Some(t) = e.t_ms() {
                assert!(t.is_finite() && t >= 0.0, "{e:?}");
            }
        }
        // The no-op sink is dropped at build time: no mutex, no events.
        let c2 = CoordinatorBuilder::new()
            .trace_sink(Box::new(crate::obs::NoTrace))
            .start();
        c2.shutdown();
    }

    #[test]
    fn failing_backend_factory_serves_failure_sentinels() {
        let c = CoordinatorBuilder::new()
            .backend(|| anyhow::bail!("no device"))
            .window(2)
            .linger(Duration::from_millis(10))
            .start();
        let h = c.submit(LaunchRequest {
            id: 0,
            profile: profile("k", 8, 2.0),
            seed: 0,
        });
        c.flush();
        let r = h.wait_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.checksum, f64::NEG_INFINITY);
        let (reports, stats) = c.shutdown();
        assert_eq!(stats.n_failures, 1);
        assert_eq!(reports[0].backend, "unavailable");
    }
}
