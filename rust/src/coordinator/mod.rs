//! The launch coordinator — the deployable system around Algorithm 1.
//!
//! A CUDA application (or, here, a request stream) submits kernel launches
//! in arrival order. The coordinator batches them in a *reorder window*,
//! derives a launch order with the configured [`crate::sched::Policy`]
//! (Algorithm 1 by default), and dispatches the batch:
//!
//! * **simulated GPU** — every batch is timed on the [`crate::sim`]
//!   GTX580 model under both FIFO and the chosen order (the paper's
//!   before/after comparison, reported per batch);
//! * **real payloads** — when constructed with artifacts, each kernel's
//!   AOT-compiled HLO is actually executed on the PJRT CPU client in the
//!   reordered sequence, so the service produces real numerics end to end
//!   (Python never runs on this path).
//!
//! Threading: one worker thread owns the PJRT runtime (the underlying C
//! handles are not `Send`), fed by an MPSC submission queue; responses
//! travel over per-request channels. This is the std-library analogue of
//! the usual tokio actor shape.

mod service;
mod stats;

pub use service::{
    BatchReport, Coordinator, CoordinatorConfig, LaunchHandle, LaunchRequest, LaunchResponse,
};
pub use stats::ServiceStats;
