//! The launch coordinator — the deployable system around the scheduling
//! policies.
//!
//! A CUDA application (or, here, a request stream) submits kernel launches
//! in arrival order. The coordinator batches them in a *reorder window*,
//! derives a launch order with the configured [`crate::sched::LaunchPolicy`]
//! (Algorithm 1 by default), and routes complete batches across N
//! *device workers* with a pluggable [`crate::fleet::RoutePolicy`]
//! (round-robin by default; load-aware policies read the live queue
//! depths the workers feed back — see
//! [`CoordinatorBuilder::route_policy`]). Each worker dispatches
//! through its own [`crate::exec::ExecutionBackend`]:
//!
//! * **simulator / analytic backends** — every batch is timed on the
//!   GTX580 model under both FIFO and the chosen order (the paper's
//!   before/after comparison, reported per batch);
//! * **PJRT backend** (`--features pjrt`) — each kernel's AOT-compiled
//!   HLO is actually executed on the PJRT CPU client in the reordered
//!   sequence, so the service produces real numerics end to end (Python
//!   never runs on this path).
//!
//! Threading: submitters push into a lock-free [`IngestQueue`] (one CAS
//! per submission, drained with one atomic swap) and ring a dispatcher
//! thread that owns batching and feeds per-device worker threads over
//! MPSC channels; each worker builds its backend on its own thread via
//! the configured factory (the PJRT C handles are not `Send`).
//! Responses travel over per-request channels. This is the std-library
//! analogue of the usual tokio actor shape. Overload protection is
//! opt-in: [`Coordinator::try_submit`] applies the configured
//! [`crate::admission::AdmissionPolicy`] to the live in-flight depth
//! and returns an explicit [`BackpressureError`] instead of queueing
//! unboundedly (rejections land in [`ServiceStats::n_rejected`]).
//!
//! *When* a window closes is decided by a pluggable
//! [`crate::online::WindowPolicy`] (shared with the online streaming
//! engine; the `window`/`linger` builder knobs are sugar for the default
//! [`crate::online::LingerWindow`]), and all batching time is measured
//! through an injectable [`BatchClock`] — a [`ManualClock`] makes
//! batching and latency accounting fully deterministic for tests.
//! [`ServiceStats`] records per-request sojourn and queue-wait samples
//! with exact p50/p95/p99. A [`crate::obs::TraceSink`] attached via
//! [`CoordinatorBuilder::trace_sink`] observes the live path with
//! wall-clock stamps: route decisions, per-device batch spans, and
//! worker panics become typed [`crate::obs::TraceEvent`]s.
//!
//! Construct with [`CoordinatorBuilder`]:
//!
//! ```no_run
//! use kreorder::coordinator::CoordinatorBuilder;
//!
//! let coord = CoordinatorBuilder::new()
//!     .policy_named("algorithm1").unwrap()
//!     .devices(2)
//!     .window(8)
//!     .start();
//! ```

mod clock;
mod ingest;
mod service;
mod stats;

pub use clock::{BatchClock, ManualClock, SystemClock};
pub use ingest::IngestQueue;
pub use service::{
    BackendFactory, BackpressureError, BatchReport, Coordinator, CoordinatorBuilder, LaunchHandle,
    LaunchRequest, LaunchResponse,
};
pub use stats::{LATENCY_SAMPLE_CAP, ServiceStats};
