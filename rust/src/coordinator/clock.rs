//! Injectable time source for the dispatcher's window batching.
//!
//! The dispatcher's linger/deadline arithmetic used to read
//! `Instant::now()` directly, which made every batching test a wall-time
//! race (a 20 ms linger under a loaded CI runner closes windows early or
//! late). [`BatchClock`] injects the *measurement* of time — blocking
//! still happens in `recv_timeout`, but deadlines, latencies and window
//! decisions are computed against the clock, so a [`ManualClock`] makes
//! batching fully deterministic: a frozen clock never expires a linger
//! (windows close on occupancy alone), and advancing it expires
//! deadlines on demand.

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A source of monotonic time for the coordinator's batching decisions
/// and latency accounting.
pub trait BatchClock: Send + Sync {
    fn now(&self) -> Instant;
}

/// The real monotonic clock (production default).
#[derive(Debug, Clone, Copy, Default)]
pub struct SystemClock;

impl BatchClock for SystemClock {
    fn now(&self) -> Instant {
        Instant::now()
    }
}

/// A clock that only moves when told to. With it installed, linger
/// deadlines are a pure function of [`ManualClock::advance`] calls:
/// frozen time = windows close only by occupancy (or flush/shutdown),
/// which is what deterministic batching tests want.
#[derive(Debug)]
pub struct ManualClock {
    base: Instant,
    offset: Mutex<Duration>,
}

impl Default for ManualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl ManualClock {
    pub fn new() -> Self {
        ManualClock {
            base: Instant::now(),
            offset: Mutex::new(Duration::ZERO),
        }
    }

    /// Move time forward by `d`.
    pub fn advance(&self, d: Duration) {
        *self.offset.lock().expect("clock poisoned") += d;
    }
}

impl BatchClock for ManualClock {
    fn now(&self) -> Instant {
        self.base + *self.offset.lock().expect("clock poisoned")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_moves() {
        let c = SystemClock;
        let a = c.now();
        assert!(c.now() >= a);
    }

    #[test]
    fn manual_clock_moves_only_on_advance() {
        let c = ManualClock::new();
        let a = c.now();
        assert_eq!(c.now(), a);
        c.advance(Duration::from_millis(250));
        assert_eq!(c.now(), a + Duration::from_millis(250));
        c.advance(Duration::from_millis(250));
        assert_eq!(c.now(), a + Duration::from_millis(500));
    }
}
