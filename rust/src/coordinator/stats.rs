//! Aggregate service statistics for the coordinator.

use super::service::{BatchReport, LaunchResponse};
use crate::metrics::percentile;

/// Cap on the retained latency samples: beyond it the buffers wrap
/// (oldest samples overwritten), so a long-lived service holds at most
/// ~1 MB of samples and its percentiles describe the **trailing
/// window** of this many responses — the quantity a live SLO dashboard
/// wants anyway. Below the cap, percentiles are exact over the whole
/// run.
pub const LATENCY_SAMPLE_CAP: usize = 65_536;

/// Running totals over the life of a coordinator.
///
/// Latency is recorded as raw per-response samples (sojourn and
/// dispatcher queue wait) in bounded ring buffers (see
/// [`LATENCY_SAMPLE_CAP`]), so the percentile accessors are exact over
/// the trailing window — the same accounting the online engine reports
/// for its virtual runs, measured here against the injectable batch
/// clock. Totals (`n_responses`, mean, max) always cover the whole
/// run.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub n_batches: usize,
    pub n_responses: usize,
    /// Sum of per-request latencies (ms).
    pub total_latency_ms: f64,
    /// Max per-request latency (ms).
    pub max_latency_ms: f64,
    /// Per-response sojourn samples (submit → response, ms); wraps at
    /// [`LATENCY_SAMPLE_CAP`].
    pub latencies_ms: Vec<f64>,
    /// Per-response queue-wait samples (submit → window dispatch, ms);
    /// wraps in lockstep with `latencies_ms`.
    pub queue_waits_ms: Vec<f64>,
    /// Device that served each sample; wraps in lockstep with
    /// `latencies_ms`, so per-device latency breakdowns survive the
    /// multi-device merge.
    pub sample_devices: Vec<usize>,
    /// Ring cursor for the wrapped sample buffers.
    sample_cursor: usize,
    /// Sum of simulated FIFO / policy makespans over valid batches.
    pub total_sim_fifo_ms: f64,
    pub total_sim_policy_ms: f64,
    /// Batches whose workload could not be simulated.
    pub n_unsimulated: usize,
    /// Sum of wall-clock batch execution times (ms).
    pub total_exec_wall_ms: f64,
    /// Responses carrying a failed-execution sentinel.
    pub n_failures: usize,
    /// Launches refused by the admission gate
    /// ([`super::Coordinator::try_submit`] backpressure) — the last
    /// rung of the degradation ladder. Folded in at shutdown; always 0
    /// inside a single worker's stats.
    pub n_rejected: u64,
    /// Panics caught inside device workers (or at worker join). Each
    /// one failed only its own in-flight batch — the service kept
    /// serving and `shutdown` completed normally.
    pub n_worker_panics: usize,
    /// Human-readable messages of those panics, in catch order.
    pub panic_messages: Vec<String>,
}

impl ServiceStats {
    pub(crate) fn record_response(&mut self, r: &LaunchResponse) {
        self.n_responses += 1;
        self.total_latency_ms += r.latency_ms;
        if r.latency_ms > self.max_latency_ms {
            self.max_latency_ms = r.latency_ms;
        }
        self.push_samples(r.latency_ms, r.queue_ms, r.device);
        if r.checksum == f64::NEG_INFINITY {
            self.n_failures += 1;
        }
    }

    /// Append one (sojourn, queue-wait, device) sample triple, wrapping
    /// the rings once [`LATENCY_SAMPLE_CAP`] samples are held.
    fn push_samples(&mut self, latency_ms: f64, queue_ms: f64, device: usize) {
        if self.latencies_ms.len() < LATENCY_SAMPLE_CAP {
            self.latencies_ms.push(latency_ms);
            self.queue_waits_ms.push(queue_ms);
            self.sample_devices.push(device);
        } else {
            self.latencies_ms[self.sample_cursor] = latency_ms;
            self.queue_waits_ms[self.sample_cursor] = queue_ms;
            self.sample_devices[self.sample_cursor] = device;
            self.sample_cursor = (self.sample_cursor + 1) % LATENCY_SAMPLE_CAP;
        }
    }

    /// Record a caught worker panic (per-batch `catch_unwind`, or a
    /// poisoned thread observed at shutdown join).
    pub(crate) fn record_panic(&mut self, message: String) {
        self.n_worker_panics += 1;
        self.panic_messages.push(message);
    }

    pub(crate) fn record_batch(&mut self, b: &BatchReport) {
        self.n_batches += 1;
        self.total_exec_wall_ms += b.exec_wall_ms;
        if b.sim_fifo_ms.is_nan() {
            self.n_unsimulated += 1;
        } else {
            self.total_sim_fifo_ms += b.sim_fifo_ms;
            self.total_sim_policy_ms += b.sim_policy_ms;
        }
    }

    /// Fold another worker's totals into this one (multi-device merge at
    /// shutdown). Latency samples concatenate through the same bounded
    /// ring, so percentiles stay exact across workers until the cap
    /// wraps.
    pub fn merge(&mut self, other: &ServiceStats) {
        self.n_batches += other.n_batches;
        self.n_responses += other.n_responses;
        self.total_latency_ms += other.total_latency_ms;
        self.max_latency_ms = self.max_latency_ms.max(other.max_latency_ms);
        // Replay the peer's ring in chronological order (oldest sample
        // sits at its cursor once wrapped), so this ring's own eviction
        // keeps dropping oldest-first and device provenance stays
        // aligned with its samples.
        let n = other.latencies_ms.len();
        for k in 0..n {
            let i = (other.sample_cursor + k) % n;
            self.push_samples(
                other.latencies_ms[i],
                other.queue_waits_ms[i],
                other.sample_devices[i],
            );
        }
        self.total_sim_fifo_ms += other.total_sim_fifo_ms;
        self.total_sim_policy_ms += other.total_sim_policy_ms;
        self.n_unsimulated += other.n_unsimulated;
        self.total_exec_wall_ms += other.total_exec_wall_ms;
        self.n_failures += other.n_failures;
        self.n_rejected += other.n_rejected;
        self.n_worker_panics += other.n_worker_panics;
        self.panic_messages.extend(other.panic_messages.iter().cloned());
    }

    /// Mean request latency (ms).
    pub fn mean_latency_ms(&self) -> f64 {
        if self.n_responses == 0 {
            0.0
        } else {
            self.total_latency_ms / self.n_responses as f64
        }
    }

    /// Exact p-th percentile (0–100) of per-request sojourn latency.
    pub fn latency_percentile_ms(&self, p: f64) -> f64 {
        percentile(&self.latencies_ms, p)
    }

    /// Exact p-th percentile (0–100) of per-request queue wait.
    pub fn queue_percentile_ms(&self, p: f64) -> f64 {
        percentile(&self.queue_waits_ms, p)
    }

    /// Retained samples in chronological order, oldest first, as
    /// `(device, latency_ms, queue_ms)` triples. Once the ring has
    /// wrapped, the oldest retained sample sits at the cursor.
    pub fn samples_chronological(&self) -> Vec<(usize, f64, f64)> {
        let n = self.latencies_ms.len();
        (0..n)
            .map(|k| {
                let i = (self.sample_cursor + k) % n;
                (
                    self.sample_devices[i],
                    self.latencies_ms[i],
                    self.queue_waits_ms[i],
                )
            })
            .collect()
    }

    /// Exact p-th percentile (0–100) of sojourn latency over the
    /// retained samples served by one device (0 when that device has no
    /// retained samples).
    pub fn device_latency_percentile_ms(&self, device: usize, p: f64) -> f64 {
        let samples: Vec<f64> = self
            .latencies_ms
            .iter()
            .zip(&self.sample_devices)
            .filter(|&(_, &d)| d == device)
            .map(|(&l, _)| l)
            .collect();
        percentile(&samples, p)
    }

    /// Aggregate simulated speedup of the policy over FIFO arrival order.
    pub fn sim_speedup(&self) -> f64 {
        if self.total_sim_policy_ms <= 0.0 {
            0.0
        } else {
            self.total_sim_fifo_ms / self.total_sim_policy_ms
        }
    }

    /// Requests served per wall-clock second of batch execution.
    pub fn throughput_per_s(&self) -> f64 {
        if self.total_exec_wall_ms <= 0.0 {
            0.0
        } else {
            self.n_responses as f64 / (self.total_exec_wall_ms / 1e3)
        }
    }

    /// One-line human summary (plus a panic line when any were caught).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} batches / {} responses | latency mean {:.2} ms p95 {:.2} p99 {:.2} (max {:.2}) | \
             queue p95 {:.2} ms | sim speedup vs FIFO {:.3}x | exec wall {:.1} ms | {} failures",
            self.n_batches,
            self.n_responses,
            self.mean_latency_ms(),
            self.latency_percentile_ms(95.0),
            self.latency_percentile_ms(99.0),
            self.max_latency_ms,
            self.queue_percentile_ms(95.0),
            self.sim_speedup(),
            self.total_exec_wall_ms,
            self.n_failures,
        );
        if self.n_rejected > 0 {
            s.push_str(&format!(" | {} rejected (backpressure)", self.n_rejected));
        }
        if self.n_worker_panics > 0 {
            s.push_str(&format!(
                " | {} worker panics (last: {})",
                self.n_worker_panics,
                self.panic_messages.last().map(String::as_str).unwrap_or("?"),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(latency: f64, checksum: f64) -> LaunchResponse {
        resp_on(0, latency, checksum)
    }

    fn resp_on(device: usize, latency: f64, checksum: f64) -> LaunchResponse {
        LaunchResponse {
            id: 0,
            checksum,
            exec_wall_ms: 1.0,
            latency_ms: latency,
            queue_ms: latency / 2.0,
            batch_id: 0,
            position: 0,
            device,
        }
    }

    fn batch(batch_id: u64, n: usize, fifo: f64, policy: f64, wall: f64) -> BatchReport {
        BatchReport {
            batch_id,
            device: 0,
            n,
            order: (0..n).collect(),
            policy: "algorithm1".into(),
            backend: "sim".into(),
            sim_fifo_ms: fifo,
            sim_policy_ms: policy,
            exec_wall_ms: wall,
        }
    }

    #[test]
    fn latency_aggregation() {
        let mut s = ServiceStats::default();
        s.record_response(&resp(10.0, 1.0));
        s.record_response(&resp(30.0, 1.0));
        assert_eq!(s.n_responses, 2);
        assert_eq!(s.mean_latency_ms(), 20.0);
        assert_eq!(s.max_latency_ms, 30.0);
        assert_eq!(s.n_failures, 0);
    }

    #[test]
    fn percentiles_are_exact_over_samples() {
        let mut s = ServiceStats::default();
        for i in 1..=100 {
            s.record_response(&resp(i as f64, 1.0));
        }
        assert!((s.latency_percentile_ms(50.0) - 50.5).abs() < 1e-9);
        assert!((s.latency_percentile_ms(99.0) - 99.01).abs() < 1e-9);
        assert!((s.queue_percentile_ms(50.0) - 25.25).abs() < 1e-9);
        assert_eq!(s.latencies_ms.len(), 100);
        assert_eq!(s.queue_waits_ms.len(), 100);
    }

    #[test]
    fn sample_buffers_wrap_at_the_cap() {
        let mut s = ServiceStats::default();
        for i in 0..(LATENCY_SAMPLE_CAP + 10) {
            s.record_response(&resp(i as f64, 1.0));
        }
        // Bounded memory: the buffers never exceed the cap…
        assert_eq!(s.latencies_ms.len(), LATENCY_SAMPLE_CAP);
        assert_eq!(s.queue_waits_ms.len(), LATENCY_SAMPLE_CAP);
        // …totals still cover the whole run…
        assert_eq!(s.n_responses, LATENCY_SAMPLE_CAP + 10);
        assert_eq!(s.max_latency_ms, (LATENCY_SAMPLE_CAP + 9) as f64);
        // …and the oldest samples were the ones overwritten.
        let min = s.latencies_ms.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(min, 10.0);
    }

    #[test]
    fn failure_sentinel_counted() {
        let mut s = ServiceStats::default();
        s.record_response(&resp(1.0, f64::NEG_INFINITY));
        assert_eq!(s.n_failures, 1);
    }

    #[test]
    fn batch_aggregation_and_speedup() {
        let mut s = ServiceStats::default();
        s.record_batch(&batch(0, 4, 200.0, 100.0, 50.0));
        s.record_batch(&batch(1, 2, f64::NAN, f64::NAN, 10.0));
        assert_eq!(s.n_batches, 2);
        assert_eq!(s.n_unsimulated, 1);
        assert_eq!(s.sim_speedup(), 2.0);
        assert!((s.total_exec_wall_ms - 60.0).abs() < 1e-12);
    }

    #[test]
    fn merge_folds_worker_totals() {
        let mut a = ServiceStats::default();
        a.record_response(&resp(10.0, 1.0));
        a.record_batch(&batch(0, 1, 100.0, 50.0, 5.0));
        a.n_rejected = 2;
        let mut b = ServiceStats::default();
        b.record_response(&resp(40.0, f64::NEG_INFINITY));
        b.record_batch(&batch(1, 1, 300.0, 150.0, 7.0));
        b.n_rejected = 3;
        a.merge(&b);
        assert_eq!(a.n_rejected, 5);
        assert!(a.summary().contains("5 rejected"));
        assert_eq!(a.n_responses, 2);
        assert_eq!(a.n_batches, 2);
        assert_eq!(a.max_latency_ms, 40.0);
        assert_eq!(a.n_failures, 1);
        assert_eq!(a.sim_speedup(), 2.0);
        assert!((a.total_exec_wall_ms - 12.0).abs() < 1e-12);
        // Percentiles see both workers' samples.
        assert_eq!(a.latencies_ms.len(), 2);
        assert_eq!(a.latency_percentile_ms(100.0), 40.0);
    }

    #[test]
    fn merge_keeps_wrapped_rings_chronological_with_device_provenance() {
        // Encode (device, sequence) into each latency so ordering and
        // provenance are checkable after the merge.
        let lat = |d: usize, i: usize| (d * 100_000_000 + i) as f64;

        // Device 0's ring wraps (cap + 100 responses); devices 1 and 2
        // stay under the cap.
        let mut w0 = ServiceStats::default();
        for i in 0..(LATENCY_SAMPLE_CAP + 100) {
            w0.record_response(&resp_on(0, lat(0, i), 1.0));
        }
        let mut w1 = ServiceStats::default();
        let mut w2 = ServiceStats::default();
        for i in 0..50 {
            w1.record_response(&resp_on(1, lat(1, i), 1.0));
            w2.record_response(&resp_on(2, lat(2, i), 1.0));
        }

        let mut merged = ServiceStats::default();
        merged.merge(&w0);
        merged.merge(&w1);
        merged.merge(&w2);

        // 100 + 50 + 50 evictions past the cap, always oldest-first.
        assert_eq!(merged.n_responses, LATENCY_SAMPLE_CAP + 200);
        let samples = merged.samples_chronological();
        assert_eq!(samples.len(), LATENCY_SAMPLE_CAP);
        // Oldest surviving sample: device 0's sequence number 200 (its
        // own ring dropped 0..100, the two merges dropped 100..200).
        assert_eq!(samples[0], (0, lat(0, 200), lat(0, 200) / 2.0));
        // Within each device the samples stay in submission order, and
        // the devices appear in merge order (0 block, then 1, then 2).
        let mut last_seq = [None::<f64>; 3];
        let mut max_device_seen = 0;
        for &(d, l, q) in &samples {
            assert!(d >= max_device_seen, "device blocks out of order");
            max_device_seen = d;
            assert!(last_seq[d].map_or(true, |prev| prev < l), "device {d} reordered");
            last_seq[d] = Some(l);
            assert_eq!(q, l / 2.0);
        }
        let count = |dev: usize| samples.iter().filter(|&&(d, _, _)| d == dev).count();
        assert_eq!(count(0), LATENCY_SAMPLE_CAP - 100);
        assert_eq!(count(1), 50);
        assert_eq!(count(2), 50);
        // Per-device percentiles read only that device's samples.
        assert_eq!(merged.device_latency_percentile_ms(1, 100.0), lat(1, 49));
        assert_eq!(merged.device_latency_percentile_ms(2, 100.0), lat(2, 49));
        assert_eq!(merged.device_latency_percentile_ms(7, 99.0), 0.0);
    }

    #[test]
    fn worker_panics_are_counted_merged_and_summarized() {
        let mut a = ServiceStats::default();
        assert!(!a.summary().contains("worker panics"));
        a.record_panic("device 1: boom".into());
        let mut b = ServiceStats::default();
        b.record_panic("device 0: pow".into());
        a.merge(&b);
        assert_eq!(a.n_worker_panics, 2);
        assert_eq!(a.panic_messages.len(), 2);
        let s = a.summary();
        assert!(s.contains("2 worker panics"), "{s}");
        assert!(s.contains("device 0: pow"), "{s}");
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = ServiceStats::default();
        assert_eq!(s.mean_latency_ms(), 0.0);
        assert_eq!(s.sim_speedup(), 0.0);
        assert_eq!(s.throughput_per_s(), 0.0);
        assert_eq!(s.latency_percentile_ms(99.0), 0.0);
        assert!(s.summary().contains("0 batches"));
    }
}
