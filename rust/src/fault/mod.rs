//! **Deterministic fault injection** — replayable device crash /
//! straggler / launch-failure plans, and seeded retry with backoff.
//!
//! The paper's reordering wins assume every launched kernel runs to
//! completion on a healthy device. A production fleet does not get that
//! luxury: devices crash and recover, stragglers appear mid-run, and
//! individual launches are rejected by the driver. This module gives the
//! virtual-clock engines ([`crate::fleet::simulate_fleet_with_faults`])
//! a *replayable* failure model, so recovery behavior is tested with the
//! same bit-identical-replay guarantee as everything else:
//!
//! * [`FaultPlan`] — a schedule of injected faults, parsed from a spec
//!   string (clauses joined with `;`) or a CSV-ish line-per-clause file,
//!   or generated from a seeded process ([`FaultPlan::generate`]);
//! * [`RetryPolicy`] — per-kernel retry with seeded exponential backoff
//!   + jitter and a max-attempts cap, after which the kernel is counted
//!   as **shed**, never silently lost;
//! * [`LaunchFailures`] — a seeded Bernoulli process over `(kernel,
//!   attempt)` pairs, so whether a given launch attempt fails is a pure
//!   function of `(seed, id, attempt)` — independent of event
//!   interleaving, which is what keeps fault runs replayable.
//!
//! | clause | meaning |
//! |---|---|
//! | `crash:<dev>@<t>` | device `<dev>` goes down at virtual time `<t>` ms |
//! | `crash:<dev>@<t>:recover@<t2>` | …and comes back at `<t2>` ms |
//! | `slowdown:<dev>@<t>:<factor>` | device `<dev>` serves `<factor>`× slower from `<t>` ms (a straggler; `< 1` models a speedup) |
//! | `launchfail:<p>:<seed>` | every launch attempt fails with probability `<p>`, seeded (at most one per plan) |
//!
//! Everything downstream — orphaning a dead device's queue back to the
//! router, health-aware routing, circuit breakers, graceful FIFO
//! degradation — lives in [`crate::fleet`]; the invariant the whole
//! subsystem is pinned on (`tests/fault_recovery.rs`) is
//! **no kernel is ever lost**: every arrival is completed, shed with a
//! cause, or failed with a cause.

use crate::util::SplitMix64;
use std::fmt;

/// Domain-separation constants for the fault PRNG streams (the arrival
/// constants live in `online::arrivals`, the routing one in
/// `fleet::route`).
const LAUNCHFAIL_SEED_XOR: u64 = 0xFA17_0001;
const RETRY_SEED_XOR: u64 = 0xFA17_0002;
const GENERATE_SEED_XOR: u64 = 0xFA17_0003;

/// Odd multiplier for folding a kernel id into a PRNG key (the
/// finalization multiplier from the splitmix64 reference).
const ID_MIX: u64 = 0x2545_F491_4F6C_DD1D;

/// A device going down at a scheduled virtual time, optionally coming
/// back.
#[derive(Debug, Clone, PartialEq)]
pub struct Crash {
    /// Device index in the fleet.
    pub device: usize,
    /// Virtual time (ms) the device goes down.
    pub at_ms: f64,
    /// Virtual time (ms) the device comes back, if it ever does.
    pub recover_at_ms: Option<f64>,
}

/// A device becoming a straggler (or, with `factor < 1`, speeding up)
/// from a scheduled virtual time onward.
#[derive(Debug, Clone, PartialEq)]
pub struct Slowdown {
    /// Device index in the fleet.
    pub device: usize,
    /// Virtual time (ms) the factor takes effect.
    pub at_ms: f64,
    /// Service-time multiplier from `at_ms` on (`2.0` = half speed).
    pub factor: f64,
}

/// Seeded Bernoulli launch-failure process: attempt `a` of kernel `id`
/// fails with probability `p`, decided by a pure function of
/// `(seed, id, a)` so replay does not depend on event interleaving.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaunchFailures {
    /// Per-attempt failure probability in `[0, 1]`.
    pub p: f64,
    /// Stream seed.
    pub seed: u64,
}

impl LaunchFailures {
    /// Whether attempt `attempt` (1-based) of kernel `id` fails.
    pub fn fails(&self, id: u64, attempt: u32) -> bool {
        let key = self.seed
            ^ LAUNCHFAIL_SEED_XOR
            ^ id.wrapping_mul(ID_MIX)
            ^ ((attempt as u64) << 32);
        SplitMix64::new(key).next_f64() < self.p
    }
}

/// What one expanded fault event does to its device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    /// The device goes down (its backlog is orphaned to the router).
    Down,
    /// The device comes back up.
    Recover,
    /// The device's service times are multiplied by the factor.
    Slow(f64),
}

/// One scheduled fault, expanded from a [`FaultPlan`] by
/// [`FaultPlan::timeline`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    /// Virtual time (ms) the event fires.
    pub at_ms: f64,
    /// Device index it applies to.
    pub device: usize,
    /// What happens.
    pub action: FaultAction,
}

/// A replayable schedule of injected faults. Equal plans on equal
/// configurations replay **bit-identically** (`tests/fault_recovery.rs`
/// pins it); an empty plan is a strict no-op — the fault-aware engine
/// produces exactly the fault-free timestamps.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    /// Scheduled device crashes (with optional recovery).
    pub crashes: Vec<Crash>,
    /// Scheduled straggler onsets.
    pub slowdowns: Vec<Slowdown>,
    /// Optional seeded launch-failure process.
    pub launch_failures: Option<LaunchFailures>,
}

impl FaultPlan {
    /// The empty plan: inject nothing.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Whether the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty() && self.slowdowns.is_empty() && self.launch_failures.is_none()
    }

    /// Parse a plan. Accepts the spec-string form (clauses joined with
    /// `;`) and the CSV-ish file form (one clause per line, `#` comments)
    /// interchangeably; see the module docs for the clause table.
    ///
    /// ```
    /// use kreorder::fault::FaultPlan;
    /// let p = FaultPlan::parse("crash:0@50:recover@200; launchfail:0.1:7").unwrap();
    /// assert_eq!(p.crashes.len(), 1);
    /// assert!(FaultPlan::parse("crash:0@oops").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<FaultPlan, FaultParseError> {
        let mut plan = FaultPlan::default();
        for raw in s.split(|c| c == ';' || c == '\n') {
            let clause = raw.trim();
            if clause.is_empty() || clause.starts_with('#') {
                continue;
            }
            plan.push_clause(clause)?;
        }
        Ok(plan)
    }

    fn push_clause(&mut self, clause: &str) -> Result<(), FaultParseError> {
        let err = |reason: &str| FaultParseError {
            input: clause.to_string(),
            reason: reason.to_string(),
        };
        let lower = clause.to_ascii_lowercase();
        let (head, rest) = lower
            .split_once(':')
            .ok_or_else(|| err("missing `:` after the clause kind"))?;
        // `<dev>@<t>` target term shared by crash and slowdown.
        let target = |term: &str| -> Result<(usize, f64), FaultParseError> {
            let (dev, t) = term
                .split_once('@')
                .ok_or_else(|| err("expected `<dev>@<t>`"))?;
            let device: usize = dev
                .trim()
                .parse()
                .map_err(|_| err("device must be a non-negative integer"))?;
            let at_ms: f64 = t
                .trim()
                .parse()
                .map_err(|_| err("time must be a number (virtual ms)"))?;
            if !at_ms.is_finite() || at_ms < 0.0 {
                return Err(err("time must be finite and >= 0"));
            }
            Ok((device, at_ms))
        };
        match head {
            "crash" => {
                let mut parts = rest.splitn(2, ':');
                let (device, at_ms) = target(parts.next().unwrap_or(""))?;
                let recover_at_ms = match parts.next() {
                    None => None,
                    Some(r) => {
                        let t = r
                            .strip_prefix("recover@")
                            .ok_or_else(|| err("expected `recover@<t2>` after the crash time"))?;
                        let t2: f64 = t
                            .trim()
                            .parse()
                            .map_err(|_| err("recovery time must be a number"))?;
                        if !t2.is_finite() || t2 <= at_ms {
                            return Err(err("recovery time must be finite and after the crash"));
                        }
                        Some(t2)
                    }
                };
                self.crashes.push(Crash {
                    device,
                    at_ms,
                    recover_at_ms,
                });
            }
            "slowdown" => {
                let (term, f) = rest
                    .rsplit_once(':')
                    .ok_or_else(|| err("expected `slowdown:<dev>@<t>:<factor>`"))?;
                let (device, at_ms) = target(term)?;
                let factor: f64 = f
                    .trim()
                    .parse()
                    .map_err(|_| err("factor must be a number"))?;
                if !factor.is_finite() || factor <= 0.0 {
                    return Err(err("factor must be finite and > 0"));
                }
                self.slowdowns.push(Slowdown {
                    device,
                    at_ms,
                    factor,
                });
            }
            "launchfail" => {
                if self.launch_failures.is_some() {
                    return Err(err("at most one launchfail clause per plan"));
                }
                let (p_str, seed_str) = rest
                    .split_once(':')
                    .ok_or_else(|| err("expected `launchfail:<p>:<seed>`"))?;
                let p: f64 = p_str
                    .trim()
                    .parse()
                    .map_err(|_| err("probability must be a number"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(err("probability must be in [0, 1]"));
                }
                let seed: u64 = seed_str
                    .trim()
                    .parse()
                    .map_err(|_| err("seed must be a non-negative integer"))?;
                self.launch_failures = Some(LaunchFailures { p, seed });
            }
            _ => return Err(err("unknown clause kind")),
        }
        Ok(())
    }

    /// Generate a plan from a seeded process: `n_faults` events spread
    /// over `[0, horizon_ms)` across `n_devices` devices — crashes
    /// (half of them recovering) and stragglers in roughly equal
    /// measure. Pure function of the arguments, so generated plans are
    /// as replayable as hand-written ones.
    pub fn generate(seed: u64, n_devices: usize, horizon_ms: f64, n_faults: usize) -> FaultPlan {
        let n_devices = n_devices.max(1);
        let horizon = if horizon_ms.is_finite() && horizon_ms > 0.0 {
            horizon_ms
        } else {
            1_000.0
        };
        let mut rng = SplitMix64::new(seed ^ GENERATE_SEED_XOR);
        let mut plan = FaultPlan::default();
        for _ in 0..n_faults {
            let device = rng.below(n_devices);
            let at_ms = rng.range_f64(0.0, horizon * 0.75);
            match rng.below(4) {
                // Crash with recovery after 10–35% of the horizon.
                0 | 1 => {
                    let recover_at_ms = Some(at_ms + rng.range_f64(0.10, 0.35) * horizon);
                    plan.crashes.push(Crash {
                        device,
                        at_ms,
                        recover_at_ms,
                    });
                }
                // Permanent crash.
                2 => plan.crashes.push(Crash {
                    device,
                    at_ms,
                    recover_at_ms: None,
                }),
                // Straggler: 1.5–4× slower.
                _ => plan.slowdowns.push(Slowdown {
                    device,
                    at_ms,
                    factor: rng.range_f64(1.5, 4.0),
                }),
            }
        }
        plan
    }

    /// Check every device index against a fleet of `n_devices`. The
    /// error echoes the *specific offending clause* (not the whole plan)
    /// and names the device index and the fleet size in one sentence, so
    /// a multi-clause plan points straight at the line to fix.
    pub fn validate_for(&self, n_devices: usize) -> Result<(), FaultParseError> {
        let bad = self
            .crashes
            .iter()
            .map(|c| (c.device, crash_clause(c)))
            .chain(self.slowdowns.iter().map(|s| (s.device, slowdown_clause(s))))
            .find(|(d, _)| *d >= n_devices);
        match bad {
            Some((d, clause)) => Err(FaultParseError {
                input: clause,
                reason: format!(
                    "device {d} does not exist in this {n_devices}-device fleet \
                     (valid device indices are 0..{n_devices})"
                ),
            }),
            None => Ok(()),
        }
    }

    /// Canonical spelling; round-trips through [`FaultPlan::parse`].
    pub fn name(&self) -> String {
        let mut clauses: Vec<String> = Vec::new();
        for c in &self.crashes {
            clauses.push(crash_clause(c));
        }
        for s in &self.slowdowns {
            clauses.push(slowdown_clause(s));
        }
        if let Some(lf) = self.launch_failures {
            clauses.push(format!("launchfail:{}:{}", lf.p, lf.seed));
        }
        if clauses.is_empty() {
            "none".to_string()
        } else {
            clauses.join(";")
        }
    }

    /// The CSV-ish file form: a header comment plus one clause per line.
    /// [`FaultPlan::parse`] reads it back.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("# kreorder-faults v1\n");
        if self.is_empty() {
            return out;
        }
        for clause in self.name().split(';') {
            out.push_str(clause);
            out.push('\n');
        }
        out
    }

    /// Expand the plan into a time-sorted event stream for the engine.
    /// Ties break by `(time, device, Down < Recover < Slow)` so the
    /// expansion is deterministic regardless of clause order.
    pub fn timeline(&self) -> Vec<FaultEvent> {
        let mut events: Vec<FaultEvent> = Vec::new();
        for c in &self.crashes {
            events.push(FaultEvent {
                at_ms: c.at_ms,
                device: c.device,
                action: FaultAction::Down,
            });
            if let Some(r) = c.recover_at_ms {
                events.push(FaultEvent {
                    at_ms: r,
                    device: c.device,
                    action: FaultAction::Recover,
                });
            }
        }
        for s in &self.slowdowns {
            events.push(FaultEvent {
                at_ms: s.at_ms,
                device: s.device,
                action: FaultAction::Slow(s.factor),
            });
        }
        let rank = |a: &FaultAction| match a {
            FaultAction::Down => 0u8,
            FaultAction::Recover => 1,
            FaultAction::Slow(_) => 2,
        };
        events.sort_by(|a, b| {
            a.at_ms
                .total_cmp(&b.at_ms)
                .then(a.device.cmp(&b.device))
                .then(rank(&a.action).cmp(&rank(&b.action)))
        });
        events
    }
}

/// Canonical spelling of one crash clause (shared by [`FaultPlan::name`]
/// and the clause-echoing validation errors).
fn crash_clause(c: &Crash) -> String {
    match c.recover_at_ms {
        Some(r) => format!("crash:{}@{}:recover@{}", c.device, c.at_ms, r),
        None => format!("crash:{}@{}", c.device, c.at_ms),
    }
}

/// Canonical spelling of one slowdown clause.
fn slowdown_clause(s: &Slowdown) -> String {
    format!("slowdown:{}@{}:{}", s.device, s.at_ms, s.factor)
}

/// Per-kernel retry with seeded exponential backoff + jitter. Attempt
/// numbers are 1-based; once `max_attempts` launch attempts have failed
/// the kernel is **shed** (counted with a cause), never silently lost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total launch attempts per kernel (including the first). Clamped
    /// to at least 1 by [`RetryPolicy::new`].
    pub max_attempts: u32,
    /// Backoff before the second attempt (doubles each retry).
    pub base_backoff_ms: f64,
    /// Cap on the exponential term.
    pub max_backoff_ms: f64,
    /// Jitter fraction in `[0, 1]`: the backoff is scaled by a seeded
    /// uniform draw from `[1 - jitter/2, 1 + jitter/2]`.
    pub jitter: f64,
    /// Jitter stream seed.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base_backoff_ms: 1.0,
            max_backoff_ms: 64.0,
            jitter: 0.5,
            seed: 0,
        }
    }
}

impl RetryPolicy {
    /// A policy with the default backoff curve and the given cap + seed.
    pub fn new(max_attempts: u32, seed: u64) -> RetryPolicy {
        RetryPolicy {
            max_attempts: max_attempts.max(1),
            seed,
            ..RetryPolicy::default()
        }
    }

    /// Backoff (ms) after failed attempt `attempt` (1-based) of kernel
    /// `id`: `base · 2^(attempt-1)` capped at `max_backoff_ms`, jittered
    /// by a pure function of `(seed, id, attempt)` — deterministic and
    /// interleaving-independent, like [`LaunchFailures::fails`].
    pub fn backoff_ms(&self, id: u64, attempt: u32) -> f64 {
        let exp = self.base_backoff_ms * 2f64.powi(attempt.saturating_sub(1).min(62) as i32);
        let capped = exp.min(self.max_backoff_ms).max(0.0);
        let jitter = self.jitter.clamp(0.0, 1.0);
        if jitter == 0.0 {
            return capped;
        }
        let key = self.seed
            ^ RETRY_SEED_XOR
            ^ id.wrapping_mul(ID_MIX)
            ^ ((attempt as u64) << 32);
        let u = SplitMix64::new(key).next_f64(); // [0, 1)
        capped * (1.0 + jitter * (u - 0.5))
    }
}

/// Fault plan + retry policy, bundled so the fault-aware engine entry
/// point stays within a sane argument count. `Default` is the no-fault
/// configuration.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultConfig {
    /// What to inject.
    pub plan: FaultPlan,
    /// How launch failures are retried.
    pub retry: RetryPolicy,
}

/// Error for malformed fault-plan clauses; `Display` names the clause,
/// the reason, and the valid forms.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultParseError {
    /// The offending clause (or plan, for fleet-validation errors).
    pub input: String,
    /// What was wrong with it.
    pub reason: String,
}

impl fmt::Display for FaultParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "invalid fault plan clause `{}`: {} — valid clauses: crash:<dev>@<t>[:recover@<t2>], \
             slowdown:<dev>@<t>:<factor>, launchfail:<p>:<seed>, joined with `;`",
            self.input, self.reason
        )
    }
}

impl std::error::Error for FaultParseError {}

/// Human-readable table of the fault-plan clauses (one per line).
pub fn fault_plan_help_table() -> String {
    let rows = [
        ("crash:<dev>@<t>", "device <dev> goes down at virtual time <t> ms"),
        ("crash:<dev>@<t>:recover@<t2>", "…and comes back at <t2> ms"),
        ("slowdown:<dev>@<t>:<factor>", "device serves <factor>x slower from <t> ms"),
        ("launchfail:<p>:<seed>", "each launch attempt fails with probability <p>, seeded"),
    ];
    let mut out = String::new();
    for (name, desc) in rows {
        out.push_str(&format!("  {name:<30} {desc}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_strings_parse_and_round_trip() {
        let p = FaultPlan::parse("crash:0@50:recover@200;slowdown:1@10:2.5;launchfail:0.1:7")
            .unwrap();
        assert_eq!(p.crashes.len(), 1);
        assert_eq!(p.crashes[0].device, 0);
        assert_eq!(p.crashes[0].recover_at_ms, Some(200.0));
        assert_eq!(p.slowdowns[0].factor, 2.5);
        assert_eq!(p.launch_failures, Some(LaunchFailures { p: 0.1, seed: 7 }));
        // Canonical name re-parses to the same plan.
        assert_eq!(FaultPlan::parse(&p.name()).unwrap(), p);
        // The CSV form reads back too.
        assert_eq!(FaultPlan::parse(&p.to_csv()).unwrap(), p);
        // Whitespace and case are forgiven; empty clauses skipped.
        let q = FaultPlan::parse(" CRASH:0@50 ; ; Slowdown:1@10:2.5 ").unwrap();
        assert_eq!(q.crashes.len(), 1);
        assert_eq!(q.slowdowns.len(), 1);
    }

    #[test]
    fn empty_and_comment_only_inputs_are_the_empty_plan() {
        for s in ["", "  ", "# kreorder-faults v1\n", ";;"] {
            let p = FaultPlan::parse(s).unwrap();
            assert!(p.is_empty(), "{s:?}");
        }
        assert_eq!(FaultPlan::none().name(), "none");
    }

    #[test]
    fn hostile_clauses_error_with_reasons() {
        for s in [
            "crash",
            "crash:0",
            "crash:0@oops",
            "crash:-1@5",
            "crash:0@-5",
            "crash:0@nan",
            "crash:0@5:recover@4",
            "crash:0@5:later@9",
            "slowdown:0@5",
            "slowdown:0@5:0",
            "slowdown:0@5:-2",
            "slowdown:0@5:inf",
            "launchfail:2:1",
            "launchfail:nan:1",
            "launchfail:0.5:x",
            "launchfail:0.5",
            "blorp:1@2",
            "launchfail:0.1:1;launchfail:0.2:2",
        ] {
            let err = FaultPlan::parse(s).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("valid clauses"), "{s}: {msg}");
        }
    }

    #[test]
    fn timeline_expands_sorted_with_pinned_tie_breaks() {
        let p = FaultPlan::parse("slowdown:1@50:2;crash:0@50;crash:1@10:recover@60").unwrap();
        let t = p.timeline();
        let kinds: Vec<(f64, usize)> = t.iter().map(|e| (e.at_ms, e.device)).collect();
        assert_eq!(kinds, vec![(10.0, 1), (50.0, 0), (50.0, 1), (60.0, 1)]);
        assert_eq!(t[1].action, FaultAction::Down);
        assert_eq!(t[2].action, FaultAction::Slow(2.0));
        assert_eq!(t[3].action, FaultAction::Recover);
    }

    #[test]
    fn launch_failures_are_pure_functions_of_seed_id_attempt() {
        let lf = LaunchFailures { p: 0.5, seed: 9 };
        for id in 0..64u64 {
            for attempt in 1..4u32 {
                assert_eq!(lf.fails(id, attempt), lf.fails(id, attempt));
            }
        }
        let hits = (0..10_000u64).filter(|&id| lf.fails(id, 1)).count();
        assert!((4_000..6_000).contains(&hits), "p=0.5 hit {hits}/10000");
        assert!((0..10_000u64).all(|id| !LaunchFailures { p: 0.0, seed: 9 }.fails(id, 1)));
        assert!((0..10_000u64).all(|id| LaunchFailures { p: 1.0, seed: 9 }.fails(id, 1)));
    }

    #[test]
    fn retry_backoff_grows_caps_and_jitters_deterministically() {
        let r = RetryPolicy {
            jitter: 0.0,
            ..RetryPolicy::new(8, 3)
        };
        assert_eq!(r.backoff_ms(5, 1), 1.0);
        assert_eq!(r.backoff_ms(5, 2), 2.0);
        assert_eq!(r.backoff_ms(5, 3), 4.0);
        assert_eq!(r.backoff_ms(5, 20), 64.0); // capped
        let j = RetryPolicy::new(8, 3);
        let b = j.backoff_ms(5, 2);
        assert_eq!(b, j.backoff_ms(5, 2), "jitter must replay");
        assert!((1.5..=2.5).contains(&b), "jittered 2ms backoff was {b}");
        assert_ne!(j.backoff_ms(5, 2), j.backoff_ms(6, 2), "per-kernel jitter");
        assert!(RetryPolicy::new(0, 0).max_attempts >= 1);
    }

    #[test]
    fn generated_plans_are_deterministic_and_in_range() {
        let a = FaultPlan::generate(11, 4, 1_000.0, 12);
        let b = FaultPlan::generate(11, 4, 1_000.0, 12);
        assert_eq!(a, b);
        assert_ne!(a, FaultPlan::generate(12, 4, 1_000.0, 12));
        assert_eq!(a.crashes.len() + a.slowdowns.len(), 12);
        assert!(a.validate_for(4).is_ok());
        for c in &a.crashes {
            assert!(c.device < 4 && c.at_ms >= 0.0 && c.at_ms < 1_000.0);
            if let Some(r) = c.recover_at_ms {
                assert!(r > c.at_ms);
            }
        }
        for s in &a.slowdowns {
            assert!(s.device < 4 && (1.5..=4.0).contains(&s.factor));
        }
    }

    #[test]
    fn validate_for_rejects_out_of_range_devices() {
        let p = FaultPlan::parse("crash:3@10").unwrap();
        assert!(p.validate_for(4).is_ok());
        let err = p.validate_for(2).unwrap_err();
        assert!(err.to_string().contains("device 3"), "{err}");
        assert!(err.to_string().contains("2-device"), "{err}");
        assert!(err.to_string().contains("`crash:3@10`"), "{err}");
        // A multi-clause plan echoes only the offending clause.
        let p = FaultPlan::parse("crash:0@5;slowdown:6@10:2;launchfail:0.1:1").unwrap();
        let err = p.validate_for(4).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("`slowdown:6@10:2`"), "{msg}");
        assert!(!msg.contains("crash:0@5"), "{msg}");
        assert!(msg.contains("device 6"), "{msg}");
        assert!(msg.contains("4-device"), "{msg}");
        assert!(msg.contains("0..4"), "{msg}");
    }

    #[test]
    fn help_table_covers_the_clauses() {
        let t = fault_plan_help_table();
        for name in ["crash:<dev>@<t>", "slowdown:<dev>@<t>:<factor>", "launchfail:<p>:<seed>"] {
            assert!(t.contains(name), "{t}");
        }
    }
}
