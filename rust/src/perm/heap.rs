//! Heap's algorithm: iterate all permutations of a slice in place, one swap
//! per step (the fastest way to enumerate a permutation space when each
//! visit costs the same). The flat sweep modes use it; the checkpointed
//! sweep instead walks a lexicographic prefix tree (see `perm`), because
//! swap-minimal enumeration destroys the long shared prefixes that
//! checkpoint reuse depends on.

/// Call `f` with every permutation of `xs`. `xs` is permuted in place and
/// restored only up to permutation (its final state is some permutation of
/// the input). The first call sees `xs` unchanged.
pub fn for_each_permutation<T, F: FnMut(&[T])>(xs: &mut [T], f: &mut F) {
    let n = xs.len();
    if n == 0 {
        return;
    }
    // Non-recursive Heap's algorithm.
    let mut c = vec![0usize; n];
    f(xs);
    let mut i = 0;
    while i < n {
        if c[i] < i {
            if i % 2 == 0 {
                xs.swap(0, i);
            } else {
                xs.swap(c[i], i);
            }
            f(xs);
            c[i] += 1;
            i = 0;
        } else {
            c[i] = 0;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn factorial(n: usize) -> usize {
        (1..=n).product::<usize>().max(1)
    }

    #[test]
    fn visits_exactly_n_factorial_distinct_permutations() {
        for n in 0..=6 {
            let mut xs: Vec<usize> = (0..n).collect();
            let mut seen: HashSet<Vec<usize>> = HashSet::new();
            let mut count = 0usize;
            for_each_permutation(&mut xs, &mut |p| {
                seen.insert(p.to_vec());
                count += 1;
            });
            let want = if n == 0 { 0 } else { factorial(n) };
            assert_eq!(count, want, "n={n}");
            assert_eq!(seen.len(), want, "n={n} distinct");
        }
    }

    #[test]
    fn first_call_is_input_order() {
        let mut xs = vec![3, 1, 4, 1, 5];
        let mut first: Option<Vec<i32>> = None;
        for_each_permutation(&mut xs, &mut |p| {
            if first.is_none() {
                first = Some(p.to_vec());
            }
        });
        assert_eq!(first.unwrap(), vec![3, 1, 4, 1, 5]);
    }

    #[test]
    fn each_step_is_a_permutation_of_input() {
        let mut xs = vec![10, 20, 30, 40];
        for_each_permutation(&mut xs, &mut |p| {
            let mut s = p.to_vec();
            s.sort_unstable();
            assert_eq!(s, vec![10, 20, 30, 40]);
        });
    }
}
