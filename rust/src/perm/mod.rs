//! Permutation-space evaluation — the paper's methodology: "our experiments
//! evaluate the concurrent execution time of all possible kernel orderings
//! (all permutations) and compare the performance of the kernel ordering
//! given by the algorithm with the optimal (best) result."
//!
//! # Architecture: prepared workloads + prefix checkpointing
//!
//! [`sweep`] evaluates every permutation of the launch order and returns
//! the full time distribution plus best/worst orders, from which
//! [`SweepResult::percentile_rank`], speedup-over-worst, and
//! deviation-from-optimal (the Table 3 columns) are computed. The hot
//! path is built on two seams:
//!
//! * **Prepared workloads** — each worker calls
//!   [`crate::exec::ExecutionBackend::prepare`] once, hoisting kernel
//!   constants, the jittered block-work table and all scratch buffers out
//!   of the per-permutation loop; evaluating one order then performs no
//!   heap allocation after warm-up (`tests/zero_alloc.rs`).
//! * **Prefix checkpointing** — when the prepared handle supports it
//!   (both model backends do), suffixes are enumerated as a lexicographic
//!   prefix tree instead of raw Heap's: the backend state at the moment a
//!   shared prefix's last block is dispatched is snapshotted once and
//!   restored per sibling suffix instead of re-simulated. Results are
//!   **bit-identical** to the flat path (`tests/sweep_equivalence.rs`).
//!
//! Work is spread across threads over the `n·(n-1)` choices of the first
//! two positions through the work-stealing [`parallel_map`].
//!
//! # Sweeping large n: memory
//!
//! [`SweepResult`] keeps every permutation's makespan: `n! × 8` bytes —
//! 290 KB at n=8, ~29 MB at n=10, ~320 MB at n=11, ~3.8 GB at n=12. For
//! n ≥ 11 use [`sweep_stats`] instead: [`SweepStats`] folds each makespan
//! into online best/worst/count/sum plus a fixed-resolution histogram
//! (`n_bins × 8` bytes, default 4096), so percentile ranks stay available
//! at histogram resolution while memory stays constant in `n`.
//!
//! Workloads with repeated kernels (real app streams submit many
//! instances of one profiled kernel) additionally admit
//! [`sweep_stats_sym`]: within-class reorderings of
//! [`crate::gpu::KernelProfile::model_identical`] kernels are
//! bit-identical ties, so only one canonical order per orbit is
//! evaluated and folded in with its orbit's multiplicity — `n!/∏ m_c!`
//! evaluations for the same reported distribution.
//!
//! # Dependency-constrained sweeps
//!
//! Workloads with precedence edges ([`crate::workloads::Workload`])
//! admit only **topological orders** of their
//! [`crate::workloads::DepGraph`]. [`sweep_dag_with`] /
//! [`sweep_stats_dag_with`] enumerate exactly that constrained space:
//! the same lexicographic prefix tree, but a node expands kernel `k`
//! only when [`crate::workloads::DepGraph::is_free`] says every
//! predecessor is already placed — an infeasible prefix prunes its
//! entire subtree for free. Results are bit-identical to filtering the
//! naive full sweep down to topological orders (pinned in tests),
//! `n_perms` equals the graph's linear-extension count, and a graph
//! with no edges delegates to the unconstrained hot path so
//! independent workloads are bit-identical to the pre-DAG sweep.

mod heap;

pub use heap::for_each_permutation;

use crate::exec::{ExecutionBackend, PreparedWorkload, SimulatorBackend};
use crate::gpu::{GpuSpec, KernelProfile};
use crate::util::{default_threads, parallel_map};
use crate::workloads::DepGraph;
use std::sync::OnceLock;

/// Distribution of simulated makespans across all launch-order
/// permutations of one workload.
#[derive(Debug, Clone)]
pub struct SweepResult {
    /// Number of permutations evaluated (`n!`).
    pub n_perms: usize,
    /// Best (minimum) makespan and the order achieving it (ties broken
    /// toward the lexicographically smallest order, so the result is
    /// independent of enumeration strategy).
    pub best_ms: f64,
    pub best_order: Vec<usize>,
    /// Worst (maximum) makespan and the order achieving it (same
    /// tie-break).
    pub worst_ms: f64,
    pub worst_order: Vec<usize>,
    /// Every permutation's makespan (unsorted; ~n! entries — see the
    /// module docs for the memory formula and [`SweepStats`] for the
    /// constant-memory alternative).
    ///
    /// Treat as read-only: the percentile/median/sorted queries serve
    /// from a sorted copy cached on first use, so mutating `times` after
    /// any query silently yields stale answers.
    pub times: Vec<f64>,
    /// Lazily computed sorted copy of `times` (total_cmp order, NaNs
    /// last), shared by the percentile/median queries.
    sorted_cache: OnceLock<Vec<f64>>,
}

impl SweepResult {
    fn empty() -> Self {
        SweepResult {
            n_perms: 0,
            best_ms: f64::INFINITY,
            best_order: Vec::new(),
            worst_ms: f64::NEG_INFINITY,
            worst_order: Vec::new(),
            times: Vec::new(),
            sorted_cache: OnceLock::new(),
        }
    }

    /// Sorted view of the distribution, computed once on first use and
    /// cached (the distribution has `n!` entries; re-sorting per query
    /// made every percentile call O(n! log n!)).
    fn sorted(&self) -> &[f64] {
        self.sorted_cache.get_or_init(|| {
            let mut ts = self.times.clone();
            ts.sort_unstable_by(f64::total_cmp);
            ts
        })
    }

    /// The sorted slice with trailing NaNs (unsimulable entries) dropped.
    fn sorted_finite(&self) -> &[f64] {
        let s = self.sorted();
        let end = s.iter().rposition(|x| !x.is_nan()).map_or(0, |i| i + 1);
        &s[..end]
    }

    /// The paper's *percentile rank* of a candidate time within the
    /// permutation space: the percentage of permutations the candidate is
    /// at least as good as, with ties counted half (mid-rank). Higher is
    /// better; the paper reports 91.5–99.4% for Algorithm 1.
    ///
    /// O(log n!) per query via binary search on the cached sorted copy.
    pub fn percentile_rank(&self, t_ms: f64) -> f64 {
        // NaN candidate (unsimulable run): beats nothing, ties nothing —
        // matches the original linear scan, where every comparison with
        // NaN is false.
        if self.times.is_empty() || t_ms.is_nan() {
            return 0.0;
        }
        let eps = 1e-9 * t_ms.abs().max(1e-300);
        let s = self.sorted_finite();
        // `worse` = entries strictly above t+eps; `equal` = within ±eps.
        let le_hi = s.partition_point(|&x| x <= t_ms + eps);
        let lt_lo = s.partition_point(|&x| x < t_ms - eps);
        let worse = s.len() - le_hi;
        let equal = le_hi - lt_lo;
        (worse as f64 + 0.5 * equal as f64) / self.times.len() as f64 * 100.0
    }

    /// Median makespan of the permutation space (the paper's "random
    /// order choice" reference point).
    pub fn median_ms(&self) -> f64 {
        let ts = self.sorted_finite();
        let n = ts.len();
        if n == 0 {
            return 0.0;
        }
        if n % 2 == 1 {
            ts[n / 2]
        } else {
            0.5 * (ts[n / 2 - 1] + ts[n / 2])
        }
    }

    /// Sorted copy of the distribution (ascending), for ranking plots.
    /// Cached; cheap to call repeatedly.
    pub fn sorted_times(&self) -> &[f64] {
        self.sorted()
    }
}

/// How [`sweep_with_mode`] evaluates each permutation. The three modes
/// produce bit-identical [`SweepResult`]s; they differ only in speed
/// (`benches/sweep_throughput.rs` tracks the ratios).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepMode {
    /// One [`ExecutionBackend::execute`] round-trip per permutation — the
    /// pre-seam baseline, kept as the golden reference.
    NaiveExecute,
    /// One [`PreparedWorkload::execute_order`] per permutation: setup
    /// hoisted, no checkpoint sharing.
    PreparedFlat,
    /// Lexicographic prefix-tree enumeration with checkpoint restore
    /// where the backend supports it (falls back to [`SweepMode::PreparedFlat`]
    /// where it does not). The default.
    Checkpointed,
}

/// Exhaustively simulate all `n!` launch orders of `kernels` on the fluid
/// simulator (the paper's methodology). See [`sweep_with`] for other
/// execution backends.
pub fn sweep(gpu: &GpuSpec, kernels: &[KernelProfile]) -> SweepResult {
    sweep_with(gpu, kernels, &|| Box::new(SimulatorBackend::new()))
}

/// Exhaustively evaluate all `n!` launch orders of `kernels` on an
/// [`ExecutionBackend`] built by `make_backend` (backends are not
/// required to be `Sync`), using the prepared + checkpointed hot path.
///
/// Parallelized over the choice of the first two positions (`n·(n-1)`
/// prefixes, work-stolen by [`parallel_map`]); `make_backend` is invoked
/// once per *prefix* — `n·(n-1)` times, not once per permutation — and
/// each worker prepares the workload once. n ≤ 10 or so is practical with
/// the full `times` vector (the paper's largest space is 8! = 40 320);
/// use [`sweep_stats_with`] beyond that.
pub fn sweep_with(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
) -> SweepResult {
    sweep_with_mode(gpu, kernels, make_backend, SweepMode::Checkpointed)
}

/// The golden-reference sweep: per-permutation `execute` calls, no
/// prepared state, no checkpoints (today's behaviour before the seam).
/// Exists so the equivalence suite can prove the fast paths exact.
pub fn sweep_flat_with(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
) -> SweepResult {
    sweep_with_mode(gpu, kernels, make_backend, SweepMode::NaiveExecute)
}

/// [`sweep_with`] with an explicit [`SweepMode`] (bench ablation knob).
pub fn sweep_with_mode(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
    mode: SweepMode,
) -> SweepResult {
    let n = kernels.len();
    assert!(n >= 1, "empty workload");
    let prefixes = position_prefixes(n);

    let partials: Vec<Partial> = parallel_map(prefixes.len(), default_threads(), |pi| {
        let mut backend = make_backend();
        let mut p = Partial::new();
        enumerate_task(
            gpu,
            kernels,
            backend.as_mut(),
            &prefixes[pi],
            mode,
            &mut |t, order| p.record(t, order),
        );
        p
    });

    merge_partials(partials)
}

/// Fold per-worker [`Partial`] accumulators into one [`SweepResult`],
/// applying the lexicographic tie-break across workers.
fn merge_partials(partials: Vec<Partial>) -> SweepResult {
    let mut result = SweepResult::empty();
    for p in partials {
        result.n_perms += p.times.len();
        if p.best_ms < result.best_ms
            || (p.best_ms == result.best_ms && p.best_order < result.best_order)
        {
            result.best_ms = p.best_ms;
            result.best_order = p.best_order;
        }
        if p.worst_ms > result.worst_ms
            || (p.worst_ms == result.worst_ms && p.worst_order < result.worst_order)
        {
            result.worst_ms = p.worst_ms;
            result.worst_order = p.worst_order;
        }
        result.times.extend(p.times);
    }
    result
}

// ---------------------------------------------------------------------------
// Streaming statistics mode
// ---------------------------------------------------------------------------

/// Online sweep statistics: exact best/worst (with orders), count, sum,
/// and a fixed-resolution histogram for percentile ranks — constant
/// memory in `n`, so n = 11–12 sweeps fit where the `times` vector of a
/// [`SweepResult`] would not (module docs have the formula).
///
/// # Accuracy: what is exact and what is approximate
///
/// `best_ms` / `worst_ms` / `best_order` / `worst_order` / `n_perms` /
/// `sum_ms` are **exact** — bit-identical to the full-distribution
/// [`SweepResult`], because they are folded online, not read back from
/// the histogram. Everything that *is* answered from the histogram is
/// approximate at its fixed resolution, with pinned error bounds
/// (`perm::tests` asserts both):
///
/// * [`SweepStats::percentile_rank`] errs by at most half the candidate
///   bin's mass, as a fraction of `n_perms` — i.e.
///   `50 · bin_mass(t) / n_perms` percentage points
///   ([`SweepStats::bin_mass`] exposes the bound).
/// * [`SweepStats::quantile_ms`] returns the center of the bin holding
///   the requested order statistic, so it errs by at most half a
///   [`SweepStats::bin_width`] while the statistic lies inside the
///   histogram range; makespans outside `[lo, hi)` clamp into the edge
///   bins and only then is the error unbounded.
#[derive(Debug, Clone)]
pub struct SweepStats {
    /// Number of permutations recorded.
    pub n_perms: usize,
    /// Exact minimum makespan and its order (lexicographic tie-break,
    /// identical to [`SweepResult`]).
    pub best_ms: f64,
    pub best_order: Vec<usize>,
    /// Exact maximum makespan and its order.
    pub worst_ms: f64,
    pub worst_order: Vec<usize>,
    /// Sum of all finite makespans (for [`SweepStats::mean_ms`]).
    pub sum_ms: f64,
    lo: f64,
    bin_width: f64,
    bins: Vec<u64>,
}

impl SweepStats {
    /// Histogram over `[lo, hi)` with `n_bins` equal bins; out-of-range
    /// makespans clamp into the edge bins (best/worst stay exact).
    pub fn new(lo: f64, hi: f64, n_bins: usize) -> Self {
        let n_bins = n_bins.max(1);
        SweepStats {
            n_perms: 0,
            best_ms: f64::INFINITY,
            best_order: Vec::new(),
            worst_ms: f64::NEG_INFINITY,
            worst_order: Vec::new(),
            sum_ms: 0.0,
            lo,
            bin_width: (hi - lo).max(f64::MIN_POSITIVE) / n_bins as f64,
            bins: vec![0; n_bins],
        }
    }

    fn bin_index(&self, t_ms: f64) -> usize {
        let raw = (t_ms - self.lo) / self.bin_width;
        if raw <= 0.0 {
            0
        } else {
            (raw as usize).min(self.bins.len() - 1)
        }
    }

    /// Fold one permutation's makespan in. Allocation-free after the
    /// first best/worst updates (orders are copied into reused buffers).
    pub fn record(&mut self, t_ms: f64, order: &[usize]) {
        self.record_weighted(t_ms, order, 1);
    }

    /// Fold one makespan in with multiplicity `weight` — `order` stands
    /// for `weight` distinct permutations sharing this exact makespan.
    /// Used by the symmetry-collapsed sweep ([`sweep_stats_sym_with`]),
    /// where each canonical order represents its whole orbit of
    /// within-class reorderings. Best/worst track `order` itself (the
    /// orbit's lexicographic minimum under canonical enumeration).
    pub fn record_weighted(&mut self, t_ms: f64, order: &[usize], weight: u64) {
        self.n_perms += weight as usize;
        if t_ms.is_nan() {
            return;
        }
        if t_ms < self.best_ms || (t_ms == self.best_ms && order < &self.best_order[..]) {
            self.best_ms = t_ms;
            self.best_order.clear();
            self.best_order.extend_from_slice(order);
        }
        if t_ms > self.worst_ms || (t_ms == self.worst_ms && order < &self.worst_order[..]) {
            self.worst_ms = t_ms;
            self.worst_order.clear();
            self.worst_order.extend_from_slice(order);
        }
        self.sum_ms += t_ms * weight as f64;
        let i = self.bin_index(t_ms);
        self.bins[i] += weight;
    }

    /// Merge another worker's statistics (same histogram configuration).
    pub fn merge(&mut self, o: &SweepStats) {
        assert!(
            self.bins.len() == o.bins.len()
                && self.lo.to_bits() == o.lo.to_bits()
                && self.bin_width.to_bits() == o.bin_width.to_bits(),
            "histogram configs differ"
        );
        self.n_perms += o.n_perms;
        self.sum_ms += o.sum_ms;
        if o.best_ms < self.best_ms
            || (o.best_ms == self.best_ms && o.best_order < self.best_order)
        {
            self.best_ms = o.best_ms;
            self.best_order.clear();
            self.best_order.extend_from_slice(&o.best_order);
        }
        if o.worst_ms > self.worst_ms
            || (o.worst_ms == self.worst_ms && o.worst_order < self.worst_order)
        {
            self.worst_ms = o.worst_ms;
            self.worst_order.clear();
            self.worst_order.extend_from_slice(&o.worst_order);
        }
        for (a, b) in self.bins.iter_mut().zip(&o.bins) {
            *a += b;
        }
    }

    /// Mean makespan over the recorded (finite) permutations.
    pub fn mean_ms(&self) -> f64 {
        let finite: u64 = self.bins.iter().sum();
        if finite == 0 {
            return f64::NAN;
        }
        self.sum_ms / finite as f64
    }

    /// Mid-rank percentile of a candidate time, at histogram resolution:
    /// mass strictly above the candidate's bin counts as worse, the
    /// candidate's own bin counts half. Agrees with
    /// [`SweepResult::percentile_rank`] to within half the candidate
    /// bin's mass (see [`SweepStats::bin_mass`]).
    pub fn percentile_rank(&self, t_ms: f64) -> f64 {
        // NaN candidate: beats nothing, ties nothing (same guard as
        // [`SweepResult::percentile_rank`] — without it, `NaN as usize`
        // saturates to bin 0 and the rank reads ~100%).
        if self.n_perms == 0 || t_ms.is_nan() {
            return 0.0;
        }
        let i = self.bin_index(t_ms);
        let worse: u64 = self.bins[i + 1..].iter().sum();
        let equal = self.bins[i];
        (worse as f64 + 0.5 * equal as f64) / self.n_perms as f64 * 100.0
    }

    /// Number of recorded makespans sharing the candidate's bin — the
    /// resolution bound on [`SweepStats::percentile_rank`].
    pub fn bin_mass(&self, t_ms: f64) -> u64 {
        self.bins[self.bin_index(t_ms)]
    }

    /// Approximate quantile (`q` in [0,1]) from the histogram: the center
    /// of the bin where the cumulative count crosses `q · n`.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let finite: u64 = self.bins.iter().sum();
        if finite == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * finite as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            cum += c;
            if cum >= target {
                return self.lo + (i as f64 + 0.5) * self.bin_width;
            }
        }
        self.lo + self.bins.len() as f64 * self.bin_width
    }

    /// Number of histogram bins.
    pub fn n_bins(&self) -> usize {
        self.bins.len()
    }

    /// Width of one histogram bin in ms — the resolution of
    /// [`SweepStats::quantile_ms`] (error ≤ half of this while the
    /// statistic lies inside the histogram range).
    pub fn bin_width(&self) -> f64 {
        self.bin_width
    }
}

/// Streaming-statistics sweep on the fluid simulator with the default
/// 4096-bin histogram. See [`sweep_stats_with`].
pub fn sweep_stats(gpu: &GpuSpec, kernels: &[KernelProfile]) -> SweepStats {
    sweep_stats_with(gpu, kernels, &|| Box::new(SimulatorBackend::new()), 4096)
}

/// Exhaustive sweep in streaming-statistics mode: every permutation is
/// evaluated on the checkpointed hot path but folded into a [`SweepStats`]
/// instead of an `n!`-entry vector, so memory is constant in `n`.
///
/// Best/worst makespans and orders are exact and bit-identical to
/// [`sweep_with`]; percentile ranks are histogram-resolution
/// approximations. The histogram spans `[r/4, 4r)` where `r` is the
/// identity order's makespan (permutation makespans cluster within a
/// small factor of any fixed order; outliers clamp to the edge bins).
pub fn sweep_stats_with(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
    n_bins: usize,
) -> SweepStats {
    let n = kernels.len();
    assert!(n >= 1, "empty workload");

    // Range reference: one evaluation of the identity order.
    let identity: Vec<usize> = (0..n).collect();
    let mut b0 = make_backend();
    let reference = b0.prepare(gpu, kernels).execute_order(&identity);
    let (lo, hi) = if reference.is_finite() && reference > 0.0 {
        (reference / 4.0, reference * 4.0)
    } else {
        (0.0, 1.0)
    };

    let prefixes = position_prefixes(n);
    let partials: Vec<SweepStats> = parallel_map(prefixes.len(), default_threads(), |pi| {
        let mut backend = make_backend();
        let mut stats = SweepStats::new(lo, hi, n_bins);
        enumerate_task(
            gpu,
            kernels,
            backend.as_mut(),
            &prefixes[pi],
            SweepMode::Checkpointed,
            &mut |t, order| stats.record(t, order),
        );
        stats
    });

    let mut result = SweepStats::new(lo, hi, n_bins);
    for p in &partials {
        result.merge(p);
    }
    result
}

// ---------------------------------------------------------------------------
// Identical-kernel symmetry collapse
// ---------------------------------------------------------------------------

/// Is every element of `prefix` the smallest not-yet-used member of its
/// equivalence class — equivalently, do class members appear in
/// ascending index order? Exactly one order per orbit of within-class
/// reorderings is canonical, and it is the orbit's lexicographic
/// minimum. Works on full orders too. Shared with the branch-and-bound
/// solver's task split ([`crate::search`]).
pub(crate) fn canonical_prefix(prefix: &[usize], class_of: &[usize]) -> bool {
    for (pos, &k) in prefix.iter().enumerate() {
        if (0..k).any(|j| class_of[j] == class_of[k] && !prefix[..pos].contains(&j)) {
            return false;
        }
    }
    true
}

/// The per-node expansion rule of the canonical enumerations, shared by
/// [`sweep_stats_sym_with`]'s DFS and the branch-and-bound solver
/// ([`crate::search`]): `k` must be skipped when a smaller unused index
/// shares its equivalence class — expanding only one representative per
/// class per node yields exactly the canonical orders
/// ([`canonical_prefix`]) and hence one lexicographic-minimum member of
/// every orbit. Keeping this rule in one place is what pins bnb and the
/// collapsed sweep to the same canonical set.
#[inline]
pub(crate) fn class_blocked(k: usize, used: &[bool], class_of: &[usize]) -> bool {
    (0..k).any(|j| !used[j] && class_of[j] == class_of[k])
}

/// Streaming sweep on the fluid simulator with the identical-kernel
/// **symmetry collapse** and the default 4096-bin histogram. See
/// [`sweep_stats_sym_with`].
pub fn sweep_stats_sym(gpu: &GpuSpec, kernels: &[KernelProfile]) -> SweepStats {
    sweep_stats_sym_with(gpu, kernels, &|| Box::new(SimulatorBackend::new()), 4096)
}

/// [`sweep_stats_with`] with **identical-kernel symmetry collapse**: only
/// canonical orders (class members of
/// [`crate::gpu::equivalence_classes`] in ascending index order) are
/// evaluated, each folded in with multiplicity `∏ m_c!` — the size of
/// its orbit of within-class reorderings, every member of which has a
/// bit-identical makespan ([`crate::gpu::KernelProfile::model_identical`]
/// documents why). On a workload with `m` copies of one kernel this
/// evaluates `n!/m!` orders instead of `n!` while reporting the same
/// `n_perms`, bit-identical best/worst makespans *and* orders
/// (canonical orders include every orbit's lexicographic minimum, which
/// is what the plain sweep's tie-break selects), an identical histogram,
/// and a mean equal up to float summation order. Workloads with no
/// duplicated kernels take the plain [`sweep_stats_with`] path
/// unchanged.
///
/// Opt-in rather than the default because the multiplicity argument
/// assumes the backend times kernels solely from their profile fields —
/// true for both model backends, not necessarily for exotic substrates.
pub fn sweep_stats_sym_with(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
    n_bins: usize,
) -> SweepStats {
    let n = kernels.len();
    assert!(n >= 1, "empty workload");
    let class_of = crate::gpu::equivalence_classes(kernels);
    let mut class_sizes = vec![0u64; n];
    for &c in &class_of {
        class_sizes[c] += 1;
    }
    // Orbit size of every canonical order: ∏ m_c! over the class sizes.
    // n ≤ 20 in any sweepable setting, so this cannot overflow u64.
    let weight: u64 = class_sizes
        .iter()
        .filter(|&&m| m > 1)
        .map(|&m| (2..=m).product::<u64>())
        .product();
    if weight == 1 {
        // No duplicated kernels: nothing to collapse.
        return sweep_stats_with(gpu, kernels, make_backend, n_bins);
    }

    // Same histogram range reference as the plain streaming sweep, so
    // the two modes' histograms are directly comparable.
    let identity: Vec<usize> = (0..n).collect();
    let mut b0 = make_backend();
    let reference = b0.prepare(gpu, kernels).execute_order(&identity);
    let (lo, hi) = if reference.is_finite() && reference > 0.0 {
        (reference / 4.0, reference * 4.0)
    } else {
        (0.0, 1.0)
    };

    let mut prefixes = position_prefixes(n);
    prefixes.retain(|p| canonical_prefix(p, &class_of));
    let partials: Vec<SweepStats> = parallel_map(prefixes.len(), default_threads(), |pi| {
        let mut backend = make_backend();
        let mut stats = SweepStats::new(lo, hi, n_bins);
        sym_enumerate_task(
            gpu,
            kernels,
            backend.as_mut(),
            &prefixes[pi],
            &class_of,
            &mut |t, order| stats.record_weighted(t, order, weight),
        );
        stats
    });

    let mut result = SweepStats::new(lo, hi, n_bins);
    for p in &partials {
        result.merge(p);
    }
    result
}

/// Evaluate every **canonical** permutation starting with `prefix`
/// (itself canonical), feeding `(makespan, order)` pairs to `rec` —
/// the symmetry-collapsed sibling of [`enumerate_task`]. Uses the
/// checkpointed prefix tree when the backend supports it, filtered flat
/// enumeration otherwise.
fn sym_enumerate_task(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    backend: &mut dyn ExecutionBackend,
    prefix: &[usize],
    class_of: &[usize],
    rec: &mut dyn FnMut(f64, &[usize]),
) {
    let n = kernels.len();
    let mut order: Vec<usize> = Vec::with_capacity(n);
    order.extend_from_slice(prefix);

    let mut prepared = backend.prepare(gpu, kernels);
    if prepared.supports_checkpoints() {
        for &k in prefix {
            prepared.checkpoint_push(k);
        }
        let mut used = vec![false; n];
        for &k in prefix {
            used[k] = true;
        }
        sym_checkpointed_dfs(prepared.as_mut(), &mut used, &mut order, n, class_of, rec);
        for _ in prefix {
            prepared.checkpoint_pop();
        }
    } else {
        let mut rest: Vec<usize> = (0..n).filter(|i| !prefix.contains(i)).collect();
        if rest.is_empty() {
            let t = prepared.execute_order(&order);
            rec(t, &order);
            return;
        }
        let plen = prefix.len();
        for_each_permutation(&mut rest, &mut |suffix| {
            order.truncate(plen);
            order.extend_from_slice(suffix);
            if canonical_prefix(&order, class_of) {
                let t = prepared.execute_order(&order);
                rec(t, &order);
            }
        });
    }
}

/// [`checkpointed_dfs`] restricted to canonical orders: each node
/// expands only the smallest unused index of every equivalence class,
/// and a model-identical final pair is completed in ascending order
/// only.
fn sym_checkpointed_dfs(
    prepared: &mut dyn PreparedWorkload,
    used: &mut [bool],
    order: &mut Vec<usize>,
    n: usize,
    class_of: &[usize],
    rec: &mut dyn FnMut(f64, &[usize]),
) {
    match n - order.len() {
        0 => {
            let t = prepared.execute_suffix(&[]);
            rec(t, order);
        }
        1 => {
            let k = used.iter().position(|u| !u).expect("one kernel left");
            order.push(k);
            let t = prepared.execute_suffix(&order[n - 1..]);
            rec(t, order);
            order.pop();
        }
        2 => {
            let a = used.iter().position(|u| !u).expect("two kernels left");
            let b = used[a + 1..]
                .iter()
                .position(|u| !u)
                .map(|i| a + 1 + i)
                .expect("two kernels left");
            for (x, y) in [(a, b), (b, a)] {
                if x == b && class_of[a] == class_of[b] {
                    continue; // out-of-order twin of (a, b)
                }
                order.push(x);
                order.push(y);
                let t = prepared.execute_suffix(&order[n - 2..]);
                rec(t, order);
                order.pop();
                order.pop();
            }
        }
        _ => {
            for k in 0..n {
                if used[k] || class_blocked(k, used, class_of) {
                    continue;
                }
                used[k] = true;
                order.push(k);
                prepared.checkpoint_push(k);
                sym_checkpointed_dfs(prepared, used, order, n, class_of, rec);
                prepared.checkpoint_pop();
                order.pop();
                used[k] = false;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Dependency-constrained sweeps (DAG workloads)
// ---------------------------------------------------------------------------

/// Exhaustively evaluate every **topological order** of `kernels` under
/// `graph` on the fluid simulator. See [`sweep_dag_with`].
pub fn sweep_dag(gpu: &GpuSpec, kernels: &[KernelProfile], graph: &DepGraph) -> SweepResult {
    sweep_dag_with(gpu, kernels, graph, &|| Box::new(SimulatorBackend::new()))
}

/// [`sweep_with`] restricted to the topological orders of `graph`: the
/// same prepared + checkpointed lexicographic prefix tree, but a node
/// expands kernel `k` only when every predecessor is already placed
/// ([`DepGraph::is_free`]), so infeasible prefixes prune their whole
/// subtree. `n_perms` equals [`DepGraph::linear_extension_count`];
/// best/worst use the same lexicographic tie-break as the plain sweep,
/// so the result is bit-identical to filtering the naive full sweep
/// down to topological orders (pinned in tests). A graph with no edges
/// delegates to [`sweep_with`] unchanged.
pub fn sweep_dag_with(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    graph: &DepGraph,
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
) -> SweepResult {
    let n = kernels.len();
    assert!(n >= 1, "empty workload");
    assert_eq!(graph.n(), n, "dependency graph sized for a different workload");
    if !graph.has_deps() {
        // No edges: the constrained space is all n! orders — take the
        // unconstrained hot path, bit-identical to the pre-DAG sweep.
        return sweep_with(gpu, kernels, make_backend);
    }

    let prefixes = dag_position_prefixes(n, graph);
    let partials: Vec<Partial> = parallel_map(prefixes.len(), default_threads(), |pi| {
        let mut backend = make_backend();
        let mut p = Partial::new();
        dag_enumerate_task(
            gpu,
            kernels,
            backend.as_mut(),
            &prefixes[pi],
            graph,
            &mut |t, order| p.record(t, order),
        );
        p
    });

    merge_partials(partials)
}

/// Streaming-statistics sweep over the topological orders of `graph` on
/// the fluid simulator with the default 4096-bin histogram. See
/// [`sweep_stats_dag_with`].
pub fn sweep_stats_dag(gpu: &GpuSpec, kernels: &[KernelProfile], graph: &DepGraph) -> SweepStats {
    sweep_stats_dag_with(gpu, kernels, graph, &|| Box::new(SimulatorBackend::new()), 4096)
}

/// [`sweep_stats_with`] restricted to the topological orders of `graph`
/// — the constant-memory spelling of [`sweep_dag_with`], with exact
/// best/worst and a histogram for percentile ranks. The histogram
/// reference order is [`DepGraph::first_topological_order`] (exactly
/// the identity when no deps exist, so the edge-free delegation to
/// [`sweep_stats_with`] uses the same reference).
pub fn sweep_stats_dag_with(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    graph: &DepGraph,
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
    n_bins: usize,
) -> SweepStats {
    let n = kernels.len();
    assert!(n >= 1, "empty workload");
    assert_eq!(graph.n(), n, "dependency graph sized for a different workload");
    if !graph.has_deps() {
        return sweep_stats_with(gpu, kernels, make_backend, n_bins);
    }

    // Range reference: one evaluation of the lexicographically smallest
    // topological order (the DAG analogue of the identity order).
    let reference_order = graph.first_topological_order();
    let mut b0 = make_backend();
    let reference = b0.prepare(gpu, kernels).execute_order(&reference_order);
    let (lo, hi) = if reference.is_finite() && reference > 0.0 {
        (reference / 4.0, reference * 4.0)
    } else {
        (0.0, 1.0)
    };

    let prefixes = dag_position_prefixes(n, graph);
    let partials: Vec<SweepStats> = parallel_map(prefixes.len(), default_threads(), |pi| {
        let mut backend = make_backend();
        let mut stats = SweepStats::new(lo, hi, n_bins);
        dag_enumerate_task(
            gpu,
            kernels,
            backend.as_mut(),
            &prefixes[pi],
            graph,
            &mut |t, order| stats.record(t, order),
        );
        stats
    });

    let mut result = SweepStats::new(lo, hi, n_bins);
    for p in &partials {
        result.merge(p);
    }
    result
}

/// [`position_prefixes`] filtered to dependency-feasible prefixes —
/// the parallelization units of the constrained sweeps. The first two
/// positions of any topological order form such a prefix, so at least
/// one survives for every validated DAG.
fn dag_position_prefixes(n: usize, graph: &DepGraph) -> Vec<Vec<usize>> {
    let mut prefixes = position_prefixes(n);
    prefixes.retain(|p| {
        let mut used = 0u64;
        p.iter().all(|&k| {
            let free = graph.is_free(k, used);
            used |= 1 << k;
            free
        })
    });
    prefixes
}

/// Evaluate every topological order starting with `prefix` (itself
/// feasible), feeding `(makespan, order)` pairs to `rec` — the
/// dependency-constrained sibling of [`enumerate_task`]. Uses the
/// checkpointed prefix tree when the backend supports it, filtered
/// flat enumeration otherwise.
fn dag_enumerate_task(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    backend: &mut dyn ExecutionBackend,
    prefix: &[usize],
    graph: &DepGraph,
    rec: &mut dyn FnMut(f64, &[usize]),
) {
    let n = kernels.len();
    let mut order: Vec<usize> = Vec::with_capacity(n);
    order.extend_from_slice(prefix);

    let mut prepared = backend.prepare(gpu, kernels);
    if prepared.supports_checkpoints() {
        for &k in prefix {
            prepared.checkpoint_push(k);
        }
        let mut used = vec![false; n];
        let mut used_mask = 0u64;
        for &k in prefix {
            used[k] = true;
            used_mask |= 1 << k;
        }
        dag_checkpointed_dfs(prepared.as_mut(), &mut used, used_mask, &mut order, n, graph, rec);
        for _ in prefix {
            prepared.checkpoint_pop();
        }
    } else {
        let mut rest: Vec<usize> = (0..n).filter(|i| !prefix.contains(i)).collect();
        if rest.is_empty() {
            let t = prepared.execute_order(&order);
            rec(t, &order);
            return;
        }
        let plen = prefix.len();
        for_each_permutation(&mut rest, &mut |suffix| {
            order.truncate(plen);
            order.extend_from_slice(suffix);
            if graph.is_topological(&order) {
                let t = prepared.execute_order(&order);
                rec(t, &order);
            }
        });
    }
}

/// [`checkpointed_dfs`] restricted to topological orders: each node
/// expands only dependency-free kernels. The last two positions are
/// completed from the parent checkpoint as in the unconstrained DFS;
/// there, only the first of the pair needs a feasibility check — the
/// lone kernel left after it has every possible predecessor placed.
fn dag_checkpointed_dfs(
    prepared: &mut dyn PreparedWorkload,
    used: &mut [bool],
    used_mask: u64,
    order: &mut Vec<usize>,
    n: usize,
    graph: &DepGraph,
    rec: &mut dyn FnMut(f64, &[usize]),
) {
    match n - order.len() {
        0 => {
            let t = prepared.execute_suffix(&[]);
            rec(t, order);
        }
        1 => {
            // The lone remaining kernel is always free: everything that
            // could precede it is already placed.
            let k = used.iter().position(|u| !u).expect("one kernel left");
            order.push(k);
            let t = prepared.execute_suffix(&order[n - 1..]);
            rec(t, order);
            order.pop();
        }
        2 => {
            let a = used.iter().position(|u| !u).expect("two kernels left");
            let b = used[a + 1..]
                .iter()
                .position(|u| !u)
                .map(|i| a + 1 + i)
                .expect("two kernels left");
            for (x, y) in [(a, b), (b, a)] {
                if !graph.is_free(x, used_mask) {
                    continue; // y -> x edge: only (y, x) is feasible
                }
                order.push(x);
                order.push(y);
                let t = prepared.execute_suffix(&order[n - 2..]);
                rec(t, order);
                order.pop();
                order.pop();
            }
        }
        _ => {
            for k in 0..n {
                if used[k] || !graph.is_free(k, used_mask) {
                    continue;
                }
                used[k] = true;
                order.push(k);
                prepared.checkpoint_push(k);
                dag_checkpointed_dfs(
                    prepared,
                    used,
                    used_mask | (1 << k),
                    order,
                    n,
                    graph,
                    rec,
                );
                prepared.checkpoint_pop();
                order.pop();
                used[k] = false;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Enumeration core
// ---------------------------------------------------------------------------

/// Parallelization units: fixed prefixes of length min(2, n). Shared
/// with the branch-and-bound solver in [`crate::search`], which splits
/// its tree over the same `n·(n-1)` first-two-position tasks.
pub(crate) fn position_prefixes(n: usize) -> Vec<Vec<usize>> {
    let mut prefixes: Vec<Vec<usize>> = Vec::new();
    if n == 1 {
        prefixes.push(vec![0]);
    } else {
        for a in 0..n {
            for b in 0..n {
                if a != b {
                    prefixes.push(vec![a, b]);
                }
            }
        }
    }
    prefixes
}

/// Evaluate every permutation starting with `prefix` on `backend`,
/// feeding `(makespan, order)` pairs to `rec`.
fn enumerate_task(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    backend: &mut dyn ExecutionBackend,
    prefix: &[usize],
    mode: SweepMode,
    rec: &mut dyn FnMut(f64, &[usize]),
) {
    let n = kernels.len();
    let mut order: Vec<usize> = Vec::with_capacity(n);
    order.extend_from_slice(prefix);
    let mut rest: Vec<usize> = (0..n).filter(|i| !prefix.contains(i)).collect();

    if mode == SweepMode::NaiveExecute {
        if rest.is_empty() {
            let t = backend.execute(gpu, kernels, &order).makespan_ms;
            rec(t, &order);
            return;
        }
        let plen = prefix.len();
        for_each_permutation(&mut rest, &mut |suffix| {
            order.truncate(plen);
            order.extend_from_slice(suffix);
            let t = backend.execute(gpu, kernels, &order).makespan_ms;
            rec(t, &order);
        });
        return;
    }

    let mut prepared = backend.prepare(gpu, kernels);
    if mode == SweepMode::Checkpointed && prepared.supports_checkpoints() {
        for &k in prefix {
            prepared.checkpoint_push(k);
        }
        let mut used = vec![false; n];
        for &k in prefix {
            used[k] = true;
        }
        checkpointed_dfs(prepared.as_mut(), &mut used, &mut order, n, rec);
        for _ in prefix {
            prepared.checkpoint_pop();
        }
    } else {
        if rest.is_empty() {
            let t = prepared.execute_order(&order);
            rec(t, &order);
            return;
        }
        let plen = prefix.len();
        for_each_permutation(&mut rest, &mut |suffix| {
            order.truncate(plen);
            order.extend_from_slice(suffix);
            let t = prepared.execute_order(&order);
            rec(t, &order);
        });
    }
}

/// Lexicographic prefix-tree enumeration over the unused kernels: each
/// internal node pushes one checkpoint shared by every permutation of its
/// subtree; the last two positions are completed directly from the
/// parent checkpoint (a depth-(n-1) checkpoint would serve one leaf).
fn checkpointed_dfs(
    prepared: &mut dyn PreparedWorkload,
    used: &mut [bool],
    order: &mut Vec<usize>,
    n: usize,
    rec: &mut dyn FnMut(f64, &[usize]),
) {
    match n - order.len() {
        0 => {
            let t = prepared.execute_suffix(&[]);
            rec(t, order);
        }
        1 => {
            let k = used.iter().position(|u| !u).expect("one kernel left");
            order.push(k);
            let t = prepared.execute_suffix(&order[n - 1..]);
            rec(t, order);
            order.pop();
        }
        2 => {
            let a = used.iter().position(|u| !u).expect("two kernels left");
            let b = used[a + 1..]
                .iter()
                .position(|u| !u)
                .map(|i| a + 1 + i)
                .expect("two kernels left");
            for (x, y) in [(a, b), (b, a)] {
                order.push(x);
                order.push(y);
                let t = prepared.execute_suffix(&order[n - 2..]);
                rec(t, order);
                order.pop();
                order.pop();
            }
        }
        _ => {
            for k in 0..n {
                if used[k] {
                    continue;
                }
                used[k] = true;
                order.push(k);
                prepared.checkpoint_push(k);
                checkpointed_dfs(prepared, used, order, n, rec);
                prepared.checkpoint_pop();
                order.pop();
                used[k] = false;
            }
        }
    }
}

/// Per-worker accumulator for the full-distribution sweep.
struct Partial {
    best_ms: f64,
    best_order: Vec<usize>,
    worst_ms: f64,
    worst_order: Vec<usize>,
    times: Vec<f64>,
}

impl Partial {
    fn new() -> Self {
        Partial {
            best_ms: f64::INFINITY,
            best_order: Vec::new(),
            worst_ms: f64::NEG_INFINITY,
            worst_order: Vec::new(),
            times: Vec::new(),
        }
    }

    #[inline]
    fn record(&mut self, t: f64, order: &[usize]) {
        // Exact ties break toward the lexicographically smallest order so
        // the reported extreme orders are enumeration-order independent
        // (Heap's, prefix-tree DFS and streaming mode all agree).
        if t < self.best_ms || (t == self.best_ms && order < &self.best_order[..]) {
            self.best_ms = t;
            self.best_order.clear();
            self.best_order.extend_from_slice(order);
        }
        if t > self.worst_ms || (t == self.worst_ms && order < &self.worst_order[..]) {
            self.worst_ms = t;
            self.worst_order.clear();
            self.worst_order.extend_from_slice(order);
        }
        self.times.push(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::AnalyticBackend;
    use crate::gpu::AppKind;
    use crate::sim::simulate_order;

    fn kernel(n_blocks: u32, warps: u32, shmem: u32, ratio: f64, work: f64) -> KernelProfile {
        KernelProfile {
            name: format!("k{warps}w{shmem}s"),
            app: AppKind::Synthetic,
            n_blocks,
            regs_per_block: 512,
            shmem_per_block: shmem,
            warps_per_block: warps,
            ratio,
            work_per_block: work,
            artifact: String::new(),
        }
    }

    #[test]
    fn sweep_counts_factorial() {
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..4)
            .map(|i| kernel(16, 4 + i * 4, 0, 2.0 + i as f64, 500.0))
            .collect();
        let r = sweep(&gpu, &ks);
        assert_eq!(r.n_perms, 24);
        assert_eq!(r.times.len(), 24);
        assert!(r.best_ms <= r.worst_ms);
    }

    #[test]
    fn sweep_single_kernel() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![kernel(16, 8, 0, 3.0, 500.0)];
        let r = sweep(&gpu, &ks);
        assert_eq!(r.n_perms, 1);
        assert_eq!(r.best_ms, r.worst_ms);
        assert_eq!(r.best_order, vec![0]);
    }

    #[test]
    fn best_and_worst_orders_reproduce_their_times() {
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..5)
            .map(|i| kernel(16, 4 + (i % 3) * 10, ((i % 2) as u32) * 16384, 1.0 + 2.0 * i as f64, 400.0))
            .collect();
        let r = sweep(&gpu, &ks);
        let tb = simulate_order(&gpu, &ks, &r.best_order).makespan_ms;
        let tw = simulate_order(&gpu, &ks, &r.worst_order).makespan_ms;
        assert!((tb - r.best_ms).abs() < 1e-9);
        assert!((tw - r.worst_ms).abs() < 1e-9);
    }

    #[test]
    fn percentile_rank_extremes() {
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..4)
            .map(|i| kernel(16, 4 + i * 8, 0, 1.0 + 3.0 * i as f64, 400.0))
            .collect();
        let r = sweep(&gpu, &ks);
        // The best time beats (or ties) everything.
        assert!(r.percentile_rank(r.best_ms) > 50.0);
        // The worst time beats nothing (up to ties).
        assert!(r.percentile_rank(r.worst_ms) < 50.0);
        // A hypothetical time faster than best outranks everything.
        assert!((r.percentile_rank(r.best_ms * 0.5) - 100.0).abs() < 1e-9);
        assert!(r.percentile_rank(r.worst_ms * 2.0) == 0.0);
    }

    #[test]
    fn percentile_rank_matches_linear_scan() {
        // The binary-search implementation must agree exactly with the
        // original O(n!) linear scan.
        fn linear_rank(times: &[f64], t_ms: f64) -> f64 {
            if times.is_empty() {
                return 0.0;
            }
            let eps = 1e-9 * t_ms.abs().max(1e-300);
            let mut worse = 0usize;
            let mut equal = 0usize;
            for &t in times {
                if t > t_ms + eps {
                    worse += 1;
                } else if (t - t_ms).abs() <= eps {
                    equal += 1;
                }
            }
            (worse as f64 + 0.5 * equal as f64) / times.len() as f64 * 100.0
        }
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..5)
            .map(|i| kernel(16, 4 + i * 8, 8192 * (i % 2) as u32, 1.0 + i as f64, 400.0))
            .collect();
        let r = sweep(&gpu, &ks);
        let probes = [
            r.best_ms,
            r.worst_ms,
            r.median_ms(),
            r.best_ms * 0.9,
            r.worst_ms * 1.1,
            r.times[7],
            r.times[63],
        ];
        for t in probes {
            assert_eq!(
                r.percentile_rank(t).to_bits(),
                linear_rank(&r.times, t).to_bits(),
                "probe {t}"
            );
        }
        // A NaN candidate (unsimulable run) ranks 0, as in the linear
        // scan where every NaN comparison is false — in both the full
        // and the streaming distribution.
        assert_eq!(r.percentile_rank(f64::NAN), 0.0);
        assert_eq!(linear_rank(&r.times, f64::NAN), 0.0);
        assert_eq!(sweep_stats(&gpu, &ks).percentile_rank(f64::NAN), 0.0);
    }

    #[test]
    fn median_between_best_and_worst() {
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..4)
            .map(|i| kernel(16, 4 + i * 8, 8192 * (i % 2) as u32, 1.0 + 3.0 * i as f64, 400.0))
            .collect();
        let r = sweep(&gpu, &ks);
        let m = r.median_ms();
        assert!(r.best_ms <= m && m <= r.worst_ms);
    }

    #[test]
    fn sweep_with_accepts_other_backends() {
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..4)
            .map(|i| kernel(16, 4 + i * 8, ((i % 2) as u32) * 24576, 2.0 + i as f64, 400.0))
            .collect();
        let r = sweep_with(&gpu, &ks, &|| Box::new(AnalyticBackend::new()));
        assert_eq!(r.n_perms, 24);
        assert!(r.best_ms.is_finite() && r.best_ms > 0.0);
        assert!(r.best_ms <= r.worst_ms);
    }

    #[test]
    fn identical_kernels_flat_distribution() {
        // Scope check (paper): identical kernels -> every permutation
        // takes the same time.
        let gpu = GpuSpec::gtx580();
        let ks = vec![kernel(16, 8, 8192, 3.0, 500.0); 4];
        let r = sweep(&gpu, &ks);
        let spread = (r.worst_ms - r.best_ms) / r.best_ms;
        assert!(spread < 1e-9, "spread {spread}");
    }

    #[test]
    fn tied_extremes_pick_lexicographically_smallest_order() {
        // Identical kernels: every permutation ties, so both extreme
        // orders must be the lexicographically smallest (the identity) —
        // in every mode.
        let gpu = GpuSpec::gtx580();
        let ks = vec![kernel(16, 8, 8192, 3.0, 500.0); 4];
        let factory: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync) =
            &|| Box::new(SimulatorBackend::new());
        for mode in [
            SweepMode::NaiveExecute,
            SweepMode::PreparedFlat,
            SweepMode::Checkpointed,
        ] {
            let r = sweep_with_mode(&gpu, &ks, factory, mode);
            assert_eq!(r.best_order, vec![0, 1, 2, 3], "{mode:?}");
            assert_eq!(r.worst_order, vec![0, 1, 2, 3], "{mode:?}");
        }
    }

    #[test]
    fn sweep_stats_tracks_exact_extremes() {
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..5)
            .map(|i| {
                let shmem = ((i % 2) as u32) * 16384;
                kernel(16, 4 + (i % 3) * 10, shmem, 1.0 + 2.0 * i as f64, 400.0)
            })
            .collect();
        let full = sweep(&gpu, &ks);
        let stats = sweep_stats(&gpu, &ks);
        assert_eq!(stats.n_perms, full.n_perms);
        assert_eq!(stats.best_ms.to_bits(), full.best_ms.to_bits());
        assert_eq!(stats.worst_ms.to_bits(), full.worst_ms.to_bits());
        assert_eq!(stats.best_order, full.best_order);
        assert_eq!(stats.worst_order, full.worst_order);
        // Mean from the histogram sum matches the full distribution.
        let mean_full: f64 = full.times.iter().sum::<f64>() / full.times.len() as f64;
        assert!((stats.mean_ms() - mean_full).abs() < 1e-9 * mean_full);
        // Quantiles land inside the observed range.
        let q50 = stats.quantile_ms(0.5);
        assert!(q50 >= stats.best_ms - stats.bin_width && q50 <= stats.worst_ms + stats.bin_width);
    }

    #[test]
    fn sweep_stats_quantile_error_bounded_by_half_bin_width() {
        // The documented quantile error bound: the histogram's partial
        // sums are exact per bin, so the bin `quantile_ms` picks is the
        // one holding the requested order statistic, and the returned
        // bin center is within bin_width/2 of the exact value (while the
        // statistic is inside the histogram range, which the reference
        // span [r/4, 4r) guarantees for these workloads).
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..5)
            .map(|i| {
                let shmem = ((i % 2) as u32) * 16384;
                kernel(16, 4 + (i % 3) * 10, shmem, 1.0 + 2.0 * i as f64, 400.0)
            })
            .collect();
        let full = sweep(&gpu, &ks);
        let stats = sweep_stats(&gpu, &ks);
        let sorted = full.sorted_times();
        let finite = sorted.len();
        for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99] {
            // Same order statistic `quantile_ms` targets: the ceil(q·n)-th
            // smallest (1-indexed).
            let target = ((q * finite as f64).ceil().max(1.0) as usize).min(finite);
            let exact = sorted[target - 1];
            let approx = stats.quantile_ms(q);
            assert!(
                (approx - exact).abs() <= stats.bin_width() / 2.0 + 1e-12,
                "q={q}: approx {approx} vs exact {exact} (bin width {})",
                stats.bin_width()
            );
        }
    }

    #[test]
    fn sweep_stats_rank_error_bounded_across_distribution() {
        // The documented rank error bound — ≤ 50·bin_mass/n_perms
        // percentage points — must hold for probes spread across the
        // whole distribution, not just the extremes.
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..5)
            .map(|i| kernel(16, 4 + i * 6, ((i % 2) as u32) * 8192, 1.0 + 1.5 * i as f64, 400.0))
            .collect();
        let full = sweep(&gpu, &ks);
        let stats = sweep_stats(&gpu, &ks);
        let sorted = full.sorted_times();
        for i in (0..sorted.len()).step_by(sorted.len() / 16 + 1) {
            let t = sorted[i];
            let exact = full.percentile_rank(t);
            let approx = stats.percentile_rank(t);
            let tol = 50.0 * stats.bin_mass(t) as f64 / stats.n_perms as f64 + 1e-6;
            assert!(
                (exact - approx).abs() <= tol,
                "probe {t}: exact {exact} vs approx {approx} (tol {tol})"
            );
        }
    }

    #[test]
    fn canonical_prefix_orders_class_members_ascending() {
        // Classes: {0, 2}, {1}, {3} (class_of maps to smallest member).
        let cls = [0usize, 1, 0, 3];
        assert!(canonical_prefix(&[], &cls));
        assert!(canonical_prefix(&[0, 2], &cls));
        assert!(canonical_prefix(&[1, 0, 3, 2], &cls));
        assert!(!canonical_prefix(&[2], &cls), "2 before its twin 0");
        assert!(!canonical_prefix(&[1, 2, 0], &cls));
        // All-distinct classes: everything is canonical.
        let distinct = [0usize, 1, 2, 3];
        assert!(canonical_prefix(&[3, 1, 2, 0], &distinct));
    }

    #[test]
    fn sym_sweep_stats_matches_plain_on_duplicated_kernels() {
        // 2 + 2 + 1 duplicate layout: the collapsed sweep evaluates
        // 5!/(2!·2!) = 30 canonical orders, each with weight 4, and must
        // agree with the plain 120-order sweep on everything except
        // float summation order.
        let gpu = GpuSpec::gtx580();
        let a = kernel(16, 8, 8192, 3.0, 500.0);
        let b = kernel(16, 4, 0, 9.0, 700.0);
        let c = kernel(24, 12, 16384, 1.5, 400.0);
        let ks = vec![a.clone(), a, b.clone(), b, c];
        let plain = sweep_stats(&gpu, &ks);
        let sym = sweep_stats_sym(&gpu, &ks);
        assert_eq!(sym.n_perms, 120);
        assert_eq!(sym.n_perms, plain.n_perms);
        assert_eq!(sym.best_ms.to_bits(), plain.best_ms.to_bits());
        assert_eq!(sym.worst_ms.to_bits(), plain.worst_ms.to_bits());
        assert_eq!(sym.best_order, plain.best_order);
        assert_eq!(sym.worst_order, plain.worst_order);
        // Orbit members share bit-identical makespans, so the histograms
        // are identical and histogram-served queries agree exactly.
        for q in [0.1, 0.5, 0.9] {
            assert_eq!(sym.quantile_ms(q).to_bits(), plain.quantile_ms(q).to_bits());
        }
        for probe in [plain.best_ms, plain.quantile_ms(0.5), plain.worst_ms] {
            assert_eq!(
                sym.percentile_rank(probe).to_bits(),
                plain.percentile_rank(probe).to_bits()
            );
        }
        let rel = (sym.mean_ms() - plain.mean_ms()).abs() / plain.mean_ms();
        assert!(rel < 1e-9, "means drifted: {rel}");
        // The analytic backend honors the same contract.
        let factory: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync) =
            &|| Box::new(AnalyticBackend::new());
        let plain_a = sweep_stats_with(&gpu, &ks, factory, 4096);
        let sym_a = sweep_stats_sym_with(&gpu, &ks, factory, 4096);
        assert_eq!(sym_a.n_perms, plain_a.n_perms);
        assert_eq!(sym_a.best_ms.to_bits(), plain_a.best_ms.to_bits());
        assert_eq!(sym_a.best_order, plain_a.best_order);
    }

    #[test]
    fn sym_sweep_stats_collapses_identical_workload_to_one_order() {
        // n identical kernels: one canonical order carries the whole n!.
        let gpu = GpuSpec::gtx580();
        let ks = vec![kernel(16, 8, 8192, 3.0, 500.0); 5];
        let sym = sweep_stats_sym(&gpu, &ks);
        assert_eq!(sym.n_perms, 120);
        assert_eq!(sym.best_order, vec![0, 1, 2, 3, 4]);
        assert_eq!(sym.worst_order, vec![0, 1, 2, 3, 4]);
        assert_eq!(sym.best_ms.to_bits(), sym.worst_ms.to_bits());
        // No duplicates: the sym spelling is exactly the plain sweep.
        let distinct: Vec<_> = (0..4)
            .map(|i| kernel(16, 4 + i * 8, 0, 2.0 + i as f64, 500.0))
            .collect();
        let sym = sweep_stats_sym(&gpu, &distinct);
        let plain = sweep_stats(&gpu, &distinct);
        assert_eq!(sym.n_perms, plain.n_perms);
        assert_eq!(sym.best_ms.to_bits(), plain.best_ms.to_bits());
    }

    #[test]
    fn dag_sweep_matches_filtered_naive_golden() {
        // The constrained prefix tree must be bit-identical — best/worst
        // makespans, orders (lexicographic tie-break) and the full
        // distribution — to filtering a naive flat sweep down to
        // topological orders.
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..5)
            .map(|i| kernel(16, 4 + (i % 3) * 10, ((i % 2) as u32) * 16384, 1.0 + 2.0 * i as f64, 400.0))
            .collect();
        let graph = DepGraph::build(5, &[(0, 2), (1, 2), (3, 4)]).unwrap();

        let mut golden = Partial::new();
        let mut n_topo = 0usize;
        let mut backend = SimulatorBackend::new();
        let mut prepared = backend.prepare(&gpu, &ks);
        let mut perm: Vec<usize> = (0..5).collect();
        for_each_permutation(&mut perm, &mut |order| {
            if graph.is_topological(order) {
                golden.record(prepared.execute_order(order), order);
                n_topo += 1;
            }
        });
        drop(prepared);

        let r = sweep_dag(&gpu, &ks, &graph);
        assert_eq!(r.n_perms, n_topo);
        assert_eq!(n_topo as u128, graph.linear_extension_count().unwrap());
        assert_eq!(r.best_ms.to_bits(), golden.best_ms.to_bits());
        assert_eq!(r.best_order, golden.best_order);
        assert_eq!(r.worst_ms.to_bits(), golden.worst_ms.to_bits());
        assert_eq!(r.worst_order, golden.worst_order);
        // Same multiset of makespans (enumeration order may differ).
        let mut a = r.times.clone();
        let mut b = golden.times.clone();
        a.sort_unstable_by(f64::total_cmp);
        b.sort_unstable_by(f64::total_cmp);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn dag_sweep_empty_graph_bit_identical_to_plain_sweep() {
        // Acceptance criterion: independent workloads (no deps) behave
        // exactly as before the DAG layer existed.
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..4)
            .map(|i| kernel(16, 4 + i * 8, 0, 1.0 + 3.0 * i as f64, 400.0))
            .collect();
        let graph = DepGraph::empty(4);
        let dag = sweep_dag(&gpu, &ks, &graph);
        let plain = sweep(&gpu, &ks);
        assert_eq!(dag.n_perms, plain.n_perms);
        assert_eq!(dag.best_ms.to_bits(), plain.best_ms.to_bits());
        assert_eq!(dag.best_order, plain.best_order);
        assert_eq!(dag.worst_ms.to_bits(), plain.worst_ms.to_bits());
        assert_eq!(dag.worst_order, plain.worst_order);
        for (x, y) in dag.times.iter().zip(&plain.times) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let s_dag = sweep_stats_dag_with(
            &gpu,
            &ks,
            &graph,
            &|| Box::new(SimulatorBackend::new()),
            4096,
        );
        let s_plain = sweep_stats(&gpu, &ks);
        assert_eq!(s_dag.n_perms, s_plain.n_perms);
        assert_eq!(s_dag.best_ms.to_bits(), s_plain.best_ms.to_bits());
        assert_eq!(s_dag.best_order, s_plain.best_order);
    }

    #[test]
    fn dag_sweep_chain_and_two_chain_counts() {
        // Chain: exactly one feasible order — the chain itself. Two
        // independent 2-chains: C(4,2) = 6 interleavings.
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..4)
            .map(|i| kernel(16, 4 + i * 8, 0, 2.0 + i as f64, 500.0))
            .collect();
        let chain = DepGraph::build(4, &[(0, 1), (1, 2), (2, 3)]).unwrap();
        let r = sweep_dag(&gpu, &ks, &chain);
        assert_eq!(r.n_perms, 1);
        assert_eq!(r.best_order, vec![0, 1, 2, 3]);
        assert_eq!(r.worst_order, vec![0, 1, 2, 3]);
        assert_eq!(r.best_ms.to_bits(), r.worst_ms.to_bits());

        let two = DepGraph::build(4, &[(0, 1), (2, 3)]).unwrap();
        let r = sweep_dag(&gpu, &ks, &two);
        assert_eq!(r.n_perms, 6);
        assert!(two.is_topological(&r.best_order));
        assert!(two.is_topological(&r.worst_order));
    }

    #[test]
    fn dag_sweep_stats_matches_dag_sweep_on_both_backends() {
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..5)
            .map(|i| kernel(16, 4 + (i % 3) * 10, ((i % 2) as u32) * 16384, 1.0 + 2.0 * i as f64, 400.0))
            .collect();
        let graph = DepGraph::build(5, &[(0, 2), (1, 2), (3, 4)]).unwrap();
        let factories: [&(dyn Fn() -> Box<dyn ExecutionBackend> + Sync); 2] = [
            &|| Box::new(SimulatorBackend::new()),
            &|| Box::new(AnalyticBackend::new()),
        ];
        for factory in factories {
            let full = sweep_dag_with(&gpu, &ks, &graph, factory);
            let stats = sweep_stats_dag_with(&gpu, &ks, &graph, factory, 4096);
            assert_eq!(stats.n_perms, full.n_perms);
            assert_eq!(stats.best_ms.to_bits(), full.best_ms.to_bits());
            assert_eq!(stats.best_order, full.best_order);
            assert_eq!(stats.worst_ms.to_bits(), full.worst_ms.to_bits());
            assert_eq!(stats.worst_order, full.worst_order);
            let mean_full: f64 = full.times.iter().sum::<f64>() / full.times.len() as f64;
            assert!((stats.mean_ms() - mean_full).abs() < 1e-9 * mean_full);
        }
    }

    #[test]
    fn sweep_stats_percentiles_within_bin_resolution() {
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..5)
            .map(|i| kernel(16, 4 + i * 6, ((i % 2) as u32) * 8192, 1.0 + 1.5 * i as f64, 400.0))
            .collect();
        let full = sweep(&gpu, &ks);
        let stats = sweep_stats(&gpu, &ks);
        for &t in [full.best_ms, full.median_ms(), full.worst_ms].iter() {
            let exact = full.percentile_rank(t);
            let approx = stats.percentile_rank(t);
            let tol = 50.0 * stats.bin_mass(t) as f64 / stats.n_perms as f64 + 1e-6;
            assert!(
                (exact - approx).abs() <= tol,
                "t={t}: exact {exact} vs approx {approx} (tol {tol})"
            );
        }
    }
}
