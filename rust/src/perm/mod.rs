//! Permutation-space evaluation — the paper's methodology: "our experiments
//! evaluate the concurrent execution time of all possible kernel orderings
//! (all permutations) and compare the performance of the kernel ordering
//! given by the algorithm with the optimal (best) result."
//!
//! [`sweep`] simulates every permutation of the launch order (rayon-parallel
//! across first-position prefixes, Heap's algorithm within each worker) and
//! returns the full time distribution plus best/worst orders, from which
//! [`SweepResult::percentile_rank`], speedup-over-worst, and
//! deviation-from-optimal (the Table 3 columns) are computed.

mod heap;

pub use heap::for_each_permutation;

use crate::exec::{ExecutionBackend, SimulatorBackend};
use crate::gpu::{GpuSpec, KernelProfile};
use crate::util::{default_threads, parallel_map};

/// Distribution of simulated makespans across all launch-order
/// permutations of one workload.
#[derive(Debug, Clone)]
pub struct SweepResult {
    /// Number of permutations evaluated (`n!`).
    pub n_perms: usize,
    /// Best (minimum) makespan and the order achieving it.
    pub best_ms: f64,
    pub best_order: Vec<usize>,
    /// Worst (maximum) makespan and the order achieving it.
    pub worst_ms: f64,
    pub worst_order: Vec<usize>,
    /// Every permutation's makespan (unsorted; ~n! entries).
    pub times: Vec<f64>,
}

impl SweepResult {
    /// The paper's *percentile rank* of a candidate time within the
    /// permutation space: the percentage of permutations the candidate is
    /// at least as good as, with ties counted half (mid-rank). Higher is
    /// better; the paper reports 91.5–99.4% for Algorithm 1.
    pub fn percentile_rank(&self, t_ms: f64) -> f64 {
        if self.times.is_empty() {
            return 0.0;
        }
        let eps = 1e-9 * t_ms.abs().max(1e-300);
        let mut worse = 0usize;
        let mut equal = 0usize;
        for &t in &self.times {
            if t > t_ms + eps {
                worse += 1;
            } else if (t - t_ms).abs() <= eps {
                equal += 1;
            }
        }
        (worse as f64 + 0.5 * equal as f64) / self.times.len() as f64 * 100.0
    }

    /// Median makespan of the permutation space (the paper's "random
    /// order choice" reference point).
    pub fn median_ms(&self) -> f64 {
        let mut ts = self.times.clone();
        ts.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ts.len();
        if n == 0 {
            return 0.0;
        }
        if n % 2 == 1 {
            ts[n / 2]
        } else {
            0.5 * (ts[n / 2 - 1] + ts[n / 2])
        }
    }

    /// Sorted copy of the distribution (ascending), for ranking plots.
    pub fn sorted_times(&self) -> Vec<f64> {
        let mut ts = self.times.clone();
        ts.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        ts
    }
}

/// Exhaustively simulate all `n!` launch orders of `kernels` on the fluid
/// simulator (the paper's methodology). See [`sweep_with`] for other
/// execution backends.
pub fn sweep(gpu: &GpuSpec, kernels: &[KernelProfile]) -> SweepResult {
    sweep_with(gpu, kernels, &|| Box::new(SimulatorBackend::new()))
}

/// Exhaustively evaluate all `n!` launch orders of `kernels` on an
/// [`ExecutionBackend`] built by `make_backend` (backends are not
/// required to be `Sync`).
///
/// Parallelized over the choice of the first two positions (`n·(n-1)`
/// prefixes, each enumerating `(n-2)!` suffixes with Heap's algorithm) so
/// work spreads evenly across cores. `make_backend` is invoked once per
/// *prefix* — `n·(n-1)` times, not once per thread — so keep the factory
/// cheap (the zero-sized model backends are; an expensive backend like
/// PJRT is the wrong substrate for a 40 320-permutation sweep anyway).
/// n ≤ 12 or so is practical (the paper's largest space is 8! = 40 320).
pub fn sweep_with(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
) -> SweepResult {
    let n = kernels.len();
    assert!(n >= 1, "empty workload");

    // Prefixes of length min(2, n).
    let mut prefixes: Vec<Vec<usize>> = Vec::new();
    if n == 1 {
        prefixes.push(vec![0]);
    } else {
        for a in 0..n {
            for b in 0..n {
                if a != b {
                    prefixes.push(vec![a, b]);
                }
            }
        }
    }

    let partials: Vec<Partial> = parallel_map(prefixes.len(), default_threads(), |pi| {
        let mut backend = make_backend();
        let prefix = &prefixes[pi];
        let mut rest: Vec<usize> = (0..n).filter(|i| !prefix.contains(i)).collect();
        let mut order = Vec::with_capacity(n);
        let mut p = Partial::new();
        if rest.is_empty() {
            let t = backend.execute(gpu, kernels, prefix).makespan_ms;
            p.record(t, prefix);
            return p;
        }
        for_each_permutation(&mut rest, &mut |suffix| {
            order.clear();
            order.extend_from_slice(prefix);
            order.extend_from_slice(suffix);
            let t = backend.execute(gpu, kernels, &order).makespan_ms;
            p.record(t, &order);
        });
        p
    });

    let mut result = SweepResult {
        n_perms: 0,
        best_ms: f64::INFINITY,
        best_order: Vec::new(),
        worst_ms: f64::NEG_INFINITY,
        worst_order: Vec::new(),
        times: Vec::new(),
    };
    for p in partials {
        result.n_perms += p.times.len();
        if p.best_ms < result.best_ms {
            result.best_ms = p.best_ms;
            result.best_order = p.best_order;
        }
        if p.worst_ms > result.worst_ms {
            result.worst_ms = p.worst_ms;
            result.worst_order = p.worst_order;
        }
        result.times.extend(p.times);
    }
    result
}

struct Partial {
    best_ms: f64,
    best_order: Vec<usize>,
    worst_ms: f64,
    worst_order: Vec<usize>,
    times: Vec<f64>,
}

impl Partial {
    fn new() -> Self {
        Partial {
            best_ms: f64::INFINITY,
            best_order: Vec::new(),
            worst_ms: f64::NEG_INFINITY,
            worst_order: Vec::new(),
            times: Vec::new(),
        }
    }

    #[inline]
    fn record(&mut self, t: f64, order: &[usize]) {
        if t < self.best_ms {
            self.best_ms = t;
            self.best_order = order.to_vec();
        }
        if t > self.worst_ms {
            self.worst_ms = t;
            self.worst_order = order.to_vec();
        }
        self.times.push(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::AnalyticBackend;
    use crate::gpu::AppKind;
    use crate::sim::simulate_order;

    fn kernel(n_blocks: u32, warps: u32, shmem: u32, ratio: f64, work: f64) -> KernelProfile {
        KernelProfile {
            name: format!("k{warps}w{shmem}s"),
            app: AppKind::Synthetic,
            n_blocks,
            regs_per_block: 512,
            shmem_per_block: shmem,
            warps_per_block: warps,
            ratio,
            work_per_block: work,
            artifact: String::new(),
        }
    }

    #[test]
    fn sweep_counts_factorial() {
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..4)
            .map(|i| kernel(16, 4 + i * 4, 0, 2.0 + i as f64, 500.0))
            .collect();
        let r = sweep(&gpu, &ks);
        assert_eq!(r.n_perms, 24);
        assert_eq!(r.times.len(), 24);
        assert!(r.best_ms <= r.worst_ms);
    }

    #[test]
    fn sweep_single_kernel() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![kernel(16, 8, 0, 3.0, 500.0)];
        let r = sweep(&gpu, &ks);
        assert_eq!(r.n_perms, 1);
        assert_eq!(r.best_ms, r.worst_ms);
        assert_eq!(r.best_order, vec![0]);
    }

    #[test]
    fn best_and_worst_orders_reproduce_their_times() {
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..5)
            .map(|i| kernel(16, 4 + (i % 3) * 10, ((i % 2) as u32) * 16384, 1.0 + 2.0 * i as f64, 400.0))
            .collect();
        let r = sweep(&gpu, &ks);
        let tb = simulate_order(&gpu, &ks, &r.best_order).makespan_ms;
        let tw = simulate_order(&gpu, &ks, &r.worst_order).makespan_ms;
        assert!((tb - r.best_ms).abs() < 1e-9);
        assert!((tw - r.worst_ms).abs() < 1e-9);
    }

    #[test]
    fn percentile_rank_extremes() {
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..4)
            .map(|i| kernel(16, 4 + i * 8, 0, 1.0 + 3.0 * i as f64, 400.0))
            .collect();
        let r = sweep(&gpu, &ks);
        // The best time beats (or ties) everything.
        assert!(r.percentile_rank(r.best_ms) > 50.0);
        // The worst time beats nothing (up to ties).
        assert!(r.percentile_rank(r.worst_ms) < 50.0);
        // A hypothetical time faster than best outranks everything.
        assert!((r.percentile_rank(r.best_ms * 0.5) - 100.0).abs() < 1e-9);
        assert!(r.percentile_rank(r.worst_ms * 2.0) == 0.0);
    }

    #[test]
    fn median_between_best_and_worst() {
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..4)
            .map(|i| kernel(16, 4 + i * 8, 8192 * (i % 2) as u32, 1.0 + 3.0 * i as f64, 400.0))
            .collect();
        let r = sweep(&gpu, &ks);
        let m = r.median_ms();
        assert!(r.best_ms <= m && m <= r.worst_ms);
    }

    #[test]
    fn sweep_with_accepts_other_backends() {
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..4)
            .map(|i| kernel(16, 4 + i * 8, ((i % 2) as u32) * 24576, 2.0 + i as f64, 400.0))
            .collect();
        let r = sweep_with(&gpu, &ks, &|| Box::new(AnalyticBackend::new()));
        assert_eq!(r.n_perms, 24);
        assert!(r.best_ms.is_finite() && r.best_ms > 0.0);
        assert!(r.best_ms <= r.worst_ms);
    }

    #[test]
    fn identical_kernels_flat_distribution() {
        // Scope check (paper): identical kernels -> every permutation
        // takes the same time.
        let gpu = GpuSpec::gtx580();
        let ks = vec![kernel(16, 8, 8192, 3.0, 500.0); 4];
        let r = sweep(&gpu, &ks);
        let spread = (r.worst_ms - r.best_ms) / r.best_ms;
        assert!(spread < 1e-9, "spread {spread}");
    }
}
