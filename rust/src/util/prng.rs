//! SplitMix64: a tiny, high-quality, dependency-free PRNG.
//!
//! Used for the `Random` launch-order baseline, synthetic workload
//! generation, and deterministic input synthesis for the PJRT runtime.
//! (Vigna 2015, public domain reference implementation.)

/// Deterministic 64-bit PRNG with splittable seeding.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Equal seeds → equal streams.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform `u32`.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift bound; bias is negligible for our n << 2^32.
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Uniform float in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = SplitMix64::new(9);
        for n in [1usize, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn below_hits_every_value() {
        let mut r = SplitMix64::new(11);
        let mut seen = [false; 5];
        for _ in 0..500 {
            seen[r.below(5)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        // And it actually moved something.
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
