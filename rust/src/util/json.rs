//! A minimal, dependency-free JSON parser — the offline environment ships
//! no serde, so `artifacts/profiles.json` is parsed with this ~RFC 8259
//! recursive-descent implementation (objects, arrays, strings with
//! escapes, numbers, booleans, null; rejects trailing garbage).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected byte `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("invalid UTF-8 start byte")),
                        };
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump();
                        }
                        let chunk = self
                            .bytes
                            .get(start..start + len)
                            .ok_or_else(|| self.err("truncated UTF-8"))?;
                        let st = std::str::from_utf8(chunk)
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        s.push_str(st);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("invalid number `{text}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested_structure() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" \\ A é""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" \\ A é"));
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[ ]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"a\" : 1 ,\r\n \"b\": [ 2 , 3 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("[1 2]").is_err());
    }

    #[test]
    fn rejects_bad_escapes() {
        assert!(Json::parse(r#""\x""#).is_err());
        assert!(Json::parse(r#""\u00g0""#).is_err());
        assert!(Json::parse(r#""\ud800""#).is_err()); // lone high surrogate
    }

    #[test]
    fn deep_nesting() {
        let doc = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        let mut v = &Json::parse(&doc).unwrap();
        for _ in 0..100 {
            v = &v.as_arr().unwrap()[0];
        }
        assert_eq!(v.as_f64(), Some(1.0));
    }

    #[test]
    fn real_manifest_shape() {
        let doc = r#"{
            "format": 1,
            "variants": {
                "ep_16k": {
                    "app": "ep",
                    "inputs": [{"shape": [16384], "dtype": "uint32"}],
                    "profile": {"flops": 245760.0, "ratio": 0.336}
                }
            }
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_f64(), Some(1.0));
        let ep = v.get("variants").unwrap().get("ep_16k").unwrap();
        assert_eq!(ep.get("app").unwrap().as_str(), Some("ep"));
        assert_eq!(
            ep.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()[0]
                .as_f64(),
            Some(16384.0)
        );
    }
}
