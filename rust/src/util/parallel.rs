//! Dependency-free data parallelism over `std::thread::scope` — the
//! offline environment ships no rayon, so the permutation sweeps use this
//! work-stealing task pool.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Map `f` over `0..n` tasks on up to `threads` OS threads, collecting the
/// results in task order. `f` must be `Sync` (it is shared by reference).
///
/// Tasks are claimed one at a time from a shared atomic counter
/// (work-stealing), so uneven task costs self-balance: a worker that
/// draws a cheap task immediately claims the next one instead of idling
/// behind a statically assigned chunk. The permutation sweeps need this —
/// checkpointed prefix tasks vary in cost with how early their prefix
/// stalls the dispatcher.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        return (0..n).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let f = &f;
                let next = &next;
                scope.spawn(move || {
                    let mut local: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("worker panicked") {
                out[i] = Some(v);
            }
        }
    });
    out.into_iter().map(|x| x.expect("task completed")).collect()
}

/// Number of worker threads to use by default: the machine's parallelism,
/// overridable with `KREORDER_THREADS`.
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("KREORDER_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_task_exactly_once() {
        let counter = AtomicUsize::new(0);
        let out = parallel_map(1000, 16, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
        assert_eq!(out.len(), 1000);
    }

    #[test]
    fn edge_cases() {
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
        assert_eq!(parallel_map(3, 100, |i| i), vec![0, 1, 2]);
        assert_eq!(parallel_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn uneven_task_costs_balance() {
        // A single pathological task (index 0) must not serialize the
        // pool: with static chunking, thread 0's whole chunk would queue
        // behind it; with stealing, other workers drain the rest.
        let out = parallel_map(64, 8, |i| {
            let spin = if i == 0 { 200_000u64 } else { 50 };
            let mut acc = 0u64;
            for x in 0..spin {
                acc = acc.wrapping_add(x);
            }
            std::hint::black_box(acc);
            i
        });
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
