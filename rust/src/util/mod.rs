//! Small shared utilities: a deterministic PRNG (so the crate needs no
//! external randomness dependency and every experiment is reproducible from
//! a seed) and misc numeric helpers.

pub mod json;
mod parallel;
mod prng;

pub use json::Json;
pub use parallel::{default_threads, parallel_map};
pub use prng::SplitMix64;

/// Relative deviation `(x - reference) / reference`, in percent.
///
/// Used for the paper's "deviation from optimal" column (Table 3).
pub fn deviation_pct(x: f64, reference: f64) -> f64 {
    if reference == 0.0 {
        return 0.0;
    }
    (x - reference) / reference * 100.0
}

/// `a / b` with a zero-guard; used for speedup columns.
pub fn ratio_or_zero(a: f64, b: f64) -> f64 {
    if b == 0.0 {
        0.0
    } else {
        a / b
    }
}

/// Approximate float equality for tests.
pub fn approx_eq(a: f64, b: f64, eps: f64) -> bool {
    (a - b).abs() <= eps * a.abs().max(b.abs()).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deviation_pct_basic() {
        assert!((deviation_pct(110.0, 100.0) - 10.0).abs() < 1e-12);
        assert!((deviation_pct(100.0, 100.0)).abs() < 1e-12);
        assert_eq!(deviation_pct(5.0, 0.0), 0.0);
    }

    #[test]
    fn ratio_or_zero_basic() {
        assert_eq!(ratio_or_zero(10.0, 2.0), 5.0);
        assert_eq!(ratio_or_zero(10.0, 0.0), 0.0);
    }

    #[test]
    fn approx_eq_scales() {
        assert!(approx_eq(1000.0, 1000.1, 1e-3));
        assert!(!approx_eq(1000.0, 1010.0, 1e-3));
    }
}
