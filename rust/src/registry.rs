//! **The unified string-registry front door** — one module that knows
//! every name-to-object spelling the crate accepts.
//!
//! Eight subsystems grew eight string registries, each with its own
//! parse function, error type and help table: launch policies
//! ([`crate::sched::registry`]), search strategies
//! ([`crate::search::parse_strategy`]), route policies
//! ([`crate::fleet::parse_route_policy`]), window policies
//! ([`crate::online::parse_window_policy`]), arrival processes
//! ([`crate::online::ArrivalSpec::parse`]), fault plans
//! ([`crate::fault::FaultPlan::parse`]), admission policies
//! ([`crate::admission::parse_admission_policy`]) and trace sinks
//! ([`crate::obs::parse_trace_sink`]). They all still exist
//! and are still the single sources of truth for their spellings — this
//! module adds the *uniform* view on top:
//!
//! * [`parse_policy`] / [`parse_strategy`] / [`parse_route`] /
//!   [`parse_window`] / [`parse_arrivals`] / [`parse_fault_plan`] /
//!   [`parse_admission`] / [`parse_trace`] —
//!   thin wrappers that convert every subsystem's error into one
//!   [`ParseError`] carrying the registry kind, the echoed input, the
//!   subsystem's own diagnostic, **and** that kind's cheat sheet of
//!   valid spellings — so a CLI boundary gets a helpful failure without
//!   knowing which subsystem it was parsing for.
//! * [`kinds`] / [`list`] — enumerate the registries and render any
//!   kind's help table; `kreorder list [--kind <k>]` is a direct
//!   dispatch to these two functions (replacing the scattered
//!   `--list` / `--list-routes` / `--list-online` / `--list-faults`
//!   flags, which remain as aliases).
//!
//! Code that wants the typed error (to match on its fields) should keep
//! calling the subsystem parser directly; these wrappers are for
//! boundaries where every failure is reported the same way.

use crate::admission::{parse_admission_policy, AdmissionPolicy};
use crate::fault::FaultPlan;
use crate::fleet::{parse_route_policy, RoutePolicy};
use crate::obs::{parse_trace_sink, TraceSink};
use crate::online::{parse_window_policy, ArrivalSpec, WindowPolicy};
use crate::sched::LaunchPolicy;
use crate::search::SearchStrategy;
use std::fmt;

/// Every registry kind, in the order `kreorder list` prints them. The
/// strings are the `--kind` spellings.
pub const KINDS: &[&str] = &[
    "policy",
    "strategy",
    "route",
    "window",
    "arrivals",
    "fault-plan",
    "admission",
    "trace",
];

/// The registry kinds, for iteration ([`KINDS`] behind a function so
/// callers do not depend on the constant's type).
pub fn kinds() -> &'static [&'static str] {
    KINDS
}

/// Render one kind's cheat sheet of valid spellings (one per line,
/// indented — the same tables the subsystems print). `None` for an
/// unknown kind; [`KINDS`] lists the valid ones.
pub fn list(kind: &str) -> Option<String> {
    match kind {
        "policy" => Some(crate::sched::registry::help_table()),
        "strategy" => Some(crate::search::strategy_help_table()),
        "route" => Some(crate::fleet::route_policy_help_table()),
        "window" => Some(crate::online::window_policy_help_table()),
        "arrivals" => Some(crate::online::arrival_help_table()),
        "fault-plan" => Some(crate::fault::fault_plan_help_table()),
        "admission" => Some(crate::admission::admission_help_table()),
        "trace" => Some(crate::obs::trace_help_table()),
        _ => None,
    }
}

/// Uniform parse failure for every registry kind: which registry, the
/// echoed input, the subsystem's own diagnostic, and the kind's valid
/// spellings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// Which registry rejected the spelling (a [`KINDS`] entry).
    pub kind: &'static str,
    /// The rejected input, verbatim.
    pub input: String,
    /// The subsystem parser's own diagnostic (already echoes the input).
    pub detail: String,
}

impl ParseError {
    fn new(kind: &'static str, input: &str, detail: impl fmt::Display) -> ParseError {
        ParseError {
            kind,
            input: input.to_string(),
            detail: detail.to_string(),
        }
    }

    /// The cheat sheet of valid spellings for this error's kind.
    pub fn cheatsheet(&self) -> String {
        list(self.kind).unwrap_or_default()
    }
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "invalid {} spelling `{}`: {}\nvalid {} spellings:\n{}",
            self.kind,
            self.input,
            self.detail,
            self.kind,
            self.cheatsheet()
        )
    }
}

impl std::error::Error for ParseError {}

/// [`crate::sched::registry::parse`] with the uniform error.
pub fn parse_policy(s: &str) -> Result<Box<dyn LaunchPolicy>, ParseError> {
    crate::sched::registry::parse(s).map_err(|e| ParseError::new("policy", s, e))
}

/// [`crate::search::parse_strategy`] with the uniform error.
pub fn parse_strategy(s: &str) -> Result<Box<dyn SearchStrategy>, ParseError> {
    crate::search::parse_strategy(s).map_err(|e| ParseError::new("strategy", s, e))
}

/// [`crate::fleet::parse_route_policy`] with the uniform error.
pub fn parse_route(s: &str) -> Result<Box<dyn RoutePolicy>, ParseError> {
    parse_route_policy(s).map_err(|e| ParseError::new("route", s, e))
}

/// [`crate::online::parse_window_policy`] with the uniform error.
pub fn parse_window(s: &str) -> Result<Box<dyn WindowPolicy>, ParseError> {
    parse_window_policy(s).map_err(|e| ParseError::new("window", s, e))
}

/// [`crate::online::ArrivalSpec::parse`] with the uniform error.
pub fn parse_arrivals(s: &str) -> Result<ArrivalSpec, ParseError> {
    ArrivalSpec::parse(s).map_err(|e| ParseError::new("arrivals", s, e))
}

/// [`crate::fault::FaultPlan::parse`] with the uniform error.
pub fn parse_fault_plan(s: &str) -> Result<FaultPlan, ParseError> {
    FaultPlan::parse(s).map_err(|e| ParseError::new("fault-plan", s, e))
}

/// [`crate::admission::parse_admission_policy`] with the uniform error.
pub fn parse_admission(s: &str) -> Result<Box<dyn AdmissionPolicy>, ParseError> {
    parse_admission_policy(s).map_err(|e| ParseError::new("admission", s, e))
}

/// [`crate::obs::parse_trace_sink`] with the uniform error.
pub fn parse_trace(s: &str) -> Result<Box<dyn TraceSink>, ParseError> {
    parse_trace_sink(s).map_err(|e| ParseError::new("trace", s, e))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_kind_lists_a_nonempty_cheatsheet() {
        for &k in kinds() {
            let table = list(k).unwrap_or_else(|| panic!("kind {k} missing from list()"));
            assert!(!table.trim().is_empty(), "{k}");
        }
        assert!(list("nope").is_none());
    }

    #[test]
    fn wrappers_accept_what_the_subsystems_accept() {
        assert!(parse_policy("algorithm1").is_ok());
        assert!(parse_strategy("anneal:7").is_ok());
        assert!(parse_route("jsq").is_ok());
        assert!(parse_window("linger:8:50").is_ok());
        assert!(parse_arrivals("poisson:80:1").is_ok());
        assert!(parse_fault_plan("crash:0@50:recover@200").is_ok());
        assert!(parse_admission("deadline:50").is_ok());
        assert!(parse_trace("ring:256").is_ok());
    }

    #[test]
    fn uniform_errors_echo_input_kind_detail_and_cheatsheet() {
        let cases: [(&str, ParseError); 8] = [
            ("policy", parse_policy("blorp").unwrap_err()),
            ("strategy", parse_strategy("blorp").unwrap_err()),
            ("route", parse_route("blorp").unwrap_err()),
            ("window", parse_window("blorp").unwrap_err()),
            ("arrivals", parse_arrivals("blorp:1:2").unwrap_err()),
            ("fault-plan", parse_fault_plan("blorp:1@2").unwrap_err()),
            ("admission", parse_admission("blorp").unwrap_err()),
            ("trace", parse_trace("blorp").unwrap_err()),
        ];
        for (kind, err) in cases {
            assert_eq!(err.kind, kind);
            let msg = err.to_string();
            assert!(msg.contains("blorp"), "{kind}: {msg}");
            assert!(msg.contains(&format!("invalid {kind} spelling")), "{msg}");
            assert!(msg.contains(&format!("valid {kind} spellings")), "{msg}");
            assert!(!err.cheatsheet().trim().is_empty(), "{kind}");
            // The cheat sheet is multi-line (a real table, not a stub).
            assert!(err.cheatsheet().lines().count() >= 3, "{kind}");
        }
    }

    #[test]
    fn cheatsheets_name_representative_spellings() {
        assert!(list("policy").unwrap().contains("algorithm1"));
        assert!(list("strategy").unwrap().contains("anneal"));
        assert!(list("route").unwrap().contains("jsq"));
        assert!(list("window").unwrap().contains("linger"));
        assert!(list("arrivals").unwrap().contains("poisson"));
        assert!(list("fault-plan").unwrap().contains("crash"));
        assert!(list("admission").unwrap().contains("deadline"));
        assert!(list("trace").unwrap().contains("jsonl"));
    }
}
