//! Launch-order scheduling — the paper's contribution (Algorithm 1) plus
//! the baseline policies it is evaluated against.
//!
//! * [`reorder`] / [`reorder_with`] — the greedy concurrent-kernel launch
//!   order algorithm: select the highest-scoring kernel pair per execution
//!   round, then grow the round greedily by score against the round's
//!   combined profile, sorting round members by decreasing shared-memory
//!   usage.
//! * [`score`] — ScoreGen: normalized leftover of the three SM resources
//!   plus the compute/memory balance term gated on opposing kernel types.
//! * [`CombinedProfile`] — ProfileCombine: the virtual kernel that stands
//!   in for everything already packed into a round.
//! * [`LaunchPolicy`] — the open policy trait: FIFO / Reverse / Random /
//!   Algorithm-1 plus SJF and a Kernelet-style greedy co-schedule, behind
//!   one interface the coordinator, CLI, benches and experiment harness
//!   all dispatch through. New policies are one `impl` + one
//!   [`registry`] line.
//! * [`registry`] — string spellings (`"fifo"`, `"random:42"`, …) to
//!   trait objects, with error messages that list every valid name.
//!
//! The pre-0.2 closed-enum `Policy` shim (and the coordinator's
//! `CoordinatorConfig` twin) rode out their one deprecation release and
//! are gone; every selection path goes through [`registry::parse`] or a
//! [`LaunchPolicy`] value.

mod algorithm;
mod launch_policy;
pub mod registry;
mod score;

pub use algorithm::{reorder, reorder_with, Schedule};
pub use launch_policy::{
    Algorithm1Policy, FifoPolicy, GreedyCoschedulePolicy, LaunchPolicy, RandomPolicy,
    ReversePolicy, SjfPolicy,
};
pub use registry::PolicyParseError;
pub use score::{score, CombinedProfile, RoundOrder, ScoreConfig};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{AppKind, GpuSpec, KernelProfile};

    pub(crate) fn kernel(
        name: &str,
        n_blocks: u32,
        warps: u32,
        shmem: u32,
        ratio: f64,
    ) -> KernelProfile {
        KernelProfile {
            name: name.into(),
            app: AppKind::Synthetic,
            n_blocks,
            regs_per_block: 512,
            shmem_per_block: shmem,
            warps_per_block: warps,
            ratio,
            work_per_block: 100.0,
            artifact: String::new(),
        }
    }

    /// End-to-end sanity: on a workload designed to reward mixing,
    /// Algorithm 1 must beat FIFO in the simulator.
    #[test]
    fn algorithm_beats_fifo_on_mixed_workload() {
        let gpu = GpuSpec::gtx580();
        // FIFO packs the two memory-bound kernels together (warps bind at
        // 2 per round); the algorithm should pair opposing types.
        let ks = vec![
            kernel("mem1", 16, 24, 0, 1.0),
            kernel("mem2", 16, 24, 0, 1.0),
            kernel("cmp1", 16, 24, 0, 40.0),
            kernel("cmp2", 16, 24, 0, 40.0),
        ];
        let sched = reorder(&gpu, &ks);
        let fifo: Vec<usize> = (0..ks.len()).collect();
        let t_alg = crate::sim::simulate_order(&gpu, &ks, &sched.order).makespan_ms;
        let t_fifo = crate::sim::simulate_order(&gpu, &ks, &fifo).makespan_ms;
        assert!(
            t_alg < t_fifo,
            "algorithm {t_alg} ms !< fifo {t_fifo} ms (order {:?})",
            sched.order
        );
    }
}
